"""L1 — Pallas kernels for the two-layer linear RMI (LearnedSort's CDF model).

Two kernels:

* ``rmi_predict``: batched two-level RMI inference. For each key ``x``:
  ``i = clamp(floor(B * (a1*x + b1)))`` selects a leaf, then
  ``F(x) = clamp(a2[i]*x + b2[i], lo[i], hi[i])`` where ``[lo, hi]`` is the
  per-leaf monotonic envelope (the paper's min/max-array construction,
  Section 4). The envelope + nonnegative leaf slopes make F globally
  monotone, which is what lets AIPS2o skip the insertion-sort repair pass.

* ``rmi_train_stats``: the segmented-reduction pass of training. Per-leaf
  least-squares needs (count, Σx, Σy, Σxy, Σx²) per leaf; a scatter-add is
  hostile to the TPU, so we restructure it as ``onehot(leaf_ids).T @ feats``
  — an (B×bn)·(bn×5) matmul that lands on the MXU systolic array. The
  (B,5) output accumulates across grid steps.

TPU adaptation notes (paper targets an AVX Xeon — see DESIGN.md
§Hardware-Adaptation): keys stream HBM→VMEM in 1-D grid blocks; the leaf
parameter table (B=1024 × 4 f64 = 32 KiB) is pinned in VMEM across all grid
steps via a constant index_map, the TPU analogue of LearnedSort keeping the
RMI second-level array cache-resident.

Kernels MUST run with ``interpret=True`` here: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Keys per grid step. 8 sublanes x 128 lanes x 8 "rows" — a multiple of the
# (8, 128) f32 tile so the block maps cleanly onto the VPU/MXU layout.
PREDICT_BLOCK = 8192
TRAIN_BLOCK = 2048

# F(x) is clamped to [0, 1). Downstream bucket index is floor(F(x) * B'),
# so keep strictly below 1.0 to avoid an out-of-range bucket.
ONE_MINUS_EPS = 1.0 - 2.0**-52


def _predict_kernel(root_ref, leaf_ref, keys_ref, out_ref, *, n_leaves):
    """One grid step: classify PREDICT_BLOCK keys through the 2-level RMI."""
    a1 = root_ref[0]
    b1 = root_ref[1]
    # +-inf inputs would produce NaN through a slope-0 leaf (0*inf);
    # clamp to the finite range — mirrored in rust/src/rmi/model.rs.
    x = jnp.clip(keys_ref[...], jnp.finfo(keys_ref.dtype).min, jnp.finfo(keys_ref.dtype).max)
    # Root model: coarse CDF estimate -> leaf index.
    coarse = a1 * x + b1
    idx = jnp.clip(
        jnp.floor(coarse * n_leaves), 0, n_leaves - 1
    ).astype(jnp.int32)
    leaf = leaf_ref[...]  # (B, 4) pinned in VMEM: [a2, b2, lo, hi]
    a2 = jnp.take(leaf[:, 0], idx)
    b2 = jnp.take(leaf[:, 1], idx)
    lo = jnp.take(leaf[:, 2], idx)
    hi = jnp.take(leaf[:, 3], idx)
    pred = jnp.clip(a2 * x + b2, lo, hi)
    out_ref[...] = jnp.clip(pred, 0.0, ONE_MINUS_EPS)


def rmi_predict(keys, root, leaf, *, block=PREDICT_BLOCK, interpret=True):
    """Batched RMI CDF prediction.

    Args:
      keys: f64[n] keys, n a multiple of ``block``.
      root: f64[2] root linear model (a1, b1).
      leaf: f64[B, 4] per-leaf (a2, b2, lo, hi).

    Returns:
      f64[n] CDF estimates in [0, 1).
    """
    n = keys.shape[0]
    assert n % block == 0, f"n={n} must be a multiple of block={block}"
    n_leaves = leaf.shape[0]
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_predict_kernel, n_leaves=n_leaves),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),            # root: pinned
            pl.BlockSpec(leaf.shape, lambda i: (0, 0)),    # leaf: pinned
            pl.BlockSpec((block,), lambda i: (i,)),        # keys: streamed
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), keys.dtype),
        interpret=interpret,
    )(root, leaf, keys)


def _train_stats_kernel(root_ref, keys_ref, ys_ref, out_ref, *, n_leaves):
    """One grid step: accumulate per-leaf regression statistics.

    out[b, :] += sum over keys in this block assigned to leaf b of
    (1, x, y, x*y, x*x). Expressed as onehot.T @ feats so it is a matmul
    (MXU) rather than a scatter-add.
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a1 = root_ref[0]
    b1 = root_ref[1]
    x = keys_ref[...]
    y = ys_ref[...]
    idx = jnp.clip(
        jnp.floor((a1 * x + b1) * n_leaves), 0, n_leaves - 1
    ).astype(jnp.int32)
    onehot = (idx[:, None] == jnp.arange(n_leaves)[None, :]).astype(x.dtype)
    feats = jnp.stack(
        [jnp.ones_like(x), x, y, x * y, x * x], axis=1
    )  # (bn, 5)
    out_ref[...] += onehot.T @ feats


def rmi_train_stats(
    keys, ys, root, *, n_leaves, block=TRAIN_BLOCK, interpret=True
):
    """Per-leaf regression statistics for the leaf least-squares fits.

    Args:
      keys: f64[n] *sorted* sample keys, n a multiple of ``block``.
      ys:   f64[n] empirical CDF targets (j + 0.5)/n.
      root: f64[2] already-fitted root model.

    Returns:
      f64[n_leaves, 5]: per-leaf (count, Σx, Σy, Σxy, Σx²).
    """
    n = keys.shape[0]
    assert n % block == 0, f"n={n} must be a multiple of block={block}"
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_train_stats_kernel, n_leaves=n_leaves),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_leaves, 5), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_leaves, 5), keys.dtype),
        interpret=interpret,
    )(root, keys, ys)
