"""Pure-jnp oracle for the RMI kernels — the correctness reference.

Implements exactly the same arithmetic as kernels/rmi.py without Pallas, so
pytest/hypothesis can assert_allclose kernel-vs-ref across shapes and
distributions. Also the reference for the native Rust implementation
(rust/src/rmi/), which mirrors this op-for-op.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

ONE_MINUS_EPS = 1.0 - 2.0**-52


def ref_predict(keys, root, leaf):
    """Reference two-level RMI CDF prediction. Same contract as rmi_predict."""
    a1, b1 = root[0], root[1]
    keys = jnp.clip(keys, jnp.finfo(keys.dtype).min, jnp.finfo(keys.dtype).max)
    n_leaves = leaf.shape[0]
    idx = jnp.clip(
        jnp.floor((a1 * keys + b1) * n_leaves), 0, n_leaves - 1
    ).astype(jnp.int32)
    a2 = leaf[idx, 0]
    b2 = leaf[idx, 1]
    lo = leaf[idx, 2]
    hi = leaf[idx, 3]
    pred = jnp.clip(a2 * keys + b2, lo, hi)
    return jnp.clip(pred, 0.0, ONE_MINUS_EPS)


def ref_train_stats(keys, ys, root, *, n_leaves):
    """Reference per-leaf regression statistics. Same contract as
    rmi_train_stats, computed with a segment-sum instead of Pallas."""
    a1, b1 = root[0], root[1]
    idx = jnp.clip(
        jnp.floor((a1 * keys + b1) * n_leaves), 0, n_leaves - 1
    ).astype(jnp.int32)
    feats = jnp.stack(
        [jnp.ones_like(keys), keys, ys, keys * ys, keys * keys], axis=1
    )
    return jax.ops.segment_sum(feats, idx, num_segments=n_leaves)


def ref_fit_root(keys, ys):
    """Closed-form least-squares root fit (see model.fit_root)."""
    n = keys.shape[0]
    sx = jnp.sum(keys)
    sy = jnp.sum(ys)
    sxy = jnp.sum(keys * ys)
    sxx = jnp.sum(keys * keys)
    denom = n * sxx - sx * sx
    a = jnp.where(jnp.abs(denom) > 0, (n * sxy - sx * sy) / denom, 0.0)
    a = jnp.maximum(a, 0.0)
    b = (sy - a * sx) / n
    return jnp.stack([a, b])


def ref_fit_leaves(stats):
    """Closed-form per-leaf fits + monotonic envelope from leaf stats.

    stats: f64[B, 5] per-leaf (count, Σx, Σy, Σxy, Σx²).
    Returns f64[B, 4] per-leaf (a2, b2, lo, hi) with a2 >= 0 and
    lo/hi the cumulative empirical-CDF envelope (nondecreasing), which
    together guarantee global monotonicity of the predicted CDF.
    """
    cnt, sx, sy, sxy, sxx = (stats[:, i] for i in range(5))
    denom = cnt * sxx - sx * sx
    ok = (cnt >= 2) & (jnp.abs(denom) > 1e-30)
    a2 = jnp.where(ok, (cnt * sxy - sx * sy) / jnp.where(ok, denom, 1.0), 0.0)
    a2 = jnp.maximum(a2, 0.0)
    b2 = jnp.where(cnt > 0, (sy - a2 * sx) / jnp.where(cnt > 0, cnt, 1.0), 0.0)
    total = jnp.sum(cnt)
    cum = jnp.concatenate([jnp.zeros((1,), stats.dtype), jnp.cumsum(cnt)])
    lo = cum[:-1] / total
    hi = cum[1:] / total
    return jnp.stack([a2, b2, lo, hi], axis=1)
