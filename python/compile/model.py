"""L2 — the JAX RMI model: training and batched prediction.

This is LearnedSort's CDF model (two-layer linear RMI, Kristo et al. SIGMOD
'20) with the monotonicity constraint from the AIPS2o paper (Section 4):
leaf slopes are clamped nonnegative and leaf outputs are clamped to the
cumulative empirical-CDF envelope [lo_i, hi_i], so F(x) is globally
nondecreasing and the partition needs no insertion-sort repair.

Both entry points are pure jax functions built on the L1 Pallas kernels
(kernels/rmi.py) and are AOT-lowered by aot.py into HLO text artifacts the
Rust runtime loads via PJRT. Python never runs at sort time.

Model parameterization (shared contract with rust/src/rmi/):
  root: f64[2]      = (a1, b1);     leaf index = clamp(floor((a1*x+b1)*B))
  leaf: f64[B, 4]   = (a2, b2, lo, hi) per leaf; F(x) = clip(a2*x+b2, lo, hi)
"""

import jax
import jax.numpy as jnp

from .kernels import rmi as k
from .kernels import ref

jax.config.update("jax_enable_x64", True)

# AOT artifact shapes (fixed: PJRT executables are static-shaped; the Rust
# runtime pads/chunks to these).
TRAIN_SAMPLE = 16384
PREDICT_BATCH = 65536
N_LEAVES = 1024


def fit_root(sample, ys):
    """Least-squares linear fit of the root model on the sorted sample.

    The slope is clamped nonnegative: the root must be monotone for the
    leaf assignment i(x) to be nondecreasing in x.
    """
    return ref.ref_fit_root(sample, ys)


def rmi_train(sample, *, n_leaves=N_LEAVES, interpret=True, block=None):
    """Train the monotonic two-layer RMI from a *sorted* sample.

    Args:
      sample: f64[n] sorted keys (duplicates allowed).

    Returns:
      (root f64[2], leaf f64[n_leaves, 4]).
    """
    n = sample.shape[0]
    ys = (jnp.arange(n, dtype=sample.dtype) + 0.5) / n
    root = fit_root(sample, ys)
    kwargs = {} if block is None else {"block": block}
    stats = k.rmi_train_stats(
        sample, ys, root, n_leaves=n_leaves, interpret=interpret, **kwargs
    )
    leaf = ref.ref_fit_leaves(stats)
    return root, leaf


def rmi_predict(keys, root, leaf, *, interpret=True, block=None):
    """Batched CDF prediction F(keys) in [0, 1). See kernels.rmi."""
    kwargs = {} if block is None else {"block": block}
    return k.rmi_predict(keys, root, leaf, interpret=interpret, **kwargs)


def rmi_train_ref(sample, *, n_leaves=N_LEAVES):
    """Pure-jnp training oracle (no Pallas) for tests."""
    n = sample.shape[0]
    ys = (jnp.arange(n, dtype=sample.dtype) + 0.5) / n
    root = fit_root(sample, ys)
    stats = ref.ref_train_stats(sample, ys, root, n_leaves=n_leaves)
    leaf = ref.ref_fit_leaves(stats)
    return root, leaf


# ---------------------------------------------------------------------------
# AOT entry points (fixed shapes, single output pytrees -> flat tuples)
# ---------------------------------------------------------------------------

def aot_train(sample):
    """AOT graph: f64[TRAIN_SAMPLE] sorted sample -> (root, leaf)."""
    root, leaf = rmi_train(sample)
    return root, leaf


def aot_predict(keys, root, leaf):
    """AOT graph: batched prediction at the fixed PREDICT_BATCH size."""
    return (rmi_predict(keys, root, leaf),)
