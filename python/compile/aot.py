"""AOT export: lower the L2 RMI model to HLO *text* artifacts for Rust/PJRT.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry_points():
    """Lower both AOT entry points; returns {name: (hlo_text, signature)}."""
    f64 = jnp.float64
    sample = jax.ShapeDtypeStruct((model.TRAIN_SAMPLE,), f64)
    keys = jax.ShapeDtypeStruct((model.PREDICT_BATCH,), f64)
    root = jax.ShapeDtypeStruct((2,), f64)
    leaf = jax.ShapeDtypeStruct((model.N_LEAVES, 4), f64)

    out = {}
    lowered = jax.jit(model.aot_train).lower(sample)
    out["rmi_train"] = (
        to_hlo_text(lowered),
        {
            "inputs": [["sample", list(sample.shape), "f64"]],
            "outputs": [
                ["root", [2], "f64"],
                ["leaf", [model.N_LEAVES, 4], "f64"],
            ],
        },
    )
    lowered = jax.jit(model.aot_predict).lower(keys, root, leaf)
    out["rmi_predict"] = (
        to_hlo_text(lowered),
        {
            "inputs": [
                ["keys", list(keys.shape), "f64"],
                ["root", [2], "f64"],
                ["leaf", [model.N_LEAVES, 4], "f64"],
            ],
            "outputs": [["cdf", [model.PREDICT_BATCH], "f64"]],
        },
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "jax": jax.__version__,
        "train_sample": model.TRAIN_SAMPLE,
        "predict_batch": model.PREDICT_BATCH,
        "n_leaves": model.N_LEAVES,
        "functions": {},
    }
    for name, (text, sig) in lower_entry_points().items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["functions"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            **sig,
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
