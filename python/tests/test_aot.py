"""AOT export checks: the HLO artifacts are well-formed, static-shaped, and
numerically identical to executing the jitted model directly.

Also the L2 perf gate from DESIGN.md §7: an HLO op census asserting the
lowered graphs contain no scatter (training is matmul-shaped, not
scatter-add) and no dynamic shapes.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def lowered():
    return aot.lower_entry_points()


def test_entry_points_present(lowered):
    assert set(lowered) == {"rmi_train", "rmi_predict"}


def test_hlo_text_parses_as_module(lowered):
    for name, (text, _) in lowered.items():
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text


def test_hlo_static_shapes(lowered):
    for name, (text, _) in lowered.items():
        assert "<=:" not in text and "?x" not in text, f"{name} has dynamic shapes"


def test_hlo_no_scatter_in_train(lowered):
    """Training statistics must lower to dot/reduce, not scatter-add."""
    text, _ = lowered["rmi_train"]
    census = [l for l in text.splitlines() if " scatter(" in l]
    assert not census, f"scatter ops in rmi_train HLO: {census[:3]}"


def test_signatures_match_model_constants(lowered):
    _, sig = lowered["rmi_predict"]
    assert sig["inputs"][0][1] == [model.PREDICT_BATCH]
    assert sig["inputs"][2][1] == [model.N_LEAVES, 4]
    _, sig = lowered["rmi_train"]
    assert sig["inputs"][0][1] == [model.TRAIN_SAMPLE]


def test_hlo_text_reparses(lowered):
    """The exported text must parse back into an HloModule — the same parser
    the Rust runtime's `HloModuleProto::from_text_file` wraps. (Numeric
    roundtrip through PJRT is covered by rust/tests/pjrt_parity.rs, the
    actual consumer; this jaxlib cannot execute a reparsed HLO module.)"""
    from jax._src.lib import xla_client as xc

    for name, (text, _) in lowered.items():
        mod = xc._xla.hlo_module_from_text(text)
        proto = mod.as_serialized_hlo_module_proto()
        assert len(proto) > 0, f"{name}: empty reserialized module"


def test_aot_main_writes_artifacts(tmp_path):
    """End-to-end: `python -m compile.aot` writes artifacts + manifest."""
    env = dict(os.environ)
    cwd = os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__)))
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
        cwd=cwd,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["n_leaves"] == model.N_LEAVES
    for fn in manifest["functions"].values():
        assert (tmp_path / fn["file"]).exists()
