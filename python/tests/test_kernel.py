"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the learned-model layer: every
numeric the Rust hot path depends on is validated here against an
independent implementation, across shapes, dtypes and key distributions
(hypothesis sweeps).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import model
from compile.kernels import ref
from compile.kernels import rmi as k

jax.config.update("jax_enable_x64", True)

RNG = np.random.default_rng(0xA1B5)


def _trained_model(sample_n=4096, n_leaves=64, dist="uniform"):
    sample = make_keys(sample_n, dist)
    sample = np.sort(sample)
    root, leaf = model.rmi_train(
        jnp.asarray(sample), n_leaves=n_leaves, block=1024
    )
    return np.asarray(root), np.asarray(leaf)


def make_keys(n, dist, rng=None):
    rng = rng or RNG
    if dist == "uniform":
        return rng.uniform(0.0, n, n)
    if dist == "normal":
        return rng.normal(0.0, 1.0, n)
    if dist == "lognormal":
        return rng.lognormal(0.0, 0.5, n)
    if dist == "exponential":
        return rng.exponential(0.5, n)
    if dist == "dups":
        return np.asarray(rng.integers(0, max(2, n // 100), n), dtype=np.float64)
    if dist == "constant":
        return np.full(n, 42.0)
    raise ValueError(dist)


DISTS = ["uniform", "normal", "lognormal", "exponential", "dups", "constant"]


# ---------------------------------------------------------------------------
# predict kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", DISTS)
def test_predict_matches_ref(dist):
    root, leaf = _trained_model(dist=dist if dist != "constant" else "uniform")
    keys = jnp.asarray(make_keys(8192, dist))
    got = k.rmi_predict(keys, jnp.asarray(root), jnp.asarray(leaf), block=1024)
    want = ref.ref_predict(keys, jnp.asarray(root), jnp.asarray(leaf))
    # interpret-mode pallas may fuse a*x+b as an FMA: allow 1-2 ulp
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-14, atol=1e-15)


@pytest.mark.parametrize("block", [128, 512, 2048, 8192])
def test_predict_block_invariance(block):
    """Output must not depend on the grid/block decomposition."""
    root, leaf = _trained_model()
    keys = jnp.asarray(make_keys(8192, "uniform"))
    got = k.rmi_predict(keys, jnp.asarray(root), jnp.asarray(leaf), block=block)
    want = ref.ref_predict(keys, jnp.asarray(root), jnp.asarray(leaf))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_predict_output_range():
    root, leaf = _trained_model()
    keys = jnp.asarray(make_keys(4096, "normal") * 1e6)  # far out of range
    out = np.asarray(k.rmi_predict(keys, jnp.asarray(root), jnp.asarray(leaf), block=1024))
    assert np.all(out >= 0.0)
    assert np.all(out < 1.0)


def test_predict_rejects_misaligned_batch():
    root, leaf = _trained_model()
    keys = jnp.asarray(make_keys(1000, "uniform"))
    with pytest.raises(AssertionError):
        k.rmi_predict(keys, jnp.asarray(root), jnp.asarray(leaf), block=512)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.sampled_from(DISTS),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_predict_hypothesis_sweep(nblocks, dist, seed):
    """Hypothesis sweep: kernel == oracle over random shapes/dists/seeds."""
    rng = np.random.default_rng(seed)
    root, leaf = _trained_model()
    keys = jnp.asarray(make_keys(256 * nblocks, dist, rng))
    got = k.rmi_predict(keys, jnp.asarray(root), jnp.asarray(leaf), block=256)
    want = ref.ref_predict(keys, jnp.asarray(root), jnp.asarray(leaf))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# train-stats kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["uniform", "normal", "lognormal", "dups"])
def test_train_stats_matches_ref(dist):
    n, n_leaves = 4096, 128
    sample = np.sort(make_keys(n, dist))
    ys = (np.arange(n) + 0.5) / n
    root = ref.ref_fit_root(jnp.asarray(sample), jnp.asarray(ys))
    got = k.rmi_train_stats(
        jnp.asarray(sample), jnp.asarray(ys), root, n_leaves=n_leaves, block=512
    )
    want = ref.ref_train_stats(
        jnp.asarray(sample), jnp.asarray(ys), root, n_leaves=n_leaves
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)


def test_train_stats_counts_total():
    n, n_leaves = 2048, 64
    sample = np.sort(make_keys(n, "uniform"))
    ys = (np.arange(n) + 0.5) / n
    root = ref.ref_fit_root(jnp.asarray(sample), jnp.asarray(ys))
    stats = np.asarray(
        k.rmi_train_stats(
            jnp.asarray(sample), jnp.asarray(ys), root, n_leaves=n_leaves, block=512
        )
    )
    assert stats[:, 0].sum() == pytest.approx(n)


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([256, 512, 1024]),
    st.sampled_from([16, 64, 256]),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_train_stats_hypothesis_sweep(block, n_leaves, seed):
    rng = np.random.default_rng(seed)
    n = 2048
    sample = np.sort(make_keys(n, "uniform", rng))
    ys = (np.arange(n) + 0.5) / n
    root = ref.ref_fit_root(jnp.asarray(sample), jnp.asarray(ys))
    got = k.rmi_train_stats(
        jnp.asarray(sample), jnp.asarray(ys), root, n_leaves=n_leaves, block=block
    )
    want = ref.ref_train_stats(
        jnp.asarray(sample), jnp.asarray(ys), root, n_leaves=n_leaves
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12)
