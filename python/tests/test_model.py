"""L2 correctness: trained-model invariants (monotonicity, accuracy, shapes).

These are the properties the AIPS2o paper needs from the model (Section 4):
a monotone F means the learned partition is exact and no insertion-sort
repair pass is required.
"""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import model

jax.config.update("jax_enable_x64", True)

RNG = np.random.default_rng(0xC0FFEE)


def make_sample(n, dist, rng=None):
    rng = rng or RNG
    if dist == "uniform":
        x = rng.uniform(0, 1e6, n)
    elif dist == "normal":
        x = rng.normal(0, 1, n)
    elif dist == "lognormal":
        x = rng.lognormal(0, 0.5, n)
    elif dist == "zipfish":
        x = np.floor(rng.pareto(1.5, n) * 100)
    elif dist == "dups":
        x = np.asarray(rng.integers(0, 50, n), dtype=np.float64)
    else:
        raise ValueError(dist)
    return np.sort(x)


DISTS = ["uniform", "normal", "lognormal", "zipfish", "dups"]


@pytest.mark.parametrize("dist", DISTS)
def test_monotone_on_sorted_input(dist):
    """F must be nondecreasing over a sorted key stream — the paper's core
    requirement for skipping the correction pass."""
    sample = make_sample(4096, dist)
    root, leaf = model.rmi_train(jnp.asarray(sample), n_leaves=256, block=1024)
    probe = np.sort(make_sample(8192, dist))
    cdf = np.asarray(
        model.rmi_predict(jnp.asarray(probe), root, leaf, block=1024)
    )
    assert np.all(np.diff(cdf) >= 0.0), f"inversions in predicted CDF ({dist})"


@pytest.mark.parametrize("dist", ["uniform", "normal", "lognormal"])
def test_cdf_accuracy(dist):
    """Predicted CDF should track the empirical CDF on smooth distributions."""
    sample = make_sample(8192, dist)
    root, leaf = model.rmi_train(jnp.asarray(sample), n_leaves=256, block=1024)
    probe = np.sort(make_sample(8192, dist))
    cdf = np.asarray(model.rmi_predict(jnp.asarray(probe), root, leaf, block=1024))
    emp = (np.arange(len(probe)) + 0.5) / len(probe)
    err = np.abs(cdf - emp).mean()
    assert err < 0.02, f"mean |F - empirical| = {err} too high for {dist}"


def test_leaf_envelope_nondecreasing():
    sample = make_sample(4096, "lognormal")
    _, leaf = model.rmi_train(jnp.asarray(sample), n_leaves=128, block=1024)
    leaf = np.asarray(leaf)
    lo, hi = leaf[:, 2], leaf[:, 3]
    assert np.all(lo <= hi + 1e-15)
    assert np.all(hi[:-1] <= lo[1:] + 1e-15)  # envelope tiles [0,1)
    assert np.all(leaf[:, 0] >= 0.0)  # nonnegative leaf slopes


def test_train_constant_input():
    """All-equal sample: degenerate fit must not NaN and must stay in range."""
    sample = np.full(2048, 7.25)
    root, leaf = model.rmi_train(jnp.asarray(sample), n_leaves=64, block=1024)
    assert np.all(np.isfinite(np.asarray(root)))
    assert np.all(np.isfinite(np.asarray(leaf)))
    cdf = np.asarray(
        model.rmi_predict(jnp.full((1024,), 7.25), root, leaf, block=1024)
    )
    assert np.all((cdf >= 0) & (cdf < 1))


def test_pallas_and_ref_training_agree():
    sample = make_sample(4096, "normal")
    root_a, leaf_a = model.rmi_train(jnp.asarray(sample), n_leaves=128, block=512)
    root_b, leaf_b = model.rmi_train_ref(jnp.asarray(sample), n_leaves=128)
    np.testing.assert_allclose(np.asarray(root_a), np.asarray(root_b), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(leaf_a), np.asarray(leaf_b), rtol=1e-9, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from(DISTS),
    st.sampled_from([32, 128, 512]),
    st.integers(min_value=0, max_value=2**32 - 1),
)
def test_monotone_hypothesis(dist, n_leaves, seed):
    rng = np.random.default_rng(seed)
    sample = make_sample(2048, dist, rng)
    root, leaf = model.rmi_train(jnp.asarray(sample), n_leaves=n_leaves, block=1024)
    probe = np.sort(make_sample(2048, dist, rng))
    cdf = np.asarray(model.rmi_predict(jnp.asarray(probe), root, leaf, block=1024))
    assert np.all(np.diff(cdf) >= 0.0)
    assert np.all((cdf >= 0.0) & (cdf < 1.0))
