//! End-to-end driver — proves all layers compose on a real small workload.
//!
//! Pipeline (a miniature of the paper's whole evaluation):
//!   1. `make artifacts` output (JAX/Pallas AOT) loads through PJRT; the
//!      XLA-trained monotonic RMI is checked against the native mirror.
//!   2. The 14-dataset suite is generated.
//!   3. Table 2 (pivot quality) is regenerated.
//!   4. A sort-service job trace (every dataset, sequential + parallel
//!      engines) runs through the L3 coordinator with routing + metrics.
//!   5. The paper's headline metric is reported: parallel win count for
//!      AIPS2o vs IPS4o/IPS2Ra/std over all datasets.
//!
//!     make artifacts && cargo run --release --example e2e_pipeline

use aipso::bench_harness::{count_wins, run_figure, BenchConfig};
use aipso::coordinator::{Coordinator, JobSpec, KeyBuf};
use aipso::datasets::{self, FigureGroup, KeyType};
use aipso::rmi::model::{Rmi, RmiConfig};
use aipso::runtime::{default_artifacts_dir, RmiRuntime};
use aipso::util::rng::Xoshiro256pp;
use aipso::util::fmt;

fn main() {
    let n: usize = std::env::var("AIPSO_N").ok().and_then(|v| v.parse().ok()).unwrap_or(1_000_000);
    let t_all = std::time::Instant::now();
    println!("=== AIPS2o end-to-end pipeline (n = {}) ===\n", fmt::keys(n));

    // ---- 1. AOT artifact path (L1/L2 -> runtime bridge) ---------------
    println!("[1/5] PJRT artifacts");
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let rt = RmiRuntime::load(&dir).expect("artifact load");
        let m = rt.manifest();
        println!("  loaded rmi_train + rmi_predict (train_sample={}, batch={}, B={})",
            m.train_sample, m.predict_batch, m.n_leaves);
        let mut rng = Xoshiro256pp::new(1);
        let mut sample: Vec<f64> = (0..m.train_sample).map(|_| rng.lognormal(0.0, 0.5)).collect();
        sample.sort_unstable_by(f64::total_cmp);
        let xla = rt.train(&sample).expect("xla train");
        let native = Rmi::train(&sample, RmiConfig { n_leaves: m.n_leaves });
        let keys: Vec<f64> = (0..8192).map(|_| rng.lognormal(0.0, 0.5)).collect();
        let pred = rt.predict(&keys, &xla).expect("xla predict");
        let max_err = keys.iter().zip(&pred)
            .map(|(k, p)| (native.predict(*k) - p).abs())
            .fold(0.0f64, f64::max);
        println!("  XLA vs native RMI parity: max err {max_err:.2e} {}",
            if max_err < 1e-9 { "(OK)" } else { "(FAIL)" });
        assert!(max_err < 1e-9);
    } else {
        println!("  SKIPPED (no artifacts; run `make artifacts`)");
    }

    // ---- 2. dataset suite ---------------------------------------------
    println!("\n[2/5] dataset suite: {} datasets", datasets::ALL.len());

    // ---- 3. Table 2 ----------------------------------------------------
    println!("\n[3/5] Table 2 (pivot quality, 255 pivots)");
    let cfg = BenchConfig { n, reps: 1, ..Default::default() };
    for (name, q_random, q_rmi) in aipso::bench_harness::table2_pivot_quality(&cfg) {
        println!("  {name:<10} random {q_random:.4}  rmi {q_rmi:.4}  ({})",
            if q_rmi < q_random { "learned pivots better, as in paper" } else { "UNEXPECTED" });
    }

    // ---- 4. coordinator job trace --------------------------------------
    println!("\n[4/5] sort-service trace through the coordinator");
    let coordinator = Coordinator::new(0);
    let mut id = 0u64;
    for ds in datasets::ALL.iter() {
        let keys = match ds.key_type {
            KeyType::F64 => KeyBuf::F64(datasets::generate_f64(ds.name, n / 2, id).unwrap()),
            KeyType::U64 => KeyBuf::U64(datasets::generate_u64(ds.name, n / 2, id).unwrap()),
        };
        coordinator.submit(JobSpec::auto(id, keys));
        id += 1;
    }
    let (reports, metrics) = coordinator.drain();
    let failures = reports.iter().filter(|r| !r.verified_sorted).count();
    println!("  {} jobs, {} failures", reports.len(), failures);
    print!("{}", indent(&metrics.report(), "  "));
    assert_eq!(failures, 0);

    // ---- 5. headline: parallel win count --------------------------------
    // On boxes with fewer cores than the paper's 48 the ranking comes from
    // the partition-balance model over measured partitions (DESIGN.md §6).
    let cores = aipso::scheduler::effective_threads(0);
    println!("\n[5/5] headline metric: parallel win count over all 14 datasets");
    let cfg = BenchConfig { n, reps: 1, ..Default::default() };
    let mut rows = Vec::new();
    for group in [FigureGroup::Synthetic1, FigureGroup::Synthetic2, FigureGroup::RealWorld] {
        if cores >= 8 {
            rows.extend(run_figure(group, true, &cfg));
        } else {
            rows.extend(aipso::bench_harness::run_figure_simulated(group, 48, &cfg));
        }
    }
    let label = if cores >= 8 {
        format!("measured on {cores} cores")
    } else {
        "simulated 48 cores from measured partitions".to_string()
    };
    println!("  ({label})");
    for (engine, wins) in count_wins(&rows) {
        println!("  {engine}: {wins}/14");
    }
    println!("  (paper: AIPS2o 10/14, IPS4o 4/14, at N=1e8 on 48 cores)");
    println!("\n=== pipeline complete in {} ===", fmt::secs(t_all.elapsed().as_secs_f64()));
}

fn indent(s: &str, pad: &str) -> String {
    s.lines().map(|l| format!("{pad}{l}\n")).collect()
}
