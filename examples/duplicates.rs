//! Duplicates — the adversarial case for learned sorting (paper §2.2, §4).
//!
//! Shows Algorithm 5 in action: on duplicate-heavy inputs AIPS²o detects
//! the skew in its probe sample and routes to the decision tree with
//! equality buckets instead of the RMI; LearnedSort 2.0 survives via its
//! homogeneity check.
//!
//!     cargo run --release --example duplicates

use aipso::aips2o::{build_partition_model, StrategyConfig};
use aipso::util::rng::Xoshiro256pp;
use aipso::util::{fmt, timer};
use aipso::{is_sorted, sort_parallel, sort_sequential, SortEngine};

fn main() {
    let n = 2_000_000;
    let mut rng = Xoshiro256pp::new(1);
    println!("inputs: RootDups (A[i] = i mod sqrt N), Zipf(0.75), Uniform\n");

    for name in ["root_dups", "zipf", "uniform"] {
        let keys = aipso::datasets::generate_f64(name, n, 5).unwrap();
        // What does Algorithm 5 decide?
        let strategy = build_partition_model(&keys, &StrategyConfig::default(), &mut rng);
        let choice = match &strategy {
            None => "input constant (already sorted)",
            Some(s) if s.is_learned() => "RMI (learned classifier, B=1024)",
            Some(_) => "decision tree with equality buckets (B=256)",
        };
        println!("{name}: Algorithm 5 chooses -> {choice}");

        for engine in [SortEngine::Aips2o, SortEngine::Ips4o, SortEngine::LearnedSort] {
            let mut v = keys.clone();
            let (_, secs) = timer::time_it(|| {
                if engine == SortEngine::LearnedSort {
                    sort_sequential(engine, &mut v)
                } else {
                    sort_parallel(engine, &mut v, 0)
                }
            });
            assert!(is_sorted(&v));
            println!(
                "    {:>12}: {} ({})",
                engine.paper_name(engine != SortEngine::LearnedSort),
                fmt::rate(n as f64 / secs),
                fmt::secs(secs)
            );
        }
        println!();
    }
}
