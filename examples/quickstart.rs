//! Quickstart: sort 4M uniform doubles with AIPS²o on all cores.
//!
//!     cargo run --release --example quickstart

use aipso::util::fmt;
use aipso::{is_sorted, sort_parallel, sort_sequential, SortEngine};

fn main() {
    let n = 4_000_000;
    println!("generating {} uniform doubles...", fmt::keys(n));
    let base = aipso::datasets::generate_f64("uniform", n, 42).unwrap();

    // Parallel AIPS2o — the paper's contribution.
    let mut keys = base.clone();
    let t0 = std::time::Instant::now();
    sort_parallel(SortEngine::Aips2o, &mut keys, 0 /* all cores */);
    let par = t0.elapsed().as_secs_f64();
    assert!(is_sorted(&keys));
    println!("AIPS2o (parallel):   {} — {}", fmt::secs(par), fmt::rate(n as f64 / par));

    // Sequential, for scale.
    let mut keys = base.clone();
    let t0 = std::time::Instant::now();
    sort_sequential(SortEngine::Aips2o, &mut keys);
    let seq = t0.elapsed().as_secs_f64();
    assert!(is_sorted(&keys));
    println!("AI1S2o (sequential): {} — {}", fmt::secs(seq), fmt::rate(n as f64 / seq));

    // The baseline everyone has.
    let mut keys = base;
    let t0 = std::time::Instant::now();
    sort_sequential(SortEngine::StdSort, &mut keys);
    let std_s = t0.elapsed().as_secs_f64();
    println!("std::sort:           {} — {}", fmt::secs(std_s), fmt::rate(n as f64 / std_s));

    println!("\nparallel speedup over std::sort: {:.1}x", std_s / par);
}
