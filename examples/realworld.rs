//! Real-world (simulated) datasets: the paper's Figure 6 in miniature —
//! parallel engines over OSM/Wiki/FB/Books/NYC.
//!
//!     cargo run --release --example realworld

use aipso::util::fmt;
use aipso::{is_sorted, sort_parallel, SortEngine};

fn main() {
    let n: usize = std::env::var("AIPSO_N").ok().and_then(|v| v.parse().ok()).unwrap_or(2_000_000);
    println!("parallel sorting rate on simulated real-world datasets (n = {})\n", fmt::keys(n));
    println!("| dataset | engine | rate |");
    println!("|---------|--------|------|");
    for ds in aipso::datasets::u64_names() {
        let base = aipso::datasets::generate_u64(ds, n, 13).unwrap();
        let mut best: (f64, &str) = (0.0, "");
        for engine in SortEngine::PARALLEL_FIGURES {
            let mut v = base.clone();
            let t0 = std::time::Instant::now();
            sort_parallel(engine, &mut v, 0);
            let rate = n as f64 / t0.elapsed().as_secs_f64();
            assert!(is_sorted(&v), "{engine:?} failed on {ds}");
            if rate > best.0 {
                best = (rate, engine.paper_name(true));
            }
            println!("| {ds} | {} | {} |", engine.paper_name(true), fmt::rate(rate));
        }
        println!("| {ds} | **winner** | {} |", best.1);
    }
    println!("\npaper expectation: AIPS2o wins most; FB/IDs and Wiki/Edit are its hard cases");
}
