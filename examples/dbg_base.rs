use aipso::radix_sort::ska_sort::ska_sort;
use aipso::sample_sort::base_case::small_sort;
use aipso::util::rng::Xoshiro256pp;

fn bench(name: &str, f: impl Fn(&mut [f64]), segs: &[Vec<f64>]) {
    let mut best = f64::MAX;
    for _ in 0..5 {
        let mut copies: Vec<Vec<f64>> = segs.to_vec();
        let t0 = std::time::Instant::now();
        for c in copies.iter_mut() { f(c); }
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
    }
    let total: usize = segs.iter().map(|s| s.len()).sum();
    println!("{name:>12}: {:.1} ns/key (best of 5)", best * 1e9 / total as f64);
}

fn main() {
    let mut rng = Xoshiro256pp::new(1);
    for seg_len in [500usize, 2000, 4000] {
        let segs: Vec<Vec<f64>> = (0..(2_000_000 / seg_len))
            .map(|_| (0..seg_len).map(|_| rng.uniform(0.0, 1e6)).collect())
            .collect();
        println!("segment length {seg_len}:");
        bench("ska_sort", |s| ska_sort(s), &segs);
        bench("small_sort", |s| small_sort(s), &segs);
        bench("std", |s| s.sort_unstable_by(f64::total_cmp), &segs);
        bench("std_by_key", |s| s.sort_unstable_by_key(|x| aipso::SortKey::to_bits_ordered(*x)), &segs);
    }
}
