//! Out-of-core quickstart: sort a dataset 8x larger than the configured
//! memory budget, reusing one RMI across all runs.
//!
//!     cargo run --release --example extsort
//!
//! Scale with AIPSO_N (keys) and AIPSO_EXT_BUDGET_MB.

use aipso::external::{self, ExternalConfig};
use aipso::util::fmt;

fn main() {
    let n: usize = std::env::var("AIPSO_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8_000_000);
    let budget_mb: usize = std::env::var("AIPSO_EXT_BUDGET_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(((n * 8) >> 20).max(8) / 8);
    let dir = std::env::temp_dir();
    let input = dir.join("aipso-extsort-example.bin");
    let output = dir.join("aipso-extsort-example.sorted.bin");

    // 1. Produce the dataset on disk through the chunked generator —
    //    it never materializes in memory.
    println!(
        "writing {} lognormal keys ({} MiB) to {} ...",
        fmt::keys(n),
        (n * 8) >> 20,
        input.display()
    );
    aipso::datasets::write_f64_file("lognormal", n, 42, &input, 1 << 20).unwrap();

    // 2. External sort under the budget: overlapped chunk IO with the
    //    first-chunk RMI reused for every run, then an RMI-sharded merge.
    let cfg = ExternalConfig::with_budget(budget_mb << 20);
    println!(
        "sorting under a {budget_mb} MiB budget (data = {:.1}x budget) ...",
        (n * 8) as f64 / (budget_mb << 20) as f64
    );
    let t0 = std::time::Instant::now();
    let report = external::sort_file::<f64>(&input, &output, &cfg).unwrap();
    let secs = t0.elapsed().as_secs_f64();

    println!(
        "sorted {} keys in {} — {}",
        fmt::keys(report.keys as usize),
        fmt::secs(secs),
        fmt::rate(report.keys as f64 / secs.max(1e-12)),
    );
    println!(
        "runs: {} ({} learned with the one shared RMI, {} IPS4o fallback), \
         merge passes: {}, final-merge shards: {}",
        report.runs,
        report.learned_runs,
        report.fallback_runs,
        report.merge_passes,
        report.merge_shards
    );

    // 3. Stream-verify the output.
    let ok = external::verify_sorted_file::<f64>(&output, cfg.effective_io_buffer()).unwrap();
    println!("output verified sorted: {ok}");
    assert!(ok);

    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}
