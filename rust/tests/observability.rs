//! End-to-end tests for the observability layer: the golden-file pin of
//! the `aipso.telemetry.v1` document shape, full-pipeline span coverage
//! (every external phase, including the drift-triggered `retrain` and the
//! sharded final merge), the block-directory hit counters under the delta
//! spill codec, and the disabled-mode contract (zero spans recorded and
//! byte-identical output with tracing on vs off).
//!
//! The span buffer and global metric registry are process-wide, so every
//! test that flips [`aipso::obs::set_enabled`] serializes on a local lock.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use aipso::datasets;
use aipso::external::{self, ExternalConfig, RunWriter, SpillCodec};
use aipso::obs;
use aipso::util::json::Json;
use aipso::{sort_parallel, SortEngine};

/// Serializes tests that touch the process-global trace/metric state.
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "aipso-obs-it-{}-{}-{tag}.bin",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Pipelined config whose chunks (budget/3/width = 8192 keys at f64)
/// still clear `min_learned_chunk`, with the sharded final merge allowed
/// to engage at test sizes.
fn traced_cfg() -> ExternalConfig {
    ExternalConfig {
        memory_budget: 3 * 8192 * 8,
        threads: 2,
        merge_shards: 4,
        min_shard_keys: 1024,
        ..ExternalConfig::default()
    }
}

/// Write the regime-shift stream (equal thirds uniform → lognormal →
/// zipf) that trips the retrain policy mid-sort.
fn write_regime_stream(path: &PathBuf, n: usize) -> usize {
    let regimes = ["uniform", "lognormal", "zipf"];
    let per = n / regimes.len();
    let mut w = RunWriter::<f64>::create(path.clone(), 1 << 16).expect("create stream");
    for name in regimes {
        let mut gen = datasets::chunked_f64(name, per, 11).expect("regime generator");
        while let Some(chunk) = gen.next_chunk(1 << 14) {
            w.write_slice(&chunk).expect("write regime chunk");
        }
    }
    w.finish().expect("finish stream");
    per * regimes.len()
}

#[test]
fn golden_telemetry_document_shape() {
    // Deterministic document through the explicit-parts constructor —
    // no wall clock, no global state, no lock needed.
    use aipso::obs::metrics::{MetricSet, RATIO_BUCKETS};
    use aipso::obs::trace::TraceNode;

    let leaf = |name, count, total_ns, keys, bytes| TraceNode {
        name,
        count,
        total_ns,
        keys,
        bytes,
        children: Vec::new(),
    };
    let tree = vec![TraceNode {
        name: obs::S_EXTSORT,
        count: 1,
        total_ns: 1_000_000,
        keys: 1000,
        bytes: 8000,
        children: vec![
            leaf(obs::S_CHUNK_READ, 4, 200_000, 1000, 8000),
            leaf(obs::S_CHUNK_SORT, 4, 300_000, 1000, 0),
            leaf(obs::S_MERGE_PASS, 1, 250_000, 1000, 8000),
            leaf(obs::S_SPILL_WRITE, 4, 150_000, 1000, 8000),
        ],
    }];
    let set = MetricSet::new();
    set.add(obs::C_SPILL_RUNS, 4);
    set.observe(obs::M_DRIFT_ERROR, RATIO_BUCKETS, 0.02);
    let report = Json::parse(r#"{"keys": 1000, "runs": 4}"#).unwrap();
    let doc = obs::telemetry_document(&tree, &set.snapshot(), Some(report));

    let golden =
        Json::parse(include_str!("golden/job_telemetry.golden.json")).expect("golden parses");
    assert_eq!(doc, golden, "telemetry document drifted from the golden file");
    assert_eq!(
        doc.dump(),
        golden.dump(),
        "canonical serialization drifted from the golden file"
    );
    obs::validate_telemetry(&golden, obs::BASE_EXTSORT_SPANS, &[obs::M_DRIFT_ERROR])
        .expect("the golden document validates against its own schema");
}

#[test]
fn regime_shift_trace_covers_every_phase_including_retrain() {
    let _l = lock();
    let input = tmp("regime-in");
    let output = tmp("regime-out");
    let n = write_regime_stream(&input, 120_000);

    obs::reset();
    obs::set_enabled(true);
    let report = external::sort_file::<f64>(&input, &output, &traced_cfg()).unwrap();
    obs::set_enabled(false);
    assert_eq!(report.keys as usize, n);
    assert!(
        report.retrains >= 1,
        "the regime shifts must trip the retrain policy"
    );

    let doc = obs::job_telemetry(Some(report.to_json()));
    let mut spans = vec![obs::S_EXTSORT, obs::S_RETRAIN];
    spans.extend_from_slice(obs::BASE_EXTSORT_SPANS);
    let mut hists = vec![
        obs::M_SPILL_BYTES_ENCODED,
        obs::M_SPILL_BYTES_RAW,
        obs::M_DRIFT_ERROR,
    ];
    if report.merge_shards >= 2 {
        spans.push(obs::S_SHARD_MERGE);
        hists.push(obs::M_SHARD_SKEW);
    }
    obs::validate_telemetry(&doc, &spans, &hists).expect("full phase coverage");

    // the retrain counter agrees with the report
    let m = obs::metrics::snapshot();
    assert_eq!(
        m.counters.get(obs::C_RETRAINS).copied().unwrap_or(0),
        report.retrains as u64
    );
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn sharded_delta_merge_emits_skew_and_directory_hits() {
    let _l = lock();
    let input = tmp("shard-in");
    let output = tmp("shard-out");
    let n = 100_000;
    datasets::write_dataset_file("uniform", n, 5, &input, 1 << 14).expect("dataset write");
    let cfg = ExternalConfig {
        spill_codec: SpillCodec::Delta,
        ..traced_cfg()
    };

    obs::reset();
    obs::set_enabled(true);
    let report = external::sort_file::<f64>(&input, &output, &cfg).unwrap();
    obs::set_enabled(false);
    assert_eq!(report.keys as usize, n);
    assert!(
        report.merge_shards >= 2,
        "uniform data at this size must engage the sharded merge"
    );

    let doc = obs::job_telemetry(Some(report.to_json()));
    let mut spans = vec![obs::S_EXTSORT, obs::S_SHARD_MERGE];
    spans.extend_from_slice(obs::BASE_EXTSORT_SPANS);
    obs::validate_telemetry(&doc, &spans, obs::BASE_EXTSORT_HISTS)
        .expect("sharded telemetry carries the full acceptance set");

    // v2 delta runs expose a block directory through the shard plan, so
    // the sharded merge's range opens must hit it rather than re-walk
    // block headers.
    let m = obs::metrics::snapshot();
    let hits = m.counters.get(obs::C_DIR_HIT).copied().unwrap_or(0);
    assert!(hits >= 1, "sharded range opens must use the block directory");
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn disabled_mode_records_nothing_and_output_is_byte_identical() {
    let _l = lock();
    let input = tmp("quiet-in");
    let out_quiet = tmp("quiet-out");
    let out_traced = tmp("traced-out");
    let n = 60_000;
    datasets::write_dataset_file("lognormal", n, 9, &input, 1 << 14).expect("dataset write");
    let cfg = traced_cfg();

    // tracing off: the whole sort must leave the buffers untouched
    obs::reset();
    obs::set_enabled(false);
    let quiet = external::sort_file::<f64>(&input, &out_quiet, &cfg).unwrap();
    assert_eq!(quiet.keys as usize, n);
    assert_eq!(obs::trace::span_count(), 0, "disabled mode records no spans");
    assert!(
        obs::metrics::snapshot().is_empty(),
        "disabled mode records no global metrics"
    );

    // tracing on: same input, same config — the output bytes must match
    obs::set_enabled(true);
    let traced = external::sort_file::<f64>(&input, &out_traced, &cfg).unwrap();
    obs::set_enabled(false);
    assert!(obs::trace::span_count() > 0, "enabled mode records spans");
    assert_eq!(quiet.keys, traced.keys);
    let a = std::fs::read(&out_quiet).unwrap();
    let b = std::fs::read(&out_traced).unwrap();
    assert_eq!(a, b, "tracing must not change the sorted output");
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&out_quiet);
    let _ = std::fs::remove_file(&out_traced);
}

#[test]
fn parallel_learned_sort_traces_the_fragment_path() {
    // Acceptance pin for the thread-parallel fragmented partition:
    // `sort_parallel(LearnedSort, …)` under the default Fragments scheme
    // must demonstrably execute the fragment path (frag-par spans + the
    // partition counter), the spans must pass schema validation against
    // the known-span taxonomy, and tracing must not change the output.
    let _l = lock();
    let n = 120_000;
    let base = datasets::generate_f64("lognormal", n, 13).expect("dataset");

    // tracing off: baseline bytes
    obs::reset();
    obs::set_enabled(false);
    let mut quiet = base.clone();
    sort_parallel(SortEngine::LearnedSort, &mut quiet, 4);
    assert_eq!(obs::trace::span_count(), 0, "disabled mode records no spans");

    // tracing on: frag-par phases visible, output byte-identical
    obs::set_enabled(true);
    let mut traced = base.clone();
    sort_parallel(SortEngine::LearnedSort, &mut traced, 4);
    obs::set_enabled(false);
    let qa: Vec<u64> = quiet.iter().map(|x| x.to_bits()).collect();
    let tb: Vec<u64> = traced.iter().map(|x| x.to_bits()).collect();
    assert_eq!(qa, tb, "tracing must not change the sorted output");

    let names = obs::trace::span_names(&obs::trace::snapshot());
    assert!(
        names.contains(&obs::S_FRAG_PAR_SWEEP),
        "parallel sweep span missing: {names:?}"
    );
    assert!(
        names.contains(&obs::S_FRAG_PAR_MERGE),
        "merge/compaction span missing: {names:?}"
    );
    let m = obs::metrics::snapshot();
    assert!(
        m.counters.get(obs::C_FRAG_PAR).copied().unwrap_or(0) >= 1,
        "frag-par partition counter must be nonzero"
    );

    // the full document passes schema validation with the new spans
    let doc = obs::job_telemetry(None);
    obs::validate_telemetry(&doc, &[obs::S_FRAG_PAR_SWEEP, obs::S_FRAG_PAR_MERGE], &[])
        .expect("frag-par spans validate against the span taxonomy");
    obs::reset();
}
