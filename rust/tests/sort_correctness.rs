//! Integration: every engine sorts every paper dataset, sequentially and
//! in parallel, preserving the key multiset.

use aipso::datasets::{self, KeyType};
use aipso::util::stats::multiset_digest;
use aipso::{is_sorted, sort_parallel, sort_sequential, SortEngine};

const N: usize = 120_000;
const SEED: u64 = 0xC0DE;

fn check_engine_on<K: aipso::SortKey>(
    engine: SortEngine,
    parallel: bool,
    base: &[K],
    label: &str,
) {
    let mut keys = base.to_vec();
    let before = multiset_digest(&keys);
    if parallel {
        sort_parallel(engine, &mut keys, 4);
    } else {
        sort_sequential(engine, &mut keys);
    }
    assert!(
        is_sorted(&keys),
        "{engine:?} (parallel={parallel}) left {label} unsorted"
    );
    assert_eq!(
        before,
        multiset_digest(&keys),
        "{engine:?} (parallel={parallel}) corrupted the multiset on {label}"
    );
}

#[test]
fn all_engines_all_f64_datasets_sequential() {
    for ds in datasets::ALL.iter().filter(|d| d.key_type == KeyType::F64) {
        let base = datasets::generate_f64(ds.name, N, SEED).unwrap();
        for engine in SortEngine::all() {
            check_engine_on(engine, false, &base, ds.name);
        }
    }
}

#[test]
fn all_engines_all_u64_datasets_sequential() {
    for ds in datasets::ALL.iter().filter(|d| d.key_type == KeyType::U64) {
        let base = datasets::generate_u64(ds.name, N, SEED).unwrap();
        for engine in SortEngine::all() {
            check_engine_on(engine, false, &base, ds.name);
        }
    }
}

#[test]
fn parallel_engines_all_datasets() {
    for ds in datasets::ALL.iter() {
        match ds.key_type {
            KeyType::F64 => {
                let base = datasets::generate_f64(ds.name, N, SEED).unwrap();
                for engine in SortEngine::PARALLEL_FIGURES {
                    check_engine_on(engine, true, &base, ds.name);
                }
            }
            KeyType::U64 => {
                let base = datasets::generate_u64(ds.name, N, SEED).unwrap();
                for engine in SortEngine::PARALLEL_FIGURES {
                    check_engine_on(engine, true, &base, ds.name);
                }
            }
        }
    }
}

#[test]
fn boundary_sizes_every_engine() {
    for n in [0usize, 1, 2, 3, 5, 63, 64, 65, 127, 128, 129, 4095, 4096, 4097] {
        let base: Vec<u64> = (0..n as u64).rev().collect();
        for engine in SortEngine::all() {
            check_engine_on(engine, false, &base, &format!("rev-{n}"));
            check_engine_on(engine, true, &base, &format!("rev-{n}"));
        }
    }
}

#[test]
fn pathological_patterns_every_engine() {
    let n = 50_000usize;
    let mut cases: Vec<(String, Vec<u64>)> = vec![
        ("sorted".into(), (0..n as u64).collect()),
        ("reversed".into(), (0..n as u64).rev().collect()),
        ("constant".into(), vec![42; n]),
        ("two-values".into(), (0..n as u64).map(|i| i % 2).collect()),
        (
            "organ-pipe".into(),
            (0..n as u64 / 2).chain((0..n as u64 / 2).rev()).collect(),
        ),
        (
            "sawtooth".into(),
            (0..n as u64).map(|i| i % 1000).collect(),
        ),
    ];
    // near-sorted with sparse swaps
    let mut nearly: Vec<u64> = (0..n as u64).collect();
    for i in (0..n - 1).step_by(997) {
        nearly.swap(i, i + 1);
    }
    cases.push(("nearly-sorted".into(), nearly));
    for (label, base) in &cases {
        for engine in SortEngine::all() {
            check_engine_on(engine, false, base, label);
        }
        for engine in SortEngine::PARALLEL_FIGURES {
            check_engine_on(engine, true, base, label);
        }
    }
}

#[test]
fn extreme_float_values() {
    let mut base: Vec<f64> = vec![
        f64::INFINITY,
        f64::NEG_INFINITY,
        0.0,
        -0.0,
        f64::MIN_POSITIVE,
        -f64::MIN_POSITIVE,
        1e308,
        -1e308,
        1e-308,
    ];
    base.extend((0..20_000).map(|i| (i as f64 - 10_000.0) * 1e100));
    for engine in SortEngine::all() {
        check_engine_on(engine, false, &base, "extreme-floats");
    }
}
