//! The AOT bridge, end to end: load artifacts/*.hlo.txt, execute the
//! JAX/Pallas-trained RMI through PJRT, and pin it numerically against
//! the native Rust mirror.
//!
//! Requires `make artifacts` (skips with a notice otherwise — CI runs
//! through the Makefile, which always builds artifacts first).

use aipso::rmi::model::{Rmi, RmiConfig};
use aipso::runtime::{default_artifacts_dir, RmiRuntime};
use aipso::util::rng::Xoshiro256pp;

fn runtime_or_skip() -> Option<RmiRuntime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
        return None;
    }
    Some(RmiRuntime::load(&dir).expect("artifacts present but failed to load"))
}

#[test]
fn artifacts_load_and_describe() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.manifest();
    assert_eq!(m.n_leaves, 1024);
    assert!(m.train_sample >= 1024);
    assert!(m.predict_batch >= 4096);
}

#[test]
fn xla_train_matches_native_train() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.manifest();
    let mut rng = Xoshiro256pp::new(42);
    let mut sample: Vec<f64> = (0..m.train_sample).map(|_| rng.lognormal(0.0, 0.5)).collect();
    sample.sort_unstable_by(f64::total_cmp);
    let xla_rmi = rt.train(&sample).expect("xla train");
    let native = Rmi::train(&sample, RmiConfig { n_leaves: m.n_leaves });
    assert_eq!(xla_rmi.n_leaves(), native.n_leaves());
    assert!((xla_rmi.root_a - native.root_a).abs() <= 1e-9 * native.root_a.abs().max(1.0));
    let mut max_rel = 0.0f64;
    for (a, b) in xla_rmi.leaves.iter().zip(&native.leaves) {
        max_rel = max_rel.max((a.a - b.a).abs() / b.a.abs().max(1.0));
        max_rel = max_rel.max((a.lo - b.lo).abs());
        max_rel = max_rel.max((a.hi - b.hi).abs());
    }
    assert!(max_rel < 1e-8, "leaf params diverge: {max_rel}");
}

#[test]
fn xla_predict_matches_native_predict() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.manifest();
    let mut rng = Xoshiro256pp::new(43);
    let mut sample: Vec<f64> = (0..m.train_sample).map(|_| rng.uniform(0.0, 1e6)).collect();
    sample.sort_unstable_by(f64::total_cmp);
    let rmi = Rmi::train(&sample, RmiConfig { n_leaves: m.n_leaves });
    // includes a padded partial final chunk (non-multiple of batch)
    let keys: Vec<f64> = (0..m.predict_batch + 1000)
        .map(|_| rng.uniform(-1e5, 1.1e6))
        .collect();
    let xla = rt.predict(&keys, &rmi).expect("xla predict");
    assert_eq!(xla.len(), keys.len());
    let mut max_err = 0.0f64;
    for (k, p) in keys.iter().zip(&xla) {
        max_err = max_err.max((rmi.predict(*k) - p).abs());
    }
    assert!(max_err < 1e-12, "native vs xla predict diverge: {max_err}");
}

#[test]
fn xla_model_is_monotone() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = rt.manifest();
    let mut rng = Xoshiro256pp::new(44);
    let mut sample: Vec<f64> = (0..m.train_sample).map(|_| rng.normal() * 1e3).collect();
    sample.sort_unstable_by(f64::total_cmp);
    let rmi = rt.train(&sample).expect("xla train");
    let mut probe: Vec<f64> = (0..8192).map(|_| rng.normal() * 1e3).collect();
    probe.sort_unstable_by(f64::total_cmp);
    assert!(rmi.is_monotone_over(&probe), "XLA-trained model not monotone");
}

#[test]
fn xla_trained_model_sorts_through_aips2o_machinery() {
    // The full loop: XLA-trained model -> native classifier -> block
    // partition -> recursive sort. Proves the artifact path composes with
    // the L3 engine.
    use aipso::classifier::rmi_classifier::RmiClassifier;
    use aipso::classifier::Classifier;
    use aipso::sample_sort::partition::partition;

    let Some(rt) = runtime_or_skip() else { return };
    let mut rng = Xoshiro256pp::new(45);
    let mut data: Vec<f64> = (0..300_000).map(|_| rng.uniform(0.0, 1e6)).collect();
    let mut sample: Vec<f64> = (0..rt.manifest().train_sample)
        .map(|_| data[rng.next_below(data.len() as u64) as usize])
        .collect();
    sample.sort_unstable_by(f64::total_cmp);
    let rmi = rt.train(&sample).expect("xla train");
    let classifier = RmiClassifier::new(rmi, 512);
    let res = partition(&mut data, &classifier, 128, 4);
    for b in 0..512 {
        let seg = &data[res.boundaries[b]..res.boundaries[b + 1]];
        for &k in seg {
            assert_eq!(Classifier::<f64>::classify(&classifier, k), b);
        }
    }
}
