//! Integration + property tests for the out-of-core sorter: byte-exact
//! agreement with `sort_unstable` on the reloaded output across random
//! chunk-size/budget combinations, duplicate-heavy inputs, edge cases,
//! the acceptance scenario (data ≥ 4x the memory budget with the RMI
//! trained once and reused for every run), and serial/parallel pipeline
//! equivalence on all 14 paper distributions.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use aipso::datasets;
use aipso::external::{self, read_keys_file, write_keys_file, ExternalConfig, RunGen};
use aipso::util::proptest::{check_sized, PropConfig};
use aipso::util::rng::Xoshiro256pp;

fn tmp(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "aipso-extsort-it-{}-{}-{tag}.bin",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Small-file config: tiny IO buffers so merge fan-in clamps kick in;
/// threads = 2 routes through the overlapped pipeline.
fn cfg_with_budget(budget_bytes: usize) -> ExternalConfig {
    ExternalConfig {
        memory_budget: budget_bytes.max(512),
        io_buffer: 1 << 12,
        threads: 2,
        ..ExternalConfig::default()
    }
}

fn sort_u64_via_file(keys: &[u64], cfg: &ExternalConfig) -> Vec<u64> {
    let input = tmp("u64-in");
    let output = tmp("u64-out");
    write_keys_file(&input, keys).unwrap();
    let report = external::sort_file::<u64>(&input, &output, cfg).unwrap();
    assert_eq!(report.keys as usize, keys.len());
    let got = read_keys_file::<u64>(&output).unwrap();
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
    got
}

fn sort_f64_via_iter(keys: &[f64], cfg: &ExternalConfig) -> Vec<f64> {
    let output = tmp("f64-out");
    let report = external::sort_iter(keys.iter().copied(), &output, cfg).unwrap();
    assert_eq!(report.keys as usize, keys.len());
    let got = read_keys_file::<f64>(&output).unwrap();
    let _ = std::fs::remove_file(&output);
    got
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn property_u64_random_budgets_match_sort_unstable() {
    check_sized(
        "extsort-u64-budgets",
        PropConfig::with_max_size(24, 1 << 14),
        |rng, n| {
            let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            // budget between 0.5 KiB and ~64 KiB — from "everything is one
            // chunk" down to hundreds of tiny runs and multi-pass merges
            let budget = 512usize << rng.next_below(8);
            let got = sort_u64_via_file(&keys, &cfg_with_budget(budget));
            let mut want = keys;
            want.sort_unstable();
            if got != want {
                return Err(format!("mismatch at n={n} budget={budget}"));
            }
            Ok(())
        },
    );
}

#[test]
fn property_f64_random_budgets_bit_exact() {
    check_sized(
        "extsort-f64-budgets",
        PropConfig::with_max_size(24, 1 << 14),
        |rng, n| {
            // NaN-free total-order keys: mixture incl. negatives and ±0
            let keys: Vec<f64> = (0..n)
                .map(|_| match rng.next_below(4) {
                    0 => rng.normal() * 1e6,
                    1 => -rng.exponential(0.001),
                    2 => 0.0 * if rng.next_f64() < 0.5 { -1.0 } else { 1.0 },
                    _ => rng.uniform(-1e9, 1e9),
                })
                .collect();
            let budget = 512usize << rng.next_below(8);
            let got = sort_f64_via_iter(&keys, &cfg_with_budget(budget));
            let mut want = keys;
            want.sort_unstable_by(f64::total_cmp);
            if bits(&got) != bits(&want) {
                return Err(format!("bit mismatch at n={n} budget={budget}"));
            }
            Ok(())
        },
    );
}

#[test]
fn duplicate_heavy_zipf_and_two_dups() {
    for name in ["zipf", "two_dups"] {
        let keys = datasets::generate_f64(name, 120_000, 13).unwrap();
        // ~16Ki-key pipelined chunks (threads=2 => a third of the budget):
        // well above min_learned_chunk, so the learned path is offered and
        // Algorithm 5's duplicate guard must route away
        let got = sort_f64_via_iter(&keys, &cfg_with_budget(3 * 16_384 * 8));
        let mut want = keys;
        want.sort_unstable_by(f64::total_cmp);
        assert_eq!(bits(&got), bits(&want), "{name}");
    }
}

#[test]
fn edge_cases_empty_single_sorted_constant() {
    let cfg = cfg_with_budget(4096);
    // empty
    assert!(sort_u64_via_file(&[], &cfg).is_empty());
    // single element
    assert_eq!(sort_u64_via_file(&[42], &cfg), vec![42]);
    // already sorted across many chunks
    let sorted: Vec<u64> = (0..20_000).collect();
    assert_eq!(sort_u64_via_file(&sorted, &cfg), sorted);
    // reverse sorted
    let rev: Vec<u64> = (0..20_000).rev().collect();
    assert_eq!(sort_u64_via_file(&rev, &cfg), sorted);
    // constant
    let c = vec![7u64; 10_000];
    assert_eq!(sort_u64_via_file(&c, &cfg), c);
}

#[test]
fn acceptance_f64_dataset_4x_budget_rmi_reused() {
    // 600k uniform doubles ≈ 4.6 MiB of keys vs a 1 MiB budget (4.58x):
    // 5 runs, all generated with the single RMI trained on chunk 1.
    let n = 600_000;
    let input = tmp("accept-f64-in");
    let output = tmp("accept-f64-out");
    datasets::write_f64_file("uniform", n, 21, &input, 1 << 16).unwrap();
    let cfg = cfg_with_budget(1 << 20);
    let report = external::sort_file::<f64>(&input, &output, &cfg).unwrap();
    assert_eq!(report.keys as usize, n);
    assert!(report.runs >= 4, "runs={}", report.runs);
    assert!(report.rmi_trained, "RMI must be trained on the first chunk");
    assert_eq!(
        report.learned_runs, report.runs,
        "the one trained RMI must be reused for every run"
    );
    assert_eq!(report.fallback_runs, 0);
    assert!(external::verify_sorted_file::<f64>(&output, 1 << 16).unwrap());
    let mut want = datasets::generate_f64("uniform", n, 21).unwrap();
    want.sort_unstable_by(f64::total_cmp);
    assert_eq!(bits(&read_keys_file::<f64>(&output).unwrap()), bits(&want));
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn acceptance_u64_dataset_4x_budget_rmi_reused() {
    // nyc_pickup: a near-uniform seasonal timestamp CDF the RMI models
    // tightly, nearly duplicate-free — the learned path engages on every
    // chunk and iid chunks keep the drift probe quiet.
    let n = 600_000;
    let input = tmp("accept-u64-in");
    let output = tmp("accept-u64-out");
    datasets::write_u64_file("nyc_pickup", n, 22, &input, 1 << 16).unwrap();
    let cfg = cfg_with_budget(1 << 20);
    let report = external::sort_file::<u64>(&input, &output, &cfg).unwrap();
    assert_eq!(report.keys as usize, n);
    assert!(report.runs >= 4, "runs={}", report.runs);
    assert!(report.rmi_trained);
    assert_eq!(report.learned_runs, report.runs);
    assert!(external::verify_sorted_file::<u64>(&output, 1 << 16).unwrap());
    let mut want = datasets::generate_u64("nyc_pickup", n, 22).unwrap();
    want.sort_unstable();
    assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn drift_fallback_engages_and_output_still_exact() {
    // First chunk U(0, 1e6), later chunks U(5e6, 6e6): the reused model
    // maps the shifted regime to CDF ≈ 1, the drift probe catches it, and
    // those runs take the IPS4o path. threads=1 pins the serial chunk
    // layout the scenario is built around.
    let mut rng = Xoshiro256pp::new(31);
    let chunk = (1usize << 20) / 8; // keys per 1 MiB chunk
    let mut keys: Vec<f64> = (0..chunk).map(|_| rng.uniform(0.0, 1e6)).collect();
    keys.extend((0..3 * chunk).map(|_| rng.uniform(5e6, 6e6)));
    let output = tmp("drift-out");
    let cfg = ExternalConfig {
        threads: 1,
        ..cfg_with_budget(1 << 20)
    };
    let report = external::sort_iter(keys.iter().copied(), &output, &cfg).unwrap();
    assert!(report.rmi_trained);
    assert_eq!(report.learned_runs, 1, "only the first run fits the model");
    assert!(report.fallback_runs >= 3, "drifted runs must fall back");
    let mut want = keys;
    want.sort_unstable_by(f64::total_cmp);
    assert_eq!(bits(&read_keys_file::<f64>(&output).unwrap()), bits(&want));
    let _ = std::fs::remove_file(&output);
}

#[test]
fn parallel_drift_shard_guard_still_sorts_exactly() {
    // Same regime shift through the parallel pipeline: whatever mix of
    // learned/fallback runs and sharded/serial final merge the guards
    // pick, the output must stay bit-exact.
    let mut rng = Xoshiro256pp::new(32);
    let chunk = (1usize << 20) / 24; // keys per pipelined chunk (budget/3)
    let mut keys: Vec<f64> = (0..chunk).map(|_| rng.uniform(0.0, 1e6)).collect();
    keys.extend((0..5 * chunk).map(|_| rng.uniform(5e6, 6e6)));
    let output = tmp("drift-par-out");
    let cfg = ExternalConfig {
        threads: 4,
        min_shard_keys: 1024,
        ..cfg_with_budget(1 << 20)
    };
    let report = external::sort_iter(keys.iter().copied(), &output, &cfg).unwrap();
    assert!(report.rmi_trained);
    assert!(report.fallback_runs >= 3, "drifted runs must fall back");
    let mut want = keys;
    want.sort_unstable_by(f64::total_cmp);
    assert_eq!(bits(&read_keys_file::<f64>(&output).unwrap()), bits(&want));
    let _ = std::fs::remove_file(&output);
}

#[test]
fn parallel_matches_serial_bytes_on_all_14_distributions() {
    // The PR's acceptance bar: on every paper distribution, the parallel
    // pipeline (overlapped IO + RMI-sharded merge, threads > 1) produces
    // *byte-identical* output to the serial reference (threads = 1).
    let n = 50_000;
    for spec in datasets::ALL.iter() {
        let input = tmp(&format!("dist-{}", spec.name));
        let serial_out = tmp(&format!("dist-{}-serial", spec.name));
        let parallel_out = tmp(&format!("dist-{}-parallel", spec.name));
        datasets::write_dataset_file(spec.name, n, 99, &input, 1 << 14).unwrap();
        let mut cfg = ExternalConfig {
            memory_budget: 3 * 8192 * 8,
            io_buffer: 1 << 12,
            threads: 1,
            min_shard_keys: 1024,
            ..ExternalConfig::default()
        };
        let serial = match spec.key_type {
            datasets::KeyType::F64 => {
                external::sort_file::<f64>(&input, &serial_out, &cfg).unwrap()
            }
            datasets::KeyType::U64 => {
                external::sort_file::<u64>(&input, &serial_out, &cfg).unwrap()
            }
        };
        cfg.threads = 4;
        let parallel = match spec.key_type {
            datasets::KeyType::F64 => {
                external::sort_file::<f64>(&input, &parallel_out, &cfg).unwrap()
            }
            datasets::KeyType::U64 => {
                external::sort_file::<u64>(&input, &parallel_out, &cfg).unwrap()
            }
        };
        assert_eq!(serial.keys, n as u64, "{}", spec.name);
        assert_eq!(parallel.keys, n as u64, "{}", spec.name);
        assert_eq!(
            std::fs::read(&serial_out).unwrap(),
            std::fs::read(&parallel_out).unwrap(),
            "{}: parallel output differs from serial",
            spec.name
        );
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&serial_out);
        let _ = std::fs::remove_file(&parallel_out);
    }
}

#[test]
fn ips4o_run_strategy_is_exact_too() {
    let keys = datasets::generate_u64("wiki_edit", 100_000, 5).unwrap();
    let input = tmp("ips4o-in");
    let output = tmp("ips4o-out");
    write_keys_file(&input, &keys).unwrap();
    let cfg = ExternalConfig {
        run_gen: RunGen::Ips4o,
        ..cfg_with_budget(16_384 * 8)
    };
    let report = external::sort_file::<u64>(&input, &output, &cfg).unwrap();
    assert!(!report.rmi_trained);
    assert_eq!(report.learned_runs, 0);
    let mut want = keys;
    want.sort_unstable();
    assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}
