//! Integration + property tests for the out-of-core sorter: byte-exact
//! agreement with `sort_unstable` on the reloaded output across random
//! chunk-size/budget combinations, duplicate-heavy inputs, edge cases,
//! the acceptance scenario (data ≥ 4x the memory budget with the RMI
//! trained once and reused for every run), serial/parallel pipeline
//! equivalence on all 14 paper distributions, the regime-shift
//! scenarios pinning the retrain-on-drift policy (enabled: the learned
//! path recovers after a shift and the sharded merge keeps its cuts;
//! disabled: the pre-retrain permanent-fallback behaviour), and the
//! spill-codec layer (raw-vs-delta byte-identical outputs across all 14
//! distributions at both key widths, compression on dup-heavy inputs,
//! v0/v1/v2 inputs via header dispatch, delta-block roundtrip property).
//!
//! The whole suite honours `SPILL_CODEC=raw|delta` (the default codec of
//! `ExternalConfig`), so CI runs it once per codec.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use aipso::datasets;
use aipso::external::{
    self, read_header, read_keys_file, write_keys_file, ExternalConfig, RetrainPolicy, RunGen,
    RunWriter, SpillCodec, SpillHeader, HEADER_LEN,
};
use aipso::util::proptest::{check_sized, PropConfig};
use aipso::util::rng::{Xoshiro256pp, Zipf};
use aipso::{KeyKind, SortKey};

fn tmp(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "aipso-extsort-it-{}-{}-{tag}.bin",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Small-file config: tiny IO buffers so merge fan-in clamps kick in;
/// threads = 2 routes through the overlapped pipeline.
fn cfg_with_budget(budget_bytes: usize) -> ExternalConfig {
    ExternalConfig {
        memory_budget: budget_bytes.max(512),
        io_buffer: 1 << 12,
        threads: 2,
        ..ExternalConfig::default()
    }
}

fn sort_u64_via_file(keys: &[u64], cfg: &ExternalConfig) -> Vec<u64> {
    let input = tmp("u64-in");
    let output = tmp("u64-out");
    write_keys_file(&input, keys).unwrap();
    let report = external::sort_file::<u64>(&input, &output, cfg).unwrap();
    assert_eq!(report.keys as usize, keys.len());
    let got = read_keys_file::<u64>(&output).unwrap();
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
    got
}

fn sort_f64_via_iter(keys: &[f64], cfg: &ExternalConfig) -> Vec<f64> {
    let output = tmp("f64-out");
    let report = external::sort_iter(keys.iter().copied(), &output, cfg).unwrap();
    assert_eq!(report.keys as usize, keys.len());
    let got = read_keys_file::<f64>(&output).unwrap();
    let _ = std::fs::remove_file(&output);
    got
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn property_u64_random_budgets_match_sort_unstable() {
    check_sized(
        "extsort-u64-budgets",
        PropConfig::with_max_size(24, 1 << 14),
        |rng, n| {
            let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            // budget between 0.5 KiB and ~64 KiB — from "everything is one
            // chunk" down to hundreds of tiny runs and multi-pass merges
            let budget = 512usize << rng.next_below(8);
            let got = sort_u64_via_file(&keys, &cfg_with_budget(budget));
            let mut want = keys;
            want.sort_unstable();
            if got != want {
                return Err(format!("mismatch at n={n} budget={budget}"));
            }
            Ok(())
        },
    );
}

#[test]
fn property_f64_random_budgets_bit_exact() {
    check_sized(
        "extsort-f64-budgets",
        PropConfig::with_max_size(24, 1 << 14),
        |rng, n| {
            // NaN-free total-order keys: mixture incl. negatives and ±0
            let keys: Vec<f64> = (0..n)
                .map(|_| match rng.next_below(4) {
                    0 => rng.normal() * 1e6,
                    1 => -rng.exponential(0.001),
                    2 => 0.0 * if rng.next_f64() < 0.5 { -1.0 } else { 1.0 },
                    _ => rng.uniform(-1e9, 1e9),
                })
                .collect();
            let budget = 512usize << rng.next_below(8);
            let got = sort_f64_via_iter(&keys, &cfg_with_budget(budget));
            let mut want = keys;
            want.sort_unstable_by(f64::total_cmp);
            if bits(&got) != bits(&want) {
                return Err(format!("bit mismatch at n={n} budget={budget}"));
            }
            Ok(())
        },
    );
}

#[test]
fn duplicate_heavy_zipf_and_two_dups() {
    for name in ["zipf", "two_dups"] {
        let keys = datasets::generate_f64(name, 120_000, 13).unwrap();
        // ~16Ki-key pipelined chunks (threads=2 => a third of the budget):
        // well above min_learned_chunk, so the learned path is offered and
        // Algorithm 5's duplicate guard must route away
        let got = sort_f64_via_iter(&keys, &cfg_with_budget(3 * 16_384 * 8));
        let mut want = keys;
        want.sort_unstable_by(f64::total_cmp);
        assert_eq!(bits(&got), bits(&want), "{name}");
    }
}

#[test]
fn edge_cases_empty_single_sorted_constant() {
    let cfg = cfg_with_budget(4096);
    // empty
    assert!(sort_u64_via_file(&[], &cfg).is_empty());
    // single element
    assert_eq!(sort_u64_via_file(&[42], &cfg), vec![42]);
    // already sorted across many chunks
    let sorted: Vec<u64> = (0..20_000).collect();
    assert_eq!(sort_u64_via_file(&sorted, &cfg), sorted);
    // reverse sorted
    let rev: Vec<u64> = (0..20_000).rev().collect();
    assert_eq!(sort_u64_via_file(&rev, &cfg), sorted);
    // constant
    let c = vec![7u64; 10_000];
    assert_eq!(sort_u64_via_file(&c, &cfg), c);
}

#[test]
fn acceptance_f64_dataset_4x_budget_rmi_reused() {
    // 600k uniform doubles ≈ 4.6 MiB of keys vs a 1 MiB budget (4.58x):
    // 5 runs, all generated with the single RMI trained on chunk 1.
    let n = 600_000;
    let input = tmp("accept-f64-in");
    let output = tmp("accept-f64-out");
    datasets::write_f64_file("uniform", n, 21, &input, 1 << 16).unwrap();
    let cfg = cfg_with_budget(1 << 20);
    let report = external::sort_file::<f64>(&input, &output, &cfg).unwrap();
    assert_eq!(report.keys as usize, n);
    assert!(report.runs >= 4, "runs={}", report.runs);
    assert!(report.rmi_trained, "RMI must be trained on the first chunk");
    assert_eq!(
        report.learned_runs, report.runs,
        "the one trained RMI must be reused for every run"
    );
    assert_eq!(report.fallback_runs, 0);
    assert!(external::verify_sorted_file::<f64>(&output, 1 << 16).unwrap());
    let mut want = datasets::generate_f64("uniform", n, 21).unwrap();
    want.sort_unstable_by(f64::total_cmp);
    assert_eq!(bits(&read_keys_file::<f64>(&output).unwrap()), bits(&want));
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn acceptance_u64_dataset_4x_budget_rmi_reused() {
    // nyc_pickup: a near-uniform seasonal timestamp CDF the RMI models
    // tightly, nearly duplicate-free — the learned path engages on every
    // chunk and iid chunks keep the drift probe quiet.
    let n = 600_000;
    let input = tmp("accept-u64-in");
    let output = tmp("accept-u64-out");
    datasets::write_u64_file("nyc_pickup", n, 22, &input, 1 << 16).unwrap();
    let cfg = cfg_with_budget(1 << 20);
    let report = external::sort_file::<u64>(&input, &output, &cfg).unwrap();
    assert_eq!(report.keys as usize, n);
    assert!(report.runs >= 4, "runs={}", report.runs);
    assert!(report.rmi_trained);
    assert_eq!(report.learned_runs, report.runs);
    assert!(external::verify_sorted_file::<u64>(&output, 1 << 16).unwrap());
    let mut want = datasets::generate_u64("nyc_pickup", n, 22).unwrap();
    want.sort_unstable();
    assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn drift_fallback_engages_and_output_still_exact() {
    // First chunk U(0, 1e6), later chunks U(5e6, 6e6): the reused model
    // maps the shifted regime to CDF ≈ 1, the drift probe catches it, and
    // those runs take the IPS4o path. threads=1 pins the serial chunk
    // layout the scenario is built around; RetrainPolicy::disabled() pins
    // the pre-retrain permanent-fallback behaviour as a regression.
    let mut rng = Xoshiro256pp::new(31);
    let chunk = (1usize << 20) / 8; // keys per 1 MiB chunk
    let mut keys: Vec<f64> = (0..chunk).map(|_| rng.uniform(0.0, 1e6)).collect();
    keys.extend((0..3 * chunk).map(|_| rng.uniform(5e6, 6e6)));
    let output = tmp("drift-out");
    let cfg = ExternalConfig {
        threads: 1,
        retrain: RetrainPolicy::disabled(),
        ..cfg_with_budget(1 << 20)
    };
    let report = external::sort_iter(keys.iter().copied(), &output, &cfg).unwrap();
    assert!(report.rmi_trained);
    assert_eq!(report.learned_runs, 1, "only the first run fits the model");
    assert!(report.fallback_runs >= 3, "drifted runs must fall back");
    assert_eq!(report.retrains, 0, "disabled policy must never retrain");
    let mut want = keys;
    want.sort_unstable_by(f64::total_cmp);
    assert_eq!(bits(&read_keys_file::<f64>(&output).unwrap()), bits(&want));
    let _ = std::fs::remove_file(&output);
}

#[test]
fn parallel_drift_shard_guard_still_sorts_exactly() {
    // Same regime shift through the parallel pipeline: whatever mix of
    // learned/fallback runs and sharded/serial final merge the guards
    // pick, the output must stay bit-exact (retrain disabled regression).
    let mut rng = Xoshiro256pp::new(32);
    let chunk = (1usize << 20) / 24; // keys per pipelined chunk (budget/3)
    let mut keys: Vec<f64> = (0..chunk).map(|_| rng.uniform(0.0, 1e6)).collect();
    keys.extend((0..5 * chunk).map(|_| rng.uniform(5e6, 6e6)));
    let output = tmp("drift-par-out");
    let cfg = ExternalConfig {
        threads: 4,
        min_shard_keys: 1024,
        retrain: RetrainPolicy::disabled(),
        ..cfg_with_budget(1 << 20)
    };
    let report = external::sort_iter(keys.iter().copied(), &output, &cfg).unwrap();
    assert!(report.rmi_trained);
    assert!(report.fallback_runs >= 3, "drifted runs must fall back");
    assert_eq!(report.retrains, 0);
    let mut want = keys;
    want.sort_unstable_by(f64::total_cmp);
    assert_eq!(bits(&read_keys_file::<f64>(&output).unwrap()), bits(&want));
    let _ = std::fs::remove_file(&output);
}

/// The regime-shift acceptance stream: 4 pipelined chunks of uniform, 6
/// of scaled lognormal, 2 of zipf — 12 chunks at 4x the memory budget,
/// with both shifts landing exactly on chunk boundaries (threads=2 ⇒
/// pipelined chunks of `budget / 3 / 8` keys).
fn regime_shift_stream(chunk: usize) -> Vec<f64> {
    let mut rng = Xoshiro256pp::new(0x2E61);
    let mut keys: Vec<f64> = Vec::with_capacity(12 * chunk);
    for _ in 0..4 * chunk {
        keys.push(rng.uniform(0.0, 1e6));
    }
    for _ in 0..6 * chunk {
        keys.push(1e5 * rng.lognormal(0.0, 0.5));
    }
    let zipf = Zipf::new(1_000_000, 0.75);
    for _ in 0..2 * chunk {
        keys.push(zipf.sample(&mut rng) as f64);
    }
    keys
}

#[test]
fn regime_shift_retrain_recovers_learned_path_and_sharded_merge() {
    // The PR's acceptance scenario: a concatenated uniform → lognormal →
    // zipf stream at 4x the budget, retrain enabled. The lognormal shift
    // must trigger a retrain that keeps its whole regime on the learned
    // path, the zipf tail may stay on the fallback (duplicate guard), and
    // the final merge must still shard — the epoch-mixture cuts describe
    // the shifted stream, so the skew guard has no reason to fire.
    let chunk = 16_384usize;
    let keys = regime_shift_stream(chunk);
    let output = tmp("regime-on-out");
    let cfg = ExternalConfig {
        memory_budget: 3 * chunk * 8, // threads=2 ⇒ 16Ki-key chunks
        threads: 2,
        min_shard_keys: 1024,
        retrain: RetrainPolicy {
            retrain_after: 1,
            max_retrains: 3,
        },
        ..ExternalConfig::default()
    };
    let report = external::sort_iter(keys.iter().copied(), &output, &cfg).unwrap();
    assert_eq!(report.keys as usize, keys.len());
    assert_eq!(report.runs, 12, "12 aligned chunks expected");
    assert!(report.rmi_trained);
    assert!(
        (1..=3).contains(&report.retrains),
        "each regime change may retrain at most once (retrains={})",
        report.retrains
    );
    // post-retrain epochs must be learned-dominated: the whole lognormal
    // regime (6 chunks) re-learns, only the zipf tail (≤ 2 chunks) may
    // stay demoted
    assert_eq!(report.epochs.len(), report.retrains + 1);
    let (post_learned, post_fallback) = report.epochs[1..]
        .iter()
        .fold((0, 0), |(l, f), e| (l + e.learned, f + e.fallback));
    assert!(
        post_learned >= 6,
        "the lognormal regime must recover the learned path (post-retrain learned={post_learned})"
    );
    assert!(
        post_learned > post_fallback,
        "post-retrain chunks must be learned-dominated ({post_learned} !> {post_fallback})"
    );
    assert_eq!(report.epochs[0].learned, 4, "uniform regime all learned");
    assert!(report.learned_runs >= 10, "learned_runs={}", report.learned_runs);
    // the sharded merge engages on the mixture cuts — no skew fallback
    assert!(
        report.merge_shards >= 2,
        "epoch-mixture cuts must keep the final merge sharded (merge_shards={})",
        report.merge_shards
    );
    // and the output is byte-equal to std's total-order sort
    let mut want = keys;
    want.sort_unstable_by(f64::total_cmp);
    assert_eq!(bits(&read_keys_file::<f64>(&output).unwrap()), bits(&want));
    let _ = std::fs::remove_file(&output);
}

#[test]
fn regime_shift_disabled_policy_pins_permanent_fallback() {
    // Same stream, retrain disabled: today's behaviour — everything after
    // the first shift is demoted for the rest of the job — must stay
    // exactly reproducible (and still byte-exact).
    let chunk = 16_384usize;
    let keys = regime_shift_stream(chunk);
    let output = tmp("regime-off-out");
    let cfg = ExternalConfig {
        memory_budget: 3 * chunk * 8,
        threads: 2,
        min_shard_keys: 1024,
        retrain: RetrainPolicy::disabled(),
        ..ExternalConfig::default()
    };
    let report = external::sort_iter(keys.iter().copied(), &output, &cfg).unwrap();
    assert_eq!(report.runs, 12);
    assert!(report.rmi_trained);
    assert_eq!(report.retrains, 0);
    assert_eq!(report.epochs.len(), 1, "one epoch without retraining");
    assert_eq!(report.learned_runs, 4, "only the uniform regime is learned");
    assert_eq!(report.fallback_runs, 8, "both shifted regimes stay demoted");
    let mut want = keys;
    want.sort_unstable_by(f64::total_cmp);
    assert_eq!(bits(&read_keys_file::<f64>(&output).unwrap()), bits(&want));
    let _ = std::fs::remove_file(&output);
}

#[test]
fn property_random_retrain_configs_stay_byte_exact() {
    // ~50 random (budget, threads, shards, drift threshold, retrain
    // policy) configurations over random multi-regime streams: whatever
    // the knobs select — learned or fallback runs, retrains or not,
    // sharded or serial merges — the output must match std sort
    // bit-for-bit. On failure the harness panics with the
    // AIPSO_PROP_SEED=... line and the bisection-shrunk size.
    check_sized(
        "extsort-retrain-mixes",
        PropConfig::with_max_size(50, 1 << 13),
        |rng, n| {
            // 1-3 regimes drawn from four distribution families
            let regimes = 1 + rng.next_below(3) as usize;
            let mut keys: Vec<f64> = Vec::with_capacity(n);
            for r in 0..regimes {
                let len = if r + 1 == regimes {
                    n - keys.len()
                } else {
                    n / regimes
                };
                match rng.next_below(4) {
                    0 => keys.extend((0..len).map(|_| rng.uniform(0.0, 1e6))),
                    1 => keys.extend((0..len).map(|_| 1e4 * rng.lognormal(0.0, 0.5))),
                    2 => keys.extend((0..len).map(|_| rng.uniform(5e6, 6e6))),
                    _ => keys.extend((0..len).map(|_| rng.next_below(100) as f64)),
                }
            }
            let cfg = ExternalConfig {
                memory_budget: 512usize << rng.next_below(6),
                io_buffer: 1 << 12,
                threads: 1 + rng.next_below(4) as usize,
                merge_shards: rng.next_below(5) as usize,
                min_shard_keys: 512,
                // chunks at these budgets hold 64–2048 keys: lower the
                // learned-path floor so models actually train and the
                // retrain knobs are exercised, not just carried along
                min_learned_chunk: 512,
                drift_threshold: [0.01, 0.05, 0.2][rng.next_below(3) as usize],
                retrain: RetrainPolicy {
                    retrain_after: rng.next_below(3) as usize,
                    max_retrains: rng.next_below(4) as usize,
                },
                ..ExternalConfig::default()
            };
            let got = sort_f64_via_iter(&keys, &cfg);
            let mut want = keys;
            want.sort_unstable_by(f64::total_cmp);
            if bits(&got) != bits(&want) {
                return Err(format!(
                    "bit mismatch at n={n} regimes={regimes} budget={} threads={} \
                     shards={} drift={} retrain={:?}",
                    cfg.memory_budget,
                    cfg.threads,
                    cfg.merge_shards,
                    cfg.drift_threshold,
                    cfg.retrain
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn parallel_matches_serial_bytes_on_all_14_distributions() {
    // The PR's acceptance bar: on every paper distribution, the parallel
    // pipeline (overlapped IO + RMI-sharded merge, threads > 1) produces
    // *byte-identical* output to the serial reference (threads = 1).
    let n = 50_000;
    for spec in datasets::ALL.iter() {
        let input = tmp(&format!("dist-{}", spec.name));
        let serial_out = tmp(&format!("dist-{}-serial", spec.name));
        let parallel_out = tmp(&format!("dist-{}-parallel", spec.name));
        datasets::write_dataset_file(spec.name, n, 99, &input, 1 << 14).unwrap();
        let mut cfg = ExternalConfig {
            memory_budget: 3 * 8192 * 8,
            io_buffer: 1 << 12,
            threads: 1,
            min_shard_keys: 1024,
            ..ExternalConfig::default()
        };
        let serial = match spec.key_type {
            datasets::KeyType::F64 => {
                external::sort_file::<f64>(&input, &serial_out, &cfg).unwrap()
            }
            datasets::KeyType::U64 => {
                external::sort_file::<u64>(&input, &serial_out, &cfg).unwrap()
            }
        };
        cfg.threads = 4;
        let parallel = match spec.key_type {
            datasets::KeyType::F64 => {
                external::sort_file::<f64>(&input, &parallel_out, &cfg).unwrap()
            }
            datasets::KeyType::U64 => {
                external::sort_file::<u64>(&input, &parallel_out, &cfg).unwrap()
            }
        };
        assert_eq!(serial.keys, n as u64, "{}", spec.name);
        assert_eq!(parallel.keys, n as u64, "{}", spec.name);
        assert_eq!(
            std::fs::read(&serial_out).unwrap(),
            std::fs::read(&parallel_out).unwrap(),
            "{}: parallel output differs from serial",
            spec.name
        );
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&serial_out);
        let _ = std::fs::remove_file(&parallel_out);
    }
}

/// Sort the key file at `input` as `K` and require byte-equality (under
/// the key's ordered bits) with `std`'s total-order sort of the same
/// keys, reloaded from the file itself.
fn assert_width_sort_matches_std<K: SortKey>(
    input: &PathBuf,
    output: &PathBuf,
    cfg: &ExternalConfig,
    label: &str,
) {
    let keys = read_keys_file::<K>(input).unwrap();
    let report = external::sort_file::<K>(input, output, cfg).unwrap();
    assert_eq!(report.keys as usize, keys.len(), "{label}");
    let got = read_keys_file::<K>(output).unwrap();
    let mut want = keys;
    want.sort_unstable_by(|a, b| a.to_bits_ordered().cmp(&b.to_bits_ordered()));
    let gb: Vec<u64> = got.iter().map(|k| k.to_bits_ordered()).collect();
    let wb: Vec<u64> = want.iter().map(|k| k.to_bits_ordered()).collect();
    assert_eq!(gb, wb, "{label}: external sort differs from std sort");
}

#[test]
fn acceptance_u32_f32_sort_all_14_distributions_byte_equal_to_std() {
    // The PR's acceptance bar: every paper distribution, narrowed to 4
    // bytes by `gen --width 4`, sorts through the external pipeline with
    // byte-equality to the in-memory std sort of the same keys.
    let n = 40_000;
    for spec in datasets::ALL.iter() {
        let input = tmp(&format!("w4-{}", spec.name));
        let output = tmp(&format!("w4-{}-out", spec.name));
        let kind =
            datasets::write_dataset_file_width(spec.name, n, 77, &input, 1 << 14, 4).unwrap();
        // budget in *bytes*: 4-byte keys make these 8192-key pipelined
        // chunks, which clear min_learned_chunk where the data allows
        let cfg = ExternalConfig {
            memory_budget: 3 * 8192 * 4,
            io_buffer: 1 << 12,
            threads: 2,
            min_shard_keys: 1024,
            ..ExternalConfig::default()
        };
        let header = read_header(&input).unwrap().expect("gen writes v1 files");
        assert_eq!(header.kind, kind, "{}", spec.name);
        assert_eq!(header.count, n as u64, "{}", spec.name);
        match kind {
            KeyKind::F32 => assert_width_sort_matches_std::<f32>(&input, &output, &cfg, spec.name),
            KeyKind::U32 => assert_width_sort_matches_std::<u32>(&input, &output, &cfg, spec.name),
            other => panic!("{}: unexpected kind {other:?}", spec.name),
        }
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }
}

#[test]
fn four_byte_keys_halve_spill_bytes_and_run_count() {
    // Equal key counts under the same byte budget: the 4-byte stream
    // spills half the bytes per key, so chunks hold twice the keys and
    // half as many runs land on disk; outputs carry exactly n*4 vs n*8
    // payload bytes behind identical headers.
    let mut rng = Xoshiro256pp::new(0x4B1D);
    let n = 65_536usize;
    let keys64: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let keys32: Vec<u32> = keys64.iter().map(|&x| (x >> 32) as u32).collect();
    let cfg = ExternalConfig {
        memory_budget: 8192 * 8,
        io_buffer: 1 << 12,
        threads: 1,
        ..ExternalConfig::default()
    };
    let out64 = tmp("width-out64");
    let out32 = tmp("width-out32");
    let r64 = external::sort_iter(keys64.iter().copied(), &out64, &cfg).unwrap();
    let r32 = external::sort_iter(keys32.iter().copied(), &out32, &cfg).unwrap();
    assert_eq!(r64.runs, 8, "8Ki-key chunks over 64Ki u64 keys");
    assert_eq!(
        r32.runs, 4,
        "the same budget holds twice the u32 keys per chunk"
    );
    let payload64 = std::fs::metadata(&out64).unwrap().len() - HEADER_LEN as u64;
    let payload32 = std::fs::metadata(&out32).unwrap().len() - HEADER_LEN as u64;
    assert_eq!(payload64, (n * 8) as u64);
    assert_eq!(payload32, (n * 4) as u64);
    assert_eq!(
        payload32 * 2,
        payload64,
        "equal key counts must occupy half the bytes at width 4"
    );
    let mut want = keys32;
    want.sort_unstable();
    assert_eq!(read_keys_file::<u32>(&out32).unwrap(), want);
    let _ = std::fs::remove_file(&out64);
    let _ = std::fs::remove_file(&out32);
}

#[test]
fn property_codec_and_header_roundtrip_all_four_widths() {
    // Write/read roundtrips through the self-describing codec for every
    // key domain, on arbitrary bit patterns (floats are compared by bits,
    // so even NaN payloads must survive).
    check_sized(
        "spill-codec-roundtrip",
        PropConfig::with_max_size(16, 1 << 12),
        |rng, n| {
            let p = tmp("prop-codec");
            let expect_header = |kind: KeyKind, path: &PathBuf| -> Result<(), String> {
                let h = read_header(path)
                    .map_err(|e| e.to_string())?
                    .ok_or("missing header")?;
                if h.kind != kind || h.count != n as u64 {
                    return Err(format!("header {h:?} != ({kind:?}, {n})"));
                }
                Ok(())
            };

            let k: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            write_keys_file(&p, &k).map_err(|e| e.to_string())?;
            expect_header(KeyKind::U64, &p)?;
            if read_keys_file::<u64>(&p).map_err(|e| e.to_string())? != k {
                return Err("u64 roundtrip".into());
            }

            let k: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            write_keys_file(&p, &k).map_err(|e| e.to_string())?;
            expect_header(KeyKind::U32, &p)?;
            if read_keys_file::<u32>(&p).map_err(|e| e.to_string())? != k {
                return Err("u32 roundtrip".into());
            }

            let k: Vec<f64> = (0..n).map(|_| f64::from_bits(rng.next_u64())).collect();
            write_keys_file(&p, &k).map_err(|e| e.to_string())?;
            expect_header(KeyKind::F64, &p)?;
            let back = read_keys_file::<f64>(&p).map_err(|e| e.to_string())?;
            let a: Vec<u64> = k.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u64> = back.iter().map(|x| x.to_bits()).collect();
            if a != b {
                return Err("f64 roundtrip".into());
            }

            let k: Vec<f32> = (0..n)
                .map(|_| f32::from_bits(rng.next_u64() as u32))
                .collect();
            write_keys_file(&p, &k).map_err(|e| e.to_string())?;
            expect_header(KeyKind::F32, &p)?;
            let back = read_keys_file::<f32>(&p).map_err(|e| e.to_string())?;
            let a: Vec<u32> = k.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
            if a != b {
                return Err("f32 roundtrip".into());
            }

            let _ = std::fs::remove_file(&p);
            Ok(())
        },
    );
}

/// Sort `input` into `output` as keys of type `K` with an explicit spill
/// codec (threads = 2 so the overlapped pipeline and sharded merge are in
/// play; width-proportional budget so every width spills ≥ 4 runs).
fn sort_codec<K: SortKey>(
    input: &PathBuf,
    output: &PathBuf,
    codec: SpillCodec,
) -> external::ExternalSortReport {
    let cfg = ExternalConfig {
        memory_budget: 3 * 8192 * K::WIDTH,
        io_buffer: 1 << 12,
        threads: 2,
        min_shard_keys: 1024,
        spill_codec: codec,
        ..ExternalConfig::default()
    };
    external::sort_file::<K>(input, output, &cfg).unwrap()
}

#[test]
fn delta_codec_matches_raw_bytes_on_all_14_distributions_at_both_widths() {
    // The tentpole's acceptance bar: every paper distribution, at its
    // native 8-byte width AND narrowed to 4 bytes (all four key domains),
    // sorts byte-identically under the raw and delta spill codecs — the
    // compressed runs change the spill IO, never a single output byte.
    let n = 30_000;
    for spec in datasets::ALL.iter() {
        for w in [8usize, 4] {
            let tag = format!("codec-{}-w{w}", spec.name);
            let input = tmp(&tag);
            let raw_out = tmp(&format!("{tag}-raw"));
            let delta_out = tmp(&format!("{tag}-delta"));
            let kind =
                datasets::write_dataset_file_width(spec.name, n, 55, &input, 1 << 14, w).unwrap();
            let (raw, delta) = match kind {
                KeyKind::F64 => (
                    sort_codec::<f64>(&input, &raw_out, SpillCodec::Raw),
                    sort_codec::<f64>(&input, &delta_out, SpillCodec::Delta),
                ),
                KeyKind::U64 => (
                    sort_codec::<u64>(&input, &raw_out, SpillCodec::Raw),
                    sort_codec::<u64>(&input, &delta_out, SpillCodec::Delta),
                ),
                KeyKind::F32 => (
                    sort_codec::<f32>(&input, &raw_out, SpillCodec::Raw),
                    sort_codec::<f32>(&input, &delta_out, SpillCodec::Delta),
                ),
                KeyKind::U32 => (
                    sort_codec::<u32>(&input, &raw_out, SpillCodec::Raw),
                    sort_codec::<u32>(&input, &delta_out, SpillCodec::Delta),
                ),
                KeyKind::Str => unreachable!("width datasets are numeric"),
            };
            assert_eq!(raw.keys, n as u64, "{tag}");
            assert_eq!(delta.keys, n as u64, "{tag}");
            assert_eq!(
                raw.spill_bytes, raw.spill_bytes_raw,
                "{tag}: raw codec spills the fixed-width baseline"
            );
            assert_eq!(raw.spill_bytes_raw, delta.spill_bytes_raw, "{tag}");
            assert_eq!(
                std::fs::read(&raw_out).unwrap(),
                std::fs::read(&delta_out).unwrap(),
                "{tag}: the spill codec leaked into the output bytes"
            );
            let _ = std::fs::remove_file(&input);
            let _ = std::fs::remove_file(&raw_out);
            let _ = std::fs::remove_file(&delta_out);
        }
    }
}

#[test]
fn delta_codec_shrinks_dup_heavy_and_zipf_spills() {
    // The codec's reason to exist: measurably fewer spill bytes exactly
    // on the duplicate-heavy inputs ("Defeating duplicates") and zipf.
    // Sorted-run deltas are small varints and duplicate plateaus collapse
    // into run-length escapes; bounds are generous vs the observed ~0.6x
    // (zipf) and ~0.3x (timestamp/plateau) ratios.
    let n = 120_000;
    for (name, max_ratio) in [("zipf", 0.85), ("wiki_edit", 0.70), ("books_sales", 0.60)] {
        let spec = datasets::spec(name).unwrap();
        let input = tmp(&format!("shrink-{name}"));
        let output = tmp(&format!("shrink-{name}-out"));
        datasets::write_dataset_file(name, n, 66, &input, 1 << 14).unwrap();
        let report = match spec.key_type {
            datasets::KeyType::F64 => sort_codec::<f64>(&input, &output, SpillCodec::Delta),
            datasets::KeyType::U64 => sort_codec::<u64>(&input, &output, SpillCodec::Delta),
        };
        let ratio = report.spill_bytes as f64 / report.spill_bytes_raw as f64;
        assert!(
            ratio < max_ratio,
            "{name}: delta spill ratio {ratio:.3} !< {max_ratio}"
        );
        assert!(report.runs >= 4, "{name}: runs={}", report.runs);
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }
}

#[test]
fn sorted_v2_input_files_sort_through_header_dispatch() {
    // A delta-coded (v2) file is a legal *input* too — the reader
    // dispatches its codec off the header, so `extsort` can consume
    // compressed run files directly; the output upgrades to raw v1.
    let mut rng = Xoshiro256pp::new(0x52D);
    let mut keys: Vec<u64> = (0..60_000).map(|_| rng.next_below(1 << 20)).collect();
    keys.sort_unstable(); // the delta writer requires nondecreasing keys
    let input = tmp("v2-in");
    let output = tmp("v2-out");
    let mut w = RunWriter::<u64>::create_with(input.clone(), 1 << 14, SpillCodec::Delta).unwrap();
    w.write_slice(&keys).unwrap();
    w.finish().unwrap();
    let h = read_header(&input).unwrap().expect("v2 header present");
    assert_eq!(h.version, external::DELTA_VERSION);

    let report = external::sort_file::<u64>(&input, &output, &cfg_with_budget(8192 * 8)).unwrap();
    assert_eq!(report.keys as usize, keys.len());
    assert!(report.runs > 1, "the v2 input must really spill");
    assert_eq!(read_keys_file::<u64>(&output).unwrap(), keys);
    let out_h = read_header(&output).unwrap().expect("output has a header");
    assert_eq!(out_h.version, external::RAW_VERSION, "outputs are raw v1");
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn property_delta_codec_roundtrip_all_four_widths() {
    // Random sorted key sets — biased toward duplicate plateaus, single
    // keys and maximal deltas — must roundtrip bit-exactly through the
    // delta+varint block codec in every key domain, with the header
    // reporting v2 and the validated count.
    check_sized(
        "delta-codec-roundtrip",
        PropConfig::with_max_size(16, 1 << 13),
        |rng, n| {
            let p = tmp("prop-delta");
            fn write_delta<K: SortKey>(p: &PathBuf, keys: &[K]) -> Result<(), String> {
                let mut w = RunWriter::<K>::create_with(p.clone(), 1 << 12, SpillCodec::Delta)
                    .map_err(|e| e.to_string())?;
                w.write_slice(keys).map_err(|e| e.to_string())?;
                w.finish().map_err(|e| e.to_string())?;
                Ok(())
            }
            let expect_v2 = |p: &PathBuf, count: u64| -> Result<(), String> {
                let h = read_header(p)
                    .map_err(|e| e.to_string())?
                    .ok_or("missing header")?;
                if h.version != external::DELTA_VERSION || h.count != count {
                    return Err(format!("header {h:?} != (v2, {count})"));
                }
                external::file_key_count(p).map_err(|e| e.to_string())?;
                Ok(())
            };
            // duplicate-plateau shape: few distinct values, long runs
            let plateau = 1 + rng.next_below(16);

            let mut k: Vec<u64> = (0..n)
                .map(|_| match rng.next_below(8) {
                    0 => 0,
                    1 => u64::MAX, // max-delta pairs appear after sorting
                    _ => rng.next_below(plateau) << 32,
                })
                .collect();
            k.sort_unstable();
            write_delta(&p, &k)?;
            expect_v2(&p, n as u64)?;
            if read_keys_file::<u64>(&p).map_err(|e| e.to_string())? != k {
                return Err("u64 delta roundtrip".into());
            }

            let mut k: Vec<u32> = (0..n)
                .map(|_| match rng.next_below(8) {
                    0 => 0,
                    1 => u32::MAX,
                    _ => rng.next_below(plateau) as u32 * 0x0100_0000,
                })
                .collect();
            k.sort_unstable();
            write_delta(&p, &k)?;
            expect_v2(&p, n as u64)?;
            if read_keys_file::<u32>(&p).map_err(|e| e.to_string())? != k {
                return Err("u32 delta roundtrip".into());
            }

            let mut k: Vec<f64> = (0..n)
                .map(|_| match rng.next_below(8) {
                    0 => f64::NEG_INFINITY,
                    1 => f64::INFINITY,
                    _ => rng.normal() * 10f64.powi(rng.next_below(plateau) as i32),
                })
                .collect();
            k.sort_unstable_by(f64::total_cmp);
            write_delta(&p, &k)?;
            expect_v2(&p, n as u64)?;
            let back = read_keys_file::<f64>(&p).map_err(|e| e.to_string())?;
            if bits(&back) != bits(&k) {
                return Err("f64 delta roundtrip".into());
            }

            let mut k: Vec<f32> = (0..n)
                .map(|_| (rng.next_below(plateau) as f32 - 4.0) * 1.5)
                .collect();
            k.sort_unstable_by(f32::total_cmp);
            write_delta(&p, &k)?;
            expect_v2(&p, n as u64)?;
            let back = read_keys_file::<f32>(&p).map_err(|e| e.to_string())?;
            let a: Vec<u32> = k.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
            if a != b {
                return Err("f32 delta roundtrip".into());
            }

            let _ = std::fs::remove_file(&p);
            Ok(())
        },
    );
}

#[test]
fn legacy_headerless_v0_files_still_sort_unchanged() {
    // Pre-header files — raw 8-byte LE keys, the old `gen --out` format —
    // must keep sorting exactly; the output is upgraded to v1.
    let mut rng = Xoshiro256pp::new(0x0F0F);
    let keys: Vec<u64> = (0..50_000).map(|_| rng.next_u64()).collect();
    let input = tmp("v0-in");
    let output = tmp("v0-out");
    let raw: Vec<u8> = keys.iter().flat_map(|k| k.to_le_bytes()).collect();
    std::fs::write(&input, &raw).unwrap();
    assert_eq!(read_header(&input).unwrap(), None, "v0 files have no header");

    let cfg = cfg_with_budget(8192 * 8);
    let report = external::sort_file::<u64>(&input, &output, &cfg).unwrap();
    assert_eq!(report.keys as usize, keys.len());
    assert!(report.runs > 1, "the v0 input must really spill");
    let mut want = keys;
    want.sort_unstable();
    assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
    assert!(
        read_header(&output).unwrap().is_some(),
        "outputs are always written in the current format"
    );

    // v0 f64 files decode through the same path
    let fkeys: Vec<f64> = (0..20_000).map(|_| rng.uniform(-1e6, 1e6)).collect();
    let raw: Vec<u8> = fkeys.iter().flat_map(|k| k.to_le_bytes()).collect();
    std::fs::write(&input, &raw).unwrap();
    let report = external::sort_file::<f64>(&input, &output, &cfg).unwrap();
    assert_eq!(report.keys as usize, fkeys.len());
    let mut want = fkeys;
    want.sort_unstable_by(f64::total_cmp);
    assert_eq!(bits(&read_keys_file::<f64>(&output).unwrap()), bits(&want));
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn sort_file_rejects_bad_inputs_with_clear_errors() {
    let input = tmp("bad-in");
    let output = tmp("bad-out");
    let cfg = ExternalConfig::default();

    // truncated v1 payload: header promises more keys than the file holds
    let mut bytes = SpillHeader::new(KeyKind::U64, 100).encode().to_vec();
    bytes.extend((0..50u64).flat_map(|k| k.to_le_bytes()));
    std::fs::write(&input, &bytes).unwrap();
    let err = external::sort_file::<u64>(&input, &output, &cfg).unwrap_err();
    assert!(err.to_string().contains("truncated"), "{err}");

    // key-type mismatch: a u32 file sorted as u64 (or f32)
    write_keys_file::<u32>(&input, &[3, 1, 2]).unwrap();
    for err in [
        external::sort_file::<u64>(&input, &output, &cfg).unwrap_err(),
        external::sort_file::<f32>(&input, &output, &cfg).unwrap_err(),
    ] {
        assert!(err.to_string().contains("u32"), "{err}");
    }

    // headerless files cannot be read as 4-byte keys at all
    std::fs::write(&input, 7u64.to_le_bytes()).unwrap();
    let err = external::sort_file::<u32>(&input, &output, &cfg).unwrap_err();
    assert!(err.to_string().contains("headerless"), "{err}");

    // headerless length not a multiple of 8
    std::fs::write(&input, [0u8; 12]).unwrap();
    let err = external::sort_file::<u64>(&input, &output, &cfg).unwrap_err();
    assert!(err.to_string().contains("multiple of 8"), "{err}");

    // corrupted magic tail: right magic, unsupported version
    let mut h = SpillHeader::new(KeyKind::U64, 0).encode();
    h[8] = 0xFF;
    std::fs::write(&input, h).unwrap();
    let err = external::sort_file::<u64>(&input, &output, &cfg).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");

    // every failure happened before the merge: no output was created
    assert!(!output.exists(), "failed validation must not touch the output");
    let _ = std::fs::remove_file(&input);
}

#[test]
fn ips4o_run_strategy_is_exact_too() {
    let keys = datasets::generate_u64("wiki_edit", 100_000, 5).unwrap();
    let input = tmp("ips4o-in");
    let output = tmp("ips4o-out");
    write_keys_file(&input, &keys).unwrap();
    let cfg = ExternalConfig {
        run_gen: RunGen::Ips4o,
        ..cfg_with_budget(16_384 * 8)
    };
    let report = external::sort_file::<u64>(&input, &output, &cfg).unwrap();
    assert!(!report.rmi_trained);
    assert_eq!(report.learned_runs, 0);
    let mut want = keys;
    want.sort_unstable();
    assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}
