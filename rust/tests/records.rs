//! Differential tests for the record-aware sorting core: key+payload
//! records ([`SortItem`]) and 16-byte prefix-string keys
//! ([`PrefixString`]) through the sequential engines, the parallel
//! engines and the external pipeline, checked against `sort_unstable_by`
//! of the same data on every paper distribution.
//!
//! Payloads are the row id of the source record (the datasets layer's
//! convention), which makes two properties checkable after any unstable
//! sort:
//!
//! - **multiset preservation** — the sorted ids are a permutation of
//!   `0..n` (no payload duplicated, dropped or corrupted);
//! - **key alignment** — every output record's payload still identifies
//!   a source record carrying that exact key (a swap of payloads between
//!   two equal keys is legal for an unstable sort; a swap across
//!   *different* keys is corruption).
//!
//! Key order itself must be byte-identical to the reference sort.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use aipso::datasets;
use aipso::external::{self, read_keys_file, write_keys_file, ExternalConfig};
use aipso::key::{PrefixString, SortItem};
use aipso::util::rng::Xoshiro256pp;
use aipso::{sort_parallel, sort_sequential, KeyKind, SortEngine, SortKey};

fn tmp(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "aipso-records-it-{}-{}-{tag}.bin",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Check one sorted record sequence against its source: byte-identical
/// key order vs the reference sort, ids a permutation of `0..n`, and
/// every id pointing back at a source record with the same key.
fn assert_records_sorted<K: SortKey>(
    got: &[SortItem<K, 8>],
    source: &[SortItem<K, 8>],
    label: &str,
) {
    assert_eq!(got.len(), source.len(), "{label}: record count drift");
    let mut want: Vec<K> = source.iter().map(|r| r.key).collect();
    want.sort_unstable_by(|a, b| a.key_cmp(*b));
    // Key order byte-identical to the reference (total order -> the bit
    // images match position by position; PrefixString compares raw bytes).
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            g.key.key_eq(*w),
            "{label}: key order diverges from the reference at row {i}"
        );
    }
    // Payload multiset + key alignment via the row-id convention.
    let mut ids: Vec<u64> = got
        .iter()
        .map(|r| u64::from_le_bytes(r.val))
        .collect();
    for (i, r) in got.iter().enumerate() {
        let id = u64::from_le_bytes(r.val) as usize;
        assert!(id < source.len(), "{label}: corrupt payload at row {i}");
        assert!(
            source[id].key.key_eq(r.key),
            "{label}: payload {id} migrated across keys at row {i}"
        );
    }
    ids.sort_unstable();
    assert!(
        ids.iter().enumerate().all(|(i, &id)| id == i as u64),
        "{label}: payload multiset not preserved"
    );
}

/// Sequential + parallel in-memory record sort of `keys` with row-id
/// payloads, differentially checked against the reference.
fn check_in_memory_records<K: SortKey>(keys: Vec<K>, label: &str) {
    let source: Vec<SortItem<K, 8>> = datasets::attach_payloads(keys, 0);
    let mut seq = source.clone();
    sort_sequential(SortEngine::Aips2o, &mut seq);
    assert_records_sorted(&seq, &source, &format!("{label}/seq"));
    let mut par = source.clone();
    sort_parallel(SortEngine::Aips2o, &mut par, 4);
    assert_records_sorted(&par, &source, &format!("{label}/par"));
}

#[test]
fn in_memory_record_sorts_match_reference_on_all_14_distributions() {
    let n = 20_000;
    for spec in datasets::ALL.iter() {
        match spec.key_type {
            datasets::KeyType::F64 => {
                let keys = datasets::generate_f64(spec.name, n, 0xA11CE).unwrap();
                check_in_memory_records(keys, spec.name);
            }
            datasets::KeyType::U64 => {
                let keys = datasets::generate_u64(spec.name, n, 0xA11CE).unwrap();
                check_in_memory_records(keys, spec.name);
            }
        }
    }
}

/// External record sort of a `gen --payload 8` file, read back and
/// differentially checked against the reference sort of the *input file's*
/// records (the file is the contract — chunked generators may legally
/// differ from the in-memory ones on stateful laws like `wiki_edit`).
fn check_external_records<K: SortKey>(input: &PathBuf, output: &PathBuf, label: &str) {
    let source = read_keys_file::<SortItem<K, 8>>(input).unwrap();
    let cfg = ExternalConfig {
        // entry = 8-byte key + 8-byte lane; ~3 pipelined chunks of 8192
        // records under the budget, so every law spills several runs
        memory_budget: 3 * 8192 * 16,
        io_buffer: 1 << 12,
        threads: 2,
        min_shard_keys: 1024,
        ..ExternalConfig::default()
    };
    let (report, _, ok) =
        external::sort_and_verify(K::KIND, 8, input, output, &cfg).unwrap();
    assert!(ok, "{label}: output failed stream verification");
    assert_eq!(report.keys as usize, source.len(), "{label}: key count drift");
    assert!(report.runs > 1, "{label}: dataset must exceed the budget");
    let got = read_keys_file::<SortItem<K, 8>>(output).unwrap();
    assert_records_sorted(&got, &source, label);
}

#[test]
fn external_record_sorts_match_reference_on_all_14_distributions() {
    let n = 40_000;
    for spec in datasets::ALL.iter() {
        let input = tmp(&format!("ext-{}", spec.name));
        let output = tmp(&format!("ext-{}-out", spec.name));
        let kind =
            datasets::write_dataset_file_ext(spec.name, n, 33, &input, 1 << 14, 8, false, 8)
                .unwrap();
        match kind {
            KeyKind::F64 => check_external_records::<f64>(&input, &output, spec.name),
            KeyKind::U64 => check_external_records::<u64>(&input, &output, spec.name),
            other => panic!("{}: unexpected kind {other:?}", spec.name),
        }
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }
}

/// Adversarial prefix-tie strings: a small pool of 8-byte prefixes (all
/// ordered bits collide within a pool entry) with random tails, so the
/// engines' bit-space work is useless inside each tie region and every
/// ordering decision there falls to the full-comparison repair.
fn prefix_tied_strings(n: usize, seed: u64) -> Vec<PrefixString> {
    let mut rng = Xoshiro256pp::new(seed);
    (0..n)
        .map(|_| {
            let mut b = [0u8; 16];
            b[..8].copy_from_slice(format!("pfx-{:04}", rng.next_below(64)).as_bytes());
            for t in b[8..].iter_mut() {
                // printable tails, including many exact full-key dups
                *t = b'a' + (rng.next_below(8) as u8);
            }
            PrefixString::from_bytes(&b)
        })
        .collect()
}

#[test]
fn string_sorts_repair_prefix_ties_in_memory_and_externally() {
    let n = 30_000;
    let base = prefix_tied_strings(n, 0x5EED);
    let mut want = base.clone();
    want.sort_unstable(); // PrefixString's derived Ord = full lexicographic
    let as_bytes = |v: &[PrefixString]| -> Vec<[u8; 16]> {
        v.iter().map(|s| *s.as_bytes()).collect()
    };

    for engine in [SortEngine::Aips2o, SortEngine::LearnedSort, SortEngine::Ips4o] {
        let mut seq = base.clone();
        sort_sequential(engine, &mut seq);
        assert_eq!(as_bytes(&seq), as_bytes(&want), "{engine:?}/seq");
        let mut par = base.clone();
        sort_parallel(engine, &mut par, 4);
        assert_eq!(as_bytes(&par), as_bytes(&want), "{engine:?}/par");
    }

    let input = tmp("str-ties");
    let output = tmp("str-ties-out");
    write_keys_file(&input, &base).unwrap();
    let cfg = ExternalConfig {
        memory_budget: 3 * 8192 * 16,
        io_buffer: 1 << 12,
        threads: 2,
        min_shard_keys: 1024,
        ..ExternalConfig::default()
    };
    let (report, _, ok) =
        external::sort_and_verify(KeyKind::Str, 0, &input, &output, &cfg).unwrap();
    assert!(ok, "external string sort failed stream verification");
    assert_eq!(report.keys as usize, n);
    assert!(report.runs > 1, "string input must exceed the budget");
    let got = read_keys_file::<PrefixString>(&output).unwrap();
    assert_eq!(as_bytes(&got), as_bytes(&want), "external");
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn string_records_carry_payloads_through_every_path() {
    // Records whose *keys* are prefix-tied strings: the tie-repair and the
    // payload lane have to compose (the repair must move whole records).
    let n = 20_000;
    let keys = prefix_tied_strings(n, 0xF00D);
    check_in_memory_records(keys, "str-records");

    // And through the external pipeline: string datasets with a payload
    // lane, straight from the chunked `gen --key str --payload 8` path.
    let input = tmp("str-rec");
    let output = tmp("str-rec-out");
    let kind =
        datasets::write_dataset_file_ext("wiki_edit", n, 7, &input, 1 << 14, 8, true, 8)
            .unwrap();
    assert_eq!(kind, KeyKind::Str);
    check_external_records::<PrefixString>(&input, &output, "wiki_edit/str-rec");
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}
