//! End-to-end tests for the spill-IO substrate: the differential matrix
//! pinning byte-identical outputs across every backend × striping ×
//! O_DIRECT combination on all 14 paper distributions at both key
//! widths, zigzag (v3) `gen` outputs sorting through header dispatch,
//! and the side-car block-skip accounting of the sharded merge (a
//! narrow-cut range open must skip whole blocks without decoding them).
//!
//! The substrate contract under test: sync vs pool backends, one vs many
//! spill dirs, and direct vs buffered IO are *pure transport* — they may
//! change where bytes sit and how they travel, never a single output
//! byte.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use aipso::datasets;
use aipso::external::{
    self, read_header, read_keys_file, write_keys_file_codec, ExternalConfig, IoBackendKind,
    SpillCodec,
};
use aipso::obs;
use aipso::util::rng::Xoshiro256pp;
use aipso::SortKey;

fn tmp(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "aipso-io-it-{}-{}-{tag}.bin",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// One substrate variant of the differential matrix.
struct Variant {
    label: &'static str,
    backend: IoBackendKind,
    stripes: usize,
    direct: bool,
}

const VARIANTS: [Variant; 4] = [
    Variant { label: "sync-1dir", backend: IoBackendKind::Sync, stripes: 1, direct: false },
    Variant { label: "pool-1dir", backend: IoBackendKind::Pool, stripes: 1, direct: false },
    Variant { label: "pool-2dir", backend: IoBackendKind::Pool, stripes: 2, direct: false },
    Variant { label: "pool-2dir-direct", backend: IoBackendKind::Pool, stripes: 2, direct: true },
];

/// Pipelined config (threads = 2, sharded merge in play) for one
/// substrate variant, with width-proportional budget so every width
/// spills several runs.
fn variant_cfg(v: &Variant, roots: &[PathBuf], width: usize) -> ExternalConfig {
    ExternalConfig {
        memory_budget: 3 * 8192 * width,
        io_buffer: 1 << 12,
        threads: 2,
        min_shard_keys: 1024,
        io_backend: v.backend,
        direct_io: v.direct,
        spill_dirs: roots[..v.stripes].to_vec(),
        ..ExternalConfig::default()
    }
}

fn sort_variant<K: SortKey>(
    input: &PathBuf,
    output: &PathBuf,
    v: &Variant,
    roots: &[PathBuf],
) -> external::ExternalSortReport {
    external::sort_file::<K>(input, output, &variant_cfg(v, roots, K::WIDTH)).unwrap()
}

#[test]
fn io_matrix_is_byte_identical_on_all_14_distributions_at_both_widths() {
    // The tentpole's acceptance bar: every paper distribution, at its
    // native 8-byte width AND narrowed to 4 (all four key domains),
    // sorts byte-identically under the sync reference and every pool /
    // striping / O_DIRECT combination. Where the filesystem refuses
    // O_DIRECT (tmpfs), the silent buffered fallback must hold the same
    // contract.
    let n = 24_000;
    let roots = [tmp("stripe-a"), tmp("stripe-b")].map(|p| p.with_extension(""));
    for spec in datasets::ALL.iter() {
        for w in [8usize, 4] {
            let tag = format!("mx-{}-w{w}", spec.name);
            let input = tmp(&tag);
            let kind =
                datasets::write_dataset_file_width(spec.name, n, 91, &input, 1 << 14, w).unwrap();
            let mut reference: Option<Vec<u8>> = None;
            for v in &VARIANTS {
                let output = tmp(&format!("{tag}-{}", v.label));
                let report = match kind {
                    aipso::KeyKind::F64 => sort_variant::<f64>(&input, &output, v, &roots),
                    aipso::KeyKind::U64 => sort_variant::<u64>(&input, &output, v, &roots),
                    aipso::KeyKind::F32 => sort_variant::<f32>(&input, &output, v, &roots),
                    aipso::KeyKind::U32 => sort_variant::<u32>(&input, &output, v, &roots),
                    aipso::KeyKind::Str => unreachable!("width datasets are numeric"),
                };
                assert_eq!(report.keys, n as u64, "{tag}/{}", v.label);
                let bytes = std::fs::read(&output).unwrap();
                match &reference {
                    None => reference = Some(bytes),
                    Some(want) => assert_eq!(
                        &bytes, want,
                        "{tag}: {} output differs from the sync reference",
                        v.label
                    ),
                }
                let _ = std::fs::remove_file(&output);
            }
            let _ = std::fs::remove_file(&input);
        }
    }
    for r in roots {
        let _ = std::fs::remove_dir_all(r);
    }
}

#[test]
fn zigzag_gen_files_sort_through_header_dispatch() {
    // A zigzag-coded (v3) file — the compressed *unsorted* `gen --codec
    // zigzag` format — is a legal extsort input: the reader dispatches
    // the codec off the header, and the sorted output upgrades to raw v1.
    let mut rng = Xoshiro256pp::new(0x2162);
    let keys: Vec<u64> = (0..60_000).map(|_| rng.next_below(1 << 24)).collect();
    let input = tmp("zz-in");
    let output = tmp("zz-out");
    let run = write_keys_file_codec(&input, &keys, SpillCodec::Zigzag).unwrap();
    assert_eq!(run.n, keys.len() as u64);
    let h = read_header(&input).unwrap().expect("v3 header present");
    assert_eq!(h.version, external::ZIGZAG_VERSION);
    // near-sequential small keys: the varint stream must actually shrink
    let on_disk = std::fs::metadata(&input).unwrap().len();
    assert!(
        on_disk < (keys.len() * 8) as u64,
        "zigzag gen file must compress ({on_disk} bytes for {} keys)",
        keys.len()
    );

    let cfg = ExternalConfig {
        memory_budget: 8192 * 8,
        io_buffer: 1 << 12,
        threads: 2,
        ..ExternalConfig::default()
    };
    let report = external::sort_file::<u64>(&input, &output, &cfg).unwrap();
    assert_eq!(report.keys as usize, keys.len());
    assert!(report.runs > 1, "the v3 input must really spill");
    let mut want = keys;
    want.sort_unstable();
    assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
    let out_h = read_header(&output).unwrap().expect("output has a header");
    assert_eq!(out_h.version, external::RAW_VERSION, "outputs are raw v1");
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn sharded_merge_skips_sidecar_bounded_blocks() {
    // The block-skip acceptance: a sharded merge over v2 delta runs
    // carries each run's side-car bounds through the shard plan, so a
    // shard's narrow cut skips whole blocks outside its range without
    // decoding them — `shard.blocks.skipped` must land above zero, which
    // is exactly "decoded strictly fewer blocks than the directory
    // holds". (This binary runs no other obs-enabled test, so no lock.)
    let input = tmp("skip-in");
    let output = tmp("skip-out");
    let n = 100_000;
    datasets::write_dataset_file("uniform", n, 7, &input, 1 << 14).expect("dataset write");
    let cfg = ExternalConfig {
        memory_budget: 3 * 8192 * 8,
        io_buffer: 1 << 12,
        threads: 4,
        merge_shards: 4,
        min_shard_keys: 1024,
        spill_codec: SpillCodec::Delta,
        io_backend: IoBackendKind::Pool,
        ..ExternalConfig::default()
    };

    obs::reset();
    obs::set_enabled(true);
    let report = external::sort_file::<f64>(&input, &output, &cfg).unwrap();
    obs::set_enabled(false);
    assert_eq!(report.keys as usize, n);
    assert!(
        report.merge_shards >= 2,
        "uniform data at this size must engage the sharded merge"
    );

    let m = obs::metrics::snapshot();
    let counter = |name: &str| m.counters.get(name).copied().unwrap_or(0);
    assert!(
        counter(obs::C_SIDECAR_HIT) >= 1,
        "v2 spilled runs must plan through their side-cars"
    );
    assert!(
        counter(obs::C_BLOCKS_SKIPPED) >= 1,
        "narrow shard cuts must skip side-car-bounded blocks undecoded"
    );
    assert!(
        counter(obs::C_IO_WRITES) >= 1,
        "the pool backend must route spill writes through the IO workers"
    );
    obs::reset();
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn direct_io_request_survives_any_filesystem_answer() {
    // --direct is a request, not a demand: on filesystems that refuse
    // O_DIRECT (tmpfs) the sink silently falls back to buffered writes.
    // Either way the sort must stay exact and the output header-clean
    // (the alignment pad never leaks into final outputs).
    let mut rng = Xoshiro256pp::new(0xD1EC);
    let keys: Vec<u64> = (0..50_000).map(|_| rng.next_u64()).collect();
    let output = tmp("direct-out");
    let cfg = ExternalConfig {
        memory_budget: 8192 * 8,
        io_buffer: 1 << 12,
        threads: 2,
        direct_io: true,
        ..ExternalConfig::default()
    };
    let report = external::sort_iter(keys.iter().copied(), &output, &cfg).unwrap();
    assert_eq!(report.keys as usize, keys.len());
    let mut want = keys;
    want.sort_unstable();
    assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
    let h = read_header(&output).unwrap().expect("output has a header");
    assert_eq!(h.pad, 0, "final outputs are never alignment-padded");
    assert_eq!(
        std::fs::metadata(&output).unwrap().len(),
        external::HEADER_LEN as u64 + (want.len() * 8) as u64,
        "no direct-IO padding may leak into the output length"
    );
    let _ = std::fs::remove_file(&output);
}

#[test]
fn single_run_direct_spill_still_copies_clean() {
    // Budget larger than the input: one run, no merge — the "plain copy"
    // final path. Under --direct the single spilled run may carry an
    // alignment pad, which the copy path must strip by transcoding.
    let mut rng = Xoshiro256pp::new(0x51C0);
    let keys: Vec<u64> = (0..5_000).map(|_| rng.next_u64()).collect();
    let output = tmp("single-direct-out");
    let cfg = ExternalConfig {
        memory_budget: 1 << 20,
        threads: 1,
        direct_io: true,
        ..ExternalConfig::default()
    };
    let report = external::sort_iter(keys.iter().copied(), &output, &cfg).unwrap();
    assert_eq!(report.runs, 1, "everything must fit one run");
    assert_eq!(report.merge_passes, 0);
    let mut want = keys;
    want.sort_unstable();
    assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
    assert_eq!(
        std::fs::metadata(&output).unwrap().len(),
        external::HEADER_LEN as u64 + (want.len() * 8) as u64,
        "the single-run copy must not carry the spill's alignment pad"
    );
    let _ = std::fs::remove_file(&output);
}
