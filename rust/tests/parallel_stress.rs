//! Stress tests for the parallel machinery: thread-count sweeps, odd
//! sizes, repeated runs racing the scheduler, skew.

use aipso::util::rng::Xoshiro256pp;
use aipso::util::stats::multiset_digest;
use aipso::{is_sorted, sort_parallel, SortEngine};

#[test]
fn thread_count_sweep() {
    let mut rng = Xoshiro256pp::new(1);
    let base: Vec<u64> = (0..200_000).map(|_| rng.next_u64()).collect();
    let digest = multiset_digest(&base);
    for threads in [1usize, 2, 3, 4, 5, 7, 8, 12, 16] {
        for engine in SortEngine::PARALLEL_FIGURES {
            let mut v = base.clone();
            sort_parallel(engine, &mut v, threads);
            assert!(is_sorted(&v), "{engine:?} t={threads}");
            assert_eq!(digest, multiset_digest(&v), "{engine:?} t={threads}");
        }
    }
}

#[test]
fn odd_sizes_with_many_threads() {
    // sizes chosen to hit partial blocks, partial stripes, single-slot
    // stripes and the overflow path (n % block != 0)
    for n in [65_537usize, 100_003, 131_071, 131_073, 262_145] {
        let mut rng = Xoshiro256pp::new(n as u64);
        let base: Vec<f64> = (0..n).map(|_| rng.normal() * 1e6).collect();
        let digest = multiset_digest(&base);
        for engine in SortEngine::PARALLEL_FIGURES {
            let mut v = base.clone();
            sort_parallel(engine, &mut v, 8);
            assert!(is_sorted(&v), "{engine:?} n={n}");
            assert_eq!(digest, multiset_digest(&v), "{engine:?} n={n}");
        }
    }
}

#[test]
fn repeated_runs_race_the_scheduler() {
    // Re-running the same parallel sort hunts for permutation races:
    // any lost/duplicated block shows up as a digest mismatch.
    let mut rng = Xoshiro256pp::new(3);
    let base: Vec<u64> = (0..300_000).map(|_| rng.next_below(1 << 48)).collect();
    let digest = multiset_digest(&base);
    for rep in 0..8 {
        let mut v = base.clone();
        sort_parallel(SortEngine::Aips2o, &mut v, 8);
        assert!(is_sorted(&v), "rep={rep}");
        assert_eq!(digest, multiset_digest(&v), "rep={rep}");
    }
}

#[test]
fn skewed_bucket_load() {
    // 99% of keys in one tiny value range + 1% spread wide: one bucket
    // dominates, exercising task-pool rebalancing.
    let mut rng = Xoshiro256pp::new(5);
    let n = 400_000;
    let base: Vec<u64> = (0..n)
        .map(|i| {
            if i % 100 == 0 {
                rng.next_u64()
            } else {
                1_000_000 + rng.next_below(1000)
            }
        })
        .collect();
    let digest = multiset_digest(&base);
    for engine in SortEngine::PARALLEL_FIGURES {
        let mut v = base.clone();
        sort_parallel(engine, &mut v, 8);
        assert!(is_sorted(&v), "{engine:?}");
        assert_eq!(digest, multiset_digest(&v), "{engine:?}");
    }
}

#[test]
fn more_threads_than_work() {
    let base: Vec<u64> = (0..10_000u64).rev().collect();
    for engine in SortEngine::PARALLEL_FIGURES {
        let mut v = base.clone();
        sort_parallel(engine, &mut v, 64);
        assert!(is_sorted(&v), "{engine:?}");
    }
}

#[test]
fn coordinator_mixed_jobs_hit_parallel_fragment_path() {
    // Hammer the parallel fragmented LearnedSort under the coordinator's
    // mixed job stream: large jobs of all four KeyBuf widths (admitted
    // on the full pool, threads > 1 ⇒ the frag-par path), a ≥90%-dup
    // stream (equality buckets under concurrency) and small jobs riding
    // the sequential batch lane. Every report must verify sorted, and
    // the telemetry must show nonzero frag-par span and counter counts —
    // proof the parallel fragment partition actually ran, not a silent
    // fallback.
    use aipso::coordinator::{Coordinator, EngineChoice, JobSpec, KeyBuf};
    use aipso::obs;

    obs::reset();
    obs::set_enabled(true);
    let mut rng = Xoshiro256pp::new(11);
    let n = 40_000; // above the coordinator's small-job threshold
    let coord = Coordinator::new(4);
    let mut id = 0u64;
    let mut large_jobs = 0u64;
    {
        let mut submit = |keys: KeyBuf, large: bool| {
            let mut job = JobSpec::auto(id, keys);
            job.engine = EngineChoice::Fixed(SortEngine::LearnedSort);
            coord.submit(job);
            id += 1;
            if large {
                large_jobs += 1;
            }
        };
        for _rep in 0..3 {
            let f64s: Vec<f64> = (0..n).map(|_| rng.normal() * 1e6).collect();
            submit(KeyBuf::F64(f64s), true);
            let u64s: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            submit(KeyBuf::U64(u64s), true);
            let f32s: Vec<f32> = (0..n).map(|_| rng.uniform(-1e5, 1e5) as f32).collect();
            submit(KeyBuf::F32(f32s), true);
            let u32s: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            submit(KeyBuf::U32(u32s), true);
            // ≥90% duplicates: eight distinct values across 40k keys
            let dups: Vec<u64> = (0..n).map(|_| rng.next_below(8)).collect();
            submit(KeyBuf::U64(dups), true);
            // small jobs interleave on the sequential batch lane
            submit(KeyBuf::U64((0..1000u64).rev().collect()), false);
            submit(KeyBuf::F64((0..1000).map(|i| i as f64).collect()), false);
        }
    }
    let (reports, _metrics) = coord.drain();
    obs::set_enabled(false);

    assert_eq!(reports.len() as u64, id, "every job must report");
    for r in &reports {
        assert!(r.verified_sorted, "job {} failed post-sort verification", r.id);
        assert_eq!(r.engine, SortEngine::LearnedSort, "job {}", r.id);
    }
    let names = obs::trace::span_names(&obs::trace::snapshot());
    let sweeps = names.iter().filter(|&&s| s == obs::S_FRAG_PAR_SWEEP).count();
    let merges = names.iter().filter(|&&s| s == obs::S_FRAG_PAR_MERGE).count();
    assert!(
        sweeps > 0 && merges > 0,
        "no frag-par spans recorded (sweeps={sweeps} merges={merges}): \
         the parallel fragment path did not run"
    );
    let m = obs::metrics::snapshot();
    let par_partitions = m.counters.get(obs::C_FRAG_PAR).copied().unwrap_or(0);
    assert!(
        par_partitions >= large_jobs,
        "expected ≥{large_jobs} parallel fragmented partitions, counted {par_partitions}"
    );
    obs::reset();
}

#[test]
fn concurrent_independent_sorts() {
    // Engines must be safe to run concurrently from independent threads
    // (the coordinator does this for small-job batches).
    let mut rng = Xoshiro256pp::new(7);
    let bases: Vec<Vec<u64>> = (0..8)
        .map(|_| (0..50_000).map(|_| rng.next_u64()).collect())
        .collect();
    std::thread::scope(|s| {
        for base in &bases {
            s.spawn(move || {
                let mut v = base.clone();
                sort_parallel(SortEngine::Aips2o, &mut v, 2);
                assert!(is_sorted(&v));
                assert_eq!(multiset_digest(base), multiset_digest(&v));
            });
        }
    });
}
