//! Statistical checks that each simulated dataset exhibits the property
//! the paper's evaluation relies on (DESIGN.md §6 substitution table).

use aipso::datasets;
use aipso::util::stats;

const N: usize = 200_000;

fn dup_fraction_u64(v: &[u64]) -> f64 {
    let mut s = v.to_vec();
    s.sort_unstable();
    let distinct = 1 + s.windows(2).filter(|w| w[0] != w[1]).count();
    1.0 - distinct as f64 / v.len() as f64
}

fn dup_fraction_f64(v: &[f64]) -> f64 {
    let mut s: Vec<u64> = v.iter().map(|x| x.to_bits()).collect();
    s.sort_unstable();
    let distinct = 1 + s.windows(2).filter(|w| w[0] != w[1]).count();
    1.0 - distinct as f64 / v.len() as f64
}

#[test]
fn smooth_synthetics_have_no_duplicates() {
    for name in ["uniform", "normal", "lognormal", "mix_gauss", "exponential", "chi_squared"] {
        let v = datasets::generate_f64(name, N, 1).unwrap();
        assert!(
            dup_fraction_f64(&v) < 0.001,
            "{name} unexpectedly duplicate-heavy"
        );
    }
}

#[test]
fn dup_synthetics_are_duplicate_heavy() {
    // RootDups: sqrt(N) distinct values
    let v = datasets::generate_f64("root_dups", N, 1).unwrap();
    assert!(dup_fraction_f64(&v) > 0.99);
    // TwoDups: at most N/2 distinct
    let v = datasets::generate_f64("two_dups", N, 1).unwrap();
    assert!(dup_fraction_f64(&v) > 0.4);
    // Zipf: rank 1 dominates
    let v = datasets::generate_f64("zipf", N, 1).unwrap();
    assert!(dup_fraction_f64(&v) > 0.1);
}

#[test]
fn zipf_follows_power_law() {
    let v = datasets::generate_f64("zipf", N, 3).unwrap();
    let c1 = v.iter().filter(|&&x| x == 1.0).count() as f64;
    let c16 = v.iter().filter(|&&x| x == 16.0).count() as f64;
    // count(1)/count(16) ~ 16^0.75 = 8
    let ratio = c1 / c16.max(1.0);
    assert!(ratio > 3.0 && ratio < 20.0, "zipf ratio {ratio}");
}

#[test]
fn wiki_and_books_exercise_equality_buckets() {
    let wiki = datasets::generate_u64("wiki_edit", N, 5).unwrap();
    assert!(dup_fraction_u64(&wiki) > 0.10, "wiki dup {}", dup_fraction_u64(&wiki));
    let books = datasets::generate_u64("books_sales", N, 5).unwrap();
    assert!(dup_fraction_u64(&books) > 0.10, "books dup {}", dup_fraction_u64(&books));
}

#[test]
fn fb_is_rmi_hard_heavy_tail() {
    // The paper: FB/IDs is the hard case for the RMI. Heavy tail =>
    // a linear fit of the CDF is poor. Check tail mass spread.
    let v = datasets::generate_u64("fb_ids", N, 7).unwrap();
    let mut s = v.clone();
    s.sort_unstable();
    let p50 = s[s.len() / 2] as f64;
    let p999 = s[(s.len() * 999) / 1000] as f64;
    assert!(p999 / p50 > 1e3, "FB tail too light: {}", p999 / p50);
    assert!(dup_fraction_u64(&v) < 0.2, "FB ids should be near-distinct");
}

#[test]
fn osm_radix_prefixes_are_skewed() {
    let v = datasets::generate_u64("osm_cellids", N, 9).unwrap();
    let mut pref = vec![0usize; 256];
    for &x in &v {
        pref[(x >> 56) as usize] += 1;
    }
    // entropy far below uniform 8 bits -> unbalanced radix partitions
    let h = stats::entropy_bits(&pref);
    assert!(h < 7.0, "osm prefix entropy {h} too uniform");
}

#[test]
fn timestamps_are_in_plausible_ranges() {
    let wiki = datasets::generate_u64("wiki_edit", 50_000, 11).unwrap();
    assert!(wiki.iter().all(|&t| (900_000_000..1_700_000_000).contains(&t)));
    let nyc = datasets::generate_u64("nyc_pickup", 50_000, 11).unwrap();
    assert!(nyc.iter().all(|&t| (1_640_000_000..1_680_000_000).contains(&t)));
}

#[test]
fn generators_scale_with_n() {
    for name in ["uniform", "root_dups"] {
        for n in [0usize, 1, 10, 1001] {
            assert_eq!(datasets::generate_f64(name, n, 1).unwrap().len(), n);
        }
    }
    for name in ["wiki_edit", "osm_cellids"] {
        for n in [0usize, 1, 10, 1001] {
            assert_eq!(datasets::generate_u64(name, n, 1).unwrap().len(), n);
        }
    }
}
