//! Differential correctness harness: every in-memory engine against the
//! standard-library reference, byte for byte.
//!
//! Coverage: all 14 paper distributions at all four key widths (each
//! f64 dataset also drawn from its native f32 stream, each u64 dataset
//! from its u32 stream), synthetic duplicate-heavy inputs (≥ 90% of the
//! mass on a handful of values — LearnedSort's adversarial case, the
//! whole point of the 2.0 equality buckets), float edge patterns
//! (signed zeros, subnormals, infinities; NaN-free, as everywhere in
//! the repo), and a seeded random-length sweep through the hand-rolled
//! property harness (failures shrink and print an `AIPSO_PROP_SEED=…`
//! reproduction line).
//!
//! The engine list is `SortEngine::all()` — AIPS²o, IPS⁴o, IPS²Ra,
//! LearnedSort (2.0 fragmented partition, the default), std::sort and
//! the two analysis-only learned quicksorts — plus the 1.x block
//! partition kept reachable behind `LearnedSortConfig::v1()`.
//!
//! A second matrix pins the **parallel** fragmented scheme against the
//! sequential one: for every distribution × width × thread count in
//! {1, 2, 3, 7, max}, `learned_sort::sort_par_cfg` must produce output
//! byte-identical to `learned_sort::sort_cfg` (both under the default
//! `Fragments` scheme), including the ≥90%-dup and float-edge inputs.
//! "max" honors `AIPSO_DIFF_THREADS` (default: the machine's available
//! parallelism), so CI can sweep an oversubscribed count.
//!
//! Scale with `AIPSO_DIFF_N` (default 48 000 keys per cell).

use aipso::datasets::{self, KeyType};
use aipso::learned_sort::{self, LearnedSortConfig};
use aipso::util::proptest::{check_sized, PropConfig};
use aipso::util::rng::Xoshiro256pp;
use aipso::{sort_sequential, SortEngine, SortKey};

const SEED: u64 = 0xD1FF_0001;

fn env_n() -> usize {
    std::env::var("AIPSO_DIFF_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48_000)
}

/// The engines under differential test. The 1.x block partition rides
/// along as a pseudo-engine so both LearnedSort schemes stay covered.
#[derive(Clone, Copy)]
enum Eng {
    Std(SortEngine),
    LearnedV1,
}

impl Eng {
    fn name(self) -> String {
        match self {
            Eng::Std(e) => format!("{e:?}"),
            Eng::LearnedV1 => "LearnedSort(v1 blocks)".to_string(),
        }
    }

    fn run<K: SortKey>(self, data: &mut [K]) {
        match self {
            Eng::Std(e) => sort_sequential(e, data),
            Eng::LearnedV1 => learned_sort::sort_cfg(data, &LearnedSortConfig::v1()),
        }
    }
}

fn all_engines() -> Vec<Eng> {
    let mut v: Vec<Eng> = SortEngine::all().into_iter().map(Eng::Std).collect();
    v.push(Eng::LearnedV1);
    v
}

/// Run every engine on a clone of `base` and compare the output against
/// the std-sorted reference in the total order — bit patterns, not an
/// epsilon. `Err` carries a full reproduction (engine, label, n, first
/// mismatching index and the bits on both sides).
fn diff_result<K: SortKey>(base: &[K], label: &str) -> Result<(), String> {
    let mut want: Vec<u64> = base.iter().map(|k| k.to_bits_ordered()).collect();
    want.sort_unstable();
    for eng in all_engines() {
        let mut keys = base.to_vec();
        eng.run(&mut keys);
        let got: Vec<u64> = keys.iter().map(|k| k.to_bits_ordered()).collect();
        if got != want {
            let at = got
                .iter()
                .zip(&want)
                .position(|(g, w)| g != w)
                .unwrap_or(got.len().min(want.len()));
            return Err(format!(
                "engine {} diverged from the std reference on {} \
                 (n={}, seed={SEED:#x}): first mismatch at index {at} \
                 (got bits {:#x?}, want {:#x?})",
                eng.name(),
                label,
                base.len(),
                got.get(at),
                want.get(at),
            ));
        }
    }
    Ok(())
}

fn diff_check<K: SortKey>(base: &[K], label: &str) {
    if let Err(msg) = diff_result(base, label) {
        panic!("{msg}");
    }
}

/// Thread counts for the parallel==sequential matrix: 1 (the fallback
/// path), small counts that leave stripes unevenly loaded, a prime
/// count, and "max" from `AIPSO_DIFF_THREADS` (default: all cores).
fn sweep_threads() -> Vec<usize> {
    let max = std::env::var("AIPSO_DIFF_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&t: &usize| t >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        });
    let mut v = vec![1usize, 2, 3, 7, max];
    v.sort_unstable();
    v.dedup();
    v
}

/// Sequential vs parallel fragmented LearnedSort, byte for byte at every
/// thread count in the sweep. The model is retrained per run from an
/// rng keyed only on `n`, so the comparison is exact, not probabilistic.
fn par_diff_result<K: SortKey>(base: &[K], label: &str) -> Result<(), String> {
    let cfg = LearnedSortConfig::default();
    let mut seq = base.to_vec();
    learned_sort::sort_cfg(&mut seq, &cfg);
    let want: Vec<u64> = seq.iter().map(|k| k.to_bits_ordered()).collect();
    for threads in sweep_threads() {
        let mut keys = base.to_vec();
        learned_sort::sort_par_cfg(&mut keys, &cfg, threads);
        let got: Vec<u64> = keys.iter().map(|k| k.to_bits_ordered()).collect();
        if got != want {
            let at = got
                .iter()
                .zip(&want)
                .position(|(g, w)| g != w)
                .unwrap_or(got.len().min(want.len()));
            return Err(format!(
                "parallel fragmented LearnedSort (threads={threads}) diverged \
                 from sequential on {} (n={}, seed={SEED:#x}): first mismatch \
                 at index {at} (got bits {:#x?}, want {:#x?})",
                label,
                base.len(),
                got.get(at),
                want.get(at),
            ));
        }
    }
    Ok(())
}

fn par_diff_check<K: SortKey>(base: &[K], label: &str) {
    if let Err(msg) = par_diff_result(base, label) {
        panic!("{msg}");
    }
}

#[test]
fn all_distributions_all_widths_differential() {
    let n = env_n();
    for ds in datasets::ALL.iter() {
        match ds.key_type {
            KeyType::F64 => {
                let wide = datasets::generate_f64(ds.name, n, SEED).unwrap();
                diff_check(&wide, &format!("{}/f64", ds.name));
                let narrow = datasets::generate_f32(ds.name, n, SEED).unwrap();
                diff_check(&narrow, &format!("{}/f32", ds.name));
            }
            KeyType::U64 => {
                let wide = datasets::generate_u64(ds.name, n, SEED).unwrap();
                diff_check(&wide, &format!("{}/u64", ds.name));
                let narrow = datasets::generate_u32(ds.name, n, SEED).unwrap();
                diff_check(&narrow, &format!("{}/u32", ds.name));
            }
        }
    }
}

#[test]
fn dup_heavy_inputs_differential() {
    let n = env_n();
    let mut rng = Xoshiro256pp::new(SEED ^ 0xD0D0);

    // 95% of the keys one heavy f64 value (single equality bucket)
    let mut f: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
    for k in f.iter_mut() {
        if rng.uniform(0.0, 1.0) < 0.95 {
            *k = 1234.5;
        }
    }
    diff_check(&f, "95%-dup/f64");
    let f_narrow: Vec<f32> = f.iter().map(|&x| x as f32).collect();
    diff_check(&f_narrow, "95%-dup/f32");

    // 90% of the keys drawn from four u64 values spread across the range
    let heavy = [3u64, 1 << 20, 1 << 40, u64::MAX - 7];
    let u: Vec<u64> = (0..n)
        .map(|_| {
            if rng.uniform(0.0, 1.0) < 0.9 {
                heavy[(rng.next_u64() % 4) as usize]
            } else {
                rng.next_u64()
            }
        })
        .collect();
    diff_check(&u, "90%-dup/u64");
    let u_narrow: Vec<u32> = u.iter().map(|&x| (x & 0xFFFF_FFFF) as u32).collect();
    diff_check(&u_narrow, "90%-dup/u32");
}

#[test]
fn float_edge_patterns_differential() {
    let mut wide: Vec<f64> = vec![
        0.0,
        -0.0,
        f64::INFINITY,
        f64::NEG_INFINITY,
        f64::MIN_POSITIVE,
        -f64::MIN_POSITIVE,
        1e-320, // subnormal
        -1e-320,
        f64::MAX,
        f64::MIN,
    ];
    wide.extend((0..30_000).map(|i| (i as f64 - 15_000.0) * 1e90));
    diff_check(&wide, "edge/f64");

    let mut narrow: Vec<f32> = vec![
        0.0,
        -0.0,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1e-44, // subnormal
        -1e-44,
        f32::MAX,
        f32::MIN,
    ];
    narrow.extend((0..30_000).map(|i| (i as f32 - 15_000.0) * 1e30));
    diff_check(&narrow, "edge/f32");
}

#[test]
fn random_length_sweep_shrinks_failures() {
    check_sized(
        "differential/f64",
        PropConfig::with_max_size(24, 6_000),
        |rng, n| {
            let base: Vec<f64> = (0..n).map(|_| rng.uniform(-1e9, 1e9)).collect();
            diff_result(&base, "random/f64")
        },
    );
    check_sized(
        "differential/u64",
        PropConfig::with_max_size(24, 6_000),
        |rng, n| {
            let base: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            diff_result(&base, "random/u64")
        },
    );
    check_sized(
        "differential/f32",
        PropConfig::with_max_size(16, 6_000),
        |rng, n| {
            let base: Vec<f32> = (0..n).map(|_| rng.uniform(-1e6, 1e6) as f32).collect();
            diff_result(&base, "random/f32")
        },
    );
    check_sized(
        "differential/u32",
        PropConfig::with_max_size(16, 6_000),
        |rng, n| {
            let base: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            diff_result(&base, "random/u32")
        },
    );
    // duplicate-heavy random sweep: two values, skewed shares
    check_sized(
        "differential/two-value",
        PropConfig::with_max_size(16, 6_000),
        |rng, n| {
            let base: Vec<u64> = (0..n)
                .map(|_| if rng.uniform(0.0, 1.0) < 0.9 { 7 } else { 9000 })
                .collect();
            diff_result(&base, "random/two-value")
        },
    );
}

#[test]
fn parallel_fragmented_all_distributions_all_widths() {
    let n = env_n();
    for ds in datasets::ALL.iter() {
        match ds.key_type {
            KeyType::F64 => {
                let wide = datasets::generate_f64(ds.name, n, SEED).unwrap();
                par_diff_check(&wide, &format!("{}/f64", ds.name));
                let narrow = datasets::generate_f32(ds.name, n, SEED).unwrap();
                par_diff_check(&narrow, &format!("{}/f32", ds.name));
            }
            KeyType::U64 => {
                let wide = datasets::generate_u64(ds.name, n, SEED).unwrap();
                par_diff_check(&wide, &format!("{}/u64", ds.name));
                let narrow = datasets::generate_u32(ds.name, n, SEED).unwrap();
                par_diff_check(&narrow, &format!("{}/u32", ds.name));
            }
        }
    }
}

#[test]
fn parallel_fragmented_dup_heavy_inputs() {
    let n = env_n();
    let mut rng = Xoshiro256pp::new(SEED ^ 0xFA2_D0B);

    // 95% of the keys one heavy f64 value: the equality bucket must
    // swallow the mass identically under concurrency
    let mut f: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
    for k in f.iter_mut() {
        if rng.uniform(0.0, 1.0) < 0.95 {
            *k = 1234.5;
        }
    }
    par_diff_check(&f, "95%-dup/f64");
    let f_narrow: Vec<f32> = f.iter().map(|&x| x as f32).collect();
    par_diff_check(&f_narrow, "95%-dup/f32");

    // 90% of the keys drawn from four u64 values spread across the range
    let heavy = [3u64, 1 << 20, 1 << 40, u64::MAX - 7];
    let u: Vec<u64> = (0..n)
        .map(|_| {
            if rng.uniform(0.0, 1.0) < 0.9 {
                heavy[(rng.next_u64() % 4) as usize]
            } else {
                rng.next_u64()
            }
        })
        .collect();
    par_diff_check(&u, "90%-dup/u64");
    let u_narrow: Vec<u32> = u.iter().map(|&x| (x & 0xFFFF_FFFF) as u32).collect();
    par_diff_check(&u_narrow, "90%-dup/u32");
}

#[test]
fn parallel_fragmented_float_edges() {
    let mut wide: Vec<f64> = vec![
        0.0,
        -0.0,
        f64::INFINITY,
        f64::NEG_INFINITY,
        f64::MIN_POSITIVE,
        -f64::MIN_POSITIVE,
        1e-320, // subnormal
        -1e-320,
        f64::MAX,
        f64::MIN,
    ];
    wide.extend((0..30_000).map(|i| (i as f64 - 15_000.0) * 1e90));
    par_diff_check(&wide, "edge/f64");

    let mut narrow: Vec<f32> = vec![
        0.0,
        -0.0,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1e-44, // subnormal
        -1e-44,
        f32::MAX,
        f32::MIN,
    ];
    narrow.extend((0..30_000).map(|i| (i as f32 - 15_000.0) * 1e30));
    par_diff_check(&narrow, "edge/f32");
}
