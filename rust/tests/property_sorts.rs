//! Property-based tests (hand-rolled harness, util::proptest): sorting
//! invariants over random sizes/distributions/engines, and framework
//! invariants (partition routing, scheduler task accounting).

use aipso::classifier::decision_tree::DecisionTree;
use aipso::classifier::Classifier;
use aipso::learned_sort::partition2::{detect_heavy, fragmented_partition, EqRmiClassifier};
use aipso::learned_sort::partition2_par::fragmented_partition_par;
use aipso::rmi::model::{Rmi, RmiConfig};
use aipso::sample_sort::partition::partition;
use aipso::util::proptest::{check_sized, PropConfig};
use aipso::util::rng::Xoshiro256pp;
use aipso::util::stats::multiset_digest;
use aipso::{is_sorted, sort_parallel, sort_sequential, SortEngine, SortKey};

fn random_keys(rng: &mut Xoshiro256pp, n: usize) -> Vec<u64> {
    // mixture of distributions, chosen by the rng itself
    let mode = rng.next_below(5);
    (0..n)
        .map(|_| match mode {
            0 => rng.next_u64(),
            1 => rng.next_below(16),                  // heavy duplicates
            2 => rng.next_below(1 << 20),             // narrow
            3 => (rng.normal().abs() * 1e12) as u64,  // skewed
            _ => (rng.exponential(1e-6)) as u64,      // heavy tail
        })
        .collect()
}

#[test]
fn prop_every_engine_sorts_any_input() {
    for engine in SortEngine::all() {
        check_sized(
            &format!("sorts/{engine:?}"),
            PropConfig::with_max_size(24, 40_000),
            |rng, n| {
                let mut v = random_keys(rng, n);
                let before = multiset_digest(&v);
                sort_sequential(engine, &mut v);
                if !is_sorted(&v) {
                    return Err("output not sorted".into());
                }
                if before != multiset_digest(&v) {
                    return Err("multiset changed".into());
                }
                Ok(())
            },
        );
    }
}

#[test]
fn prop_parallel_equals_sequential() {
    check_sized(
        "parallel == sequential",
        PropConfig::with_max_size(16, 150_000),
        |rng, n| {
            let base = random_keys(rng, n);
            let threads = 1 + rng.next_below(8) as usize;
            for engine in SortEngine::PARALLEL_FIGURES {
                let mut a = base.clone();
                let mut b = base.clone();
                sort_sequential(engine, &mut a);
                sort_parallel(engine, &mut b, threads);
                if a != b {
                    return Err(format!("{engine:?} t={threads}: parallel != sequential"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partition_routes_every_key_to_its_bucket() {
    check_sized(
        "partition routing",
        PropConfig::with_max_size(24, 60_000),
        |rng, n| {
            let mut data = random_keys(rng, n);
            if data.is_empty() {
                return Ok(());
            }
            let before = multiset_digest(&data);
            let mut sample: Vec<u64> = (0..256.min(n))
                .map(|_| data[rng.next_below(n as u64) as usize])
                .collect();
            sample.sort_unstable();
            let buckets = [4usize, 16, 64, 256][rng.next_below(4) as usize];
            let block = [16usize, 64, 128][rng.next_below(3) as usize];
            let threads = 1 + rng.next_below(6) as usize;
            let tree = DecisionTree::from_sorted_sample(&sample, buckets);
            let res = partition(&mut data, &tree, block, threads);
            // boundaries form a monotone cover
            if res.boundaries[0] != 0 || *res.boundaries.last().unwrap() != n {
                return Err("boundaries do not cover input".into());
            }
            for w in res.boundaries.windows(2) {
                if w[0] > w[1] {
                    return Err("boundaries not monotone".into());
                }
            }
            // every key is in the bucket the classifier says
            for b in 0..tree.num_buckets() {
                for &k in &data[res.boundaries[b]..res.boundaries[b + 1]] {
                    if tree.classify(k) != b {
                        return Err(format!("key {k} routed to wrong bucket {b}"));
                    }
                }
            }
            if before != multiset_digest(&data) {
                return Err("partition changed the multiset".into());
            }
            Ok(())
        },
    );
}

/// Shared invariant check for the LearnedSort 2.0 fragmented partition:
/// boundaries form a monotone cover, every key sits in the bucket the
/// classifier assigns it, and the input multiset is preserved (the
/// compaction is a permutation).
fn check_frag_partition<K: SortKey, C: Classifier<K>>(
    data: &mut [K],
    classifier: &C,
    frag: usize,
) -> Result<(), String> {
    let nb = classifier.num_buckets();
    let before = multiset_digest(data);
    let res = fragmented_partition(data, classifier, frag);
    if res.boundaries.len() != nb + 1 {
        return Err(format!(
            "expected {} boundaries, got {}",
            nb + 1,
            res.boundaries.len()
        ));
    }
    if res.boundaries[0] != 0 || *res.boundaries.last().unwrap() != data.len() {
        return Err("boundaries do not cover input".into());
    }
    for w in res.boundaries.windows(2) {
        if w[0] > w[1] {
            return Err("boundaries not monotone".into());
        }
    }
    for b in 0..nb {
        for &k in &data[res.boundaries[b]..res.boundaries[b + 1]] {
            if classifier.classify(k) != b {
                return Err(format!(
                    "key {k:?} landed in bucket {b}, classifier says {} (frag={frag})",
                    classifier.classify(k)
                ));
            }
        }
    }
    if before != multiset_digest(data) {
        return Err("fragmented partition changed the multiset".into());
    }
    Ok(())
}

/// Parallel variant of [`check_frag_partition`]: the thread-parallel
/// fragmented partition must satisfy the same boundary-cover / routing /
/// multiset oracle, *and* return boundaries identical to the sequential
/// partition of the same input (they depend only on the bucket map, not
/// on the stripe split or thread schedule).
fn check_frag_partition_par<K: SortKey, C: Classifier<K>>(
    data: &mut [K],
    classifier: &C,
    frag: usize,
    threads: usize,
) -> Result<(), String> {
    let mut seq = data.to_vec();
    let want = fragmented_partition(&mut seq, classifier, frag);
    let nb = classifier.num_buckets();
    let before = multiset_digest(data);
    let res = fragmented_partition_par(data, classifier, frag, threads);
    if res.boundaries != want.boundaries {
        return Err(format!(
            "parallel boundaries diverge from sequential (frag={frag} threads={threads}): \
             {:?} vs {:?}",
            res.boundaries, want.boundaries
        ));
    }
    if res.boundaries[0] != 0 || *res.boundaries.last().unwrap() != data.len() {
        return Err("boundaries do not cover input".into());
    }
    for b in 0..nb {
        for &k in &data[res.boundaries[b]..res.boundaries[b + 1]] {
            if classifier.classify(k) != b {
                return Err(format!(
                    "key {k:?} landed in bucket {b}, classifier says {} \
                     (frag={frag} threads={threads})",
                    classifier.classify(k)
                ));
            }
        }
    }
    if before != multiset_digest(data) {
        return Err("parallel fragmented partition changed the multiset".into());
    }
    Ok(())
}

#[test]
fn prop_fragmented_partition_routes_and_preserves() {
    check_sized(
        "fragmented partition routing",
        PropConfig::with_max_size(40, 60_000),
        |rng, n| {
            if n == 0 {
                return Ok(());
            }
            // adversarial input modes: random, all-equal, two-value,
            // Zipf-like heavy head, sorted, reverse-sorted
            let mode = rng.next_below(6);
            let mut data: Vec<u64> = (0..n)
                .map(|i| match mode {
                    0 => rng.next_u64(),
                    1 => 42,
                    2 => [7u64, 9000][(rng.next_u64() % 2) as usize],
                    3 => {
                        let r = rng.uniform(0.0, 1.0);
                        if r < 0.5 {
                            1
                        } else if r < 0.75 {
                            2
                        } else {
                            rng.next_below(1 << 30)
                        }
                    }
                    4 => i as u64,
                    _ => (n - i) as u64,
                })
                .collect();
            let mut sample: Vec<u64> = (0..256.min(n))
                .map(|_| data[rng.next_below(n as u64) as usize])
                .collect();
            sample.sort_unstable();
            let buckets = [4usize, 16, 64][rng.next_below(3) as usize];
            let frag = [1usize, 4, 64, 128][rng.next_below(4) as usize];
            let tree = DecisionTree::from_sorted_sample(&sample, buckets);
            check_frag_partition(&mut data, &tree, frag)
        },
    );
}

#[test]
fn prop_fragmented_partition_with_equality_classifier() {
    // the real v2 stack: heavy-value detection + EqRmiClassifier on
    // duplicate-heavy floats, swept over random sizes and fragment sizes
    check_sized(
        "fragmented partition + equality buckets",
        PropConfig::with_max_size(16, 40_000),
        |rng, n| {
            if n < 64 {
                return Ok(());
            }
            let mut data: Vec<f64> = (0..n)
                .map(|_| {
                    let r = rng.uniform(0.0, 1.0);
                    if r < 0.4 {
                        123.25
                    } else if r < 0.6 {
                        -55.5
                    } else {
                        rng.uniform(-1e4, 1e4)
                    }
                })
                .collect();
            let ssz = 512.min(n);
            let mut skeys: Vec<f64> = (0..ssz)
                .map(|_| data[rng.next_below(n as u64) as usize])
                .collect();
            skeys.sort_unstable_by(f64::total_cmp);
            let nb = 32;
            let heavy = detect_heavy(&skeys, nb, 8);
            let rmi = Rmi::train(&skeys, RmiConfig { n_leaves: 64 });
            let c = EqRmiClassifier::new(rmi, nb, &heavy);
            let frag = 1 + rng.next_below(128) as usize;
            check_frag_partition(&mut data, &c, frag)
        },
    );
}

#[test]
fn prop_parallel_fragmented_partition_matches_sequential() {
    // the tentpole oracle: per-thread chain merge + compaction over
    // adversarial inputs and thread counts, against the sequential
    // partition's boundaries and the shared routing/multiset checks
    check_sized(
        "parallel fragmented partition",
        PropConfig::with_max_size(40, 60_000),
        |rng, n| {
            if n == 0 {
                return Ok(());
            }
            let mode = rng.next_below(6);
            let mut data: Vec<u64> = (0..n)
                .map(|i| match mode {
                    0 => rng.next_u64(),
                    1 => 42,
                    2 => [7u64, 9000][(rng.next_u64() % 2) as usize],
                    3 => {
                        let r = rng.uniform(0.0, 1.0);
                        if r < 0.5 {
                            1
                        } else if r < 0.75 {
                            2
                        } else {
                            rng.next_below(1 << 30)
                        }
                    }
                    4 => i as u64,
                    _ => (n - i) as u64,
                })
                .collect();
            let mut sample: Vec<u64> = (0..256.min(n))
                .map(|_| data[rng.next_below(n as u64) as usize])
                .collect();
            sample.sort_unstable();
            let buckets = [4usize, 16, 64][rng.next_below(3) as usize];
            let frag = [1usize, 4, 64, 128][rng.next_below(4) as usize];
            // include oversubscribed thread counts: workers beyond the
            // slot supply must degrade into fewer stripes or the
            // sequential fallback, never an empty-stripe crash
            let threads = [1usize, 2, 3, 7, 16, 64][rng.next_below(6) as usize];
            let tree = DecisionTree::from_sorted_sample(&sample, buckets);
            check_frag_partition_par(&mut data, &tree, frag, threads)
        },
    );
}

#[test]
fn prop_parallel_fragmented_with_equality_classifier() {
    // heavy-value equality buckets under concurrency: the per-thread
    // chains of an equality bucket must merge into one extent holding
    // only the heavy value, at every thread count
    check_sized(
        "parallel fragmented partition + equality buckets",
        PropConfig::with_max_size(16, 40_000),
        |rng, n| {
            if n < 64 {
                return Ok(());
            }
            let mut data: Vec<f64> = (0..n)
                .map(|_| {
                    let r = rng.uniform(0.0, 1.0);
                    if r < 0.4 {
                        123.25
                    } else if r < 0.6 {
                        -55.5
                    } else {
                        rng.uniform(-1e4, 1e4)
                    }
                })
                .collect();
            let ssz = 512.min(n);
            let mut skeys: Vec<f64> = (0..ssz)
                .map(|_| data[rng.next_below(n as u64) as usize])
                .collect();
            skeys.sort_unstable_by(f64::total_cmp);
            let nb = 32;
            let heavy = detect_heavy(&skeys, nb, 8);
            let rmi = Rmi::train(&skeys, RmiConfig { n_leaves: 64 });
            let c = EqRmiClassifier::new(rmi, nb, &heavy);
            let frag = 1 + rng.next_below(128) as usize;
            let threads = 1 + rng.next_below(8) as usize;
            check_frag_partition_par(&mut data, &c, frag, threads)
        },
    );
}

#[test]
fn parallel_fragmented_adversarial_splits() {
    // deterministic worst cases for the stripe cutter: prime lengths ×
    // fragment sizes (unaligned tails), fragments larger than a fair
    // per-worker share (a worker would get no whole slot — the
    // slots-per-worker guard must fall back), and thread counts far
    // beyond the slot supply (empty worker slices structurally
    // impossible, fewer stripes come back instead)
    let sample = vec![-3.0f64, -1.0, 0.0, 1.5, 2.5];
    let tree = DecisionTree::from_sorted_sample(&sample, 4);
    for n in [2usize, 3, 5, 7, 11, 13, 17, 19, 23, 97, 101, 997] {
        for frag in [1usize, 2, 3, 8, 64] {
            for threads in [2usize, 3, 7, 64] {
                let mut asc: Vec<f64> = (0..n).map(|i| i as f64 * 0.37 - 2.0).collect();
                check_frag_partition_par(&mut asc, &tree, frag, threads).unwrap();
                let mut desc: Vec<f64> =
                    (0..n).map(|i| i as f64 * 0.37 - 2.0).rev().collect();
                check_frag_partition_par(&mut desc, &tree, frag, threads).unwrap();
            }
        }
    }
    // fragment bigger than the whole input, many workers
    let mut tiny: Vec<f64> = (0..37).map(|i| i as f64 * 0.11 - 2.0).collect();
    check_frag_partition_par(&mut tiny, &tree, 128, 8).unwrap();
    // empty input
    let mut empty: Vec<f64> = Vec::new();
    check_frag_partition_par(&mut empty, &tree, 16, 4).unwrap();
}

#[test]
fn prop_learned_sort_parallel_equals_sequential() {
    // the full engine: parallel fragmented LearnedSort must be
    // byte-identical to the sequential sort at any thread count
    check_sized(
        "learned_sort parallel == sequential",
        PropConfig::with_max_size(16, 150_000),
        |rng, n| {
            let base = random_keys(rng, n);
            let threads = 1 + rng.next_below(8) as usize;
            let mut a = base.clone();
            let mut b = base;
            sort_sequential(SortEngine::LearnedSort, &mut a);
            sort_parallel(SortEngine::LearnedSort, &mut b, threads);
            if a != b {
                return Err(format!("LearnedSort t={threads}: parallel != sequential"));
            }
            Ok(())
        },
    );
}

#[test]
fn fragmented_partition_small_lengths_and_float_edges() {
    // lengths 0..=small primes, at fragment sizes around the length
    let sample = vec![-3.0f64, -1.0, 0.0, 1.5, 2.5];
    let tree = DecisionTree::from_sorted_sample(&sample, 4);
    for n in [0usize, 1, 2, 3, 5, 7, 11, 13, 17, 19, 23] {
        for frag in [1usize, 2, 3, 8] {
            let mut asc: Vec<f64> = (0..n).map(|i| i as f64 * 0.37 - 2.0).collect();
            check_frag_partition(&mut asc, &tree, frag).unwrap();
            let mut desc: Vec<f64> = (0..n).map(|i| i as f64 * 0.37 - 2.0).rev().collect();
            check_frag_partition(&mut desc, &tree, frag).unwrap();
        }
    }
    // NaN-free f32 edge patterns: signed zeros, subnormals, infinities
    let edges: Vec<f32> = vec![
        0.0,
        -0.0,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1e-44,
        -1e-44,
        f32::MAX,
        f32::MIN,
    ];
    let mut data: Vec<f32> = (0..311).map(|i| edges[i % edges.len()]).collect();
    let mut esample = data.clone();
    esample.sort_unstable_by(|a, b| a.to_bits_ordered().cmp(&b.to_bits_ordered()));
    let etree = DecisionTree::from_sorted_sample(&esample, 8);
    check_frag_partition(&mut data, &etree, 4).unwrap();
}

#[test]
fn prop_scheduler_task_accounting() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    check_sized(
        "scheduler accounting",
        PropConfig::with_max_size(24, 200),
        |rng, n| {
            let threads = 1 + rng.next_below(8) as usize;
            let done = AtomicUsize::new(0);
            // each task i spawns i % 3 children of value i/2
            let expected = {
                fn count(v: usize) -> usize {
                    1 + (v % 3) * if v > 0 { count(v / 2) } else { 1 }
                }
                (0..n).map(count).sum::<usize>()
            };
            aipso::scheduler::run_task_pool(threads, (0..n).collect(), |t, s| {
                done.fetch_add(1, Ordering::Relaxed);
                for _ in 0..(t % 3) {
                    s.spawn(if t > 0 { t / 2 } else { 0 });
                }
            });
            let got = done.load(Ordering::Relaxed);
            if got != expected {
                return Err(format!("ran {got} tasks, expected {expected}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rmi_monotone_and_in_range() {
    use aipso::rmi::model::{Rmi, RmiConfig};
    check_sized(
        "rmi monotonicity",
        PropConfig::with_max_size(24, 20_000),
        |rng, n| {
            if n < 2 {
                return Ok(());
            }
            let mut sample: Vec<f64> = random_keys(rng, n).iter().map(|&k| k as f64).collect();
            sample.sort_unstable_by(f64::total_cmp);
            let leaves = [4usize, 32, 256, 1024][rng.next_below(4) as usize];
            let rmi = Rmi::train(&sample, RmiConfig { n_leaves: leaves });
            let mut probe: Vec<f64> = random_keys(rng, 4096).iter().map(|&k| k as f64).collect();
            probe.sort_unstable_by(f64::total_cmp);
            let mut prev = -1.0;
            for &x in &probe {
                let p = rmi.predict(x);
                if !(0.0..1.0).contains(&p) {
                    return Err(format!("predict({x}) = {p} out of range"));
                }
                if p < prev {
                    return Err(format!("monotonicity violated at {x}"));
                }
                prev = p;
            }
            Ok(())
        },
    );
}
