//! Integration: the sort-job coordinator end to end — routing, batching,
//! verification, metrics.

use aipso::coordinator::{Coordinator, EngineChoice, JobSpec, KeyBuf};
use aipso::datasets;
use aipso::util::rng::Xoshiro256pp;
use aipso::SortEngine;

#[test]
fn mixed_trace_completes_and_verifies() {
    let coordinator = Coordinator::new(4);
    let mut rng = Xoshiro256pp::new(11);
    let mut expected = 0usize;
    for id in 0..20u64 {
        let n = match id % 3 {
            0 => 200_000,
            1 => 20_000,
            _ => 2_000,
        };
        let keys = if id % 2 == 0 {
            KeyBuf::F64(datasets::generate_f64("uniform", n, rng.next_u64()).unwrap())
        } else {
            KeyBuf::U64(datasets::generate_u64("fb_ids", n, rng.next_u64()).unwrap())
        };
        coordinator.submit(JobSpec::auto(id, keys));
        expected += 1;
    }
    let (reports, metrics) = coordinator.drain();
    assert_eq!(reports.len(), expected);
    assert!(reports.iter().all(|r| r.verified_sorted), "a job failed verify");
    assert_eq!(metrics.total_jobs(), expected);
    assert_eq!(metrics.total_failures(), 0);
    // all job ids come back exactly once
    let mut ids: Vec<u64> = reports.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..20).collect::<Vec<_>>());
}

#[test]
fn router_policies_visible_in_reports() {
    let coordinator = Coordinator::new(2);
    // big smooth input -> AIPS2o
    coordinator.submit(JobSpec::auto(
        0,
        KeyBuf::F64(datasets::generate_f64("uniform", 150_000, 1).unwrap()),
    ));
    // duplicate-heavy -> IPS4o
    coordinator.submit(JobSpec::auto(
        1,
        KeyBuf::F64(datasets::generate_f64("root_dups", 150_000, 2).unwrap()),
    ));
    // small -> std::sort
    coordinator.submit(JobSpec::auto(
        2,
        KeyBuf::U64((0..1000u64).rev().collect()),
    ));
    let (reports, _) = coordinator.drain();
    let by_id = |id: u64| reports.iter().find(|r| r.id == id).unwrap();
    assert_eq!(by_id(0).engine, SortEngine::Aips2o);
    assert_eq!(by_id(1).engine, SortEngine::Ips4o);
    assert_eq!(by_id(2).engine, SortEngine::StdSort);
}

#[test]
fn fixed_engine_jobs_and_throughput_reporting() {
    let coordinator = Coordinator::new(4);
    for (i, engine) in SortEngine::PARALLEL_FIGURES.iter().enumerate() {
        coordinator.submit(JobSpec {
            id: i as u64,
            keys: KeyBuf::U64(datasets::generate_u64("nyc_pickup", 100_000, i as u64).unwrap()),
            engine: EngineChoice::Fixed(*engine),
            parallel: true,
        });
    }
    let (reports, metrics) = coordinator.drain();
    assert_eq!(reports.len(), 4);
    for r in &reports {
        assert!(r.verified_sorted);
        assert!(r.keys_per_sec > 0.0);
        assert!(r.secs > 0.0);
    }
    let report = metrics.report();
    assert!(report.contains("AIPS2o"), "report:\n{report}");
}

#[test]
fn many_small_jobs_batch_path() {
    let coordinator = Coordinator::new(4);
    for id in 0..40u64 {
        coordinator.submit(JobSpec::auto(
            id,
            KeyBuf::U64((0..500u64).map(|x| (x * 7919 + id) % 1000).collect()),
        ));
    }
    let (reports, _) = coordinator.drain();
    assert_eq!(reports.len(), 40);
    assert!(reports.iter().all(|r| r.verified_sorted));
}
