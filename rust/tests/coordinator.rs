//! Integration: the sort-job coordinator end to end — routing, batching,
//! verification, metrics.

use aipso::coordinator::{Coordinator, EngineChoice, JobSpec, KeyBuf};
use aipso::datasets;
use aipso::util::rng::Xoshiro256pp;
use aipso::SortEngine;

#[test]
fn mixed_trace_completes_and_verifies() {
    let coordinator = Coordinator::new(4);
    let mut rng = Xoshiro256pp::new(11);
    let mut expected = 0usize;
    for id in 0..20u64 {
        let n = match id % 3 {
            0 => 200_000,
            1 => 20_000,
            _ => 2_000,
        };
        let keys = if id % 2 == 0 {
            KeyBuf::F64(datasets::generate_f64("uniform", n, rng.next_u64()).unwrap())
        } else {
            KeyBuf::U64(datasets::generate_u64("fb_ids", n, rng.next_u64()).unwrap())
        };
        coordinator.submit(JobSpec::auto(id, keys));
        expected += 1;
    }
    let (reports, metrics) = coordinator.drain();
    assert_eq!(reports.len(), expected);
    assert!(reports.iter().all(|r| r.verified_sorted), "a job failed verify");
    assert_eq!(metrics.total_jobs(), expected);
    assert_eq!(metrics.total_failures(), 0);
    // all job ids come back exactly once
    let mut ids: Vec<u64> = reports.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..20).collect::<Vec<_>>());
}

#[test]
fn router_policies_visible_in_reports() {
    let coordinator = Coordinator::new(2);
    // big smooth input -> AIPS2o
    coordinator.submit(JobSpec::auto(
        0,
        KeyBuf::F64(datasets::generate_f64("uniform", 150_000, 1).unwrap()),
    ));
    // duplicate-heavy -> IPS4o
    coordinator.submit(JobSpec::auto(
        1,
        KeyBuf::F64(datasets::generate_f64("root_dups", 150_000, 2).unwrap()),
    ));
    // small -> std::sort
    coordinator.submit(JobSpec::auto(
        2,
        KeyBuf::U64((0..1000u64).rev().collect()),
    ));
    let (reports, _) = coordinator.drain();
    let by_id = |id: u64| reports.iter().find(|r| r.id == id).unwrap();
    assert_eq!(by_id(0).engine, SortEngine::Aips2o);
    assert_eq!(by_id(1).engine, SortEngine::Ips4o);
    assert_eq!(by_id(2).engine, SortEngine::StdSort);
}

#[test]
fn fixed_engine_jobs_and_throughput_reporting() {
    let coordinator = Coordinator::new(4);
    for (i, engine) in SortEngine::PARALLEL_FIGURES.iter().enumerate() {
        let mut job = JobSpec::auto(
            i as u64,
            KeyBuf::U64(datasets::generate_u64("nyc_pickup", 100_000, i as u64).unwrap()),
        );
        job.engine = EngineChoice::Fixed(*engine);
        coordinator.submit(job);
    }
    let (reports, metrics) = coordinator.drain();
    assert_eq!(reports.len(), 4);
    for r in &reports {
        assert!(r.verified_sorted);
        assert!(r.keys_per_sec > 0.0);
        assert!(r.secs > 0.0);
    }
    let report = metrics.report();
    assert!(report.contains("AIPS2o"), "report:\n{report}");
}

#[test]
fn external_job_end_to_end() {
    use aipso::coordinator::ExternalJob;
    use aipso::external::{read_keys_file, ExternalConfig};
    use aipso::KeyKind;

    let dir = std::env::temp_dir();
    let input = dir.join(format!("aipso-it-coord-ext-{}.bin", std::process::id()));
    let output = dir.join(format!("aipso-it-coord-ext-{}.out.bin", std::process::id()));
    // dataset 4x larger than the configured budget, straight from the
    // chunked generator (never materialized in memory at once)
    let n = 65_536;
    datasets::write_f64_file("uniform", n, 9, &input, 8192).unwrap();

    let coordinator = Coordinator::new(2);
    coordinator.submit(JobSpec::external(
        0,
        ExternalJob {
            input: input.clone(),
            output: output.clone(),
            key_kind: KeyKind::F64,
            payload: 0,
            config: ExternalConfig::with_budget(n / 4 * 8),
        },
    ));
    let (reports, metrics) = coordinator.drain();
    assert_eq!(reports.len(), 1);
    assert!(reports[0].verified_sorted);
    let ext = reports[0].external.as_ref().expect("external report surfaced");
    assert_eq!(ext.keys as usize, n);
    assert_eq!(ext.retrains, 0, "iid stream never retrains");
    assert_eq!(reports[0].n, n);
    assert_eq!(metrics.total_failures(), 0);

    let mut want = datasets::generate_f64("uniform", n, 9).unwrap();
    want.sort_unstable_by(f64::total_cmp);
    let got = read_keys_file::<f64>(&output).unwrap();
    assert_eq!(
        got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
    );
    let _ = std::fs::remove_file(&input);
    let _ = std::fs::remove_file(&output);
}

#[test]
fn many_small_jobs_batch_path() {
    let coordinator = Coordinator::new(4);
    for id in 0..40u64 {
        coordinator.submit(JobSpec::auto(
            id,
            KeyBuf::U64((0..500u64).map(|x| (x * 7919 + id) % 1000).collect()),
        ));
    }
    let (reports, _) = coordinator.drain();
    assert_eq!(reports.len(), 40);
    assert!(reports.iter().all(|r| r.verified_sorted));
}
