//! Ablation A1: AIPS²o bucket count B for the RMI classifier.
//! The paper fixes B = 1024 (Section 4); this sweep shows the trade-off
//! that choice sits on (classification cost vs recursion depth).

use aipso::aips2o::{self, Aips2oConfig};
use aipso::datasets;
use aipso::util::{fmt, stats};

fn main() {
    let n: usize = std::env::var("AIPSO_N").ok().and_then(|v| v.parse().ok()).unwrap_or(2_000_000);
    let reps: usize = std::env::var("AIPSO_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    println!("# Ablation: AIPS2o RMI bucket count (n = {n}, parallel, all cores)\n");
    println!("| dataset | B | rate |");
    println!("|---------|---|------|");
    for ds in ["uniform", "lognormal"] {
        let base = datasets::generate_f64(ds, n, 7).unwrap();
        for buckets in [64usize, 256, 1024, 4096] {
            let mut cfg = Aips2oConfig::default();
            cfg.strategy.rmi_buckets = buckets;
            let mut rates = Vec::new();
            for _ in 0..reps {
                let mut v = base.clone();
                let t0 = std::time::Instant::now();
                aips2o::sort_par_cfg(&mut v, 0, &cfg);
                rates.push(n as f64 / t0.elapsed().as_secs_f64());
                assert!(aipso::is_sorted(&v));
            }
            println!("| {ds} | {buckets} | {} |", fmt::rate(stats::mean(&rates)));
        }
    }
    println!("\nexpected shape: flat plateau around B=256..1024; small B loses to recursion depth");
}
