//! Regenerates paper Figures 4–6: parallel sorting throughput of AIPS²o,
//! IPS⁴o, IPS²Ra and std::sort(par) over all 14 datasets.
//!
//! Two views are printed:
//!  * measured on this box's cores (time-sliced if the box is small), and
//!  * simulated on the paper's 48 cores via the partition-balance model
//!    (real measured bucket sizes -> LPT makespan; see bench_harness::balance).

use aipso::bench_harness::{
    count_wins, render_learned_par_rows, render_rows, run_figure, run_figure_simulated,
    run_learned_thread_sweep, BenchConfig,
};
use aipso::datasets::FigureGroup;
use aipso::scheduler::effective_threads;

fn main() {
    let cfg = BenchConfig::default();
    let cores = effective_threads(cfg.threads);
    println!(
        "# Parallel figures (n = {}, reps = {}, threads = {})\n",
        cfg.n, cfg.reps, cores
    );
    let mut all = Vec::new();
    for (title, group) in [
        ("Figure 4: parallel, synthetic (Uniform/Normal/Log-Normal)", FigureGroup::Synthetic1),
        ("Figure 5: parallel, synthetic (MixGauss..Zipf)", FigureGroup::Synthetic2),
        ("Figure 6: parallel, real-world (simulated)", FigureGroup::RealWorld),
    ] {
        let rows = run_figure(group, true, &cfg);
        print!("{}\n", render_rows(title, &rows));
        all.extend(rows);
    }
    println!("## Parallel win count, measured on {cores} core(s) (paper: AIPS2o 10/14, IPS4o 4/14 on 48)");
    for (engine, wins) in count_wins(&all) {
        println!("  {engine}: {wins}/14");
    }

    // Beyond the paper: the thread-parallel fragmented LearnedSort
    // (per-thread fragment chains + deterministic merge/compaction,
    // byte-identical to the sequential engine at every thread count).
    // The paper excludes LearnedSort from its parallel figures; this
    // sweep shows what its parallelization buys on this box.
    let mut sweep = vec![1usize];
    let mut t = 2;
    while t <= cores {
        sweep.push(t);
        t *= 2;
    }
    if *sweep.last().unwrap() != cores {
        sweep.push(cores);
    }
    let par_rows = run_learned_thread_sweep(
        &["uniform", "lognormal", "zipf", "wiki_edit"],
        &sweep,
        &cfg,
    );
    print!(
        "{}\n",
        render_learned_par_rows(
            "Parallel LearnedSort 2.0 thread sweep (beyond the paper)",
            &par_rows
        )
    );

    // The paper's testbed has 48 cores; when this box has fewer, the
    // ranking mechanism (partition balance -> thread utilization) is
    // reproduced by the balance model — DESIGN.md §6, EXPERIMENTS.md.
    let sim_threads: usize = std::env::var("AIPSO_SIM_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48);
    println!("\n# Simulated {sim_threads}-core figures (measured partitions, LPT makespan model)\n");
    let mut all = Vec::new();
    for (title, group) in [
        ("Figure 4 (simulated cores): synthetic 1", FigureGroup::Synthetic1),
        ("Figure 5 (simulated cores): synthetic 2", FigureGroup::Synthetic2),
        ("Figure 6 (simulated cores): real-world", FigureGroup::RealWorld),
    ] {
        let rows = run_figure_simulated(group, sim_threads, &cfg);
        print!("{}\n", render_rows(title, &rows));
        all.extend(rows);
    }
    println!("## Simulated {sim_threads}-core win count (paper: AIPS2o 10/14, IPS4o 4/14)");
    for (engine, wins) in count_wins(&all) {
        println!("  {engine}: {wins}/14");
    }
}
