//! Ablation A3: Algorithm 5's two thresholds — duplicate fraction and
//! minimum input size — versus forcing the RMI or the tree everywhere.
//! Reproduces why AIPS²o needs the fallback ("avoids the common
//! adversarial case for LearnedSort", Section 4).

use aipso::aips2o::{self, Aips2oConfig};
use aipso::datasets;
use aipso::util::{fmt, stats};

fn run(cfg: &Aips2oConfig, base: &[f64], reps: usize) -> f64 {
    let mut rates = Vec::new();
    for _ in 0..reps {
        let mut v = base.to_vec();
        let t0 = std::time::Instant::now();
        aips2o::sort_par_cfg(&mut v, 0, cfg);
        rates.push(base.len() as f64 / t0.elapsed().as_secs_f64());
        assert!(aipso::is_sorted(&v));
    }
    stats::mean(&rates)
}

fn main() {
    let n: usize = std::env::var("AIPSO_N").ok().and_then(|v| v.parse().ok()).unwrap_or(2_000_000);
    let reps: usize = std::env::var("AIPSO_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    println!("# Ablation: strategy selection (parallel, n = {n})\n");
    let mut paper = Aips2oConfig::default(); // dup<=10%, n>=1e5 (Algorithm 5)
    paper.strategy.min_rmi_input = 100_000;
    paper.strategy.max_dup_fraction = 0.10;
    let mut always_rmi = Aips2oConfig::default();
    always_rmi.strategy.min_rmi_input = 0;
    always_rmi.strategy.max_dup_fraction = 1.1;
    let mut always_tree = Aips2oConfig::default();
    always_tree.strategy.min_rmi_input = usize::MAX;

    println!("| dataset | Algorithm 5 | always-RMI | always-tree |");
    println!("|---------|-------------|------------|-------------|");
    for ds in ["uniform", "zipf", "root_dups", "two_dups"] {
        let base = datasets::generate_f64(ds, n, 3).unwrap();
        println!(
            "| {ds} | {} | {} | {} |",
            fmt::rate(run(&paper, &base, reps)),
            fmt::rate(run(&always_rmi, &base, reps)),
            fmt::rate(run(&always_tree, &base, reps)),
        );
    }
    println!("\nexpected shape: Algorithm 5 ~= always-RMI on uniform, ~= always-tree on");
    println!("root_dups/two_dups, and never the worst column (that is its whole point)");
}
