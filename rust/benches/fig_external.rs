//! External-sort figure (beyond the paper): out-of-core sorting throughput.
//!
//! Four sections (methodology: see `BENCHMARKS.md` at the repository root):
//!
//! 1. **Run-generation strategies** — learned run generation (one monotonic
//!    RMI trained on the first chunk and reused for every run, PCF-style)
//!    vs plain IPS⁴o run generation; identical spill codec and merge on
//!    both sides, so the delta isolates the run-generation strategy.
//! 2. **Serial-vs-parallel sweep** — the learned pipeline at 1, 2 and 4
//!    threads: 1 = the serial reference (serial chunk loop, serial
//!    loser-tree merge); ≥ 2 = overlapped chunk IO plus the RMI-sharded
//!    parallel merge. Same budget everywhere, so the delta isolates
//!    pipeline parallelism.
//! 3. **Regime-shift retrain sweep** — one stream concatenating equal
//!    thirds of uniform → lognormal → zipf, sorted with the rolling
//!    retrain policy on vs off; identical budget/threads/merge, so the
//!    delta isolates retrain-on-drift (learned-run recovery after the
//!    shifts, and mixture-weighted shard cuts in the final merge).
//! 4. **Key-width sweep** — each dataset at its native 8-byte width vs
//!    narrowed to 4 bytes (`gen --width 4`); same key count and budget,
//!    so the delta isolates the spill width (half the bytes per key
//!    through disk, twice the keys per chunk).
//! 5. **Spill-codec sweep** — the raw fixed-width spill codec vs the
//!    delta+varint block codec (`extsort --codec delta`); identical
//!    budget/threads/merge *and byte-identical outputs*, so the rate
//!    delta isolates the spill IO volume and the spill column shows the
//!    compression ratio.
//! 6. **IO-substrate sweep** — the sync reference backend vs the
//!    submission-queue pool backend (`--io-backend pool`), spill runs
//!    striped across one vs two directories (`--spill-dir`), and
//!    `O_DIRECT` run-generation spills (`--direct`, buffered fallback
//!    where the filesystem refuses); identical everything else and
//!    byte-identical outputs, so the delta isolates how the spill IO is
//!    issued and where it lands.
//! 7. **Payload-width sweep** — the same key stream as bare keys vs
//!    records carrying an 8-byte and a 64-byte payload lane
//!    (`gen --payload N`); same key count and budget, so the delta
//!    isolates the payload bytes riding through every spill and merge
//!    (the spill column grows with the lane: 8 → 16 → 72 B/entry).
//!
//! Scale with AIPSO_N / AIPSO_EXT_BUDGET_MB / AIPSO_EXT_THREADS (e.g.
//! `AIPSO_EXT_THREADS=1,2,4,8`; defaults are CI-sized: the dataset is ~4x
//! the memory budget). Set AIPSO_TRACE=1 to run every job with phase-span
//! tracing on: each table gains a `phases` column breaking the row's wall
//! time down by pipeline phase (chunk-read / chunk-sort / spill-write /
//! merge-pass / retrain / shard-merge).

use aipso::bench_harness::{
    render_external_rows, run_external_codec_sweep, run_external_figure,
    run_external_io_sweep, run_external_payload_sweep, run_external_regime_shift,
    run_external_thread_sweep, run_external_width_sweep, BenchConfig,
};

fn main() {
    let cfg = BenchConfig::default();
    let trace = std::env::var("AIPSO_TRACE").map(|v| v != "0").unwrap_or(false);
    if trace {
        aipso::obs::reset();
        aipso::obs::set_enabled(true);
    }
    let budget_mb: usize = std::env::var("AIPSO_EXT_BUDGET_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| ((cfg.n * 8) >> 20).max(1) / 4)
        .max(1);
    let thread_counts: Vec<usize> = std::env::var("AIPSO_EXT_THREADS")
        .ok()
        .map(|v| v.split(',').filter_map(|t| t.trim().parse().ok()).collect())
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| vec![1, 2, 4]);
    println!(
        "# External sort (n = {}, budget = {} MiB, data ≈ {:.1}x budget)\n",
        cfg.n,
        budget_mb,
        (cfg.n * 8) as f64 / ((budget_mb << 20) as f64),
    );

    let rows = run_external_figure(
        &["uniform", "lognormal", "zipf", "fb_ids", "wiki_edit"],
        budget_mb << 20,
        &cfg,
    );
    print!(
        "{}",
        render_external_rows("External sort: run-generation strategies", &rows)
    );
    println!(
        "\n(zipf and wiki_edit are duplicate-heavy: Algorithm 5's guard routes\n\
         their runs to IPS4o even under the learned strategy — the learned\n\
         column shows where the reused RMI actually engages)\n"
    );

    let sweep = run_external_thread_sweep(
        &["uniform", "lognormal", "fb_ids"],
        budget_mb << 20,
        &thread_counts,
        &cfg,
    );
    print!(
        "{}",
        render_external_rows(
            "External sort: serial vs parallel pipeline (learned runs)",
            &sweep
        )
    );
    println!(
        "\n(threads = 1 is the fully serial reference; parallel rows overlap\n\
         chunk IO with sorting and shard the final merge with the shared RMI —\n\
         'serial' in the final-merge column means the drift/size guard fell\n\
         back to the single loser tree)\n"
    );

    let regime = run_external_regime_shift(budget_mb << 20, &cfg);
    print!(
        "{}",
        render_external_rows(
            "External sort: regime shift (uniform → lognormal → zipf), retrain on/off",
            &regime
        )
    );
    println!(
        "\n(the stream changes distribution twice mid-sort: with retraining\n\
         off every post-shift chunk is demoted to IPS4o for the rest of the\n\
         job; with it on, run generation retrains after the drift streak and\n\
         recovers the learned path — zipf stays on the fallback by design,\n\
         Algorithm 5's duplicate guard blocks its model)\n"
    );

    let widths = run_external_width_sweep(
        &["uniform", "wiki_edit"],
        budget_mb << 20,
        &cfg,
    );
    print!(
        "{}",
        render_external_rows("External sort: 8-byte vs 4-byte keys (gen --width)", &widths)
    );
    println!(
        "\n(same key count and budget at both widths: 4-byte keys spill half\n\
         the bytes per key and fit twice the keys per chunk, so fewer, longer\n\
         runs and less merge IO — the narrow-key speedup PCF Learned Sort\n\
         reports, here for u32/f32 through the same width-generic pipeline)\n"
    );

    let codecs = run_external_codec_sweep(
        &["uniform", "zipf", "wiki_edit", "books_sales"],
        budget_mb << 20,
        &cfg,
    );
    print!(
        "{}",
        render_external_rows(
            "External sort: spill codec (raw fixed-width vs delta+varint blocks)",
            &codecs
        )
    );
    println!(
        "\n(runs are sorted by construction, so the v2 codec delta-encodes\n\
         them in non-negative varints with run-length escapes for duplicates;\n\
         outputs are byte-identical either way. Expect zipf/wiki_edit/\n\
         books_sales — the dup-heavy inputs of 'Defeating duplicates' — to\n\
         spill a fraction of the raw bytes, and uniform random keys to sit\n\
         near 1.0x: wide gaps cost full-width varints)\n"
    );

    let io = run_external_io_sweep(&["uniform", "fb_ids"], budget_mb << 20, &cfg);
    print!(
        "{}",
        render_external_rows(
            "External sort: IO substrate (sync vs pool backend, spill striping, O_DIRECT)",
            &io
        )
    );
    println!(
        "\n(every variant sorts the same file to byte-identical output — the\n\
         substrate is pure transport. The pool backend overlaps spill IO\n\
         with sorting through a bounded submission queue; two spill dirs\n\
         stripe runs round-robin, which pays off when they sit on separate\n\
         devices; O_DIRECT bypasses the page cache for run-generation\n\
         spills and silently falls back to buffered IO where the\n\
         filesystem refuses it, e.g. tmpfs)\n"
    );

    let payloads = run_external_payload_sweep(
        &["uniform", "wiki_edit"],
        budget_mb << 20,
        &cfg,
    );
    print!(
        "{}",
        render_external_rows(
            "External sort: record payload width (0 vs 8 vs 64 B lanes)",
            &payloads
        )
    );
    println!(
        "\n(same keys, now carrying a payload lane per record: every spill,\n\
         merge and the output move key+lane together, so the spill column\n\
         grows from 8 to 16 to 72 B/entry while the key count stays fixed —\n\
         the rate delta is the pure cost of hauling values alongside keys\n\
         through the out-of-core pipeline)"
    );
}
