//! External-sort figure (beyond the paper): out-of-core sorting throughput
//! with learned run generation (one monotonic RMI trained on the first
//! chunk and reused for every run, PCF-style) vs plain IPS⁴o run
//! generation — identical spill codec and k-way loser-tree merge on both
//! sides, so the delta isolates the run-generation strategy.
//!
//! Scale with AIPSO_N / AIPSO_EXT_BUDGET_MB (defaults are CI-sized: the
//! dataset is ~4x the memory budget).

use aipso::bench_harness::{render_external_rows, run_external_figure, BenchConfig};

fn main() {
    let cfg = BenchConfig::default();
    let budget_mb: usize = std::env::var("AIPSO_EXT_BUDGET_MB")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| ((cfg.n * 8) >> 20).max(1) / 4)
        .max(1);
    println!(
        "# External sort (n = {}, budget = {} MiB, data ≈ {:.1}x budget)\n",
        cfg.n,
        budget_mb,
        (cfg.n * 8) as f64 / ((budget_mb << 20) as f64),
    );
    let rows = run_external_figure(
        &["uniform", "lognormal", "zipf", "fb_ids", "wiki_edit"],
        budget_mb << 20,
        &cfg,
    );
    print!(
        "{}",
        render_external_rows("External sort: run-generation strategies", &rows)
    );
    println!(
        "\n(zipf and wiki_edit are duplicate-heavy: Algorithm 5's guard routes\n\
         their runs to IPS4o even under the learned strategy — the learned\n\
         column shows where the reused RMI actually engages)"
    );
}
