//! Ablation A2: RMI training-sample size — the paper's Section 5.1
//! explanation for AI1S²o losing sequentially: "The advantage of having
//! better pivots is offset by the training cost", while the parallel case
//! benefits. This sweep reproduces that trade-off.

use aipso::aips2o::{self, Aips2oConfig};
use aipso::datasets;
use aipso::util::{fmt, stats};

fn main() {
    let n: usize = std::env::var("AIPSO_N").ok().and_then(|v| v.parse().ok()).unwrap_or(2_000_000);
    let reps: usize = std::env::var("AIPSO_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let base = datasets::generate_f64("uniform", n, 11).unwrap();
    println!("# Ablation: RMI sample fraction, sequential vs parallel (uniform, n = {n})\n");
    println!("| sample frac | seq rate | par rate |");
    println!("|-------------|----------|----------|");
    for frac in [0.001f64, 0.005, 0.01, 0.03] {
        let mut cfg = Aips2oConfig::default();
        cfg.strategy.rmi_sample_frac = frac;
        let mut seq = Vec::new();
        let mut par = Vec::new();
        for _ in 0..reps {
            let mut v = base.clone();
            let t0 = std::time::Instant::now();
            aips2o::sort_seq_cfg(&mut v, &cfg);
            seq.push(n as f64 / t0.elapsed().as_secs_f64());
            let mut v = base.clone();
            let t0 = std::time::Instant::now();
            aips2o::sort_par_cfg(&mut v, 0, &cfg);
            par.push(n as f64 / t0.elapsed().as_secs_f64());
        }
        println!(
            "| {frac} | {} | {} |",
            fmt::rate(stats::mean(&seq)),
            fmt::rate(stats::mean(&par))
        );
    }
    println!("\nexpected shape: sequential rate degrades as sample grows; parallel flat/improving");
}
