//! Regenerates paper Table 2: quality of 255 pivots — random (IPS⁴o-style
//! oversampling) vs learned (Algorithm 4 over the LearnedSort RMI) — on
//! Uniform and Wiki/Edit. Metric: sum_i |P(A <= p_i) - (i+1)/B|.

use aipso::bench_harness::{table2_pivot_quality, BenchConfig};

fn main() {
    let cfg = BenchConfig::default();
    println!("# Table 2: pivot quality (n = {})\n", cfg.n);
    println!("| dataset | Random (255 pivots) | RMI (255 pivots) |");
    println!("|---------|---------------------|------------------|");
    for (name, q_random, q_rmi) in table2_pivot_quality(&cfg) {
        println!("| {name} | {q_random:.4} | {q_rmi:.4} |");
    }
    println!("\npaper reports: Uniform 1.1016 / 0.4388 ; Wiki/Edit 0.9991 / 0.5157");
    println!("expected shape: RMI column ~2x lower than Random on both rows");
}
