//! Regenerates paper Figures 1–3: sequential sorting throughput of
//! LearnedSort, I1S⁴o, I1S²Ra, AI1S²o and std::sort over all 14 datasets.
//!
//! Also runs the LearnedSort 2.0 duplicate sweep (beyond the paper's
//! figures; methodology in `BENCHMARKS.md`): uniform keys with 0–99% of
//! them overwritten by two heavy values, sorted by the 2.0 fragmented
//! scheme (equality buckets), the 1.x block scheme (spill bucket) and
//! std::sort. Set AIPSO_TRACE=1 to run the sweep with phase-span tracing
//! on: its table gains a `phases` column breaking each row down by
//! sample / train / partition / frag-partition / frag-compact / sort.
//!
//! A string-key section (beyond the paper) reruns a synthetic and a
//! dup-heavy law as 16-byte prefix strings: the learned engines model the
//! 8-byte ordered prefix and repair prefix ties by full comparison, so
//! the rows show the cost of string keys through the same engines.
//!
//! Scale with AIPSO_N / AIPSO_REPS (defaults are CI-sized; the paper used
//! N = 1e8 / 2e8 and 10 reps — shape, not absolute keys/s, is the target).

use aipso::bench_harness::{
    count_wins, render_dup_rows, render_rows, run_dup_sweep, run_figure, run_str_cell,
    BenchConfig,
};
use aipso::datasets::FigureGroup;
use aipso::SortEngine;

fn main() {
    let cfg = BenchConfig::default();
    let trace = std::env::var("AIPSO_TRACE").map(|v| v != "0").unwrap_or(false);
    println!(
        "# Sequential figures (n = {}, reps = {})\n",
        cfg.n, cfg.reps
    );
    let mut all = Vec::new();
    for (title, group) in [
        ("Figure 1: sequential, synthetic (Uniform/Normal/Log-Normal)", FigureGroup::Synthetic1),
        ("Figure 2: sequential, synthetic (MixGauss..Zipf)", FigureGroup::Synthetic2),
        ("Figure 3: sequential, real-world (simulated)", FigureGroup::RealWorld),
    ] {
        let rows = run_figure(group, false, &cfg);
        print!("{}\n", render_rows(title, &rows));
        all.extend(rows);
    }
    println!("## Sequential win count (paper: LearnedSort 9/14, I1S2Ra 4/14, I1S4o 1/14)");
    for (engine, wins) in count_wins(&all) {
        println!("  {engine}: {wins}/14");
    }

    if trace {
        aipso::obs::reset();
        aipso::obs::set_enabled(true);
    }
    let dup_rows = run_dup_sweep(&[0.0, 0.5, 0.9, 0.99], &cfg);
    if trace {
        aipso::obs::set_enabled(false);
    }
    print!(
        "\n{}",
        render_dup_rows(
            "Duplicate sweep: fragmented (2.0) vs block (1.x) partition",
            &dup_rows
        )
    );

    let mut str_rows = Vec::new();
    for dataset in ["uniform", "wiki_edit"] {
        for engine in [SortEngine::Aips2o, SortEngine::Ips4o, SortEngine::StdSort] {
            str_rows.push(run_str_cell(dataset, engine, false, &cfg));
        }
    }
    print!(
        "\n{}",
        render_rows(
            "String keys: 16-byte prefix strings through the same engines",
            &str_rows
        )
    );
    println!(
        "\n(keys are the figures' laws rendered as order-preserving hex\n\
         strings: the learned engines model the 8-byte prefix as bits and\n\
         repair prefix ties by full lexicographic comparison, so dup-heavy\n\
         laws stress the tie-repair path)"
    );
}
