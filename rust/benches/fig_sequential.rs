//! Regenerates paper Figures 1–3: sequential sorting throughput of
//! LearnedSort, I1S⁴o, I1S²Ra, AI1S²o and std::sort over all 14 datasets.
//!
//! Scale with AIPSO_N / AIPSO_REPS (defaults are CI-sized; the paper used
//! N = 1e8 / 2e8 and 10 reps — shape, not absolute keys/s, is the target).

use aipso::bench_harness::{count_wins, render_rows, run_figure, BenchConfig};
use aipso::datasets::FigureGroup;

fn main() {
    let cfg = BenchConfig::default();
    println!(
        "# Sequential figures (n = {}, reps = {})\n",
        cfg.n, cfg.reps
    );
    let mut all = Vec::new();
    for (title, group) in [
        ("Figure 1: sequential, synthetic (Uniform/Normal/Log-Normal)", FigureGroup::Synthetic1),
        ("Figure 2: sequential, synthetic (MixGauss..Zipf)", FigureGroup::Synthetic2),
        ("Figure 3: sequential, real-world (simulated)", FigureGroup::RealWorld),
    ] {
        let rows = run_figure(group, false, &cfg);
        print!("{}\n", render_rows(title, &rows));
        all.extend(rows);
    }
    println!("## Sequential win count (paper: LearnedSort 9/14, I1S2Ra 4/14, I1S4o 1/14)");
    for (engine, wins) in count_wins(&all) {
        println!("  {engine}: {wins}/14");
    }
}
