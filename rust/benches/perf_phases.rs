//! §Perf tool: per-phase time breakdown (sampling / model-train /
//! classification / block-permutation / cleanup / base-case) for each
//! engine — the hand-rolled profiler behind EXPERIMENTS.md §Perf.

use aipso::datasets;
use aipso::util::timer;
use aipso::util::{fmt, timer::PHASE_NAMES};
use aipso::{sort_parallel, sort_sequential, SortEngine};

fn main() {
    let n: usize = std::env::var("AIPSO_N").ok().and_then(|v| v.parse().ok()).unwrap_or(2_000_000);
    println!("# Phase breakdown (uniform, n = {n})\n");
    for (engine, parallel) in [
        (SortEngine::Aips2o, false),
        (SortEngine::Aips2o, true),
        (SortEngine::Ips4o, false),
        (SortEngine::Ips4o, true),
        (SortEngine::Ips2ra, true),
        (SortEngine::LearnedSort, false),
    ] {
        let mut v = datasets::generate_f64("uniform", n, 9).unwrap();
        timer::set_phase_profiling(true);
        timer::reset_phases();
        let t0 = std::time::Instant::now();
        if parallel {
            sort_parallel(engine, &mut v, 0);
        } else {
            sort_sequential(engine, &mut v);
        }
        let wall = t0.elapsed().as_secs_f64();
        timer::set_phase_profiling(false);
        let snap = timer::phase_snapshot();
        let total: u64 = snap.iter().sum();
        println!(
            "## {} — wall {} ({})",
            engine.paper_name(parallel),
            fmt::secs(wall),
            fmt::rate(n as f64 / wall)
        );
        for (name, ns) in PHASE_NAMES.iter().zip(snap.iter()) {
            if *ns > 0 {
                println!(
                    "  {:>18}: {:>9.1} ms ({:>4.1}% of phase time)",
                    name,
                    *ns as f64 / 1e6,
                    100.0 * *ns as f64 / total as f64
                );
            }
        }
        println!();
    }
}
