//! Ablation A4 (architecture): classification throughput of the RMI via
//! the AOT-compiled XLA artifact (batched through PJRT) vs the native
//! Rust mirror — the measurement behind DESIGN.md §1's "why two RMI
//! implementations". Requires `make artifacts`.

use aipso::classifier::rmi_classifier::RmiClassifier;
use aipso::classifier::Classifier;
use aipso::rmi::model::{Rmi, RmiConfig};
use aipso::runtime::{default_artifacts_dir, RmiRuntime};
use aipso::util::fmt;
use aipso::util::rng::Xoshiro256pp;

fn main() {
    let n: usize = std::env::var("AIPSO_N").ok().and_then(|v| v.parse().ok()).unwrap_or(2_000_000);
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
        return;
    }
    let rt = RmiRuntime::load(&dir).expect("load artifacts");
    let mut rng = Xoshiro256pp::new(5);
    let keys: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
    let mut sample: Vec<f64> = (0..rt.manifest().train_sample)
        .map(|_| keys[rng.next_below(n as u64) as usize])
        .collect();
    sample.sort_unstable_by(f64::total_cmp);

    println!("# Ablation: PJRT-artifact vs native RMI (n = {n})\n");

    // training
    let t0 = std::time::Instant::now();
    let rmi_xla = rt.train(&sample).unwrap();
    let t_xla_train = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let rmi_native = Rmi::train(&sample, RmiConfig { n_leaves: rt.manifest().n_leaves });
    let t_native_train = t0.elapsed().as_secs_f64();
    println!("| path | train time | predict rate |");
    println!("|------|------------|--------------|");

    // prediction: XLA batched
    let t0 = std::time::Instant::now();
    let cdf = rt.predict(&keys, &rmi_xla).unwrap();
    let t_xla = t0.elapsed().as_secs_f64();
    assert_eq!(cdf.len(), n);

    // prediction: native batch
    let classifier = RmiClassifier::new(rmi_native.clone(), 1024);
    let mut out = vec![0u32; n];
    let t0 = std::time::Instant::now();
    classifier.classify_batch(&keys, &mut out);
    let t_native = t0.elapsed().as_secs_f64();

    println!(
        "| XLA/PJRT artifact | {} | {} |",
        fmt::secs(t_xla_train),
        fmt::rate(n as f64 / t_xla)
    );
    println!(
        "| native Rust mirror | {} | {} |",
        fmt::secs(t_native_train),
        fmt::rate(n as f64 / t_native)
    );
    println!(
        "\nnative/XLA predict speedup: {:.1}x (expected >1: per-call FFI + literal copies;\nthis is why the sort hot loop uses the native mirror — DESIGN.md §1)",
        t_xla / t_native
    );
    // numeric agreement while we're here
    let max_err = keys
        .iter()
        .zip(&cdf)
        .map(|(k, p)| (rmi_native.predict(*k) - p).abs())
        .fold(0.0f64, f64::max);
    println!("max |native - xla| = {max_err:.2e}");
    assert!(max_err < 1e-9);
}
