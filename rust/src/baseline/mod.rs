//! `std::sort` baselines (engine E5).
//!
//! The paper's sequential baseline is GNU libstdc++ IntroSort; Rust's
//! `sort_unstable` is pdqsort — the algorithm the paper itself cites as
//! "currently implemented by the Rust Standard Library" (Section 2.3), so
//! it is the natural stand-in. The parallel baseline stands in for
//! `std::sort(std::execution::par_unseq, ...)`: chunk-sort with pdqsort,
//! then parallel pairwise merge passes.

use crate::key::SortKey;
use crate::scheduler::{par_chunks_mut, parallel_for};

/// Sequential baseline: pdqsort over the order-preserving bit image.
pub fn std_sort<K: SortKey>(data: &mut [K]) {
    data.sort_unstable_by_key(|k| k.to_bits_ordered());
}

#[derive(Clone, Copy)]
struct ConstPtr<K>(*const K);
unsafe impl<K> Send for ConstPtr<K> {}
unsafe impl<K> Sync for ConstPtr<K> {}
impl<K> ConstPtr<K> {
    /// Accessor (not field) so closures capture the Sync wrapper whole.
    fn get(self) -> *const K {
        self.0
    }
}

#[derive(Clone, Copy)]
struct MutPtr<K>(*mut K);
unsafe impl<K> Send for MutPtr<K> {}
unsafe impl<K> Sync for MutPtr<K> {}
impl<K> MutPtr<K> {
    fn get(self) -> *mut K {
        self.0
    }
}

/// Parallel baseline: parallel chunk sort + log(threads) merge passes.
pub fn par_sort<K: SortKey>(data: &mut [K], threads: usize) {
    let threads = threads.max(1);
    let n = data.len();
    if threads == 1 || n < 1 << 13 {
        return std_sort(data);
    }
    // 1. sort `threads` chunks in parallel
    let chunk = n.div_ceil(threads);
    par_chunks_mut(threads, data, |_, _, piece| {
        piece.sort_unstable_by_key(|k| k.to_bits_ordered());
    });
    // 2. pairwise parallel merge passes, ping-ponging via scratch
    let mut scratch: Vec<K> = data.to_vec();
    let mut in_data = true;
    let mut width = chunk;
    while width < n {
        let (src, dst) = if in_data {
            (ConstPtr(data.as_ptr()), MutPtr(scratch.as_mut_ptr()))
        } else {
            (ConstPtr(scratch.as_ptr()), MutPtr(data.as_mut_ptr()))
        };
        let pairs = n.div_ceil(2 * width);
        parallel_for(threads, pairs, |_, range| {
            for p in range {
                let lo = p * 2 * width;
                let mid = (lo + width).min(n);
                let hi = (lo + 2 * width).min(n);
                // SAFETY: pair output ranges [lo, hi) are disjoint; src and
                // dst are distinct allocations.
                unsafe {
                    let a = std::slice::from_raw_parts(src.get().add(lo), mid - lo);
                    let b = std::slice::from_raw_parts(src.get().add(mid), hi - mid);
                    let out = std::slice::from_raw_parts_mut(dst.get().add(lo), hi - lo);
                    merge_into(a, b, out);
                }
            }
        });
        in_data = !in_data;
        width *= 2;
    }
    if !in_data {
        data.copy_from_slice(&scratch);
    }
}

fn merge_into<K: SortKey>(a: &[K], b: &[K], out: &mut [K]) {
    debug_assert_eq!(a.len() + b.len(), out.len());
    let (mut i, mut j) = (0, 0);
    for slot in out.iter_mut() {
        let take_a =
            j >= b.len() || (i < a.len() && a[i].to_bits_ordered() <= b[j].to_bits_ordered());
        if take_a {
            *slot = a[i];
            i += 1;
        } else {
            *slot = b[j];
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn std_sort_floats() {
        let mut rng = Xoshiro256pp::new(1);
        let mut v: Vec<f64> = (0..10_000).map(|_| rng.normal()).collect();
        std_sort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn par_sort_matches_std() {
        for (n, t) in [(100usize, 4usize), (1 << 13, 2), (200_000, 8), (131_073, 3)] {
            let mut rng = Xoshiro256pp::new(n as u64);
            let mut v: Vec<u64> = (0..n).map(|_| rng.next_below(1 << 40)).collect();
            let mut want = v.clone();
            want.sort_unstable();
            par_sort(&mut v, t);
            assert_eq!(v, want, "n={n} t={t}");
        }
    }

    #[test]
    fn merge_into_basic() {
        let a = [1u64, 3, 5];
        let b = [2u64, 2, 6];
        let mut out = [0u64; 6];
        merge_into(&a, &b, &mut out);
        assert_eq!(out, [1, 2, 2, 3, 5, 6]);
    }

    #[test]
    fn par_sort_with_duplicates() {
        let mut rng = Xoshiro256pp::new(77);
        let mut v: Vec<u64> = (0..50_000).map(|_| rng.next_below(10)).collect();
        let mut want = v.clone();
        want.sort_unstable();
        par_sort(&mut v, 4);
        assert_eq!(v, want);
    }
}
