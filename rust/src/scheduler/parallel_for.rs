//! Fork-join data parallelism over index ranges and mutable slices.

use std::ops::Range;

/// Split `0..n` into at most `threads` contiguous chunks and run `f(chunk
/// index, range)` on its own scoped thread. Chunk 0 runs on the caller
/// thread. Returns after all chunks complete (fork-join barrier).
pub fn parallel_for<F>(threads: usize, n: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let threads = threads.max(1);
    if n == 0 {
        return;
    }
    if threads == 1 || n == 1 {
        f(0, 0..n);
        return;
    }
    let workers = threads.min(n);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        let fref = &f;
        for t in 1..workers {
            let lo = t * chunk;
            if lo >= n {
                break;
            }
            let hi = ((t + 1) * chunk).min(n);
            s.spawn(move || fref(t, lo..hi));
        }
        f(0, 0..chunk.min(n));
    });
}

/// Split `0..n` into at most `parts` contiguous, non-empty ranges whose
/// starts are multiples of `align`; the last range absorbs the unaligned
/// tail. Fewer than `parts` ranges come back when `n / align < parts` —
/// a worker is never handed an empty range. The LearnedSort 2.0 parallel
/// fragmented partition stripes its input with this so every stripe's
/// fragment slots stay aligned to the global slot grid.
pub fn aligned_ranges(n: usize, align: usize, parts: usize) -> Vec<Range<usize>> {
    assert!(align >= 1, "alignment must be positive");
    if n == 0 {
        return Vec::new();
    }
    let units = n / align;
    let workers = parts.max(1).min(units.max(1));
    let chunk = units.div_ceil(workers);
    let mut out = Vec::with_capacity(workers);
    let mut start = 0usize;
    for t in 0..workers {
        let end = if t + 1 == workers {
            n
        } else {
            ((t + 1) * chunk * align).min(n)
        };
        if start >= end {
            break;
        }
        out.push(start..end);
        start = end;
    }
    out
}

/// Split a mutable slice into at most `threads` contiguous chunks and run
/// `f(chunk index, start offset, chunk)` per chunk in parallel.
pub fn par_chunks_mut<T: Send, F>(threads: usize, data: &mut [T], f: F)
where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let threads = threads.max(1);
    let n = data.len();
    if n == 0 {
        return;
    }
    if threads == 1 {
        f(0, 0, data);
        return;
    }
    let workers = threads.min(n);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        let fref = &f;
        for (t, piece) in data.chunks_mut(chunk).enumerate() {
            let offset = t * chunk;
            s.spawn(move || fref(t, offset, piece));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let n = 10_001;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(8, n, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_and_empty() {
        let count = AtomicUsize::new(0);
        parallel_for(1, 5, |_, r| {
            count.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 5);
        parallel_for(4, 0, |_, _| panic!("must not run"));
    }

    #[test]
    fn par_chunks_mut_writes_disjoint() {
        let mut v = vec![0usize; 1000];
        par_chunks_mut(7, &mut v, |_, offset, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = offset + i;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn aligned_ranges_cover_and_align() {
        for (n, align, parts) in [
            (1000usize, 128usize, 4usize),
            (1001, 128, 4),
            (127, 128, 4),
            (128, 128, 4),
            (129, 128, 4),
            (131, 8, 7),
            (4096, 1, 16),
            (65_537, 64, 8),
            (13, 4, 64),
        ] {
            let ranges = aligned_ranges(n, align, parts);
            assert!(ranges.len() <= parts, "n={n} align={align} parts={parts}");
            assert!(!ranges.is_empty());
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, n);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous cover");
            }
            for r in &ranges {
                assert!(r.start < r.end, "no empty range");
                assert_eq!(r.start % align, 0, "aligned start");
            }
        }
        assert!(aligned_ranges(0, 8, 4).is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let mut v = vec![1u8; 3];
        par_chunks_mut(64, &mut v, |_, _, chunk| {
            for x in chunk.iter_mut() {
                *x += 1;
            }
        });
        assert_eq!(v, vec![2, 2, 2]);
    }
}
