//! Task scheduler / thread pool (substrate S5).
//!
//! IPS⁴o ships its own scheduler rather than TBB; likewise we build ours on
//! `std::thread` (no rayon offline). Two primitives cover everything the
//! engines need:
//!
//! * [`parallel_for`] / [`par_chunks_mut`] — fork-join data parallelism for
//!   the cooperative phases (striped classification, block permutation).
//! * [`run_task_pool`] — a shared work queue with dynamic spawning for the
//!   recursion phase (buckets become tasks; tasks may push sub-tasks), the
//!   analogue of IPS⁴o's sub-problem scheduler.
//!
//! The external sorter builds on the same two primitives: its merge
//! groups and quantile shards are `run_task_pool` tasks, and its pipelined
//! run generation runs the per-chunk sort on the pool.
//!
//! ```
//! use std::sync::atomic::{AtomicUsize, Ordering};
//! use aipso::scheduler::{parallel_for, run_task_pool};
//!
//! // fork-join over an index range
//! let sum = AtomicUsize::new(0);
//! parallel_for(4, 1000, |_chunk, range| {
//!     sum.fetch_add(range.len(), Ordering::Relaxed);
//! });
//! assert_eq!(sum.load(Ordering::Relaxed), 1000);
//!
//! // dynamic task pool: tasks may spawn sub-tasks
//! let done = AtomicUsize::new(0);
//! run_task_pool(4, vec![3usize], |depth, spawner| {
//!     done.fetch_add(1, Ordering::Relaxed);
//!     if depth > 0 {
//!         spawner.spawn(depth - 1);
//!     }
//! });
//! assert_eq!(done.load(Ordering::Relaxed), 4);
//! ```

pub mod parallel_for;
pub mod pool;

pub use parallel_for::{aligned_ranges, par_chunks_mut, parallel_for};
pub use pool::{run_task_pool, Spawner};

/// Resolve a thread-count argument: 0 = all available cores.
pub fn effective_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_threads_resolves_zero() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }
}
