//! Task scheduler / thread pool (substrate S5).
//!
//! IPS⁴o ships its own scheduler rather than TBB; likewise we build ours on
//! `std::thread` (no rayon offline). Two primitives cover everything the
//! engines need:
//!
//! * [`parallel_for`] / [`par_chunks_mut`] — fork-join data parallelism for
//!   the cooperative phases (striped classification, block permutation).
//! * [`run_task_pool`] — a shared work queue with dynamic spawning for the
//!   recursion phase (buckets become tasks; tasks may push sub-tasks), the
//!   analogue of IPS⁴o's sub-problem scheduler.

pub mod parallel_for;
pub mod pool;

pub use parallel_for::{par_chunks_mut, parallel_for};
pub use pool::{run_task_pool, Spawner};

/// Resolve a thread-count argument: 0 = all available cores.
pub fn effective_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_threads_resolves_zero() {
        assert!(effective_threads(0) >= 1);
        assert_eq!(effective_threads(3), 3);
    }
}
