//! Shared work queue with dynamic task spawning — the recursion scheduler.
//!
//! Mirrors IPS⁴o's sub-problem handling: after the cooperative top-level
//! partition, every bucket becomes a task; workers pop tasks LIFO (depth
//! first — better locality, bounded queue growth) and may push the
//! sub-buckets they create. The pool terminates when the queue is empty
//! *and* no worker is mid-task.

use std::sync::{Condvar, Mutex};

struct QueueState<T> {
    tasks: Vec<T>,
    active: usize,
}

/// Handle workers use to push newly created sub-tasks.
pub struct Spawner<'a, T> {
    state: &'a Mutex<QueueState<T>>,
    cv: &'a Condvar,
}

impl<'a, T> Spawner<'a, T> {
    /// Push one new task onto the shared queue.
    pub fn spawn(&self, task: T) {
        let mut q = self.state.lock().unwrap();
        q.tasks.push(task);
        let depth = q.tasks.len();
        drop(q);
        observe_depth(depth);
        self.cv.notify_one();
    }

    /// Push many tasks with one lock round-trip.
    pub fn spawn_all(&self, tasks: impl IntoIterator<Item = T>) {
        let mut q = self.state.lock().unwrap();
        q.tasks.extend(tasks);
        let depth = q.tasks.len();
        drop(q);
        observe_depth(depth);
        self.cv.notify_all();
    }
}

/// Sample the queue depth into the observability histogram — outside the
/// queue lock, and a single relaxed load while tracing is off.
fn observe_depth(depth: usize) {
    if crate::obs::enabled() {
        crate::obs::metrics::observe(
            crate::obs::M_POOL_DEPTH,
            crate::obs::metrics::DEPTH_BUCKETS,
            depth as f64,
        );
    }
}

/// Run `initial` tasks (plus any tasks they spawn) on `threads` workers.
/// `worker` must be safe to call concurrently from multiple threads.
pub fn run_task_pool<T, F>(threads: usize, initial: Vec<T>, worker: F)
where
    T: Send,
    F: Fn(T, &Spawner<T>) + Sync,
{
    let threads = threads.max(1);
    if initial.is_empty() {
        return;
    }
    let state = Mutex::new(QueueState {
        tasks: initial,
        active: 0,
    });
    let cv = Condvar::new();

    // Panic safety: if `worker` panics, the active count must still drop
    // and sleepers must be woken, or the remaining workers deadlock and
    // the panic never propagates out of the scope join.
    struct ActiveGuard<'a, T> {
        state: &'a Mutex<QueueState<T>>,
        cv: &'a Condvar,
    }
    impl<'a, T> Drop for ActiveGuard<'a, T> {
        fn drop(&mut self) {
            let mut q = self.state.lock().unwrap();
            q.active -= 1;
            if q.tasks.is_empty() && q.active == 0 {
                // done (or unwinding): wake all sleepers so they can exit
                self.cv.notify_all();
            } else if std::thread::panicking() {
                // propagate shutdown urgency — sleepers re-check and the
                // scope join can collect the panic
                self.cv.notify_all();
            }
        }
    }

    let run_worker = || {
        let spawner = Spawner {
            state: &state,
            cv: &cv,
        };
        let mut guard = state.lock().unwrap();
        loop {
            if let Some(task) = guard.tasks.pop() {
                guard.active += 1;
                drop(guard);
                {
                    let _active = ActiveGuard {
                        state: &state,
                        cv: &cv,
                    };
                    worker(task, &spawner);
                }
                guard = state.lock().unwrap();
            } else if guard.active == 0 {
                return; // queue drained and nobody can produce more
            } else {
                guard = cv.wait(guard).unwrap();
            }
        }
    };

    if threads == 1 {
        run_worker();
        return;
    }
    std::thread::scope(|s| {
        for _ in 1..threads {
            s.spawn(run_worker);
        }
        run_worker();
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn processes_all_initial_tasks() {
        let done = AtomicUsize::new(0);
        run_task_pool(4, (0..100).collect(), |_t: usize, _s| {
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn spawned_tasks_run() {
        // Each task k spawns two tasks k-1 until 0: total = 2^k - 1 per root
        let done = AtomicUsize::new(0);
        run_task_pool(8, vec![6usize], |t, s| {
            done.fetch_add(1, Ordering::Relaxed);
            if t > 0 {
                s.spawn(t - 1);
                s.spawn(t - 1);
            }
        });
        assert_eq!(done.load(Ordering::Relaxed), (1 << 7) - 1);
    }

    #[test]
    fn spawn_all_batches() {
        let done = AtomicUsize::new(0);
        run_task_pool(4, vec![0usize], |t, s| {
            done.fetch_add(1, Ordering::Relaxed);
            if t == 0 {
                s.spawn_all(1..=50);
            }
        });
        assert_eq!(done.load(Ordering::Relaxed), 51);
    }

    #[test]
    fn single_thread_correct() {
        let done = AtomicUsize::new(0);
        run_task_pool(1, vec![3usize], |t, s| {
            done.fetch_add(1, Ordering::Relaxed);
            if t > 0 {
                s.spawn(t - 1);
            }
        });
        assert_eq!(done.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn empty_initial_returns_immediately() {
        run_task_pool::<usize, _>(4, vec![], |_, _| panic!("no tasks"));
    }

    #[test]
    fn spawns_sample_queue_depth_when_tracing() {
        let _l = crate::obs::test_lock();
        crate::obs::reset();
        crate::obs::set_enabled(true);
        run_task_pool(2, vec![0usize], |t, s| {
            if t == 0 {
                s.spawn_all(1..=8);
            }
        });
        crate::obs::set_enabled(false);
        let m = crate::obs::metrics::snapshot();
        let h = m.hists.get(crate::obs::M_POOL_DEPTH).expect("depth sampled");
        assert!(h.count >= 1);
        assert!(h.max >= 8.0, "the batch spawn saw 8 queued tasks");
    }

    #[test]
    fn heavy_contention_terminates() {
        // Many tiny tasks with bursts of spawning; exercises the
        // wait/notify paths under contention.
        let done = AtomicUsize::new(0);
        run_task_pool(16, (0..64).map(|_| 3usize).collect(), |t, s| {
            done.fetch_add(1, Ordering::Relaxed);
            if t > 0 {
                for _ in 0..2 {
                    s.spawn(t - 1);
                }
            }
        });
        // 64 roots, each expands to 2^4 - 1 = 15 tasks
        assert_eq!(done.load(Ordering::Relaxed), 64 * 15);
    }
}
