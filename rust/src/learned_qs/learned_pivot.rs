//! Paper Algorithm 1 + 2: Quicksort whose pivot is selected by a CDF
//! model — "the largest element from A that has predicted CDF less than
//! or equal to the true median", then a classic Lomuto partition.

use crate::key::SortKey;
use crate::learned_qs::{train_cdf_model, BASECASE_SIZE};
use crate::rmi::model::Rmi;
use crate::sample_sort::base_case::{heapsort, insertion_sort};
use crate::util::rng::Xoshiro256pp;

/// Sort with Quicksort + learned pivots (paper Algorithms 1 and 2).
pub fn sort<K: SortKey>(data: &mut [K]) {
    let mut rng = Xoshiro256pp::new(0x1EA2_1 ^ data.len() as u64);
    let depth = 2 * (usize::BITS - data.len().leading_zeros()) as usize + 8;
    quicksort(data, depth, &mut rng);
}

fn quicksort<K: SortKey>(data: &mut [K], depth: usize, rng: &mut Xoshiro256pp) {
    // Algorithm 1
    if data.len() <= BASECASE_SIZE {
        insertion_sort(data);
        return;
    }
    if depth == 0 {
        // IntroSort guard — the paper notes the Θ(N²) worst case persists
        heapsort(data);
        return;
    }
    let q = partition_with_learned_pivot(data, rng);
    let (lo, hi) = data.split_at_mut(q);
    quicksort(lo, depth - 1, rng);
    quicksort(&mut hi[1..], depth - 1, rng);
}

/// Paper Algorithm 2. Returns the final pivot index.
pub fn partition_with_learned_pivot<K: SortKey>(data: &mut [K], rng: &mut Xoshiro256pp) -> usize {
    let r = data.len() - 1;
    let model: Rmi = train_cdf_model(data, rng);
    // Select the largest element with predicted CDF <= 0.5 (the median
    // according to the model).
    let mut t: Option<usize> = None;
    for w in 0..data.len() {
        if model.predict(data[w].to_f64()) <= 0.5 {
            t = Some(match t {
                None => w,
                Some(t0) => {
                    if data[t0].key_lt(data[w]) {
                        w
                    } else {
                        t0
                    }
                }
            });
        }
    }
    // Fallback: a model that puts every key above the median gives no
    // pivot; pick a random element (the paper's "otherwise we would fall
    // back to a random pick").
    let t = t.unwrap_or_else(|| rng.next_below(data.len() as u64) as usize);
    data.swap(t, r);
    // Classic Lomuto partition around data[r].
    let pivot = data[r].to_bits_ordered();
    let mut i = 0usize;
    for j in 0..r {
        if data[j].to_bits_ordered() <= pivot {
            data.swap(i, j);
            i += 1;
        }
    }
    data.swap(i, r);
    i
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn sorts_random_inputs() {
        for n in [0usize, 1, 64, 65, 1000, 50_000] {
            let mut rng = Xoshiro256pp::new(n as u64);
            let mut v: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
            sort(&mut v);
            assert!(is_sorted(&v), "n={n}");
        }
    }

    #[test]
    fn sorts_adversaries() {
        let n = 20_000;
        let mut v: Vec<u64> = (0..n).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
        let mut v: Vec<u64> = (0..n).rev().collect();
        sort(&mut v);
        assert!(is_sorted(&v));
        let mut v = vec![7u64; n as usize];
        sort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn partition_splits_correctly() {
        let mut rng = Xoshiro256pp::new(42);
        let mut v: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        let q = partition_with_learned_pivot(&mut v, &mut rng);
        let p = v[q];
        assert!(v[..q].iter().all(|x| x.key_le(p)));
        assert!(v[q + 1..].iter().all(|x| !x.key_lt(p)));
    }

    #[test]
    fn learned_pivot_near_median_on_uniform() {
        // The paper's claim: the learned pivot approximates the median.
        let mut rng = Xoshiro256pp::new(43);
        let mut v: Vec<f64> = (0..50_000).map(|_| rng.uniform(0.0, 1.0)).collect();
        let q = partition_with_learned_pivot(&mut v, &mut rng);
        let eta = (q as f64 / v.len() as f64 - 0.5).abs();
        assert!(eta < 0.1, "learned pivot far from median: eta = {eta}");
    }
}
