//! The analysis algorithms of the paper's Section 3 (engines E6, E7):
//! Quicksort with Learned Pivots (Algorithms 1+2) and Learned Quicksort
//! (Algorithm 3, LearnedSort with B = 2).
//!
//! These exist to validate the paper's *analysis*, not to win benchmarks:
//! Section 3.1 concludes "Quicksort with Learned Pivots is not efficient
//! to outperform IntroSort or pdqsort. However, it is conceptually useful"
//! — the complexity tests in `rust/tests/` verify exactly the claims made
//! (O(N log N) behaviour, Learned Quicksort ≡ learned-pivot partition).

pub mod learned_pivot;
pub mod learned_quicksort;

use crate::key::SortKey;
use crate::rmi::model::{Rmi, RmiConfig};
use crate::util::rng::Xoshiro256pp;

/// Shared base-case size (the paper's BASECASE_SIZE).
pub const BASECASE_SIZE: usize = 64;

/// Shared TrainCDFModel: sample, HeapSort the sample (the paper uses
/// HeapSort explicitly — "any algorithm with the same complexity would
/// work"), fit a small RMI.
pub(crate) fn train_cdf_model<K: SortKey>(data: &[K], rng: &mut Xoshiro256pp) -> Rmi {
    let n = data.len();
    let ssz = (n / 8).clamp(16, 512).min(n);
    let mut sample: Vec<f64> = (0..ssz)
        .map(|_| data[rng.next_below(n as u64) as usize].to_f64())
        .collect();
    // the paper's Algorithm 2 sorts the sample with HeapSort
    crate::sample_sort::base_case::heapsort(&mut sample);
    Rmi::train(&sample, RmiConfig { n_leaves: 16 })
}
