//! Paper Algorithm 3: Learned Quicksort — LearnedSort with B = 2 buckets.
//!
//! The partition never computes a pivot: elements with F(A[i]) <= 0.5 go
//! left, the rest go right. Section 3.2's insight is that this is the
//! *same* partition as Quicksort with Learned Pivots, minus the
//! comparisons — "a Quicksort variant that circumvents the bounds on the
//! theoretical number of comparisons by embracing the numerical properties
//! of the CDF".

use crate::key::SortKey;
use crate::learned_qs::{train_cdf_model, BASECASE_SIZE};
use crate::sample_sort::base_case::{heapsort, insertion_sort};
use crate::util::rng::Xoshiro256pp;

/// Sort with Learned Quicksort (paper Algorithm 3).
pub fn sort<K: SortKey>(data: &mut [K]) {
    let mut rng = Xoshiro256pp::new(0x1EA2_3 ^ data.len() as u64);
    let depth = 2 * (usize::BITS - data.len().leading_zeros()) as usize + 8;
    learned_quicksort(data, depth, &mut rng);
}

fn learned_quicksort<K: SortKey>(data: &mut [K], depth: usize, rng: &mut Xoshiro256pp) {
    if data.len() <= BASECASE_SIZE {
        insertion_sort(data);
        return;
    }
    if depth == 0 {
        heapsort(data);
        return;
    }
    let model = train_cdf_model(data, rng);
    // Two-pointer partition on the model output (Algorithm 3's loop).
    let mut i = 0usize;
    let mut j = data.len() - 1;
    while i < j {
        if model.predict(data[i].to_f64()) <= 0.5 {
            i += 1;
        } else {
            data.swap(i, j);
            j -= 1;
        }
    }
    // include data[i] on the left when it also classifies left
    let split = if model.predict(data[i].to_f64()) <= 0.5 {
        i + 1
    } else {
        i
    };
    // Degenerate model (everything on one side): fall back to a random
    // median-of-3 step so progress is guaranteed.
    if split == 0 || split == data.len() {
        let q = fallback_partition(data, rng);
        let (lo, hi) = data.split_at_mut(q);
        learned_quicksort(lo, depth - 1, rng);
        learned_quicksort(&mut hi[1..], depth - 1, rng);
        return;
    }
    let (lo, hi) = data.split_at_mut(split);
    learned_quicksort(lo, depth - 1, rng);
    learned_quicksort(hi, depth - 1, rng);
}

fn fallback_partition<K: SortKey>(data: &mut [K], rng: &mut Xoshiro256pp) -> usize {
    let n = data.len();
    let r = n - 1;
    let t = rng.next_below(n as u64) as usize;
    data.swap(t, r);
    let pivot = data[r].to_bits_ordered();
    let mut i = 0usize;
    for j in 0..r {
        if data[j].to_bits_ordered() <= pivot {
            data.swap(i, j);
            i += 1;
        }
    }
    data.swap(i, r);
    i
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn sorts_random_inputs() {
        for n in [0usize, 1, 64, 100, 10_000, 100_000] {
            let mut rng = Xoshiro256pp::new(n as u64 + 17);
            let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            sort(&mut v);
            assert!(is_sorted(&v), "n={n}");
        }
    }

    #[test]
    fn sorts_duplicates_and_patterns() {
        let n = 30_000;
        let mut v = vec![1.0f64; n];
        sort(&mut v);
        assert!(is_sorted(&v));
        let mut v: Vec<u64> = (0..n as u64).map(|i| i % 10).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
        let mut v: Vec<u64> = (0..n as u64).rev().collect();
        sort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn partition_is_balanced_on_uniform() {
        // Section 3.2: the implicit pivot should land near the median.
        let mut rng = Xoshiro256pp::new(3);
        let data: Vec<f64> = (0..50_000).map(|_| rng.uniform(0.0, 1.0)).collect();
        let model = crate::learned_qs::train_cdf_model(&data, &mut rng);
        let left = data.iter().filter(|x| model.predict(x.to_f64()) <= 0.5).count();
        let frac = left as f64 / data.len() as f64;
        assert!((frac - 0.5).abs() < 0.1, "split fraction {frac}");
    }
}
