//! Pluggable spill-IO substrate: submission-based positioned writes and
//! reads, optional `O_DIRECT`, and the aligned-buffer plumbing behind
//! both.
//!
//! The external pipeline used to do all spill IO through buffered
//! `std::fs` streams, with one ad-hoc flusher thread per merge shard and
//! a dedicated writer thread in the pipelined run generator. This module
//! replaces those with one substrate:
//!
//! - [`IoBackendKind`] selects between the **sync** backend (positioned
//!   writes issued inline on the calling thread — the reference
//!   behavior) and the **pool** backend (a fixed worker pool consuming a
//!   submission queue of positioned `read_at`/`write_at` ops, returning
//!   completion handles). Both produce byte-identical files; the pool
//!   overlaps encode/merge compute with disk time without per-call-site
//!   thread management.
//! - [`SpillSink`] is the sequential append writer both backends share:
//!   it accumulates into [`ALIGN`]-aligned buffers, dispatches full
//!   buffers (inline or to the pool), and in `O_DIRECT` mode keeps the
//!   unaligned tail resident until [`SpillSink::seal`] zero-pads it to
//!   the alignment — the pad is reported to the caller so the spill
//!   header can record it and readers stop before it.
//! - [`PoolReader`] is the pool-backed counterpart of a
//!   `BufReader<File>`: it prefetches the next buffer through the
//!   submission queue while the current one is consumed, and implements
//!   the small [`SpillRead`] seek surface the v2 block decoder needs.
//! - `O_DIRECT` is attempted per file (create-time probe write); when
//!   the filesystem refuses (tmpfs does), the sink silently reopens
//!   buffered and counts an `io.direct.fallback`, so a striped set of
//!   dirs with mixed filesystems still works.
//!
//! Nothing here changes file contents: the backends, direct mode, and
//! striping are pure transport. The only on-disk difference direct mode
//! makes is the zero pad after the final block, which is recorded in the
//! spill header and invisible to every reader.

use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::{self, Read};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::obs;

/// Alignment for `O_DIRECT` buffers, offsets, and lengths (one page —
/// satisfies the 512-byte logical-block floor on every common device).
pub const ALIGN: usize = 4096;

/// Worker threads in a submission-queue pool. Spill IO is bandwidth- not
/// IOPS-bound; a few workers saturate a handful of striped disks.
const POOL_WORKERS: usize = 4;

/// Completed-but-unrecycled writes a [`SpillSink`] keeps in flight
/// before it backpressures on the oldest submission.
const MAX_INFLIGHT: usize = 4;

/// `O_DIRECT` bit for [`open_direct`]: 0o200000 on arm/aarch64,
/// 0o40000 elsewhere (x86, the generic value).
#[cfg(all(unix, any(target_arch = "aarch64", target_arch = "arm")))]
const O_DIRECT: i32 = 0o200000;
#[cfg(all(unix, not(any(target_arch = "aarch64", target_arch = "arm"))))]
const O_DIRECT: i32 = 0o40000;

/// Which transport executes spill reads and writes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoBackendKind {
    /// Positioned IO issued inline on the calling thread (reference).
    Sync,
    /// Submission-queue thread pool with completion handles.
    Pool,
}

impl IoBackendKind {
    /// Parse a backend name as spelled on the CLI (`sync` | `pool`).
    pub fn parse(s: &str) -> Option<IoBackendKind> {
        match s {
            "sync" => Some(IoBackendKind::Sync),
            "pool" => Some(IoBackendKind::Pool),
            _ => None,
        }
    }

    /// The CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            IoBackendKind::Sync => "sync",
            IoBackendKind::Pool => "pool",
        }
    }

    /// Backend named by the `AIPSO_IO_BACKEND` environment variable, if
    /// set and valid (the CI matrix re-runs suites under `pool`).
    pub fn from_env() -> Option<IoBackendKind> {
        std::env::var("AIPSO_IO_BACKEND").ok().and_then(|v| IoBackendKind::parse(&v))
    }
}

/// Per-job IO context: the chosen backend (owning the worker pool when
/// one is configured) and the `O_DIRECT` preference. Cheap to clone and
/// share across merge workers — clones reference one pool.
#[derive(Clone)]
pub struct IoCtx {
    backend: IoBackendKind,
    direct: bool,
    pool: Option<Arc<IoPool>>,
}

impl IoCtx {
    /// Build a context for a job; `Pool` spawns the worker pool here.
    pub fn new(backend: IoBackendKind, direct: bool) -> IoCtx {
        let pool = match backend {
            IoBackendKind::Pool => Some(Arc::new(IoPool::new(POOL_WORKERS))),
            IoBackendKind::Sync => None,
        };
        IoCtx { backend, direct, pool }
    }

    /// The reference context: inline IO, no direct mode (what every
    /// legacy call site gets).
    pub fn sync() -> IoCtx {
        IoCtx { backend: IoBackendKind::Sync, direct: false, pool: None }
    }

    /// The configured backend.
    pub fn backend(&self) -> IoBackendKind {
        self.backend
    }

    /// Whether `O_DIRECT` should be attempted for spill-file writes.
    pub fn direct(&self) -> bool {
        self.direct
    }

    pub(crate) fn pool(&self) -> Option<&Arc<IoPool>> {
        self.pool.as_ref()
    }
}

impl Default for IoCtx {
    fn default() -> IoCtx {
        IoCtx::sync()
    }
}

impl std::fmt::Debug for IoCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("IoCtx")
            .field("backend", &self.backend)
            .field("direct", &self.direct)
            .finish()
    }
}

/// A positioned-IO file handle shareable between submitters and pool
/// workers. On unix this is `pread`/`pwrite`; elsewhere positioned IO is
/// emulated with seek+read/write under a lock.
#[derive(Clone)]
pub(crate) struct PFile {
    file: Arc<File>,
    #[cfg(not(unix))]
    lock: Arc<Mutex<()>>,
}

impl PFile {
    pub(crate) fn new(file: File) -> PFile {
        PFile {
            file: Arc::new(file),
            #[cfg(not(unix))]
            lock: Arc::new(Mutex::new(())),
        }
    }

    /// Write the whole buffer at `off` (no file-cursor involvement).
    #[cfg(unix)]
    pub(crate) fn write_all_at(&self, buf: &[u8], off: u64) -> io::Result<()> {
        use std::os::unix::fs::FileExt;
        self.file.write_all_at(buf, off)
    }

    #[cfg(not(unix))]
    pub(crate) fn write_all_at(&self, buf: &[u8], off: u64) -> io::Result<()> {
        use std::io::{Seek, SeekFrom, Write};
        let _g = self.lock.lock().unwrap();
        let mut f = &*self.file;
        f.seek(SeekFrom::Start(off))?;
        f.write_all(buf)
    }

    /// Read at `off` until the buffer is full or EOF; returns the bytes
    /// read (short only at end of file).
    #[cfg(unix)]
    pub(crate) fn read_some_at(&self, buf: &mut [u8], mut off: u64) -> io::Result<usize> {
        use std::os::unix::fs::FileExt;
        let mut total = 0;
        while total < buf.len() {
            match self.file.read_at(&mut buf[total..], off) {
                Ok(0) => break,
                Ok(n) => {
                    total += n;
                    off += n as u64;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(total)
    }

    #[cfg(not(unix))]
    pub(crate) fn read_some_at(&self, buf: &mut [u8], off: u64) -> io::Result<usize> {
        use std::io::{Seek, SeekFrom};
        let _g = self.lock.lock().unwrap();
        let mut f = &*self.file;
        f.seek(SeekFrom::Start(off))?;
        let mut total = 0;
        while total < buf.len() {
            match Read::read(&mut f, &mut buf[total..]) {
                Ok(0) => break,
                Ok(n) => total += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(total)
    }
}

/// A heap buffer whose usable region starts on an [`ALIGN`] boundary
/// (required by `O_DIRECT`, harmless otherwise), with a usable capacity
/// rounded up to a multiple of [`ALIGN`]. The backing allocation is
/// never grown, so the alignment computed at construction stays valid.
pub(crate) struct AlignedBuf {
    raw: Vec<u8>,
    start: usize,
    len: usize,
    cap: usize,
}

impl AlignedBuf {
    /// Allocate with at least `want` usable bytes (rounded up to a
    /// multiple of [`ALIGN`]).
    pub(crate) fn with_capacity(want: usize) -> AlignedBuf {
        let cap = want.max(ALIGN).div_ceil(ALIGN) * ALIGN;
        let raw = vec![0u8; cap + ALIGN];
        let start = {
            let addr = raw.as_ptr() as usize;
            (ALIGN - addr % ALIGN) % ALIGN
        };
        AlignedBuf { raw, start, len: 0, cap }
    }

    /// Live bytes.
    pub(crate) fn len(&self) -> usize {
        self.len
    }

    /// Usable capacity (a multiple of [`ALIGN`]).
    pub(crate) fn capacity(&self) -> usize {
        self.cap
    }

    /// Drop the live bytes (capacity is retained for reuse).
    pub(crate) fn clear(&mut self) {
        self.len = 0;
    }

    /// The live region.
    pub(crate) fn filled(&self) -> &[u8] {
        &self.raw[self.start..self.start + self.len]
    }

    /// Append up to the remaining capacity; returns the bytes copied.
    pub(crate) fn extend(&mut self, data: &[u8]) -> usize {
        let n = data.len().min(self.cap - self.len);
        let at = self.start + self.len;
        self.raw[at..at + n].copy_from_slice(&data[..n]);
        self.len += n;
        n
    }

    /// Zero-fill to the next multiple of `align`; returns the pad bytes
    /// appended (0 when already aligned or empty).
    pub(crate) fn pad_to(&mut self, align: usize) -> usize {
        let pad = (align - self.len % align) % align;
        let at = self.start + self.len;
        self.raw[at..at + pad].fill(0);
        self.len += pad;
        pad
    }

    /// Mutable scratch space for positioned reads: the first
    /// `len.min(capacity)` usable bytes. Pair with [`set_len`].
    ///
    /// [`set_len`]: AlignedBuf::set_len
    pub(crate) fn space(&mut self, len: usize) -> &mut [u8] {
        let len = len.min(self.cap);
        &mut self.raw[self.start..self.start + len]
    }

    /// Declare `n` live bytes (after a read filled [`space`]).
    ///
    /// [`space`]: AlignedBuf::space
    pub(crate) fn set_len(&mut self, n: usize) {
        debug_assert!(n <= self.cap);
        self.len = n;
    }
}

/// Completion handle for one submitted op; [`wait`] blocks until the
/// worker finishes and yields the op's result (recycling the buffer).
///
/// [`wait`]: Completion::wait
pub(crate) struct Completion<T> {
    rx: Receiver<io::Result<T>>,
}

impl<T> Completion<T> {
    pub(crate) fn wait(self) -> io::Result<T> {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(io::Error::other("io pool worker dropped a submission")),
        }
    }
}

enum IoOp {
    Write {
        file: PFile,
        off: u64,
        buf: AlignedBuf,
        done: SyncSender<io::Result<AlignedBuf>>,
    },
    Read {
        file: PFile,
        off: u64,
        len: usize,
        buf: AlignedBuf,
        done: SyncSender<io::Result<(AlignedBuf, usize)>>,
    },
}

/// The submission-queue backend: a fixed pool of workers draining one
/// queue of positioned ops. Submitters get [`Completion`] handles;
/// dropping the pool closes the queue and joins the workers.
pub(crate) struct IoPool {
    tx: Mutex<Option<Sender<IoOp>>>,
    workers: Vec<JoinHandle<()>>,
    depth: Arc<AtomicUsize>,
}

impl IoPool {
    pub(crate) fn new(workers: usize) -> IoPool {
        let (tx, rx) = std::sync::mpsc::channel::<IoOp>();
        let rx = Arc::new(Mutex::new(rx));
        let depth = Arc::new(AtomicUsize::new(0));
        let handles = (0..workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let depth = Arc::clone(&depth);
                std::thread::spawn(move || worker_loop(&rx, &depth))
            })
            .collect();
        IoPool { tx: Mutex::new(Some(tx)), workers: handles, depth }
    }

    fn submit(&self, op: IoOp) {
        let tx = self
            .tx
            .lock()
            .unwrap()
            .as_ref()
            .expect("io pool already shut down")
            .clone();
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        obs::metrics::gauge_set(obs::G_IO_QUEUE, d as f64);
        tx.send(op).expect("io pool workers alive");
    }

    /// Submit a positioned write of the buffer's live bytes.
    pub(crate) fn submit_write(
        &self,
        file: PFile,
        off: u64,
        buf: AlignedBuf,
    ) -> Completion<AlignedBuf> {
        let (done, rx) = std::sync::mpsc::sync_channel(1);
        self.submit(IoOp::Write { file, off, buf, done });
        Completion { rx }
    }

    /// Submit a positioned read of up to `len` bytes into the buffer.
    pub(crate) fn submit_read(
        &self,
        file: PFile,
        off: u64,
        len: usize,
        buf: AlignedBuf,
    ) -> Completion<(AlignedBuf, usize)> {
        let (done, rx) = std::sync::mpsc::sync_channel(1);
        self.submit(IoOp::Read { file, off, len, buf, done });
        Completion { rx }
    }
}

impl Drop for IoPool {
    fn drop(&mut self) {
        drop(self.tx.lock().unwrap().take());
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<IoOp>>, depth: &AtomicUsize) {
    loop {
        // The guard is held only while blocked in recv; it drops as soon
        // as an op is dequeued, so other workers keep draining.
        let op = match rx.lock().unwrap().recv() {
            Ok(op) => op,
            Err(_) => break,
        };
        let d = depth.fetch_sub(1, Ordering::Relaxed).saturating_sub(1);
        obs::metrics::gauge_set(obs::G_IO_QUEUE, d as f64);
        match op {
            IoOp::Write { file, off, buf, done } => {
                obs::metrics::counter_add(obs::C_IO_WRITES, 1);
                let res = {
                    let _s = obs::trace::span_n(obs::S_SPILL_IO, 0, buf.len() as u64);
                    file.write_all_at(buf.filled(), off)
                };
                let _ = done.send(res.map(|()| buf));
            }
            IoOp::Read { file, off, len, mut buf, done } => {
                obs::metrics::counter_add(obs::C_IO_READS, 1);
                let res = {
                    let mut s = obs::trace::span(obs::S_SPILL_IO);
                    match file.read_some_at(buf.space(len), off) {
                        Ok(n) => {
                            s.set_bytes(n as u64);
                            buf.set_len(n);
                            Ok((buf, n))
                        }
                        Err(e) => Err(e),
                    }
                };
                let _ = done.send(res);
            }
        }
    }
}

#[cfg(unix)]
fn open_direct(path: &Path) -> io::Result<File> {
    use std::os::unix::fs::OpenOptionsExt;
    let f = OpenOptions::new()
        .write(true)
        .create(true)
        .truncate(true)
        .custom_flags(O_DIRECT)
        .open(path)?;
    // Probe: some filesystems accept the flag at open but refuse the
    // first direct write (and tmpfs refuses at open on some kernels).
    // One aligned block of zeros at offset 0 settles it; real data
    // overwrites the probe and the truncate below drops it meanwhile.
    let mut probe = AlignedBuf::with_capacity(ALIGN);
    probe.set_len(ALIGN);
    PFile::new(f.try_clone()?).write_all_at(probe.filled(), 0)?;
    f.set_len(0)?;
    Ok(f)
}

#[cfg(not(unix))]
fn open_direct(_path: &Path) -> io::Result<File> {
    Err(io::Error::new(io::ErrorKind::Unsupported, "O_DIRECT requires a unix platform"))
}

/// Sequential append writer over either backend, with optional
/// `O_DIRECT`.
///
/// Bytes accumulate in an aligned buffer of `target` capacity; full
/// buffers are dispatched as positioned writes at monotonically
/// increasing offsets (inline on the sync backend, submitted on the
/// pool backend with bounded in-flight depth and buffer recycling).
/// In direct mode only whole [`ALIGN`] multiples leave the sink until
/// [`seal`] zero-pads the tail; the caller records the returned pad in
/// the spill header. [`patch`] rewrites small header fields after seal
/// (through a plain descriptor when the data fd is direct).
///
/// [`seal`]: SpillSink::seal
/// [`patch`]: SpillSink::patch
pub(crate) struct SpillSink {
    path: PathBuf,
    file: PFile,
    pool: Option<Arc<IoPool>>,
    buf: AlignedBuf,
    spare: Vec<AlignedBuf>,
    inflight: VecDeque<Completion<AlignedBuf>>,
    base: u64,
    appended: u64,
    disk: u64,
    target: usize,
    direct: bool,
    sealed: bool,
}

impl SpillSink {
    /// Create (or truncate) `path` for sequential writing from offset 0.
    /// Direct mode is attempted only when both the context asks for it
    /// and the call site allows it (spill-dir files only — never final
    /// outputs, whose bytes must not carry a pad).
    pub(crate) fn create(
        path: &Path,
        target: usize,
        io: &IoCtx,
        allow_direct: bool,
    ) -> io::Result<SpillSink> {
        let (file, direct) = if allow_direct && io.direct() {
            match open_direct(path) {
                Ok(f) => (f, true),
                Err(_) => {
                    obs::metrics::counter_add(obs::C_IO_DIRECT_FALLBACK, 1);
                    (plain_create(path)?, false)
                }
            }
        } else {
            (plain_create(path)?, false)
        };
        Ok(SpillSink::from_file(path, file, 0, target, io.pool().cloned(), direct))
    }

    /// Open an existing (presized) file for sequential writing starting
    /// at `offset` — the sharded merge's disjoint output ranges. Interior
    /// offsets are unaligned, so direct mode never applies here.
    pub(crate) fn append_at(
        path: &Path,
        offset: u64,
        target: usize,
        io: &IoCtx,
    ) -> io::Result<SpillSink> {
        let file = OpenOptions::new().write(true).open(path)?;
        Ok(SpillSink::from_file(path, file, offset, target, io.pool().cloned(), false))
    }

    fn from_file(
        path: &Path,
        file: File,
        base: u64,
        target: usize,
        pool: Option<Arc<IoPool>>,
        direct: bool,
    ) -> SpillSink {
        let target = target.max(ALIGN);
        SpillSink {
            path: path.to_path_buf(),
            file: PFile::new(file),
            pool,
            buf: AlignedBuf::with_capacity(target),
            spare: Vec::new(),
            inflight: VecDeque::new(),
            base,
            appended: 0,
            disk: 0,
            target,
            direct,
            sealed: false,
        }
    }

    /// Logical bytes appended so far (pads excluded).
    pub(crate) fn position(&self) -> u64 {
        self.appended
    }

    /// True when the file descriptor is in `O_DIRECT` mode.
    pub(crate) fn is_direct(&self) -> bool {
        self.direct
    }

    /// Append `data` after everything written so far.
    pub(crate) fn write_all(&mut self, mut data: &[u8]) -> io::Result<()> {
        debug_assert!(!self.sealed, "write after seal");
        self.appended += data.len() as u64;
        while !data.is_empty() {
            let n = self.buf.extend(data);
            data = &data[n..];
            if self.buf.len() == self.buf.capacity() {
                self.flush_buf()?;
            }
        }
        Ok(())
    }

    /// Dispatch the accumulation buffer. In direct mode only whole
    /// [`ALIGN`] multiples leave; the tail moves into the next buffer.
    fn flush_buf(&mut self) -> io::Result<()> {
        let len = self.buf.len();
        let keep = if self.direct { len % ALIGN } else { 0 };
        let send = len - keep;
        if send == 0 {
            return Ok(());
        }
        let mut next = self.take_spare();
        if keep > 0 {
            next.extend(&self.buf.filled()[send..]);
        }
        let mut full = std::mem::replace(&mut self.buf, next);
        full.set_len(send);
        let off = self.base + self.disk;
        self.disk += send as u64;
        self.dispatch(full, off)
    }

    fn take_spare(&mut self) -> AlignedBuf {
        match self.spare.pop() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => AlignedBuf::with_capacity(self.target),
        }
    }

    fn dispatch(&mut self, buf: AlignedBuf, off: u64) -> io::Result<()> {
        match &self.pool {
            None => {
                obs::metrics::counter_add(obs::C_IO_WRITES, 1);
                let _s = obs::trace::span_n(obs::S_SPILL_IO, 0, buf.len() as u64);
                self.file.write_all_at(buf.filled(), off)?;
                self.spare.push(buf);
                Ok(())
            }
            Some(pool) => {
                self.inflight.push_back(pool.submit_write(self.file.clone(), off, buf));
                if self.inflight.len() > MAX_INFLIGHT {
                    let done = self.inflight.pop_front().unwrap();
                    self.spare.push(done.wait()?);
                }
                Ok(())
            }
        }
    }

    /// Flush everything and wait for all in-flight writes. In direct
    /// mode the tail is zero-padded to [`ALIGN`] first; the pad length
    /// is returned so the caller can record it in the spill header
    /// (0 on buffered files).
    pub(crate) fn seal(&mut self) -> io::Result<u32> {
        debug_assert!(!self.sealed, "seal called twice");
        let mut pad = 0u32;
        if self.direct {
            self.flush_buf()?;
            pad = self.buf.pad_to(ALIGN) as u32;
        }
        if self.buf.len() > 0 {
            let off = self.base + self.disk;
            self.disk += self.buf.len() as u64;
            let buf = std::mem::replace(&mut self.buf, AlignedBuf::with_capacity(ALIGN));
            self.dispatch(buf, off)?;
        }
        while let Some(c) = self.inflight.pop_front() {
            self.spare.push(c.wait()?);
        }
        self.sealed = true;
        Ok(pad)
    }

    /// Positioned rewrite of a small already-written region (header
    /// count/pad patching) — only valid after [`seal`]. A direct-mode
    /// sink reopens the file with a plain descriptor, since `O_DIRECT`
    /// would reject the unaligned write.
    ///
    /// [`seal`]: SpillSink::seal
    pub(crate) fn patch(&mut self, off: u64, data: &[u8]) -> io::Result<()> {
        debug_assert!(self.sealed, "patch before seal");
        if self.direct {
            let f = OpenOptions::new().write(true).open(&self.path)?;
            PFile::new(f).write_all_at(data, off)
        } else {
            self.file.write_all_at(data, off)
        }
    }
}

fn plain_create(path: &Path) -> io::Result<File> {
    OpenOptions::new().write(true).create(true).truncate(true).open(path)
}

/// Minimal read surface the v2 block decoder needs from a spill source:
/// `Read` plus a relative seek (block skips).
pub(crate) trait SpillRead: Read {
    /// Move the logical read position by `delta` bytes.
    fn seek_relative(&mut self, delta: i64) -> io::Result<()>;
}

impl SpillRead for std::io::BufReader<File> {
    fn seek_relative(&mut self, delta: i64) -> io::Result<()> {
        std::io::BufReader::seek_relative(self, delta)
    }
}

/// Pool-backed sequential reader with one-buffer read-ahead: while the
/// caller consumes the current buffer, the next chunk is already
/// submitted. Seeks inside the buffered window are free; seeks outside
/// it drop the window and refill lazily at the target.
pub(crate) struct PoolReader {
    file: PFile,
    pool: Arc<IoPool>,
    chunk: usize,
    cur: AlignedBuf,
    cur_off: usize,
    cur_file: u64,
    pending: Option<(u64, Completion<(AlignedBuf, usize)>)>,
    eof_at: Option<u64>,
    spare: Option<AlignedBuf>,
}

impl PoolReader {
    /// Wrap an open file; `chunk` is the per-submission read size.
    pub(crate) fn new(file: File, chunk: usize, pool: Arc<IoPool>) -> PoolReader {
        let chunk = chunk.max(ALIGN);
        PoolReader {
            file: PFile::new(file),
            pool,
            chunk,
            cur: AlignedBuf::with_capacity(chunk),
            cur_off: 0,
            cur_file: 0,
            pending: None,
            eof_at: None,
            spare: None,
        }
    }

    /// Position the next read at absolute file offset `off`.
    pub(crate) fn seek_to(&mut self, off: u64) {
        let window_end = self.cur_file + self.cur.len() as u64;
        if off >= self.cur_file && off <= window_end {
            self.cur_off = (off - self.cur_file) as usize;
            return;
        }
        self.pending = None;
        self.cur.clear();
        self.cur_off = 0;
        self.cur_file = off;
        self.eof_at = None;
    }

    fn take_buf(&mut self) -> AlignedBuf {
        match self.spare.take() {
            Some(mut b) => {
                b.clear();
                b
            }
            None => AlignedBuf::with_capacity(self.chunk),
        }
    }

    /// Bytes available at the read cursor after refilling (0 = EOF).
    fn fill(&mut self) -> io::Result<usize> {
        if self.cur_off < self.cur.len() {
            return Ok(self.cur.len() - self.cur_off);
        }
        let next_off = self.cur_file + self.cur.len() as u64;
        if let Some(end) = self.eof_at {
            if next_off >= end {
                return Ok(0);
            }
        }
        let (buf, n) = match self.pending.take() {
            Some((off, c)) if off == next_off => c.wait()?,
            stale => {
                drop(stale);
                let buf = self.take_buf();
                self.pool.submit_read(self.file.clone(), next_off, self.chunk, buf).wait()?
            }
        };
        let mut old = std::mem::replace(&mut self.cur, buf);
        old.clear();
        self.spare = Some(old);
        self.cur_file = next_off;
        self.cur_off = 0;
        if n < self.chunk {
            // read_some_at is short only at EOF
            self.eof_at = Some(next_off + n as u64);
        } else {
            let buf = self.take_buf();
            let ahead = next_off + n as u64;
            self.pending =
                Some((ahead, self.pool.submit_read(self.file.clone(), ahead, self.chunk, buf)));
        }
        Ok(n)
    }
}

impl Read for PoolReader {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        let avail = self.fill()?;
        if avail == 0 {
            return Ok(0);
        }
        let n = avail.min(out.len());
        out[..n].copy_from_slice(&self.cur.filled()[self.cur_off..self.cur_off + n]);
        self.cur_off += n;
        Ok(n)
    }
}

impl SpillRead for PoolReader {
    fn seek_relative(&mut self, delta: i64) -> io::Result<()> {
        let here = self.cur_file + self.cur_off as u64;
        let target = here.checked_add_signed(delta).ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidInput, "seek before start of file")
        })?;
        self.seek_to(target);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("aipso-io-{}-{}", name, std::process::id()))
    }

    #[test]
    fn backend_names_parse_and_roundtrip() {
        assert_eq!(IoBackendKind::parse("sync"), Some(IoBackendKind::Sync));
        assert_eq!(IoBackendKind::parse("pool"), Some(IoBackendKind::Pool));
        assert_eq!(IoBackendKind::parse("uring"), None);
        for b in [IoBackendKind::Sync, IoBackendKind::Pool] {
            assert_eq!(IoBackendKind::parse(b.name()), Some(b));
        }
    }

    #[test]
    fn aligned_buf_is_aligned_and_tracks_len() {
        let mut b = AlignedBuf::with_capacity(1000);
        assert_eq!(b.capacity() % ALIGN, 0);
        assert!(b.capacity() >= 1000);
        assert_eq!(b.filled().as_ptr() as usize % ALIGN, 0, "start is aligned");
        assert_eq!(b.extend(&[7u8; 10]), 10);
        assert_eq!(b.len(), 10);
        assert_eq!(b.filled(), &[7u8; 10]);
        let pad = b.pad_to(ALIGN);
        assert_eq!(pad, ALIGN - 10);
        assert_eq!(b.len() % ALIGN, 0);
        assert!(b.filled()[10..].iter().all(|&x| x == 0), "pad is zeros");
        b.clear();
        let huge = vec![1u8; b.capacity() + 5];
        assert_eq!(b.extend(&huge), b.capacity(), "extend clamps to capacity");
    }

    /// Deterministic pseudo-random payload (no RNG dependency needed).
    fn payload(n: usize) -> Vec<u8> {
        let mut v = Vec::with_capacity(n);
        let mut x = 0x9e3779b97f4a7c15u64;
        while v.len() < n {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            v.extend_from_slice(&x.to_le_bytes());
        }
        v.truncate(n);
        v
    }

    fn write_through(path: &std::path::Path, io: &IoCtx, direct: bool, data: &[u8]) -> u32 {
        let mut sink = SpillSink::create(path, 1 << 14, io, direct).unwrap();
        // uneven write sizes exercise buffer boundaries
        let mut rest = data;
        let mut step = 1;
        while !rest.is_empty() {
            let n = step.min(rest.len());
            sink.write_all(&rest[..n]).unwrap();
            rest = &rest[n..];
            step = step * 3 % 7001 + 1;
        }
        assert_eq!(sink.position(), data.len() as u64);
        let pad = sink.seal().unwrap();
        sink.patch(0, &data[..8.min(data.len())]).unwrap();
        pad
    }

    #[test]
    fn sync_and_pool_sinks_write_identical_bytes() {
        let data = payload(150_000);
        let a = tmp("sink-sync.bin");
        let b = tmp("sink-pool.bin");
        write_through(&a, &IoCtx::sync(), false, &data);
        {
            let pool = IoCtx::new(IoBackendKind::Pool, false);
            write_through(&b, &pool, false, &data);
        }
        let got_a = std::fs::read(&a).unwrap();
        let got_b = std::fs::read(&b).unwrap();
        assert_eq!(got_a, got_b, "backends must be byte-identical");
        assert_eq!(got_a.len(), data.len());
        assert_eq!(&got_a[8..], &data[8..]);
        let _ = std::fs::remove_file(&a);
        let _ = std::fs::remove_file(&b);
    }

    #[test]
    fn direct_mode_or_fallback_produces_the_same_payload() {
        // Whether the filesystem grants O_DIRECT (disk-backed /tmp) or
        // refuses it (tmpfs), the payload bytes must match; only a
        // trailing zero pad may differ, and it is exactly what seal
        // reported.
        let data = payload(10_000);
        let p = tmp("sink-direct.bin");
        let io = IoCtx::new(IoBackendKind::Sync, true);
        let pad = write_through(&p, &io, true, &data);
        let got = std::fs::read(&p).unwrap();
        assert_eq!(got.len(), data.len() + pad as usize);
        assert_eq!(&got[8..data.len()], &data[8..]);
        assert!(got[data.len()..].iter().all(|&x| x == 0), "pad is zeros");
        if pad > 0 {
            assert_eq!((data.len() + pad as usize) % ALIGN, 0);
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn append_at_writes_disjoint_interior_ranges() {
        let p = tmp("sink-append.bin");
        let f = std::fs::File::create(&p).unwrap();
        f.set_len(300).unwrap();
        drop(f);
        let io = IoCtx::new(IoBackendKind::Pool, false);
        let mut hi = SpillSink::append_at(&p, 200, 1 << 12, &io).unwrap();
        let mut lo = SpillSink::append_at(&p, 100, 1 << 12, &io).unwrap();
        hi.write_all(&[2u8; 100]).unwrap();
        lo.write_all(&[1u8; 100]).unwrap();
        assert_eq!(hi.seal().unwrap(), 0);
        assert_eq!(lo.seal().unwrap(), 0);
        drop((lo, hi, io));
        let got = std::fs::read(&p).unwrap();
        assert_eq!(&got[..100], &[0u8; 100][..]);
        assert_eq!(&got[100..200], &[1u8; 100][..]);
        assert_eq!(&got[200..], &[2u8; 100][..]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn pool_reader_streams_and_seeks() {
        let data = payload(70_000);
        let p = tmp("pool-read.bin");
        std::fs::write(&p, &data).unwrap();
        let pool = Arc::new(IoPool::new(2));
        let mut r = PoolReader::new(File::open(&p).unwrap(), 8192, Arc::clone(&pool));
        let mut got = Vec::new();
        r.read_to_end(&mut got).unwrap();
        assert_eq!(got, data, "sequential read matches");

        // absolute seek back, then relative skips both ways
        r.seek_to(1000);
        let mut four = [0u8; 4];
        r.read_exact(&mut four).unwrap();
        assert_eq!(four, data[1000..1004]);
        r.seek_relative(9996).unwrap();
        r.read_exact(&mut four).unwrap();
        assert_eq!(four, data[11000..11004]);
        r.seek_relative(-10_000).unwrap();
        r.read_exact(&mut four).unwrap();
        assert_eq!(four, data[1004..1008]);
        drop(r);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn pool_reader_hits_eof_cleanly_past_the_end() {
        let p = tmp("pool-eof.bin");
        std::fs::write(&p, payload(100)).unwrap();
        let pool = Arc::new(IoPool::new(1));
        let mut r = PoolReader::new(File::open(&p).unwrap(), 4096, pool);
        let mut buf = Vec::new();
        assert_eq!(r.read_to_end(&mut buf).unwrap(), 100);
        assert_eq!(r.read(&mut [0u8; 8]).unwrap(), 0, "EOF is sticky");
        r.seek_to(1_000_000);
        assert_eq!(r.read(&mut [0u8; 8]).unwrap(), 0, "seek past end reads 0");
        let _ = std::fs::remove_file(&p);
    }
}
