//! RMI-partitioned parallel merge — the replay side of the paper's
//! parallelization story.
//!
//! The serial loser tree consumes runs one key at a time on one thread.
//! But the run-generation phase already trained CDF models of the stream —
//! the shared first-chunk RMI plus one replacement per retrain-on-drift
//! epoch — and a monotone CDF can be inverted: cut `[0,1)` into `p`
//! equal-probability slices, map each cut back to a boundary key, and
//! binary-search every sorted run for the boundary offsets
//! ([`RunIndex::lower_bound`] — on delta-compressed v2 runs the search
//! runs over the block directory's restart keys and decodes exactly one
//! candidate block per cut). The result is `p` *range-disjoint* merge
//! problems — shard `s` of every run holds exactly the keys in
//! `[bound_{s-1}, bound_s)` — which merge independently on the scheduler
//! pool and land in disjoint byte ranges of the output file, concatenating
//! into the fully sorted result with no extra pass. The seek-written
//! output is therefore always a *raw* pre-sized file, whatever codec the
//! source runs spilled through; the shard range readers dispatch their
//! codec per file, so raw and delta runs mix freely in one plan.
//!
//! After a regime change no single epoch's model describes the whole
//! stream, so the cuts come from the **learned-keys-weighted mixture** of
//! all epoch models ([`crate::rmi::quality::quantile_key_weighted`]):
//! each model is weighted by the keys its epoch actually sorted on the
//! learned path (fallback chunks drifted from their epoch's model and are
//! excluded, optionally age-decayed — `ExternalConfig::epoch_age_decay`),
//! making the mixture the stream's estimated global CDF.
//! The boundary offsets are still binary-searched *per run against the
//! file's actual keys*, which is why runs spilled before a retrain index
//! exactly under cuts derived from models installed after them.
//!
//! Correctness never depends on the models: any nondecreasing boundary set
//! yields an exact sort (the cuts are enforced nondecreasing, and
//! lower-bound semantics keep duplicate keys on one side of every cut).
//! Model *quality* only shows up as shard balance, so the driver applies a
//! drift guard: when [`ShardPlan::skew`] exceeds
//! `ExternalConfig::shard_skew_limit`, the data no longer matches the
//! epoch models and the merge falls back to the serial loser tree.
//!
//! The same plan/merge machinery serves two call sites: the **final pass**
//! ([`merge_sharded`] over all surviving runs into the output file) and
//! the **intermediate passes** (the driver shards each merge *group* when
//! it has threads to spare — see `external::merge_pass`). Each shard
//! writes its disjoint output range through a
//! [`SpillSink`](crate::external::io::SpillSink) positioned at the
//! shard's byte offset: on the pool backend full buffers drain on the IO
//! workers while the merge loop keeps comparing (what a per-shard
//! flusher thread used to do by hand), and on the sync backend they are
//! issued inline as positioned writes.

use std::io;
use std::path::Path;
use std::sync::Mutex;

use crate::external::config::ExternalConfig;
use crate::external::io::{IoCtx, SpillSink};
use crate::external::loser_tree::{open_merge_sources, LoserTree, MergeSource};
use crate::external::spill::{self, BlockDirectory, RunFile, RunIndex, SpillHeader, HEADER_LEN};
use crate::key::SortKey;
use crate::rmi::model::Rmi;
use crate::rmi::quality;
use crate::scheduler::run_task_pool;

/// Precomputed sharding of a set of sorted runs: boundary cuts in
/// ordered-bits space plus, per run, the key offsets of every shard.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Nondecreasing shard cuts in ordered-bits space (`p - 1` entries).
    bounds: Vec<u64>,
    /// `offsets[r][s]` = first key index of shard `s` inside run `r`
    /// (`p + 1` entries per run; `offsets[r][p]` = run length).
    offsets: Vec<Vec<u64>>,
    /// Total keys per shard across all runs.
    shard_keys: Vec<u64>,
    /// Per run, the v2 block directory the planner's [`RunIndex`] built
    /// while locating cut offsets (`None` for raw runs). The merge's
    /// range-opens reuse it so each shard seeks straight to its first
    /// block instead of re-walking every block header before it.
    dirs: Vec<Option<BlockDirectory>>,
    /// Per run, the spill header the planner decoded (`None` only for
    /// headerless v0 files). The merge's range-opens reuse it so each
    /// shard skips the per-source header re-read.
    headers: Vec<Option<SpillHeader>>,
}

impl ShardPlan {
    /// Number of shards `p`.
    pub fn shards(&self) -> usize {
        self.shard_keys.len()
    }

    /// The shard cuts in ordered-bits space (`p - 1` nondecreasing
    /// values; shard `s` holds keys in `[bounds[s-1], bounds[s])`).
    pub fn bounds(&self) -> &[u64] {
        &self.bounds
    }

    /// Total keys per shard across all runs.
    pub fn shard_keys(&self) -> &[u64] {
        &self.shard_keys
    }

    /// Total keys across all shards.
    pub fn total_keys(&self) -> u64 {
        self.shard_keys.iter().sum()
    }

    /// Key offset of each shard inside the merged output (prefix sums of
    /// the shard sizes; `p + 1` entries, the last being the total).
    pub fn out_key_offsets(&self) -> Vec<u64> {
        let mut offs = Vec::with_capacity(self.shards() + 1);
        let mut acc = 0u64;
        offs.push(0);
        for &keys in &self.shard_keys {
            acc += keys;
            offs.push(acc);
        }
        offs
    }

    /// The per-run block directories the planner collected (`None` for
    /// raw runs), indexed like the `runs` slice the plan was built over.
    pub fn directories(&self) -> &[Option<BlockDirectory>] {
        &self.dirs
    }

    /// Load imbalance: largest shard relative to the ideal `total / p`.
    /// `1.0` is perfect balance; the driver falls back to the serial merge
    /// above `ExternalConfig::shard_skew_limit`.
    pub fn skew(&self) -> f64 {
        let total = self.total_keys();
        if total == 0 {
            return 1.0;
        }
        let ideal = total as f64 / self.shards() as f64;
        let max = self.shard_keys.iter().copied().max().unwrap_or(0);
        max as f64 / ideal.max(1.0)
    }
}

/// Build a `p`-shard plan for `runs` by inverting the keys-weighted
/// mixture of the epoch `models` (pairs of model and cut weight — the
/// keys generated under that model's epoch) at the quantiles
/// `1/p .. (p-1)/p` and binary-searching every run for the resulting
/// boundary keys. A single `(model, 1.0)` entry reproduces the
/// pre-retrain single-model cuts. Costs `O(p · models · log n)` predicts
/// plus `O(runs · p · log n)` positioned reads — negligible next to the
/// merge.
///
/// `empirical` is the fallback chunks' mixture component: a sorted sample
/// of their keys' ordered bits plus the fallback key count (see
/// [`quality::quantile_key_mixture`]). Fallback chunks have no epoch
/// model, so without this component their mass is invisible to the cuts —
/// a drift-heavy stream would shard on whatever the *learned* minority
/// looked like. `None` (or an empty sample) reproduces the models-only
/// cuts exactly.
pub fn plan_shards<K: SortKey>(
    models: &[(&Rmi, f64)],
    empirical: Option<(&[u64], f64)>,
    runs: &[RunFile],
    p: usize,
) -> io::Result<ShardPlan> {
    let p = p.max(1);
    let mut bounds = Vec::with_capacity(p.saturating_sub(1));
    for i in 1..p {
        let q = i as f64 / p as f64;
        let key: K = quality::quantile_key_mixture(models, empirical, q);
        bounds.push(key.to_bits_ordered());
    }
    // The monotone model makes these nondecreasing already; enforce it so
    // correctness cannot hinge on the model (cf. module docs).
    for i in 1..bounds.len() {
        if bounds[i] < bounds[i - 1] {
            bounds[i] = bounds[i - 1];
        }
    }

    let mut offsets = Vec::with_capacity(runs.len());
    let mut dirs = Vec::with_capacity(runs.len());
    let mut headers = Vec::with_capacity(runs.len());
    for run in runs {
        let mut idx = RunIndex::<K>::open(&run.path)?;
        let mut offs = Vec::with_capacity(p + 1);
        offs.push(0u64);
        for &b in &bounds {
            offs.push(idx.lower_bound(b)?);
        }
        offs.push(run.n);
        // lower bounds of nondecreasing cuts are nondecreasing; clamp all
        // the same so a corrupt run cannot produce negative ranges
        for i in 1..offs.len() {
            if offs[i] < offs[i - 1] {
                offs[i] = offs[i - 1];
            }
        }
        offsets.push(offs);
        // keep the index's header and block directory for the merge's
        // range-opens
        headers.push(idx.header());
        dirs.push(idx.into_directory());
    }

    let mut shard_keys = vec![0u64; p];
    for offs in &offsets {
        for (s, keys) in shard_keys.iter_mut().enumerate() {
            *keys += offs[s + 1] - offs[s];
        }
    }
    let plan = ShardPlan {
        bounds,
        offsets,
        shard_keys,
        dirs,
        headers,
    };
    crate::obs::metrics::observe(
        crate::obs::M_SHARD_SKEW,
        crate::obs::metrics::SKEW_BUCKETS,
        plan.skew(),
    );
    Ok(plan)
}

/// Merge all runs into `output` by running one loser tree per shard on the
/// scheduler pool; every shard seek-writes its own disjoint byte range of
/// the pre-sized output file, so shard order never serializes the work.
/// Returns the total key count written.
pub fn merge_sharded<K: SortKey>(
    runs: &[RunFile],
    plan: &ShardPlan,
    output: &Path,
    cfg: &ExternalConfig,
    threads: usize,
    io: &IoCtx,
) -> io::Result<u64> {
    let p = plan.shards();
    let total = plan.total_keys();
    // Header + pre-sized payload so every shard can open + seek
    // independently (and the count is correct from the start).
    spill::create_presized::<K>(output, total)?;
    let out_key_off = plan.out_key_offsets();
    // Up to `threads` shards in flight, each with `runs.len()` readers and
    // one output sink: scale the per-stream buffer so the whole merge
    // stays within one io-buffer budget per worker.
    let buf = (cfg.effective_io_buffer() / threads.max(1)).max(4096);

    let first_err: Mutex<Option<io::Error>> = Mutex::new(None);
    let tasks: Vec<usize> = (0..p).filter(|&s| plan.shard_keys[s] > 0).collect();
    run_task_pool(threads, tasks, |s, _spawner| {
        if first_err.lock().unwrap().is_some() {
            return; // a shard already failed; drain the queue cheaply
        }
        if let Err(e) = merge_one_shard::<K>(runs, plan, s, out_key_off[s], output, buf, io) {
            let mut slot = first_err.lock().unwrap();
            if slot.is_none() {
                *slot = Some(e);
            }
        }
    });
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    Ok(total)
}

/// Merge shard `s` of every run into the output range starting at key
/// offset `out_key_off` (an index into the payload; the header offset is
/// added here). The output goes through a [`SpillSink`] positioned at
/// the shard's byte offset: the sink buffers full blocks and, on the
/// pool backend, submits them to the IO workers so disk time overlaps
/// the comparison work — replacing the hand-rolled per-shard flusher
/// thread. Sources are opened through [`open_merge_sources`], which
/// reuses the plan's cached headers and block directories.
pub(crate) fn merge_one_shard<K: SortKey>(
    runs: &[RunFile],
    plan: &ShardPlan,
    s: usize,
    out_key_off: u64,
    output: &Path,
    io_buffer: usize,
    io: &IoCtx,
) -> io::Result<()> {
    // scoped span over the whole shard merge (keys + output bytes)
    let _span = crate::obs::trace::span_n(
        crate::obs::S_SHARD_MERGE,
        plan.shard_keys[s],
        plan.shard_keys[s] * K::WIDTH as u64,
    );
    let specs: Vec<MergeSource<'_>> = runs
        .iter()
        .zip(&plan.offsets)
        .zip(&plan.dirs)
        .zip(&plan.headers)
        .map(|(((run, offs), dir), header)| MergeSource {
            path: &run.path,
            start: offs[s],
            len: offs[s + 1] - offs[s],
            dir: dir.as_ref(),
            header: header.as_ref(),
        })
        .collect();
    let mut tree = LoserTree::new(open_merge_sources::<K>(&specs, io_buffer, io)?)?;
    let byte_off = HEADER_LEN as u64 + out_key_off * K::WIDTH as u64;
    // Interior offsets are unaligned and the bytes are final output, so
    // direct mode never applies here (append_at enforces that).
    let mut sink = SpillSink::append_at(output, byte_off, io_buffer.max(4096), io)?;
    let mut pushed = 0u64;
    while let Some(k) = tree.next()? {
        sink.write_all(k.to_le_bytes().as_ref())?;
        pushed += 1;
    }
    let pad = sink.seal()?;
    debug_assert_eq!(pad, 0);
    debug_assert_eq!(pushed, plan.shard_keys[s]);
    debug_assert_eq!(sink.position(), plan.shard_keys[s] * K::WIDTH as u64);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::external::spill::{read_keys_file, write_keys_file, RunReader};
    use crate::rmi::model::RmiConfig;
    use crate::util::rng::Xoshiro256pp;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("aipso-shard-{}-{name}", std::process::id()))
    }

    fn uniform_rmi(rng: &mut Xoshiro256pp) -> Rmi {
        let mut sample: Vec<f64> = (0..8192).map(|_| rng.uniform(0.0, 1e6)).collect();
        sample.sort_unstable_by(f64::total_cmp);
        Rmi::train(&sample, RmiConfig { n_leaves: 128 })
    }

    fn spill_sorted(name: &str, mut keys: Vec<f64>) -> RunFile {
        keys.sort_unstable_by(f64::total_cmp);
        write_keys_file(&tmp(name), &keys).unwrap()
    }

    fn cleanup(runs: &[RunFile], out: &std::path::Path) {
        for r in runs {
            let _ = std::fs::remove_file(&r.path);
        }
        let _ = std::fs::remove_file(out);
    }

    #[test]
    fn sharded_merge_matches_flat_sort() {
        let mut rng = Xoshiro256pp::new(0x5AAD);
        let rmi = uniform_rmi(&mut rng);
        let mut all: Vec<f64> = Vec::new();
        let mut runs = Vec::new();
        for i in 0..5 {
            let keys: Vec<f64> = (0..4000).map(|_| rng.uniform(0.0, 1e6)).collect();
            all.extend_from_slice(&keys);
            runs.push(spill_sorted(&format!("flat-{i}"), keys));
        }
        let plan = plan_shards::<f64>(&[(&rmi, 1.0)], None, &runs, 4).unwrap();
        assert_eq!(plan.shards(), 4);
        assert_eq!(plan.total_keys(), all.len() as u64);
        // in-distribution data: the model's cuts are close to balanced
        assert!(plan.skew() < 2.0, "skew={}", plan.skew());

        let out = tmp("flat-out.bin");
        let n = merge_sharded::<f64>(&runs, &plan, &out, &ExternalConfig::default(), 4, &IoCtx::sync()).unwrap();
        assert_eq!(n, all.len() as u64);
        all.sort_unstable_by(f64::total_cmp);
        let got = read_keys_file::<f64>(&out).unwrap();
        let gb: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u64> = all.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb);
        cleanup(&runs, &out);
    }

    #[test]
    fn duplicate_heavy_keys_collapse_into_one_shard() {
        // Every key identical: lower-bound cuts put the whole population on
        // one side of every boundary, so exactly one shard holds all keys —
        // maximal skew, but the merge output is still exact.
        let mut rng = Xoshiro256pp::new(0xD0B5);
        let rmi = uniform_rmi(&mut rng);
        let runs = vec![
            spill_sorted("dup-0", vec![5e5; 3000]),
            spill_sorted("dup-1", vec![5e5; 2000]),
        ];
        let plan = plan_shards::<f64>(&[(&rmi, 1.0)], None, &runs, 4).unwrap();
        let non_empty: Vec<&u64> = plan.shard_keys().iter().filter(|&&k| k > 0).collect();
        assert_eq!(non_empty, vec![&5000u64], "all duplicates in one shard");
        assert!(plan.skew() > 3.9, "skew={}", plan.skew());

        let out = tmp("dup-out.bin");
        let n = merge_sharded::<f64>(&runs, &plan, &out, &ExternalConfig::default(), 4, &IoCtx::sync()).unwrap();
        assert_eq!(n, 5000);
        let got = read_keys_file::<f64>(&out).unwrap();
        assert_eq!(got.len(), 5000);
        assert!(got.iter().all(|&x| x == 5e5));
        cleanup(&runs, &out);
    }

    #[test]
    fn runs_with_empty_shard_ranges_merge_exactly() {
        // Run A lives entirely in the bottom quarter, run B in the top: for
        // most shards one (or both) runs contribute an empty range.
        let mut rng = Xoshiro256pp::new(0xE3B1);
        let rmi = uniform_rmi(&mut rng);
        let a: Vec<f64> = (0..2500).map(|_| rng.uniform(0.0, 2.4e5)).collect();
        let b: Vec<f64> = (0..2500).map(|_| rng.uniform(7.6e5, 1e6)).collect();
        let mut all = a.clone();
        all.extend_from_slice(&b);
        let runs = vec![spill_sorted("empty-a", a), spill_sorted("empty-b", b)];
        let plan = plan_shards::<f64>(&[(&rmi, 1.0)], None, &runs, 4).unwrap();
        // the two middle quantile shards see (almost) nothing
        assert_eq!(plan.total_keys(), 5000);

        let out = tmp("empty-out.bin");
        let n = merge_sharded::<f64>(&runs, &plan, &out, &ExternalConfig::default(), 4, &IoCtx::sync()).unwrap();
        assert_eq!(n, 5000);
        all.sort_unstable_by(f64::total_cmp);
        let got = read_keys_file::<f64>(&out).unwrap();
        let gb: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u64> = all.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb);
        cleanup(&runs, &out);
    }

    #[test]
    fn epoch_mixture_cuts_rebalance_a_regime_change() {
        // Two regimes on disjoint ranges — runs A in U(0, 1e5), runs B in
        // U(9e5, 1e6) — modeled by one RMI each (what retrain-on-drift
        // produces). Cuts from the first epoch's model alone collapse the
        // whole second regime into the top shard; the keys-weighted
        // mixture restores balance without touching correctness.
        let mut rng = Xoshiro256pp::new(0x417E);
        let train = |lo: f64, hi: f64, rng: &mut Xoshiro256pp| {
            let mut s: Vec<f64> = (0..8192).map(|_| rng.uniform(lo, hi)).collect();
            s.sort_unstable_by(f64::total_cmp);
            Rmi::train(&s, crate::rmi::model::RmiConfig { n_leaves: 128 })
        };
        let model_a = train(0.0, 1e5, &mut rng);
        let model_b = train(9e5, 1e6, &mut rng);
        let a: Vec<f64> = (0..4000).map(|_| rng.uniform(0.0, 1e5)).collect();
        let b: Vec<f64> = (0..4000).map(|_| rng.uniform(9e5, 1e6)).collect();
        let mut all = a.clone();
        all.extend_from_slice(&b);
        let runs = vec![spill_sorted("mix-a", a), spill_sorted("mix-b", b)];

        let stale = plan_shards::<f64>(&[(&model_a, 1.0)], None, &runs, 4).unwrap();
        assert!(
            stale.skew() > 1.9,
            "first-epoch cuts must leave the shifted regime lopsided (skew={})",
            stale.skew()
        );
        let mixed =
            plan_shards::<f64>(&[(&model_a, 4000.0), (&model_b, 4000.0)], None, &runs, 4).unwrap();
        assert!(
            mixed.skew() < 1.5,
            "mixture cuts must rebalance the shards (skew={})",
            mixed.skew()
        );

        let out = tmp("mix-out.bin");
        let n = merge_sharded::<f64>(&runs, &mixed, &out, &ExternalConfig::default(), 4, &IoCtx::sync()).unwrap();
        assert_eq!(n, 8000);
        all.sort_unstable_by(f64::total_cmp);
        let got = read_keys_file::<f64>(&out).unwrap();
        let gb: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u64> = all.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb);
        cleanup(&runs, &out);
    }

    #[test]
    fn empty_runs_in_the_plan_merge_exactly() {
        // Zero-key runs can reach the planner (degenerate chunk layouts);
        // their offsets must be all-zero and the merge must skip them.
        let mut rng = Xoshiro256pp::new(0xE317);
        let rmi = uniform_rmi(&mut rng);
        let keys: Vec<f64> = (0..3000).map(|_| rng.uniform(0.0, 1e6)).collect();
        let runs = vec![
            spill_sorted("er-0", Vec::new()),
            spill_sorted("er-1", keys.clone()),
            spill_sorted("er-2", Vec::new()),
        ];
        let plan = plan_shards::<f64>(&[(&rmi, 1.0)], None, &runs, 4).unwrap();
        assert_eq!(plan.total_keys(), 3000);
        let out = tmp("er-out.bin");
        let n = merge_sharded::<f64>(&runs, &plan, &out, &ExternalConfig::default(), 2, &IoCtx::sync()).unwrap();
        assert_eq!(n, 3000);
        let mut want = keys;
        want.sort_unstable_by(f64::total_cmp);
        let got = read_keys_file::<f64>(&out).unwrap();
        let gb: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u64> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb);
        cleanup(&runs, &out);
    }

    #[test]
    fn single_shard_plan_equals_serial_merge() {
        // p = 1: no cuts, one merge task — byte-identical to the serial
        // loser tree over the same runs.
        let mut rng = Xoshiro256pp::new(0x0121);
        let rmi = uniform_rmi(&mut rng);
        let mut runs = Vec::new();
        let mut all: Vec<f64> = Vec::new();
        for i in 0..3 {
            let keys: Vec<f64> = (0..1500).map(|_| rng.uniform(0.0, 1e6)).collect();
            all.extend_from_slice(&keys);
            runs.push(spill_sorted(&format!("p1-{i}"), keys));
        }
        let plan = plan_shards::<f64>(&[(&rmi, 1.0)], None, &runs, 1).unwrap();
        assert_eq!(plan.shards(), 1);
        assert!((plan.skew() - 1.0).abs() < 1e-12);

        let sharded_out = tmp("p1-sharded.bin");
        merge_sharded::<f64>(&runs, &plan, &sharded_out, &ExternalConfig::default(), 2, &IoCtx::sync()).unwrap();

        // serial reference: one loser tree over full-range readers
        let serial_out = tmp("p1-serial.bin");
        {
            let sources: Vec<RunReader<f64>> = runs
                .iter()
                .map(|r| RunReader::open(&r.path, 1 << 16).unwrap())
                .collect();
            let mut tree = LoserTree::new(sources).unwrap();
            let mut w = crate::external::spill::RunWriter::<f64>::create(
                serial_out.clone(),
                1 << 16,
            )
            .unwrap();
            while let Some(k) = tree.next().unwrap() {
                w.push(k).unwrap();
            }
            w.finish().unwrap();
        }
        assert_eq!(
            std::fs::read(&sharded_out).unwrap(),
            std::fs::read(&serial_out).unwrap(),
            "p=1 sharded merge must be byte-identical to the serial merge"
        );
        cleanup(&runs, &sharded_out);
        let _ = std::fs::remove_file(&serial_out);
    }

    #[test]
    fn delta_coded_runs_plan_and_merge_identically_to_raw() {
        // The same runs spilled through both codecs must produce the same
        // plan (cut offsets found via the v2 restart-point search) and a
        // byte-identical sharded merge output.
        use crate::external::spill::{RunWriter, SpillCodec};
        let mut rng = Xoshiro256pp::new(0xDE17A);
        let rmi = uniform_rmi(&mut rng);
        let mut raw_runs = Vec::new();
        let mut delta_runs = Vec::new();
        for i in 0..4 {
            let mut keys: Vec<f64> = (0..6000).map(|_| rng.uniform(0.0, 1e6)).collect();
            // dup plateaus so the run-length escape is exercised in-plan
            for j in 0..keys.len() / 4 {
                keys[4 * j + 1] = keys[4 * j];
            }
            keys.sort_unstable_by(f64::total_cmp);
            raw_runs.push(write_keys_file(&tmp(&format!("codec-raw-{i}")), &keys).unwrap());
            let mut w = RunWriter::<f64>::create_with(
                tmp(&format!("codec-delta-{i}")),
                1 << 14,
                SpillCodec::Delta,
            )
            .unwrap();
            w.write_slice(&keys).unwrap();
            delta_runs.push(w.finish().unwrap());
        }
        let models = [(&rmi, 1.0)];
        let raw_plan = plan_shards::<f64>(&models, None, &raw_runs, 4).unwrap();
        let delta_plan = plan_shards::<f64>(&models, None, &delta_runs, 4).unwrap();
        // the planner keeps every v2 run's block directory for the merge;
        // raw runs have none (their range seeks are already O(1))
        assert!(raw_plan.directories().iter().all(Option::is_none));
        assert!(delta_plan.directories().iter().all(Option::is_some));
        assert_eq!(raw_plan.bounds(), delta_plan.bounds());
        assert_eq!(raw_plan.shard_keys(), delta_plan.shard_keys());
        assert_eq!(raw_plan.offsets, delta_plan.offsets, "identical cut offsets");

        let raw_out = tmp("codec-raw-out.bin");
        let delta_out = tmp("codec-delta-out.bin");
        let cfg = ExternalConfig::default();
        let a = merge_sharded::<f64>(&raw_runs, &raw_plan, &raw_out, &cfg, 3, &IoCtx::sync()).unwrap();
        let b = merge_sharded::<f64>(&delta_runs, &delta_plan, &delta_out, &cfg, 3, &IoCtx::sync()).unwrap();
        assert_eq!(a, b);
        assert_eq!(
            std::fs::read(&raw_out).unwrap(),
            std::fs::read(&delta_out).unwrap(),
            "sharded merge over delta runs must be byte-identical to raw"
        );
        cleanup(&raw_runs, &raw_out);
        cleanup(&delta_runs, &delta_out);
    }

    #[test]
    fn faithful_weights_beat_stale_fallback_inflated_weights() {
        // Regression for the mixture-weight bugfix. Two modeled regimes —
        // A on U(0, 1e5), B on U(9e5, 1e6) — plus a fallback run whose
        // keys landed back in A's range *after* the retrain budget was
        // spent (epoch B's fallback chunks). The old weighting credited
        // those 8000 fallback keys to model B, overweighting the top of
        // the range; weighting each model by its *learned* keys only
        // (4000:4000) tracks the data better and plans flatter shards.
        let mut rng = Xoshiro256pp::new(0xFA17);
        let train = |lo: f64, hi: f64, rng: &mut Xoshiro256pp| {
            let mut s: Vec<f64> = (0..8192).map(|_| rng.uniform(lo, hi)).collect();
            s.sort_unstable_by(f64::total_cmp);
            Rmi::train(&s, crate::rmi::model::RmiConfig { n_leaves: 128 })
        };
        let model_a = train(0.0, 1e5, &mut rng);
        let model_b = train(9e5, 1e6, &mut rng);
        let a: Vec<f64> = (0..4000).map(|_| rng.uniform(0.0, 1e5)).collect();
        let b: Vec<f64> = (0..4000).map(|_| rng.uniform(9e5, 1e6)).collect();
        let tail: Vec<f64> = (0..8000).map(|_| rng.uniform(0.0, 1e5)).collect();
        let runs = vec![
            spill_sorted("fw-a", a),
            spill_sorted("fw-b", b),
            spill_sorted("fw-tail", tail),
        ];
        // stale: epoch B inflated by the 8000 fallback keys it never sorted
        let stale =
            plan_shards::<f64>(&[(&model_a, 4000.0), (&model_b, 12_000.0)], None, &runs, 4).unwrap();
        // faithful: learned keys only
        let faithful =
            plan_shards::<f64>(&[(&model_a, 4000.0), (&model_b, 4000.0)], None, &runs, 4).unwrap();
        assert!(
            faithful.skew() < stale.skew(),
            "learned-keys weights must plan flatter shards (faithful {} !< stale {})",
            faithful.skew(),
            stale.skew()
        );
        // and the stale plan really was lopsided: its bottom shard holds
        // at least the whole low regime
        assert!(stale.skew() > 2.5, "stale skew {}", stale.skew());
        let out = tmp("fw-out.bin");
        let cfg = ExternalConfig::default();
        let n = merge_sharded::<f64>(&runs, &faithful, &out, &cfg, 4, &IoCtx::sync()).unwrap();
        assert_eq!(n, 16_000);
        cleanup(&runs, &out);
    }

    #[test]
    fn empirical_component_rebalances_a_fallback_heavy_plan() {
        // The only trained model saw the low regime; two thirds of the
        // stream are *fallback* keys in a disjoint high regime (drifted
        // chunks sorted by IPS⁴o, so no epoch model describes them).
        // Models-only cuts squeeze the whole high regime into the top
        // shard; folding a sample of the fallback keys in as an
        // empirical-CDF component restores balance. Correctness is
        // unconditional either way — the offsets always come from the
        // runs' actual keys.
        let mut rng = Xoshiro256pp::new(0xFBC7);
        let mut sample: Vec<f64> = (0..8192).map(|_| rng.uniform(0.0, 1e5)).collect();
        sample.sort_unstable_by(f64::total_cmp);
        let low = Rmi::train(&sample, RmiConfig { n_leaves: 128 });
        let learned: Vec<f64> = (0..4000).map(|_| rng.uniform(0.0, 1e5)).collect();
        let fb_a: Vec<f64> = (0..4000).map(|_| rng.uniform(9e5, 1e6)).collect();
        let fb_b: Vec<f64> = (0..4000).map(|_| rng.uniform(9e5, 1e6)).collect();
        let mut all = learned.clone();
        all.extend_from_slice(&fb_a);
        all.extend_from_slice(&fb_b);
        // what run generation's fallback reservoir would hold: a sample of
        // the fallback keys' ordered bits, sorted
        let mut fb_bits: Vec<u64> = fb_a
            .iter()
            .chain(&fb_b)
            .step_by(8)
            .map(|k| k.to_bits_ordered())
            .collect();
        fb_bits.sort_unstable();
        let runs = vec![
            spill_sorted("fbc-l", learned),
            spill_sorted("fbc-a", fb_a),
            spill_sorted("fbc-b", fb_b),
        ];
        let blind = plan_shards::<f64>(&[(&low, 4000.0)], None, &runs, 4).unwrap();
        assert!(
            blind.skew() > 2.5,
            "models-only cuts must leave the fallback regime lopsided (skew={})",
            blind.skew()
        );
        let seen =
            plan_shards::<f64>(&[(&low, 4000.0)], Some((&fb_bits, 8000.0)), &runs, 4).unwrap();
        assert!(
            seen.skew() < 1.8,
            "empirical component must rebalance the shards (skew={})",
            seen.skew()
        );
        let out = tmp("fbc-out.bin");
        let n = merge_sharded::<f64>(&runs, &seen, &out, &ExternalConfig::default(), 4, &IoCtx::sync()).unwrap();
        assert_eq!(n, 12_000);
        all.sort_unstable_by(f64::total_cmp);
        let got = read_keys_file::<f64>(&out).unwrap();
        let gb: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u64> = all.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb);
        cleanup(&runs, &out);
    }

    #[test]
    fn boundary_duplicates_never_straddle_a_cut() {
        // A value sitting exactly on a quantile cut: lower-bound semantics
        // must put every copy in the shard that starts at the cut.
        let mut rng = Xoshiro256pp::new(0xB0B);
        let rmi = uniform_rmi(&mut rng);
        let cut: f64 = quality::quantile_key(&rmi, 0.5);
        let mut keys = vec![cut; 100];
        keys.extend((0..400).map(|_| rng.uniform(0.0, 1e6)));
        let runs = vec![spill_sorted("cut-0", keys.clone())];
        let plan = plan_shards::<f64>(&[(&rmi, 1.0)], None, &runs, 2).unwrap();
        let out = tmp("cut-out.bin");
        let n = merge_sharded::<f64>(&runs, &plan, &out, &ExternalConfig::default(), 2, &IoCtx::sync()).unwrap();
        assert_eq!(n, 500);
        keys.sort_unstable_by(f64::total_cmp);
        let got = read_keys_file::<f64>(&out).unwrap();
        let gb: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u64> = keys.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb);
        cleanup(&runs, &out);
    }
}
