//! Run generation — the learned half of the external sorter.
//!
//! Classical external sorts (and IPS⁴o used out-of-core) sample and build
//! a fresh partitioning model for every chunk. Following PCF Learned Sort's
//! observation that the learned-CDF machinery amortizes when a model is
//! reused across partitions, we train **one** monotonic RMI on a sample of
//! the *first* chunk and reuse it to partition every subsequent chunk:
//!
//! 1. first chunk: draw a sample; if it is duplicate-heavy (Algorithm 5's
//!    guard) skip the model entirely, else train the shared RMI;
//! 2. every chunk: score the shared model with [`quality::model_drift`]
//!    against a fresh probe — if the stream's distribution drifted, fall
//!    back to IPS⁴o ([`crate::sample_sort`]) for that chunk; once the
//!    probe fails for [`RetrainPolicy::retrain_after`] consecutive chunks
//!    (a regime change, not an outlier burst), resample the offending
//!    chunk, train a **fresh** RMI on it and install it as the shared
//!    model for subsequent chunks — each successful install opens a new
//!    model *epoch* (bounded by `max_retrains`);
//! 3. learned path: partition the chunk in place with the shared
//!    [`RmiClassifier`] (the same block framework every engine uses), then
//!    sort each bucket with sequential AIPS²o tasks on the pool;
//! 4. write the sorted chunk as one spilled run — through the configured
//!    spill codec (`ExternalConfig::spill_codec`; the delta codec
//!    compresses the sorted run as varint blocks) — tagged with the epoch
//!    of the model that was current when it was generated (the merge
//!    weights its quantile cuts by each epoch's *learned* keys; see
//!    [`crate::external::shard`]).
//!
//! With `threads > 1` the three per-chunk stages run as an **overlapped
//! pipeline** on rendezvous channels: a reader thread fills chunk `N+1`
//! while the caller's thread sorts chunk `N` on the scheduler pool and a
//! background writer spills chunk `N−1`. At most three chunks are resident
//! (one per stage), so each holds a third of the memory budget
//! ([`ExternalConfig::pipelined_chunk_keys`]); `threads == 1` keeps the
//! strictly serial read → sort → write loop as the reference path.

use std::io;
use std::sync::mpsc;

use std::path::PathBuf;

use crate::classifier::rmi_classifier::RmiClassifier;
use crate::classifier::Classifier;
use crate::external::config::{ExternalConfig, RetrainPolicy, RunGen};
use crate::external::io::IoCtx;
use crate::external::spill::{RunFile, RunWriter, SpillCodec, SpillDir, HEADER_LEN};
use crate::key::SortKey;
use crate::obs;
use crate::rmi::model::{Rmi, RmiConfig};
use crate::rmi::quality;
use crate::scheduler::run_task_pool;
use crate::util::rng::Xoshiro256pp;

/// Per-epoch chunk counters. Epoch 0 spans the first installed model —
/// trained on the first chunk, or recovered mid-stream from a cold start
/// (its entry then also absorbs the model-less prefix; a fully model-free
/// stream is a single epoch-0 entry) — and each later install under
/// [`RetrainPolicy`] opens the next epoch. The
/// split shows *where* the learned path ran: after a regime change with
/// retraining enabled, the post-retrain epochs should be learned-dominated
/// while the tail of the previous epoch absorbed the drift fallbacks.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EpochStats {
    /// Chunks of this epoch sorted via the shared RMI partition.
    pub learned: usize,
    /// Chunks of this epoch sorted via the IPS⁴o fallback.
    pub fallback: usize,
    /// Keys across this epoch's chunks, learned and fallback alike.
    pub keys: u64,
    /// Keys of the chunks the epoch's model actually sorted — the merge's
    /// cut weight. Fallback chunks' keys are excluded: they demonstrably
    /// drifted from (or were never described by) this epoch's model, so
    /// counting them toward it would inflate a stale model's share of the
    /// mixture cuts (e.g. a vetoed zipf tail skewing the shard plan).
    pub learned_keys: u64,
}

/// Counters describing one run-generation pass.
#[derive(Debug, Clone, Default)]
pub struct RunGenStats {
    /// Chunks read (== runs written).
    pub chunks: usize,
    /// Chunks sorted via the shared RMI partition.
    pub learned_chunks: usize,
    /// Chunks sorted via the IPS⁴o fallback.
    pub fallback_chunks: usize,
    /// Whether the initial shared RMI was trained on the first chunk.
    pub rmi_trained: bool,
    /// Mid-stream installs: replacement models after drift, plus a first
    /// model recovered from a cold start (which reuses epoch 0 instead of
    /// opening a new entry).
    pub retrains: usize,
    /// Learned/fallback chunk counts per model epoch (always at least one
    /// entry once a chunk was processed).
    pub epochs: Vec<EpochStats>,
    /// Total keys across all runs.
    pub keys: u64,
}

/// Everything run generation hands to the merge phase.
pub(crate) struct GeneratedRuns {
    /// Sorted runs on disk, in generation order.
    pub runs: Vec<RunFile>,
    /// Pass counters for the report.
    pub stats: RunGenStats,
    /// The shared models in install order — `models[e]` served epoch `e`
    /// (empty when no model was ever trained). The sharded merge inverts
    /// their keys-weighted mixture to cut the key range into quantiles.
    pub models: Vec<Rmi>,
    /// Run ↔ model map: `run_epochs[i]` is the epoch `runs[i]` was
    /// generated under (parallel to `runs`). The merge's cut weights come
    /// from each epoch's *learned* keys ([`EpochStats::learned_keys`]);
    /// this map remains the per-run provenance record (and the
    /// consistency check between run generation and the driver).
    pub run_epochs: Vec<usize>,
    /// Sorted ordered-bits sample of the *fallback* chunks' keys (capped
    /// at [`FALLBACK_SAMPLE_CAP`]). Fallback chunks have no epoch model,
    /// so their mass would otherwise be invisible to the merge's mixture
    /// cuts; the shard planner folds this sample in as an empirical-CDF
    /// component weighted by the fallback key count
    /// ([`crate::rmi::quality::quantile_key_mixture`]). Empty when every
    /// chunk took the learned path.
    pub fallback_sample: Vec<u64>,
}

/// Keys sampled from each fallback chunk for the empirical mixture
/// component (a reservoir draw, so cost is O(chunk) scans it already pays).
const FALLBACK_SAMPLE_PER_CHUNK: usize = 1024;

/// Cap on the total fallback sample handed to the merge planner; above it
/// the sorted sample is thinned at an even stride, which preserves its
/// quantiles — all the planner reads from it.
pub(crate) const FALLBACK_SAMPLE_CAP: usize = 8192;

/// Pull chunks from `next_chunk`, sort each, and spill them as sorted
/// runs. `threads == 1` runs the serial reference loop; more threads run
/// the overlapped read/sort/write pipeline.
pub(crate) fn generate_runs<K: SortKey, F>(
    next_chunk: F,
    spill: &mut SpillDir,
    cfg: &ExternalConfig,
    io: &IoCtx,
) -> io::Result<GeneratedRuns>
where
    F: FnMut(usize) -> io::Result<Option<Vec<K>>> + Send,
{
    let threads = crate::scheduler::effective_threads(cfg.threads);
    if threads <= 1 {
        generate_runs_serial(next_chunk, spill, cfg, io)
    } else {
        generate_runs_pipelined(next_chunk, spill, cfg, io, threads)
    }
}

/// The serial reference pipeline: read → sort → write, one chunk resident.
fn generate_runs_serial<K: SortKey, F>(
    mut next_chunk: F,
    spill: &mut SpillDir,
    cfg: &ExternalConfig,
    io: &IoCtx,
) -> io::Result<GeneratedRuns>
where
    F: FnMut(usize) -> io::Result<Option<Vec<K>>>,
{
    let chunk_keys = cfg.chunk_keys::<K>();
    let mut sorter = ChunkSorter::new(cfg, 1, chunk_keys);
    let mut runs = Vec::new();
    loop {
        let mut read_span = obs::trace::span(obs::S_CHUNK_READ);
        let Some(mut chunk) = next_chunk(chunk_keys)? else {
            break;
        };
        read_span.set_keys(chunk.len() as u64);
        read_span.set_bytes((chunk.len() * K::WIDTH) as u64);
        drop(read_span);
        if chunk.is_empty() {
            continue;
        }
        sorter.sort_chunk(&mut chunk);
        runs.push(spill_run(
            &chunk,
            spill.next_run_path(),
            cfg.effective_io_buffer(),
            cfg.spill_codec,
            io,
        )?);
    }
    Ok(sorter.finish(runs))
}

/// Spill one sorted chunk as a run, recording the spill-write span and the
/// per-run byte histograms (encoded = actual on-disk size in the run's
/// codec; raw = what the same run costs uncompressed — the pair is the
/// codec's measured compression ratio).
fn spill_run<K: SortKey>(
    chunk: &[K],
    path: PathBuf,
    io_buffer: usize,
    codec: SpillCodec,
    io: &IoCtx,
) -> io::Result<RunFile> {
    let mut span = obs::trace::span(obs::S_SPILL_WRITE);
    // Spilled runs go through the configured backend, write a block
    // side-car (delta codec), and are the one place direct mode applies:
    // they live in the spill dirs and are read back only by our own
    // pad-aware readers.
    let mut w = RunWriter::<K>::create_io(path, io_buffer, codec, io, true, true)?;
    w.write_slice(chunk)?;
    let run = w.finish()?;
    span.set_keys(run.n);
    span.set_bytes(run.bytes);
    obs::metrics::counter_add(obs::C_SPILL_RUNS, 1);
    obs::metrics::observe(
        obs::M_SPILL_BYTES_ENCODED,
        obs::metrics::BYTES_BUCKETS,
        run.bytes as f64,
    );
    obs::metrics::observe(
        obs::M_SPILL_BYTES_RAW,
        obs::metrics::BYTES_BUCKETS,
        (HEADER_LEN as u64 + run.n * K::WIDTH as u64) as f64,
    );
    Ok(run)
}

/// The overlapped pipeline: a reader thread prefetches chunk `N+1` while
/// the caller's thread sorts chunk `N` on the pool, and chunk `N−1` is
/// spilled concurrently — by a dedicated writer thread on the sync
/// backend, or by the submission queue itself on the pool backend (the
/// sink's bounded in-flight writes already overlap encode with disk
/// time, so a writer thread would only add a resident chunk). Rendezvous
/// (zero-capacity) channels give backpressure with exactly one resident
/// chunk per stage.
fn generate_runs_pipelined<K: SortKey, F>(
    next_chunk: F,
    spill: &mut SpillDir,
    cfg: &ExternalConfig,
    io: &IoCtx,
    threads: usize,
) -> io::Result<GeneratedRuns>
where
    F: FnMut(usize) -> io::Result<Option<Vec<K>>> + Send,
{
    let chunk_keys = cfg.pipelined_chunk_keys::<K>();
    let io_buffer = cfg.effective_io_buffer();
    let codec = cfg.spill_codec;
    let mut sorter = ChunkSorter::new(cfg, threads, chunk_keys);
    let mut source_err: Option<io::Error> = None;

    let runs = std::thread::scope(|scope| -> io::Result<Vec<RunFile>> {
        let (chunk_tx, chunk_rx) = mpsc::sync_channel::<io::Result<Vec<K>>>(0);

        // Reader: pulls raw chunks off the source. A failed send means the
        // sorter hung up (a downstream error); just stop.
        let mut source = next_chunk;
        let reader = scope.spawn(move || loop {
            let mut read_span = obs::trace::span(obs::S_CHUNK_READ);
            match source(chunk_keys) {
                Ok(Some(chunk)) => {
                    read_span.set_keys(chunk.len() as u64);
                    read_span.set_bytes((chunk.len() * K::WIDTH) as u64);
                    drop(read_span);
                    if chunk.is_empty() {
                        continue;
                    }
                    if chunk_tx.send(Ok(chunk)).is_err() {
                        return;
                    }
                }
                Ok(None) => return, // EOF — dropping chunk_tx closes the stage
                Err(e) => {
                    let _ = chunk_tx.send(Err(e));
                    return;
                }
            }
        });

        let write_result = if io.pool().is_some() {
            // Pool backend: spill inline after the sort — the sink's
            // submissions drain on the IO workers while the next chunk
            // sorts.
            let mut runs = Vec::new();
            let mut failed: Option<io::Error> = None;
            loop {
                let msg = match chunk_rx.recv() {
                    Ok(m) => m,
                    Err(_) => break,
                };
                let mut chunk = match msg {
                    Ok(c) => c,
                    Err(e) => {
                        source_err = Some(e);
                        break;
                    }
                };
                sorter.sort_chunk(&mut chunk);
                match spill_run(&chunk, spill.next_run_path(), io_buffer, codec, io) {
                    Ok(r) => runs.push(r),
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            drop(chunk_rx); // unblock a reader mid-send so it can exit
            match failed {
                Some(e) => Err(e),
                None => Ok(runs),
            }
        } else {
            let (sorted_tx, sorted_rx) = mpsc::sync_channel::<Vec<K>>(0);

            // Writer: spills sorted chunks in arrival order. An IO error
            // ends the loop; dropping sorted_rx then unblocks the
            // sorter's send.
            let spill = &mut *spill;
            let writer = scope.spawn(move || -> io::Result<Vec<RunFile>> {
                let mut runs = Vec::new();
                for chunk in sorted_rx.iter() {
                    runs.push(spill_run(&chunk, spill.next_run_path(), io_buffer, codec, io)?);
                }
                Ok(runs)
            });

            // Sorter: this thread — model training and the pool-parallel
            // sort.
            loop {
                let msg = match chunk_rx.recv() {
                    Ok(m) => m,
                    Err(_) => break, // reader done (EOF or after an error)
                };
                let mut chunk = match msg {
                    Ok(c) => c,
                    Err(e) => {
                        source_err = Some(e);
                        break;
                    }
                };
                sorter.sort_chunk(&mut chunk);
                if sorted_tx.send(chunk).is_err() {
                    break; // writer failed; its join below reports the cause
                }
            }
            drop(chunk_rx); // unblock a reader mid-send so it can exit
            drop(sorted_tx); // close the writer's queue
            match writer.join() {
                Ok(r) => r,
                Err(p) => std::panic::resume_unwind(p),
            }
        };
        if let Err(p) = reader.join() {
            std::panic::resume_unwind(p);
        }
        write_result
    })?;

    if let Some(e) = source_err {
        return Err(e);
    }
    Ok(sorter.finish(runs))
}

/// Per-chunk sorting state shared by the serial and pipelined paths: the
/// shared model, the drift/duplicate/retrain routing, and the counters.
struct ChunkSorter<'a> {
    cfg: &'a ExternalConfig,
    threads: usize,
    rng: Xoshiro256pp,
    shared: Option<RmiClassifier>,
    /// Installed models in epoch order (initial + retrains).
    models: Vec<Rmi>,
    /// Epoch of each generated run, in generation order.
    run_epochs: Vec<usize>,
    /// Consecutive chunks whose drift probe failed — the retrain trigger.
    drift_streak: usize,
    /// Ordered-bits reservoir over the fallback chunks' keys (the merge
    /// planner's empirical mixture component; sorted + thinned in
    /// [`ChunkSorter::finish`]).
    fallback_bits: Vec<u64>,
    first_chunk: bool,
    stats: RunGenStats,
}

impl<'a> ChunkSorter<'a> {
    fn new(cfg: &'a ExternalConfig, threads: usize, chunk_keys: usize) -> ChunkSorter<'a> {
        ChunkSorter {
            cfg,
            threads,
            rng: Xoshiro256pp::new(0xE87_5041 ^ chunk_keys as u64),
            shared: None,
            models: Vec::new(),
            run_epochs: Vec::new(),
            drift_streak: 0,
            fallback_bits: Vec::new(),
            first_chunk: true,
            stats: RunGenStats::default(),
        }
    }

    /// Sort one chunk in place: train the shared RMI on the first chunk,
    /// route drifted / duplicate-heavy chunks to the IPS⁴o path, and
    /// retrain the shared model when the drift streak clears the policy.
    fn sort_chunk<K: SortKey>(&mut self, chunk: &mut [K]) {
        let _span = obs::trace::span_n(
            obs::S_CHUNK_SORT,
            chunk.len() as u64,
            (chunk.len() * K::WIDTH) as u64,
        );
        self.stats.chunks += 1;
        self.stats.keys += chunk.len() as u64;

        if self.cfg.run_gen == RunGen::LearnedReuse && self.first_chunk {
            self.shared = train_shared_rmi(chunk, self.cfg, &mut self.rng);
            self.stats.rmi_trained = self.shared.is_some();
            if let Some(classifier) = &self.shared {
                self.models.push(classifier.rmi().clone());
            }
        }
        self.first_chunk = false;

        let learned = self.route_chunk(chunk);
        if learned {
            learned_sort_chunk(chunk, self.shared.as_ref().unwrap(), self.cfg, self.threads);
        } else {
            crate::sample_sort::sort_par(chunk, self.threads);
        }
        // both engines sort by ordered bits; prefix-tied string keys need
        // their equal-bits runs finished under the full key order before
        // the run spills (a no-op that compiles away for exact-bit keys)
        crate::key::repair_bit_ties(chunk);

        let epoch = self.models.len().saturating_sub(1);
        self.run_epochs.push(epoch);
        if self.stats.epochs.len() <= epoch {
            self.stats.epochs.resize(epoch + 1, EpochStats::default());
        }
        let e = &mut self.stats.epochs[epoch];
        e.keys += chunk.len() as u64;
        if learned {
            e.learned += 1;
            e.learned_keys += chunk.len() as u64;
            self.stats.learned_chunks += 1;
        } else {
            e.fallback += 1;
            self.stats.fallback_chunks += 1;
            // sample this fallback chunk's keys for the merge planner's
            // empirical mixture component (no epoch model describes them)
            let m = FALLBACK_SAMPLE_PER_CHUNK.min(chunk.len());
            let mut picked: Vec<K> = Vec::new();
            self.rng.reservoir_sample(chunk, m, &mut picked);
            self.fallback_bits
                .extend(picked.iter().map(|k| k.to_bits_ordered()));
        }
        debug_assert!(crate::is_sorted(chunk));
    }

    /// Decide the chunk's path — true selects the learned partition. This
    /// is where the rolling retrain lives: a drifted chunk extends the
    /// streak, and once the streak reaches `retrain_after` (with installs
    /// left under `max_retrains`) the chunk itself becomes the training
    /// set for a replacement model, recovering the learned path instead of
    /// demoting the rest of the stream to IPS⁴o.
    ///
    /// The same machinery covers the **cold start**: when the first chunk
    /// trained nothing (duplicate-heavy or tiny), there is no model for
    /// the drift probe to score — so every model-eligible chunk counts as
    /// trivially drifted and [`RetrainPolicy`] can install a *first* model
    /// mid-stream once a later regime turns tractable. Without this, a
    /// bad first chunk used to demote the whole stream to IPS⁴o forever.
    fn route_chunk<K: SortKey>(&mut self, chunk: &[K]) -> bool {
        if self.shared.is_none() {
            return self.route_cold_start(chunk);
        }
        let classifier = self.shared.as_ref().unwrap();
        if chunk.len() < self.cfg.min_learned_chunk {
            return false; // size guard — says nothing about drift
        }
        if !drifted(chunk, classifier.rmi(), self.cfg, &mut self.rng) {
            self.drift_streak = 0;
            return true;
        }
        self.drift_streak += 1;
        self.try_install_model(chunk)
    }

    /// Model-less routing: no shared RMI exists (the first chunk was
    /// duplicate-heavy or too small to train). Model-eligible chunks build
    /// the drift streak exactly as drifted chunks do, and the retrain
    /// policy may install a *first* model from one of them; until then
    /// every chunk takes the IPS⁴o path. The very first chunk never counts
    /// — its training attempt just failed in `sort_chunk`, and an
    /// immediate second draw from the same data would be wasted work.
    fn route_cold_start<K: SortKey>(&mut self, chunk: &[K]) -> bool {
        if self.cfg.run_gen != RunGen::LearnedReuse
            || chunk.len() < self.cfg.min_learned_chunk
            || self.stats.chunks <= 1
        {
            return false;
        }
        self.drift_streak += 1;
        self.try_install_model(chunk)
    }

    /// Shared tail of both retrain paths (drifted and cold-start): gate on
    /// the policy, streak and install budget, then try to fit a model from
    /// this chunk and install it as the shared classifier. Attempts —
    /// successful or vetoed by Algorithm 5's duplicate guard — reset the
    /// streak, so a persistently intractable stream must re-earn
    /// `retrain_after` chunks before the next attempt and can't
    /// retrain-and-fail on every chunk. Returns true when the chunk should
    /// take the learned path (the installed model was fit on it).
    fn try_install_model<K: SortKey>(&mut self, chunk: &[K]) -> bool {
        let policy: RetrainPolicy = self.cfg.retrain;
        if !policy.enabled()
            || self.drift_streak < policy.retrain_after
            || self.stats.retrains >= policy.max_retrains
        {
            return false;
        }
        self.drift_streak = 0;
        let mut span = obs::trace::span_n(obs::S_RETRAIN, chunk.len() as u64, 0);
        match train_shared_rmi(chunk, self.cfg, &mut self.rng) {
            Some(fresh) => {
                drop(span);
                self.models.push(fresh.rmi().clone());
                self.shared = Some(fresh);
                self.stats.retrains += 1;
                obs::metrics::counter_add(obs::C_RETRAINS, 1);
                true
            }
            None => {
                span.set_keys(0); // vetoed attempt: no keys re-modeled
                false
            }
        }
    }

    fn finish(mut self, runs: Vec<RunFile>) -> GeneratedRuns {
        debug_assert_eq!(runs.len(), self.run_epochs.len());
        self.fallback_bits.sort_unstable();
        if self.fallback_bits.len() > FALLBACK_SAMPLE_CAP {
            // even-stride thinning of a sorted sample preserves its
            // quantiles — all the shard planner reads from it
            let step = self.fallback_bits.len().div_ceil(FALLBACK_SAMPLE_CAP);
            self.fallback_bits = self.fallback_bits.into_iter().step_by(step).collect();
        }
        for e in &self.stats.epochs {
            if e.keys > 0 {
                obs::metrics::observe(
                    obs::M_EPOCH_LEARNED_RATIO,
                    obs::metrics::RATIO_BUCKETS,
                    e.learned_keys as f64 / e.keys as f64,
                );
            }
        }
        GeneratedRuns {
            runs,
            stats: self.stats,
            models: self.models,
            run_epochs: self.run_epochs,
            fallback_sample: self.fallback_bits,
        }
    }
}

/// Train the shared RMI from a sample of the first chunk; `None` when the
/// chunk is too small to amortize a model or the sample is duplicate-heavy
/// (every chunk then takes the IPS⁴o path, exactly Algorithm 5's routing).
fn train_shared_rmi<K: SortKey>(
    chunk: &[K],
    cfg: &ExternalConfig,
    rng: &mut Xoshiro256pp,
) -> Option<RmiClassifier> {
    if chunk.len() < cfg.min_learned_chunk {
        return None;
    }
    // Reservoir (without replacement): the sample is a large fraction of
    // one chunk, and index collisions from with-replacement draws would
    // masquerade as duplicates and falsely trip the guard below.
    let ssz = cfg.rmi_sample.min(chunk.len());
    let mut picked: Vec<K> = Vec::new();
    rng.reservoir_sample(chunk, ssz, &mut picked);
    let mut sample: Vec<f64> = picked.iter().map(|k| k.to_f64()).collect();
    sample.sort_unstable_by(f64::total_cmp);
    if crate::aips2o::strategy::duplicate_fraction(&sample) > cfg.max_dup_fraction {
        return None;
    }
    let rmi = Rmi::train(
        &sample,
        RmiConfig {
            n_leaves: cfg.rmi_leaves,
        },
    );
    // Fan-out scaled to the chunk so the per-thread block buffers
    // (buckets × block keys) stay a small fraction of the memory budget
    // and buckets land near the base-case size.
    let n_buckets = cfg
        .rmi_buckets
        .min((chunk.len() / (4 * cfg.block.max(1))).max(2).next_power_of_two());
    Some(RmiClassifier::new(rmi, n_buckets))
}

/// Probe the chunk and score the shared model; true when the stream's
/// distribution no longer matches what the model was trained on.
fn drifted<K: SortKey>(
    chunk: &[K],
    rmi: &Rmi,
    cfg: &ExternalConfig,
    rng: &mut Xoshiro256pp,
) -> bool {
    let m = cfg.drift_probe.min(chunk.len());
    if m == 0 {
        return false;
    }
    let mut probe: Vec<f64> = if chunk.len() <= 4 * m {
        // Near or below the probe size, with-replacement draws would
        // repeat and omit elements and bias the verdict; the reservoir
        // (without replacement) scores small chunks on their (near-)exact
        // empirical CDF, and costs only O(m) here.
        let mut picked: Vec<K> = Vec::new();
        rng.reservoir_sample(chunk, m, &mut picked);
        picked.iter().map(|k| k.to_f64()).collect()
    } else {
        // Large chunks: O(m) index draws keep the per-chunk probe off the
        // hot path's O(chunk) — the with-replacement collision bias is
        // ~m/(2·chunk) and vanishes exactly where this branch runs.
        (0..m)
            .map(|_| chunk[rng.next_below(chunk.len() as u64) as usize].to_f64())
            .collect()
    };
    probe.sort_unstable_by(f64::total_cmp);
    let err = quality::model_drift(rmi, &probe);
    obs::metrics::observe(obs::M_DRIFT_ERROR, obs::metrics::RATIO_BUCKETS, err);
    err > cfg.drift_threshold
}

/// Partition the chunk with the shared RMI through the LearnedSort 2.0
/// parallel fragmented partition, then sort the buckets as pool tasks
/// (the same pattern as `aips2o::sort_par`, with the top-level model
/// fixed instead of retrained). The runs stay byte-identical to the v1
/// block-partition path: every bucket is fully sorted before spilling,
/// so only the internal shuffle differs.
fn learned_sort_chunk<K: SortKey>(
    chunk: &mut [K],
    classifier: &RmiClassifier,
    cfg: &ExternalConfig,
    threads: usize,
) {
    // cooperative partition only pays off with enough keys per thread
    // (same guard as the in-memory engines; the fragmented partition
    // applies its own slots-per-worker fallback on top)
    let threads = if chunk.len() >= 4 * cfg.block * threads.max(1) {
        threads
    } else {
        1
    };
    let result = crate::learned_sort::partition2_par::fragmented_partition_par(
        chunk, classifier, cfg.block, threads,
    );
    let nb = Classifier::<K>::num_buckets(classifier);
    let base = chunk.as_mut_ptr() as usize;
    let mut tasks: Vec<(usize, usize)> = Vec::new();
    for b in 0..nb {
        let (lo, hi) = (result.boundaries[b], result.boundaries[b + 1]);
        if hi - lo > 1 {
            tasks.push((lo, hi - lo));
        }
    }
    run_task_pool(threads, tasks, move |(off, len), _spawner| {
        // SAFETY: partition boundaries produce disjoint ranges of `chunk`.
        let sub = unsafe { std::slice::from_raw_parts_mut((base as *mut K).add(off), len) };
        crate::aips2o::sort_seq(sub);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::external::spill::read_keys_file;
    use crate::is_sorted;

    fn gen_from_vec<K: SortKey>(
        keys: Vec<K>,
        cfg: &ExternalConfig,
    ) -> (Vec<RunFile>, RunGenStats, SpillDir) {
        let mut it = keys.into_iter();
        let src = move |max: usize| -> io::Result<Option<Vec<K>>> {
            let chunk: Vec<K> = it.by_ref().take(max).collect();
            Ok(if chunk.is_empty() { None } else { Some(chunk) })
        };
        let mut spill = SpillDir::create(None).unwrap();
        let gen = generate_runs(src, &mut spill, cfg, &IoCtx::sync()).unwrap();
        (gen.runs, gen.stats, spill)
    }

    #[test]
    fn runs_are_sorted_and_cover_input() {
        let mut rng = Xoshiro256pp::new(3);
        // threads=2 takes the overlapped pipeline, whose chunks are a third
        // of the budget: 3 * 16Ki keys of budget → 16Ki-key chunks, so all
        // 6 chunks clear min_learned_chunk
        let keys: Vec<f64> = (0..98_304).map(|_| rng.uniform(0.0, 1e6)).collect();
        let cfg = ExternalConfig {
            memory_budget: 3 * 16_384 * 8,
            threads: 2,
            ..ExternalConfig::default()
        };
        let (runs, stats, _spill) = gen_from_vec(keys.clone(), &cfg);
        assert_eq!(stats.chunks, runs.len());
        assert_eq!(stats.chunks, 6, "16Ki-key pipelined chunks expected");
        assert_eq!(stats.keys, keys.len() as u64);
        assert!(stats.rmi_trained, "smooth first chunk must train the RMI");
        assert_eq!(stats.learned_chunks, stats.chunks, "no drift expected");
        let mut total = 0u64;
        for r in &runs {
            let keys: Vec<f64> = read_keys_file(&r.path).unwrap();
            assert_eq!(keys.len() as u64, r.n);
            assert!(is_sorted(&keys));
            total += r.n;
        }
        assert_eq!(total, stats.keys);
    }

    #[test]
    fn serial_path_uses_full_budget_chunks() {
        let mut rng = Xoshiro256pp::new(6);
        let keys: Vec<f64> = (0..65_536).map(|_| rng.uniform(0.0, 1e6)).collect();
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            threads: 1,
            ..ExternalConfig::default()
        };
        let (runs, stats, _spill) = gen_from_vec(keys, &cfg);
        assert_eq!(stats.chunks, 4, "serial chunks hold the whole budget");
        assert_eq!(runs.len(), 4);
        assert_eq!(stats.learned_chunks, 4);
    }

    #[test]
    fn duplicate_heavy_first_chunk_skips_model() {
        let keys: Vec<u64> = (0..60_000).map(|i| i % 7).collect();
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            threads: 1,
            ..ExternalConfig::default()
        };
        let (_runs, stats, _spill) = gen_from_vec(keys, &cfg);
        assert!(!stats.rmi_trained);
        assert_eq!(stats.fallback_chunks, stats.chunks);
    }

    #[test]
    fn drifted_chunks_fall_back_when_retrain_disabled() {
        let mut rng = Xoshiro256pp::new(4);
        // chunk 1: U(0, 1e6); chunks 2-3: U(5e6, 6e6) — model predicts ~1
        // (threads=1 pins the serial chunk layout this scenario assumes)
        let mut keys: Vec<f64> = (0..16_384).map(|_| rng.uniform(0.0, 1e6)).collect();
        keys.extend((0..32_768).map(|_| rng.uniform(5e6, 6e6)));
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            threads: 1,
            retrain: RetrainPolicy::disabled(),
            ..ExternalConfig::default()
        };
        let (runs, stats, _spill) = gen_from_vec(keys, &cfg);
        assert!(stats.rmi_trained);
        assert_eq!(stats.learned_chunks, 1);
        assert_eq!(stats.fallback_chunks, 2);
        assert_eq!(stats.retrains, 0);
        assert_eq!(stats.epochs.len(), 1, "disabled policy never opens epochs");
        for r in &runs {
            assert!(is_sorted(&read_keys_file::<f64>(&r.path).unwrap()));
        }
    }

    #[test]
    fn fallback_chunks_feed_the_empirical_sample() {
        let mut rng = Xoshiro256pp::new(4);
        // chunk 1 trains the model; chunks 2-3 drift (retrain disabled) and
        // take the fallback path, so their keys must reach the sample
        let mut keys: Vec<f64> = (0..16_384).map(|_| rng.uniform(0.0, 1e6)).collect();
        keys.extend((0..32_768).map(|_| rng.uniform(5e6, 6e6)));
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            threads: 1,
            retrain: RetrainPolicy::disabled(),
            ..ExternalConfig::default()
        };
        let mut it = keys.into_iter();
        let src = move |max: usize| -> io::Result<Option<Vec<f64>>> {
            let chunk: Vec<f64> = it.by_ref().take(max).collect();
            Ok(if chunk.is_empty() { None } else { Some(chunk) })
        };
        let mut spill = SpillDir::create(None).unwrap();
        let gen = generate_runs(src, &mut spill, &cfg, &IoCtx::sync()).unwrap();
        assert_eq!(gen.stats.fallback_chunks, 2);
        let s = &gen.fallback_sample;
        assert_eq!(s.len(), 2 * 1024, "one reservoir draw per fallback chunk");
        assert!(s.len() <= FALLBACK_SAMPLE_CAP);
        assert!(s.windows(2).all(|w| w[0] <= w[1]), "sample must be sorted");
        let (lo, hi) = (5e6f64.to_bits_ordered(), 6e6f64.to_bits_ordered());
        assert!(
            s.iter().all(|&b| (lo..=hi).contains(&b)),
            "sample must come from the drifted regime only"
        );
        // an all-learned stream leaves the sample empty
        let mut rng = Xoshiro256pp::new(9);
        let smooth: Vec<f64> = (0..49_152).map(|_| rng.uniform(0.0, 1e6)).collect();
        let mut it = smooth.into_iter();
        let src = move |max: usize| -> io::Result<Option<Vec<f64>>> {
            let chunk: Vec<f64> = it.by_ref().take(max).collect();
            Ok(if chunk.is_empty() { None } else { Some(chunk) })
        };
        let mut spill = SpillDir::create(None).unwrap();
        let gen = generate_runs(src, &mut spill, &cfg, &IoCtx::sync()).unwrap();
        assert_eq!(gen.stats.fallback_chunks, 0);
        assert!(gen.fallback_sample.is_empty());
    }

    #[test]
    fn retrain_recovers_learned_path_after_regime_change() {
        let mut rng = Xoshiro256pp::new(4);
        // chunk 1: U(0, 1e6); chunks 2-4: U(5e6, 6e6). With
        // retrain_after=1 the first shifted chunk triggers a retrain, so
        // the whole shifted regime stays on the learned path.
        let mut keys: Vec<f64> = (0..16_384).map(|_| rng.uniform(0.0, 1e6)).collect();
        keys.extend((0..3 * 16_384).map(|_| rng.uniform(5e6, 6e6)));
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            threads: 1,
            retrain: RetrainPolicy { retrain_after: 1, max_retrains: 2 },
            ..ExternalConfig::default()
        };
        let mut it = keys.into_iter();
        let src = move |max: usize| -> io::Result<Option<Vec<f64>>> {
            let chunk: Vec<f64> = it.by_ref().take(max).collect();
            Ok(if chunk.is_empty() { None } else { Some(chunk) })
        };
        let mut spill = SpillDir::create(None).unwrap();
        let gen = generate_runs(src, &mut spill, &cfg, &IoCtx::sync()).unwrap();
        assert!(gen.stats.rmi_trained);
        assert_eq!(gen.stats.retrains, 1, "one regime change, one retrain");
        assert_eq!(gen.stats.learned_chunks, 4, "retrain keeps every chunk learned");
        assert_eq!(gen.stats.fallback_chunks, 0);
        assert_eq!(gen.models.len(), 2, "initial model + one replacement");
        assert_eq!(gen.run_epochs, vec![0, 1, 1, 1], "run↔epoch map");
        assert_eq!(gen.stats.epochs.len(), 2);
        assert_eq!(
            gen.stats.epochs[0],
            EpochStats { learned: 1, fallback: 0, keys: 16_384, learned_keys: 16_384 }
        );
        assert_eq!(
            gen.stats.epochs[1],
            EpochStats { learned: 3, fallback: 0, keys: 3 * 16_384, learned_keys: 3 * 16_384 }
        );
        for r in &gen.runs {
            assert!(is_sorted(&read_keys_file::<f64>(&r.path).unwrap()));
        }
    }

    #[test]
    fn retrain_streak_and_budget_are_honoured() {
        let mut rng = Xoshiro256pp::new(40);
        // Three regimes of 2 chunks each; retrain_after=2 retrains on the
        // *second* drifted chunk of a regime, and max_retrains=1 leaves
        // the last regime demoted even though its streak qualifies.
        let mut keys: Vec<f64> = (0..2 * 8192).map(|_| rng.uniform(0.0, 1e6)).collect();
        keys.extend((0..2 * 8192).map(|_| rng.uniform(5e6, 6e6)));
        keys.extend((0..2 * 8192).map(|_| rng.uniform(9e6, 10e6)));
        let cfg = ExternalConfig {
            memory_budget: 8192 * 8,
            threads: 1,
            retrain: RetrainPolicy { retrain_after: 2, max_retrains: 1 },
            ..ExternalConfig::default()
        };
        let (_runs, stats, _spill) = gen_from_vec(keys, &cfg);
        assert_eq!(stats.retrains, 1);
        // regime 1: 2 learned; regime 2: 1 fallback (streak=1) + retrain
        // on the 2nd chunk; regime 3: 1 fallback building the streak, then
        // the budget is spent → fallback.
        assert_eq!(stats.epochs.len(), 2);
        assert_eq!(
            stats.epochs[0],
            EpochStats { learned: 2, fallback: 1, keys: 3 * 8192, learned_keys: 2 * 8192 }
        );
        assert_eq!(
            stats.epochs[1],
            EpochStats { learned: 1, fallback: 2, keys: 3 * 8192, learned_keys: 8192 }
        );
    }

    #[test]
    fn retrain_attempt_on_duplicate_heavy_regime_keeps_old_model() {
        let mut rng = Xoshiro256pp::new(41);
        // smooth first regime, then a constant-valued (100% duplicate)
        // regime: the retrain attempt trips Algorithm 5's guard, installs
        // nothing, and does not burn the retrain budget.
        let mut keys: Vec<f64> = (0..16_384).map(|_| rng.uniform(0.0, 1e6)).collect();
        keys.resize(keys.len() + 2 * 16_384, 7e6);
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            threads: 1,
            retrain: RetrainPolicy { retrain_after: 1, max_retrains: 2 },
            ..ExternalConfig::default()
        };
        let (_runs, stats, _spill) = gen_from_vec(keys, &cfg);
        assert!(stats.rmi_trained);
        assert_eq!(stats.retrains, 0, "duplicate guard must block the install");
        assert_eq!(stats.learned_chunks, 1);
        assert_eq!(stats.fallback_chunks, 2);
        assert_eq!(stats.epochs.len(), 1, "no install → no new epoch");
    }

    #[test]
    fn vetoed_tail_keys_stay_out_of_the_epoch_cut_weight() {
        // Smooth regime trains the model, then a constant (100% dup) tail
        // drifts away and every retrain attempt is vetoed by Algorithm 5's
        // guard. The tail's keys land in epoch 0's `keys` but must NOT
        // count toward its `learned_keys` — the stale model never
        // described them, and weighting it by them used to inflate its
        // share of the merge's mixture cuts (the ROADMAP-named bug).
        let mut rng = Xoshiro256pp::new(0x7A11);
        let mut keys: Vec<f64> = (0..2 * 16_384).map(|_| rng.uniform(0.0, 1e6)).collect();
        keys.resize(keys.len() + 2 * 16_384, 7e6);
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            threads: 1,
            retrain: RetrainPolicy { retrain_after: 1, max_retrains: 2 },
            ..ExternalConfig::default()
        };
        let (_runs, stats, _spill) = gen_from_vec(keys, &cfg);
        assert!(stats.rmi_trained);
        assert_eq!(stats.retrains, 0, "constant tail must veto every install");
        assert_eq!(stats.epochs.len(), 1);
        assert_eq!(stats.epochs[0].keys, 4 * 16_384, "all keys counted");
        assert_eq!(
            stats.epochs[0].learned_keys,
            2 * 16_384,
            "only the learned regime may weight the model's cuts"
        );
    }

    #[test]
    fn delta_codec_spills_identical_runs_in_fewer_bytes() {
        // Same stream, both codecs: identical sorted keys per run, and the
        // duplicate-heavy runs shrink under delta (RunFile.bytes is what
        // the report's spill accounting sums).
        use crate::external::spill::SpillCodec;
        let keys: Vec<u64> = (0..40_000u64).map(|i| 1_000_000_000 + (i * i) % 97).collect();
        let base = ExternalConfig {
            memory_budget: 8192 * 8,
            threads: 1,
            ..ExternalConfig::default()
        };
        let raw_cfg = ExternalConfig { spill_codec: SpillCodec::Raw, ..base.clone() };
        let delta_cfg = ExternalConfig { spill_codec: SpillCodec::Delta, ..base };
        let (raw_runs, _, _raw_spill) = gen_from_vec(keys.clone(), &raw_cfg);
        let (delta_runs, _, _delta_spill) = gen_from_vec(keys, &delta_cfg);
        assert_eq!(raw_runs.len(), delta_runs.len());
        for (r, d) in raw_runs.iter().zip(&delta_runs) {
            assert_eq!(
                read_keys_file::<u64>(&r.path).unwrap(),
                read_keys_file::<u64>(&d.path).unwrap(),
                "codecs must decode to identical runs"
            );
            assert!(
                d.bytes < r.bytes / 2,
                "97 distinct values per run must collapse (delta {} vs raw {})",
                d.bytes,
                r.bytes
            );
        }
    }

    #[test]
    fn cold_start_installs_first_model_mid_stream() {
        let mut rng = Xoshiro256pp::new(42);
        // Chunks 1-2 are constant (Algorithm 5's guard vetoes any model);
        // chunks 3-6 are smooth uniform. The cold-start path must keep
        // probing and install a *first* model once the stream turns
        // tractable, instead of demoting the rest of it to IPS⁴o.
        let mut keys: Vec<f64> = vec![7e6; 2 * 16_384];
        keys.extend((0..4 * 16_384).map(|_| rng.uniform(0.0, 1e6)));
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            threads: 1,
            retrain: RetrainPolicy { retrain_after: 1, max_retrains: 2 },
            ..ExternalConfig::default()
        };
        let mut it = keys.into_iter();
        let src = move |max: usize| -> io::Result<Option<Vec<f64>>> {
            let chunk: Vec<f64> = it.by_ref().take(max).collect();
            Ok(if chunk.is_empty() { None } else { Some(chunk) })
        };
        let mut spill = SpillDir::create(None).unwrap();
        let gen = generate_runs(src, &mut spill, &cfg, &IoCtx::sync()).unwrap();
        assert!(!gen.stats.rmi_trained, "first chunk must not train");
        assert_eq!(gen.stats.retrains, 1, "first model installs mid-stream");
        assert_eq!(gen.models.len(), 1);
        // chunk 2's attempt is vetoed (constant data); chunk 3 installs
        // and sorts learned, as do chunks 4-6
        assert_eq!(gen.stats.learned_chunks, 4);
        assert_eq!(gen.stats.fallback_chunks, 2);
        assert_eq!(gen.run_epochs, vec![0, 0, 0, 0, 0, 0], "one epoch only");
        for r in &gen.runs {
            assert!(is_sorted(&read_keys_file::<f64>(&r.path).unwrap()));
        }
    }

    #[test]
    fn cold_start_never_engages_for_ips4o_strategy_or_tiny_chunks() {
        // Dup-heavy stream under RunGen::Ips4o: no cold-start installs.
        let keys: Vec<u64> = (0..60_000).map(|i| i % 7).collect();
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            threads: 1,
            run_gen: RunGen::Ips4o,
            retrain: RetrainPolicy { retrain_after: 1, max_retrains: 4 },
            ..ExternalConfig::default()
        };
        let (_runs, stats, _spill) = gen_from_vec(keys, &cfg);
        assert!(!stats.rmi_trained);
        assert_eq!(stats.retrains, 0);
        // Chunks below min_learned_chunk never build a cold-start streak.
        let keys: Vec<u64> = (0..4096).collect();
        let cfg = ExternalConfig {
            memory_budget: 512 * 8,
            threads: 1,
            retrain: RetrainPolicy { retrain_after: 1, max_retrains: 4 },
            ..ExternalConfig::default()
        };
        let (_runs, stats, _spill) = gen_from_vec(keys, &cfg);
        assert!(!stats.rmi_trained);
        assert_eq!(stats.retrains, 0, "tiny chunks must stay model-less");
    }

    #[test]
    fn drift_probe_is_unbiased_on_chunks_below_probe_size() {
        let mut rng = Xoshiro256pp::new(0xD21F);
        let mut sample: Vec<f64> = (0..8192).map(|_| rng.uniform(0.0, 1e6)).collect();
        sample.sort_unstable_by(f64::total_cmp);
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 256 });
        let cfg = ExternalConfig::default(); // drift_probe = 2048
        // chunks *smaller* than the probe: the reservoir covers the whole
        // chunk, so the verdict is exact — a shifted regime must read as
        // drifted and an in-distribution one must not.
        let shifted: Vec<f64> = (0..512).map(|_| rng.uniform(5e6, 6e6)).collect();
        assert!(shifted.len() < cfg.drift_probe);
        assert!(drifted(&shifted, &rmi, &cfg, &mut rng));
        let in_dist: Vec<f64> = (0..512).map(|_| rng.uniform(0.0, 1e6)).collect();
        assert!(!drifted(&in_dist, &rmi, &cfg, &mut rng));
        // the empty chunk keeps reporting "no drift" (nothing to score)
        assert!(!drifted(&[] as &[f64], &rmi, &cfg, &mut rng));
    }

    #[test]
    fn ips4o_strategy_never_trains() {
        let mut rng = Xoshiro256pp::new(5);
        let keys: Vec<f64> = (0..40_000).map(|_| rng.uniform(0.0, 1e6)).collect();
        let cfg = ExternalConfig {
            memory_budget: 16_384 * 8,
            run_gen: RunGen::Ips4o,
            threads: 1,
            ..ExternalConfig::default()
        };
        let (_runs, stats, _spill) = gen_from_vec(keys, &cfg);
        assert!(!stats.rmi_trained);
        assert_eq!(stats.learned_chunks, 0);
        assert_eq!(stats.fallback_chunks, stats.chunks);
    }

    #[test]
    fn pipelined_source_error_propagates() {
        let mut calls = 0u32;
        let src = move |max: usize| -> io::Result<Option<Vec<u64>>> {
            calls += 1;
            if calls <= 2 {
                Ok(Some((0..max as u64).collect()))
            } else {
                Err(io::Error::other("source failed"))
            }
        };
        let mut spill = SpillDir::create(None).unwrap();
        let cfg = ExternalConfig {
            memory_budget: 3 * 8192 * 8,
            threads: 2,
            ..ExternalConfig::default()
        };
        let err = generate_runs::<u64, _>(src, &mut spill, &cfg, &IoCtx::sync()).unwrap_err();
        assert_eq!(err.to_string(), "source failed");
    }

    #[test]
    fn pipelined_trains_model_once_and_reports_it() {
        let mut rng = Xoshiro256pp::new(8);
        let keys: Vec<f64> = (0..60_000).map(|_| rng.uniform(0.0, 1e6)).collect();
        let mut it = keys.into_iter();
        let src = move |max: usize| -> io::Result<Option<Vec<f64>>> {
            let chunk: Vec<f64> = it.by_ref().take(max).collect();
            Ok(if chunk.is_empty() { None } else { Some(chunk) })
        };
        let mut spill = SpillDir::create(None).unwrap();
        let cfg = ExternalConfig {
            memory_budget: 3 * 16_384 * 8,
            threads: 2,
            ..ExternalConfig::default()
        };
        let gen = generate_runs(src, &mut spill, &cfg, &IoCtx::sync()).unwrap();
        assert!(gen.stats.rmi_trained);
        assert_eq!(gen.models.len(), 1, "trained model must reach the merge");
        assert!(gen.run_epochs.iter().all(|&e| e == 0), "single epoch");
        assert_eq!(gen.run_epochs.len(), gen.runs.len());
        assert_eq!(gen.stats.keys, 60_000);
    }
}
