//! Cache-friendly k-way merge via a tournament **loser tree**.
//!
//! The classic external-merge structure (Knuth TAOCP Vol. 3, §5.4.1): an
//! implicit array of k−1 internal nodes, each holding the *loser* of its
//! subtree's match, with the overall winner cached at index 0. Popping the
//! winner replays exactly one leaf-to-root path — ⌈log₂ k⌉ comparisons
//! against a contiguous `usize` array, versus a binary heap's sift-down
//! that compares both children at every level.
//!
//! Layout for arbitrary `k` (no power-of-two padding): internal nodes are
//! `1..k`, the leaf of source `s` is node `k + s`, and the parent of node
//! `m` is `m / 2`. Exhausted sources hold `None`, which loses to every
//! live key, so the merge needs no sentinel keys.
//!
//! Sources are [`KeyStream`]s, so the tree is codec-agnostic: a
//! [`RunReader`] source decodes raw fixed-width (v0/v1/v4) or
//! delta+varint block (v2/v5) payloads per its file's header, and runs
//! of different codecs merge together in one tournament. Records and
//! string keys flow through unchanged — matches compare under
//! [`SortKey::key_cmp`], so payload lanes ride along and prefix-tied
//! strings order on their tails.

use std::io;
use std::path::Path;

use crate::external::io::IoCtx;
use crate::external::spill::{BlockDirectory, RunReader, SpillHeader};
use crate::key::SortKey;

/// One merge input: the run path, the key range to read, and whatever
/// the caller already learned about the file — the decoded spill header
/// and (for v2 runs) the planner's block directory — so opening the
/// source re-reads neither. Before this, every merge open re-read the
/// 24-byte header from a fresh buffered reader even when a [`RunIndex`]
/// had just walked the same file.
///
/// [`RunIndex`]: crate::external::spill::RunIndex
pub(crate) struct MergeSource<'a> {
    /// The run file.
    pub path: &'a Path,
    /// First key of the range.
    pub start: u64,
    /// Keys in the range (empty sources are skipped at open).
    pub len: u64,
    /// The planner's block directory, when one was built.
    pub dir: Option<&'a BlockDirectory>,
    /// The cached spill header, when the caller already decoded it.
    pub header: Option<&'a SpillHeader>,
}

/// Open every nonempty source of a merge through one code path — the
/// serial group merge and the sharded merge share it — reusing whatever
/// cached metadata each [`MergeSource`] carries and routing reads
/// through the configured IO backend.
pub(crate) fn open_merge_sources<K: SortKey>(
    specs: &[MergeSource<'_>],
    io_buffer: usize,
    io: &IoCtx,
) -> io::Result<Vec<RunReader<K>>> {
    let mut sources = Vec::with_capacity(specs.len());
    for s in specs {
        if s.len == 0 {
            continue;
        }
        sources.push(RunReader::open_range_ctx(
            s.path, s.start, s.len, io_buffer, s.dir, s.header, io,
        )?);
    }
    Ok(sources)
}

/// A stream of keys consumed by the merge (each run is nondecreasing).
pub trait KeyStream<K> {
    /// Next key, or `None` when the stream is exhausted.
    fn next_key(&mut self) -> io::Result<Option<K>>;
}

impl<K: SortKey> KeyStream<K> for RunReader<K> {
    fn next_key(&mut self) -> io::Result<Option<K>> {
        self.next()
    }
}

/// In-memory stream, for tests and for merging resident chunks.
pub struct VecStream<K> {
    iter: std::vec::IntoIter<K>,
}

impl<K> VecStream<K> {
    /// Stream over an in-memory (sorted) vector.
    pub fn new(keys: Vec<K>) -> VecStream<K> {
        VecStream {
            iter: keys.into_iter(),
        }
    }
}

impl<K: SortKey> KeyStream<K> for VecStream<K> {
    fn next_key(&mut self) -> io::Result<Option<K>> {
        Ok(self.iter.next())
    }
}

/// K-way merging loser tree over any [`KeyStream`] sources.
pub struct LoserTree<K: SortKey, S: KeyStream<K>> {
    sources: Vec<S>,
    /// Current head key per source (`None` = exhausted).
    head: Vec<Option<K>>,
    /// `tree[0]` = overall winner source; `tree[1..k]` = per-node losers.
    tree: Vec<usize>,
    k: usize,
}

impl<K: SortKey, S: KeyStream<K>> LoserTree<K, S> {
    /// Build the initial tournament over `sources` (reads one head key
    /// from each).
    pub fn new(mut sources: Vec<S>) -> io::Result<LoserTree<K, S>> {
        let k = sources.len();
        let mut head = Vec::with_capacity(k);
        for s in sources.iter_mut() {
            head.push(s.next_key()?);
        }
        let mut tree = vec![0usize; k.max(1)];
        if k > 0 {
            let winner = build(1, k, &head, &mut tree);
            tree[0] = winner;
        }
        Ok(LoserTree {
            sources,
            head,
            tree,
            k,
        })
    }

    /// Pop the smallest head key across all sources; `None` when all
    /// sources are exhausted.
    #[allow(clippy::should_implement_trait)] // fallible: io::Result, not Iterator
    pub fn next(&mut self) -> io::Result<Option<K>> {
        if self.k == 0 {
            return Ok(None);
        }
        let w = self.tree[0];
        let Some(key) = self.head[w] else {
            return Ok(None); // winner exhausted ⇒ everyone exhausted
        };
        self.head[w] = self.sources[w].next_key()?;
        // Replay the leaf-to-root path of source w.
        let mut winner = w;
        let mut node = (self.k + w) / 2;
        while node >= 1 {
            let challenger = self.tree[node];
            if wins(&self.head, challenger, winner) {
                self.tree[node] = winner;
                winner = challenger;
            }
            node /= 2;
        }
        self.tree[0] = winner;
        Ok(Some(key))
    }

    /// Drain the merge into a vector (tests / small merges).
    pub fn collect_all(&mut self) -> io::Result<Vec<K>> {
        let mut out = Vec::new();
        while let Some(k) = self.next()? {
            out.push(k);
        }
        Ok(out)
    }
}

/// Source `a` beats source `b` iff its head orders strictly first
/// (exhausted sources lose to everything; ties break to the lower index
/// for determinism). Matches play under the key's *full* order
/// ([`SortKey::key_cmp`]) — for bare numerics that is the ordered-bits
/// compare it always was, and for prefix-encoded strings it breaks
/// prefix-collided bits on the tail so merged runs come out in full
/// lexicographic order, not just bit order.
fn wins<K: SortKey>(head: &[Option<K>], a: usize, b: usize) -> bool {
    match (head[a], head[b]) {
        (Some(x), Some(y)) => match x.key_cmp(y) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Equal => a < b,
            std::cmp::Ordering::Greater => false,
        },
        (Some(_), None) => true,
        (None, Some(_)) => false,
        (None, None) => a < b,
    }
}

/// Recursively play the initial tournament under `node`, recording losers
/// and returning the subtree's winner.
fn build<K: SortKey>(node: usize, k: usize, head: &[Option<K>], tree: &mut [usize]) -> usize {
    if node >= k {
        return node - k; // leaf: source index
    }
    let a = build(2 * node, k, head, tree);
    let b = build(2 * node + 1, k, head, tree);
    let (winner, loser) = if wins(head, a, b) { (a, b) } else { (b, a) };
    tree[node] = loser;
    winner
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn merge_vecs(runs: Vec<Vec<u64>>) -> Vec<u64> {
        let sources: Vec<VecStream<u64>> = runs.into_iter().map(VecStream::new).collect();
        LoserTree::new(sources).unwrap().collect_all().unwrap()
    }

    #[test]
    fn merges_three_runs() {
        let out = merge_vecs(vec![vec![5], vec![1, 9], vec![3]]);
        assert_eq!(out, vec![1, 3, 5, 9]);
    }

    #[test]
    fn handles_empty_and_degenerate() {
        assert_eq!(merge_vecs(vec![]), Vec::<u64>::new());
        assert_eq!(merge_vecs(vec![vec![]]), Vec::<u64>::new());
        assert_eq!(merge_vecs(vec![vec![], vec![2, 4], vec![]]), vec![2, 4]);
        assert_eq!(merge_vecs(vec![vec![7, 8, 9]]), vec![7, 8, 9]);
    }

    #[test]
    fn duplicates_across_runs() {
        let out = merge_vecs(vec![vec![1, 1, 5], vec![1, 5, 5], vec![1]]);
        assert_eq!(out, vec![1, 1, 1, 1, 5, 5, 5]);
    }

    #[test]
    fn random_fanouts_match_flat_sort() {
        let mut rng = Xoshiro256pp::new(0x105E);
        for k in [1usize, 2, 3, 4, 5, 7, 8, 13, 16, 31] {
            let mut all = Vec::new();
            let mut runs = Vec::new();
            for _ in 0..k {
                let len = rng.next_below(200) as usize;
                let mut run: Vec<u64> =
                    (0..len).map(|_| rng.next_below(1000)).collect();
                run.sort_unstable();
                all.extend_from_slice(&run);
                runs.push(run);
            }
            all.sort_unstable();
            assert_eq!(merge_vecs(runs), all, "k={k}");
        }
    }

    #[test]
    fn fanin_extremes_k1_and_k_max() {
        // k = 1: the degenerate tournament (tree = [0], no internal
        // nodes) must stream its single run through unchanged.
        let mut rng = Xoshiro256pp::new(0xFA71);
        let mut solo: Vec<u64> = (0..500).map(|_| rng.next_below(10_000)).collect();
        solo.sort_unstable();
        assert_eq!(merge_vecs(vec![solo.clone()]), solo);

        // k far beyond any budget-clamped fan-in (ExternalConfig clamps
        // to budget/io_buffer; 509 is prime, so the implicit non-power-
        // of-two layout gets no accidental alignment help). Sources
        // include empty runs interleaved throughout.
        let k = 509;
        let mut all = Vec::new();
        let mut runs = Vec::new();
        for i in 0..k {
            let len = if i % 7 == 0 { 0 } else { rng.next_below(40) as usize };
            let mut run: Vec<u64> = (0..len).map(|_| rng.next_below(100_000)).collect();
            run.sort_unstable();
            all.extend_from_slice(&run);
            runs.push(run);
        }
        all.sort_unstable();
        assert_eq!(merge_vecs(runs), all, "k={k}");
    }

    #[test]
    fn mixed_codec_run_readers_merge_exactly() {
        // One raw (v1) and one delta (v2) run through the same tree: the
        // header-dispatched readers must interleave transparently.
        use crate::external::spill::{write_keys_file, RunReader, RunWriter, SpillCodec};
        let dir = std::env::temp_dir();
        let p_raw = dir.join(format!("aipso-lt-raw-{}.bin", std::process::id()));
        let p_delta = dir.join(format!("aipso-lt-delta-{}.bin", std::process::id()));
        let mut rng = Xoshiro256pp::new(0x717E);
        let mut a: Vec<u64> = (0..4000).map(|_| rng.next_below(10_000)).collect();
        let mut b: Vec<u64> = (0..4000).map(|_| rng.next_below(10_000)).collect();
        a.sort_unstable();
        b.sort_unstable();
        write_keys_file(&p_raw, &a).unwrap();
        let mut w =
            RunWriter::<u64>::create_with(p_delta.clone(), 4096, SpillCodec::Delta).unwrap();
        w.write_slice(&b).unwrap();
        w.finish().unwrap();

        let sources = vec![
            RunReader::<u64>::open(&p_raw, 4096).unwrap(),
            RunReader::<u64>::open(&p_delta, 4096).unwrap(),
        ];
        let got = LoserTree::new(sources).unwrap().collect_all().unwrap();
        let mut want = a;
        want.extend_from_slice(&b);
        want.sort_unstable();
        assert_eq!(got, want);
        let _ = std::fs::remove_file(&p_raw);
        let _ = std::fs::remove_file(&p_delta);
    }

    #[test]
    fn f64_total_order_merge() {
        let runs = vec![vec![-1.5f64, -0.0, 2.0], vec![-2.0, 0.0, 1.0]];
        let sources: Vec<VecStream<f64>> = runs.into_iter().map(VecStream::new).collect();
        let out = LoserTree::new(sources).unwrap().collect_all().unwrap();
        let bits: Vec<u64> = out.iter().map(|x| x.to_bits_ordered()).collect();
        let mut sorted = bits.clone();
        sorted.sort_unstable();
        assert_eq!(bits, sorted);
        assert_eq!(out.len(), 6);
    }
}
