//! Spill codec and run files — the IO substrate of the external sorter.
//!
//! Keys are stored as fixed-width little-endian values in their *native*
//! encoding ([`SortKey::to_le_bytes`]), `K::WIDTH` bytes per key — the
//! same format `aipso gen --out` writes, so any generated dataset file is
//! a valid `sort_file` input and outputs round-trip byte-exactly. All four
//! [`SortKey`] domains (`u64`/`f64` at 8 bytes, `u32`/`f32` at 4) flow
//! through the one codec.
//!
//! # Spill format
//!
//! Every file this module writes is **self-describing**: a fixed
//! [`HEADER_LEN`]-byte header precedes the key payload.
//!
//! | offset | size | field |
//! |-------:|-----:|-------|
//! | 0      | 8    | magic `b"AIPSPILL"` |
//! | 8      | 2    | format version (little-endian, currently [`FORMAT_VERSION`]) |
//! | 10     | 1    | key-type tag ([`KeyKind::tag`]: 0=u64, 1=f64, 2=u32, 3=f32) |
//! | 11     | 1    | key width in bytes (redundant with the tag; cross-checked) |
//! | 12     | 4    | reserved (zero; future codecs — varint, compressed runs) |
//! | 16     | 8    | key count (little-endian) |
//!
//! Version table:
//!
//! * **v0** — legacy headerless files: raw 8-byte little-endian keys,
//!   nothing else. Still accepted on *read* (the pre-header `gen --out`
//!   format), for 8-byte key types only; `length % 8 == 0` is the only
//!   validation available.
//! * **v1** — the current format above. Readers validate magic, version,
//!   key-type tag and that the payload holds exactly `count` keys, so a
//!   truncated or mis-typed file fails loudly instead of decoding garbage.
//!
//! Readers distinguish the two by the magic: a v0 file whose first eight
//! bytes spell `b"AIPSPILL"` (one specific key value) would be
//! misdetected, which is why v1 exists — new files always carry the
//! header.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::key::{KeyKind, SortKey};

/// Magic prefix of self-describing (v1+) key files.
pub const MAGIC: [u8; 8] = *b"AIPSPILL";

/// Newest spill-format version this build writes (and the highest it
/// accepts on read).
pub const FORMAT_VERSION: u16 = 1;

/// Bytes of header preceding the key payload in v1+ files.
pub const HEADER_LEN: usize = 24;

/// Byte offset of the key-count field inside the header (patched by
/// [`RunWriter::finish`] once the count is known).
const COUNT_OFFSET: u64 = 16;

/// Decoded header of a self-describing key file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpillHeader {
    /// Format version the file was written with.
    pub version: u16,
    /// Key domain of the payload.
    pub kind: KeyKind,
    /// Keys in the payload.
    pub count: u64,
}

impl SpillHeader {
    /// Header for a fresh file of `count` keys in the current format.
    pub fn new(kind: KeyKind, count: u64) -> SpillHeader {
        SpillHeader {
            version: FORMAT_VERSION,
            kind,
            count,
        }
    }

    /// Serialize into the on-disk layout (see the module docs).
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut b = [0u8; HEADER_LEN];
        b[..8].copy_from_slice(&MAGIC);
        b[8..10].copy_from_slice(&self.version.to_le_bytes());
        b[10] = self.kind.tag();
        b[11] = self.kind.width() as u8;
        b[16..24].copy_from_slice(&self.count.to_le_bytes());
        b
    }

    /// Parse and validate an on-disk header (the caller has already
    /// matched the magic).
    fn decode(b: &[u8; HEADER_LEN], path: &Path) -> io::Result<SpillHeader> {
        debug_assert_eq!(&b[..8], &MAGIC);
        let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
        let version = u16::from_le_bytes([b[8], b[9]]);
        if version == 0 || version > FORMAT_VERSION {
            return Err(bad(format!(
                "{}: unsupported spill format version {version} (this build reads v1..=v{FORMAT_VERSION})",
                path.display()
            )));
        }
        let kind = KeyKind::from_tag(b[10]).ok_or_else(|| {
            bad(format!(
                "{}: unknown key-type tag {} in spill header",
                path.display(),
                b[10]
            ))
        })?;
        if b[11] as usize != kind.width() {
            return Err(bad(format!(
                "{}: header key width {} does not match key type {} (width {})",
                path.display(),
                b[11],
                kind.name(),
                kind.width()
            )));
        }
        let count = u64::from_le_bytes(b[16..24].try_into().unwrap());
        Ok(SpillHeader {
            version,
            kind,
            count,
        })
    }
}

/// Read the header of a key file: `Some` for self-describing (v1+) files,
/// `None` for legacy headerless (v0) files. Malformed headers — matching
/// magic but bad version/tag/width — are errors, not `None`.
pub fn read_header(path: &Path) -> io::Result<Option<SpillHeader>> {
    let mut file = File::open(path)?;
    parse_header(&mut file, path)
}

/// Header probe over an open file; leaves the cursor unspecified.
fn parse_header(file: &mut File, path: &Path) -> io::Result<Option<SpillHeader>> {
    let len = file.metadata()?.len();
    if len < MAGIC.len() as u64 {
        return Ok(None);
    }
    file.seek(SeekFrom::Start(0))?;
    let mut probe = [0u8; 8];
    file.read_exact(&mut probe)?;
    if probe != MAGIC {
        return Ok(None);
    }
    if len < HEADER_LEN as u64 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "{}: truncated spill header ({len} bytes, need {HEADER_LEN})",
                path.display()
            ),
        ));
    }
    let mut buf = [0u8; HEADER_LEN];
    buf[..8].copy_from_slice(&probe);
    file.read_exact(&mut buf[8..])?;
    SpillHeader::decode(&buf, path).map(Some)
}

/// Resolved location of the key payload inside a file.
#[derive(Debug, Clone, Copy)]
struct KeyLayout {
    /// Byte offset of the first key ([`HEADER_LEN`], or 0 for v0 files).
    data_start: u64,
    /// Keys in the file.
    n: u64,
}

/// Check that a v1 file's byte length holds exactly the header's `count`
/// keys (shared by [`resolve_layout`] and [`file_key_count`]).
fn validate_payload(h: &SpillHeader, len: u64, path: &Path) -> io::Result<()> {
    let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
    let payload = len - HEADER_LEN as u64;
    let expect = h.count.checked_mul(h.kind.width() as u64).ok_or_else(|| {
        bad(format!(
            "{}: absurd key count {} in spill header",
            path.display(),
            h.count
        ))
    })?;
    if payload != expect {
        return Err(bad(format!(
            "{}: truncated or oversized payload — header promises {} {} keys \
             ({expect} bytes) but the file holds {payload}",
            path.display(),
            h.count,
            h.kind.name()
        )));
    }
    Ok(())
}

/// Validate a file against the expected key domain and locate its
/// payload. Accepts v1 files of exactly `kind` and headerless v0 files
/// when `kind` is 8 bytes wide.
fn resolve_layout(file: &mut File, path: &Path, kind: KeyKind) -> io::Result<KeyLayout> {
    let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
    let len = file.metadata()?.len();
    match parse_header(file, path)? {
        Some(h) => {
            if h.kind != kind {
                return Err(bad(format!(
                    "{}: file holds {} keys but the sort was invoked for {}",
                    path.display(),
                    h.kind.name(),
                    kind.name()
                )));
            }
            validate_payload(&h, len, path)?;
            Ok(KeyLayout {
                data_start: HEADER_LEN as u64,
                n: h.count,
            })
        }
        None => {
            if kind.width() != 8 {
                return Err(bad(format!(
                    "{}: headerless (v0) key files hold 8-byte keys; {} requires \
                     a self-describing v1 header (write it with this build's gen)",
                    path.display(),
                    kind.name()
                )));
            }
            Ok(KeyLayout {
                data_start: 0,
                n: v0_key_count(len, path)?,
            })
        }
    }
}

/// Validate a headerless (v0) file's length and return its key count —
/// `length % 8 == 0` is the only check the legacy format affords.
fn v0_key_count(len: u64, path: &Path) -> io::Result<u64> {
    if len % 8 != 0 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "{}: length {len} is not a multiple of 8 (headerless v0 key file)",
                path.display()
            ),
        ));
    }
    Ok(len / 8)
}

/// A spilled run (or any key file) on disk.
#[derive(Debug, Clone)]
pub struct RunFile {
    /// Location of the run on disk.
    pub path: PathBuf,
    /// Number of keys in the file.
    pub n: u64,
}

/// Scratch directory owning the spilled runs of one sort; removed
/// (best-effort) on drop.
#[derive(Debug)]
pub struct SpillDir {
    dir: PathBuf,
    counter: u64,
}

impl SpillDir {
    /// Create a fresh uniquely-named scratch directory under `base`
    /// (`None` = the OS temp dir).
    pub fn create(base: Option<&Path>) -> io::Result<SpillDir> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let base = base
            .map(Path::to_path_buf)
            .unwrap_or_else(std::env::temp_dir);
        let dir = base.join(format!(
            "aipso-extsort-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        Ok(SpillDir { dir, counter: 0 })
    }

    /// The scratch directory's location.
    pub fn path(&self) -> &Path {
        &self.dir
    }

    /// Fresh path for the next spilled run.
    pub fn next_run_path(&mut self) -> PathBuf {
        self.counter += 1;
        self.dir.join(format!("run-{:06}.bin", self.counter))
    }
}

impl Drop for SpillDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Keys per decode/encode slab pass (the slab is a fixed byte array so
/// peak memory stays `O(slab)` regardless of chunk size).
const SLAB_BYTES: usize = 8192;

/// Buffered streaming reader over a key file.
pub struct RunReader<K: SortKey> {
    r: BufReader<File>,
    remaining: u64,
    _pd: PhantomData<K>,
}

impl<K: SortKey> RunReader<K> {
    /// Open a buffered reader over a whole key file (validating its
    /// header, or accepting a headerless v0 file for 8-byte key types).
    pub fn open(path: &Path, io_buffer: usize) -> io::Result<RunReader<K>> {
        Self::open_range(path, 0, u64::MAX, io_buffer)
    }

    /// Open a buffered reader over the key range `[start, start + len)` of
    /// a key file (indices in keys, clamped to the file). The sharded
    /// merge streams each run's shard segment through one of these.
    pub fn open_range(
        path: &Path,
        start: u64,
        len: u64,
        io_buffer: usize,
    ) -> io::Result<RunReader<K>> {
        let mut file = File::open(path)?;
        let layout = resolve_layout(&mut file, path, K::KIND)?;
        let start = start.min(layout.n);
        let len = len.min(layout.n - start);
        file.seek(SeekFrom::Start(layout.data_start + start * K::WIDTH as u64))?;
        Ok(RunReader {
            r: BufReader::with_capacity(io_buffer.max(4096), file),
            remaining: len,
            _pd: PhantomData,
        })
    }

    /// Keys left in the file.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// Next key, or `None` at end of file.
    #[allow(clippy::should_implement_trait)] // fallible: io::Result, not Iterator
    pub fn next(&mut self) -> io::Result<Option<K>> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let mut buf = K::Bytes::default();
        self.r.read_exact(buf.as_mut())?;
        self.remaining -= 1;
        Ok(Some(K::from_le_bytes(buf)))
    }

    /// Read up to `max` keys; an empty vec means EOF. Decodes through a
    /// fixed scratch slab so peak memory stays `max * WIDTH + O(slab)` —
    /// not double the chunk, which would break the sorter's byte budget.
    pub fn read_chunk(&mut self, max: usize) -> io::Result<Vec<K>> {
        let take = (self.remaining.min(max as u64)) as usize;
        if take == 0 {
            return Ok(Vec::new());
        }
        let per_slab = SLAB_BYTES / K::WIDTH;
        let mut out = Vec::with_capacity(take);
        let mut slab = [0u8; SLAB_BYTES];
        let mut left = take;
        while left > 0 {
            let now = left.min(per_slab);
            let bytes = &mut slab[..now * K::WIDTH];
            self.r.read_exact(bytes)?;
            for c in bytes.chunks_exact(K::WIDTH) {
                let mut b = K::Bytes::default();
                b.as_mut().copy_from_slice(c);
                out.push(K::from_le_bytes(b));
            }
            left -= now;
        }
        self.remaining -= take as u64;
        Ok(out)
    }
}

/// Random-access view of a sorted run file: positioned single-key reads
/// and a lower-bound binary search over the key order. The shard planner
/// uses this to locate shard boundaries in `O(log n)` seeks per run
/// instead of streaming the whole file.
pub struct RunIndex<K: SortKey> {
    file: File,
    data_start: u64,
    n: u64,
    _pd: PhantomData<K>,
}

impl<K: SortKey> RunIndex<K> {
    /// Open a key file for random access.
    pub fn open(path: &Path) -> io::Result<RunIndex<K>> {
        let mut file = File::open(path)?;
        let layout = resolve_layout(&mut file, path, K::KIND)?;
        Ok(RunIndex {
            file,
            data_start: layout.data_start,
            n: layout.n,
            _pd: PhantomData,
        })
    }

    /// Number of keys in the file.
    pub fn len(&self) -> u64 {
        self.n
    }

    /// True when the file holds no keys.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Read the key at index `idx` with one positioned read.
    pub fn key_at(&mut self, idx: u64) -> io::Result<K> {
        debug_assert!(idx < self.n);
        self.file
            .seek(SeekFrom::Start(self.data_start + idx * K::WIDTH as u64))?;
        let mut buf = K::Bytes::default();
        self.file.read_exact(buf.as_mut())?;
        Ok(K::from_le_bytes(buf))
    }

    /// First index whose key's ordered bits are `>= bound_bits`, assuming
    /// the file is sorted (`n` when every key is below the bound). This is
    /// the shard-boundary cut: keys equal to the bound fall into the shard
    /// that *starts* at the bound, so duplicates never straddle a cut.
    pub fn lower_bound(&mut self, bound_bits: u64) -> io::Result<u64> {
        let (mut lo, mut hi) = (0u64, self.n);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.key_at(mid)?.to_bits_ordered() < bound_bits {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        Ok(lo)
    }
}

/// Buffered streaming writer producing a [`RunFile`] in the current
/// (v1, self-describing) spill format.
pub struct RunWriter<K: SortKey> {
    w: BufWriter<File>,
    path: PathBuf,
    n: u64,
    _pd: PhantomData<K>,
}

impl<K: SortKey> RunWriter<K> {
    /// Create (truncate) the file at `path`, write its header with a
    /// placeholder count, and return a writer over it.
    pub fn create(path: PathBuf, io_buffer: usize) -> io::Result<RunWriter<K>> {
        let file = File::create(&path)?;
        let mut w = BufWriter::with_capacity(io_buffer.max(4096), file);
        w.write_all(&SpillHeader::new(K::KIND, 0).encode())?;
        Ok(RunWriter {
            w,
            path,
            n: 0,
            _pd: PhantomData,
        })
    }

    /// Append one key.
    #[inline]
    pub fn push(&mut self, key: K) -> io::Result<()> {
        self.w.write_all(key.to_le_bytes().as_ref())?;
        self.n += 1;
        Ok(())
    }

    /// Bulk spill: encodes through a fixed slab and writes in blocks,
    /// mirroring `RunReader::read_chunk` (no per-key `write_all`).
    pub fn write_slice(&mut self, keys: &[K]) -> io::Result<()> {
        let per_slab = SLAB_BYTES / K::WIDTH;
        let mut slab = [0u8; SLAB_BYTES];
        for block in keys.chunks(per_slab) {
            let bytes = &mut slab[..block.len() * K::WIDTH];
            for (c, k) in bytes.chunks_exact_mut(K::WIDTH).zip(block) {
                c.copy_from_slice(k.to_le_bytes().as_ref());
            }
            self.w.write_all(bytes)?;
        }
        self.n += keys.len() as u64;
        Ok(())
    }

    /// Flush, patch the real key count into the header, and close,
    /// returning the finished run's metadata.
    pub fn finish(mut self) -> io::Result<RunFile> {
        self.w.flush()?;
        let file = self.w.get_mut();
        file.seek(SeekFrom::Start(COUNT_OFFSET))?;
        file.write_all(&self.n.to_le_bytes())?;
        Ok(RunFile {
            path: self.path,
            n: self.n,
        })
    }
}

/// Create a v1 key file of exactly `count` keys whose payload will be
/// filled by positioned writes (the sharded merges): header up front,
/// file pre-sized so every shard can open + seek independently.
pub(crate) fn create_presized<K: SortKey>(path: &Path, count: u64) -> io::Result<()> {
    let mut f = File::create(path)?;
    f.write_all(&SpillHeader::new(K::KIND, count).encode())?;
    f.set_len(HEADER_LEN as u64 + count * K::WIDTH as u64)?;
    Ok(())
}

/// Write a whole in-memory slice as a key file.
pub fn write_keys_file<K: SortKey>(path: &Path, keys: &[K]) -> io::Result<RunFile> {
    let mut w = RunWriter::create(path.to_path_buf(), 1 << 16)?;
    w.write_slice(keys)?;
    w.finish()
}

/// Load a whole key file into memory (tests / small files only).
pub fn read_keys_file<K: SortKey>(path: &Path) -> io::Result<Vec<K>> {
    let mut r = RunReader::<K>::open(path, 1 << 16)?;
    let n = r.remaining() as usize;
    r.read_chunk(n)
}

/// Number of keys in a key file: the header's count for self-describing
/// files (validated against the payload length), the byte length over 8
/// for headerless v0 files.
pub fn file_key_count(path: &Path) -> io::Result<u64> {
    let mut file = File::open(path)?;
    let len = file.metadata()?.len();
    match parse_header(&mut file, path)? {
        Some(h) => {
            validate_payload(&h, len, path)?;
            Ok(h.count)
        }
        None => v0_key_count(len, path),
    }
}

/// Stream-verify that a key file is nondecreasing under the key's total
/// order, in O(io_buffer) memory.
pub fn verify_sorted_file<K: SortKey>(path: &Path, io_buffer: usize) -> io::Result<bool> {
    let mut r = RunReader::<K>::open(path, io_buffer)?;
    let mut prev: Option<u64> = None;
    while let Some(k) = r.next()? {
        let bits = k.to_bits_ordered();
        if let Some(p) = prev {
            if bits < p {
                return Ok(false);
            }
        }
        prev = Some(bits);
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("aipso-spill-test-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_u64_and_f64() {
        let p = tmp("rt-u64.bin");
        let keys: Vec<u64> = vec![0, 1, u64::MAX, 42, 7];
        write_keys_file(&p, &keys).unwrap();
        assert_eq!(file_key_count(&p).unwrap(), 5);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        let _ = std::fs::remove_file(&p);

        let p = tmp("rt-f64.bin");
        let keys: Vec<f64> = vec![-1.5, 0.0, -0.0, 1e300, 1e-300];
        write_keys_file(&p, &keys).unwrap();
        let back = read_keys_file::<f64>(&p).unwrap();
        let a: Vec<u64> = keys.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u64> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "bit-exact reload");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn roundtrip_u32_and_f32_at_half_the_bytes() {
        let p32 = tmp("rt-u32.bin");
        let keys32: Vec<u32> = vec![0, 1, u32::MAX, 42, 7];
        write_keys_file(&p32, &keys32).unwrap();
        assert_eq!(file_key_count(&p32).unwrap(), 5);
        assert_eq!(read_keys_file::<u32>(&p32).unwrap(), keys32);

        let p64 = tmp("rt-u64-vs-u32.bin");
        let keys64: Vec<u64> = keys32.iter().map(|&x| x as u64).collect();
        write_keys_file(&p64, &keys64).unwrap();
        let payload32 = std::fs::metadata(&p32).unwrap().len() - HEADER_LEN as u64;
        let payload64 = std::fs::metadata(&p64).unwrap().len() - HEADER_LEN as u64;
        assert_eq!(payload32 * 2, payload64, "4-byte keys halve the payload");
        let _ = std::fs::remove_file(&p32);
        let _ = std::fs::remove_file(&p64);

        let p = tmp("rt-f32.bin");
        let keys: Vec<f32> = vec![-1.5, 0.0, -0.0, 1e30, 1e-30];
        write_keys_file(&p, &keys).unwrap();
        let back = read_keys_file::<f32>(&p).unwrap();
        let a: Vec<u32> = keys.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "bit-exact reload");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn header_roundtrips_and_reports() {
        let p = tmp("hdr.bin");
        write_keys_file::<u32>(&p, &[1, 2, 3]).unwrap();
        let h = read_header(&p).unwrap().expect("v1 file has a header");
        assert_eq!(
            h,
            SpillHeader {
                version: FORMAT_VERSION,
                kind: KeyKind::U32,
                count: 3
            }
        );
        // encode/decode are inverses
        let enc = h.encode();
        assert_eq!(SpillHeader::decode(&enc, &p).unwrap(), h);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn legacy_v0_files_read_as_8_byte_keys_only() {
        let p = tmp("v0.bin");
        let keys: Vec<u64> = vec![9, 1, 5];
        let raw: Vec<u8> = keys.iter().flat_map(|k| k.to_le_bytes()).collect();
        std::fs::write(&p, &raw).unwrap();
        assert_eq!(read_header(&p).unwrap(), None, "no header on v0 files");
        assert_eq!(file_key_count(&p).unwrap(), 3);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        // but a 4-byte type cannot claim a headerless file
        let err = read_keys_file::<u32>(&p).unwrap_err();
        assert!(err.to_string().contains("headerless"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn mismatched_key_type_is_rejected() {
        let p = tmp("mismatch.bin");
        write_keys_file::<f32>(&p, &[1.0, 2.0]).unwrap();
        for (err, want) in [
            (read_keys_file::<u32>(&p).unwrap_err(), "f32"),
            (read_keys_file::<f64>(&p).unwrap_err(), "f32"),
        ] {
            assert!(err.to_string().contains(want), "{err}");
            assert!(err.to_string().contains("invoked for"), "{err}");
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn truncated_and_corrupt_headers_fail_loudly() {
        let p = tmp("bad-hdr.bin");

        // payload shorter than the header's count
        let mut bytes = SpillHeader::new(KeyKind::U64, 4).encode().to_vec();
        bytes.extend_from_slice(&7u64.to_le_bytes()); // only 1 of 4 keys
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        assert!(file_key_count(&p).is_err());

        // magic but the header itself is cut off
        std::fs::write(&p, &MAGIC[..]).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("truncated spill header"), "{err}");

        // future version
        let mut h = SpillHeader::new(KeyKind::U64, 0).encode();
        h[8..10].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        std::fs::write(&p, h).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        // unknown key-type tag
        let mut h = SpillHeader::new(KeyKind::U64, 0).encode();
        h[10] = 9;
        std::fs::write(&p, h).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("key-type tag"), "{err}");

        // width byte contradicting the tag
        let mut h = SpillHeader::new(KeyKind::U32, 0).encode();
        h[11] = 8;
        std::fs::write(&p, h).unwrap();
        let err = read_keys_file::<u32>(&p).unwrap_err();
        assert!(err.to_string().contains("width"), "{err}");

        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn chunked_reads_cover_file() {
        let p = tmp("chunks.bin");
        let keys: Vec<u64> = (0..1000).collect();
        write_keys_file(&p, &keys).unwrap();
        let mut r = RunReader::<u64>::open(&p, 4096).unwrap();
        let mut got = Vec::new();
        loop {
            let c = r.read_chunk(64);
            let c = c.unwrap();
            if c.is_empty() {
                break;
            }
            got.extend(c);
        }
        assert_eq!(got, keys);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn verify_detects_disorder() {
        let p = tmp("verify.bin");
        write_keys_file(&p, &[1u64, 2, 3]).unwrap();
        assert!(verify_sorted_file::<u64>(&p, 4096).unwrap());
        write_keys_file(&p, &[3u64, 2]).unwrap();
        assert!(!verify_sorted_file::<u64>(&p, 4096).unwrap());
        write_keys_file::<u64>(&p, &[]).unwrap();
        assert!(verify_sorted_file::<u64>(&p, 4096).unwrap());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn f64_order_via_bits_in_verify() {
        let p = tmp("verify-f64.bin");
        write_keys_file(&p, &[-2.5f64, -0.0, 0.0, 3.5]).unwrap();
        assert!(verify_sorted_file::<f64>(&p, 4096).unwrap());
        write_keys_file(&p, &[0.0f64, -0.0]).unwrap();
        assert!(!verify_sorted_file::<f64>(&p, 4096).unwrap());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn spill_dir_cleans_up() {
        let dir;
        {
            let mut s = SpillDir::create(None).unwrap();
            dir = s.path().to_path_buf();
            let p = s.next_run_path();
            write_keys_file(&p, &[1u64]).unwrap();
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "SpillDir must remove itself on drop");
    }

    #[test]
    fn range_reads_and_index_lower_bound() {
        let p = tmp("range.bin");
        let keys: Vec<u64> = (0..500).map(|i| i * 2).collect(); // evens 0..998
        write_keys_file(&p, &keys).unwrap();

        let mut r = RunReader::<u64>::open_range(&p, 10, 5, 4096).unwrap();
        let got = r.read_chunk(100).unwrap();
        assert_eq!(got, vec![20, 22, 24, 26, 28]);

        // ranges clamp to the file
        let mut r = RunReader::<u64>::open_range(&p, 498, 100, 4096).unwrap();
        assert_eq!(r.read_chunk(100).unwrap(), vec![996, 998]);
        let mut r = RunReader::<u64>::open_range(&p, 9999, 10, 4096).unwrap();
        assert!(r.read_chunk(10).unwrap().is_empty());

        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx.len(), 500);
        assert_eq!(idx.key_at(0).unwrap(), 0);
        assert_eq!(idx.key_at(499).unwrap(), 998);
        // present key -> its index; absent key -> insertion point
        assert_eq!(idx.lower_bound(40u64.to_bits_ordered()).unwrap(), 20);
        assert_eq!(idx.lower_bound(41u64.to_bits_ordered()).unwrap(), 21);
        assert_eq!(idx.lower_bound(0).unwrap(), 0);
        assert_eq!(idx.lower_bound(u64::MAX).unwrap(), 500);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn range_reads_and_index_work_on_4_byte_keys() {
        let p = tmp("range-u32.bin");
        let keys: Vec<u32> = (0..500).map(|i| i * 2).collect();
        write_keys_file(&p, &keys).unwrap();
        let mut r = RunReader::<u32>::open_range(&p, 10, 3, 4096).unwrap();
        assert_eq!(r.read_chunk(10).unwrap(), vec![20, 22, 24]);
        let mut idx = RunIndex::<u32>::open(&p).unwrap();
        assert_eq!(idx.len(), 500);
        assert_eq!(idx.key_at(499).unwrap(), 998);
        assert_eq!(idx.lower_bound(40u32.to_bits_ordered()).unwrap(), 20);
        assert_eq!(idx.lower_bound(u32::MAX as u64).unwrap(), 500);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn empty_run_index_is_harmless() {
        // A zero-key run (legal: an empty input still truncates an output
        // file, and sharding may probe any run) must index without error:
        // every lower bound is 0, never an out-of-range read.
        let p = tmp("empty-idx.bin");
        write_keys_file::<u64>(&p, &[]).unwrap();
        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx.len(), 0);
        assert!(idx.is_empty());
        assert_eq!(idx.lower_bound(0).unwrap(), 0);
        assert_eq!(idx.lower_bound(u64::MAX).unwrap(), 0);
        // range reads over the empty file clamp to nothing
        let mut r = RunReader::<u64>::open_range(&p, 0, 10, 4096).unwrap();
        assert!(r.read_chunk(10).unwrap().is_empty());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn odd_length_headerless_file_rejected() {
        let p = tmp("odd.bin");
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(RunReader::<u64>::open(&p, 4096).is_err());
        assert!(file_key_count(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
