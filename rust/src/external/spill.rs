//! Spill codec and run files — the on-disk formats of the external
//! sorter (the byte-moving machinery lives in [`crate::external::io`]).
//!
//! Three payload codecs share one self-describing container:
//!
//! * **Raw** (format v1): keys as fixed-width little-endian values in
//!   their *native* encoding ([`SortKey::to_le_bytes`]), `K::WIDTH` bytes
//!   per key — the same format `aipso gen --out` writes, so any generated
//!   dataset file is a valid `sort_file` input and outputs round-trip
//!   byte-exactly. This is the interchange format: inputs, sorted outputs
//!   and pre-sized shard-merge targets are always raw.
//! * **Delta** (format v2): *sorted* runs as blocks of delta-encoded,
//!   LEB128-varint keys. A run is nondecreasing by construction, so
//!   consecutive ordered-bit deltas are non-negative and duplicate keys
//!   collapse into run-length escapes — dup-heavy spills (zipf,
//!   timestamps, sales plateaus) shrink well below `n × WIDTH` bytes,
//!   which is exactly where the IO-bound merge spends its time.
//! * **Zigzag** (format v3): *unsorted* keys in the same block framing,
//!   with deltas zigzag-mapped into the varint token space so negative
//!   steps stay cheap. `gen` outputs ship compressed without the
//!   sorted-run precondition; the run/merge paths never produce v3.
//!
//! Elements with a **lane** ([`SortKey::LANE_WIDTH`] `> 0` — records and
//! string keys) reuse the same two payload codecs under their own version
//! numbers (v4 = record raw, v5 = record delta), because the lane bytes
//! change the entry layout: v4 entries are the full `WIDTH`-byte encoding
//! (core key + lane), and v5 blocks carry a per-key lane array between
//! the restart key and the delta tokens. Zigzag never carries lanes —
//! record/string payloads spill raw or delta only.
//!
//! All five [`SortKey`] domains (`u64`/`f64` at 8 bytes, `u32`/`f32` at
//! 4, prefix strings at 8 core bytes) flow through both codecs, bare or
//! as [`crate::key::SortItem`] records.
//!
//! # Spill format
//!
//! Every file this module writes is **self-describing**: a fixed
//! [`HEADER_LEN`]-byte header precedes the key payload.
//!
//! | offset | size | field |
//! |-------:|-----:|-------|
//! | 0      | 8    | magic `b"AIPSPILL"` |
//! | 8      | 2    | format version (little-endian; dispatches the payload codec) |
//! | 10     | 1    | key-type tag ([`KeyKind::tag`]: 0=u64, 1=f64, 2=u32, 3=f32, 4=str) |
//! | 11     | 1    | v1–v3: key width in bytes (redundant with the tag; cross-checked). v4/v5: **lane width** in bytes (`≥ 1` — payload + string tail per entry; the core key width is implied by the tag) |
//! | 12     | 4    | direct-IO pad: trailing zero bytes past the payload (LE; 0 unless `O_DIRECT` wrote the file) |
//! | 16     | 8    | key count (little-endian) |
//!
//! Version table ([`SpillVersion`] dispatches readers off the version
//! field):
//!
//! * **v0** — legacy headerless files: raw 8-byte little-endian keys,
//!   nothing else. Still accepted on *read* (the pre-header `gen --out`
//!   format), for 8-byte key types only; `length % 8 == 0` is the only
//!   validation available.
//! * **v1** ([`RAW_VERSION`]) — header above + `count × WIDTH` bytes of
//!   fixed-width native-LE keys. Readers validate magic, version,
//!   key-type tag and that the payload holds exactly `count` keys.
//! * **v2** ([`DELTA_VERSION`]) — header above + a sequence of delta
//!   blocks holding `count` keys total. Requires nondecreasing keys
//!   (sorted runs); [`RunWriter`] rejects out-of-order pushes.
//! * **v3** ([`ZIGZAG_VERSION`]) — the same block layout with the delta
//!   token carrying `zigzag(next − prev)` over the ordered-bits space
//!   (wrapping arithmetic), so any key order encodes. v3 files stream
//!   and sort like any input but have no sorted-run index.
//! * **v4** ([`RECORD_RAW_VERSION`]) — v1's fixed-width layout for
//!   lane-carrying elements: `count × WIDTH` bytes, each entry the full
//!   [`SortKey::to_le_bytes`] encoding (core key immediately followed by
//!   its lane). The header's byte 11 records the lane width.
//! * **v5** ([`RECORD_DELTA_VERSION`]) — v2's block layout for
//!   lane-carrying elements: each block inserts a `count × LANE_WIDTH`
//!   lane array between the (core-width) restart key and the delta
//!   tokens, and the block's payload length covers lanes **plus** tokens
//!   — so every offset computation (walks, side-cars, whole-block skips)
//!   is shared with v2 verbatim. Key bits delta-encode exactly as in v2;
//!   equal-bits keys still collapse into dup-run escapes (their distinct
//!   lanes live in the lane array).
//!
//! # v2 block layout
//!
//! | field | size | meaning |
//! |---|---:|---|
//! | key count | 4 | keys in this block (`1..=` [`BLOCK_KEYS`], LE) |
//! | payload length | 4 | bytes of token payload after the restart key (LE) |
//! | restart key | `WIDTH` | first key of the block as its **ordered bits** ([`SortKey::to_bits_ordered`], LE) |
//! | payload | payload length | varint tokens encoding keys 2..=count |
//!
//! Payload tokens (LEB128 varints over the ordered-bits space):
//!
//! * `d ≥ 1` — v2: next key = previous key + `d`; v3: next key =
//!   previous key `+ unzigzag(d)` (wrapping);
//! * `0` followed by `r ≥ 1` — the previous key repeats `r` more times
//!   (the duplicate-run escape: a plateau of `m` equal keys costs
//!   `1 + varint(m)` bytes instead of `m × WIDTH`).
//!
//! The restart key plus the explicit payload length keep blocks
//! *seekable*: [`RunIndex`] walks the block directory once and
//! binary-searches restart keys (block minima — the file is sorted), and
//! [`RunReader::open_range`] skips whole blocks without decoding them, so
//! the sharded merge's cut-offset searches stay `O(log blocks)` +
//! one-block decodes.
//!
//! # Block side-cars
//!
//! A v2 run written by the spill path carries a sibling `<run>.bin.idx`
//! file: a 24-byte header (magic `b"AIPSIDX\0"`, version `u16`, key
//! width `u8`, reserved `u8`, block count `u32`, key count `u64`) and
//! one 32-byte entry per block — `first_bits u64 | last_bits u64 |
//! payload_offset u64 | count u32 | payload_len u32`. The side-car gives
//! [`RunIndex`] the block directory without walking block headers, and
//! its *exact* per-block maxima let shard-boundary searches and narrow
//! range-opens skip whole blocks without decoding them
//! (`shard.blocks.skipped`). Side-cars are advisory: a missing, stale or
//! malformed one falls back to the header walk (`shard.sidecar.miss`),
//! so pre-side-car v2 files keep merging unchanged.

use std::fs::File;
use std::io::{self, BufReader, Read, Seek, SeekFrom, Write};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::external::io::{IoCtx, PoolReader, SpillRead, SpillSink};
use crate::key::{KeyKind, SortKey};

/// Magic prefix of self-describing (v1+) key files.
pub const MAGIC: [u8; 8] = *b"AIPSPILL";

/// Format version of raw fixed-width files (the interchange format).
pub const RAW_VERSION: u16 = 1;

/// Format version of delta+varint block-compressed run files.
pub const DELTA_VERSION: u16 = 2;

/// Format version of zigzag+varint block-compressed (unsorted) files.
pub const ZIGZAG_VERSION: u16 = 3;

/// Format version of raw fixed-width files whose entries carry a lane
/// (records / string keys): v1's layout at `WIDTH = core + lane` bytes
/// per entry.
pub const RECORD_RAW_VERSION: u16 = 4;

/// Format version of delta block files whose entries carry a lane: v2's
/// layout plus a per-block lane array.
pub const RECORD_DELTA_VERSION: u16 = 5;

/// Newest spill-format version this build understands.
pub const FORMAT_VERSION: u16 = RECORD_DELTA_VERSION;

/// Bytes of header preceding the key payload in v1+ files.
pub const HEADER_LEN: usize = 24;

/// Byte offset of the key-count field inside the header (patched by
/// [`RunWriter::finish`] once the count is known).
const COUNT_OFFSET: u64 = 16;

/// Keys per v2 delta block. Small enough that a one-block decode (the
/// unit of [`RunIndex`] random access) stays cheap, large enough that
/// the fixed block framing (8 bytes + one restart key) is noise.
pub const BLOCK_KEYS: usize = 4096;

/// Payload codec of files the external sorter writes. The version byte in
/// every file's header records which codec wrote it, so readers dispatch
/// per file and the two codecs interoperate freely within one sort.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpillCodec {
    /// Fixed-width native-LE keys (format v1) — the interchange format;
    /// works for sorted and unsorted files alike.
    Raw,
    /// Delta+varint blocks (format v2) — sorted runs only; shrinks
    /// duplicate-heavy and small-gap spills well below `WIDTH` bytes/key.
    Delta,
    /// Zigzag+varint blocks (format v3) — any key order; `gen` outputs
    /// ship compressed without the sorted-run precondition. Never
    /// produced by the run/merge paths.
    Zigzag,
}

impl SpillCodec {
    /// Header version this codec writes for lane-free (bare numeric)
    /// keys.
    pub const fn version(self) -> u16 {
        match self {
            SpillCodec::Raw => RAW_VERSION,
            SpillCodec::Delta => DELTA_VERSION,
            SpillCodec::Zigzag => ZIGZAG_VERSION,
        }
    }

    /// Header version this codec writes for an element with `lane` bytes
    /// of lane: the legacy versions when `lane == 0` (byte-identical
    /// files), the record versions otherwise. Zigzag never carries lanes
    /// — the writers reject that combination before a header exists.
    pub const fn version_for(self, lane: usize) -> u16 {
        match (self, lane) {
            (SpillCodec::Raw, 0) | (SpillCodec::Zigzag, _) => self.version(),
            (SpillCodec::Delta, 0) => DELTA_VERSION,
            (SpillCodec::Raw, _) => RECORD_RAW_VERSION,
            (SpillCodec::Delta, _) => RECORD_DELTA_VERSION,
        }
    }

    /// CLI spelling of the codec.
    pub const fn name(self) -> &'static str {
        match self {
            SpillCodec::Raw => "raw",
            SpillCodec::Delta => "delta",
            SpillCodec::Zigzag => "zigzag",
        }
    }

    /// Parse a CLI spelling (`raw`, `delta`, `zigzag`).
    pub fn parse(s: &str) -> Option<SpillCodec> {
        match s {
            "raw" => Some(SpillCodec::Raw),
            "delta" => Some(SpillCodec::Delta),
            "zigzag" => Some(SpillCodec::Zigzag),
            _ => None,
        }
    }

    /// Codec selected by the `SPILL_CODEC` environment variable, if set to
    /// a valid spelling (CI runs the external suite once per codec this
    /// way; see `ExternalConfig::spill_codec`).
    pub fn from_env() -> Option<SpillCodec> {
        std::env::var("SPILL_CODEC")
            .ok()
            .and_then(|v| SpillCodec::parse(v.trim()))
    }
}

/// Payload layout of a key file, dispatched from the header's version
/// field (`V0` = no header at all).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpillVersion {
    /// Legacy headerless raw 8-byte keys (read-only).
    V0,
    /// Raw fixed-width keys behind the v1 header.
    V1,
    /// Delta+varint blocks behind the v2 header.
    V2,
    /// Zigzag+varint blocks behind the v3 header (unsorted-capable).
    V3,
    /// Raw fixed-width lane-carrying entries behind the v4 header.
    V4,
    /// Delta blocks with per-block lane arrays behind the v5 header.
    V5,
}

impl SpillVersion {
    /// Map a header version field to its layout; `None` for versions this
    /// build does not understand.
    pub const fn of(version: u16) -> Option<SpillVersion> {
        match version {
            1 => Some(SpillVersion::V1),
            2 => Some(SpillVersion::V2),
            3 => Some(SpillVersion::V3),
            4 => Some(SpillVersion::V4),
            5 => Some(SpillVersion::V5),
            _ => None,
        }
    }

    /// The header version field this layout is spelled as (0 for the
    /// headerless legacy format).
    pub const fn code(self) -> u16 {
        match self {
            SpillVersion::V0 => 0,
            SpillVersion::V1 => RAW_VERSION,
            SpillVersion::V2 => DELTA_VERSION,
            SpillVersion::V3 => ZIGZAG_VERSION,
            SpillVersion::V4 => RECORD_RAW_VERSION,
            SpillVersion::V5 => RECORD_DELTA_VERSION,
        }
    }
}

/// Decoded header of a self-describing key file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpillHeader {
    /// Format version the file was written with.
    pub version: u16,
    /// Key domain of the payload.
    pub kind: KeyKind,
    /// Keys in the payload.
    pub count: u64,
    /// Trailing zero bytes past the payload — nonzero only when an
    /// `O_DIRECT` writer rounded the file up to the IO alignment. Readers
    /// subtract it from the file length everywhere the payload's byte
    /// extent matters; pre-pad writers left these header bytes zero, so
    /// old files decode as `pad == 0` unchanged.
    pub pad: u32,
    /// Lane bytes per entry ([`SortKey::LANE_WIDTH`]): record payload plus
    /// string tail. `0` for the legacy bare-key formats (v1–v3), `≥ 1`
    /// for the record formats (v4/v5).
    pub lane: u8,
}

impl SpillHeader {
    /// Header for a fresh **raw** (v1, interchange-format) file of `count`
    /// lane-free keys.
    pub fn new(kind: KeyKind, count: u64) -> SpillHeader {
        SpillHeader {
            version: RAW_VERSION,
            kind,
            count,
            pad: 0,
            lane: 0,
        }
    }

    /// Header for a fresh lane-free file written with `codec`.
    pub fn for_codec(codec: SpillCodec, kind: KeyKind, count: u64) -> SpillHeader {
        SpillHeader {
            version: codec.version(),
            kind,
            count,
            pad: 0,
            lane: 0,
        }
    }

    /// Header for a fresh file of `count` elements of type `K` written
    /// with `codec` — the lane-aware constructor every writer uses:
    /// lane-free keys get the legacy versions byte-for-byte, records and
    /// string keys the record versions.
    pub fn for_sort_key<K: SortKey>(codec: SpillCodec, count: u64) -> SpillHeader {
        SpillHeader {
            version: codec.version_for(K::LANE_WIDTH),
            kind: K::KIND,
            count,
            pad: 0,
            lane: K::LANE_WIDTH as u8,
        }
    }

    /// Payload layout behind this header.
    pub fn spill_version(&self) -> SpillVersion {
        SpillVersion::of(self.version).expect("decode validated the version")
    }

    /// Bytes per entry of the fixed-width (v1/v4) layout: the core key
    /// width plus the lane.
    pub fn entry_width(&self) -> usize {
        self.kind.width() + self.lane as usize
    }

    /// Serialize into the on-disk layout (see the module docs). Byte 11
    /// doubles as the redundant key width (lane-free formats) or the lane
    /// width (record formats) — the two never collide because record
    /// lanes are `≥ 1` only under the record version numbers.
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut b = [0u8; HEADER_LEN];
        b[..8].copy_from_slice(&MAGIC);
        b[8..10].copy_from_slice(&self.version.to_le_bytes());
        b[10] = self.kind.tag();
        b[11] = if self.lane == 0 {
            self.kind.width() as u8
        } else {
            self.lane
        };
        b[12..16].copy_from_slice(&self.pad.to_le_bytes());
        b[16..24].copy_from_slice(&self.count.to_le_bytes());
        b
    }

    /// Parse and validate an on-disk header (the caller has already
    /// matched the magic).
    fn decode(b: &[u8; HEADER_LEN], path: &Path) -> io::Result<SpillHeader> {
        debug_assert_eq!(&b[..8], &MAGIC);
        let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
        let version = u16::from_le_bytes([b[8], b[9]]);
        let Some(v) = SpillVersion::of(version) else {
            return Err(bad(format!(
                "{}: unsupported spill format version {version} (this build reads v1..=v{FORMAT_VERSION})",
                path.display()
            )));
        };
        let kind = KeyKind::from_tag(b[10]).ok_or_else(|| {
            bad(format!(
                "{}: unknown key-type tag {} in spill header",
                path.display(),
                b[10]
            ))
        })?;
        let lane = match v {
            SpillVersion::V4 | SpillVersion::V5 => {
                if b[11] == 0 {
                    return Err(bad(format!(
                        "{}: record spill header carries a zero lane width \
                         (lane-free files use format v1..=v3)",
                        path.display()
                    )));
                }
                b[11]
            }
            _ => {
                if b[11] as usize != kind.width() {
                    return Err(bad(format!(
                        "{}: header key width {} does not match key type {} (width {})",
                        path.display(),
                        b[11],
                        kind.name(),
                        kind.width()
                    )));
                }
                0
            }
        };
        let pad = u32::from_le_bytes(b[12..16].try_into().unwrap());
        let count = u64::from_le_bytes(b[16..24].try_into().unwrap());
        Ok(SpillHeader {
            version,
            kind,
            count,
            pad,
            lane,
        })
    }
}

/// `InvalidData` error with the file path prefixed.
fn bad_data(path: &Path, msg: &str) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!("{}: {msg}", path.display()),
    )
}

/// Read the header of a key file: `Some` for self-describing (v1+) files,
/// `None` for legacy headerless (v0) files. Malformed headers — matching
/// magic but bad version/tag/width — are errors, not `None`.
pub fn read_header(path: &Path) -> io::Result<Option<SpillHeader>> {
    let mut file = File::open(path)?;
    parse_header(&mut file, path)
}

/// Header probe over an open file; leaves the cursor unspecified.
fn parse_header(file: &mut File, path: &Path) -> io::Result<Option<SpillHeader>> {
    let len = file.metadata()?.len();
    if len < MAGIC.len() as u64 {
        return Ok(None);
    }
    file.seek(SeekFrom::Start(0))?;
    let mut probe = [0u8; 8];
    file.read_exact(&mut probe)?;
    if probe != MAGIC {
        return Ok(None);
    }
    if len < HEADER_LEN as u64 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "{}: truncated spill header ({len} bytes, need {HEADER_LEN})",
                path.display()
            ),
        ));
    }
    let mut buf = [0u8; HEADER_LEN];
    buf[..8].copy_from_slice(&probe);
    file.read_exact(&mut buf[8..])?;
    SpillHeader::decode(&buf, path).map(Some)
}

/// Resolved location and layout of the key payload inside a file.
#[derive(Debug, Clone, Copy)]
struct KeyLayout {
    /// Payload codec (dispatched from the header's version byte).
    version: SpillVersion,
    /// Byte offset of the first key ([`HEADER_LEN`], or 0 for v0 files).
    data_start: u64,
    /// Keys in the file.
    n: u64,
    /// Direct-IO pad bytes past the payload (0 for v0 files).
    pad: u64,
}

/// Byte length of a headered file's payload: the file length minus the
/// header and the direct-IO pad, rejecting a pad the file cannot hold.
fn payload_extent(h: &SpillHeader, len: u64, path: &Path) -> io::Result<u64> {
    (len - HEADER_LEN as u64)
        .checked_sub(h.pad as u64)
        .ok_or_else(|| bad_data(path, "direct-IO pad larger than the file's payload"))
}

/// Check that a v1/v4 file's byte length holds exactly the header's
/// `count` fixed-width entries (shared by [`resolve_layout`] and
/// [`file_key_count`]).
fn validate_payload_v1(h: &SpillHeader, len: u64, path: &Path) -> io::Result<()> {
    let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
    let payload = payload_extent(h, len, path)?;
    let expect = h.count.checked_mul(h.entry_width() as u64).ok_or_else(|| {
        bad(format!(
            "{}: absurd key count {} in spill header",
            path.display(),
            h.count
        ))
    })?;
    if payload != expect {
        return Err(bad(format!(
            "{}: truncated or oversized payload — header promises {} {} keys \
             ({expect} bytes) but the file holds {payload}",
            path.display(),
            h.count,
            h.kind.name()
        )));
    }
    Ok(())
}

/// Cheap open-time sanity check of a v2 file's length (a nonempty file
/// must at least hold one block header; the exact key count is validated
/// by the block walk in [`file_key_count`]/[`RunIndex`] and by streaming
/// reads).
fn validate_payload_v2(h: &SpillHeader, len: u64, path: &Path) -> io::Result<()> {
    let payload = payload_extent(h, len, path)?;
    if h.count == 0 && payload != 0 {
        return Err(bad_data(
            path,
            "delta file promises 0 keys but carries payload bytes",
        ));
    }
    if h.count > 0 && payload < (8 + h.kind.width()) as u64 {
        return Err(bad_data(
            path,
            "truncated delta payload (shorter than one block header)",
        ));
    }
    Ok(())
}

/// Validate a file against the expected key domain and lane width, and
/// locate its payload. Accepts headered files of exactly `kind`/`lane`,
/// and headerless v0 files only for lane-free 8-byte key types.
fn resolve_layout(
    file: &mut File,
    path: &Path,
    kind: KeyKind,
    lane: usize,
) -> io::Result<KeyLayout> {
    let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
    let len = file.metadata()?.len();
    match parse_header(file, path)? {
        Some(h) => {
            if h.kind != kind {
                return Err(bad(format!(
                    "{}: file holds {} keys but the sort was invoked for {}",
                    path.display(),
                    h.kind.name(),
                    kind.name()
                )));
            }
            if h.lane as usize != lane {
                return Err(bad(format!(
                    "{}: file entries carry a {}-byte lane but the sort \
                     expects {} (record payload widths must match)",
                    path.display(),
                    h.lane,
                    lane
                )));
            }
            let version = h.spill_version();
            match version {
                SpillVersion::V1 | SpillVersion::V4 => validate_payload_v1(&h, len, path)?,
                SpillVersion::V2 | SpillVersion::V3 | SpillVersion::V5 => {
                    validate_payload_v2(&h, len, path)?
                }
                SpillVersion::V0 => unreachable!("headered files are v1+"),
            }
            Ok(KeyLayout {
                version,
                data_start: HEADER_LEN as u64,
                n: h.count,
                pad: h.pad as u64,
            })
        }
        None => {
            if kind.width() != 8 || lane != 0 {
                return Err(bad(format!(
                    "{}: headerless (v0) key files hold bare 8-byte keys; {} \
                     requires a self-describing header (write it with this \
                     build's gen)",
                    path.display(),
                    kind.name()
                )));
            }
            Ok(KeyLayout {
                version: SpillVersion::V0,
                data_start: 0,
                n: v0_key_count(len, path)?,
                pad: 0,
            })
        }
    }
}

/// Validate a headerless (v0) file's length and return its key count —
/// `length % 8 == 0` is the only check the legacy format affords.
fn v0_key_count(len: u64, path: &Path) -> io::Result<u64> {
    if len % 8 != 0 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "{}: length {len} is not a multiple of 8 (headerless v0 key file)",
                path.display()
            ),
        ));
    }
    Ok(len / 8)
}

// ---------------------------------------------------------------------------
// v2 block primitives: LEB128 varints + block header IO + block decode.
// ---------------------------------------------------------------------------

/// Append `v` as an LEB128 varint (7 payload bits per byte, continuation
/// in the top bit; at most 10 bytes for a `u64`).
fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

/// Map a signed delta into the varint token space (v3): interleaves
/// negatives and positives so small steps of either sign stay short.
/// `zigzag(d) == 0` iff `d == 0`, which the dup-run escape owns — a v3
/// payload never encodes a zero delta as a plain token.
fn zigzag(d: i64) -> u64 {
    ((d << 1) ^ (d >> 63)) as u64
}

/// Inverse of [`zigzag`].
fn unzigzag(t: u64) -> i64 {
    ((t >> 1) as i64) ^ -((t & 1) as i64)
}

/// `read_exact` with truncation mapped to a clear block-level error.
fn read_exact_block<R: Read>(r: &mut R, buf: &mut [u8], path: &Path) -> io::Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            bad_data(path, "truncated delta block")
        } else {
            e
        }
    })
}

/// Read one LEB128 varint, charging each byte against the block's
/// remaining payload `budget` so a corrupt payload length fails loudly
/// instead of decoding into the next block.
fn read_varint<R: Read>(r: &mut R, budget: &mut u32, path: &Path) -> io::Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        if *budget == 0 {
            return Err(bad_data(
                path,
                "delta block payload ends mid-varint (corrupt payload length)",
            ));
        }
        let mut b = [0u8; 1];
        read_exact_block(r, &mut b, path)?;
        *budget -= 1;
        let byte = b[0];
        if shift >= 63 && (byte & 0x7F) > 1 {
            return Err(bad_data(path, "varint overflows 64 bits in delta block"));
        }
        v |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(bad_data(path, "varint longer than 10 bytes in delta block"));
        }
    }
}

/// Read a v2 block header: `(key count, payload length, restart key's
/// ordered bits)`. `key_width` is in bytes (≤ 8; the restart key
/// zero-extends into the `u64` ordered-bits space).
fn read_block_header<R: Read>(
    r: &mut R,
    key_width: usize,
    path: &Path,
) -> io::Result<(u32, u32, u64)> {
    let mut fixed = [0u8; 8];
    read_exact_block(r, &mut fixed, path)?;
    let count = u32::from_le_bytes(fixed[..4].try_into().unwrap());
    let payload_len = u32::from_le_bytes(fixed[4..8].try_into().unwrap());
    if count == 0 {
        return Err(bad_data(path, "empty delta block (key count 0)"));
    }
    if count as usize > BLOCK_KEYS {
        // the bound the format promises — and the cap on what a corrupt
        // count can make the block-decode paths allocate
        return Err(bad_data(path, "oversized delta block (key count over the block cap)"));
    }
    let mut kb = [0u8; 8];
    read_exact_block(r, &mut kb[..key_width], path)?;
    Ok((count, payload_len, u64::from_le_bytes(kb)))
}

/// Decode one whole block's keys (as ordered bits) from its token
/// payload. Used by [`RunIndex`] random access; the streaming readers
/// decode incrementally instead.
fn decode_block_bits<K: SortKey>(
    payload: &[u8],
    first: u64,
    count: u32,
    path: &Path,
) -> io::Result<Vec<u64>> {
    let mut out = Vec::with_capacity(count as usize);
    out.push(first);
    let mut prev = first;
    let mut cur = payload;
    let mut budget = payload.len() as u32;
    while (out.len() as u32) < count {
        let d = read_varint(&mut cur, &mut budget, path)?;
        if d == 0 {
            let run = read_varint(&mut cur, &mut budget, path)?;
            if run == 0 {
                return Err(bad_data(path, "zero-length duplicate run in delta block"));
            }
            if out.len() as u64 + run > count as u64 {
                return Err(bad_data(path, "duplicate run overruns its delta block"));
            }
            for _ in 0..run {
                out.push(prev);
            }
        } else {
            prev = match prev.checked_add(d) {
                Some(b) if b <= K::max_ordered_bits() => b,
                _ => return Err(bad_data(path, "key delta overflows the key domain")),
            };
            out.push(prev);
        }
    }
    if budget != 0 {
        return Err(bad_data(
            path,
            "delta block payload is longer than its tokens (corrupt block framing)",
        ));
    }
    Ok(out)
}

/// One entry of a v2 file's block directory.
#[derive(Debug, Clone)]
struct BlockEntry {
    /// Ordered bits of the block's first (minimum) key.
    first_bits: u64,
    /// Ordered bits of an upper bound on the block's last (maximum) key:
    /// exact when the entry came from a side-car, the next block's
    /// restart key (or `u64::MAX` for the final block) when derived from
    /// a header walk. Skip decisions fire only when a bound exceeds this,
    /// so an inexact bound degrades to a decode, never a wrong answer.
    last_bits: u64,
    /// Key index of the block's first key within the file.
    start_idx: u64,
    /// Byte offset of the token payload (past the block header).
    payload_offset: u64,
    /// Keys in the block.
    count: u32,
    /// Bytes of token payload.
    payload_len: u32,
}

/// Walk a v2 file's blocks, validating framing (exact file length, key
/// counts summing to the header's promise, nondecreasing restart keys)
/// and returning the directory.
fn walk_v2_blocks(
    file: &mut File,
    path: &Path,
    n: u64,
    width: usize,
    pad: u64,
    sorted: bool,
) -> io::Result<Vec<BlockEntry>> {
    let len = file
        .metadata()?
        .len()
        .checked_sub(pad)
        .ok_or_else(|| bad_data(path, "direct-IO pad larger than the file's payload"))?;
    let mut pos = HEADER_LEN as u64;
    file.seek(SeekFrom::Start(pos))?;
    let mut blocks: Vec<BlockEntry> = Vec::new();
    let mut start_idx = 0u64;
    while pos < len {
        let (count, payload_len, first_bits) = read_block_header(file, width, path)?;
        pos += (8 + width) as u64;
        if pos + payload_len as u64 > len {
            return Err(bad_data(path, "truncated delta block payload"));
        }
        if sorted && blocks.last().is_some_and(|prev| first_bits < prev.first_bits) {
            return Err(bad_data(path, "delta block restart keys out of order"));
        }
        // the walk never decodes payloads, so the per-block maximum is
        // only bounded by the next block's restart (patched up below)
        if let Some(prev) = blocks.last_mut() {
            prev.last_bits = first_bits;
        }
        blocks.push(BlockEntry {
            first_bits,
            last_bits: u64::MAX,
            start_idx,
            payload_offset: pos,
            count,
            payload_len,
        });
        start_idx += count as u64;
        pos += payload_len as u64;
        file.seek(SeekFrom::Start(pos))?;
    }
    if start_idx != n {
        return Err(bad_data(
            path,
            &format!("delta blocks hold {start_idx} keys but the header promises {n}"),
        ));
    }
    Ok(blocks)
}

/// A v2 run's validated block directory, detached from the [`RunIndex`]
/// that built it. The shard planner walks every v2 run's block headers
/// once (inside [`RunIndex::open`]); handing the resulting directory to
/// [`RunReader::open_range_with`] lets each shard's range-open seek
/// straight to its first block — `O(log blocks)` — instead of re-walking
/// every block header before the range start.
#[derive(Debug, Clone)]
pub struct BlockDirectory {
    blocks: Vec<BlockEntry>,
    /// Key count of the file the directory was built from (cross-checked
    /// on use so a stale directory degrades to the re-walk path instead
    /// of mis-seeking).
    n: u64,
}

impl BlockDirectory {
    /// Number of delta blocks in the indexed file.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }
}

/// Magic prefix of block side-car (`.idx`) files.
const SIDECAR_MAGIC: [u8; 8] = *b"AIPSIDX\0";

/// Side-car format version.
const SIDECAR_VERSION: u16 = 1;

/// Bytes of side-car header (magic, version, width, reserved, block
/// count, key count).
const SIDECAR_HEADER_LEN: usize = 24;

/// Bytes per side-car block entry.
const SIDECAR_ENTRY_LEN: usize = 32;

/// Location of a run's block side-car: the run path with `.idx`
/// appended (not substituted — `run-000001.bin.idx` sits next to
/// `run-000001.bin`).
pub(crate) fn sidecar_path(run: &Path) -> PathBuf {
    let mut s = run.as_os_str().to_os_string();
    s.push(".idx");
    PathBuf::from(s)
}

/// Write a run's block side-car. Callers treat failure as advisory
/// (remove the partial file, keep the run).
fn write_sidecar(run: &Path, width: usize, n: u64, blocks: &[BlockEntry]) -> io::Result<()> {
    let mut buf = Vec::with_capacity(SIDECAR_HEADER_LEN + blocks.len() * SIDECAR_ENTRY_LEN);
    buf.extend_from_slice(&SIDECAR_MAGIC);
    buf.extend_from_slice(&SIDECAR_VERSION.to_le_bytes());
    buf.push(width as u8);
    buf.push(0);
    buf.extend_from_slice(&(blocks.len() as u32).to_le_bytes());
    buf.extend_from_slice(&n.to_le_bytes());
    for e in blocks {
        buf.extend_from_slice(&e.first_bits.to_le_bytes());
        buf.extend_from_slice(&e.last_bits.to_le_bytes());
        buf.extend_from_slice(&e.payload_offset.to_le_bytes());
        buf.extend_from_slice(&e.count.to_le_bytes());
        buf.extend_from_slice(&e.payload_len.to_le_bytes());
    }
    std::fs::write(sidecar_path(run), &buf)
}

/// Load and validate a run's block side-car against the run's header
/// (`width`/`n`) and payload extent (`payload_len`, already pad-free).
/// Any mismatch — missing file, framing that does not chain exactly
/// through the payload, counts that disagree with the header, unordered
/// or inconsistent key bounds — returns `None` and the caller falls back
/// to the block-header walk, so a stale side-car can degrade performance
/// but never correctness.
fn load_sidecar(run: &Path, width: usize, n: u64, payload_len: u64) -> Option<Vec<BlockEntry>> {
    let bytes = std::fs::read(sidecar_path(run)).ok()?;
    if bytes.len() < SIDECAR_HEADER_LEN || bytes[..8] != SIDECAR_MAGIC {
        return None;
    }
    if u16::from_le_bytes(bytes[8..10].try_into().unwrap()) != SIDECAR_VERSION
        || bytes[10] as usize != width
    {
        return None;
    }
    let n_blocks = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    if u64::from_le_bytes(bytes[16..24].try_into().unwrap()) != n
        || bytes.len() != SIDECAR_HEADER_LEN + n_blocks * SIDECAR_ENTRY_LEN
    {
        return None;
    }
    let mut blocks = Vec::with_capacity(n_blocks);
    let mut start_idx = 0u64;
    let mut expect_off = (HEADER_LEN + 8 + width) as u64;
    for chunk in bytes[SIDECAR_HEADER_LEN..].chunks_exact(SIDECAR_ENTRY_LEN) {
        let first_bits = u64::from_le_bytes(chunk[..8].try_into().unwrap());
        let last_bits = u64::from_le_bytes(chunk[8..16].try_into().unwrap());
        let payload_offset = u64::from_le_bytes(chunk[16..24].try_into().unwrap());
        let count = u32::from_le_bytes(chunk[24..28].try_into().unwrap());
        let plen = u32::from_le_bytes(chunk[28..32].try_into().unwrap());
        let in_order = blocks
            .last()
            .is_none_or(|p: &BlockEntry| p.first_bits <= first_bits && p.last_bits <= first_bits);
        if payload_offset != expect_off
            || count == 0
            || count as usize > BLOCK_KEYS
            || first_bits > last_bits
            || !in_order
        {
            return None;
        }
        blocks.push(BlockEntry {
            first_bits,
            last_bits,
            start_idx,
            payload_offset,
            count,
            payload_len: plen,
        });
        start_idx += count as u64;
        expect_off += plen as u64 + (8 + width) as u64;
    }
    // the chained offsets must land exactly at the payload's end, and
    // the per-block counts must sum to the header's promise
    if start_idx != n || expect_off - (8 + width) as u64 != HEADER_LEN as u64 + payload_len {
        return None;
    }
    Some(blocks)
}

/// A spilled run (or any key file) on disk.
#[derive(Debug, Clone)]
pub struct RunFile {
    /// Location of the run on disk.
    pub path: PathBuf,
    /// Number of keys in the file.
    pub n: u64,
    /// Total bytes on disk (header + payload) — with the delta codec this
    /// is what the run *actually* costs in IO, vs `HEADER_LEN + n × WIDTH`
    /// for raw.
    pub bytes: u64,
}

/// Scratch directories owning the spilled runs of one sort — one stripe
/// per configured spill root, with run paths dealt round-robin across
/// stripes so a multi-disk setup spreads spill bandwidth. All stripes
/// are removed (best-effort) on drop.
#[derive(Debug)]
pub struct SpillDir {
    dirs: Vec<PathBuf>,
    counter: u64,
}

impl SpillDir {
    /// Create a fresh uniquely-named scratch directory under `base`
    /// (`None` = the OS temp dir) — the single-stripe case.
    pub fn create(base: Option<&Path>) -> io::Result<SpillDir> {
        match base {
            Some(b) => Self::create_striped(std::slice::from_ref(&b.to_path_buf())),
            None => Self::create_striped(&[]),
        }
    }

    /// Create one uniquely-named scratch directory under *each* root
    /// (`[]` = one stripe in the OS temp dir). Every stripe of one sort
    /// shares a sequence number; stripes are suffixed `-s0`, `-s1`, …
    pub fn create_striped(roots: &[PathBuf]) -> io::Result<SpillDir> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp;
        let roots: &[PathBuf] = if roots.is_empty() {
            tmp = [std::env::temp_dir()];
            &tmp
        } else {
            roots
        };
        let mut dirs: Vec<PathBuf> = Vec::with_capacity(roots.len());
        for (i, root) in roots.iter().enumerate() {
            let dir = root.join(format!("aipso-extsort-{}-{seq}-s{i}", std::process::id()));
            if let Err(e) = std::fs::create_dir_all(&dir) {
                for made in &dirs {
                    let _ = std::fs::remove_dir_all(made);
                }
                return Err(e);
            }
            dirs.push(dir);
        }
        Ok(SpillDir { dirs, counter: 0 })
    }

    /// The first stripe's location (the only one in unstriped setups).
    pub fn path(&self) -> &Path {
        &self.dirs[0]
    }

    /// Number of stripes runs are dealt across.
    pub fn num_stripes(&self) -> usize {
        self.dirs.len()
    }

    /// Fresh path for the next spilled run, rotating across stripes.
    pub fn next_run_path(&mut self) -> PathBuf {
        let dir = &self.dirs[(self.counter as usize) % self.dirs.len()];
        self.counter += 1;
        dir.join(format!("run-{:06}.bin", self.counter))
    }
}

impl Drop for SpillDir {
    fn drop(&mut self) {
        for dir in &self.dirs {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

/// Keys per decode/encode slab pass (the slab is a fixed byte array so
/// peak memory stays `O(slab)` regardless of chunk size).
const SLAB_BYTES: usize = 8192;

/// Streaming decoder state of one v2 reader: at most one block is "open"
/// at a time, and within it at most one duplicate run — O(1) memory.
#[derive(Default)]
struct DeltaState {
    /// Ordered bits of the last decoded key.
    prev: u64,
    /// Keys in the current block (fixed at block open; indexes the lane
    /// array).
    block_count: u32,
    /// Keys of the current block not yet emitted.
    block_remaining: u32,
    /// Token-payload bytes of the current block not yet consumed.
    payload_remaining: u32,
    /// Further copies of `prev` still owed by a duplicate-run token.
    pending_run: u64,
    /// The next emit is the block's restart key itself.
    emit_restart: bool,
    /// Tokens carry zigzag-mapped signed deltas (v3) instead of plain
    /// non-negative deltas (v2).
    zigzag: bool,
    /// The current block's lane array (v5 only; empty for lane-free
    /// streams) — `block_count × LANE_WIDTH` bytes, indexed per key.
    lanes: Vec<u8>,
}

impl DeltaState {
    /// Fresh decoder state for the given payload layout.
    fn for_version(version: SpillVersion) -> DeltaState {
        DeltaState {
            zigzag: version == SpillVersion::V3,
            ..DeltaState::default()
        }
    }
}

/// Per-codec decoding state of a [`RunReader`].
enum Dec {
    /// v0/v1 fixed-width keys.
    Raw,
    /// v2/v3 delta blocks.
    Delta(DeltaState),
}

/// Byte source of a [`RunReader`]: a plain buffered reader (sync
/// backend) or a pool-backed read-ahead stream (submission backend).
enum Src {
    Buf(BufReader<File>),
    Pool(PoolReader),
}

impl Src {
    /// Position the next read at absolute file offset `off`.
    fn seek_abs(&mut self, off: u64) -> io::Result<()> {
        match self {
            Src::Buf(b) => b.seek(SeekFrom::Start(off)).map(|_| ()),
            Src::Pool(p) => {
                p.seek_to(off);
                Ok(())
            }
        }
    }
}

impl Read for Src {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        match self {
            Src::Buf(b) => b.read(out),
            Src::Pool(p) => p.read(out),
        }
    }
}

impl SpillRead for Src {
    fn seek_relative(&mut self, delta: i64) -> io::Result<()> {
        match self {
            Src::Buf(b) => b.seek_relative(delta),
            Src::Pool(p) => SpillRead::seek_relative(p, delta),
        }
    }
}

/// Load the just-opened block's lane array (v5 — `K::LANE_WIDTH > 0`).
/// The block's payload length covers lanes + tokens, so the lane bytes
/// are charged against the payload budget up front and the varint budget
/// checks keep working unchanged. No-op for lane-free streams.
fn read_block_lanes<K: SortKey, R: SpillRead>(
    r: &mut R,
    st: &mut DeltaState,
    path: &Path,
) -> io::Result<()> {
    if K::LANE_WIDTH == 0 {
        return Ok(());
    }
    let lane_bytes = st.block_count as usize * K::LANE_WIDTH;
    if lane_bytes as u64 > st.payload_remaining as u64 {
        return Err(bad_data(
            path,
            "record block payload shorter than its lane array",
        ));
    }
    st.lanes.resize(lane_bytes, 0);
    read_exact_block(r, &mut st.lanes, path)?;
    st.payload_remaining -= lane_bytes as u32;
    Ok(())
}

/// Rebuild key `idx`-of-block from its decoded ordered bits plus its
/// entry in the block's lane array (exact for every supported type;
/// lane-free keys reconstruct from bits alone).
#[inline(always)]
fn key_with_lane<K: SortKey>(st: &DeltaState, bits: u64, idx: usize) -> K {
    if K::LANE_WIDTH == 0 {
        K::from_bits_ordered(bits)
    } else {
        K::with_lane(bits, &st.lanes[idx * K::LANE_WIDTH..(idx + 1) * K::LANE_WIDTH])
    }
}

/// Decode the next key of a v2/v3/v5 stream (the caller tracks how many
/// keys remain and never over-calls).
fn next_delta<K: SortKey, R: SpillRead>(
    r: &mut R,
    st: &mut DeltaState,
    path: &Path,
) -> io::Result<K> {
    if st.block_remaining == 0 {
        if st.payload_remaining != 0 {
            return Err(bad_data(
                path,
                "delta block payload is longer than its tokens (corrupt block framing)",
            ));
        }
        let cw = K::WIDTH - K::LANE_WIDTH;
        let (count, payload_len, first) = read_block_header(r, cw, path)?;
        st.prev = first;
        st.block_count = count;
        st.block_remaining = count;
        st.payload_remaining = payload_len;
        st.pending_run = 0;
        st.emit_restart = true;
        read_block_lanes::<K, R>(r, st, path)?;
    }
    // lane index of the key being emitted — before the decrement
    let idx = (st.block_count - st.block_remaining) as usize;
    st.block_remaining -= 1;
    if st.emit_restart {
        st.emit_restart = false;
        return Ok(key_with_lane::<K>(st, st.prev, idx));
    }
    if st.pending_run > 0 {
        st.pending_run -= 1;
        return Ok(key_with_lane::<K>(st, st.prev, idx));
    }
    let d = read_varint(r, &mut st.payload_remaining, path)?;
    if d == 0 {
        let run = read_varint(r, &mut st.payload_remaining, path)?;
        if run == 0 {
            return Err(bad_data(path, "zero-length duplicate run in delta block"));
        }
        if run - 1 > st.block_remaining as u64 {
            return Err(bad_data(path, "duplicate run overruns its delta block"));
        }
        st.pending_run = run - 1;
        return Ok(key_with_lane::<K>(st, st.prev, idx));
    }
    let next = if st.zigzag {
        // signed step over the ordered-bits space; exact mod 2^64, and
        // the domain check catches narrow-width escapes on corrupt data
        let b = st.prev.wrapping_add(unzigzag(d) as u64);
        if b > K::max_ordered_bits() {
            return Err(bad_data(path, "key delta overflows the key domain"));
        }
        b
    } else {
        match st.prev.checked_add(d) {
            Some(b) if b <= K::max_ordered_bits() => b,
            _ => return Err(bad_data(path, "key delta overflows the key domain")),
        }
    };
    st.prev = next;
    Ok(key_with_lane::<K>(st, next, idx))
}

/// Skip `skip` keys of a v2/v3/v5 stream positioned at a block boundary,
/// seeking over whole blocks (restart key + payload length — no decode;
/// a v5 payload length covers the lane array too, so the seek clears it
/// in the same hop) and decode-skipping only inside the final partial
/// block.
fn skip_delta<K: SortKey, R: SpillRead>(
    r: &mut R,
    st: &mut DeltaState,
    path: &Path,
    mut skip: u64,
) -> io::Result<()> {
    while skip > 0 {
        if st.block_remaining == 0 {
            let cw = K::WIDTH - K::LANE_WIDTH;
            let (count, payload_len, first) = read_block_header(r, cw, path)?;
            if count as u64 <= skip {
                skip -= count as u64;
                r.seek_relative(payload_len as i64)?;
                continue;
            }
            st.prev = first;
            st.block_count = count;
            st.block_remaining = count;
            st.payload_remaining = payload_len;
            st.pending_run = 0;
            st.emit_restart = true;
            read_block_lanes::<K, R>(r, st, path)?;
        }
        next_delta::<K, R>(r, st, path)?;
        skip -= 1;
    }
    Ok(())
}

/// Buffered streaming reader over a key file (any version — the payload
/// codec is dispatched from the file's header).
pub struct RunReader<K: SortKey> {
    r: Src,
    path: PathBuf,
    remaining: u64,
    dec: Dec,
    _pd: PhantomData<K>,
}

impl<K: SortKey> RunReader<K> {
    /// Open a buffered reader over a whole key file (validating its
    /// header, or accepting a headerless v0 file for 8-byte key types).
    pub fn open(path: &Path, io_buffer: usize) -> io::Result<RunReader<K>> {
        Self::open_range(path, 0, u64::MAX, io_buffer)
    }

    /// Open a buffered reader over the key range `[start, start + len)` of
    /// a key file (indices in keys, clamped to the file). The sharded
    /// merge streams each run's shard segment through one of these; on v2
    /// files the skip to `start` seeks over whole blocks and decodes only
    /// the final partial one.
    pub fn open_range(
        path: &Path,
        start: u64,
        len: u64,
        io_buffer: usize,
    ) -> io::Result<RunReader<K>> {
        Self::open_range_with(path, start, len, io_buffer, None)
    }

    /// [`RunReader::open_range`] with an optional precomputed
    /// [`BlockDirectory`]. On v2 files with a matching directory the skip
    /// to `start` becomes one binary search plus a direct seek to the
    /// containing block (`obs` counter `shard.dir.hit`); without one —
    /// or on a directory whose key count no longer matches the file —
    /// the reader falls back to walking block headers from the front
    /// (`shard.dir.rewalk`). Raw files ignore the directory: their seek
    /// is already O(1) arithmetic.
    pub fn open_range_with(
        path: &Path,
        start: u64,
        len: u64,
        io_buffer: usize,
        dir: Option<&BlockDirectory>,
    ) -> io::Result<RunReader<K>> {
        Self::open_range_ctx(path, start, len, io_buffer, dir, None, &IoCtx::sync())
    }

    /// The most general open: [`RunReader::open_range_with`] plus an
    /// optional already-parsed header (skipping the per-source header
    /// re-read when the shard planner validated the file moments ago)
    /// and an [`IoCtx`] choosing the byte source — a plain buffered
    /// reader, or pool-backed read-ahead on the submission backend.
    pub(crate) fn open_range_ctx(
        path: &Path,
        start: u64,
        len: u64,
        io_buffer: usize,
        dir: Option<&BlockDirectory>,
        header: Option<&SpillHeader>,
        io: &IoCtx,
    ) -> io::Result<RunReader<K>> {
        let mut file = File::open(path)?;
        let layout = match header {
            Some(h) => {
                debug_assert_eq!(h.kind, K::KIND, "cached header for the wrong key type");
                KeyLayout {
                    version: h.spill_version(),
                    data_start: HEADER_LEN as u64,
                    n: h.count,
                    pad: h.pad as u64,
                }
            }
            None => resolve_layout(&mut file, path, K::KIND, K::LANE_WIDTH)?,
        };
        let start = start.min(layout.n);
        let len = len.min(layout.n - start);
        let mut src = match io.pool() {
            Some(pool) => Src::Pool(PoolReader::new(
                file,
                io_buffer.max(4096),
                std::sync::Arc::clone(pool),
            )),
            None => Src::Buf(BufReader::with_capacity(io_buffer.max(4096), file)),
        };
        let dec = match layout.version {
            SpillVersion::V0 | SpillVersion::V1 | SpillVersion::V4 => {
                src.seek_abs(layout.data_start + start * K::WIDTH as u64)?;
                Dec::Raw
            }
            v @ (SpillVersion::V2 | SpillVersion::V3 | SpillVersion::V5) => {
                src.seek_abs(layout.data_start)?;
                Dec::Delta(DeltaState::for_version(v))
            }
        };
        let mut reader = RunReader {
            r: src,
            path: path.to_path_buf(),
            remaining: len,
            dec,
            _pd: PhantomData,
        };
        if let Dec::Delta(st) = &mut reader.dec {
            // a zero-length range must not walk block headers that may
            // not exist past the clamped start
            let mut skip = if len == 0 { 0 } else { start };
            if skip > 0 {
                match dir.filter(|d| d.n == layout.n && !d.blocks.is_empty()) {
                    Some(d) => {
                        // last block whose first key index is <= start:
                        // seek to its header and decode-skip only within it
                        let b = d.blocks.partition_point(|e| e.start_idx <= skip) - 1;
                        let e = &d.blocks[b];
                        // block header = count u32 | payload_len u32 |
                        // restart core bits (lanes live inside the payload)
                        let header_off = e.payload_offset - (8 + K::WIDTH - K::LANE_WIDTH) as u64;
                        reader.r.seek_abs(header_off)?;
                        skip -= e.start_idx;
                        crate::obs::metrics::counter_add(crate::obs::C_DIR_HIT, 1);
                        // every block before the seek target or past the
                        // range's end is never read, let alone decoded
                        let end = d.blocks.partition_point(|e| e.start_idx < start + len);
                        crate::obs::metrics::counter_add(
                            crate::obs::C_BLOCKS_SKIPPED,
                            (d.blocks.len() - (end - b)) as u64,
                        );
                    }
                    None => crate::obs::metrics::counter_add(crate::obs::C_DIR_REWALK, 1),
                }
            }
            skip_delta::<K, Src>(&mut reader.r, st, &reader.path, skip)?;
        }
        Ok(reader)
    }

    /// Keys left in the file.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// Next key, or `None` at end of file.
    #[allow(clippy::should_implement_trait)] // fallible: io::Result, not Iterator
    pub fn next(&mut self) -> io::Result<Option<K>> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let key = match &mut self.dec {
            Dec::Raw => {
                let mut buf = K::Bytes::default();
                self.r.read_exact(buf.as_mut())?;
                K::from_le_bytes(buf)
            }
            Dec::Delta(st) => next_delta::<K, Src>(&mut self.r, st, &self.path)?,
        };
        self.remaining -= 1;
        Ok(Some(key))
    }

    /// Read up to `max` keys; an empty vec means EOF. Raw files decode
    /// through a fixed scratch slab so peak memory stays `max * WIDTH +
    /// O(slab)` — not double the chunk, which would break the sorter's
    /// byte budget; v2 files decode incrementally in O(1) extra memory.
    pub fn read_chunk(&mut self, max: usize) -> io::Result<Vec<K>> {
        let take = (self.remaining.min(max as u64)) as usize;
        if take == 0 {
            return Ok(Vec::new());
        }
        if matches!(self.dec, Dec::Delta(_)) {
            let mut out = Vec::with_capacity(take);
            for _ in 0..take {
                match self.next()? {
                    Some(k) => out.push(k),
                    None => break,
                }
            }
            return Ok(out);
        }
        let per_slab = SLAB_BYTES / K::WIDTH;
        let mut out = Vec::with_capacity(take);
        let mut slab = [0u8; SLAB_BYTES];
        let mut left = take;
        while left > 0 {
            let now = left.min(per_slab);
            let bytes = &mut slab[..now * K::WIDTH];
            self.r.read_exact(bytes)?;
            for c in bytes.chunks_exact(K::WIDTH) {
                let mut b = K::Bytes::default();
                b.as_mut().copy_from_slice(c);
                out.push(K::from_le_bytes(b));
            }
            left -= now;
        }
        self.remaining -= take as u64;
        Ok(out)
    }
}

/// Version-specific random-access state of a [`RunIndex`].
enum IndexKind {
    /// v0/v1: positioned fixed-width reads.
    Raw {
        /// Byte offset of the first key.
        data_start: u64,
    },
    /// v2: block directory + one-block decode cache. `lower_bound` binary
    /// searches the restart keys (block minima) and decodes exactly one
    /// candidate block.
    Delta {
        blocks: Vec<BlockEntry>,
        cache: Option<(usize, Vec<u64>)>,
    },
}

/// Random-access view of a sorted run file: positioned single-key reads
/// and a lower-bound binary search over the key order. The shard planner
/// uses this to locate shard boundaries in `O(log n)` seeks per run
/// (v0/v1) or `O(log blocks)` + one block decode (v2) instead of
/// streaming the whole file.
pub struct RunIndex<K: SortKey> {
    file: File,
    path: PathBuf,
    n: u64,
    header: Option<SpillHeader>,
    kind: IndexKind,
    _pd: PhantomData<K>,
}

impl<K: SortKey> RunIndex<K> {
    /// Open a key file for random access. v2 files take their block
    /// directory from the run's side-car when one validates
    /// (`shard.sidecar.hit` — no header walk at all) and otherwise get
    /// their block framing fully validated by the walk that builds the
    /// directory (`shard.sidecar.miss`). v3 (zigzag) files are unsorted
    /// and have no run index.
    pub fn open(path: &Path) -> io::Result<RunIndex<K>> {
        let mut file = File::open(path)?;
        let layout = resolve_layout(&mut file, path, K::KIND, K::LANE_WIDTH)?;
        let kind = match layout.version {
            SpillVersion::V0 | SpillVersion::V1 | SpillVersion::V4 => IndexKind::Raw {
                data_start: layout.data_start,
            },
            SpillVersion::V2 | SpillVersion::V5 => {
                let cw = K::WIDTH - K::LANE_WIDTH;
                let payload = file.metadata()?.len() - HEADER_LEN as u64 - layout.pad;
                let blocks = match load_sidecar(path, cw, layout.n, payload) {
                    Some(b) => {
                        crate::obs::metrics::counter_add(crate::obs::C_SIDECAR_HIT, 1);
                        b
                    }
                    None => {
                        crate::obs::metrics::counter_add(crate::obs::C_SIDECAR_MISS, 1);
                        walk_v2_blocks(&mut file, path, layout.n, cw, layout.pad, true)?
                    }
                };
                IndexKind::Delta {
                    blocks,
                    cache: None,
                }
            }
            SpillVersion::V3 => {
                return Err(bad_data(
                    path,
                    "zigzag (v3) files are unsorted and have no run index",
                ))
            }
        };
        let header = match layout.version {
            SpillVersion::V0 => None,
            v => Some(SpillHeader {
                version: v.code(),
                kind: K::KIND,
                count: layout.n,
                pad: layout.pad as u32,
                lane: K::LANE_WIDTH as u8,
            }),
        };
        Ok(RunIndex {
            file,
            path: path.to_path_buf(),
            n: layout.n,
            header,
            kind,
            _pd: PhantomData,
        })
    }

    /// The file's parsed header (`None` for headerless v0 files) — the
    /// shard planner caches this so per-shard range-opens skip the
    /// redundant header re-read.
    pub(crate) fn header(&self) -> Option<SpillHeader> {
        self.header
    }

    /// Number of keys in the file.
    pub fn len(&self) -> u64 {
        self.n
    }

    /// True when the file holds no keys.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Read the key at index `idx` — one positioned read (v0/v1/v4) or a
    /// cached one-block decode (v2/v5). On v5 files the delta path
    /// reconstructs from ordered bits alone (zero lane): the index exists
    /// for shard-boundary probes, which compare `to_bits_ordered()` only,
    /// and bit order is exact for every key the cut logic ever compares.
    pub fn key_at(&mut self, idx: u64) -> io::Result<K> {
        debug_assert!(idx < self.n);
        if let IndexKind::Raw { data_start } = &self.kind {
            let off = *data_start + idx * K::WIDTH as u64;
            self.file.seek(SeekFrom::Start(off))?;
            let mut buf = K::Bytes::default();
            self.file.read_exact(buf.as_mut())?;
            return Ok(K::from_le_bytes(buf));
        }
        let (b, start) = {
            let IndexKind::Delta { blocks, .. } = &self.kind else {
                unreachable!();
            };
            // last block whose start index is <= idx
            let b = blocks.partition_point(|e| e.start_idx <= idx) - 1;
            (b, blocks[b].start_idx)
        };
        let bits = self.ensure_block(b)?;
        Ok(K::from_bits_ordered(bits[(idx - start) as usize]))
    }

    /// Decode (or reuse the cached decode of) block `b`, returning its
    /// keys as ordered bits.
    fn ensure_block(&mut self, b: usize) -> io::Result<&[u64]> {
        let IndexKind::Delta { blocks, cache } = &mut self.kind else {
            unreachable!("ensure_block is v2-only");
        };
        if cache.as_ref().map(|(i, _)| *i) != Some(b) {
            let e = &blocks[b];
            self.file.seek(SeekFrom::Start(e.payload_offset))?;
            let mut payload = vec![0u8; e.payload_len as usize];
            read_exact_block(&mut self.file, &mut payload, &self.path)?;
            // v5 payloads lead with the block's lane array; the tokens
            // (all the bit decoder needs) follow it
            let lane_bytes = e.count as usize * K::LANE_WIDTH;
            if payload.len() < lane_bytes {
                return Err(bad_data(
                    &self.path,
                    "record block payload shorter than its lane array",
                ));
            }
            let bits =
                decode_block_bits::<K>(&payload[lane_bytes..], e.first_bits, e.count, &self.path)?;
            *cache = Some((b, bits));
        }
        Ok(&cache.as_ref().unwrap().1)
    }

    /// First index whose key's ordered bits are `>= bound_bits`, assuming
    /// the file is sorted (`n` when every key is below the bound). This is
    /// the shard-boundary cut: keys equal to the bound fall into the shard
    /// that *starts* at the bound, so duplicates never straddle a cut.
    ///
    /// On v2 files the search runs over the block directory's restart
    /// keys first, then decodes exactly one candidate block.
    pub fn lower_bound(&mut self, bound_bits: u64) -> io::Result<u64> {
        if matches!(self.kind, IndexKind::Delta { .. }) {
            return self.delta_lower_bound(bound_bits);
        }
        let (mut lo, mut hi) = (0u64, self.n);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.key_at(mid)?.to_bits_ordered() < bound_bits {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        Ok(lo)
    }

    /// v2 lower bound: restart keys are block minima of a sorted file, so
    /// the only block that can straddle the bound is the last one whose
    /// restart key is below it. When the directory carries an exact
    /// per-block maximum (side-car entries) and the bound clears it, the
    /// answer is the next block's start — no decode at all.
    fn delta_lower_bound(&mut self, bound_bits: u64) -> io::Result<u64> {
        let (cand, cand_start) = {
            let IndexKind::Delta { blocks, .. } = &self.kind else {
                unreachable!();
            };
            let p = blocks.partition_point(|e| e.first_bits < bound_bits);
            if p == 0 {
                return Ok(0); // every block starts at or above the bound
            }
            let e = &blocks[p - 1];
            if bound_bits > e.last_bits {
                // every key of the candidate is under the bound; walk-
                // derived bounds (next restart) can never satisfy this,
                // so the shortcut only fires on side-car exact maxima
                return Ok(e.start_idx + e.count as u64);
            }
            (p - 1, e.start_idx)
        };
        let bits = self.ensure_block(cand)?;
        let off = bits.partition_point(|&b| b < bound_bits) as u64;
        Ok(cand_start + off)
    }

    /// Detach the block directory this index built when it opened a v2
    /// file, so the shard planner can hand it to the merge's range-opens
    /// ([`RunReader::open_range_with`]). `None` for raw files, whose
    /// range-opens are already O(1).
    pub fn into_directory(self) -> Option<BlockDirectory> {
        match self.kind {
            IndexKind::Raw { .. } => None,
            IndexKind::Delta { blocks, .. } => Some(BlockDirectory {
                blocks,
                n: self.n,
            }),
        }
    }
}

/// Per-block encoder state of a delta [`RunWriter`]: keys accumulate as
/// encoded tokens (never as a key buffer), with at most one duplicate run
/// pending coalescence.
#[derive(Default)]
struct DeltaBlock {
    /// Keys in the open block.
    count: u32,
    /// Ordered bits of the block's first key.
    restart: u64,
    /// Ordered bits of the last pushed key.
    prev: u64,
    /// Duplicates of `prev` not yet flushed as a run token.
    pending_run: u64,
    /// Encoded token payload of the open block.
    payload: Vec<u8>,
    /// Per-key lane bytes of the open block (v5 only; stays empty for
    /// lane-free key types). Every accepted key appends its lane here —
    /// including duplicate-run members, whose lanes may differ even when
    /// their ordered bits collide (prefix-tied strings).
    lanes: Vec<u8>,
}

/// Buffered streaming writer producing a [`RunFile`] in the configured
/// codec: raw v1 (the default — the interchange format `gen --out`
/// writes), delta v2 for sorted runs ([`RunWriter::create_with`]), or
/// zigzag v3 for unsorted payloads ([`RunWriter::create_unsorted`]).
/// Bytes move through a [`SpillSink`], so the same writer runs on the
/// sync backend, the submission pool, and (spill-dir runs only)
/// `O_DIRECT`.
pub struct RunWriter<K: SortKey> {
    sink: SpillSink,
    path: PathBuf,
    n: u64,
    bytes: u64,
    codec: SpillCodec,
    block: DeltaBlock,
    /// `Some` = collect per-block bounds and write a `.idx` side-car at
    /// finish (delta spill runs).
    sidecar: Option<Vec<BlockEntry>>,
    _pd: PhantomData<K>,
}

impl<K: SortKey> RunWriter<K> {
    /// Create (truncate) the file at `path` in the raw (v1) codec, write
    /// its header with a placeholder count, and return a writer over it.
    pub fn create(path: PathBuf, io_buffer: usize) -> io::Result<RunWriter<K>> {
        Self::create_with(path, io_buffer, SpillCodec::Raw)
    }

    /// [`RunWriter::create`] with an explicit codec. The delta codec
    /// requires nondecreasing keys (sorted runs) — an out-of-order push
    /// fails with `InvalidInput` rather than writing an undecodable file.
    /// The zigzag codec is rejected here: sorted-run paths (spills,
    /// merge outputs) must never produce v3 — use
    /// [`RunWriter::create_unsorted`] for `gen`-style payloads.
    pub fn create_with(
        path: PathBuf,
        io_buffer: usize,
        codec: SpillCodec,
    ) -> io::Result<RunWriter<K>> {
        if codec == SpillCodec::Zigzag {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "{}: the zigzag codec is for unsorted payloads — sorted-run \
                     writers take raw or delta",
                    path.display()
                ),
            ));
        }
        Self::open_with(path, io_buffer, codec, &IoCtx::sync(), false, false)
    }

    /// Writer for *unsorted* payloads (`gen` outputs): raw v1 or zigzag
    /// v3. The delta codec is rejected — it encodes sorted runs only.
    pub fn create_unsorted(
        path: PathBuf,
        io_buffer: usize,
        codec: SpillCodec,
    ) -> io::Result<RunWriter<K>> {
        if codec == SpillCodec::Delta {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "{}: the delta codec encodes sorted runs only — unsorted \
                     payloads take raw or zigzag",
                    path.display()
                ),
            ));
        }
        if codec == SpillCodec::Zigzag && K::LANE_WIDTH > 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "{}: the zigzag codec is bits-only — records and string \
                     keys spill raw or delta",
                    path.display()
                ),
            ));
        }
        Self::open_with(path, io_buffer, codec, &IoCtx::sync(), false, false)
    }

    /// Spill-path writer: bytes flow through `io`'s backend, direct mode
    /// is attempted when the context carries it, and delta runs write a
    /// block side-car when `sidecar` is set. Zigzag is rejected exactly
    /// as in [`RunWriter::create_with`] — spills are sorted runs.
    pub(crate) fn create_io(
        path: PathBuf,
        io_buffer: usize,
        codec: SpillCodec,
        io: &IoCtx,
        sidecar: bool,
        allow_direct: bool,
    ) -> io::Result<RunWriter<K>> {
        if codec == SpillCodec::Zigzag {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("{}: spilled runs are sorted — zigzag is gen-only", path.display()),
            ));
        }
        Self::open_with(path, io_buffer, codec, io, sidecar, allow_direct)
    }

    fn open_with(
        path: PathBuf,
        io_buffer: usize,
        codec: SpillCodec,
        io: &IoCtx,
        sidecar: bool,
        allow_direct: bool,
    ) -> io::Result<RunWriter<K>> {
        let mut sink = SpillSink::create(&path, io_buffer.max(4096), io, allow_direct)?;
        sink.write_all(&SpillHeader::for_sort_key::<K>(codec, 0).encode())?;
        let sidecar = (sidecar && codec == SpillCodec::Delta).then(Vec::new);
        Ok(RunWriter {
            sink,
            path,
            n: 0,
            bytes: HEADER_LEN as u64,
            codec,
            block: DeltaBlock::default(),
            sidecar,
            _pd: PhantomData,
        })
    }

    /// Append one key.
    #[inline]
    pub fn push(&mut self, key: K) -> io::Result<()> {
        match self.codec {
            SpillCodec::Raw => {
                self.sink.write_all(key.to_le_bytes().as_ref())?;
                self.bytes += K::WIDTH as u64;
            }
            SpillCodec::Delta => self.push_delta(key)?,
            SpillCodec::Zigzag => self.push_zigzag(key.to_bits_ordered())?,
        }
        self.n += 1;
        Ok(())
    }

    /// Zigzag-encode one key into the open block (v3 — any key order).
    fn push_zigzag(&mut self, bits: u64) -> io::Result<()> {
        let b = &mut self.block;
        if b.count == 0 {
            b.restart = bits;
            b.prev = bits;
            b.count = 1;
        } else if bits == b.prev {
            b.pending_run += 1;
            b.count += 1;
        } else {
            if b.pending_run > 0 {
                push_varint(&mut b.payload, 0);
                push_varint(&mut b.payload, b.pending_run);
                b.pending_run = 0;
            }
            push_varint(&mut b.payload, zigzag(bits.wrapping_sub(b.prev) as i64));
            b.prev = bits;
            b.count += 1;
        }
        if b.count as usize >= BLOCK_KEYS {
            self.flush_block()?;
        }
        Ok(())
    }

    /// Keys per delta block: [`BLOCK_KEYS`] for lane-free types; lane'd
    /// blocks additionally cap their lane array near 64 KiB so the
    /// reader's per-block lane buffer stays bounded no matter how wide
    /// the payload is.
    const fn block_cap() -> usize {
        if K::LANE_WIDTH == 0 {
            BLOCK_KEYS
        } else {
            let by_bytes = (64 << 10) / K::LANE_WIDTH;
            let by_bytes = if by_bytes < 16 { 16 } else { by_bytes };
            if by_bytes < BLOCK_KEYS {
                by_bytes
            } else {
                BLOCK_KEYS
            }
        }
    }

    /// Delta-encode one key into the open block, flushing the block once
    /// it holds [`Self::block_cap`] keys. Deltas run over the key's
    /// ordered bits only; its lane bytes (v5) are appended verbatim to
    /// the block's lane array — one entry per key, duplicate-bit runs
    /// included, so equal-bits keys with different tails round-trip.
    fn push_delta(&mut self, key: K) -> io::Result<()> {
        let bits = key.to_bits_ordered();
        let b = &mut self.block;
        if b.count == 0 {
            b.restart = bits;
            b.prev = bits;
            b.count = 1;
        } else if bits == b.prev {
            b.pending_run += 1;
            b.count += 1;
        } else if bits > b.prev {
            if b.pending_run > 0 {
                push_varint(&mut b.payload, 0);
                push_varint(&mut b.payload, b.pending_run);
                b.pending_run = 0;
            }
            push_varint(&mut b.payload, bits - b.prev);
            b.prev = bits;
            b.count += 1;
        } else {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!(
                    "{}: the delta spill codec encodes sorted runs only \
                     (keys must be nondecreasing)",
                    self.path.display()
                ),
            ));
        }
        if K::LANE_WIDTH > 0 {
            let s = b.lanes.len();
            b.lanes.resize(s + K::LANE_WIDTH, 0);
            key.write_lane(&mut b.lanes[s..]);
        }
        if b.count as usize >= Self::block_cap() {
            self.flush_block()?;
        }
        Ok(())
    }

    /// Write the open block (if any), record its side-car entry, and
    /// reset the encoder.
    fn flush_block(&mut self) -> io::Result<()> {
        let b = &mut self.block;
        if b.count == 0 {
            return Ok(());
        }
        if b.pending_run > 0 {
            push_varint(&mut b.payload, 0);
            push_varint(&mut b.payload, b.pending_run);
            b.pending_run = 0;
        }
        // the block's framed payload = lane array (v5) + tokens; its
        // header carries the restart key's *core* bits only — the
        // restart's lane lives in the lane array like every other key's
        let cw = K::WIDTH - K::LANE_WIDTH;
        let payload_len = (b.lanes.len() + b.payload.len()) as u32;
        self.sink.write_all(&b.count.to_le_bytes())?;
        self.sink.write_all(&payload_len.to_le_bytes())?;
        self.sink.write_all(&b.restart.to_le_bytes()[..cw])?;
        self.sink.write_all(&b.lanes)?;
        self.sink.write_all(&b.payload)?;
        if let Some(entries) = &mut self.sidecar {
            let start_idx = entries
                .last()
                .map_or(0, |e| e.start_idx + e.count as u64);
            entries.push(BlockEntry {
                first_bits: b.restart,
                // the writer knows the true block maximum — this is what
                // makes side-car skips exact where walk bounds are not
                last_bits: b.prev,
                start_idx,
                payload_offset: self.bytes + (8 + cw) as u64,
                count: b.count,
                payload_len,
            });
        }
        self.bytes += (8 + cw) as u64 + payload_len as u64;
        b.lanes.clear();
        b.payload.clear();
        b.count = 0;
        Ok(())
    }

    /// Bulk spill. Raw encodes through a fixed slab and writes in blocks,
    /// mirroring `RunReader::read_chunk` (no per-key `write_all`); delta
    /// feeds the block encoder.
    pub fn write_slice(&mut self, keys: &[K]) -> io::Result<()> {
        if self.codec != SpillCodec::Raw {
            for &k in keys {
                self.push(k)?;
            }
            return Ok(());
        }
        let per_slab = SLAB_BYTES / K::WIDTH;
        let mut slab = [0u8; SLAB_BYTES];
        for block in keys.chunks(per_slab) {
            let bytes = &mut slab[..block.len() * K::WIDTH];
            for (c, k) in bytes.chunks_exact_mut(K::WIDTH).zip(block) {
                c.copy_from_slice(k.to_le_bytes().as_ref());
            }
            self.sink.write_all(bytes)?;
        }
        self.n += keys.len() as u64;
        self.bytes += (keys.len() * K::WIDTH) as u64;
        Ok(())
    }

    /// Flush (including a partial final block), seal the sink (padding
    /// direct-mode files to the IO alignment), patch the real key count
    /// and pad into the header, write the block side-car if one was
    /// requested, and close, returning the finished run's metadata.
    pub fn finish(mut self) -> io::Result<RunFile> {
        if self.codec != SpillCodec::Raw {
            self.flush_block()?;
        }
        let pad = self.sink.seal()?;
        // pad (bytes 12..16) and count (16..24) are contiguous — one patch
        let mut tail = [0u8; 12];
        tail[..4].copy_from_slice(&pad.to_le_bytes());
        tail[4..].copy_from_slice(&self.n.to_le_bytes());
        self.sink.patch(COUNT_OFFSET - 4, &tail)?;
        if let Some(entries) = self.sidecar.take() {
            // advisory: a run without a side-car merges fine, a partial
            // side-car must not survive to mislead a reader
            if write_sidecar(&self.path, K::WIDTH - K::LANE_WIDTH, self.n, &entries).is_err() {
                let _ = std::fs::remove_file(sidecar_path(&self.path));
            }
        }
        Ok(RunFile {
            path: self.path,
            n: self.n,
            bytes: self.bytes + pad as u64,
        })
    }
}

/// Create a raw (v1) key file of exactly `count` keys whose payload will
/// be filled by positioned writes (the sharded merges): header up front,
/// file pre-sized so every shard can open + seek independently. Always
/// raw — seek-written disjoint ranges are incompatible with a
/// variable-length payload.
pub(crate) fn create_presized<K: SortKey>(path: &Path, count: u64) -> io::Result<()> {
    let mut f = File::create(path)?;
    f.write_all(&SpillHeader::for_sort_key::<K>(SpillCodec::Raw, count).encode())?;
    f.set_len(HEADER_LEN as u64 + count * K::WIDTH as u64)?;
    Ok(())
}

/// Stream-rewrite any key file as raw v1 (the interchange format). Used
/// by the single-run fast path when the spilled run was delta-coded: the
/// output file contract is raw regardless of the spill codec.
pub(crate) fn transcode_raw<K: SortKey>(
    src: &Path,
    dst: &Path,
    io_buffer: usize,
) -> io::Result<RunFile> {
    let mut r = RunReader::<K>::open(src, io_buffer)?;
    let mut w = RunWriter::<K>::create(dst.to_path_buf(), io_buffer)?;
    let chunk_keys = (io_buffer / K::WIDTH).max(1024);
    loop {
        let chunk = r.read_chunk(chunk_keys)?;
        if chunk.is_empty() {
            break;
        }
        w.write_slice(&chunk)?;
    }
    w.finish()
}

/// Write a whole in-memory slice as a raw (v1) key file.
pub fn write_keys_file<K: SortKey>(path: &Path, keys: &[K]) -> io::Result<RunFile> {
    write_keys_file_codec(path, keys, SpillCodec::Raw)
}

/// Write a whole in-memory slice as a key file in any codec. Raw and
/// zigzag accept any key order; delta requires nondecreasing keys and
/// fails with `InvalidInput` otherwise.
pub fn write_keys_file_codec<K: SortKey>(
    path: &Path,
    keys: &[K],
    codec: SpillCodec,
) -> io::Result<RunFile> {
    let mut w = match codec {
        SpillCodec::Delta => RunWriter::create_with(path.to_path_buf(), 1 << 16, codec)?,
        _ => RunWriter::create_unsorted(path.to_path_buf(), 1 << 16, codec)?,
    };
    w.write_slice(keys)?;
    w.finish()
}

/// Load a whole key file into memory (tests / small files only).
pub fn read_keys_file<K: SortKey>(path: &Path) -> io::Result<Vec<K>> {
    let mut r = RunReader::<K>::open(path, 1 << 16)?;
    let n = r.remaining() as usize;
    r.read_chunk(n)
}

/// Number of keys in a key file: the header's count for self-describing
/// files (validated against the payload — exact length for v1, a full
/// block walk for v2), the byte length over 8 for headerless v0 files.
pub fn file_key_count(path: &Path) -> io::Result<u64> {
    let mut file = File::open(path)?;
    let len = file.metadata()?.len();
    match parse_header(&mut file, path)? {
        Some(h) => {
            match h.spill_version() {
                SpillVersion::V1 | SpillVersion::V4 => validate_payload_v1(&h, len, path)?,
                // v5 frames like v2 with the kind's core width — the lane
                // array hides inside each block's framed payload length
                SpillVersion::V2 | SpillVersion::V5 => {
                    walk_v2_blocks(&mut file, path, h.count, h.kind.width(), h.pad as u64, true)?;
                }
                SpillVersion::V3 => {
                    // same framing walk, minus the sorted-restart check
                    walk_v2_blocks(&mut file, path, h.count, h.kind.width(), h.pad as u64, false)?;
                }
                SpillVersion::V0 => unreachable!("headered files are v1+"),
            }
            Ok(h.count)
        }
        None => v0_key_count(len, path),
    }
}

/// Stream-verify that a key file is nondecreasing under the key's total
/// order, in O(io_buffer) memory.
pub fn verify_sorted_file<K: SortKey>(path: &Path, io_buffer: usize) -> io::Result<bool> {
    let mut r = RunReader::<K>::open(path, io_buffer)?;
    // full key order, not just ordered bits: a prefix-tied string file
    // whose tails regress is mis-sorted even though its bits are flat
    let mut prev: Option<K> = None;
    while let Some(k) = r.next()? {
        if let Some(p) = prev {
            if k.key_lt(p) {
                return Ok(false);
            }
        }
        prev = Some(k);
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("aipso-spill-test-{}-{name}", std::process::id()))
    }

    /// Write sorted keys through the delta codec.
    fn write_delta<K: SortKey>(path: &Path, keys: &[K]) -> RunFile {
        let mut w =
            RunWriter::<K>::create_with(path.to_path_buf(), 1 << 14, SpillCodec::Delta).unwrap();
        w.write_slice(keys).unwrap();
        w.finish().unwrap()
    }

    #[test]
    fn roundtrip_u64_and_f64() {
        let p = tmp("rt-u64.bin");
        let keys: Vec<u64> = vec![0, 1, u64::MAX, 42, 7];
        write_keys_file(&p, &keys).unwrap();
        assert_eq!(file_key_count(&p).unwrap(), 5);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        let _ = std::fs::remove_file(&p);

        let p = tmp("rt-f64.bin");
        let keys: Vec<f64> = vec![-1.5, 0.0, -0.0, 1e300, 1e-300];
        write_keys_file(&p, &keys).unwrap();
        let back = read_keys_file::<f64>(&p).unwrap();
        let a: Vec<u64> = keys.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u64> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "bit-exact reload");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn roundtrip_u32_and_f32_at_half_the_bytes() {
        let p32 = tmp("rt-u32.bin");
        let keys32: Vec<u32> = vec![0, 1, u32::MAX, 42, 7];
        write_keys_file(&p32, &keys32).unwrap();
        assert_eq!(file_key_count(&p32).unwrap(), 5);
        assert_eq!(read_keys_file::<u32>(&p32).unwrap(), keys32);

        let p64 = tmp("rt-u64-vs-u32.bin");
        let keys64: Vec<u64> = keys32.iter().map(|&x| x as u64).collect();
        write_keys_file(&p64, &keys64).unwrap();
        let payload32 = std::fs::metadata(&p32).unwrap().len() - HEADER_LEN as u64;
        let payload64 = std::fs::metadata(&p64).unwrap().len() - HEADER_LEN as u64;
        assert_eq!(payload32 * 2, payload64, "4-byte keys halve the payload");
        let _ = std::fs::remove_file(&p32);
        let _ = std::fs::remove_file(&p64);

        let p = tmp("rt-f32.bin");
        let keys: Vec<f32> = vec![-1.5, 0.0, -0.0, 1e30, 1e-30];
        write_keys_file(&p, &keys).unwrap();
        let back = read_keys_file::<f32>(&p).unwrap();
        let a: Vec<u32> = keys.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "bit-exact reload");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn header_roundtrips_and_reports() {
        let p = tmp("hdr.bin");
        write_keys_file::<u32>(&p, &[1, 2, 3]).unwrap();
        let h = read_header(&p).unwrap().expect("v1 file has a header");
        assert_eq!(
            h,
            SpillHeader {
                version: RAW_VERSION,
                kind: KeyKind::U32,
                count: 3,
                pad: 0,
                lane: 0
            }
        );
        assert_eq!(h.spill_version(), SpillVersion::V1);
        // encode/decode are inverses
        let enc = h.encode();
        assert_eq!(SpillHeader::decode(&enc, &p).unwrap(), h);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn codec_and_version_tables_agree() {
        assert_eq!(SpillCodec::Raw.version(), RAW_VERSION);
        assert_eq!(SpillCodec::Delta.version(), DELTA_VERSION);
        assert_eq!(SpillCodec::Zigzag.version(), ZIGZAG_VERSION);
        assert_eq!(SpillCodec::parse("raw"), Some(SpillCodec::Raw));
        assert_eq!(SpillCodec::parse("delta"), Some(SpillCodec::Delta));
        assert_eq!(SpillCodec::parse("zigzag"), Some(SpillCodec::Zigzag));
        assert_eq!(SpillCodec::parse("zstd"), None);
        assert_eq!(SpillVersion::of(1), Some(SpillVersion::V1));
        assert_eq!(SpillVersion::of(2), Some(SpillVersion::V2));
        assert_eq!(SpillVersion::of(3), Some(SpillVersion::V3));
        assert_eq!(SpillVersion::of(4), Some(SpillVersion::V4));
        assert_eq!(SpillVersion::of(5), Some(SpillVersion::V5));
        assert_eq!(SpillVersion::of(0), None);
        assert_eq!(SpillVersion::of(6), None);
        for v in [
            SpillVersion::V1,
            SpillVersion::V2,
            SpillVersion::V3,
            SpillVersion::V4,
            SpillVersion::V5,
        ] {
            assert_eq!(SpillVersion::of(v.code()), Some(v));
        }
        // lane-free sorts keep the legacy versions byte-identical; lane'd
        // sorts promote to the record versions (zigzag never promotes —
        // its writers reject lanes before a header exists)
        assert_eq!(SpillCodec::Raw.version_for(0), RAW_VERSION);
        assert_eq!(SpillCodec::Delta.version_for(0), DELTA_VERSION);
        assert_eq!(SpillCodec::Raw.version_for(8), RECORD_RAW_VERSION);
        assert_eq!(SpillCodec::Delta.version_for(8), RECORD_DELTA_VERSION);
        assert_eq!(SpillCodec::Zigzag.version_for(8), ZIGZAG_VERSION);
        let h = SpillHeader::for_codec(SpillCodec::Delta, KeyKind::F32, 9);
        assert_eq!(h.version, DELTA_VERSION);
        assert_eq!(h.spill_version(), SpillVersion::V2);
    }

    #[test]
    fn legacy_v0_files_read_as_8_byte_keys_only() {
        let p = tmp("v0.bin");
        let keys: Vec<u64> = vec![9, 1, 5];
        let raw: Vec<u8> = keys.iter().flat_map(|k| k.to_le_bytes()).collect();
        std::fs::write(&p, &raw).unwrap();
        assert_eq!(read_header(&p).unwrap(), None, "no header on v0 files");
        assert_eq!(file_key_count(&p).unwrap(), 3);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        // but a 4-byte type cannot claim a headerless file
        let err = read_keys_file::<u32>(&p).unwrap_err();
        assert!(err.to_string().contains("headerless"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn mismatched_key_type_is_rejected() {
        let p = tmp("mismatch.bin");
        write_keys_file::<f32>(&p, &[1.0, 2.0]).unwrap();
        for (err, want) in [
            (read_keys_file::<u32>(&p).unwrap_err(), "f32"),
            (read_keys_file::<f64>(&p).unwrap_err(), "f32"),
        ] {
            assert!(err.to_string().contains(want), "{err}");
            assert!(err.to_string().contains("invoked for"), "{err}");
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn truncated_and_corrupt_headers_fail_loudly() {
        let p = tmp("bad-hdr.bin");

        // payload shorter than the header's count
        let mut bytes = SpillHeader::new(KeyKind::U64, 4).encode().to_vec();
        bytes.extend_from_slice(&7u64.to_le_bytes()); // only 1 of 4 keys
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        assert!(file_key_count(&p).is_err());

        // magic but the header itself is cut off
        std::fs::write(&p, &MAGIC[..]).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("truncated spill header"), "{err}");

        // future version
        let mut h = SpillHeader::new(KeyKind::U64, 0).encode();
        h[8..10].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        std::fs::write(&p, h).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        // unknown key-type tag
        let mut h = SpillHeader::new(KeyKind::U64, 0).encode();
        h[10] = 9;
        std::fs::write(&p, h).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("key-type tag"), "{err}");

        // width byte contradicting the tag
        let mut h = SpillHeader::new(KeyKind::U32, 0).encode();
        h[11] = 8;
        std::fs::write(&p, h).unwrap();
        let err = read_keys_file::<u32>(&p).unwrap_err();
        assert!(err.to_string().contains("width"), "{err}");

        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn chunked_reads_cover_file() {
        let p = tmp("chunks.bin");
        let keys: Vec<u64> = (0..1000).collect();
        write_keys_file(&p, &keys).unwrap();
        let mut r = RunReader::<u64>::open(&p, 4096).unwrap();
        let mut got = Vec::new();
        loop {
            let c = r.read_chunk(64);
            let c = c.unwrap();
            if c.is_empty() {
                break;
            }
            got.extend(c);
        }
        assert_eq!(got, keys);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn verify_detects_disorder() {
        let p = tmp("verify.bin");
        write_keys_file(&p, &[1u64, 2, 3]).unwrap();
        assert!(verify_sorted_file::<u64>(&p, 4096).unwrap());
        write_keys_file(&p, &[3u64, 2]).unwrap();
        assert!(!verify_sorted_file::<u64>(&p, 4096).unwrap());
        write_keys_file::<u64>(&p, &[]).unwrap();
        assert!(verify_sorted_file::<u64>(&p, 4096).unwrap());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn f64_order_via_bits_in_verify() {
        let p = tmp("verify-f64.bin");
        write_keys_file(&p, &[-2.5f64, -0.0, 0.0, 3.5]).unwrap();
        assert!(verify_sorted_file::<f64>(&p, 4096).unwrap());
        write_keys_file(&p, &[0.0f64, -0.0]).unwrap();
        assert!(!verify_sorted_file::<f64>(&p, 4096).unwrap());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn spill_dir_cleans_up() {
        let dir;
        {
            let mut s = SpillDir::create(None).unwrap();
            dir = s.path().to_path_buf();
            let p = s.next_run_path();
            write_keys_file(&p, &[1u64]).unwrap();
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "SpillDir must remove itself on drop");
    }

    #[test]
    fn range_reads_and_index_lower_bound() {
        let p = tmp("range.bin");
        let keys: Vec<u64> = (0..500).map(|i| i * 2).collect(); // evens 0..998
        write_keys_file(&p, &keys).unwrap();

        let mut r = RunReader::<u64>::open_range(&p, 10, 5, 4096).unwrap();
        let got = r.read_chunk(100).unwrap();
        assert_eq!(got, vec![20, 22, 24, 26, 28]);

        // ranges clamp to the file
        let mut r = RunReader::<u64>::open_range(&p, 498, 100, 4096).unwrap();
        assert_eq!(r.read_chunk(100).unwrap(), vec![996, 998]);
        let mut r = RunReader::<u64>::open_range(&p, 9999, 10, 4096).unwrap();
        assert!(r.read_chunk(10).unwrap().is_empty());

        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx.len(), 500);
        assert_eq!(idx.key_at(0).unwrap(), 0);
        assert_eq!(idx.key_at(499).unwrap(), 998);
        // present key -> its index; absent key -> insertion point
        assert_eq!(idx.lower_bound(40u64.to_bits_ordered()).unwrap(), 20);
        assert_eq!(idx.lower_bound(41u64.to_bits_ordered()).unwrap(), 21);
        assert_eq!(idx.lower_bound(0).unwrap(), 0);
        assert_eq!(idx.lower_bound(u64::MAX).unwrap(), 500);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn range_reads_and_index_work_on_4_byte_keys() {
        let p = tmp("range-u32.bin");
        let keys: Vec<u32> = (0..500).map(|i| i * 2).collect();
        write_keys_file(&p, &keys).unwrap();
        let mut r = RunReader::<u32>::open_range(&p, 10, 3, 4096).unwrap();
        assert_eq!(r.read_chunk(10).unwrap(), vec![20, 22, 24]);
        let mut idx = RunIndex::<u32>::open(&p).unwrap();
        assert_eq!(idx.len(), 500);
        assert_eq!(idx.key_at(499).unwrap(), 998);
        assert_eq!(idx.lower_bound(40u32.to_bits_ordered()).unwrap(), 20);
        assert_eq!(idx.lower_bound(u32::MAX as u64).unwrap(), 500);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn empty_run_index_is_harmless() {
        // A zero-key run (legal: an empty input still truncates an output
        // file, and sharding may probe any run) must index without error:
        // every lower bound is 0, never an out-of-range read.
        let p = tmp("empty-idx.bin");
        write_keys_file::<u64>(&p, &[]).unwrap();
        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx.len(), 0);
        assert!(idx.is_empty());
        assert_eq!(idx.lower_bound(0).unwrap(), 0);
        assert_eq!(idx.lower_bound(u64::MAX).unwrap(), 0);
        // range reads over the empty file clamp to nothing
        let mut r = RunReader::<u64>::open_range(&p, 0, 10, 4096).unwrap();
        assert!(r.read_chunk(10).unwrap().is_empty());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn odd_length_headerless_file_rejected() {
        let p = tmp("odd.bin");
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(RunReader::<u64>::open(&p, 4096).is_err());
        assert!(file_key_count(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }

    // -- v2 delta codec ----------------------------------------------------

    #[test]
    fn varint_roundtrips_edge_values() {
        let p = tmp("varint-probe");
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX - 1, u64::MAX] {
            let mut buf = Vec::new();
            push_varint(&mut buf, v);
            assert!(buf.len() <= 10, "v={v}: {} bytes", buf.len());
            let mut budget = buf.len() as u32;
            let got = read_varint(&mut buf.as_slice(), &mut budget, &p).unwrap();
            assert_eq!(got, v);
            assert_eq!(budget, 0, "v={v}: trailing bytes");
        }
    }

    #[test]
    fn delta_roundtrip_sorted_runs_all_four_widths() {
        // Sorted keys through the v2 writer must reload identically via
        // both the streaming reader and the block index, in every domain.
        let p = tmp("delta-rt.bin");

        let keys: Vec<u64> = vec![0, 0, 1, 5, 5, 5, 1000, u64::MAX - 1, u64::MAX, u64::MAX];
        let run = write_delta(&p, &keys);
        assert_eq!(run.n, keys.len() as u64);
        let h = read_header(&p).unwrap().unwrap();
        assert_eq!(h.version, DELTA_VERSION);
        assert_eq!(h.count, keys.len() as u64);
        assert_eq!(file_key_count(&p).unwrap(), keys.len() as u64);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        assert!(verify_sorted_file::<u64>(&p, 4096).unwrap());

        let keys: Vec<u32> = vec![0, 7, 7, 7, 9, u32::MAX];
        write_delta(&p, &keys);
        assert_eq!(read_keys_file::<u32>(&p).unwrap(), keys);

        let mut keys: Vec<f64> = vec![f64::NEG_INFINITY, -2.5, -0.0, 0.0, 0.0, 7.25, 1e300];
        keys.sort_unstable_by(f64::total_cmp);
        write_delta(&p, &keys);
        let back = read_keys_file::<f64>(&p).unwrap();
        let a: Vec<u64> = keys.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u64> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "bit-exact f64 reload through the delta codec");

        let keys: Vec<f32> = vec![-1e30, -1.5, 0.0, 0.0, 2.5, 1e30];
        write_delta(&p, &keys);
        let back = read_keys_file::<f32>(&p).unwrap();
        let a: Vec<u32> = keys.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "bit-exact f32 reload through the delta codec");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn delta_single_key_all_dups_and_max_delta_blocks() {
        let p = tmp("delta-edges.bin");

        // single-key file: one block, empty payload
        write_delta::<u64>(&p, &[42]);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), vec![42]);
        assert_eq!(
            std::fs::metadata(&p).unwrap().len(),
            (HEADER_LEN + 8 + 8) as u64,
            "single-key block is header + block framing + restart key"
        );

        // all-duplicates: the run-length escape collapses the payload
        let dups = vec![7u64; 3 * BLOCK_KEYS + 5];
        let run = write_delta(&p, &dups);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), dups);
        assert!(
            run.bytes < (dups.len() * 8) as u64 / 100,
            "all-dup blocks must collapse ({} bytes for {} keys)",
            run.bytes,
            dups.len()
        );

        // maximum delta: 0 -> u64::MAX in one 10-byte varint
        write_delta::<u64>(&p, &[0, u64::MAX]);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), vec![0, u64::MAX]);

        // empty file: header only
        write_delta::<u64>(&p, &[]);
        assert_eq!(file_key_count(&p).unwrap(), 0);
        assert!(read_keys_file::<u64>(&p).unwrap().is_empty());
        assert_eq!(std::fs::metadata(&p).unwrap().len(), HEADER_LEN as u64);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn delta_spans_block_boundaries() {
        // More keys than one block holds: framing + restarts must stitch
        // blocks back together seamlessly.
        let p = tmp("delta-blocks.bin");
        let keys: Vec<u64> = (0..(2 * BLOCK_KEYS + 123) as u64).map(|i| i * 3).collect();
        write_delta(&p, &keys);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn delta_range_reads_and_index_lower_bound() {
        // The v2 analogue of `range_reads_and_index_lower_bound`: ranged
        // readers skip whole blocks, and the index searches restart points.
        let p = tmp("delta-range.bin");
        let n = 2 * BLOCK_KEYS as u64 + 500;
        let keys: Vec<u64> = (0..n).map(|i| i * 2).collect();
        write_delta(&p, &keys);

        let mut r = RunReader::<u64>::open_range(&p, 10, 5, 4096).unwrap();
        assert_eq!(r.read_chunk(100).unwrap(), vec![20, 22, 24, 26, 28]);

        // a range starting beyond the first block exercises the block skip
        let start = BLOCK_KEYS as u64 + 7;
        let mut r = RunReader::<u64>::open_range(&p, start, 3, 4096).unwrap();
        assert_eq!(
            r.read_chunk(10).unwrap(),
            vec![start * 2, start * 2 + 2, start * 2 + 4]
        );
        let mut r = RunReader::<u64>::open_range(&p, n - 2, 100, 4096).unwrap();
        assert_eq!(r.read_chunk(100).unwrap(), vec![(n - 2) * 2, (n - 1) * 2]);
        let mut r = RunReader::<u64>::open_range(&p, n + 9999, 10, 4096).unwrap();
        assert!(r.read_chunk(10).unwrap().is_empty());

        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx.len(), n);
        assert_eq!(idx.key_at(0).unwrap(), 0);
        assert_eq!(idx.key_at(n - 1).unwrap(), (n - 1) * 2);
        assert_eq!(idx.key_at(BLOCK_KEYS as u64).unwrap(), BLOCK_KEYS as u64 * 2);
        // present key -> its index; absent key -> insertion point; cuts
        // beyond the first block land via the restart-key directory
        assert_eq!(idx.lower_bound(40).unwrap(), 20);
        assert_eq!(idx.lower_bound(41).unwrap(), 21);
        let mid = (BLOCK_KEYS as u64 + 100) * 2;
        assert_eq!(idx.lower_bound(mid).unwrap(), BLOCK_KEYS as u64 + 100);
        assert_eq!(idx.lower_bound(0).unwrap(), 0);
        assert_eq!(idx.lower_bound(u64::MAX).unwrap(), n);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn delta_duplicate_runs_split_across_blocks_index_exactly() {
        // A duplicate plateau longer than one block: lower_bound must put
        // the cut at the plateau's first copy even though several blocks
        // share the same restart key.
        let p = tmp("delta-dup-cut.bin");
        let mut keys: Vec<u64> = vec![1; 100];
        keys.extend(vec![5u64; 2 * BLOCK_KEYS]);
        keys.extend(vec![9u64; 100]);
        write_delta(&p, &keys);
        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx.lower_bound(5).unwrap(), 100);
        assert_eq!(idx.lower_bound(6).unwrap(), 100 + 2 * BLOCK_KEYS as u64);
        assert_eq!(idx.lower_bound(9).unwrap(), 100 + 2 * BLOCK_KEYS as u64);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn delta_writer_rejects_unsorted_keys() {
        let p = tmp("delta-unsorted.bin");
        let mut w = RunWriter::<u64>::create_with(p.clone(), 4096, SpillCodec::Delta).unwrap();
        w.push(10).unwrap();
        let err = w.push(9).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("nondecreasing"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    /// Build a v2 file from hand-crafted block bytes.
    fn craft_v2(kind: KeyKind, count: u64, blocks: &[u8]) -> Vec<u8> {
        let mut bytes = SpillHeader::for_codec(SpillCodec::Delta, kind, count)
            .encode()
            .to_vec();
        bytes.extend_from_slice(blocks);
        bytes
    }

    /// One encoded block: count + payload_len + restart (width bytes) + payload.
    fn craft_block(count: u32, restart: u64, width: usize, payload: &[u8]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&count.to_le_bytes());
        b.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        b.extend_from_slice(&restart.to_le_bytes()[..width]);
        b.extend_from_slice(payload);
        b
    }

    #[test]
    fn corrupted_delta_blocks_fail_loudly() {
        // The v2 mirror of `truncated_and_corrupt_headers_fail_loudly`:
        // every class of block corruption gets a specific error.
        let p = tmp("delta-corrupt.bin");

        // zero-count block
        let bytes = craft_v2(KeyKind::U64, 1, &craft_block(0, 5, 8, &[]));
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("empty delta block"), "{err}");
        assert!(file_key_count(&p).is_err());

        // count past the block cap must error, never size an allocation
        let huge = craft_block(u32::MAX, 5, 8, &[0x00, 0x01]);
        let bytes = craft_v2(KeyKind::U64, u32::MAX as u64, &huge);
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("oversized delta block"), "{err}");
        assert!(file_key_count(&p).is_err());

        // payload ends mid-varint (continuation bit set on the last byte)
        let bytes = craft_v2(KeyKind::U64, 2, &craft_block(2, 5, 8, &[0x80]));
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("mid-varint"), "{err}");

        // zero-length duplicate run (token 0 followed by run 0)
        let bytes = craft_v2(KeyKind::U64, 2, &craft_block(2, 5, 8, &[0x00, 0x00]));
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("zero-length duplicate run"), "{err}");

        // duplicate run overrunning its block (run 5 in a 2-key block)
        let bytes = craft_v2(KeyKind::U64, 2, &craft_block(2, 5, 8, &[0x00, 0x05]));
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("overruns"), "{err}");

        // delta overflowing a narrow key domain (u32: restart MAX, delta 1)
        let bytes = craft_v2(KeyKind::U32, 2, &craft_block(2, u32::MAX as u64, 4, &[0x01]));
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u32>(&p).unwrap_err();
        assert!(err.to_string().contains("overflows the key domain"), "{err}");

        // truncated block payload (payload_len reaches past EOF)
        let mut blk = craft_block(3, 5, 8, &[0x01, 0x01]);
        let cut = blk.len() - 1;
        blk.truncate(cut);
        blk[4..8].copy_from_slice(&2u32.to_le_bytes()); // still promises 2 bytes
        let bytes = craft_v2(KeyKind::U64, 3, &blk);
        std::fs::write(&p, &bytes).unwrap();
        assert!(read_keys_file::<u64>(&p).is_err());
        let err = file_key_count(&p).unwrap_err();
        assert!(err.to_string().contains("truncated delta block"), "{err}");

        // blocks holding fewer keys than the header promises
        let bytes = craft_v2(KeyKind::U64, 9, &craft_block(2, 5, 8, &[0x01]));
        std::fs::write(&p, &bytes).unwrap();
        let err = file_key_count(&p).unwrap_err();
        assert!(err.to_string().contains("header promises"), "{err}");
        // the streaming reader hits EOF looking for the missing block
        assert!(read_keys_file::<u64>(&p).is_err());

        // payload longer than its tokens (framing says 3 bytes, tokens use 1)
        let blocks = [
            craft_block(2, 5, 8, &[0x01, 0x00, 0x00]),
            craft_block(2, 50, 8, &[0x01]),
        ]
        .concat();
        let bytes = craft_v2(KeyKind::U64, 4, &blocks);
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(
            err.to_string().contains("longer than its tokens")
                || err.to_string().contains("zero-length duplicate run"),
            "{err}"
        );

        // restart keys out of order across blocks (not a sorted run)
        let bytes = craft_v2(
            KeyKind::U64,
            2,
            &[craft_block(1, 50, 8, &[]), craft_block(1, 5, 8, &[])].concat(),
        );
        std::fs::write(&p, &bytes).unwrap();
        let err = RunIndex::<u64>::open(&p).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("out of order"), "{err}");

        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn transcode_raw_rewrites_delta_as_interchange() {
        let src = tmp("transcode-src.bin");
        let dst = tmp("transcode-dst.bin");
        let keys: Vec<u64> = (0..10_000).map(|i| i / 3).collect();
        write_delta(&src, &keys);
        let out = transcode_raw::<u64>(&src, &dst, 4096).unwrap();
        assert_eq!(out.n, keys.len() as u64);
        let h = read_header(&dst).unwrap().unwrap();
        assert_eq!(h.version, RAW_VERSION, "outputs are always raw");
        assert_eq!(read_keys_file::<u64>(&dst).unwrap(), keys);
        assert_eq!(
            std::fs::metadata(&dst).unwrap().len(),
            (HEADER_LEN + keys.len() * 8) as u64
        );
        let _ = std::fs::remove_file(&src);
        let _ = std::fs::remove_file(&dst);
    }

    #[test]
    fn run_file_bytes_track_the_on_disk_size() {
        let p = tmp("bytes.bin");
        let keys: Vec<u64> = (0..5000).collect();
        let raw = write_keys_file(&p, &keys).unwrap();
        assert_eq!(raw.bytes, std::fs::metadata(&p).unwrap().len());
        let delta = write_delta(&p, &keys);
        assert_eq!(delta.bytes, std::fs::metadata(&p).unwrap().len());
        // consecutive integers: 1-byte deltas vs 8-byte raw keys
        assert!(
            delta.bytes * 4 < raw.bytes,
            "delta {} !<< raw {}",
            delta.bytes,
            raw.bytes
        );
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn directory_seeks_match_the_block_walk_exactly() {
        // > 4 blocks, with a duplicate plateau straddling a boundary so
        // partial-block skips exercise the run-token path too
        let mut keys: Vec<u64> = (0..(BLOCK_KEYS as u64 * 4 + 777)).map(|i| i / 3).collect();
        keys.sort_unstable();
        let p = tmp("dir-seek");
        write_delta(&p, &keys);
        let dir = RunIndex::<u64>::open(&p).unwrap().into_directory().unwrap();
        assert!(dir.num_blocks() >= 4, "blocks={}", dir.num_blocks());
        let n = keys.len() as u64;
        for (start, len) in [
            (0u64, 100u64),
            (1, 5),
            (BLOCK_KEYS as u64 - 1, 3),
            (BLOCK_KEYS as u64, BLOCK_KEYS as u64),
            (BLOCK_KEYS as u64 * 2 + 17, 9000),
            (n - 1, 1),
            (n - 1, 100), // len clamps
            (n, 10),      // start clamps to EOF → empty
            (n / 2, 0),   // explicit empty range
        ] {
            let mut walk = RunReader::<u64>::open_range(&p, start, len, 1 << 12).unwrap();
            let mut seek =
                RunReader::<u64>::open_range_with(&p, start, len, 1 << 12, Some(&dir)).unwrap();
            assert_eq!(walk.remaining(), seek.remaining(), "range ({start},{len})");
            loop {
                let (a, b) = (walk.next().unwrap(), seek.next().unwrap());
                assert_eq!(a, b, "range ({start},{len})");
                if a.is_none() {
                    break;
                }
            }
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn raw_files_have_no_directory_and_ignore_one() {
        let keys: Vec<u64> = (0..5000).collect();
        let p = tmp("dir-raw");
        write_keys_file(&p, &keys).unwrap();
        assert!(RunIndex::<u64>::open(&p).unwrap().into_directory().is_none());
        // a (v2) directory handed to a raw open is simply unused
        let d = tmp("dir-raw-donor");
        write_delta(&d, &keys);
        let dir = RunIndex::<u64>::open(&d).unwrap().into_directory().unwrap();
        let mut r = RunReader::<u64>::open_range_with(&p, 1000, 5, 1 << 12, Some(&dir)).unwrap();
        assert_eq!(r.next().unwrap(), Some(1000));
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(&d);
    }

    #[test]
    fn stale_directory_falls_back_to_the_walk() {
        let keys: Vec<u64> = (0..(BLOCK_KEYS as u64 * 2 + 5)).collect();
        let p = tmp("dir-stale");
        write_delta(&p, &keys);
        let dir = RunIndex::<u64>::open(&p).unwrap().into_directory().unwrap();
        // rewrite the file shorter: the directory's key count no longer
        // matches, so the open must ignore it and still read correctly
        let shorter: Vec<u64> = (0..(BLOCK_KEYS as u64 + 3)).collect();
        write_delta(&p, &shorter);
        let mut r =
            RunReader::<u64>::open_range_with(&p, BLOCK_KEYS as u64, 3, 1 << 12, Some(&dir))
                .unwrap();
        assert_eq!(r.next().unwrap(), Some(BLOCK_KEYS as u64));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn directory_hits_and_rewalks_are_counted() {
        let _l = crate::obs::test_lock();
        let keys: Vec<u64> = (0..(BLOCK_KEYS as u64 * 2)).collect();
        let p = tmp("dir-count");
        write_delta(&p, &keys);
        let dir = RunIndex::<u64>::open(&p).unwrap().into_directory().unwrap();
        crate::obs::set_enabled(true);
        crate::obs::metrics::reset();
        drop(RunReader::<u64>::open_range_with(&p, 10, 5, 1 << 12, Some(&dir)).unwrap());
        drop(RunReader::<u64>::open_range(&p, 10, 5, 1 << 12).unwrap());
        drop(RunReader::<u64>::open_range(&p, 0, 5, 1 << 12).unwrap()); // no skip: uncounted
        crate::obs::set_enabled(false);
        let snap = crate::obs::metrics::snapshot();
        assert_eq!(snap.counters.get(crate::obs::C_DIR_HIT), Some(&1));
        assert_eq!(snap.counters.get(crate::obs::C_DIR_REWALK), Some(&1));
        let _ = std::fs::remove_file(&p);
    }

    // -- direct-IO pad ----------------------------------------------------

    #[test]
    fn padded_v1_files_read_back_without_the_pad() {
        let p = tmp("pad-v1.bin");
        let keys = [3u64, 7, 9];
        let mut h = SpillHeader::new(KeyKind::U64, 3);
        h.pad = 16;
        let mut bytes = h.encode().to_vec();
        for k in keys {
            bytes.extend_from_slice(&k.to_le_bytes());
        }
        bytes.extend_from_slice(&[0u8; 16]);
        std::fs::write(&p, &bytes).unwrap();
        assert_eq!(read_header(&p).unwrap().unwrap().pad, 16);
        assert_eq!(file_key_count(&p).unwrap(), 3);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        // a pad the file cannot hold fails loudly
        let mut h = SpillHeader::new(KeyKind::U64, 3);
        h.pad = 10_000;
        bytes[..HEADER_LEN].copy_from_slice(&h.encode());
        std::fs::write(&p, &bytes).unwrap();
        let err = read_keys_file::<u64>(&p).unwrap_err();
        assert!(err.to_string().contains("pad"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn padded_v2_files_walk_and_index_cleanly() {
        let p = tmp("pad-v2.bin");
        let keys: Vec<u64> = (0..(BLOCK_KEYS as u64 + 77)).map(|i| i * 5).collect();
        write_delta(&p, &keys);
        // append a fake pad and record it in the header
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(&[0u8; 24]);
        bytes[12..16].copy_from_slice(&24u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert_eq!(file_key_count(&p).unwrap(), keys.len() as u64);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx.lower_bound(10).unwrap(), 2);
        let _ = std::fs::remove_file(&p);
    }

    // -- v3 zigzag codec ---------------------------------------------------

    #[test]
    fn zigzag_roundtrips_unsorted_keys_in_every_domain() {
        let p = tmp("zz-rt.bin");

        let keys: Vec<u64> = vec![9, 2, 2, 2, u64::MAX, 0, 5, 5, u64::MAX - 1];
        let run = write_keys_file_codec(&p, &keys, SpillCodec::Zigzag).unwrap();
        assert_eq!(run.n, keys.len() as u64);
        let h = read_header(&p).unwrap().unwrap();
        assert_eq!(h.version, ZIGZAG_VERSION);
        assert_eq!(file_key_count(&p).unwrap(), keys.len() as u64);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        assert!(!verify_sorted_file::<u64>(&p, 4096).unwrap());

        let keys: Vec<u32> = vec![7, 0, u32::MAX, 3, 3, 1];
        write_keys_file_codec(&p, &keys, SpillCodec::Zigzag).unwrap();
        assert_eq!(read_keys_file::<u32>(&p).unwrap(), keys);

        let keys: Vec<f64> = vec![1.5, -2.25, f64::NEG_INFINITY, 0.0, -0.0, 1e300];
        write_keys_file_codec(&p, &keys, SpillCodec::Zigzag).unwrap();
        let back = read_keys_file::<f64>(&p).unwrap();
        let a: Vec<u64> = keys.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u64> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "bit-exact unsorted f64 reload");

        let keys: Vec<f32> = vec![2.5, -1.5, 0.0, 1e30, -1e30];
        write_keys_file_codec(&p, &keys, SpillCodec::Zigzag).unwrap();
        let back = read_keys_file::<f32>(&p).unwrap();
        let a: Vec<u32> = keys.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "bit-exact unsorted f32 reload");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn zigzag_spans_blocks_and_range_reads() {
        // an alternating sequence never collapses into dup runs and
        // exercises negative deltas across block boundaries
        let p = tmp("zz-blocks.bin");
        let n = 2 * BLOCK_KEYS as u64 + 321;
        let keys: Vec<u64> = (0..n).map(|i| if i % 2 == 0 { i * 7 } else { i }).collect();
        write_keys_file_codec(&p, &keys, SpillCodec::Zigzag).unwrap();
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        // ranged opens decode-skip (no sorted directory exists for v3)
        let start = BLOCK_KEYS as u64 + 11;
        let mut r = RunReader::<u64>::open_range(&p, start, 4, 4096).unwrap();
        assert_eq!(
            r.read_chunk(10).unwrap(),
            keys[start as usize..start as usize + 4]
        );
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn codec_entry_points_reject_wrong_orderings() {
        let p = tmp("zz-reject.bin");
        // sorted-run writers refuse zigzag…
        let err = RunWriter::<u64>::create_with(p.clone(), 4096, SpillCodec::Zigzag).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("unsorted"), "{err}");
        // …the unsorted entry refuses delta…
        let err =
            RunWriter::<u64>::create_unsorted(p.clone(), 4096, SpillCodec::Delta).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("sorted runs only"), "{err}");
        // …the spill path refuses zigzag…
        let err = RunWriter::<u64>::create_io(
            p.clone(),
            4096,
            SpillCodec::Zigzag,
            &IoCtx::sync(),
            false,
            false,
        )
        .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        // …and v3 files have no sorted-run index
        write_keys_file_codec(&p, &[5u64, 1, 9], SpillCodec::Zigzag).unwrap();
        let err = RunIndex::<u64>::open(&p).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("no run index"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn zigzag_helpers_are_inverses_at_the_edges() {
        for d in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN, 1 << 40, -(1 << 40)] {
            assert_eq!(unzigzag(zigzag(d)), d, "d={d}");
        }
        assert_eq!(zigzag(0), 0);
        assert!(zigzag(1) >= 1 && zigzag(-1) >= 1, "nonzero deltas never collide with the dup escape");
    }

    // -- v4/v5 records and string keys ------------------------------------

    use crate::key::{PrefixString, SortItem};

    /// A deterministic record stream: keys with heavy duplicates, payload
    /// = a function of the emission index so key-alignment is checkable.
    fn record_keys(n: u64) -> Vec<SortItem<u64, 8>> {
        (0..n)
            .map(|i| SortItem::new(i / 3, (i * 0x9E37_79B9).to_le_bytes()))
            .collect()
    }

    fn assert_records_eq(a: &[SortItem<u64, 8>], b: &[SortItem<u64, 8>], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.key, y.key, "{what}: key {i}");
            assert_eq!(x.val, y.val, "{what}: payload {i}");
        }
    }

    #[test]
    fn record_raw_roundtrips_as_v4() {
        let p = tmp("rec-v4.bin");
        let recs = record_keys(1000);
        write_keys_file(&p, &recs).unwrap();
        let h = read_header(&p).unwrap().unwrap();
        assert_eq!(h.version, RECORD_RAW_VERSION);
        assert_eq!(h.kind, KeyKind::U64);
        assert_eq!(h.lane, 8);
        assert_eq!(h.entry_width(), 16);
        assert_eq!(file_key_count(&p).unwrap(), 1000);
        assert_records_eq(&read_keys_file(&p).unwrap(), &recs, "v4 raw");
        assert!(verify_sorted_file::<SortItem<u64, 8>>(&p, 4096).unwrap());
        // raw range-opens seek at the full entry width
        let mut r = RunReader::<SortItem<u64, 8>>::open_range(&p, 500, 3, 4096).unwrap();
        assert_records_eq(&r.read_chunk(10).unwrap(), &recs[500..503], "v4 range");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn record_delta_roundtrips_as_v5_with_dup_runs() {
        // i/3 keys: every ordered-bits value repeats 3× with *distinct*
        // payloads — the dup-run escape must still emit per-key lanes
        let p = tmp("rec-v5.bin");
        let n = 2 * BLOCK_KEYS as u64 + 57;
        let recs = record_keys(n);
        let run = write_delta(&p, &recs);
        assert_eq!(run.n, n);
        let h = read_header(&p).unwrap().unwrap();
        assert_eq!(h.version, RECORD_DELTA_VERSION);
        assert_eq!(h.lane, 8);
        assert_eq!(file_key_count(&p).unwrap(), n);
        assert_records_eq(&read_keys_file(&p).unwrap(), &recs, "v5 delta");
        // decode-skipping and whole-block seeks both cross lane arrays
        let start = BLOCK_KEYS as u64 + 13;
        let mut r = RunReader::<SortItem<u64, 8>>::open_range(&p, start, 5, 4096).unwrap();
        assert_records_eq(
            &r.read_chunk(10).unwrap(),
            &recs[start as usize..start as usize + 5],
            "v5 range",
        );
        // the run index probes on bits alone
        let mut idx = RunIndex::<SortItem<u64, 8>>::open(&p).unwrap();
        assert_eq!(idx.lower_bound(100).unwrap(), 300);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn prefix_strings_roundtrip_in_both_record_codecs() {
        // heavy prefix ties: same first 8 bytes, ordering carried by the
        // tail lane — both codecs must reproduce the tails exactly
        let mut keys: Vec<PrefixString> = (0..500u32)
            .flat_map(|i| {
                let tie = PrefixString::from_bytes(format!("prefix00-{i:05}").as_bytes());
                let uniq = PrefixString::from_bytes(format!("key{i:05}").as_bytes());
                [tie, uniq]
            })
            .collect();
        keys.sort_unstable();
        for (p, codec, version) in [
            (tmp("str-v4.bin"), SpillCodec::Raw, RECORD_RAW_VERSION),
            (tmp("str-v5.bin"), SpillCodec::Delta, RECORD_DELTA_VERSION),
        ] {
            let mut w = RunWriter::<PrefixString>::create_with(p.clone(), 1 << 14, codec).unwrap();
            w.write_slice(&keys).unwrap();
            w.finish().unwrap();
            let h = read_header(&p).unwrap().unwrap();
            assert_eq!(h.version, version, "{codec:?}");
            assert_eq!(h.kind, KeyKind::Str);
            assert_eq!(h.lane, 8, "{codec:?}");
            assert_eq!(read_keys_file::<PrefixString>(&p).unwrap(), keys, "{codec:?}");
            assert!(verify_sorted_file::<PrefixString>(&p, 4096).unwrap());
            let _ = std::fs::remove_file(&p);
            let _ = std::fs::remove_file(sidecar_path(&p));
        }
    }

    #[test]
    fn verify_sorted_checks_full_order_not_just_bits() {
        // bit-sorted but tail-regressing: "aaaaaaaab" then "aaaaaaaaa"
        // shares the 8-byte prefix (equal bits) with a descending tail
        let p = tmp("str-fullorder.bin");
        let keys = [
            PrefixString::from_bytes(b"aaaaaaaab"),
            PrefixString::from_bytes(b"aaaaaaaaa"),
        ];
        write_keys_file(&p, &keys).unwrap();
        assert!(
            !verify_sorted_file::<PrefixString>(&p, 4096).unwrap(),
            "a tail regression under equal bits is a sort violation"
        );
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn record_spills_reject_lane_mismatches_and_zigzag() {
        // a bare-key v1 file must not open under a record type…
        let p = tmp("rec-mismatch.bin");
        write_keys_file::<u64>(&p, &[1, 2, 3]).unwrap();
        let err = RunReader::<SortItem<u64, 8>>::open(&p, 4096).unwrap_err();
        assert!(err.to_string().contains("lane"), "{err}");
        // …nor a record file under the bare key type
        write_keys_file::<SortItem<u64, 8>>(&p, &record_keys(3)).unwrap();
        let err = RunReader::<u64>::open(&p, 4096).unwrap_err();
        assert!(err.to_string().contains("lane"), "{err}");
        // zigzag is bits-only: record writers refuse it up front
        let err = RunWriter::<SortItem<u64, 8>>::create_unsorted(
            p.clone(),
            4096,
            SpillCodec::Zigzag,
        )
        .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        assert!(err.to_string().contains("bits-only"), "{err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn record_sidecars_index_v5_blocks() {
        let p = tmp("rec-sidecar.bin");
        let n = 3 * BLOCK_KEYS as u64 + 71;
        let recs = record_keys(n);
        write_delta_sidecar(&p, &recs);
        assert!(sidecar_path(&p).exists());
        let mut idx = RunIndex::<SortItem<u64, 8>>::open(&p).unwrap();
        for probe in [0u64, 1, BLOCK_KEYS as u64, n / 3, u64::MAX] {
            let want = recs.partition_point(|r| r.key < probe) as u64;
            assert_eq!(idx.lower_bound(probe).unwrap(), want, "probe={probe}");
        }
        // a ranged open through the block directory lands exactly
        let dir = RunIndex::<SortItem<u64, 8>>::open(&p).unwrap().into_directory().unwrap();
        let start = 2 * BLOCK_KEYS as u64 + 17;
        let mut r = RunReader::<SortItem<u64, 8>>::open_range_with(&p, start, 4, 4096, Some(&dir))
            .unwrap();
        assert_records_eq(
            &r.read_chunk(10).unwrap(),
            &recs[start as usize..start as usize + 4],
            "v5 dir range",
        );
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(sidecar_path(&p));
    }

    // -- block side-cars ---------------------------------------------------

    /// Write sorted keys through the spill path with a side-car.
    fn write_delta_sidecar<K: SortKey>(path: &Path, keys: &[K]) -> RunFile {
        let mut w = RunWriter::<K>::create_io(
            path.to_path_buf(),
            1 << 14,
            SpillCodec::Delta,
            &IoCtx::sync(),
            true,
            false,
        )
        .unwrap();
        w.write_slice(keys).unwrap();
        w.finish().unwrap()
    }

    #[test]
    fn sidecar_and_walk_agree_and_misses_fall_back() {
        let _l = crate::obs::test_lock();
        let p = tmp("sidecar.bin");
        let keys: Vec<u64> = (0..(BLOCK_KEYS as u64 * 3 + 99)).map(|i| i / 2).collect();
        write_delta_sidecar(&p, &keys);
        let sc = sidecar_path(&p);
        assert!(sc.exists(), "spill-path delta runs write a side-car");

        crate::obs::set_enabled(true);
        crate::obs::metrics::reset();
        // side-car present: loaded, not walked
        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        for probe in [0u64, 1, 77, BLOCK_KEYS as u64, keys.len() as u64 / 2, u64::MAX] {
            let want = keys.partition_point(|&k| k < probe) as u64;
            assert_eq!(idx.lower_bound(probe).unwrap(), want, "probe={probe}");
        }
        // corrupt side-car: quietly ignored, same answers via the walk
        let mut bytes = std::fs::read(&sc).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&sc, &bytes).unwrap();
        let mut idx2 = RunIndex::<u64>::open(&p).unwrap();
        for probe in [0u64, 77, keys.len() as u64 / 2, u64::MAX] {
            assert_eq!(
                idx2.lower_bound(probe).unwrap(),
                idx.lower_bound(probe).unwrap(),
                "probe={probe}"
            );
        }
        // absent side-car: same again
        std::fs::remove_file(&sc).unwrap();
        let mut idx3 = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx3.lower_bound(500).unwrap(), idx.lower_bound(500).unwrap());
        crate::obs::set_enabled(false);
        let snap = crate::obs::metrics::snapshot();
        assert_eq!(snap.counters.get(crate::obs::C_SIDECAR_HIT), Some(&1));
        assert_eq!(snap.counters.get(crate::obs::C_SIDECAR_MISS), Some(&2));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn sidecar_bounds_skip_block_decodes_in_lower_bound() {
        // a bound past a block's true maximum but before the next restart
        // resolves without decoding when the side-car's exact maxima are
        // present (the walk-derived upper bound can never certify this)
        let p = tmp("sidecar-skip.bin");
        let mut keys: Vec<u64> = Vec::new();
        for b in 0..4u64 {
            // block-sized strides of even keys: gaps between blocks
            keys.extend((0..BLOCK_KEYS as u64).map(|i| b * 1_000_000 + i * 2));
        }
        write_delta_sidecar(&p, &keys);
        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        // bound = last key of block 0 + 1 (odd → absent, past the max)
        let bound = (BLOCK_KEYS as u64 - 1) * 2 + 1;
        assert_eq!(idx.lower_bound(bound).unwrap(), BLOCK_KEYS as u64);
        assert_eq!(
            idx.lower_bound(u64::MAX).unwrap(),
            keys.len() as u64,
            "a bound past every block resolves through exact maxima alone"
        );
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(sidecar_path(&p));
    }

    #[test]
    fn narrow_range_opens_count_skipped_blocks() {
        let _l = crate::obs::test_lock();
        let p = tmp("blocks-skipped.bin");
        let keys: Vec<u64> = (0..(BLOCK_KEYS as u64 * 5)).collect();
        write_delta_sidecar(&p, &keys);
        let dir = RunIndex::<u64>::open(&p).unwrap().into_directory().unwrap();
        let total = dir.num_blocks() as u64;
        assert!(total >= 5);
        crate::obs::set_enabled(true);
        crate::obs::metrics::reset();
        // a one-block-wide cut in the middle touches exactly one block
        let mut r = RunReader::<u64>::open_range_with(
            &p,
            2 * BLOCK_KEYS as u64 + 10,
            100,
            1 << 12,
            Some(&dir),
        )
        .unwrap();
        assert_eq!(r.read_chunk(3).unwrap(), vec![
            2 * BLOCK_KEYS as u64 + 10,
            2 * BLOCK_KEYS as u64 + 11,
            2 * BLOCK_KEYS as u64 + 12
        ]);
        crate::obs::set_enabled(false);
        let snap = crate::obs::metrics::snapshot();
        assert_eq!(
            snap.counters.get(crate::obs::C_BLOCKS_SKIPPED),
            Some(&(total - 1)),
            "all but the cut's one block must be skipped"
        );
        let _ = std::fs::remove_file(&p);
        let _ = std::fs::remove_file(sidecar_path(&p));
    }

    // -- striped spill dirs ------------------------------------------------

    #[test]
    fn striped_spill_dirs_rotate_and_clean_up() {
        let root_a = tmp("stripe-a");
        let root_b = tmp("stripe-b");
        let made: Vec<PathBuf>;
        {
            let mut s = SpillDir::create_striped(&[root_a.clone(), root_b.clone()]).unwrap();
            assert_eq!(s.num_stripes(), 2);
            let runs: Vec<PathBuf> = (0..4).map(|_| s.next_run_path()).collect();
            // consecutive runs land on alternating stripes
            assert!(runs[0].starts_with(&root_a), "{:?}", runs[0]);
            assert!(runs[1].starts_with(&root_b), "{:?}", runs[1]);
            assert!(runs[2].starts_with(&root_a), "{:?}", runs[2]);
            assert!(runs[3].starts_with(&root_b), "{:?}", runs[3]);
            for r in &runs {
                write_keys_file(r, &[1u64]).unwrap();
            }
            made = runs.iter().map(|r| r.parent().unwrap().to_path_buf()).collect();
            assert!(made.iter().all(|d| d.exists()));
        }
        assert!(
            made.iter().all(|d| !d.exists()),
            "every stripe must be removed on drop"
        );
        let _ = std::fs::remove_dir_all(&root_a);
        let _ = std::fs::remove_dir_all(&root_b);
    }
}
