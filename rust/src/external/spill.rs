//! Spill codec and run files — the IO substrate of the external sorter.
//!
//! Keys are stored as fixed-width 8-byte little-endian values in their
//! *native* encoding (`f64::to_le_bytes` / `u64::to_le_bytes`), the same
//! format `aipso gen --out` writes, so any generated dataset file is a
//! valid `sort_file` input and outputs round-trip byte-exactly. The
//! [`ExtKey`] trait bounds the codec to the paper's two key domains.

use std::fs::File;
use std::io::{self, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::key::SortKey;

/// Bytes per encoded key.
pub const KEY_BYTES: usize = 8;

/// A key type the external sorter can spill: [`SortKey`] plus a fixed
/// 8-byte little-endian native encoding (the paper's two domains).
pub trait ExtKey: SortKey {
    /// Encode the key as 8 little-endian bytes (its native representation).
    fn to_le8(self) -> [u8; 8];
    /// Decode a key from its 8-byte little-endian encoding.
    fn from_le8(bytes: [u8; 8]) -> Self;
}

impl ExtKey for u64 {
    #[inline(always)]
    fn to_le8(self) -> [u8; 8] {
        self.to_le_bytes()
    }

    #[inline(always)]
    fn from_le8(bytes: [u8; 8]) -> Self {
        u64::from_le_bytes(bytes)
    }
}

impl ExtKey for f64 {
    #[inline(always)]
    fn to_le8(self) -> [u8; 8] {
        self.to_le_bytes()
    }

    #[inline(always)]
    fn from_le8(bytes: [u8; 8]) -> Self {
        f64::from_le_bytes(bytes)
    }
}

/// A spilled run (or any key file) on disk.
#[derive(Debug, Clone)]
pub struct RunFile {
    /// Location of the run on disk.
    pub path: PathBuf,
    /// Number of keys in the file.
    pub n: u64,
}

/// Scratch directory owning the spilled runs of one sort; removed
/// (best-effort) on drop.
#[derive(Debug)]
pub struct SpillDir {
    dir: PathBuf,
    counter: u64,
}

impl SpillDir {
    /// Create a fresh uniquely-named scratch directory under `base`
    /// (`None` = the OS temp dir).
    pub fn create(base: Option<&Path>) -> io::Result<SpillDir> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let base = base
            .map(Path::to_path_buf)
            .unwrap_or_else(std::env::temp_dir);
        let dir = base.join(format!(
            "aipso-extsort-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        Ok(SpillDir { dir, counter: 0 })
    }

    /// The scratch directory's location.
    pub fn path(&self) -> &Path {
        &self.dir
    }

    /// Fresh path for the next spilled run.
    pub fn next_run_path(&mut self) -> PathBuf {
        self.counter += 1;
        self.dir.join(format!("run-{:06}.bin", self.counter))
    }
}

impl Drop for SpillDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Buffered streaming reader over a key file.
pub struct RunReader<K: ExtKey> {
    r: BufReader<File>,
    remaining: u64,
    _pd: PhantomData<K>,
}

impl<K: ExtKey> RunReader<K> {
    /// Open a buffered reader over a whole key file.
    pub fn open(path: &Path, io_buffer: usize) -> io::Result<RunReader<K>> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        if len % KEY_BYTES as u64 != 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{}: length {len} is not a multiple of {KEY_BYTES}",
                    path.display()
                ),
            ));
        }
        Ok(RunReader {
            r: BufReader::with_capacity(io_buffer.max(4096), file),
            remaining: len / KEY_BYTES as u64,
            _pd: PhantomData,
        })
    }

    /// Open a buffered reader over the key range `[start, start + len)` of
    /// a key file (indices in keys, clamped to the file). The sharded
    /// merge streams each run's shard segment through one of these.
    pub fn open_range(
        path: &Path,
        start: u64,
        len: u64,
        io_buffer: usize,
    ) -> io::Result<RunReader<K>> {
        let mut file = File::open(path)?;
        let bytes = file.metadata()?.len();
        if bytes % KEY_BYTES as u64 != 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{}: length {bytes} is not a multiple of {KEY_BYTES}",
                    path.display()
                ),
            ));
        }
        let n = bytes / KEY_BYTES as u64;
        let start = start.min(n);
        let len = len.min(n - start);
        file.seek(SeekFrom::Start(start * KEY_BYTES as u64))?;
        Ok(RunReader {
            r: BufReader::with_capacity(io_buffer.max(4096), file),
            remaining: len,
            _pd: PhantomData,
        })
    }

    /// Keys left in the file.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// Next key, or `None` at end of file.
    #[allow(clippy::should_implement_trait)] // fallible: io::Result, not Iterator
    pub fn next(&mut self) -> io::Result<Option<K>> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let mut buf = [0u8; KEY_BYTES];
        self.r.read_exact(&mut buf)?;
        self.remaining -= 1;
        Ok(Some(K::from_le8(buf)))
    }

    /// Read up to `max` keys; an empty vec means EOF. Decodes through a
    /// fixed scratch slab so peak memory stays `max * 8 + O(slab)` — not
    /// double the chunk, which would break the sorter's byte budget.
    pub fn read_chunk(&mut self, max: usize) -> io::Result<Vec<K>> {
        let take = (self.remaining.min(max as u64)) as usize;
        if take == 0 {
            return Ok(Vec::new());
        }
        let mut out = Vec::with_capacity(take);
        let mut slab = [0u8; 1024 * KEY_BYTES];
        let mut left = take;
        while left > 0 {
            let now = left.min(slab.len() / KEY_BYTES);
            let bytes = &mut slab[..now * KEY_BYTES];
            self.r.read_exact(bytes)?;
            for c in bytes.chunks_exact(KEY_BYTES) {
                let mut b = [0u8; KEY_BYTES];
                b.copy_from_slice(c);
                out.push(K::from_le8(b));
            }
            left -= now;
        }
        self.remaining -= take as u64;
        Ok(out)
    }
}

/// Random-access view of a sorted run file: positioned single-key reads
/// and a lower-bound binary search over the key order. The shard planner
/// uses this to locate shard boundaries in `O(log n)` seeks per run
/// instead of streaming the whole file.
pub struct RunIndex<K: ExtKey> {
    file: File,
    n: u64,
    _pd: PhantomData<K>,
}

impl<K: ExtKey> RunIndex<K> {
    /// Open a key file for random access.
    pub fn open(path: &Path) -> io::Result<RunIndex<K>> {
        let file = File::open(path)?;
        let bytes = file.metadata()?.len();
        if bytes % KEY_BYTES as u64 != 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{}: length {bytes} is not a multiple of {KEY_BYTES}",
                    path.display()
                ),
            ));
        }
        Ok(RunIndex {
            file,
            n: bytes / KEY_BYTES as u64,
            _pd: PhantomData,
        })
    }

    /// Number of keys in the file.
    pub fn len(&self) -> u64 {
        self.n
    }

    /// True when the file holds no keys.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Read the key at index `idx` with one positioned read.
    pub fn key_at(&mut self, idx: u64) -> io::Result<K> {
        debug_assert!(idx < self.n);
        self.file.seek(SeekFrom::Start(idx * KEY_BYTES as u64))?;
        let mut buf = [0u8; KEY_BYTES];
        self.file.read_exact(&mut buf)?;
        Ok(K::from_le8(buf))
    }

    /// First index whose key's ordered bits are `>= bound_bits`, assuming
    /// the file is sorted (`n` when every key is below the bound). This is
    /// the shard-boundary cut: keys equal to the bound fall into the shard
    /// that *starts* at the bound, so duplicates never straddle a cut.
    pub fn lower_bound(&mut self, bound_bits: u64) -> io::Result<u64> {
        let (mut lo, mut hi) = (0u64, self.n);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.key_at(mid)?.to_bits_ordered() < bound_bits {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        Ok(lo)
    }
}

/// Buffered streaming writer producing a [`RunFile`].
pub struct RunWriter<K: ExtKey> {
    w: BufWriter<File>,
    path: PathBuf,
    n: u64,
    _pd: PhantomData<K>,
}

impl<K: ExtKey> RunWriter<K> {
    /// Create (truncate) the file at `path` and return a writer over it.
    pub fn create(path: PathBuf, io_buffer: usize) -> io::Result<RunWriter<K>> {
        let file = File::create(&path)?;
        Ok(RunWriter {
            w: BufWriter::with_capacity(io_buffer.max(4096), file),
            path,
            n: 0,
            _pd: PhantomData,
        })
    }

    /// Append one key.
    #[inline]
    pub fn push(&mut self, key: K) -> io::Result<()> {
        self.w.write_all(&key.to_le8())?;
        self.n += 1;
        Ok(())
    }

    /// Bulk spill: encodes through a fixed slab and writes in blocks,
    /// mirroring `RunReader::read_chunk` (no per-key `write_all`).
    pub fn write_slice(&mut self, keys: &[K]) -> io::Result<()> {
        let mut slab = [0u8; 1024 * KEY_BYTES];
        for block in keys.chunks(1024) {
            let bytes = &mut slab[..block.len() * KEY_BYTES];
            for (c, k) in bytes.chunks_exact_mut(KEY_BYTES).zip(block) {
                c.copy_from_slice(&k.to_le8());
            }
            self.w.write_all(bytes)?;
        }
        self.n += keys.len() as u64;
        Ok(())
    }

    /// Flush and close, returning the finished run's metadata.
    pub fn finish(mut self) -> io::Result<RunFile> {
        self.w.flush()?;
        Ok(RunFile {
            path: self.path,
            n: self.n,
        })
    }
}

/// Write a whole in-memory slice as a key file.
pub fn write_keys_file<K: ExtKey>(path: &Path, keys: &[K]) -> io::Result<RunFile> {
    let mut w = RunWriter::create(path.to_path_buf(), 1 << 16)?;
    w.write_slice(keys)?;
    w.finish()
}

/// Load a whole key file into memory (tests / small files only).
pub fn read_keys_file<K: ExtKey>(path: &Path) -> io::Result<Vec<K>> {
    let mut r = RunReader::<K>::open(path, 1 << 16)?;
    let n = r.remaining() as usize;
    r.read_chunk(n)
}

/// Number of keys in a key file (from its byte length).
pub fn file_key_count(path: &Path) -> io::Result<u64> {
    let len = std::fs::metadata(path)?.len();
    if len % KEY_BYTES as u64 != 0 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "{}: length {len} is not a multiple of {KEY_BYTES}",
                path.display()
            ),
        ));
    }
    Ok(len / KEY_BYTES as u64)
}

/// Stream-verify that a key file is nondecreasing under the key's total
/// order, in O(io_buffer) memory.
pub fn verify_sorted_file<K: ExtKey>(path: &Path, io_buffer: usize) -> io::Result<bool> {
    let mut r = RunReader::<K>::open(path, io_buffer)?;
    let mut prev: Option<u64> = None;
    while let Some(k) = r.next()? {
        let bits = k.to_bits_ordered();
        if let Some(p) = prev {
            if bits < p {
                return Ok(false);
            }
        }
        prev = Some(bits);
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("aipso-spill-test-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_u64_and_f64() {
        let p = tmp("rt-u64.bin");
        let keys: Vec<u64> = vec![0, 1, u64::MAX, 42, 7];
        write_keys_file(&p, &keys).unwrap();
        assert_eq!(file_key_count(&p).unwrap(), 5);
        assert_eq!(read_keys_file::<u64>(&p).unwrap(), keys);
        let _ = std::fs::remove_file(&p);

        let p = tmp("rt-f64.bin");
        let keys: Vec<f64> = vec![-1.5, 0.0, -0.0, 1e300, 1e-300];
        write_keys_file(&p, &keys).unwrap();
        let back = read_keys_file::<f64>(&p).unwrap();
        let a: Vec<u64> = keys.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u64> = back.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "bit-exact reload");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn chunked_reads_cover_file() {
        let p = tmp("chunks.bin");
        let keys: Vec<u64> = (0..1000).collect();
        write_keys_file(&p, &keys).unwrap();
        let mut r = RunReader::<u64>::open(&p, 4096).unwrap();
        let mut got = Vec::new();
        loop {
            let c = r.read_chunk(64);
            let c = c.unwrap();
            if c.is_empty() {
                break;
            }
            got.extend(c);
        }
        assert_eq!(got, keys);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn verify_detects_disorder() {
        let p = tmp("verify.bin");
        write_keys_file(&p, &[1u64, 2, 3]).unwrap();
        assert!(verify_sorted_file::<u64>(&p, 4096).unwrap());
        write_keys_file(&p, &[3u64, 2]).unwrap();
        assert!(!verify_sorted_file::<u64>(&p, 4096).unwrap());
        write_keys_file::<u64>(&p, &[]).unwrap();
        assert!(verify_sorted_file::<u64>(&p, 4096).unwrap());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn f64_order_via_bits_in_verify() {
        let p = tmp("verify-f64.bin");
        write_keys_file(&p, &[-2.5f64, -0.0, 0.0, 3.5]).unwrap();
        assert!(verify_sorted_file::<f64>(&p, 4096).unwrap());
        write_keys_file(&p, &[0.0f64, -0.0]).unwrap();
        assert!(!verify_sorted_file::<f64>(&p, 4096).unwrap());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn spill_dir_cleans_up() {
        let dir;
        {
            let mut s = SpillDir::create(None).unwrap();
            dir = s.path().to_path_buf();
            let p = s.next_run_path();
            write_keys_file(&p, &[1u64]).unwrap();
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "SpillDir must remove itself on drop");
    }

    #[test]
    fn range_reads_and_index_lower_bound() {
        let p = tmp("range.bin");
        let keys: Vec<u64> = (0..500).map(|i| i * 2).collect(); // evens 0..998
        write_keys_file(&p, &keys).unwrap();

        let mut r = RunReader::<u64>::open_range(&p, 10, 5, 4096).unwrap();
        let got = r.read_chunk(100).unwrap();
        assert_eq!(got, vec![20, 22, 24, 26, 28]);

        // ranges clamp to the file
        let mut r = RunReader::<u64>::open_range(&p, 498, 100, 4096).unwrap();
        assert_eq!(r.read_chunk(100).unwrap(), vec![996, 998]);
        let mut r = RunReader::<u64>::open_range(&p, 9999, 10, 4096).unwrap();
        assert!(r.read_chunk(10).unwrap().is_empty());

        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx.len(), 500);
        assert_eq!(idx.key_at(0).unwrap(), 0);
        assert_eq!(idx.key_at(499).unwrap(), 998);
        // present key -> its index; absent key -> insertion point
        assert_eq!(idx.lower_bound(40u64.to_bits_ordered()).unwrap(), 20);
        assert_eq!(idx.lower_bound(41u64.to_bits_ordered()).unwrap(), 21);
        assert_eq!(idx.lower_bound(0).unwrap(), 0);
        assert_eq!(idx.lower_bound(u64::MAX).unwrap(), 500);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn empty_run_index_is_harmless() {
        // A zero-key run (legal: an empty input still truncates an output
        // file, and sharding may probe any run) must index without error:
        // every lower bound is 0, never an out-of-range read.
        let p = tmp("empty-idx.bin");
        write_keys_file::<u64>(&p, &[]).unwrap();
        let mut idx = RunIndex::<u64>::open(&p).unwrap();
        assert_eq!(idx.len(), 0);
        assert!(idx.is_empty());
        assert_eq!(idx.lower_bound(0).unwrap(), 0);
        assert_eq!(idx.lower_bound(u64::MAX).unwrap(), 0);
        // range reads over the empty file clamp to nothing
        let mut r = RunReader::<u64>::open_range(&p, 0, 10, 4096).unwrap();
        assert!(r.read_chunk(10).unwrap().is_empty());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn odd_length_file_rejected() {
        let p = tmp("odd.bin");
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(RunReader::<u64>::open(&p, 4096).is_err());
        assert!(file_key_count(&p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
