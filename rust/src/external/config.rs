//! Tuning knobs for the out-of-core sorter.
//!
//! The contract: `sort_file`/`sort_iter` never hold more than roughly
//! [`ExternalConfig::memory_budget`] bytes of keys in memory at once. The
//! budget sets the run length (one chunk = one sorted run — three pipeline
//! stages share it when IO is overlapped) and clamps the merge fan-in so
//! `k` read buffers also stay inside it.

use std::path::PathBuf;

use crate::external::io::IoBackendKind;
use crate::external::spill::SpillCodec;

/// How sorted runs are produced from raw chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunGen {
    /// Train one monotonic RMI on a sample of the *first* chunk and reuse
    /// it to partition every subsequent chunk (PCF-style model reuse);
    /// chunks whose distribution drifted fall back to IPS⁴o.
    LearnedReuse,
    /// Plain IPS⁴o run generation (the classical external-sort baseline
    /// that `fig_external` compares against).
    Ips4o,
}

/// Rolling retrain policy for the shared model.
///
/// The external sorter trains one RMI on the first chunk and reuses it; a
/// per-chunk drift probe guards the reuse. Without retraining, a regime
/// change mid-stream permanently demotes every later chunk to the IPS⁴o
/// fallback. With retraining enabled, once the probe fails for
/// `retrain_after` *consecutive* chunks, run generation resamples the
/// offending chunk, trains a fresh monotonic RMI on it and installs it as
/// the shared model for subsequent chunks — opening a new model *epoch*
/// (see [`crate::external::EpochStats`]). Successful installs are bounded
/// by `max_retrains` per sort; an attempt that trips Algorithm 5's
/// duplicate guard keeps the old model, does not count, and resets the
/// streak so attempts stay one per `retrain_after` chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetrainPolicy {
    /// Consecutive drifted chunks before a retrain attempt (0 disables
    /// retraining: every drifted chunk falls back to IPS⁴o forever).
    pub retrain_after: usize,
    /// Maximum successful retrains per sort (0 disables retraining).
    pub max_retrains: usize,
}

impl RetrainPolicy {
    /// The pre-retrain behaviour: drifted chunks always fall back.
    pub fn disabled() -> RetrainPolicy {
        RetrainPolicy {
            retrain_after: 0,
            max_retrains: 0,
        }
    }

    /// True when the policy can ever trigger a retrain.
    pub fn enabled(&self) -> bool {
        self.retrain_after > 0 && self.max_retrains > 0
    }
}

impl Default for RetrainPolicy {
    fn default() -> Self {
        // Two consecutive failed probes before retraining: one drifted
        // chunk can be an outlier burst, two in a row is a regime.
        RetrainPolicy {
            retrain_after: 2,
            max_retrains: 4,
        }
    }
}

/// Configuration for [`crate::external::sort_file`] / `sort_iter`.
#[derive(Debug, Clone)]
pub struct ExternalConfig {
    /// In-memory working-set budget in bytes. In the serial pipeline
    /// (`threads == 1`) one chunk (= one run) holds
    /// `memory_budget / size_of::<K>()` keys; the overlapped pipeline
    /// (`threads > 1`) keeps three chunks resident (one being read, one
    /// being sorted, one being spilled), so each holds a third of that.
    pub memory_budget: usize,
    /// Maximum runs merged per k-way pass (clamped so the merge readers'
    /// buffers fit the memory budget too).
    pub merge_fanout: usize,
    /// Buffered-IO size in bytes per run reader/writer.
    pub io_buffer: usize,
    /// Keys per buffer block when partitioning a chunk with the shared RMI
    /// (same role as `Aips2oConfig::block`).
    pub block: usize,
    /// Run-generation strategy.
    pub run_gen: RunGen,
    /// Sample size for the shared RMI trained on the first chunk.
    pub rmi_sample: usize,
    /// Second-level models in the shared RMI.
    pub rmi_leaves: usize,
    /// Buckets when partitioning a chunk with the shared RMI.
    pub rmi_buckets: usize,
    /// Duplicate fraction in the first-chunk sample above which no RMI is
    /// trained at all (Algorithm 5's guard, applied once up front).
    pub max_dup_fraction: f64,
    /// Chunks smaller than this always use the IPS⁴o path (model and
    /// partition setup cannot amortize).
    pub min_learned_chunk: usize,
    /// Per-chunk probe size for the drift check.
    pub drift_probe: usize,
    /// Mean |F(x) − empirical CDF(x)| over the probe above which the chunk
    /// is declared drifted and falls back to IPS⁴o.
    pub drift_threshold: f64,
    /// Rolling retrain policy: how many consecutive drifted chunks trigger
    /// training a replacement model, and how many replacements one sort
    /// may install ([`RetrainPolicy::disabled`] pins the pre-retrain
    /// behaviour where drift always demotes the chunk).
    pub retrain: RetrainPolicy,
    /// Payload codec for spilled runs: [`SpillCodec::Raw`] writes
    /// fixed-width keys, [`SpillCodec::Delta`] writes delta+varint blocks
    /// (sorted runs compress, duplicate-heavy ones dramatically — the
    /// merge is IO-bound, so fewer spill bytes are wall-clock). The final
    /// output file is always raw (the interchange format), so both codecs
    /// produce byte-identical outputs. Defaults to the `SPILL_CODEC`
    /// environment variable (`raw`/`delta`) when set, else raw — CI runs
    /// the external suite once per codec through that variable.
    pub spill_codec: SpillCodec,
    /// Exponential age decay applied to the epoch mixture weights the
    /// sharded merge cuts its quantiles from: epoch `e` of `E` weighs
    /// `learned_keys(e) × decay^(E−1−e)`. `1.0` (the default) weighs
    /// epochs purely by their learned keys; values below 1 tilt the cuts
    /// toward the most recent regimes of a long stream. Balance-only —
    /// the skew guard still backstops any weighting. Values outside
    /// `(0, 1]` are treated as 1.0.
    pub epoch_age_decay: f64,
    /// Worker threads (0 = all cores). `1` selects the fully serial
    /// reference pipeline; `> 1` enables overlapped chunk IO during run
    /// generation and the RMI-sharded parallel merge.
    pub threads: usize,
    /// Shards for the RMI-partitioned final merge (0 = one per worker
    /// thread, 1 = always the serial loser-tree merge).
    pub merge_shards: usize,
    /// Drift guard for the sharded merge: when the largest shard exceeds
    /// `total_keys / shards` by this factor, the quantile cuts derived from
    /// the first-chunk RMI no longer describe the data and the merge falls
    /// back to the serial loser tree.
    pub shard_skew_limit: f64,
    /// Minimum keys per shard; with fewer, per-shard setup (boundary
    /// binary searches, reader buffers) cannot amortize and the merge
    /// stays serial.
    pub min_shard_keys: usize,
    /// Directories spilled runs are striped across round-robin (empty =
    /// one stripe in the OS temp dir). Pointing the entries at distinct
    /// disks multiplies spill bandwidth; a single entry reproduces the
    /// old one-spill-dir behaviour. Defaults to the colon-separated
    /// `AIPSO_SPILL_DIRS` environment variable when set — CI runs the
    /// external suite striped over two tmpfs dirs through it.
    pub spill_dirs: Vec<PathBuf>,
    /// IO transport for spill reads and writes:
    /// [`IoBackendKind::Sync`] issues positioned IO inline,
    /// [`IoBackendKind::Pool`] routes it through a submission-queue
    /// worker pool with completion handles (overlapping encode/merge
    /// compute with disk time). Both are byte-identical. Defaults to the
    /// `AIPSO_IO_BACKEND` environment variable (`sync`/`pool`) when
    /// set, else sync.
    pub io_backend: IoBackendKind,
    /// Attempt `O_DIRECT` for spill-dir run files so budget-accounted
    /// spill data stops being double-cached by the page cache. Files
    /// gain an alignment pad after the final block (recorded in the
    /// spill header, invisible to readers); filesystems that refuse
    /// direct IO fall back to buffered writes per file. Never applied
    /// to final outputs.
    pub direct_io: bool,
}

impl Default for ExternalConfig {
    fn default() -> Self {
        ExternalConfig {
            memory_budget: 64 << 20,
            merge_fanout: 16,
            io_buffer: 1 << 20,
            block: 128,
            run_gen: RunGen::LearnedReuse,
            rmi_sample: 1 << 16,
            rmi_leaves: 1024,
            rmi_buckets: 1024,
            max_dup_fraction: 0.10,
            min_learned_chunk: 8192,
            drift_probe: 2048,
            drift_threshold: 0.05,
            retrain: RetrainPolicy::default(),
            spill_codec: SpillCodec::from_env().unwrap_or(SpillCodec::Raw),
            epoch_age_decay: 1.0,
            threads: 0,
            merge_shards: 0,
            shard_skew_limit: 4.0,
            min_shard_keys: 1 << 16,
            spill_dirs: spill_dirs_from_env(),
            io_backend: IoBackendKind::from_env().unwrap_or(IoBackendKind::Sync),
            direct_io: false,
        }
    }
}

/// Spill stripe set named by the colon-separated `AIPSO_SPILL_DIRS`
/// environment variable (empty/unset = OS temp dir, one stripe).
fn spill_dirs_from_env() -> Vec<PathBuf> {
    match std::env::var("AIPSO_SPILL_DIRS") {
        Ok(v) => v
            .split(':')
            .filter(|s| !s.is_empty())
            .map(PathBuf::from)
            .collect(),
        Err(_) => Vec::new(),
    }
}

impl ExternalConfig {
    /// Default config with a specific memory budget in bytes.
    pub fn with_budget(bytes: usize) -> ExternalConfig {
        ExternalConfig {
            memory_budget: bytes,
            ..ExternalConfig::default()
        }
    }

    /// Keys per chunk (= per run) for key type `K` under the budget, in
    /// the serial pipeline (one resident chunk). Scales with the key's
    /// in-memory size — which equals its spill width for all four
    /// supported domains — so a 4-byte key stream fits twice the keys per
    /// chunk (and per run) of an 8-byte one under the same budget.
    pub fn chunk_keys<K>(&self) -> usize {
        (self.memory_budget / std::mem::size_of::<K>().max(1)).max(64)
    }

    /// Keys per chunk in the overlapped pipeline: the reader, sorter and
    /// spill writer each hold one chunk, so the budget is split three ways
    /// (and, like [`ExternalConfig::chunk_keys`], 4-byte keys fit twice as
    /// many per chunk).
    pub fn pipelined_chunk_keys<K>(&self) -> usize {
        (self.memory_budget / 3 / std::mem::size_of::<K>().max(1)).max(64)
    }

    /// IO buffer size actually used, clamped into `[4 KiB, budget/4]` so
    /// buffers can never dwarf a small memory budget.
    pub fn effective_io_buffer(&self) -> usize {
        self.io_buffer.clamp(4096, (self.memory_budget / 4).max(4096))
    }

    /// Merge fan-in, clamped so `k` reader buffers fit the budget.
    pub fn effective_fanout(&self) -> usize {
        let by_budget = (self.memory_budget / self.effective_io_buffer()).max(2);
        self.merge_fanout.clamp(2, by_budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_keys_scale_with_budget() {
        let cfg = ExternalConfig::with_budget(1 << 20);
        assert_eq!(cfg.chunk_keys::<u64>(), (1 << 20) / 8);
        assert_eq!(cfg.chunk_keys::<f64>(), (1 << 20) / 8);
        // tiny budgets still make progress
        assert!(ExternalConfig::with_budget(1).chunk_keys::<u64>() >= 64);
    }

    #[test]
    fn pipelined_chunks_are_a_third() {
        let cfg = ExternalConfig::with_budget(3 << 20);
        assert_eq!(cfg.pipelined_chunk_keys::<u64>(), (1 << 20) / 8);
        assert!(ExternalConfig::with_budget(1).pipelined_chunk_keys::<u64>() >= 64);
    }

    #[test]
    fn io_buffer_clamps_to_budget() {
        let mut cfg = ExternalConfig::with_budget(64 << 10);
        // default 1 MiB buffer would be 16x a 64 KiB budget
        assert_eq!(cfg.effective_io_buffer(), 16 << 10);
        cfg.memory_budget = 1; // degenerate budget still gets a sane floor
        assert_eq!(cfg.effective_io_buffer(), 4096);
        cfg.memory_budget = 1 << 30;
        assert_eq!(cfg.effective_io_buffer(), cfg.io_buffer);
    }

    #[test]
    fn retrain_policy_enablement() {
        assert!(RetrainPolicy::default().enabled());
        assert!(!RetrainPolicy::disabled().enabled());
        // either knob at zero disables the policy
        assert!(!RetrainPolicy { retrain_after: 0, max_retrains: 4 }.enabled());
        assert!(!RetrainPolicy { retrain_after: 2, max_retrains: 0 }.enabled());
        assert!(RetrainPolicy { retrain_after: 1, max_retrains: 1 }.enabled());
    }

    #[test]
    fn codec_and_decay_defaults() {
        let cfg = ExternalConfig::default();
        // default honours SPILL_CODEC when set; otherwise raw (the tests
        // run under both via CI, so assert consistency with the env)
        let expect = SpillCodec::from_env().unwrap_or(SpillCodec::Raw);
        assert_eq!(cfg.spill_codec, expect);
        assert_eq!(cfg.epoch_age_decay, 1.0, "no age decay by default");
    }

    #[test]
    fn io_substrate_defaults_follow_the_env() {
        let cfg = ExternalConfig::default();
        // like SPILL_CODEC, the IO knobs honour their env variables when
        // set (CI re-runs the suite under pool + striped dirs this way)
        let backend = IoBackendKind::from_env().unwrap_or(IoBackendKind::Sync);
        assert_eq!(cfg.io_backend, backend);
        assert_eq!(cfg.spill_dirs, spill_dirs_from_env());
        assert!(!cfg.direct_io, "direct IO is strictly opt-in");
    }

    #[test]
    fn fanout_clamps_to_budget() {
        let mut cfg = ExternalConfig::with_budget(1 << 20);
        cfg.io_buffer = 1 << 19;
        // buffer clamps to budget/4 = 256 KiB → 4 of them fit
        assert_eq!(cfg.effective_fanout(), 4);
        cfg.io_buffer = 1 << 12;
        assert_eq!(cfg.effective_fanout(), 16); // configured fanout holds
        cfg.merge_fanout = 1;
        assert_eq!(cfg.effective_fanout(), 2); // never below 2
    }
}
