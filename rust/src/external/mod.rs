//! Out-of-core learned sorting (substrate S13) — sorts datasets larger
//! than memory under an explicit byte budget.
//!
//! Pipeline (the classic two-phase external sort, with a learned twist):
//!
//! 1. **Run generation** ([`run_writer`]): the input is consumed in
//!    budget-sized chunks; one monotonic RMI is trained on a sample of the
//!    *first* chunk and **reused** to partition every subsequent chunk
//!    (PCF-style model reuse). A per-chunk drift probe
//!    ([`crate::rmi::quality::model_drift`]) demotes chunks whose
//!    distribution no longer matches the model to the IPS⁴o path. Each
//!    sorted chunk spills as one run ([`spill`]).
//! 2. **K-way merge** ([`loser_tree`]): runs stream-merge through a
//!    tournament loser tree, fan-in clamped so the read buffers respect
//!    the same memory budget; extra passes handle run counts above the
//!    fan-in.
//!
//! Entry points: [`sort_file`] (binary key files, the `aipso gen --out` /
//! `aipso extsort` format) and [`sort_iter`] (any in-process key stream).
//! The coordinator admits these as `JobPayload::External` jobs so one
//! out-of-core sort never thrashes the in-memory service path.

pub mod config;
pub mod loser_tree;
pub mod run_writer;
pub mod spill;

pub use config::{ExternalConfig, RunGen};
pub use loser_tree::{KeyStream, LoserTree, VecStream};
pub use run_writer::RunGenStats;
pub use spill::{
    file_key_count, read_keys_file, verify_sorted_file, write_keys_file, ExtKey, RunFile,
    RunReader, RunWriter, SpillDir,
};

use std::io;
use std::path::Path;

/// Outcome of one external sort.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExternalSortReport {
    /// Total keys sorted.
    pub keys: u64,
    /// Sorted runs spilled during run generation.
    pub runs: usize,
    /// Runs sorted via the reused RMI partition.
    pub learned_runs: usize,
    /// Runs sorted via the IPS⁴o fallback.
    pub fallback_runs: usize,
    /// Whether the shared RMI was trained (at most once per sort).
    pub rmi_trained: bool,
    /// K-way merge passes performed (0 when the input fit in one run).
    pub merge_passes: usize,
}

/// Sort a binary key file (8-byte little-endian keys, the format written
/// by `aipso gen --out`) into `output`, holding at most roughly
/// `cfg.memory_budget` bytes of keys in memory.
pub fn sort_file<K: ExtKey>(
    input: &Path,
    output: &Path,
    cfg: &ExternalConfig,
) -> io::Result<ExternalSortReport> {
    let mut reader = RunReader::<K>::open(input, cfg.effective_io_buffer())?;
    let mut src = move |max: usize| -> io::Result<Option<Vec<K>>> {
        let chunk = reader.read_chunk(max)?;
        Ok(if chunk.is_empty() { None } else { Some(chunk) })
    };
    sort_from(&mut src, output, cfg)
}

/// Sort an arbitrary key stream into `output` under the memory budget.
pub fn sort_iter<K: ExtKey, I>(
    keys: I,
    output: &Path,
    cfg: &ExternalConfig,
) -> io::Result<ExternalSortReport>
where
    I: IntoIterator<Item = K>,
{
    let mut it = keys.into_iter();
    let mut src = move |max: usize| -> io::Result<Option<Vec<K>>> {
        let chunk: Vec<K> = it.by_ref().take(max).collect();
        Ok(if chunk.is_empty() { None } else { Some(chunk) })
    };
    sort_from(&mut src, output, cfg)
}

/// Shared driver: generate runs, then merge them into `output`.
fn sort_from<K: ExtKey>(
    next_chunk: &mut dyn FnMut(usize) -> io::Result<Option<Vec<K>>>,
    output: &Path,
    cfg: &ExternalConfig,
) -> io::Result<ExternalSortReport> {
    let mut spill = SpillDir::create(cfg.tmp_dir.as_deref())?;
    let (mut runs, stats) = run_writer::generate_runs(next_chunk, &mut spill, cfg)?;

    let mut report = ExternalSortReport {
        keys: stats.keys,
        runs: runs.len(),
        learned_runs: stats.learned_chunks,
        fallback_runs: stats.fallback_chunks,
        rmi_trained: stats.rmi_trained,
        merge_passes: 0,
    };

    if runs.is_empty() {
        // empty input — still produce (truncate to) an empty output file
        std::fs::File::create(output)?;
        return Ok(report);
    }

    // Intermediate passes while the run count exceeds the fan-in.
    let fanout = cfg.effective_fanout();
    while runs.len() > fanout {
        let mut next_round = Vec::with_capacity((runs.len() + fanout - 1) / fanout);
        for group in runs.chunks(fanout) {
            if group.len() == 1 {
                // a trailing singleton carries forward untouched — no point
                // rewriting a whole run through a 1-way merge
                next_round.push(group[0].clone());
                continue;
            }
            let merged = merge_group::<K>(group, spill.next_run_path(), cfg)?;
            for r in group {
                let _ = std::fs::remove_file(&r.path);
            }
            next_round.push(merged);
        }
        runs = next_round;
        report.merge_passes += 1;
    }

    // Final pass streams straight into the output file.
    if runs.len() == 1 {
        // single run: plain buffered copy, no tree needed
        std::fs::copy(&runs[0].path, output)?;
    } else {
        let merged = merge_group::<K>(&runs, output.to_path_buf(), cfg)?;
        debug_assert_eq!(merged.n, report.keys);
        report.merge_passes += 1;
    }
    Ok(report)
}

/// Merge one group of runs into `out_path` through the loser tree.
fn merge_group<K: ExtKey>(
    runs: &[RunFile],
    out_path: std::path::PathBuf,
    cfg: &ExternalConfig,
) -> io::Result<RunFile> {
    let io_buffer = cfg.effective_io_buffer();
    let mut sources = Vec::with_capacity(runs.len());
    for r in runs {
        sources.push(RunReader::<K>::open(&r.path, io_buffer)?);
    }
    let mut tree = LoserTree::new(sources)?;
    let mut w = RunWriter::<K>::create(out_path, io_buffer)?;
    while let Some(k) = tree.next()? {
        w.push(k)?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("aipso-ext-mod-{}-{name}", std::process::id()))
    }

    #[test]
    fn sort_iter_multi_pass_merge() {
        let out = tmp("multipass.bin");
        let mut rng = Xoshiro256pp::new(9);
        let n = 20_000;
        let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        // 256-key chunks and fan-in 2 force several merge passes
        let cfg = ExternalConfig {
            memory_budget: 256 * 8,
            io_buffer: 1024, // budget/io_buffer = 2 → fan-in 2
            threads: 1,
            ..ExternalConfig::default()
        };
        let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
        assert_eq!(report.keys, n as u64);
        assert!(report.runs > 16, "runs={}", report.runs);
        assert!(report.merge_passes >= 2, "passes={}", report.merge_passes);
        let mut want = keys;
        want.sort_unstable();
        assert_eq!(read_keys_file::<u64>(&out).unwrap(), want);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn empty_input_writes_empty_output() {
        let out = tmp("empty.bin");
        let report =
            sort_iter::<u64, _>(std::iter::empty(), &out, &ExternalConfig::default()).unwrap();
        assert_eq!(report.keys, 0);
        assert_eq!(report.runs, 0);
        assert_eq!(std::fs::metadata(&out).unwrap().len(), 0);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn single_run_copies_through() {
        let out = tmp("single.bin");
        let keys: Vec<u64> = vec![5, 3, 9, 1];
        let report = sort_iter(keys, &out, &ExternalConfig::default()).unwrap();
        assert_eq!(report.runs, 1);
        assert_eq!(report.merge_passes, 0);
        assert_eq!(read_keys_file::<u64>(&out).unwrap(), vec![1, 3, 5, 9]);
        let _ = std::fs::remove_file(&out);
    }
}
