//! Out-of-core learned sorting (substrate S13) — sorts datasets larger
//! than memory under an explicit byte budget, in parallel.
//!
//! Pipeline (the classic two-phase external sort, with a learned twist):
//!
//! 1. **Run generation** ([`run_writer`]): the input is consumed in
//!    budget-sized chunks; one monotonic RMI is trained on a sample of the
//!    *first* chunk and **reused** to partition every subsequent chunk
//!    (PCF-style model reuse). A per-chunk drift probe
//!    ([`crate::rmi::quality::model_drift`]) demotes chunks whose
//!    distribution no longer matches the model to the IPS⁴o path — and
//!    once the probe fails for [`RetrainPolicy::retrain_after`]
//!    consecutive chunks (a regime change), a **fresh RMI is retrained**
//!    from the offending chunk and installed for the rest of the stream
//!    (bounded by `max_retrains`), each install opening a new model
//!    *epoch* ([`EpochStats`]). Each sorted chunk spills as one run
//!    ([`spill`]). With `threads > 1` the read / sort / spill stages run
//!    as an overlapped pipeline: a reader thread prefetches chunk `N+1`
//!    while the pool sorts chunk `N`, and chunk `N−1` spills on a writer
//!    thread (sync backend) or through the IO pool's submission queue
//!    (pool backend).
//! 2. **Merge**: intermediate k-way passes ([`loser_tree`], fan-in clamped
//!    to the budget) run their independent merge groups concurrently on
//!    the scheduler pool; the final pass inverts the keys-weighted mixture
//!    of the epoch models — plus an **empirical CDF component** standing
//!    in for the fallback chunks' keys (reservoir-sampled during run
//!    generation, weighted by their true count) — into `p` quantile cuts
//!    and merges `p` range-disjoint shards in parallel ([`shard`]),
//!    falling back to the serial loser tree when neither a model nor a
//!    fallback sample exists or the cuts come out skewed (drift guard).
//!
//! All spill reads and writes go through the pluggable [`io`] substrate:
//! the **sync** backend issues positioned IO inline (the reference), the
//! **pool** backend drains a submission queue on a fixed worker pool so
//! disk time overlaps compute, optional `O_DIRECT` keeps budget-accounted
//! spill data out of the page cache (with automatic buffered fallback),
//! and [`ExternalConfig::spill_dirs`] stripes runs round-robin across
//! several directories/disks. Every combination produces byte-identical
//! outputs — the substrate is pure transport.
//!
//! The whole pipeline is threaded with [`crate::obs`] spans (`extsort` →
//! `chunk-read`/`chunk-sort`/`spill-write`/`retrain` → `merge-pass` →
//! `shard-merge`, plus `spill-io` under the pool backend) and metrics
//! (spill bytes, drift error, shard skew, merge fan-in, io queue depth);
//! `aipso extsort --trace-json` dumps the resulting `JobTelemetry`
//! document. All of it is disabled (one relaxed atomic load per site)
//! unless [`crate::obs::set_enabled`] turned it on.
//!
//! Entry points: [`sort_file`] (binary key files, the `aipso gen --out` /
//! `aipso extsort` format) and [`sort_iter`] (any in-process key stream).
//! Both are generic over **all four** [`crate::key::SortKey`] domains —
//! `u64`/`f64` at 8 bytes per key and `u32`/`f32` at 4 — through one
//! width-generic codec; files carry a small self-describing header
//! (magic, version, key-type tag, width, count; see [`spill`]) that
//! [`sort_file`] validates up front, with legacy headerless 8-byte files
//! still accepted as format v0. Spilled runs optionally compress through
//! the delta+varint block codec ([`SpillCodec::Delta`], format v2):
//! sorted runs delta-encode in non-negative varints with duplicate
//! run-length escapes, cutting the IO the merge is bound by — while the
//! sorted *output* stays raw v1, so both codecs produce byte-identical
//! results ([`ExternalSortReport::spill_bytes`] reports the savings).
//! The coordinator admits these as `JobPayload::External` jobs; see
//! [`crate::coordinator`] for how they overlap with in-memory traffic.
//!
//! The architecture, data flow and fallback decision points are documented
//! end to end in `ARCHITECTURE.md` at the repository root.
//!
//! ```
//! use aipso::external::{self, ExternalConfig};
//!
//! let out = std::env::temp_dir().join(format!("aipso-doc-ext-{}.bin", std::process::id()));
//! let cfg = ExternalConfig {
//!     memory_budget: 1 << 16, // 64 KiB working set => several runs
//!     threads: 2,             // overlapped IO + sharded merge
//!     ..ExternalConfig::default()
//! };
//! let keys = (0..20_000u64).rev();
//! let report = external::sort_iter(keys, &out, &cfg).unwrap();
//! assert_eq!(report.keys, 20_000);
//! assert!(report.runs > 1);
//! assert!(external::verify_sorted_file::<u64>(&out, 1 << 16).unwrap());
//! std::fs::remove_file(&out).unwrap();
//! ```

pub mod config;
pub mod io;
pub mod loser_tree;
pub mod run_writer;
pub mod shard;
pub mod spill;

pub use config::{ExternalConfig, RetrainPolicy, RunGen};
pub use io::{IoBackendKind, IoCtx};
pub use loser_tree::{KeyStream, LoserTree, VecStream};
pub use run_writer::{EpochStats, RunGenStats};
pub use shard::ShardPlan;
pub use spill::{
    file_key_count, read_header, read_keys_file, verify_sorted_file, write_keys_file,
    write_keys_file_codec, RunFile, RunIndex, RunReader, RunWriter, SpillCodec, SpillDir,
    SpillHeader, SpillVersion, DELTA_VERSION, FORMAT_VERSION, HEADER_LEN, MAGIC, RAW_VERSION,
    ZIGZAG_VERSION,
};

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::key::{KeyKind, SortKey};
use crate::obs;
use crate::rmi::model::Rmi;
use crate::scheduler::run_task_pool;
use crate::util::json::Json;

/// Outcome of one external sort.
#[derive(Debug, Clone, Default)]
pub struct ExternalSortReport {
    /// Total keys sorted.
    pub keys: u64,
    /// Sorted runs spilled during run generation.
    pub runs: usize,
    /// Runs sorted via the reused RMI partition.
    pub learned_runs: usize,
    /// Runs sorted via the IPS⁴o fallback.
    pub fallback_runs: usize,
    /// Whether the initial shared RMI was trained on the first chunk.
    pub rmi_trained: bool,
    /// Mid-stream installs under [`RetrainPolicy`]: replacement models
    /// after drift, plus a *first* model recovered from a cold start
    /// (0 = the initial model served the whole stream, or retraining is
    /// disabled).
    pub retrains: usize,
    /// Learned/fallback chunk counts per model epoch — epoch 0 is the
    /// first model, each later install opens the next entry. When the
    /// first chunk trained (`rmi_trained`), `epochs.len() == retrains +
    /// 1`; after a cold start the first mid-stream install *is* epoch 0
    /// (its entry also absorbs the model-less prefix), so the count is
    /// one lower.
    pub epochs: Vec<EpochStats>,
    /// K-way merge passes performed (0 when the input fit in one run).
    pub merge_passes: usize,
    /// Shards of the RMI-partitioned final merge (0 = the final pass ran
    /// the serial loser tree — no model, one thread, or skewed cuts).
    pub merge_shards: usize,
    /// Intermediate-pass merge groups that themselves ran sharded (spare
    /// threads split a group's merge into range-disjoint quantile shards;
    /// 0 = every intermediate group merged through one serial loser tree).
    pub sharded_groups: usize,
    /// Actual bytes of the run-generation spill files on disk (headers
    /// included). With [`SpillCodec::Delta`] this is the compressed size;
    /// with [`SpillCodec::Raw`] it equals `spill_bytes_raw`.
    pub spill_bytes: u64,
    /// Bytes the raw fixed-width codec would have spilled for the same
    /// runs (`runs × header + keys × width`) — the baseline the codec's
    /// savings are measured against.
    pub spill_bytes_raw: u64,
}

impl ExternalSortReport {
    /// The report as a JSON object — the `report` section of the
    /// `JobTelemetry` document ([`crate::obs::job_telemetry`]).
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let epochs: Vec<Json> = self
            .epochs
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                o.insert("learned".to_string(), Json::Num(e.learned as f64));
                o.insert("fallback".to_string(), Json::Num(e.fallback as f64));
                o.insert("keys".to_string(), Json::Num(e.keys as f64));
                o.insert("learned_keys".to_string(), Json::Num(e.learned_keys as f64));
                Json::Obj(o)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("keys".to_string(), Json::Num(self.keys as f64));
        m.insert("runs".to_string(), Json::Num(self.runs as f64));
        m.insert("learned_runs".to_string(), Json::Num(self.learned_runs as f64));
        m.insert("fallback_runs".to_string(), Json::Num(self.fallback_runs as f64));
        m.insert("rmi_trained".to_string(), Json::Bool(self.rmi_trained));
        m.insert("retrains".to_string(), Json::Num(self.retrains as f64));
        m.insert("epochs".to_string(), Json::Arr(epochs));
        m.insert("merge_passes".to_string(), Json::Num(self.merge_passes as f64));
        m.insert("merge_shards".to_string(), Json::Num(self.merge_shards as f64));
        m.insert("sharded_groups".to_string(), Json::Num(self.sharded_groups as f64));
        m.insert("spill_bytes".to_string(), Json::Num(self.spill_bytes as f64));
        m.insert("spill_bytes_raw".to_string(), Json::Num(self.spill_bytes_raw as f64));
        Json::Obj(m)
    }
}

/// Sort a binary key file (the self-describing `aipso gen --out` format,
/// or a legacy headerless 8-byte file) into `output`, holding at most
/// roughly `cfg.memory_budget` bytes of keys in memory. The input header
/// is validated against `K` — sorting a `u32` file as `f32` (or any other
/// mismatch) fails up front instead of decoding garbage.
pub fn sort_file<K: SortKey>(
    input: &Path,
    output: &Path,
    cfg: &ExternalConfig,
) -> std::io::Result<ExternalSortReport> {
    let mut reader = RunReader::<K>::open(input, cfg.effective_io_buffer())?;
    let src = move |max: usize| -> std::io::Result<Option<Vec<K>>> {
        let chunk = reader.read_chunk(max)?;
        Ok(if chunk.is_empty() { None } else { Some(chunk) })
    };
    sort_from(src, output, cfg)
}

/// [`sort_file`] dispatched by a runtime `(KeyKind, payload-width)` pair
/// via [`crate::dispatch_key_type!`], followed by a stream-verification
/// of the output — the one kind→generic dispatch point shared by the
/// CLI, the coordinator and the bench harness (a future key domain or
/// payload width only needs an arm in the macro). `payload` is the
/// record's value width in bytes; `0` sorts bare keys exactly as before.
/// Returns the pipeline report, the wall-clock seconds of the sort
/// itself (verification excluded), and whether the output verified
/// sorted under the key's full order.
pub fn sort_and_verify(
    kind: KeyKind,
    payload: usize,
    input: &Path,
    output: &Path,
    cfg: &ExternalConfig,
) -> std::io::Result<(ExternalSortReport, f64, bool)> {
    fn go<K: SortKey>(
        input: &Path,
        output: &Path,
        cfg: &ExternalConfig,
    ) -> std::io::Result<(ExternalSortReport, f64, bool)> {
        let t0 = std::time::Instant::now();
        let report = sort_file::<K>(input, output, cfg)?;
        let secs = t0.elapsed().as_secs_f64();
        let ok = verify_sorted_file::<K>(output, cfg.effective_io_buffer())?;
        Ok((report, secs, ok))
    }
    crate::dispatch_key_type!(kind, payload, K => go::<K>(input, output, cfg), _ => {
        Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("unsupported record payload width {payload} (supported: {:?})",
                crate::key::DISPATCH_PAYLOADS),
        ))
    })
}

/// Sort an arbitrary key stream into `output` under the memory budget.
/// (`Send` because the overlapped pipeline pulls the stream from a reader
/// thread when `cfg.threads != 1`.)
pub fn sort_iter<K: SortKey, I>(
    keys: I,
    output: &Path,
    cfg: &ExternalConfig,
) -> std::io::Result<ExternalSortReport>
where
    I: IntoIterator<Item = K>,
    I::IntoIter: Send,
{
    let mut it = keys.into_iter();
    let src = move |max: usize| -> std::io::Result<Option<Vec<K>>> {
        let chunk: Vec<K> = it.by_ref().take(max).collect();
        Ok(if chunk.is_empty() { None } else { Some(chunk) })
    };
    sort_from(src, output, cfg)
}

/// Removes a partially written output when armed: spilled runs are covered
/// by `SpillDir`'s drop, but the output lives at the caller's path and must
/// not leak half-written when the merge fails. Armed only once this sort
/// first touches the output — a failure before that (bad tmp dir, source
/// IO error during run generation) must not delete a pre-existing file the
/// caller still owns.
struct OutputGuard<'a> {
    path: &'a Path,
    armed: bool,
}

impl Drop for OutputGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            let _ = std::fs::remove_file(self.path);
        }
    }
}

/// Shared driver: generate runs, then merge them into `output`.
fn sort_from<K, F>(
    next_chunk: F,
    output: &Path,
    cfg: &ExternalConfig,
) -> std::io::Result<ExternalSortReport>
where
    K: SortKey,
    F: FnMut(usize) -> std::io::Result<Option<Vec<K>>> + Send,
{
    let mut guard = OutputGuard {
        path: output,
        armed: false,
    };
    let io = IoCtx::new(cfg.io_backend, cfg.direct_io);
    let mut spill = SpillDir::create_striped(&cfg.spill_dirs)?;
    let mut job_span = obs::trace::span(obs::S_EXTSORT);
    let gen = run_writer::generate_runs(next_chunk, &mut spill, cfg, &io)?;
    let (mut runs, stats, models, fallback_sample) =
        (gen.runs, gen.stats, gen.models, gen.fallback_sample);

    // Cut weight per epoch model = the keys its model *actually sorted*
    // (`EpochStats::learned_keys`), resolved before intermediate merge
    // passes collapse runs across epochs, optionally age-decayed
    // (`cfg.epoch_age_decay`). The sharded final merge inverts this
    // weighted mixture — the stream's estimated global CDF — so its
    // quantile cuts stay balanced across retrain-on-drift regime changes.
    // Fallback chunks' keys are excluded on purpose: their epoch's model
    // demonstrably drifted from them (or Algorithm 5's guard refused to
    // model them at all), so counting them — as earlier revisions did —
    // inflated a stale model's share of the cuts whenever a vetoed tail
    // (e.g. zipf) rode an epoch out. Balance-only either way: the skew
    // guard below still backstops the cuts.
    debug_assert_eq!(gen.run_epochs.len(), runs.len());
    let weights = epoch_cut_weights(&stats.epochs, cfg.epoch_age_decay);
    let cut_models: Vec<(&Rmi, f64)> = models
        .iter()
        .zip(&weights)
        .filter(|(_, &w)| w > 0.0)
        .map(|(m, &w)| (m, w))
        .collect();

    // The excluded fallback mass re-enters the mixture as an *empirical*
    // CDF component: run generation reservoir-sampled the fallback
    // chunks' keys (sorted ordered bits), and those keys weigh in at
    // their true count. A fallback-heavy stream's cuts thus track where
    // the un-modelled keys actually live instead of only the learned
    // regimes — and an all-fallback stream (no usable model at all) can
    // still merge sharded off the sample alone.
    let learned_keys: u64 = stats.epochs.iter().map(|e| e.learned_keys).sum();
    let fallback_keys = stats.keys.saturating_sub(learned_keys);
    let empirical: Option<(&[u64], f64)> = if fallback_sample.is_empty() || fallback_keys == 0 {
        None
    } else {
        Some((&fallback_sample, fallback_keys as f64))
    };

    let mut report = ExternalSortReport {
        keys: stats.keys,
        runs: runs.len(),
        learned_runs: stats.learned_chunks,
        fallback_runs: stats.fallback_chunks,
        rmi_trained: stats.rmi_trained,
        retrains: stats.retrains,
        epochs: stats.epochs.clone(),
        merge_passes: 0,
        merge_shards: 0,
        sharded_groups: 0,
        spill_bytes: runs.iter().map(|r| r.bytes).sum(),
        spill_bytes_raw: raw_spill_bytes::<K>(&runs),
    };
    let threads = crate::scheduler::effective_threads(cfg.threads);

    if runs.is_empty() {
        // empty input — still produce (truncate to) an empty, validly
        // headered output file
        guard.armed = true;
        write_keys_file::<K>(output, &[])?;
        guard.armed = false;
        return Ok(report);
    }

    // Intermediate passes while the run count exceeds the fan-in; the
    // merge groups of one pass are independent, so they run concurrently
    // on the pool (each group's readers get a slice of the io budget),
    // and spare threads shard *within* groups along the same mixture cuts
    // the final pass uses.
    let fanout = cfg.effective_fanout();
    while runs.len() > fanout {
        let (merged, sharded_groups) =
            merge_pass::<K>(runs, &mut spill, cfg, threads, &cut_models, empirical, &io)?;
        runs = merged;
        report.merge_passes += 1;
        report.sharded_groups += sharded_groups;
    }

    // Final pass streams straight into the output file. The output is
    // always raw v1 — the interchange format — whatever codec the runs
    // spilled through, so raw and delta sorts are byte-identical.
    if runs.len() == 1 {
        guard.armed = true;
        let pad = read_header(&runs[0].path)?.map_or(0, |h| h.pad);
        if cfg.spill_codec == SpillCodec::Raw && pad == 0 {
            // single raw run: plain buffered copy, no tree needed
            std::fs::copy(&runs[0].path, output)?;
        } else {
            // single delta run — or a raw run whose direct-IO writer
            // padded the final block: stream-rewrite it as plain raw
            spill::transcode_raw::<K>(&runs[0].path, output, cfg.effective_io_buffer())?;
        }
    } else {
        let _pass_span = obs::trace::span_n(
            obs::S_MERGE_PASS,
            report.keys,
            report.keys * K::WIDTH as u64,
        );
        obs::metrics::counter_add(obs::C_MERGE_PASSES, 1);
        obs::metrics::observe(
            obs::M_MERGE_FANIN,
            obs::metrics::FANIN_BUCKETS,
            runs.len() as f64,
        );
        let shards = final_shards(cfg, threads, report.keys);
        let mut sharded = false;
        if (!cut_models.is_empty() || empirical.is_some()) && shards >= 2 {
            // planning only reads the runs; the output stays untouched
            // (and thus unguarded) until a merge actually starts below
            let plan = shard::plan_shards::<K>(&cut_models, empirical, &runs, shards)?;
            debug_assert_eq!(plan.total_keys(), report.keys);
            if plan.skew() <= cfg.shard_skew_limit {
                guard.armed = true;
                shard::merge_sharded::<K>(&runs, &plan, output, cfg, threads, &io)?;
                report.merge_shards = shards;
                sharded = true;
            }
            // else: the quantile cuts no longer describe the data (drift);
            // fall through to the serial tree rather than merge lopsided
        }
        if !sharded {
            guard.armed = true;
            let merged = merge_group::<K>(
                &runs,
                output.to_path_buf(),
                cfg.effective_io_buffer(),
                SpillCodec::Raw, // the output contract, independent of the spill codec
                &io,
            )?;
            debug_assert_eq!(merged.n, report.keys);
        }
        report.merge_passes += 1;
    }
    guard.armed = false;
    job_span.set_keys(report.keys);
    job_span.set_bytes(report.keys * K::WIDTH as u64);
    Ok(report)
}

/// Bytes the raw fixed-width codec spills for `runs` (header + `n ×
/// WIDTH` each) — the baseline `ExternalSortReport.spill_bytes_raw`
/// measures the configured codec against.
fn raw_spill_bytes<K: SortKey>(runs: &[RunFile]) -> u64 {
    runs.iter()
        .map(|r| HEADER_LEN as u64 + r.n * K::WIDTH as u64)
        .sum()
}

/// Cut weight per epoch model for the sharded merge's mixture quantiles:
/// the keys the epoch's model actually sorted (`learned_keys` — fallback
/// chunks drifted from it and must not inflate its share), scaled by an
/// exponential age decay so `decay < 1` tilts a long stream's cuts toward
/// its most recent regimes. `decay` outside `(0, 1]` means no decay.
fn epoch_cut_weights(epochs: &[EpochStats], decay: f64) -> Vec<f64> {
    let decay = if decay.is_finite() && decay > 0.0 && decay < 1.0 {
        decay
    } else {
        1.0
    };
    let last = epochs.len().saturating_sub(1);
    epochs
        .iter()
        .enumerate()
        .map(|(e, s)| s.learned_keys as f64 * decay.powi((last - e) as i32))
        .collect()
}

/// Shards for the final merge: the configured count (or one per thread),
/// capped so every shard still clears `min_shard_keys`.
fn final_shards(cfg: &ExternalConfig, threads: usize, total_keys: u64) -> usize {
    let want = if cfg.merge_shards > 0 {
        cfg.merge_shards
    } else {
        threads
    };
    let cap = (total_keys / cfg.min_shard_keys.max(1) as u64).min(256) as usize;
    want.min(cap.max(1))
}

/// An intermediate-pass merge group whose output is produced by parallel
/// quantile shards instead of one serial loser tree.
struct ShardedGroup {
    /// Index of the group's slot in the next round.
    slot: usize,
    /// The group's source runs.
    runs: Vec<RunFile>,
    /// Quantile cuts + per-run offsets (skew-guarded before admission).
    plan: ShardPlan,
    /// The group's pre-sized output run.
    out: PathBuf,
    /// Total keys across the group.
    total: u64,
}

/// One intermediate merge pass: groups of up to `fanout` runs merge
/// concurrently into fresh spill files; trailing singletons carry forward
/// untouched (no point rewriting a whole run through a 1-way merge).
///
/// When the pass has fewer multi-run groups than worker threads, the
/// spare threads **shard within groups**: each group's merge splits into
/// range-disjoint quantile shards along the same epoch-mixture (plus
/// empirical fallback component) cuts the
/// final pass uses ([`shard::plan_shards`]), with the same skew guard
/// demoting a group back to the serial loser tree when the cuts no longer
/// describe its data. All group- and shard-tasks of the pass run in one
/// flat pool, so shards of different groups interleave freely. Returns
/// the next round's runs plus how many groups merged sharded.
#[allow(clippy::too_many_arguments)]
fn merge_pass<K: SortKey>(
    runs: Vec<RunFile>,
    spill_dir: &mut SpillDir,
    cfg: &ExternalConfig,
    threads: usize,
    cut_models: &[(&Rmi, f64)],
    empirical: Option<(&[u64], f64)>,
    io: &IoCtx,
) -> std::io::Result<(Vec<RunFile>, usize)> {
    let _span = obs::trace::span_n(
        obs::S_MERGE_PASS,
        runs.iter().map(|r| r.n).sum(),
        runs.iter().map(|r| r.bytes).sum(),
    );
    obs::metrics::counter_add(obs::C_MERGE_PASSES, 1);
    let fanout = cfg.effective_fanout();
    let n_groups = runs.len().div_ceil(fanout);
    let mut next_round: Vec<Option<RunFile>> = vec![None; n_groups];

    let multi = runs.chunks(fanout).filter(|g| g.len() > 1).count();
    // Threads beyond one-per-group are spent sharding *inside* groups.
    let per_group = if multi == 0 { 1 } else { (threads / multi).max(1) };
    let mut serial: Vec<(usize, Vec<RunFile>, PathBuf)> = Vec::new();
    let mut sharded: Vec<ShardedGroup> = Vec::new();
    for (slot, group) in runs.chunks(fanout).enumerate() {
        if group.len() == 1 {
            next_round[slot] = Some(group[0].clone());
            continue;
        }
        let total: u64 = group.iter().map(|r| r.n).sum();
        obs::metrics::observe(
            obs::M_MERGE_FANIN,
            obs::metrics::FANIN_BUCKETS,
            group.len() as f64,
        );
        let cap = (total / cfg.min_shard_keys.max(1) as u64).min(256) as usize;
        let p = per_group.min(cap.max(1));
        let out = spill_dir.next_run_path();
        let mut plan = None;
        if p >= 2 && (!cut_models.is_empty() || empirical.is_some()) {
            let candidate = shard::plan_shards::<K>(cut_models, empirical, group, p)?;
            if candidate.skew() <= cfg.shard_skew_limit {
                plan = Some(candidate);
            }
            // else: stale cuts would serialize behind one lopsided shard;
            // the serial tree is the better merge for this group
        }
        match plan {
            Some(plan) => {
                spill::create_presized::<K>(&out, total)?;
                sharded.push(ShardedGroup {
                    slot,
                    runs: group.to_vec(),
                    plan,
                    out,
                    total,
                });
            }
            None => serial.push((slot, group.to_vec(), out)),
        }
    }

    /// A unit of work in the pass's flat pool.
    enum Task {
        /// Merge serial group `i` through one loser tree.
        Serial(usize),
        /// Merge shard `s` of sharded group `g`.
        Shard(usize, usize),
    }
    let mut tasks: Vec<Task> = (0..serial.len()).map(Task::Serial).collect();
    for (g, grp) in sharded.iter().enumerate() {
        for s in 0..grp.plan.shards() {
            if grp.plan.shard_keys()[s] > 0 {
                tasks.push(Task::Shard(g, s));
            }
        }
    }
    let workers = threads.min(tasks.len()).max(1);
    // each in-flight task holds up to `fanout` reader buffers + 1 writer;
    // split the io budget across the tasks that can run at once
    let io_buffer = (cfg.effective_io_buffer() / workers).max(4096);
    let shard_offsets: Vec<Vec<u64>> = sharded.iter().map(|g| g.plan.out_key_offsets()).collect();
    let serial_results: Mutex<Vec<(usize, std::io::Result<RunFile>)>> = Mutex::new(Vec::new());
    let first_err: Mutex<Option<std::io::Error>> = Mutex::new(None);
    // Once any task fails the whole pass's result is discarded, so every
    // queued task — serial or shard — drains cheaply instead of grinding
    // a failing disk through more whole-group merges.
    let failed = std::sync::atomic::AtomicBool::new(false);
    use std::sync::atomic::Ordering::Relaxed;
    run_task_pool(workers, tasks, |task, _spawner| match task {
        Task::Serial(i) => {
            if failed.load(Relaxed) {
                return;
            }
            let (slot, group, out) = &serial[i];
            let res = merge_group::<K>(group, out.clone(), io_buffer, cfg.spill_codec, io);
            match &res {
                Ok(_) => {
                    for r in group {
                        let _ = std::fs::remove_file(&r.path);
                        let _ = std::fs::remove_file(spill::sidecar_path(&r.path));
                    }
                }
                Err(_) => failed.store(true, Relaxed),
            }
            serial_results.lock().unwrap().push((*slot, res));
        }
        Task::Shard(g, s) => {
            if failed.load(Relaxed) {
                return;
            }
            let grp = &sharded[g];
            if let Err(e) = shard::merge_one_shard::<K>(
                &grp.runs,
                &grp.plan,
                s,
                shard_offsets[g][s],
                &grp.out,
                io_buffer,
                io,
            ) {
                failed.store(true, Relaxed);
                let mut slot = first_err.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(e);
                }
            }
        }
    });
    for (slot, res) in serial_results.into_inner().unwrap() {
        next_round[slot] = Some(res?);
    }
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    let n_sharded = sharded.len();
    for grp in sharded {
        for r in &grp.runs {
            let _ = std::fs::remove_file(&r.path);
            let _ = std::fs::remove_file(spill::sidecar_path(&r.path));
        }
        next_round[grp.slot] = Some(RunFile {
            path: grp.out,
            n: grp.total,
            // sharded group outputs are pre-sized raw files (seek-written
            // disjoint ranges are incompatible with variable-length blocks)
            bytes: HEADER_LEN as u64 + grp.total * K::WIDTH as u64,
        });
    }
    Ok((
        next_round.into_iter().map(Option::unwrap).collect(),
        n_sharded,
    ))
}

/// Merge one group of runs into `out_path` through the loser tree,
/// writing with `codec` (the spill codec for intermediate runs, raw for
/// the final output). The sources dispatch their own codec per file, so
/// raw and delta runs merge together freely. Reads and writes route
/// through the configured IO backend; intermediate delta outputs also
/// get a block-bounds side-car so a later sharded pass can skip blocks.
/// The output is never `O_DIRECT` — final outputs are the interchange
/// contract and intermediate runs are read straight back.
fn merge_group<K: SortKey>(
    runs: &[RunFile],
    out_path: PathBuf,
    io_buffer: usize,
    codec: SpillCodec,
    io: &IoCtx,
) -> std::io::Result<RunFile> {
    let specs: Vec<loser_tree::MergeSource<'_>> = runs
        .iter()
        .map(|r| loser_tree::MergeSource {
            path: &r.path,
            start: 0,
            len: r.n,
            dir: None,
            header: None,
        })
        .collect();
    let mut tree = LoserTree::new(loser_tree::open_merge_sources::<K>(&specs, io_buffer, io)?)?;
    let mut w = RunWriter::<K>::create_io(out_path, io_buffer, codec, io, true, false)?;
    while let Some(k) = tree.next()? {
        w.push(k)?;
    }
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("aipso-ext-mod-{}-{name}", std::process::id()))
    }

    #[test]
    fn sort_iter_multi_pass_merge() {
        let out = tmp("multipass.bin");
        let mut rng = Xoshiro256pp::new(9);
        let n = 20_000;
        let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        // 256-key chunks and fan-in 2 force several merge passes
        let cfg = ExternalConfig {
            memory_budget: 256 * 8,
            io_buffer: 1024, // budget/io_buffer = 2 → fan-in 2
            threads: 1,
            ..ExternalConfig::default()
        };
        let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
        assert_eq!(report.keys, n as u64);
        assert!(report.runs > 16, "runs={}", report.runs);
        assert!(report.merge_passes >= 2, "passes={}", report.merge_passes);
        assert_eq!(report.merge_shards, 0, "threads=1 stays serial");
        let mut want = keys;
        want.sort_unstable();
        assert_eq!(read_keys_file::<u64>(&out).unwrap(), want);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn parallel_multi_pass_matches_serial_bytes() {
        let mut rng = Xoshiro256pp::new(10);
        let n = 80_000;
        let keys: Vec<u64> = (0..n).map(|_| rng.next_below(1 << 40)).collect();
        let serial_out = tmp("par-vs-serial-1.bin");
        let parallel_out = tmp("par-vs-serial-4.bin");
        // 3 * 8Ki-key budget: pipelined chunks (a third) still clear
        // min_learned_chunk, so the shared RMI trains on both paths;
        // fan-in 4 forces the parallel side through an intermediate pass
        // (10 runs -> 3) before the sharded final merge
        let mut cfg = ExternalConfig {
            memory_budget: 3 * 8192 * 8,
            io_buffer: 4096,
            merge_fanout: 4,
            threads: 1,
            min_shard_keys: 1024, // let the sharded merge engage at test sizes
            ..ExternalConfig::default()
        };
        let serial = sort_iter(keys.iter().copied(), &serial_out, &cfg).unwrap();
        assert_eq!(serial.merge_shards, 0);
        cfg.threads = 4;
        let parallel = sort_iter(keys.iter().copied(), &parallel_out, &cfg).unwrap();
        assert_eq!(serial.keys, parallel.keys);
        assert_eq!(
            std::fs::read(&serial_out).unwrap(),
            std::fs::read(&parallel_out).unwrap(),
            "parallel pipeline must be byte-identical to the serial one"
        );
        // smooth input + trained model => the final merge really sharded
        assert!(parallel.rmi_trained);
        assert!(parallel.merge_passes >= 2, "passes={}", parallel.merge_passes);
        assert!(
            parallel.merge_shards >= 2,
            "merge_shards={}",
            parallel.merge_shards
        );
        let _ = std::fs::remove_file(&serial_out);
        let _ = std::fs::remove_file(&parallel_out);
    }

    #[test]
    fn intermediate_passes_shard_when_threads_exceed_groups() {
        // 10 runs at fan-in 4 → one intermediate pass of 3 groups; with 8
        // threads each group gets 2 quantile shards. The sharded groups
        // must merge byte-identically to the serial reference.
        let mut rng = Xoshiro256pp::new(21);
        let n = 10 * 8192;
        let keys: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
        let mut cfg = ExternalConfig {
            memory_budget: 3 * 8192 * 8, // pipelined chunks of 8192 keys
            io_buffer: 4096,
            merge_fanout: 4,
            threads: 8,
            min_shard_keys: 1024,
            ..ExternalConfig::default()
        };
        let out = tmp("inter-shard.bin");
        let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
        assert_eq!(report.runs, 10);
        assert!(report.rmi_trained);
        assert!(report.merge_passes >= 2, "passes={}", report.merge_passes);
        assert_eq!(
            report.sharded_groups, 3,
            "all three intermediate groups must shard"
        );
        let serial_out = tmp("inter-shard-serial.bin");
        cfg.threads = 1;
        let serial = sort_iter(keys.iter().copied(), &serial_out, &cfg).unwrap();
        assert_eq!(serial.sharded_groups, 0, "one thread never shards groups");
        assert_eq!(
            std::fs::read(&out).unwrap(),
            std::fs::read(&serial_out).unwrap(),
            "sharded intermediate passes must not change a single byte"
        );
        let _ = std::fs::remove_file(&out);
        let _ = std::fs::remove_file(&serial_out);
    }

    #[test]
    fn explicit_shard_count_is_honoured() {
        let mut rng = Xoshiro256pp::new(12);
        let keys: Vec<f64> = (0..40_000).map(|_| rng.uniform(0.0, 1e6)).collect();
        let out = tmp("explicit-shards.bin");
        let cfg = ExternalConfig {
            memory_budget: 3 * 8192 * 8, // pipelined chunks still train the RMI
            threads: 2,
            merge_shards: 3,
            min_shard_keys: 1024,
            ..ExternalConfig::default()
        };
        let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
        assert_eq!(report.merge_shards, 3);
        assert!(verify_sorted_file::<f64>(&out, 1 << 16).unwrap());
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn merge_shards_one_forces_serial_merge() {
        let mut rng = Xoshiro256pp::new(13);
        let keys: Vec<f64> = (0..30_000).map(|_| rng.uniform(0.0, 1e6)).collect();
        let out = tmp("shards-one.bin");
        let cfg = ExternalConfig {
            memory_budget: 3 * 8192 * 8, // model trains, yet p=1 stays serial
            threads: 4,
            merge_shards: 1,
            min_shard_keys: 1,
            ..ExternalConfig::default()
        };
        let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
        assert_eq!(report.merge_shards, 0, "p=1 is the serial loser tree");
        assert!(verify_sorted_file::<f64>(&out, 1 << 16).unwrap());
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn merge_fanout_extremes_clamp_and_sort() {
        // merge_fanout = 1 clamps to the floor of 2 (a 1-way merge would
        // never reduce the run count); usize::MAX clamps to what the
        // budget's reader buffers allow (k = max). Both must sort exactly.
        let mut rng = Xoshiro256pp::new(15);
        let n = 24_000;
        let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        let mut want = keys.clone();
        want.sort_unstable();
        for fanout in [1usize, usize::MAX] {
            let out = tmp(&format!("fanout-{}.bin", fanout.min(9999)));
            let cfg = ExternalConfig {
                memory_budget: 1024 * 8,
                io_buffer: 4096, // budget/io_buffer = 2 readers at most
                merge_fanout: fanout,
                threads: 1,
                ..ExternalConfig::default()
            };
            assert_eq!(cfg.effective_fanout(), 2, "fanout={fanout}");
            let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
            assert!(report.runs > 16, "runs={}", report.runs);
            assert!(report.merge_passes >= 4, "passes={}", report.merge_passes);
            assert_eq!(read_keys_file::<u64>(&out).unwrap(), want);
            let _ = std::fs::remove_file(&out);
        }
        // a roomier budget lets the huge configured fan-in clamp to the
        // budget's k-max (64 reader buffers) and merge all 10 runs in a
        // single final pass
        let keys: Vec<u64> = (0..320_000).map(|_| rng.next_u64()).collect();
        let mut want = keys.clone();
        want.sort_unstable();
        let out = tmp("fanout-kmax.bin");
        let cfg = ExternalConfig {
            memory_budget: 32_768 * 8,
            io_buffer: 4096,
            merge_fanout: usize::MAX,
            threads: 1,
            ..ExternalConfig::default()
        };
        assert_eq!(cfg.effective_fanout(), 64, "k-max = budget / io_buffer");
        let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
        assert_eq!(report.runs, 10);
        assert_eq!(report.merge_passes, 1, "all runs fit one k-max pass");
        assert_eq!(read_keys_file::<u64>(&out).unwrap(), want);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn epoch_cut_weights_use_learned_keys_and_decay() {
        let epochs = vec![
            EpochStats { learned: 2, fallback: 1, keys: 3000, learned_keys: 2000 },
            EpochStats { learned: 1, fallback: 2, keys: 3000, learned_keys: 1000 },
            EpochStats { learned: 4, fallback: 0, keys: 4000, learned_keys: 4000 },
        ];
        // no decay: the weights are exactly the learned keys — fallback
        // keys (the vetoed/drifted chunks) never inflate an epoch
        assert_eq!(epoch_cut_weights(&epochs, 1.0), vec![2000.0, 1000.0, 4000.0]);
        // decay 0.5: each older epoch halves relative to the newest
        assert_eq!(epoch_cut_weights(&epochs, 0.5), vec![500.0, 500.0, 4000.0]);
        // out-of-range decay values mean "no decay", never a poisoned weight
        for bad in [0.0, -1.0, 2.0, f64::NAN, f64::INFINITY] {
            assert_eq!(epoch_cut_weights(&epochs, bad), vec![2000.0, 1000.0, 4000.0]);
        }
        // an all-fallback epoch weighs zero and is filtered from the cuts
        let dead = vec![EpochStats { learned: 0, fallback: 3, keys: 900, learned_keys: 0 }];
        assert_eq!(epoch_cut_weights(&dead, 1.0), vec![0.0]);
        assert!(epoch_cut_weights(&[], 0.5).is_empty());
    }

    #[test]
    fn delta_codec_pipeline_is_byte_identical_to_raw() {
        // The tentpole's core contract at the driver level: same stream,
        // raw vs delta spill codec, identical output bytes — including
        // the multi-pass + sharded-merge path — with the delta report
        // showing fewer spill bytes on this dup-heavy input.
        let mut rng = Xoshiro256pp::new(0xC0DEC);
        let n = 60_000;
        let keys: Vec<u64> = (0..n).map(|_| 7_000_000 + rng.next_below(5_000)).collect();
        let raw_out = tmp("codec-raw.bin");
        let delta_out = tmp("codec-delta.bin");
        let base = ExternalConfig {
            memory_budget: 3 * 8192 * 8,
            io_buffer: 4096,
            merge_fanout: 4,
            threads: 2,
            min_shard_keys: 1024,
            ..ExternalConfig::default()
        };
        let raw_cfg = ExternalConfig { spill_codec: SpillCodec::Raw, ..base.clone() };
        let delta_cfg = ExternalConfig { spill_codec: SpillCodec::Delta, ..base };
        let raw = sort_iter(keys.iter().copied(), &raw_out, &raw_cfg).unwrap();
        let delta = sort_iter(keys.iter().copied(), &delta_out, &delta_cfg).unwrap();
        assert_eq!(raw.keys, delta.keys);
        assert_eq!(
            std::fs::read(&raw_out).unwrap(),
            std::fs::read(&delta_out).unwrap(),
            "spill codec must never change the output bytes"
        );
        assert_eq!(raw.spill_bytes, raw.spill_bytes_raw, "raw spills at parity");
        assert_eq!(delta.spill_bytes_raw, raw.spill_bytes_raw);
        assert!(
            delta.spill_bytes * 2 < delta.spill_bytes_raw,
            "dup-heavy spill must compress (delta {} vs raw {})",
            delta.spill_bytes,
            delta.spill_bytes_raw
        );
        let _ = std::fs::remove_file(&raw_out);
        let _ = std::fs::remove_file(&delta_out);
    }

    #[test]
    fn delta_codec_single_run_transcodes_to_raw_output() {
        // One run (input fits the budget) under the delta codec: the
        // copy-through path must rewrite the run as a raw v1 output, not
        // leak a v2 file into the interchange format.
        let out = tmp("codec-single.bin");
        let keys: Vec<u64> = vec![5, 3, 9, 9, 1];
        let cfg = ExternalConfig {
            spill_codec: SpillCodec::Delta,
            ..ExternalConfig::default()
        };
        let report = sort_iter(keys, &out, &cfg).unwrap();
        assert_eq!(report.runs, 1);
        assert_eq!(report.merge_passes, 0);
        let h = read_header(&out).unwrap().expect("output carries a header");
        assert_eq!(h.version, spill::RAW_VERSION, "outputs are always raw v1");
        assert_eq!(read_keys_file::<u64>(&out).unwrap(), vec![1, 3, 5, 9, 9]);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn age_decay_shifts_cuts_toward_recent_epochs_and_stays_exact() {
        // Three-regime shard-balance pin for the age-decay knob: with an
        // aggressive decay the sort must still be byte-exact (balance
        // only), and the weight helper must tilt toward late epochs.
        let mut rng = Xoshiro256pp::new(0xA9ED);
        let chunk = 16_384usize;
        let mut keys: Vec<f64> = (0..2 * chunk).map(|_| rng.uniform(0.0, 1e5)).collect();
        keys.extend((0..2 * chunk).map(|_| rng.uniform(4e5, 5e5)));
        keys.extend((0..2 * chunk).map(|_| rng.uniform(9e5, 1e6)));
        let out = tmp("age-decay.bin");
        let cfg = ExternalConfig {
            memory_budget: chunk * 8,
            threads: 1,
            min_shard_keys: 1024,
            merge_shards: 3,
            epoch_age_decay: 0.25,
            retrain: RetrainPolicy { retrain_after: 1, max_retrains: 4 },
            ..ExternalConfig::default()
        };
        let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
        assert!(report.retrains >= 1, "regime changes must retrain");
        let mut want = keys;
        want.sort_unstable_by(f64::total_cmp);
        let got = read_keys_file::<f64>(&out).unwrap();
        let gb: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u64> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb, "age decay is balance-only, never correctness");
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn all_fallback_stream_shards_off_the_empirical_mixture() {
        // No model ever trains (min_learned_chunk above the chunk size),
        // so the final merge's quantile cuts come purely from the
        // fallback chunks' empirical sample — which must still admit a
        // balanced sharded merge where the old pipeline forced the
        // serial loser tree.
        let mut rng = Xoshiro256pp::new(0xE417);
        let n = 40_000;
        let keys: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
        let out = tmp("empirical-shard.bin");
        let cfg = ExternalConfig {
            memory_budget: 8192 * 8,
            threads: 2,
            min_learned_chunk: usize::MAX,
            min_shard_keys: 1024,
            merge_shards: 4,
            ..ExternalConfig::default()
        };
        let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
        assert!(!report.rmi_trained);
        assert_eq!(report.learned_runs, 0);
        assert_eq!(report.fallback_runs, report.runs);
        assert!(
            report.merge_shards >= 2,
            "empirical-only cuts must shard: {report:?}"
        );
        let mut want = keys;
        want.sort_unstable_by(f64::total_cmp);
        let got = read_keys_file::<f64>(&out).unwrap();
        let gb: Vec<u64> = got.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u64> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn report_serializes_to_json() {
        let report = ExternalSortReport {
            keys: 100,
            runs: 2,
            rmi_trained: true,
            epochs: vec![EpochStats { learned: 1, fallback: 1, keys: 100, learned_keys: 60 }],
            ..Default::default()
        };
        let back = Json::parse(&report.to_json().dump()).unwrap();
        assert_eq!(back.get("keys").and_then(Json::as_usize), Some(100));
        assert_eq!(back.get("runs").and_then(Json::as_usize), Some(2));
        assert!(matches!(back.get("rmi_trained"), Some(Json::Bool(true))));
        let e0 = back.get("epochs").and_then(|e| e.idx(0)).unwrap();
        assert_eq!(e0.get("learned_keys").and_then(Json::as_usize), Some(60));
    }

    #[test]
    fn pipeline_emits_phase_spans_and_counters() {
        let _l = crate::obs::test_lock();
        crate::obs::reset();
        crate::obs::set_enabled(true);
        let mut rng = Xoshiro256pp::new(0x0B5);
        let keys: Vec<u64> = (0..20_000).map(|_| rng.next_u64()).collect();
        let out = tmp("obs-spans.bin");
        // serial pipeline, 8Ki-key chunks: the model trains, every later
        // chunk runs the drift probe, and 3 runs force one merge pass
        let cfg = ExternalConfig {
            memory_budget: 8192 * 8,
            threads: 1,
            ..ExternalConfig::default()
        };
        let report = sort_iter(keys.iter().copied(), &out, &cfg).unwrap();
        crate::obs::set_enabled(false);
        let doc = crate::obs::job_telemetry(Some(report.to_json()));
        crate::obs::validate_telemetry(
            &doc,
            &[
                crate::obs::S_EXTSORT,
                crate::obs::S_CHUNK_READ,
                crate::obs::S_CHUNK_SORT,
                crate::obs::S_SPILL_WRITE,
                crate::obs::S_MERGE_PASS,
            ],
            &[
                crate::obs::M_SPILL_BYTES_ENCODED,
                crate::obs::M_SPILL_BYTES_RAW,
                crate::obs::M_DRIFT_ERROR,
            ],
        )
        .unwrap();
        let m = crate::obs::metrics::snapshot();
        assert_eq!(
            m.counters.get(crate::obs::C_SPILL_RUNS),
            Some(&(report.runs as u64))
        );
        assert_eq!(
            m.counters.get(crate::obs::C_MERGE_PASSES),
            Some(&(report.merge_passes as u64))
        );
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn empty_input_writes_empty_output() {
        let out = tmp("empty.bin");
        let report =
            sort_iter::<u64, _>(std::iter::empty(), &out, &ExternalConfig::default()).unwrap();
        assert_eq!(report.keys, 0);
        assert_eq!(report.runs, 0);
        // header only: a valid self-describing file of zero keys
        assert_eq!(
            std::fs::metadata(&out).unwrap().len(),
            spill::HEADER_LEN as u64
        );
        assert_eq!(file_key_count(&out).unwrap(), 0);
        assert!(read_keys_file::<u64>(&out).unwrap().is_empty());
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn single_run_copies_through() {
        let out = tmp("single.bin");
        let keys: Vec<u64> = vec![5, 3, 9, 1];
        let report = sort_iter(keys, &out, &ExternalConfig::default()).unwrap();
        assert_eq!(report.runs, 1);
        assert_eq!(report.merge_passes, 0);
        assert_eq!(read_keys_file::<u64>(&out).unwrap(), vec![1, 3, 5, 9]);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn early_failure_preserves_preexisting_output() {
        // the spill dir is a *file*, so SpillDir::create_striped fails
        // before this run ever touches the output — a pre-existing
        // result must survive.
        let bad_tmp = tmp("bad-tmp-as-file");
        std::fs::write(&bad_tmp, b"x").unwrap();
        let out = tmp("preexisting-out.bin");
        std::fs::write(&out, b"12345678").unwrap(); // prior run's data
        let cfg = ExternalConfig {
            spill_dirs: vec![bad_tmp.clone()],
            threads: 1,
            ..ExternalConfig::default()
        };
        let err = sort_iter(vec![3u64, 1, 2], &out, &cfg);
        assert!(err.is_err(), "spilling into a file-as-dir must fail");
        assert_eq!(
            std::fs::read(&out).unwrap(),
            b"12345678".to_vec(),
            "a failure before the merge must not delete the caller's file"
        );
        let _ = std::fs::remove_file(&bad_tmp);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn failed_merge_cleans_spill_dir_and_output() {
        // The output directory does not exist, so the final merge (or the
        // single-run copy) fails after runs were spilled. Neither the
        // scratch directory nor a partial output may survive the error.
        let base = tmp("fail-clean-base");
        std::fs::create_dir_all(&base).unwrap();
        let out = base.join("no-such-dir").join("out.bin");
        let mut rng = Xoshiro256pp::new(14);
        let keys: Vec<u64> = (0..20_000).map(|_| rng.next_u64()).collect();
        let cfg = ExternalConfig {
            memory_budget: 2048 * 8,
            threads: 1,
            spill_dirs: vec![base.clone()],
            ..ExternalConfig::default()
        };
        let err = sort_iter(keys.iter().copied(), &out, &cfg);
        assert!(err.is_err(), "merge into a missing directory must fail");
        assert!(!out.exists());
        let leftovers: Vec<_> = std::fs::read_dir(&base)
            .unwrap()
            .map(|e| e.unwrap().path())
            .collect();
        assert!(
            leftovers.is_empty(),
            "spilled runs leaked after a failed merge: {leftovers:?}"
        );
        let _ = std::fs::remove_dir_all(&base);
    }
}
