//! `aipso` — CLI for the AIPS²o reproduction (leader entrypoint).
//!
//! Subcommands:
//!   gen             generate a dataset to stdout stats or a binary file
//!   sort            sort one dataset with one engine, report rate
//!   extsort         out-of-core sort of a binary key file (memory budget)
//!   bench           regenerate paper figures (F1–F6) as markdown
//!   pivot-quality   regenerate Table 2
//!   phases          per-phase time breakdown for one engine (perf tool)
//!   serve           run a synthetic job trace through the coordinator
//!   artifacts-check load the PJRT artifacts, verify native/XLA parity
//!
//! Arg parsing is hand-rolled (no clap offline): `--key value` pairs.

use std::collections::BTreeMap;

use aipso::bench_harness::{self, BenchConfig};
use aipso::coordinator::{Coordinator, JobSpec, KeyBuf};
use aipso::datasets::{self, FigureGroup, KeyType};
use aipso::external::{self, ExternalConfig, IoBackendKind, RetrainPolicy, RunGen, SpillCodec};
use aipso::key::{KeyKind, SortKey};
use aipso::obs;
use aipso::rmi::model::{Rmi, RmiConfig};
use aipso::runtime::RmiRuntime;
use aipso::util::json::Json;
use aipso::util::rng::Xoshiro256pp;
use aipso::util::timer;
use aipso::util::{fmt, stats};
use aipso::{sort_parallel, sort_sequential, SortEngine};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        usage_and_exit(None);
    };
    let opts = parse_opts(&args[1..]);
    let code = match cmd.as_str() {
        "gen" => cmd_gen(&opts),
        "sort" => cmd_sort(&opts),
        "extsort" => cmd_extsort(&opts),
        "bench" => cmd_bench(&opts),
        "pivot-quality" => cmd_pivot_quality(&opts),
        "phases" => cmd_phases(&opts),
        "serve" => cmd_serve(&opts),
        "telemetry-check" => cmd_telemetry_check(&opts),
        "artifacts-check" => cmd_artifacts_check(&opts),
        "help" | "--help" | "-h" => {
            usage_and_exit(None);
        }
        other => usage_and_exit(Some(other)),
    };
    std::process::exit(code);
}

fn usage_and_exit(unknown: Option<&str>) -> ! {
    if let Some(u) = unknown {
        eprintln!("unknown command: {u}\n");
    }
    eprintln!(
        "aipso — LearnedSort as a learning-augmented SampleSort (SSDBM'23 reproduction)

USAGE: aipso <command> [--key value ...]

COMMANDS
  gen             --dataset NAME [--n N] [--seed S] [--out FILE] [--stream]
                  [--width 4|8] [--codec raw|zigzag] [--key str]
                  [--payload 0|8|64]
                  (4 writes the dataset-native f32/u32 stream at half the
                  bytes; files carry a self-describing header; --codec
                  zigzag compresses the unsorted output through the v3
                  zigzag+varint block codec — extsort reads it directly;
                  --key str renders the stream as prefix-encoded string
                  keys and --payload attaches row-id payloads, writing a
                  record (v4) file — both need --out and imply raw)
  sort            --dataset NAME --engine ENGINE [--n N] [--threads T] [--seq]
  extsort         --input FILE --output FILE [--key f64|u64|f32|u32|str]
                  [--payload 0|8|64]
                  [--budget-mb MB] [--fanout K] [--threads T] [--shards P]
                  [--ips4o-runs] [--retrain N|off] [--max-retrains M]
                  [--codec raw|delta] [--age-decay D] [--trace-json FILE]
                  [--spill-dir DIR[,DIR...]] [--io-backend sync|pool]
                  [--direct]
                  (--trace-json traces the job and writes the
                   machine-readable aipso.telemetry.v1 document — phase
                   spans, pipeline counters/histograms, final report;
                   --key and --payload are inferred from the input's
                   header when omitted;
                   or --dataset NAME --n N [--width 4|8] to synthesize
                   --input first; --threads 1 = serial reference pipeline;
                   --retrain N retrains the model after N consecutive
                   drifted chunks, 'off' pins the permanent fallback;
                   --codec delta spills sorted runs as compressed
                   delta+varint blocks — the output stays raw either way;
                   --age-decay D<1 tilts the merge's shard cuts toward
                   recent model epochs; --spill-dir is repeatable and
                   stripes runs round-robin across the listed dirs;
                   --io-backend pool drains spill IO on a worker pool;
                   --direct opens run-generation spills O_DIRECT where the
                   filesystem allows, falling back to buffered; every
                   combination is byte-identical)
  bench           [--figure f1|f2|f3|f4|f5|f6|all] [--n N] [--reps R] [--threads T]
  pivot-quality   [--n N]
  phases          --dataset NAME --engine ENGINE [--n N] [--threads T]
  serve           [--jobs J] [--n N] [--threads T] [--metrics-json FILE]
  telemetry-check --input FILE
                  (validate an extsort --trace-json document against the
                   aipso.telemetry.v1 schema and the base span/histogram
                   sets; exits 1 on a malformed or incomplete document)
  artifacts-check [--dir artifacts]

ENGINES: aips2o ips4o ips2ra learnedsort std learnedpivotqs learnedqs
DATASETS: {}",
        datasets::ALL
            .iter()
            .map(|d| d.name)
            .collect::<Vec<_>>()
            .join(" ")
    );
    std::process::exit(2);
}

fn parse_opts(args: &[String]) -> BTreeMap<String, String> {
    let mut m = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let flag_like = i + 1 >= args.len() || args[i + 1].starts_with("--");
            if flag_like {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                let v = args[i + 1].clone();
                // Repeated options accumulate comma-separated, so
                // `--spill-dir a --spill-dir b` ≡ `--spill-dir a,b`.
                m.entry(key.to_string())
                    .and_modify(|prev| {
                        prev.push(',');
                        prev.push_str(&v);
                    })
                    .or_insert(v);
                i += 2;
            }
        } else {
            eprintln!("ignoring stray argument: {a}");
            i += 1;
        }
    }
    m
}

fn opt_usize(opts: &BTreeMap<String, String>, key: &str, default: usize) -> usize {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn opt_u64(opts: &BTreeMap<String, String>, key: &str, default: u64) -> u64 {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn cmd_gen(opts: &BTreeMap<String, String>) -> i32 {
    let Some(name) = opts.get("dataset") else {
        eprintln!("gen: --dataset required");
        return 2;
    };
    let n = opt_usize(opts, "n", 1_000_000);
    let seed = opt_u64(opts, "seed", 42);
    let width = opt_usize(opts, "width", 8);
    if width != 4 && width != 8 {
        eprintln!("gen: --width must be 4 or 8");
        return 2;
    }
    let Some(spec) = datasets::spec(name) else {
        eprintln!("unknown dataset {name}");
        return 2;
    };
    // --codec zigzag writes the unsorted stream through the v3
    // zigzag+varint block codec instead of raw fixed-width v1.
    let codec = match opts.get("codec").map(String::as_str) {
        None | Some("raw") => SpillCodec::Raw,
        Some("zigzag") => SpillCodec::Zigzag,
        Some(other) => {
            eprintln!("gen: unknown --codec {other} (use raw|zigzag — delta needs sorted keys)");
            return 2;
        }
    };
    // --key str / --payload N: prefix-encoded string keys and/or record
    // payloads — always chunked to a record-capable (v4) file.
    let str_keys = match opts.get("key").map(String::as_str) {
        Some("str") => true,
        None => false,
        Some(other) => {
            eprintln!(
                "gen: --key only takes 'str' (numeric domains follow the dataset; use --width)"
            );
            eprintln!("     (got --key {other})");
            return 2;
        }
    };
    let payload = opt_usize(opts, "payload", 0);
    if !aipso::key::DISPATCH_PAYLOADS.contains(&payload) {
        eprintln!(
            "gen: --payload must be one of {:?}",
            aipso::key::DISPATCH_PAYLOADS
        );
        return 2;
    }
    if str_keys || payload > 0 {
        if codec != SpillCodec::Raw {
            eprintln!("gen: string keys and record payloads write raw (v4) only (drop --codec)");
            return 2;
        }
        let Some(out) = opts.get("out") else {
            eprintln!("gen: --key str / --payload require --out FILE");
            return 2;
        };
        let chunk = opt_usize(opts, "chunk", 1 << 20);
        return match datasets::write_dataset_file_ext(
            spec.name,
            n,
            seed,
            out.as_ref(),
            chunk,
            width,
            str_keys,
            payload,
        ) {
            Ok(kind) => {
                let entry = kind.width() + kind.base_lane() + payload;
                println!(
                    "wrote {out} ({n} {} keys, {payload} B payload, {entry} B/entry + header, chunked)",
                    kind.name(),
                );
                0
            }
            Err(e) => {
                eprintln!("gen: {e}");
                1
            }
        };
    }
    if opts.contains_key("stream") {
        if codec != SpillCodec::Raw {
            eprintln!("gen: --stream writes raw v1 only (drop --codec)");
            return 2;
        }
        // chunked generation: the dataset never materializes in memory
        let Some(out) = opts.get("out") else {
            eprintln!("gen --stream requires --out FILE");
            return 2;
        };
        let chunk = opt_usize(opts, "chunk", 1 << 20);
        match datasets::write_dataset_file_width(spec.name, n, seed, out.as_ref(), chunk, width) {
            Ok(kind) => {
                println!(
                    "wrote {out} ({n} {} keys, {} payload bytes + header, chunked)",
                    kind.name(),
                    n * kind.width(),
                );
                return 0;
            }
            Err(e) => {
                eprintln!("gen --stream: {e}");
                return 1;
            }
        }
    }
    // In-memory generation: narrow first when --width 4 so the printed
    // stats describe the keys actually written, then (optionally) write
    // the file through the spill codec.
    let written = match spec.key_type {
        KeyType::F64 => {
            let v = datasets::generate_f64(spec.name, n, seed).unwrap();
            if width == 8 {
                print_f64_stats(spec.name, &v);
                opts.get("out").map(|out| write_gen_file::<f64>(out, &v, codec))
            } else {
                let narrow: Vec<f32> = v.iter().map(|&x| x as f32).collect();
                let f: Vec<f64> = narrow.iter().map(|&x| x as f64).collect();
                print_f64_stats(spec.name, &f);
                opts.get("out").map(|out| write_gen_file::<f32>(out, &narrow, codec))
            }
        }
        KeyType::U64 => {
            let v = datasets::generate_u64(spec.name, n, seed).unwrap();
            if width == 8 {
                let f: Vec<f64> = v.iter().map(|&x| x as f64).collect();
                print_f64_stats(spec.name, &f);
                opts.get("out").map(|out| write_gen_file::<u64>(out, &v, codec))
            } else {
                let narrow: Vec<u32> = v.iter().map(|&x| x as u32).collect();
                let f: Vec<f64> = narrow.iter().map(|&x| x as f64).collect();
                print_f64_stats(spec.name, &f);
                opts.get("out").map(|out| write_gen_file::<u32>(out, &narrow, codec))
            }
        }
    };
    match written {
        Some(Err(code)) => code,
        _ => 0,
    }
}

/// Write a generated key slice as a self-describing key file (raw v1 or
/// zigzag v3 per `codec`); returns the process exit code on failure.
fn write_gen_file<K: SortKey>(out: &str, keys: &[K], codec: SpillCodec) -> Result<(), i32> {
    match external::write_keys_file_codec::<K>(std::path::Path::new(out), keys, codec) {
        Ok(run) => {
            println!(
                "wrote {} ({} {} keys, {} {} bytes + header)",
                out,
                run.n,
                K::KIND.name(),
                run.bytes.saturating_sub(external::HEADER_LEN as u64),
                codec.name(),
            );
            Ok(())
        }
        Err(e) => {
            eprintln!("write {out}: {e}");
            Err(1)
        }
    }
}

fn print_f64_stats(name: &str, v: &[f64]) {
    println!(
        "{name}: n={} min={:.4e} p50={:.4e} max={:.4e} mean={:.4e}",
        v.len(),
        stats::min(v),
        stats::median(&v[..v.len().min(100_000)]),
        stats::max(v),
        stats::mean(v),
    );
}

fn cmd_sort(opts: &BTreeMap<String, String>) -> i32 {
    let Some(name) = opts.get("dataset") else {
        eprintln!("sort: --dataset required");
        return 2;
    };
    let engine = match opts.get("engine").and_then(|e| SortEngine::parse(e)) {
        Some(e) => e,
        None => {
            eprintln!("sort: --engine required (or unknown engine)");
            return 2;
        }
    };
    let n = opt_usize(opts, "n", 2_000_000);
    let seed = opt_u64(opts, "seed", 42);
    let threads = opt_usize(opts, "threads", 0);
    let parallel = !opts.contains_key("seq");
    let Some(spec) = datasets::spec(name) else {
        eprintln!("unknown dataset {name}");
        return 2;
    };
    let (secs, ok) = match spec.key_type {
        KeyType::F64 => {
            let mut v = datasets::generate_f64(spec.name, n, seed).unwrap();
            let (_, secs) = timer::time_it(|| {
                if parallel {
                    sort_parallel(engine, &mut v, threads)
                } else {
                    sort_sequential(engine, &mut v)
                }
            });
            (secs, aipso::is_sorted(&v))
        }
        KeyType::U64 => {
            let mut v = datasets::generate_u64(spec.name, n, seed).unwrap();
            let (_, secs) = timer::time_it(|| {
                if parallel {
                    sort_parallel(engine, &mut v, threads)
                } else {
                    sort_sequential(engine, &mut v)
                }
            });
            (secs, aipso::is_sorted(&v))
        }
    };
    println!(
        "{} on {} (n={}): {} — {} [{}]",
        engine.paper_name(parallel),
        spec.paper_name,
        fmt::keys(n),
        fmt::secs(secs),
        fmt::rate(n as f64 / secs.max(1e-12)),
        if ok { "sorted" } else { "NOT SORTED" },
    );
    if ok {
        0
    } else {
        1
    }
}

fn cmd_extsort(opts: &BTreeMap<String, String>) -> i32 {
    let Some(input) = opts.get("input") else {
        eprintln!("extsort: --input required");
        return 2;
    };
    let Some(output) = opts.get("output") else {
        eprintln!("extsort: --output required");
        return 2;
    };
    let mut cfg = ExternalConfig::default();
    if let Some(mb) = opts.get("budget-mb").and_then(|v| v.parse::<usize>().ok()) {
        cfg.memory_budget = mb.max(1) << 20;
    }
    cfg.merge_fanout = opt_usize(opts, "fanout", cfg.merge_fanout);
    cfg.threads = opt_usize(opts, "threads", 0);
    cfg.merge_shards = opt_usize(opts, "shards", cfg.merge_shards);
    if opts.contains_key("ips4o-runs") {
        cfg.run_gen = RunGen::Ips4o;
    }
    // --retrain off|N (bare --retrain keeps the default-enabled policy);
    // --max-retrains M bounds the installs per sort.
    if let Some(v) = opts.get("retrain") {
        cfg.retrain = match v.as_str() {
            "off" | "false" | "0" => RetrainPolicy::disabled(),
            "on" | "true" => RetrainPolicy::default(),
            n => match n.parse::<usize>() {
                Ok(after) => RetrainPolicy {
                    retrain_after: after,
                    ..RetrainPolicy::default()
                },
                Err(_) => {
                    eprintln!("extsort: --retrain expects a chunk count, 'on' or 'off'");
                    return 2;
                }
            },
        };
    }
    cfg.retrain.max_retrains = opt_usize(opts, "max-retrains", cfg.retrain.max_retrains);
    if let Some(c) = opts.get("codec") {
        cfg.spill_codec = match SpillCodec::parse(c) {
            // zigzag is the *unsorted* codec (gen outputs); spilled runs
            // are sorted by construction and take the tighter delta form
            Some(SpillCodec::Zigzag) | None => {
                eprintln!("extsort: unknown --codec {c} (use raw|delta)");
                return 2;
            }
            Some(codec) => codec,
        };
    }
    if let Some(dirs) = opts.get("spill-dir") {
        cfg.spill_dirs = dirs
            .split(',')
            .filter(|s| !s.is_empty())
            .map(std::path::PathBuf::from)
            .collect();
    }
    if let Some(b) = opts.get("io-backend") {
        cfg.io_backend = match IoBackendKind::parse(b) {
            Some(kind) => kind,
            None => {
                eprintln!("extsort: unknown --io-backend {b} (use sync|pool)");
                return 2;
            }
        };
    }
    if opts.contains_key("direct") {
        cfg.direct_io = true;
    }
    if let Some(d) = opts.get("age-decay") {
        cfg.epoch_age_decay = match d.parse::<f64>() {
            Ok(decay) if decay > 0.0 && decay <= 1.0 => decay,
            _ => {
                eprintln!("extsort: --age-decay expects a number in (0, 1]");
                return 2;
            }
        };
    }

    // Resolve the key domain and payload width: synthesize from a
    // dataset, take --key/--payload, or read both off the input's
    // self-describing header (v4/v5 headers carry the lane width).
    let mut payload: Option<usize> = match opts.get("payload") {
        Some(v) => match v.parse::<usize>() {
            Ok(p) if aipso::key::DISPATCH_PAYLOADS.contains(&p) => Some(p),
            _ => {
                eprintln!(
                    "extsort: --payload must be one of {:?}",
                    aipso::key::DISPATCH_PAYLOADS
                );
                return 2;
            }
        },
        None => None,
    };
    let kind: KeyKind = if let Some(dataset) = opts.get("dataset") {
        let n = opt_usize(opts, "n", 8_000_000);
        let seed = opt_u64(opts, "seed", 42);
        let width = opt_usize(opts, "width", 8);
        let str_keys = matches!(opts.get("key").map(String::as_str), Some("str"));
        let pay = payload.unwrap_or(0);
        match datasets::write_dataset_file_ext(
            dataset,
            n,
            seed,
            input.as_ref(),
            1 << 20,
            width,
            str_keys,
            pay,
        ) {
            Ok(kind) => {
                payload = Some(pay);
                println!(
                    "synthesized {input}: {dataset}, {n} {} keys ({pay} B payload)",
                    kind.name()
                );
                kind
            }
            Err(e) => {
                eprintln!("extsort: {e}");
                return 2;
            }
        }
    } else if let Some(k) = opts.get("key") {
        match KeyKind::parse(k) {
            Some(kind) => kind,
            None => {
                eprintln!("extsort: unknown --key {k} (use f64|u64|f32|u32|str)");
                return 2;
            }
        }
    } else {
        match external::read_header(input.as_ref()) {
            Ok(Some(h)) => {
                let inferred = (h.lane as usize).saturating_sub(h.kind.base_lane());
                if payload.is_none() && inferred > 0 {
                    payload = Some(inferred);
                }
                println!(
                    "{input}: {} keys ({} B lane) per its spill header",
                    h.kind.name(),
                    h.lane,
                );
                h.kind
            }
            Ok(None) => {
                eprintln!(
                    "extsort: {input} is a headerless (v0) file — pass --key f64|u64"
                );
                return 2;
            }
            Err(e) => {
                eprintln!("extsort: {e}");
                return 1;
            }
        }
    };
    let payload = payload.unwrap_or(0);

    // --trace-json: collect phase spans + pipeline metrics for this job
    // and write the aipso.telemetry.v1 document next to the report.
    let trace_path = opts.get("trace-json");
    if trace_path.is_some() {
        obs::reset();
        obs::set_enabled(true);
    }
    let result = external::sort_and_verify(kind, payload, input.as_ref(), output.as_ref(), &cfg);
    if trace_path.is_some() {
        obs::set_enabled(false);
    }
    let (report, secs, ok) = match result {
        Ok(r) => r,
        Err(e) => {
            eprintln!("extsort failed: {e}");
            return 1;
        }
    };
    println!(
        "extsort {} -> {} ({} keys, {} B/entry): {} keys in {} — {} [{}]\n  \
         budget {} MiB, {} runs ({} learned, {} fallback), rmi trained: {}, \
         retrains: {}, merge passes: {} ({} sharded groups), \
         final-merge shards: {}",
        input,
        output,
        kind.name(),
        kind.width() + kind.base_lane() + payload,
        fmt::keys(report.keys as usize),
        fmt::secs(secs),
        fmt::rate(report.keys as f64 / secs.max(1e-12)),
        if ok { "sorted" } else { "NOT SORTED" },
        cfg.memory_budget >> 20,
        report.runs,
        report.learned_runs,
        report.fallback_runs,
        report.rmi_trained,
        report.retrains,
        report.merge_passes,
        report.sharded_groups,
        if report.merge_shards == 0 {
            "serial".to_string()
        } else {
            report.merge_shards.to_string()
        },
    );
    // raw-vs-compressed spill accounting: with --codec raw the two sides
    // are equal; with delta the ratio is the codec's IO saving
    let ratio = report.spill_bytes as f64 / report.spill_bytes_raw.max(1) as f64;
    println!(
        "  spill ({}): {} B on disk vs {} B raw ({:.2}x)",
        cfg.spill_codec.name(),
        report.spill_bytes,
        report.spill_bytes_raw,
        ratio,
    );
    if report.retrains > 0 {
        let epochs: Vec<String> = report
            .epochs
            .iter()
            .enumerate()
            .map(|(e, s)| format!("e{e}: {} learned / {} fallback", s.learned, s.fallback))
            .collect();
        println!("  epochs: {}", epochs.join(", "));
    }
    if let Some(path) = trace_path {
        let doc = obs::job_telemetry(Some(report.to_json()));
        if let Err(e) = std::fs::write(path, doc.dump()) {
            eprintln!("extsort: writing {path}: {e}");
            return 1;
        }
        println!("  telemetry: wrote {path} ({})", obs::SCHEMA);
    }
    if ok {
        0
    } else {
        1
    }
}

fn cmd_telemetry_check(opts: &BTreeMap<String, String>) -> i32 {
    let Some(input) = opts.get("input") else {
        eprintln!("telemetry-check: --input required");
        return 2;
    };
    let text = match std::fs::read_to_string(input) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("telemetry-check: {input}: {e}");
            return 1;
        }
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("telemetry-check: {input}: parse error: {e}");
            return 1;
        }
    };
    // The acceptance contract: the whole-job root span, every base
    // pipeline phase, and the spill/drift/skew histograms.
    let mut spans: Vec<&str> = vec![obs::S_EXTSORT];
    spans.extend_from_slice(obs::BASE_EXTSORT_SPANS);
    match obs::validate_telemetry(&doc, &spans, obs::BASE_EXTSORT_HISTS) {
        Ok(()) => {
            println!("{input}: telemetry OK ({})", obs::SCHEMA);
            0
        }
        Err(e) => {
            eprintln!("{input}: telemetry INVALID: {e}");
            1
        }
    }
}

fn cmd_bench(opts: &BTreeMap<String, String>) -> i32 {
    let cfg = BenchConfig {
        n: opt_usize(opts, "n", BenchConfig::default().n),
        reps: opt_usize(opts, "reps", BenchConfig::default().reps),
        threads: opt_usize(opts, "threads", 0),
        ..Default::default()
    };
    let figure = opts.get("figure").map(|s| s.as_str()).unwrap_or("all");
    let figures: Vec<(&str, FigureGroup, bool)> = vec![
        ("Figure 1 (sequential, synthetic 1)", FigureGroup::Synthetic1, false),
        ("Figure 2 (sequential, synthetic 2)", FigureGroup::Synthetic2, false),
        ("Figure 3 (sequential, real-world)", FigureGroup::RealWorld, false),
        ("Figure 4 (parallel, synthetic 1)", FigureGroup::Synthetic1, true),
        ("Figure 5 (parallel, synthetic 2)", FigureGroup::Synthetic2, true),
        ("Figure 6 (parallel, real-world)", FigureGroup::RealWorld, true),
    ];
    let selected: Vec<usize> = match figure {
        "all" => (0..6).collect(),
        "f1" => vec![0],
        "f2" => vec![1],
        "f3" => vec![2],
        "f4" => vec![3],
        "f5" => vec![4],
        "f6" => vec![5],
        other => {
            eprintln!("unknown figure {other}");
            return 2;
        }
    };
    for idx in selected {
        let (title, group, parallel) = figures[idx];
        let rows = bench_harness::run_figure(group, parallel, &cfg);
        print!("{}", bench_harness::render_rows(title, &rows));
        println!();
    }
    0
}

fn cmd_pivot_quality(opts: &BTreeMap<String, String>) -> i32 {
    let cfg = BenchConfig {
        n: opt_usize(opts, "n", 2_000_000),
        ..Default::default()
    };
    println!("Table 2: pivot quality, sum_i |CDF(p_i) - (i+1)/B|, 255 pivots\n");
    let rows = bench_harness::table2_pivot_quality(&cfg);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|(name, qr, qm)| {
            vec![name.clone(), format!("{qr:.4}"), format!("{qm:.4}")]
        })
        .collect();
    print!(
        "{}",
        fmt::markdown_table(&["dataset", "Random (255 pivots)", "RMI (255 pivots)"], &table)
    );
    println!("\npaper: Uniform 1.1016 vs 0.4388; Wiki/Edit 0.9991 vs 0.5157");
    0
}

fn cmd_phases(opts: &BTreeMap<String, String>) -> i32 {
    let name = opts.get("dataset").cloned().unwrap_or("uniform".into());
    let engine = opts
        .get("engine")
        .and_then(|e| SortEngine::parse(e))
        .unwrap_or(SortEngine::Aips2o);
    let n = opt_usize(opts, "n", 2_000_000);
    let threads = opt_usize(opts, "threads", 0);
    let spec = datasets::spec(&name).expect("unknown dataset");
    timer::set_phase_profiling(true);
    timer::reset_phases();
    let secs = match spec.key_type {
        KeyType::F64 => {
            let mut v = datasets::generate_f64(spec.name, n, 42).unwrap();
            timer::time_it(|| sort_parallel(engine, &mut v, threads)).1
        }
        KeyType::U64 => {
            let mut v = datasets::generate_u64(spec.name, n, 42).unwrap();
            timer::time_it(|| sort_parallel(engine, &mut v, threads)).1
        }
    };
    timer::set_phase_profiling(false);
    println!(
        "{} on {} (n={}): {}\nphase breakdown (cumulative across threads):",
        engine.paper_name(true),
        spec.paper_name,
        fmt::keys(n),
        fmt::secs(secs)
    );
    print!("{}", timer::phase_report(&timer::phase_snapshot()));
    0
}

fn cmd_serve(opts: &BTreeMap<String, String>) -> i32 {
    let jobs = opt_usize(opts, "jobs", 24);
    let n = opt_usize(opts, "n", 500_000);
    let threads = opt_usize(opts, "threads", 0);
    let mut rng = Xoshiro256pp::new(opt_u64(opts, "seed", 7));
    // --metrics-json: also collect the process-global observability
    // metrics (router decisions, pool depth) for the dump.
    let metrics_path = opts.get("metrics-json");
    if metrics_path.is_some() {
        obs::reset();
        obs::set_enabled(true);
    }
    let coordinator = Coordinator::new(threads);
    // synthetic trace: mix of sizes, distributions and key types
    for id in 0..jobs as u64 {
        let size = match id % 4 {
            0 => n,
            1 => n / 4,
            2 => n / 16,
            _ => 4_000,
        };
        let keys = match id % 7 {
            0 => KeyBuf::F64(
                datasets::generate_f64("uniform", size, rng.next_u64()).unwrap(),
            ),
            1 => KeyBuf::U64(
                datasets::generate_u64("wiki_edit", size, rng.next_u64()).unwrap(),
            ),
            2 => KeyBuf::F32(
                datasets::generate_f32("normal", size, rng.next_u64()).unwrap(),
            ),
            3 => KeyBuf::U32(
                datasets::generate_u32("fb_ids", size, rng.next_u64()).unwrap(),
            ),
            4 => KeyBuf::Str(
                datasets::generate_str("books_sales", size, rng.next_u64()).unwrap(),
            ),
            5 => KeyBuf::Rec64(datasets::attach_payloads(
                datasets::generate_u64("osm_cellids", size, rng.next_u64()).unwrap(),
                0,
            )),
            _ => KeyBuf::F64(
                datasets::generate_f64("root_dups", size, rng.next_u64()).unwrap(),
            ),
        };
        coordinator.submit(JobSpec::auto(id, keys));
    }
    let (reports, metrics) = coordinator.drain();
    if metrics_path.is_some() {
        obs::set_enabled(false);
    }
    let failures = reports.iter().filter(|r| !r.verified_sorted).count();
    println!(
        "served {} jobs ({} failures)\n\n{}",
        reports.len(),
        failures,
        metrics.report()
    );
    if let Some(path) = metrics_path {
        let mut doc = BTreeMap::new();
        doc.insert("schema".to_string(), Json::Str(obs::SCHEMA.to_string()));
        doc.insert("coordinator".to_string(), metrics.to_json());
        doc.insert("global".to_string(), obs::metrics::snapshot().to_json());
        doc.insert(
            "jobs".to_string(),
            Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
        );
        if let Err(e) = std::fs::write(path, Json::Obj(doc).dump()) {
            eprintln!("serve: writing {path}: {e}");
            return 1;
        }
        println!("\nmetrics dump: wrote {path}");
    }
    if failures == 0 {
        0
    } else {
        1
    }
}

fn cmd_artifacts_check(opts: &BTreeMap<String, String>) -> i32 {
    let dir = opts
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(aipso::runtime::default_artifacts_dir);
    let rt = match RmiRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("artifact load failed: {e:#}");
            return 1;
        }
    };
    let m = rt.manifest();
    println!(
        "artifacts ok: train_sample={} predict_batch={} n_leaves={}",
        m.train_sample, m.predict_batch, m.n_leaves
    );
    // parity spot-check: XLA-trained model vs native-trained model
    let mut rng = Xoshiro256pp::new(99);
    let mut sample: Vec<f64> = (0..m.train_sample).map(|_| rng.uniform(0.0, 1e6)).collect();
    sample.sort_unstable_by(f64::total_cmp);
    let xla_rmi = rt.train(&sample).expect("xla train");
    let native_rmi = Rmi::train(&sample, RmiConfig { n_leaves: m.n_leaves });
    let keys: Vec<f64> = (0..4096).map(|_| rng.uniform(0.0, 1e6)).collect();
    let xla_pred = rt.predict(&keys, &xla_rmi).expect("xla predict");
    let mut max_err: f64 = 0.0;
    for (k, xp) in keys.iter().zip(&xla_pred) {
        max_err = max_err.max((native_rmi.predict(*k) - xp).abs());
    }
    println!("max |native - xla| over 4096 predictions: {max_err:.3e}");
    if max_err < 1e-9 {
        println!("parity OK");
        0
    } else {
        eprintln!("parity FAILED");
        1
    }
}
