//! PJRT artifact runtime (substrate S9): loads the AOT-compiled JAX/Pallas
//! RMI (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! it on the XLA CPU client from Rust. Python never runs at sort time.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The runtime is the training/inference *reference* path; the native
//! mirror in [`crate::rmi`] is the per-key hot path. `rust/tests/
//! pjrt_parity.rs` pins the two together numerically, and the
//! `ablation_pjrt_vs_native` bench quantifies the FFI + batching overhead.
//!
//! Offline builds compile against the in-tree [`xla`] stub (the
//! `xla_extension` native library cannot be vendored here); every XLA
//! entry point then reports "backend not available" and the callers fall
//! back to / skip onto the native path.

pub mod xla;

use std::path::{Path, PathBuf};

use crate::rmi::model::Rmi;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{anyhow, bail};

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Static sample size the `rmi_train` artifact was compiled for.
    pub train_sample: usize,
    /// Static batch size the `rmi_predict` artifact was compiled for.
    pub predict_batch: usize,
    /// Second-level model count baked into the artifacts.
    pub n_leaves: usize,
    /// HLO text file of the training function.
    pub train_file: PathBuf,
    /// HLO text file of the prediction function.
    pub predict_file: PathBuf,
}

impl Manifest {
    /// Parse `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let functions = j.get("functions").context("manifest missing functions")?;
        let file_of = |name: &str| -> Result<PathBuf> {
            Ok(dir.join(
                functions
                    .get(name)
                    .and_then(|f| f.get("file"))
                    .and_then(|f| f.as_str())
                    .with_context(|| format!("manifest missing functions.{name}.file"))?,
            ))
        };
        Ok(Manifest {
            train_sample: j
                .get("train_sample")
                .and_then(|v| v.as_usize())
                .context("manifest missing train_sample")?,
            predict_batch: j
                .get("predict_batch")
                .and_then(|v| v.as_usize())
                .context("manifest missing predict_batch")?,
            n_leaves: j
                .get("n_leaves")
                .and_then(|v| v.as_usize())
                .context("manifest missing n_leaves")?,
            train_file: file_of("rmi_train")?,
            predict_file: file_of("rmi_predict")?,
        })
    }
}

/// Default artifact directory: `$AIPSO_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("AIPSO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// The loaded XLA executables for the RMI model.
pub struct RmiRuntime {
    manifest: Manifest,
    train_exe: xla::PjRtLoadedExecutable,
    predict_exe: xla::PjRtLoadedExecutable,
}

impl RmiRuntime {
    /// Load + compile both artifacts on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<RmiRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |path: &Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let train_exe = compile(&manifest.train_file)?;
        let predict_exe = compile(&manifest.predict_file)?;
        Ok(RmiRuntime {
            manifest,
            train_exe,
            predict_exe,
        })
    }

    /// Load from the default artifact directory.
    pub fn load_default() -> Result<RmiRuntime> {
        Self::load(&default_artifacts_dir())
    }

    /// The manifest the runtime was loaded from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Train the RMI through the XLA `rmi_train` artifact.
    ///
    /// The artifact is static-shaped (`train_sample` keys); other sample
    /// sizes are resampled by linear index stretching, which preserves
    /// sortedness and the empirical distribution.
    pub fn train(&self, sorted_sample: &[f64]) -> Result<Rmi> {
        if sorted_sample.is_empty() {
            bail!("cannot train on an empty sample");
        }
        let m = self.manifest.train_sample;
        let fitted: Vec<f64> = if sorted_sample.len() == m {
            sorted_sample.to_vec()
        } else {
            (0..m)
                .map(|i| sorted_sample[i * sorted_sample.len() / m])
                .collect()
        };
        let input = xla::Literal::vec1(&fitted);
        let result = self.train_exe.execute::<xla::Literal>(&[input])?[0][0]
            .to_literal_sync()?;
        let (root_lit, leaf_lit) = result.to_tuple2()?;
        let root = root_lit.to_vec::<f64>()?;
        let leaf = leaf_lit.to_vec::<f64>()?;
        if leaf.len() != self.manifest.n_leaves * 4 {
            bail!(
                "artifact returned {} leaf params, expected {}",
                leaf.len(),
                self.manifest.n_leaves * 4
            );
        }
        Ok(Rmi::from_params(&root, &leaf))
    }

    /// Predict CDF values through the XLA `rmi_predict` artifact, chunking
    /// and padding to the artifact's static batch size.
    pub fn predict(&self, keys: &[f64], rmi: &Rmi) -> Result<Vec<f64>> {
        let batch = self.manifest.predict_batch;
        let (root, leaf) = rmi.to_params();
        if leaf.len() != self.manifest.n_leaves * 4 {
            bail!(
                "model has {} leaves, artifact expects {}",
                leaf.len() / 4,
                self.manifest.n_leaves
            );
        }
        let root_lit = xla::Literal::vec1(&root);
        let leaf_lit =
            xla::Literal::vec1(&leaf).reshape(&[self.manifest.n_leaves as i64, 4])?;
        let mut out = Vec::with_capacity(keys.len());
        let mut padded = vec![0.0f64; batch];
        for chunk in keys.chunks(batch) {
            let lit = if chunk.len() == batch {
                xla::Literal::vec1(chunk)
            } else {
                padded[..chunk.len()].copy_from_slice(chunk);
                for p in padded[chunk.len()..].iter_mut() {
                    *p = 0.0;
                }
                xla::Literal::vec1(&padded)
            };
            let result = self
                .predict_exe
                .execute::<xla::Literal>(&[lit, root_lit.clone(), leaf_lit.clone()])?[0][0]
                .to_literal_sync()?;
            let cdf = result.to_tuple1()?.to_vec::<f64>()?;
            out.extend_from_slice(&cdf[..chunk.len()]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full runtime tests (artifact load + execute + parity with the native
    // RMI) live in rust/tests/pjrt_parity.rs since they need `make
    // artifacts` to have run. Here: manifest-level units.

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join("aipso_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"train_sample": 16384, "predict_batch": 65536, "n_leaves": 1024,
                "functions": {"rmi_train": {"file": "t.hlo.txt"},
                              "rmi_predict": {"file": "p.hlo.txt"}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.train_sample, 16384);
        assert_eq!(m.predict_batch, 65536);
        assert_eq!(m.n_leaves, 1024);
        assert!(m.train_file.ends_with("t.hlo.txt"));
    }

    #[test]
    fn manifest_missing_dir_errors() {
        let err = Manifest::load(Path::new("/nonexistent/nowhere")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn default_dir_env_override() {
        let d = default_artifacts_dir();
        assert!(d.ends_with("artifacts") || d.is_absolute());
    }
}
