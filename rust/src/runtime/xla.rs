//! Stub of the `xla_extension` FFI surface the runtime compiles against.
//!
//! The real backend (PJRT CPU client + HLO text parser) is an optional
//! native library that is not present in offline builds, and Cargo has no
//! way to fetch it here. This stub keeps the whole runtime layer — the
//! manifest loader, artifact paths, batching/padding logic and its tests —
//! compiling and testable; every entry point that would touch XLA returns
//! a descriptive error instead, which the callers already treat as
//! "artifacts unavailable" (`rust/tests/pjrt_parity.rs` skips, `aipso
//! artifacts-check` reports the load failure). Swapping this module for
//! the real `xla` crate restores the hardware path without touching
//! `runtime/mod.rs`.

use std::fmt;
use std::path::Path;

/// Error from the (absent) XLA backend. Implements `std::error::Error`, so
/// `?` converts it into the crate's context-chained [`crate::util::error::Error`].
#[derive(Debug, Clone)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

/// Result alias over [`XlaError`].
pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: XLA/PJRT backend not available (offline build without \
         xla_extension; the native RMI mirror in `rmi::` is the supported path)"
    ))
}

/// Parsed HLO module (stub: retains nothing).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text (stub: always "backend not available").
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        // Even reading the file would be pointless without a compiler for
        // it; fail up front so load() reports one coherent error.
        Err(unavailable(&format!("parsing {}", path.display())))
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module (stub: carries nothing).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU client (stub: always "backend not available").
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("creating PJRT CPU client"))
    }

    /// Compile a computation (stub: always "backend not available").
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling computation"))
    }
}

/// Compiled executable handle (stub; unreachable since `cpu()` errors).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on device buffers (stub: always "backend not available").
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy to host (stub: always "backend not available").
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("transferring buffer"))
    }
}

/// Host literal (stub).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 f64 literal (stub: carries nothing).
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal
    }

    /// Reshape (stub: no-op).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Destructure a 1-tuple (stub: always "backend not available").
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("destructuring tuple"))
    }

    /// Destructure a 2-tuple (stub: always "backend not available").
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(unavailable("destructuring tuple"))
    }

    /// Read out as a host vector (stub: always "backend not available").
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("reading literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_descriptive_errors() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file(Path::new("x.hlo.txt")).is_err());
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_vec::<f64>().is_err());
    }
}
