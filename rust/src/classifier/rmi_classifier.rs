//! The learned classifier of AIPS²o: a monotonic RMI evaluated as
//! `bucket = floor(F(x) * k)`.
//!
//! Because the RMI is monotone (see [`crate::rmi::model`]), the bucket map
//! is a valid ordered partition — exactly the "SampleSort with pivots
//! selected by a CDF model" of the paper's Section 3.3, with the pivots
//! left implicit (Section 3.2's insight: using the model directly skips
//! the comparisons entirely).

use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::rmi::model::Rmi;

/// Learned bucket classifier: a monotonic RMI scaled to `n_buckets`.
#[derive(Debug, Clone)]
pub struct RmiClassifier {
    rmi: Rmi,
    n_buckets: usize,
    scale: f64,
}

impl RmiClassifier {
    /// Wrap a trained model as a `n_buckets`-way classifier.
    pub fn new(rmi: Rmi, n_buckets: usize) -> RmiClassifier {
        assert!(n_buckets >= 2);
        RmiClassifier {
            rmi,
            n_buckets,
            scale: n_buckets as f64,
        }
    }

    /// The underlying trained model.
    pub fn rmi(&self) -> &Rmi {
        &self.rmi
    }
}

impl<K: SortKey> Classifier<K> for RmiClassifier {
    fn num_buckets(&self) -> usize {
        self.n_buckets
    }

    #[inline(always)]
    fn classify(&self, key: K) -> usize {
        let b = (self.rmi.predict(key.to_f64()) * self.scale) as usize;
        if b >= self.n_buckets {
            self.n_buckets - 1
        } else {
            b
        }
    }

    fn is_equality_bucket(&self, _b: usize) -> bool {
        // The learned path has no equality buckets; Algorithm 5 routes
        // duplicate-heavy inputs to the decision tree instead.
        false
    }

    fn classify_batch(&self, keys: &[K], out: &mut [u32]) {
        debug_assert_eq!(keys.len(), out.len());
        // 8-wide branchless batches through the shared Rmi::predict_batch
        // (the same kernel the LearnedSort 2.0 fragmentation sweep uses).
        let mut kc = keys.chunks_exact(8);
        let mut oc = out.chunks_exact_mut(8);
        for (k8, o8) in (&mut kc).zip(&mut oc) {
            let mut xs = [0.0f64; 8];
            for (x, k) in xs.iter_mut().zip(k8.iter()) {
                *x = k.to_f64();
            }
            let ps = self.rmi.predict_batch(&xs);
            for (o, &p) in o8.iter_mut().zip(ps.iter()) {
                let b = (p * self.scale) as usize;
                let b = if b >= self.n_buckets { self.n_buckets - 1 } else { b };
                *o = b as u32;
            }
        }
        for (k, o) in kc.remainder().iter().zip(oc.into_remainder()) {
            *o = Classifier::<K>::classify(self, *k) as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmi::model::RmiConfig;
    use crate::util::rng::Xoshiro256pp;

    fn classifier(n_buckets: usize) -> RmiClassifier {
        let mut rng = Xoshiro256pp::new(11);
        let mut sample: Vec<f64> = (0..8192).map(|_| rng.uniform(0.0, 1e6)).collect();
        sample.sort_unstable_by(f64::total_cmp);
        let rmi = Rmi::train(&sample, RmiConfig { n_leaves: 256 });
        RmiClassifier::new(rmi, n_buckets)
    }

    #[test]
    fn buckets_in_range_and_monotone() {
        let c = classifier(1024);
        let mut prev = 0usize;
        for i in 0..2000 {
            let x = i as f64 * 500.0;
            let b = Classifier::<f64>::classify(&c, x);
            assert!(b < 1024);
            assert!(b >= prev, "bucket map must be monotone");
            prev = b;
        }
    }

    #[test]
    fn balanced_on_uniform() {
        let c = classifier(64);
        let mut rng = Xoshiro256pp::new(12);
        let mut counts = vec![0usize; 64];
        for _ in 0..64_000 {
            let b = Classifier::<f64>::classify(&c, rng.uniform(0.0, 1e6));
            counts[b] += 1;
        }
        // uniform + good model: no bucket more than 3x the mean
        let max = *counts.iter().max().unwrap();
        assert!(max < 3 * 1000, "worst bucket {max}");
    }

    #[test]
    fn batch_matches_scalar() {
        let c = classifier(128);
        let mut rng = Xoshiro256pp::new(13);
        let keys: Vec<f64> = (0..517).map(|_| rng.uniform(-1e5, 2e6)).collect();
        let mut out = vec![0u32; keys.len()];
        c.classify_batch(&keys, &mut out);
        for (k, o) in keys.iter().zip(&out) {
            assert_eq!(*o as usize, Classifier::<f64>::classify(&c, *k));
        }
    }

    #[test]
    fn u64_keys_via_embedding() {
        let mut rng = Xoshiro256pp::new(14);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_below(1 << 48)).collect();
        let rmi = Rmi::train_from_keys(&keys, 1024, RmiConfig { n_leaves: 128 }, &mut rng);
        let c = RmiClassifier::new(rmi, 256);
        let b_lo = Classifier::<u64>::classify(&c, 0u64);
        let b_hi = Classifier::<u64>::classify(&c, (1u64 << 48) - 1);
        assert!(b_lo <= b_hi);
        assert!(b_hi > 128, "top key should map near the top bucket");
    }
}
