//! Branchless k-ary splitter tree with equality buckets — the classifier
//! of Super Scalar SampleSort (Sanders & Winkel '04) as engineered in
//! IPS⁴o (Axtmann et al., TOPC '22).
//!
//! The `k-1` sorted splitters are stored twice: once in Eytzinger (BFS)
//! layout for the branchless descent `j = 2j + (key > tree[j])`, and once
//! sorted for the equality probe. Keys compare via their order-preserving
//! `u64` image, so the descent is a pure integer pipeline (no float
//! branches) — the "super scalar" part.
//!
//! Equality buckets (IPS⁴o §5.3): when the sample shows duplicated
//! splitters, each base bucket `b` splits into `2b` (strictly-between keys)
//! and `2b+1` (keys equal to splitter `s_b`). Equality buckets are already
//! sorted and are skipped by the recursion — this is what defeats the
//! RootDups adversary.

use crate::classifier::Classifier;
use crate::key::SortKey;

/// IPS⁴o's branchless splitter-tree classifier with equality buckets.
#[derive(Debug, Clone)]
pub struct DecisionTree<K: SortKey> {
    /// Eytzinger-layout splitter images, indices 1..k (index 0 unused).
    tree: Vec<u64>,
    /// Sorted splitter images, for the equality probe.
    sorted: Vec<u64>,
    /// Sorted splitter keys (original domain), for diagnostics.
    splitters: Vec<K>,
    log_k: u32,
    equality_buckets: bool,
}

impl<K: SortKey> DecisionTree<K> {
    /// Build from a **sorted** sample. `target_buckets` is the desired
    /// fan-out (power of two, >= 2); the real fan-out shrinks if the sample
    /// has fewer distinct splitter candidates. Equality buckets switch on
    /// automatically when the sample contains duplicated splitters —
    /// IPS⁴o's skew detection.
    pub fn from_sorted_sample(sample: &[K], target_buckets: usize) -> DecisionTree<K> {
        assert!(target_buckets >= 2);
        let k = target_buckets.next_power_of_two();
        // Equidistant splitter candidates from the sample.
        let mut cands: Vec<u64> = Vec::with_capacity(k - 1);
        if !sample.is_empty() {
            for i in 1..k {
                let idx = i * sample.len() / k;
                cands.push(sample[idx.min(sample.len() - 1)].to_bits_ordered());
            }
        }
        let had_dups = cands.windows(2).any(|w| w[0] == w[1]);
        cands.dedup();
        // Shrink fan-out to the next power of two that the distinct
        // candidates can fill.
        let mut k_eff = k;
        while k_eff > 2 && cands.len() < k_eff - 1 {
            k_eff /= 2;
        }
        let splitters_bits: Vec<u64> = if cands.len() >= k_eff {
            // re-pick equidistant among distinct candidates
            (1..k_eff)
                .map(|i| cands[i * cands.len() / k_eff])
                .collect()
        } else {
            cands.clone()
        };
        // Pad (rare: fewer distinct than k_eff-1) by repeating the last.
        let mut bits = splitters_bits;
        if bits.is_empty() {
            bits.push(sample.first().map(|s| s.to_bits_ordered()).unwrap_or(0));
        }
        while bits.len() < k_eff - 1 {
            let last = *bits.last().unwrap();
            bits.push(last);
        }

        let log_k = k_eff.trailing_zeros();
        let mut tree = vec![0u64; k_eff];
        Self::fill_eytzinger(&mut tree, &bits, 1, &mut 0);
        let splitters = bits.iter().map(|&b| K::from_bits_ordered(b)).collect();
        DecisionTree {
            tree,
            sorted: bits,
            splitters,
            log_k,
            equality_buckets: had_dups,
        }
    }

    /// In-order fill of the Eytzinger array from the sorted splitters.
    fn fill_eytzinger(tree: &mut [u64], sorted: &[u64], node: usize, next: &mut usize) {
        if node >= tree.len() {
            return;
        }
        Self::fill_eytzinger(tree, sorted, 2 * node, next);
        tree[node] = sorted[(*next).min(sorted.len() - 1)];
        *next += 1;
        Self::fill_eytzinger(tree, sorted, 2 * node + 1, next);
    }

    /// Base fan-out k (number of non-equality buckets).
    pub fn fanout(&self) -> usize {
        self.tree.len()
    }

    /// Whether duplicated splitters switched equality buckets on.
    pub fn equality_buckets_enabled(&self) -> bool {
        self.equality_buckets
    }

    /// The sorted splitters in the original key domain.
    pub fn splitters(&self) -> &[K] {
        &self.splitters
    }

    /// Force equality buckets on/off (tests + Algorithm 5 tuning).
    pub fn set_equality_buckets(&mut self, on: bool) {
        self.equality_buckets = on;
    }

    /// Branchless descent: bucket = |{ s_i < key }|.
    #[inline(always)]
    fn base_bucket(&self, bits: u64) -> usize {
        let mut j = 1usize;
        for _ in 0..self.log_k {
            // SAFETY: j < k_eff by construction (log_k descents from 1).
            let s = unsafe { *self.tree.get_unchecked(j) };
            j = 2 * j + usize::from(bits > s);
        }
        j - self.tree.len()
    }
}

impl<K: SortKey> Classifier<K> for DecisionTree<K> {
    fn num_buckets(&self) -> usize {
        if self.equality_buckets {
            2 * self.fanout()
        } else {
            self.fanout()
        }
    }

    #[inline(always)]
    fn classify(&self, key: K) -> usize {
        let bits = key.to_bits_ordered();
        let b = self.base_bucket(bits);
        if !self.equality_buckets {
            return b;
        }
        // keys equal to splitter s_b go to the equality bucket 2b+1;
        // bucket b holds keys in (s_{b-1}, s_b], so only s_b can be equal.
        let eq = b < self.sorted.len() && bits == self.sorted[b];
        2 * b + usize::from(eq)
    }

    fn is_equality_bucket(&self, b: usize) -> bool {
        self.equality_buckets && b % 2 == 1
    }

    fn classify_batch(&self, keys: &[K], out: &mut [u32]) {
        debug_assert_eq!(keys.len(), out.len());
        // 4-way unroll keeps several independent descents in flight —
        // the instruction-level parallelism Super Scalar SampleSort is
        // named for.
        let mut chunks = keys.chunks_exact(4);
        let mut outs = out.chunks_exact_mut(4);
        for (kc, oc) in (&mut chunks).zip(&mut outs) {
            oc[0] = self.classify(kc[0]) as u32;
            oc[1] = self.classify(kc[1]) as u32;
            oc[2] = self.classify(kc[2]) as u32;
            oc[3] = self.classify(kc[3]) as u32;
        }
        for (k, o) in chunks.remainder().iter().zip(outs.into_remainder()) {
            *o = self.classify(*k) as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree_from(vals: &[u64], buckets: usize) -> DecisionTree<u64> {
        let mut s = vals.to_vec();
        s.sort_unstable();
        DecisionTree::from_sorted_sample(&s, buckets)
    }

    #[test]
    fn bucket_is_count_of_smaller_splitters() {
        // distinct sample 0..64, 8 buckets
        let sample: Vec<u64> = (0..64).collect();
        let t = DecisionTree::from_sorted_sample(&sample, 8);
        assert_eq!(t.fanout(), 8);
        assert!(!t.equality_buckets_enabled());
        for key in 0..70u64 {
            let want = t.sorted.iter().filter(|&&s| s < key).count();
            assert_eq!(t.classify(key), want, "key={key}");
        }
    }

    #[test]
    fn buckets_are_ordered_partition() {
        let mut rng = crate::util::rng::Xoshiro256pp::new(2);
        let mut sample: Vec<u64> = (0..4096).map(|_| rng.next_below(1 << 30)).collect();
        sample.sort_unstable();
        let t = DecisionTree::from_sorted_sample(&sample, 64);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_below(1 << 30)).collect();
        // max key of bucket b must be <= min key of bucket b+1
        let nb = t.num_buckets();
        let mut lo = vec![u64::MAX; nb];
        let mut hi = vec![0u64; nb];
        for &k in &keys {
            let b = t.classify(k);
            lo[b] = lo[b].min(k);
            hi[b] = hi[b].max(k);
        }
        let mut last_hi = 0u64;
        for b in 0..nb {
            if lo[b] == u64::MAX {
                continue;
            }
            assert!(lo[b] >= last_hi, "bucket {b} overlaps previous");
            last_hi = hi[b];
        }
    }

    #[test]
    fn equality_buckets_catch_duplicates() {
        // sample dominated by value 5 -> duplicated splitters -> equality on
        let mut vals = vec![5u64; 1000];
        vals.extend(0..10u64);
        vals.sort_unstable();
        let t = DecisionTree::from_sorted_sample(&vals, 16);
        assert!(t.equality_buckets_enabled());
        let b5 = t.classify(5);
        assert!(t.is_equality_bucket(b5), "5 must land in an equality bucket");
        // all copies land in the same bucket
        assert_eq!(t.classify(5), b5);
        // neighbors land elsewhere
        assert_ne!(t.classify(4), b5);
        assert_ne!(t.classify(6), b5);
    }

    #[test]
    fn f64_keys_work() {
        let mut sample: Vec<f64> = (0..1024).map(|i| (i as f64) - 512.0).collect();
        sample.sort_unstable_by(f64::total_cmp);
        let t = DecisionTree::from_sorted_sample(&sample, 32);
        let lo = t.classify(-600.0);
        let mid = t.classify(0.0);
        let hi = t.classify(600.0);
        assert!(lo <= mid && mid <= hi);
        assert_eq!(lo, 0);
        assert_eq!(hi, t.num_buckets() - 1);
    }

    #[test]
    fn tiny_and_degenerate_samples() {
        // single-value sample: tree still classifies
        let t = tree_from(&[42], 256);
        assert!(t.num_buckets() >= 2);
        let a = t.classify(41);
        let b = t.classify(42);
        let c = t.classify(43);
        assert!(a <= b && b <= c);
        // empty sample
        let t = DecisionTree::<u64>::from_sorted_sample(&[], 8);
        let _ = t.classify(7);
    }

    #[test]
    fn classify_batch_matches_scalar() {
        let mut rng = crate::util::rng::Xoshiro256pp::new(9);
        let mut sample: Vec<u64> = (0..512).map(|_| rng.next_below(1000)).collect();
        sample.sort_unstable();
        let t = DecisionTree::from_sorted_sample(&sample, 16);
        let keys: Vec<u64> = (0..1003).map(|_| rng.next_below(1000)).collect();
        let mut out = vec![0u32; keys.len()];
        t.classify_batch(&keys, &mut out);
        for (k, o) in keys.iter().zip(&out) {
            assert_eq!(*o as usize, t.classify(*k));
        }
    }

    #[test]
    fn fanout_shrinks_with_few_distinct() {
        let t = tree_from(&[1, 2, 3], 256);
        assert!(t.fanout() <= 8, "fanout {} too big for 3 distinct", t.fanout());
    }
}
