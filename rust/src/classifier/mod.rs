//! Bucket classifiers (substrate S3).
//!
//! A classifier maps a key to one of `k` buckets such that all keys of
//! bucket `i` order before all keys of bucket `i+1` (equality buckets
//! excepted — they hold exactly one value). Two implementations:
//!
//! * [`decision_tree::DecisionTree`] — IPS⁴o's branchless Eytzinger-layout
//!   splitter tree with optional equality buckets.
//! * [`rmi_classifier::RmiClassifier`] — AIPS²o's learned classifier: the
//!   monotonic RMI evaluated as `floor(F(x) * k)`.

pub mod decision_tree;
pub mod rmi_classifier;

use crate::key::SortKey;

/// Common interface the partitioning framework consumes.
pub trait Classifier<K: SortKey>: Send + Sync {
    /// Total number of buckets (including equality buckets).
    fn num_buckets(&self) -> usize;

    /// Bucket index for one key, in `0..num_buckets()`.
    fn classify(&self, key: K) -> usize;

    /// True if bucket `b` holds exactly one distinct value (already sorted,
    /// recursion can skip it).
    fn is_equality_bucket(&self, b: usize) -> bool;

    /// Batch classification (engines call this on the hot path; impls
    /// override with unrolled versions).
    fn classify_batch(&self, keys: &[K], out: &mut [u32]) {
        debug_assert_eq!(keys.len(), out.len());
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = self.classify(*k) as u32;
        }
    }
}
