//! The LearnedSort 2.0 in-place fragmented-bucket partition (Kristo,
//! Vaidya & Kraska, "Defeating duplicates", arXiv 2107.03290, §3).
//!
//! LearnedSort 1.x gave every bucket a fixed capacity and overflowed the
//! excess into a *spill bucket* that was comparison-sorted at the end —
//! on duplicate-heavy inputs most keys land in few buckets, the spill
//! grows to Θ(n), and the algorithm collapses to `std::sort`. The 2.0
//! re-design emulates **variable-size buckets** instead: the predicted
//! keys stream through small per-bucket buffers, and every full buffer
//! is flushed as a *fragment* over the already-consumed prefix of the
//! input array. A bucket owns a chain of fragments scattered through the
//! array; a compaction pass then reassembles the chains into contiguous
//! buckets, in bucket order. No bucket can overflow, so there is no
//! spill bucket to collapse into.
//!
//! Layout during the fragmentation sweep (`F` = fragment size):
//!
//! ```text
//!           0        F        2F       3F        read              n
//!           +--------+--------+--------+----//----+----------------+
//!   data    | frag 0 | frag 1 | frag 2 |  free    |   unconsumed   |
//!           | (b=4)  | (b=1)  | (b=4)  |          |                |
//!           +--------+--------+--------+----//----+----------------+
//!   chains: bucket 1 -> [frag 1]     bucket 4 -> [frag 0, frag 2]
//!   buffers: per-bucket partial fills (< F keys each)
//! ```
//!
//! The flush target never overtakes the read cursor: after `r` keys are
//! consumed, `flushed·F = r − buffered` and a flush requires `buffered ≥
//! F`, so `flushed·F + F ≤ r` — fragments only ever overwrite input that
//! has already been copied out. Auxiliary memory is the per-bucket
//! buffers (`nb·F` keys) plus one `u32` per fragment (`n/F`), a small
//! fraction of the input for the default `F = 128`.
//!
//! Duplicates get **equality buckets** instead of a spill: values that
//! dominate the training sample are promoted by [`EqRmiClassifier`] into
//! dedicated single-value buckets spliced between the model buckets (so
//! the partition stays an ordered partition), and the recursion skips
//! them — an all-equal bucket is already sorted.
//!
//! The classification sweep is batched: [`Rmi::predict_batch`] evaluates
//! [`PREDICT_BATCH`] keys per loop iteration (independent model
//! evaluations pipeline without data-dependent branches) and the flush
//! targets are software-prefetched on x86-64.

use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::rmi::model::Rmi;
use crate::util::timer::{phase_scope, Phase};

/// Keys classified per hot-loop iteration in the fragmentation sweep.
pub const PREDICT_BATCH: usize = 16;

/// Result of a fragmented partition: `boundaries[b]..boundaries[b+1]`
/// holds bucket `b`, exactly sized (variable-size buckets — no spill).
#[derive(Debug, Clone)]
pub struct FragPartition {
    /// `num_buckets + 1` cumulative bucket boundaries over the input.
    pub boundaries: Vec<usize>,
}

/// Partition `data` in place into `classifier.num_buckets()` variable-size
/// buckets with the LearnedSort 2.0 fragment scheme: classify in batches
/// into per-bucket buffers of `frag` keys, flush full buffers as fragments
/// over the consumed prefix, then compact the fragment chains into
/// contiguous buckets in bucket order.
pub fn fragmented_partition<K: SortKey, C: Classifier<K> + ?Sized>(
    data: &mut [K],
    classifier: &C,
    frag: usize,
) -> FragPartition {
    let n = data.len();
    let nb = classifier.num_buckets();
    assert!(nb >= 2, "need at least two buckets");
    assert!(frag >= 1, "fragment size must be positive");
    let mut boundaries = vec![0usize; nb + 1];
    if n == 0 {
        return FragPartition { boundaries };
    }

    // ---- Fragmentation sweep: classify + flush full buffers ----------
    let mut buffers: Vec<K> = vec![data[0]; nb * frag];
    let mut lens: Vec<u32> = vec![0u32; nb];
    // fragment chain, in flush order: fragment f sits at data[f*frag..]
    // and belongs to bucket frag_bucket[f]
    let mut frag_bucket: Vec<u32> = Vec::with_capacity(n / frag + 1);
    {
        let _p = phase_scope(Phase::Classification);
        let _s = crate::obs::enabled()
            .then(|| crate::obs::trace::span_n(crate::obs::S_FRAG_PARTITION, n as u64, 0));
        fragment_sweep(data, classifier, frag, &mut buffers, &mut lens, &mut frag_bucket);
    }

    // ---- Compaction: reassemble fragment chains in bucket order ------
    {
        let _p = phase_scope(Phase::Cleanup);
        let _s = crate::obs::enabled()
            .then(|| crate::obs::trace::span_n(crate::obs::S_FRAG_COMPACT, n as u64, 0));
        let nf = frag_bucket.len();
        let mut fcnt = vec![0usize; nb];
        for &b in &frag_bucket {
            fcnt[b as usize] += 1;
        }
        // fragment-slot prefix sums: bucket b's fragments belong in slots
        // fstart[b]..fstart[b+1] once the chains are gathered
        let mut fstart = vec![0usize; nb + 1];
        for b in 0..nb {
            fstart[b + 1] = fstart[b] + fcnt[b];
        }
        // destination slot of every fragment (chain order preserved)
        let mut next = fstart.clone();
        let mut dest = vec![0u32; nf];
        for (f, &b) in frag_bucket.iter().enumerate() {
            dest[f] = next[b as usize] as u32;
            next[b as usize] += 1;
        }
        // apply the slot permutation by following its cycles: lift one
        // fragment, then keep displacing the occupant of its destination
        // until the cycle closes — every fragment moves exactly once
        if nf > 0 {
            let mut placed = vec![false; nf];
            let mut hold: Vec<K> = vec![data[0]; frag];
            let mut disp: Vec<K> = vec![data[0]; frag];
            for s in 0..nf {
                if placed[s] || dest[s] as usize == s {
                    placed[s] = true;
                    continue;
                }
                hold.copy_from_slice(&data[s * frag..(s + 1) * frag]);
                let mut cur = s;
                loop {
                    let d = dest[cur] as usize;
                    if d == s {
                        data[s * frag..(s + 1) * frag].copy_from_slice(&hold);
                        break;
                    }
                    disp.copy_from_slice(&data[d * frag..(d + 1) * frag]);
                    data[d * frag..(d + 1) * frag].copy_from_slice(&hold);
                    std::mem::swap(&mut hold, &mut disp);
                    placed[d] = true;
                    cur = d;
                }
                placed[s] = true;
            }
        }
        // exact variable-size boundaries (fragments + partial buffer)
        for b in 0..nb {
            boundaries[b + 1] = boundaries[b] + fcnt[b] * frag + lens[b] as usize;
        }
        debug_assert_eq!(boundaries[nb], n);
        // shift each bucket's gathered fragment block right onto its final
        // (unaligned) offset and append the partial buffer. Every source
        // start is ≤ its destination (slots undercount by the partials of
        // lower buckets), so walking right-to-left never clobbers an
        // unmoved block; the self-overlapping move is a `copy_within`.
        for b in (0..nb).rev() {
            let src = fstart[b] * frag;
            let flen = fcnt[b] * frag;
            let dst = boundaries[b];
            debug_assert!(src <= dst);
            if flen > 0 && src != dst {
                data.copy_within(src..src + flen, dst);
            }
            let plen = lens[b] as usize;
            data[dst + flen..dst + flen + plen]
                .copy_from_slice(&buffers[b * frag..b * frag + plen]);
        }
    }
    FragPartition { boundaries }
}

/// The fragmentation sweep shared by the sequential partition and the
/// per-thread stripes of the parallel formulation
/// ([`super::partition2_par`]): classify `data` in [`PREDICT_BATCH`]
/// batches into the per-bucket `buffers` (`num_buckets · frag` keys,
/// fill levels in `lens`), flushing every full buffer as a fragment over
/// the consumed prefix of `data` and recording its owning bucket in
/// `frag_bucket` — fragment `j` ends up at `data[j * frag..]`. The flush
/// cursor never overtakes the read cursor (see the module docs), so the
/// sweep is safe on any slice, including a stripe of a larger array.
pub(super) fn fragment_sweep<K: SortKey, C: Classifier<K> + ?Sized>(
    data: &mut [K],
    classifier: &C,
    frag: usize,
    buffers: &mut [K],
    lens: &mut [u32],
    frag_bucket: &mut Vec<u32>,
) {
    let n = data.len();
    let mut idx = [0u32; PREDICT_BATCH];
    let mut read = 0usize;
    while read < n {
        let m = PREDICT_BATCH.min(n - read);
        classifier.classify_batch(&data[read..read + m], &mut idx[..m]);
        prefetch_targets(buffers, lens, &idx[..m], frag);
        for (i, &bu) in idx[..m].iter().enumerate() {
            let b = bu as usize;
            let key = data[read + i];
            let len = lens[b] as usize;
            buffers[b * frag + len] = key;
            if len + 1 == frag {
                let dst = frag_bucket.len() * frag;
                // the flush target lies inside the consumed prefix
                debug_assert!(dst + frag <= read + i + 1);
                data[dst..dst + frag].copy_from_slice(&buffers[b * frag..(b + 1) * frag]);
                frag_bucket.push(b as u32);
                lens[b] = 0;
            } else {
                lens[b] = (len + 1) as u32;
            }
        }
        read += m;
    }
}

/// Software-prefetch the buffer slots an incoming batch will write
/// (x86-64 only; a no-op hint elsewhere and under Miri).
#[inline]
fn prefetch_targets<K>(buffers: &[K], lens: &[u32], idx: &[u32], frag: usize) {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        for &b in idx {
            let slot = b as usize * frag + lens[b as usize] as usize;
            // SAFETY: prefetch is a cache hint and never dereferences;
            // `slot < nb*frag = buffers.len()` keeps the address in-bounds.
            unsafe { _mm_prefetch::<{ _MM_HINT_T0 }>(buffers.as_ptr().add(slot) as *const i8) };
        }
    }
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    {
        let _ = (buffers, lens, idx, frag);
    }
}

/// Heavy duplicate values found in a sorted training sample: the ordered
/// bit pattern (classifier comparison domain) and the f64 model embedding
/// of each, ascending.
pub type HeavyValues = Vec<(u64, f64)>;

/// Scan a **sorted** sample for values heavy enough to deserve equality
/// buckets: a run whose expected mass covers ≥ 2 of `model_buckets`
/// average-sized buckets would dominate its bucket and recurse uselessly.
/// Returns at most `max_heavy` values (the heaviest), ascending.
pub fn detect_heavy<K: SortKey>(
    sample_sorted: &[K],
    model_buckets: usize,
    max_heavy: usize,
) -> HeavyValues {
    let n = sample_sorted.len();
    if n == 0 || max_heavy == 0 {
        return Vec::new();
    }
    let mut runs: Vec<(usize, u64, f64)> = Vec::new();
    let mut start = 0usize;
    let mut bits = sample_sorted[0].to_bits_ordered();
    for i in 1..=n {
        let b = if i < n {
            sample_sorted[i].to_bits_ordered()
        } else {
            !bits // sentinel differing from the current run
        };
        if b != bits {
            let len = i - start;
            // run mass ≥ 2 average buckets ⇔ len · B ≥ 2 · n
            if len * model_buckets >= 2 * n {
                runs.push((len, bits, sample_sorted[start].to_f64()));
            }
            start = i;
            bits = b;
        }
    }
    // keep the heaviest, then restore value order for the classifier
    runs.sort_unstable_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    runs.truncate(max_heavy);
    runs.sort_unstable_by_key(|r| r.1);
    runs.into_iter().map(|(_, b, e)| (b, e)).collect()
}

/// A monotone RMI bucket map with **equality buckets** spliced in for
/// heavy duplicate values (LearnedSort 2.0's replacement for the spill
/// bucket).
///
/// Each of the `model_buckets` RMI buckets that contains `k` heavy values
/// is split into `2k + 1` final buckets: regular segment, equality bucket
/// for the first heavy value, regular segment, … — so the final map is
/// still an ordered partition (keys of bucket `i` order before keys of
/// bucket `i+1`) and every equality bucket holds exactly one value.
#[derive(Debug, Clone)]
pub struct EqRmiClassifier {
    rmi: Rmi,
    model_buckets: usize,
    scale: f64,
    /// Heavy values in ordered-bits domain, ascending; the slice with
    /// model bucket `m` is `extra_before[m]/2 .. extra_before[m+1]/2`.
    heavy_bits: Vec<u64>,
    /// `extra_before[m]` = final-bucket inflation before model bucket
    /// `m`, i.e. `2 ·` (heavy values in model buckets `< m`).
    extra_before: Vec<u32>,
    /// Per final bucket: is it an equality bucket?
    eq_flag: Vec<bool>,
    /// Per final bucket: the model bucket it was split from.
    model_of: Vec<u32>,
}

impl EqRmiClassifier {
    /// Wrap a trained model as a `model_buckets`-way classifier with
    /// equality buckets for `heavy` (as returned by [`detect_heavy`]:
    /// `(ordered_bits, f64_embedding)` pairs, ascending).
    pub fn new(rmi: Rmi, model_buckets: usize, heavy: &[(u64, f64)]) -> EqRmiClassifier {
        assert!(model_buckets >= 2);
        let scale = model_buckets as f64;
        let mut per_bucket = vec![0u32; model_buckets];
        let mut heavy_bits = Vec::with_capacity(heavy.len());
        let mut heavy_model = Vec::with_capacity(heavy.len());
        let mut prev_m = 0usize;
        for &(bits, embed) in heavy {
            let m = bucket_of(rmi.predict(embed), scale, model_buckets);
            // ascending values + monotone model ⇒ nondecreasing buckets
            debug_assert!(m >= prev_m);
            prev_m = m;
            per_bucket[m] += 1;
            heavy_bits.push(bits);
            heavy_model.push(m);
        }
        let mut extra_before = vec![0u32; model_buckets + 1];
        for m in 0..model_buckets {
            extra_before[m + 1] = extra_before[m] + 2 * per_bucket[m];
        }
        let total = model_buckets + 2 * heavy.len();
        let mut eq_flag = vec![false; total];
        for (i, &m) in heavy_model.iter().enumerate() {
            let within = i - (extra_before[m] / 2) as usize;
            eq_flag[m + extra_before[m] as usize + 2 * within + 1] = true;
        }
        let mut model_of = vec![0u32; total];
        for m in 0..model_buckets {
            let lo = m + extra_before[m] as usize;
            let hi = m + extra_before[m + 1] as usize;
            for slot in model_of.iter_mut().take(hi + 1).skip(lo) {
                *slot = m as u32;
            }
        }
        EqRmiClassifier {
            rmi,
            model_buckets,
            scale,
            heavy_bits,
            extra_before,
            eq_flag,
            model_of,
        }
    }

    /// The underlying trained model.
    pub fn rmi(&self) -> &Rmi {
        &self.rmi
    }

    /// Total final buckets (model buckets + 2 per heavy value).
    pub fn total_buckets(&self) -> usize {
        self.model_buckets + 2 * self.heavy_bits.len()
    }

    /// Whether final bucket `b` is a single-value equality bucket.
    pub fn is_eq_bucket(&self, b: usize) -> bool {
        self.eq_flag[b]
    }

    /// CDF range `[lo, hi)` of the model bucket that final bucket `b`
    /// was split from — the rescaling window for the second round.
    pub fn model_range(&self, b: usize) -> (f64, f64) {
        let m = self.model_of[b] as f64;
        (m / self.scale, (m + 1.0) / self.scale)
    }

    /// Final bucket from a model prediction `p` and the key's ordered
    /// bits: splice the key around the heavy values of its model bucket.
    #[inline]
    fn classify_embedded(&self, p: f64, kb: u64) -> usize {
        let m = bucket_of(p, self.scale, self.model_buckets);
        let mut idx = m + self.extra_before[m] as usize;
        let lo = (self.extra_before[m] / 2) as usize;
        let hi = (self.extra_before[m + 1] / 2) as usize;
        for &hb in &self.heavy_bits[lo..hi] {
            if kb > hb {
                idx += 2;
            } else if kb == hb {
                return idx + 1;
            } else {
                break;
            }
        }
        idx
    }
}

/// `floor(p · scale)` clamped into `0..nb`.
#[inline(always)]
fn bucket_of(p: f64, scale: f64, nb: usize) -> usize {
    let b = (p * scale) as usize;
    if b >= nb {
        nb - 1
    } else {
        b
    }
}

impl<K: SortKey> Classifier<K> for EqRmiClassifier {
    fn num_buckets(&self) -> usize {
        self.total_buckets()
    }

    #[inline(always)]
    fn classify(&self, key: K) -> usize {
        self.classify_embedded(self.rmi.predict(key.to_f64()), key.to_bits_ordered())
    }

    fn is_equality_bucket(&self, b: usize) -> bool {
        self.eq_flag[b]
    }

    fn classify_batch(&self, keys: &[K], out: &mut [u32]) {
        debug_assert_eq!(keys.len(), out.len());
        let mut kc = keys.chunks_exact(8);
        let mut oc = out.chunks_exact_mut(8);
        for (k8, o8) in (&mut kc).zip(&mut oc) {
            let mut xs = [0.0f64; 8];
            for (x, k) in xs.iter_mut().zip(k8.iter()) {
                *x = k.to_f64();
            }
            let ps = self.rmi.predict_batch(&xs);
            for ((o, &p), k) in o8.iter_mut().zip(ps.iter()).zip(k8.iter()) {
                *o = self.classify_embedded(p, k.to_bits_ordered()) as u32;
            }
        }
        for (k, o) in kc.remainder().iter().zip(oc.into_remainder()) {
            *o = Classifier::<K>::classify(self, *k) as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rmi::model::RmiConfig;
    use crate::util::rng::Xoshiro256pp;

    /// Fixed-step range classifier: bucket = key / step (monotone).
    struct StepClassifier {
        nb: usize,
        step: u64,
    }

    impl Classifier<u64> for StepClassifier {
        fn num_buckets(&self) -> usize {
            self.nb
        }

        fn classify(&self, key: u64) -> usize {
            ((key / self.step) as usize).min(self.nb - 1)
        }

        fn is_equality_bucket(&self, _b: usize) -> bool {
            false
        }
    }

    fn check_partition(data: &[u64], c: &StepClassifier, frag: usize) {
        let mut v = data.to_vec();
        let r = fragmented_partition(&mut v, c, frag);
        // permutation: same multiset
        let mut got = v.clone();
        let mut want = data.to_vec();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "frag={frag} n={}", data.len());
        // boundaries cover and respect the bucket map
        assert_eq!(r.boundaries[0], 0);
        assert_eq!(*r.boundaries.last().unwrap(), data.len());
        for b in 0..c.nb {
            for &k in &v[r.boundaries[b]..r.boundaries[b + 1]] {
                assert_eq!(Classifier::<u64>::classify(c, k), b, "key {k} in bucket {b}");
            }
        }
    }

    #[test]
    fn partitions_exactly_with_fragment_chains() {
        let c = StepClassifier { nb: 8, step: 100 };
        let mut rng = Xoshiro256pp::new(21);
        for n in [0usize, 1, 2, 3, 7, 64, 100, 257, 1024, 4096] {
            let data: Vec<u64> = (0..n).map(|_| rng.next_below(800)).collect();
            for frag in [1usize, 4, 16, 128] {
                check_partition(&data, &c, frag);
            }
        }
    }

    #[test]
    fn skewed_chains_and_empty_buckets() {
        let c = StepClassifier { nb: 8, step: 100 };
        let mut rng = Xoshiro256pp::new(22);
        // all keys in one middle bucket: one long chain, 7 empty buckets
        let data: Vec<u64> = vec![450; 999];
        check_partition(&data, &c, 16);
        // two-value input on the extreme buckets
        let data: Vec<u64> = (0..1000).map(|_| rng.next_below(2) * 799).collect();
        check_partition(&data, &c, 8);
        // already sorted and reverse sorted
        let data: Vec<u64> = (0..2000u64).map(|i| i % 800).collect();
        check_partition(&data, &c, 32);
        let data: Vec<u64> = (0..2000u64).rev().map(|i| i % 800).collect();
        check_partition(&data, &c, 32);
    }

    #[test]
    fn partial_buffers_only_no_flushes() {
        // n < frag: nothing is ever flushed; compaction assembles the
        // buckets purely from the partial buffers
        let c = StepClassifier { nb: 4, step: 25 };
        let data: Vec<u64> = vec![99, 0, 50, 26, 1, 75];
        check_partition(&data, &c, 64);
    }

    fn trained_rmi(sample: &mut Vec<f64>) -> Rmi {
        sample.sort_unstable_by(f64::total_cmp);
        Rmi::train(sample, RmiConfig { n_leaves: 64 })
    }

    #[test]
    fn detect_heavy_finds_dominant_runs() {
        // 60% of the sample is the value 7, 20% is 42
        let mut sample: Vec<f64> = vec![7.0; 600];
        sample.extend(vec![42.0f64; 200]);
        sample.extend((0..200).map(|i| i as f64 * 0.001));
        sample.sort_unstable_by(f64::total_cmp);
        let heavy = detect_heavy(&sample, 16, 8);
        let values: Vec<f64> = heavy.iter().map(|&(_, e)| e).collect();
        assert_eq!(values, vec![7.0, 42.0]);
        // ascending in the ordered-bits domain too
        assert!(heavy.windows(2).all(|w| w[0].0 < w[1].0));
        // a uniform sample has no heavy values
        let uni: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        assert!(detect_heavy(&uni, 16, 8).is_empty());
    }

    #[test]
    fn equality_classifier_is_an_ordered_partition() {
        let mut rng = Xoshiro256pp::new(23);
        let mut sample: Vec<f64> = Vec::new();
        for _ in 0..2000 {
            if rng.next_below(2) == 0 {
                sample.push(500.0);
            } else {
                sample.push(rng.uniform(0.0, 1000.0));
            }
        }
        let rmi = trained_rmi(&mut sample);
        let heavy = detect_heavy(&sample, 32, 8);
        assert!(heavy.iter().any(|&(_, e)| e == 500.0));
        let c = EqRmiClassifier::new(rmi, 32, &heavy);
        // the heavy value maps to an equality bucket
        let eq = Classifier::<f64>::classify(&c, 500.0f64);
        assert!(c.is_eq_bucket(eq));
        // bucket map is monotone over a sorted probe
        let mut probe: Vec<f64> = (0..4000).map(|_| rng.uniform(-10.0, 1010.0)).collect();
        probe.push(500.0);
        probe.sort_unstable_by(f64::total_cmp);
        let mut prev = 0usize;
        for &x in &probe {
            let b = Classifier::<f64>::classify(&c, x);
            assert!(b < c.total_buckets());
            assert!(b >= prev, "bucket map must stay monotone at {x}");
            prev = b;
        }
        // neighbors of the heavy value stay out of its equality bucket
        assert!(Classifier::<f64>::classify(&c, 499.999f64) < eq);
        assert!(Classifier::<f64>::classify(&c, 500.001f64) > eq);
        // model_range round-trips the split
        let (lo, hi) = c.model_range(eq);
        assert!(lo < hi && hi <= 1.0);
    }

    #[test]
    fn eq_classifier_batch_matches_scalar() {
        let mut rng = Xoshiro256pp::new(24);
        let mut sample: Vec<f64> = Vec::new();
        for _ in 0..1500 {
            if rng.next_below(3) == 0 {
                sample.push(250.0);
            } else {
                sample.push(rng.uniform(0.0, 1000.0));
            }
        }
        let rmi = trained_rmi(&mut sample);
        let heavy = detect_heavy(&sample, 16, 4);
        let c = EqRmiClassifier::new(rmi, 16, &heavy);
        let mut keys: Vec<f64> = Vec::new();
        for i in 0..533 {
            if i % 5 == 0 {
                keys.push(250.0);
            } else {
                keys.push(rng.uniform(-50.0, 1050.0));
            }
        }
        let mut out = vec![0u32; keys.len()];
        c.classify_batch(&keys, &mut out);
        for (k, o) in keys.iter().zip(&out) {
            assert_eq!(*o as usize, Classifier::<f64>::classify(&c, *k));
        }
    }

    #[test]
    fn fragmented_partition_with_equality_buckets() {
        let mut rng = Xoshiro256pp::new(25);
        let n = 4000;
        let draw = |rng: &mut Xoshiro256pp| {
            if rng.next_below(10) < 9 {
                123.0f64
            } else {
                rng.uniform(0.0, 1000.0)
            }
        };
        let mut sample: Vec<f64> = (0..1000).map(|_| draw(&mut rng)).collect();
        let rmi = trained_rmi(&mut sample);
        let heavy = detect_heavy(&sample, 8, 4);
        let c = EqRmiClassifier::new(rmi, 8, &heavy);
        let data: Vec<f64> = (0..n).map(|_| draw(&mut rng)).collect();
        let mut v = data.clone();
        let r = fragmented_partition(&mut v, &c, 32);
        let mut got: Vec<u64> = v.iter().map(|x| x.to_bits()).collect();
        let mut want: Vec<u64> = data.iter().map(|x| x.to_bits()).collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
        let nb = c.total_buckets();
        assert_eq!(r.boundaries.len(), nb + 1);
        for b in 0..nb {
            let bucket = &v[r.boundaries[b]..r.boundaries[b + 1]];
            for &k in bucket {
                assert_eq!(Classifier::<f64>::classify(&c, k), b);
            }
            if c.is_eq_bucket(b) {
                assert!(bucket.windows(2).all(|w| w[0] == w[1]));
            }
        }
        // ≥90% duplicates: the heavy value's equality bucket caught it
        let eq = Classifier::<f64>::classify(&c, 123.0f64);
        assert!(c.is_eq_bucket(eq));
        assert!(r.boundaries[eq + 1] - r.boundaries[eq] > n / 2);
    }
}
