//! Thread-parallel formulation of the LearnedSort 2.0 fragmented-bucket
//! partition ([`super::partition2`]).
//!
//! The parallelization follows the shape the paper inherits from IPS⁴o:
//! a cooperative fork-join classification phase over disjoint stripes of
//! the input, then a deterministic sequential reconciliation over the
//! per-thread metadata. Concretely:
//!
//! 1. **Stripe sweeps.** The input is cut into at most `threads`
//!    contiguous stripes whose starts are multiples of the fragment size
//!    `F` ([`crate::scheduler::aligned_ranges`]), so every stripe's flush
//!    targets land on the *global* `F`-aligned slot grid. Each worker
//!    runs the unmodified sequential fragmentation sweep
//!    ([`super::partition2::fragment_sweep`]) over its own stripe with a
//!    private set of per-bucket buffers — producing a *per-thread
//!    fragment chain* per bucket plus per-thread partial buffers. Stripe
//!    `t` with `f_t` flushed fragments occupies global slots
//!    `start_t/F .. start_t/F + f_t`; the sweep invariant `f_t·F ≤ len_t`
//!    keeps those slots inside the stripe, so the stripes never race.
//!
//! 2. **Chain merge.** The per-thread chains are stitched per bucket in
//!    (thread, local-flush-order) order — a purely counting step over the
//!    per-thread `frag_bucket` vectors that assigns each source slot a
//!    destination slot in the bucket-ordered global prefix `0..nf`. The
//!    assignment is deterministic, so repeated runs (and any thread
//!    schedule) produce the same layout.
//!
//! 3. **Slot compaction.** Unlike the sequential case, the occupied
//!    source slots are *scattered* (a per-stripe prefix each), so the
//!    slot map is an injective — not bijective — map onto the global
//!    prefix. The cycle-following rotation generalizes to
//!    path-following: starting from any unmoved source, displace the
//!    occupant of its destination if that occupant is itself an unmoved
//!    source, else terminate the path (the destination holds dead bytes
//!    already copied into some stripe's buffers, or a previously moved
//!    fragment's stale copy). Injectivity guarantees each destination is
//!    written exactly once, so each fragment still moves exactly once.
//!
//! 4. **Boundary shift.** Identical to the sequential epilogue — bucket
//!    extents are `fcnt[b]·F` gathered fragment bytes plus the summed
//!    per-thread partial lengths — except each bucket's partial buffers
//!    are appended in thread order. `fstart[b]·F ≤ boundaries[b]` for
//!    every bucket (slots undercount by lower buckets' partials), so the
//!    right-to-left walk never clobbers an unmoved block.
//!
//! An IPS⁴o-style block-trading pass over fragments (swap misplaced
//! fragments pairwise across per-bucket write heads) would avoid the
//! `O(n/F)` destination table, but needs atomics on the write heads and
//! loses the deterministic layout; with `F = 128` the table is ~3% of
//! the input and the deterministic merge wins (see ARCHITECTURE.md).
//! Degenerate inputs — fewer than two slots per worker — fall back to
//! the sequential partition, which produces the same boundaries (they
//! depend only on the per-key bucket map, not on the execution
//! schedule).

use std::sync::Mutex;

use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::scheduler::{aligned_ranges, parallel_for};
use crate::util::timer::{phase_scope, Phase};

use super::partition2::{fragment_sweep, fragmented_partition, FragPartition};

/// Raw-pointer wrapper so the stripe closures can carve disjoint
/// `&mut [K]` sub-slices out of one array across threads.
#[derive(Clone, Copy)]
struct SendPtr<K>(*mut K);
// SAFETY: the wrapped pointer is only dereferenced through disjoint
// stripe ranges, one per worker (see `fragmented_partition_par`).
unsafe impl<K> Send for SendPtr<K> {}
unsafe impl<K> Sync for SendPtr<K> {}

impl<K> SendPtr<K> {
    /// Accessor (not field) so closures capture the Sync wrapper whole.
    fn get(self) -> *mut K {
        self.0
    }
}

/// One stripe's sweep output: its fragment chain (global-slot anchored)
/// and its private partial buffers.
struct StripeOut<K> {
    /// Global slot index of the stripe's first fragment (`start / frag`).
    first_slot: usize,
    /// Owning bucket of the stripe's fragment `j` (at global slot
    /// `first_slot + j`), in local flush order.
    frag_bucket: Vec<u32>,
    /// Per-bucket partial buffers (`num_buckets · frag` keys).
    buffers: Vec<K>,
    /// Per-bucket partial fill levels (`< frag` each).
    lens: Vec<u32>,
}

/// Partition `data` in place into `classifier.num_buckets()` variable-size
/// buckets with the thread-parallel fragmented scheme: per-thread stripe
/// sweeps into private fragment chains, then a deterministic chain merge,
/// injective-slot compaction and boundary shift (see the module docs).
///
/// Returns the same boundaries as the sequential
/// [`fragmented_partition`] — they depend only on the per-key bucket map
/// — and falls back to it outright when `threads <= 1` or the input is
/// too small to give every worker at least two fragment slots.
pub fn fragmented_partition_par<K: SortKey, C: Classifier<K> + ?Sized>(
    data: &mut [K],
    classifier: &C,
    frag: usize,
    threads: usize,
) -> FragPartition {
    let n = data.len();
    let nb = classifier.num_buckets();
    assert!(nb >= 2, "need at least two buckets");
    assert!(frag >= 1, "fragment size must be positive");
    let threads = threads.max(1);
    if threads == 1 || n / frag < 2 * threads {
        return fragmented_partition(data, classifier, frag);
    }
    let stripes = aligned_ranges(n, frag, threads);
    let nt = stripes.len();
    crate::obs::metrics::counter_add(crate::obs::C_FRAG_PAR, 1);

    // ---- Phase 1: per-thread stripe sweeps ---------------------------
    let fill = data[0];
    let mut outs: Vec<Option<StripeOut<K>>> = Vec::with_capacity(nt);
    outs.resize_with(nt, || None);
    {
        let _p = phase_scope(Phase::Classification);
        let _s = crate::obs::enabled()
            .then(|| crate::obs::trace::span_n(crate::obs::S_FRAG_PAR_SWEEP, n as u64, 0));
        let results = Mutex::new(&mut outs);
        let data_ptr = SendPtr(data.as_mut_ptr());
        let stripes_ref = &stripes;
        parallel_for(nt, nt, |_, range| {
            for t in range {
                let r = stripes_ref[t].clone();
                // SAFETY: stripe ranges are contiguous, disjoint and
                // in-bounds (`aligned_ranges` covers 0..n exactly), and
                // each index t is visited by exactly one worker.
                let stripe = unsafe {
                    std::slice::from_raw_parts_mut(data_ptr.get().add(r.start), r.len())
                };
                let mut buffers: Vec<K> = vec![fill; nb * frag];
                let mut lens: Vec<u32> = vec![0u32; nb];
                let mut frag_bucket: Vec<u32> = Vec::with_capacity(stripe.len() / frag + 1);
                fragment_sweep(stripe, classifier, frag, &mut buffers, &mut lens, &mut frag_bucket);
                let out = StripeOut {
                    first_slot: r.start / frag,
                    frag_bucket,
                    buffers,
                    lens,
                };
                results.lock().unwrap()[t] = Some(out);
            }
        });
    }
    let outs: Vec<StripeOut<K>> = outs
        .into_iter()
        .map(|o| o.expect("every stripe sweep completed"))
        .collect();

    // ---- Phase 2: chain merge + compaction + boundary shift ----------
    let mut boundaries = vec![0usize; nb + 1];
    {
        let _p = phase_scope(Phase::Cleanup);
        let _s = crate::obs::enabled()
            .then(|| crate::obs::trace::span_n(crate::obs::S_FRAG_PAR_MERGE, n as u64, 0));
        // global per-bucket fragment and partial-key counts
        let mut fcnt = vec![0usize; nb];
        let mut plen = vec![0usize; nb];
        for out in &outs {
            for &b in &out.frag_bucket {
                fcnt[b as usize] += 1;
            }
            for (b, &l) in out.lens.iter().enumerate() {
                plen[b] += l as usize;
            }
        }
        // bucket-ordered destination prefix: bucket b's fragments gather
        // into slots fstart[b]..fstart[b+1]
        let mut fstart = vec![0usize; nb + 1];
        for b in 0..nb {
            fstart[b + 1] = fstart[b] + fcnt[b];
        }
        let nf = fstart[nb];
        let n_slots = n / frag;
        debug_assert!(nf <= n_slots);
        // stitch the per-thread chains: iterate stripes in thread order,
        // each chain in local flush order — deterministic dest per slot
        let mut dest_of = vec![u32::MAX; n_slots];
        let mut next = fstart.clone();
        for out in &outs {
            for (j, &b) in out.frag_bucket.iter().enumerate() {
                dest_of[out.first_slot + j] = next[b as usize] as u32;
                next[b as usize] += 1;
            }
        }
        // path/cycle-following application of the injective slot map:
        // every destination is written exactly once, every source's
        // content is lifted before its slot can be overwritten
        if nf > 0 {
            let mut lifted = vec![false; n_slots];
            let mut hold: Vec<K> = vec![data[0]; frag];
            let mut disp: Vec<K> = vec![data[0]; frag];
            for s in 0..n_slots {
                if dest_of[s] == u32::MAX || lifted[s] {
                    continue;
                }
                if dest_of[s] as usize == s {
                    lifted[s] = true;
                    continue;
                }
                hold.copy_from_slice(&data[s * frag..(s + 1) * frag]);
                lifted[s] = true;
                let mut cur = s;
                loop {
                    let d = dest_of[cur] as usize;
                    if dest_of[d] != u32::MAX && !lifted[d] {
                        // d is an unmoved source: displace its content
                        disp.copy_from_slice(&data[d * frag..(d + 1) * frag]);
                        data[d * frag..(d + 1) * frag].copy_from_slice(&hold);
                        std::mem::swap(&mut hold, &mut disp);
                        lifted[d] = true;
                        cur = d;
                    } else {
                        // d holds dead bytes (non-source slot, or a
                        // source already lifted — incl. the cycle close
                        // d == s): the path ends here
                        data[d * frag..(d + 1) * frag].copy_from_slice(&hold);
                        break;
                    }
                }
            }
        }
        // exact variable-size boundaries (fragments + summed partials)
        for b in 0..nb {
            boundaries[b + 1] = boundaries[b] + fcnt[b] * frag + plen[b];
        }
        debug_assert_eq!(boundaries[nb], n);
        // shift each bucket's gathered fragment block right onto its
        // final offset and append the per-thread partials in thread
        // order; right-to-left is safe because fstart[b]·frag ≤
        // boundaries[b] for every b
        for b in (0..nb).rev() {
            let src = fstart[b] * frag;
            let flen = fcnt[b] * frag;
            let dst = boundaries[b];
            debug_assert!(src <= dst);
            if flen > 0 && src != dst {
                data.copy_within(src..src + flen, dst);
            }
            let mut w = dst + flen;
            for out in &outs {
                let l = out.lens[b] as usize;
                data[w..w + l].copy_from_slice(&out.buffers[b * frag..b * frag + l]);
                w += l;
            }
            debug_assert_eq!(w, boundaries[b + 1]);
        }
    }
    FragPartition { boundaries }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    /// Fixed-step range classifier: bucket = key / step (monotone).
    struct StepClassifier {
        nb: usize,
        step: u64,
    }

    impl Classifier<u64> for StepClassifier {
        fn num_buckets(&self) -> usize {
            self.nb
        }

        fn classify(&self, key: u64) -> usize {
            ((key / self.step) as usize).min(self.nb - 1)
        }

        fn is_equality_bucket(&self, _b: usize) -> bool {
            false
        }
    }

    /// Run the parallel partition and check it against the sequential
    /// one: identical boundaries, same multiset, correct routing.
    fn check_par(data: &[u64], c: &StepClassifier, frag: usize, threads: usize) {
        let mut seq = data.to_vec();
        let want = fragmented_partition(&mut seq, c, frag);
        let mut par = data.to_vec();
        let got = fragmented_partition_par(&mut par, c, frag, threads);
        assert_eq!(
            got.boundaries, want.boundaries,
            "boundaries diverge: frag={frag} threads={threads} n={}",
            data.len()
        );
        let mut a = par.clone();
        let mut b = data.to_vec();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "multiset changed: frag={frag} threads={threads}");
        for bu in 0..c.nb {
            for &k in &par[got.boundaries[bu]..got.boundaries[bu + 1]] {
                assert_eq!(
                    Classifier::<u64>::classify(c, k),
                    bu,
                    "key {k} misrouted to bucket {bu} (frag={frag} threads={threads})"
                );
            }
        }
    }

    #[test]
    fn parallel_matches_sequential_boundaries() {
        let c = StepClassifier { nb: 8, step: 100 };
        let mut rng = Xoshiro256pp::new(31);
        for n in [0usize, 1, 64, 257, 1024, 4096] {
            let data: Vec<u64> = (0..n).map(|_| rng.next_below(800)).collect();
            for frag in [1usize, 4, 16, 128] {
                for threads in [1usize, 2, 3, 4] {
                    check_par(&data, &c, frag, threads);
                }
            }
        }
    }

    #[test]
    fn adversarial_stripe_splits() {
        let c = StepClassifier { nb: 5, step: 160 };
        let mut rng = Xoshiro256pp::new(32);
        // prime lengths × frag sizes: unaligned tails in the last stripe
        for n in [97usize, 101, 997, 2003] {
            let data: Vec<u64> = (0..n).map(|_| rng.next_below(800)).collect();
            for frag in [3usize, 7, 16] {
                for threads in [2usize, 3, 7, 64] {
                    check_par(&data, &c, frag, threads);
                }
            }
        }
        // frag larger than the whole input / than a fair stripe share:
        // the slot-count guard falls back to the sequential path
        let data: Vec<u64> = (0..50u64).map(|_| rng.next_below(800)).collect();
        check_par(&data, &c, 64, 4);
        check_par(&data, &c, 128, 4);
        // threads far exceeding the slot count → fallback, still exact
        let data: Vec<u64> = (0..40u64).map(|_| rng.next_below(800)).collect();
        check_par(&data, &c, 4, 64);
    }

    #[test]
    fn skewed_and_duplicate_chains() {
        let c = StepClassifier { nb: 8, step: 100 };
        let mut rng = Xoshiro256pp::new(33);
        // every key in one middle bucket: one long chain per stripe
        let data: Vec<u64> = vec![450; 2048];
        check_par(&data, &c, 16, 4);
        // two-value input on the extreme buckets (≥ 90% duplicates)
        let data: Vec<u64> = (0..2048)
            .map(|_| if rng.next_below(10) < 9 { 0 } else { 799 })
            .collect();
        check_par(&data, &c, 8, 3);
        // sorted and reverse-sorted inputs
        let data: Vec<u64> = (0..3000u64).map(|i| i % 800).collect();
        check_par(&data, &c, 32, 4);
        let data: Vec<u64> = (0..3000u64).rev().map(|i| i % 800).collect();
        check_par(&data, &c, 32, 4);
    }
}
