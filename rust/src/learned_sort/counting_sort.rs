//! Model-based Counting Sort — LearnedSort 2.0's base case (Kristo et al.,
//! arXiv 2107.03290): the CDF model predicts each key's final position in
//! the sub-bucket, a counting pass places keys by predicted position, and
//! Insertion Sort repairs the (rare, local) prediction errors.

use crate::key::SortKey;
use crate::sample_sort::base_case::insertion_sort;

/// Sort `data` by predicted position. `predict(key)` returns a position
/// estimate in `0..data.len()` (clamped here). `scratch` is reused across
/// calls to avoid re-allocation.
pub fn model_counting_sort<K: SortKey>(
    data: &mut [K],
    mut predict: impl FnMut(K) -> usize,
    scratch: &mut Vec<K>,
    counts: &mut Vec<u32>,
) {
    let n = data.len();
    if n < 2 {
        return;
    }
    counts.clear();
    counts.resize(n + 1, 0);
    scratch.clear();
    scratch.extend_from_slice(data);
    // counting pass over predicted positions
    let mut pos: Vec<u32> = Vec::with_capacity(n);
    for &k in scratch.iter() {
        let p = predict(k).min(n - 1);
        pos.push(p as u32);
        counts[p] += 1;
    }
    // prefix sums -> slot starts
    let mut acc = 0u32;
    for c in counts.iter_mut() {
        let v = *c;
        *c = acc;
        acc += v;
    }
    // placement
    for (i, &k) in scratch.iter().enumerate() {
        let p = pos[i] as usize;
        data[counts[p] as usize] = k;
        counts[p] += 1;
    }
    // correction: the sequence is almost sorted, InsertionSort is cheap
    insertion_sort(data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn perfect_predictor_sorts() {
        let mut v: Vec<u64> = (0..1000u64).rev().collect();
        let mut scratch = Vec::new();
        let mut counts = Vec::new();
        model_counting_sort(&mut v, |k| k as usize, &mut scratch, &mut counts);
        assert!(is_sorted(&v));
    }

    #[test]
    fn noisy_predictor_still_sorts() {
        let mut rng = Xoshiro256pp::new(1);
        let mut v: Vec<u64> = (0..2000).map(|_| rng.next_below(100_000)).collect();
        let mut want = v.clone();
        want.sort_unstable();
        let mut scratch = Vec::new();
        let mut counts = Vec::new();
        // predictor with heavy noise: correctness must not depend on it
        model_counting_sort(
            &mut v,
            |k| ((k as usize) / 50).saturating_sub(7),
            &mut scratch,
            &mut counts,
        );
        assert_eq!(v, want);
    }

    #[test]
    fn adversarial_constant_prediction() {
        let mut rng = Xoshiro256pp::new(2);
        let mut v: Vec<u64> = (0..500).map(|_| rng.next_below(1000)).collect();
        let mut want = v.clone();
        want.sort_unstable();
        let mut scratch = Vec::new();
        let mut counts = Vec::new();
        model_counting_sort(&mut v, |_| 0, &mut scratch, &mut counts);
        assert_eq!(v, want); // degenerates to insertion sort but stays correct
    }

    #[test]
    fn empty_and_single() {
        let mut scratch = Vec::new();
        let mut counts = Vec::new();
        let mut v: Vec<u64> = vec![];
        model_counting_sort(&mut v, |_| 0, &mut scratch, &mut counts);
        let mut v = vec![9u64];
        model_counting_sort(&mut v, |_| 0, &mut scratch, &mut counts);
        assert_eq!(v, [9]);
    }
}
