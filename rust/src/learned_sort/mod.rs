//! LearnedSort 2.0 (engine E3) — Kristo, Vaidya & Kraska, "Defeating
//! duplicates: A re-design of the LearnedSort algorithm" (arXiv
//! 2107.03290), as analyzed by the paper's Section 2.2.
//!
//! Four routines, matching the paper's description:
//!
//! 1. **Train the model** once: RMI on a ~1% random sample (the paper's
//!    key deviation from SampleSort — sample once, in bulk).
//! 2. **Two rounds of partitioning** with per-bucket buffers and a
//!    defragmentation pass — our shared block-partition framework *is*
//!    that routine (the paper, Section 2.4: "the blocking strategy adopted
//!    by IPS⁴o shares many ideas with those adopted by LearnedSort").
//!    Round 2 re-uses the same global model, rescaled to the bucket's CDF
//!    range — LearnedSort never retrains ("samples data only once").
//! 3. **Homogeneity check** per bucket: all-equal buckets are already
//!    sorted and skipped (the duplicate fix of LearnedSort 2.0).
//! 4. **Model-based Counting Sort** in the sub-buckets, then an
//!    **Insertion Sort** correction pass.
//!
//! Bucket counts scale with input size (`B = clamp(n/5000, 2, 1000)`) so
//! small benchmark inputs keep the paper's ~1000-key base-case granularity
//! (the paper's fixed B=1000 assumes N ≈ 10⁸ — Section 3.3 discusses
//! exactly this trade-off).

pub mod counting_sort;

use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::rmi::model::{sample_f64, Rmi, RmiConfig};
use crate::sample_sort::base_case::small_sort;
use crate::sample_sort::partition::partition;
use crate::util::rng::Xoshiro256pp;
use crate::util::timer::{phase_scope, Phase};

use counting_sort::model_counting_sort;

/// Tuning knobs of LearnedSort 2.0.
#[derive(Debug, Clone, Copy)]
pub struct LearnedSortConfig {
    /// Sampling rate for model training (paper: 1%).
    pub sample_frac: f64,
    /// Sample size floor.
    pub min_sample: usize,
    /// Sample size cap.
    pub max_sample: usize,
    /// Second-level model count (paper: B = 1000).
    pub leaves: usize,
    /// Max fan-out per partitioning round (paper: 1000).
    pub max_fanout: usize,
    /// Target keys per round-1 bucket.
    pub bucket_target: usize,
    /// Below this, sort directly with the base case.
    pub base_case: usize,
    /// Sub-buckets at or below this size go to model counting sort.
    pub counting_threshold: usize,
    /// Keys per buffer block in the partition rounds.
    pub block: usize,
}

impl Default for LearnedSortConfig {
    fn default() -> Self {
        LearnedSortConfig {
            sample_frac: 0.01,
            min_sample: 256,
            max_sample: 1 << 16,
            leaves: 1000,
            max_fanout: 1000,
            // ~2000-key round-1 buckets: inputs up to ~2M keys reach the
            // counting-sort base in ONE partitioning round (2 model evals
            // per key instead of 3) — at the paper's N=1e8 this still
            // resolves to the paper's two rounds (perf log, §Perf)
            bucket_target: 2000,
            base_case: 2048,
            counting_threshold: 2048,
            block: 128,
        }
    }
}

/// Rescaled view of the global model over one bucket's CDF range —
/// round 2 classifies with `floor((F(x) - lo) / width * nb)`.
struct SubRangeRmi<'a> {
    rmi: &'a Rmi,
    lo: f64,
    inv_width: f64,
    nb: usize,
}

impl<'a, K: SortKey> Classifier<K> for SubRangeRmi<'a> {
    fn num_buckets(&self) -> usize {
        self.nb
    }

    #[inline(always)]
    fn classify(&self, key: K) -> usize {
        let f = self.rmi.predict(key.to_f64());
        let rel = (f - self.lo) * self.inv_width * self.nb as f64;
        let b = rel as usize; // saturating cast clamps negatives to 0
        if b >= self.nb {
            self.nb - 1
        } else {
            b
        }
    }

    fn is_equality_bucket(&self, _b: usize) -> bool {
        false
    }
}

/// Sort with LearnedSort 2.0 (sequential — the paper benchmarks it
/// sequentially only).
pub fn sort<K: SortKey>(data: &mut [K]) {
    sort_cfg(data, &LearnedSortConfig::default());
}

/// Sort with explicit configuration (tests and ablations).
pub fn sort_cfg<K: SortKey>(data: &mut [K], cfg: &LearnedSortConfig) {
    let n = data.len();
    if n <= cfg.base_case {
        let _g = phase_scope(Phase::BaseCase);
        small_sort(data);
        return;
    }
    let mut rng = Xoshiro256pp::new(0x1EA2_4ED ^ n as u64);

    // ---- Routine 1: train the CDF model (once) -----------------------
    let rmi = {
        let _g = phase_scope(Phase::ModelTrain);
        let ssz = ((n as f64 * cfg.sample_frac) as usize)
            .clamp(cfg.min_sample, cfg.max_sample)
            .min(n);
        let mut sample = Vec::new();
        sample_f64(data, ssz, &mut rng, &mut sample);
        sample.sort_unstable_by(f64::total_cmp);
        Rmi::train(&sample, RmiConfig { n_leaves: cfg.leaves })
    };

    // ---- Routine 2a: first partitioning round ------------------------
    let nb1 = (n / cfg.bucket_target).clamp(2, cfg.max_fanout);
    let c1 = crate::classifier::rmi_classifier::RmiClassifier::new(rmi, nb1);
    let r1 = partition(data, &c1, cfg.block, 1);
    let rmi = c1.rmi();

    let mut scratch: Vec<K> = Vec::new();
    let mut counts: Vec<u32> = Vec::new();
    for b1 in 0..nb1 {
        let (lo, hi) = (r1.boundaries[b1], r1.boundaries[b1 + 1]);
        if hi - lo < 2 {
            continue;
        }
        let bucket = &mut data[lo..hi];
        // ---- Routine 3: homogeneity check (duplicate fix) ------------
        if is_homogeneous(bucket) {
            continue;
        }
        let f_lo = b1 as f64 / nb1 as f64;
        let f_width = 1.0 / nb1 as f64;
        if bucket.len() > cfg.counting_threshold {
            // ---- Routine 2b: second partitioning round ---------------
            let nb2 = (bucket.len() / (cfg.counting_threshold / 2).max(1))
                .clamp(2, cfg.max_fanout);
            let c2 = SubRangeRmi {
                rmi,
                lo: f_lo,
                inv_width: nb1 as f64,
                nb: nb2,
            };
            let r2 = partition(bucket, &c2, cfg.block, 1);
            for b2 in 0..nb2 {
                let (slo, shi) = (r2.boundaries[b2], r2.boundaries[b2 + 1]);
                if shi - slo < 2 {
                    continue;
                }
                let sub = &mut bucket[slo..shi];
                if is_homogeneous(sub) {
                    continue;
                }
                // ---- Routine 4: model counting sort + correction -----
                counting_base(sub, rmi, f_lo + (b2 as f64 / nb2 as f64) * f_width,
                    nb1 as f64 * nb2 as f64, &mut scratch, &mut counts);
            }
        } else {
            counting_base(bucket, rmi, f_lo, nb1 as f64, &mut scratch, &mut counts);
        }
    }
}

/// Model counting sort over a sub-bucket covering CDF range
/// `[f_lo, f_lo + 1/scale)`.
fn counting_base<K: SortKey>(
    sub: &mut [K],
    rmi: &Rmi,
    f_lo: f64,
    scale: f64,
    scratch: &mut Vec<K>,
    counts: &mut Vec<u32>,
) {
    let _g = phase_scope(Phase::BaseCase);
    let m = sub.len() as f64;
    model_counting_sort(
        sub,
        |k| {
            let rel = (rmi.predict(k.to_f64()) - f_lo) * scale;
            // saturating float->usize cast clamps negatives to 0
            (rel * m) as usize
        },
        scratch,
        counts,
    );
}

#[inline]
fn is_homogeneous<K: SortKey>(data: &[K]) -> bool {
    let first = data[0].to_bits_ordered();
    data.iter().all(|k| k.to_bits_ordered() == first)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn sorts_various_sizes() {
        for n in [0usize, 1, 100, 2048, 2049, 10_000, 200_000] {
            let mut rng = Xoshiro256pp::new(n as u64 + 3);
            let mut v: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
            sort(&mut v);
            assert!(is_sorted(&v), "n={n}");
        }
    }

    #[test]
    fn sorts_skewed_distributions() {
        let mut rng = Xoshiro256pp::new(4);
        let mut v: Vec<f64> = (0..150_000).map(|_| rng.lognormal(0.0, 2.0)).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
        let mut v: Vec<f64> = (0..150_000).map(|_| rng.exponential(2.0)).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn duplicate_heavy_homogeneity_path() {
        let mut rng = Xoshiro256pp::new(5);
        let n = 120_000;
        let mut v: Vec<f64> = (0..n).map(|_| rng.next_below(30) as f64).collect();
        let mut want = v.clone();
        want.sort_unstable_by(f64::total_cmp);
        sort(&mut v);
        assert_eq!(v, want);
        // root-dups pattern
        let m = (n as f64).sqrt() as u64;
        let mut v: Vec<f64> = (0..n as u64).map(|i| (i % m) as f64).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn u64_keys() {
        let mut rng = Xoshiro256pp::new(6);
        let mut v: Vec<u64> = (0..100_000).map(|_| rng.next_below(1 << 50)).collect();
        let mut want = v.clone();
        want.sort_unstable();
        sort(&mut v);
        assert_eq!(v, want);
    }

    #[test]
    fn constant_input() {
        let mut v = vec![5.5f64; 50_000];
        sort(&mut v);
        assert!(v.iter().all(|&x| x == 5.5));
    }

    #[test]
    fn already_sorted_and_reverse() {
        let mut v: Vec<f64> = (0..100_000).map(|i| i as f64).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
        let mut v: Vec<f64> = (0..100_000).rev().map(|i| i as f64).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
    }
}
