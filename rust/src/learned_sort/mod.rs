//! LearnedSort 2.0 (engine E3) — Kristo, Vaidya & Kraska, "Defeating
//! duplicates: A re-design of the LearnedSort algorithm" (arXiv
//! 2107.03290), as analyzed by the paper's Section 2.2.
//!
//! Four routines, matching the paper's description:
//!
//! 1. **Train the model** once: RMI on a ~1% random sample (the paper's
//!    key deviation from SampleSort — sample once, in bulk). The sample
//!    also drives two duplicate defenses: the round-1 fan-out is capped
//!    by the sample's distinct count (more buckets than distinct values
//!    cannot subdivide anything), and values heavy enough to dominate a
//!    bucket are promoted to equality buckets.
//! 2. **Two rounds of partitioning.** Two interchangeable schemes (see
//!    [`PartitionScheme`]): the 2.0 re-design's in-place fragmented
//!    partition ([`partition2`] — variable-size buckets emulated by
//!    overwriting the input in fragments, equality buckets instead of a
//!    spill bucket; the default), or the shared IPS⁴o block-partition
//!    framework (the 1.x-shaped formulation kept as the differential
//!    baseline). Round 2 re-uses the same global model, rescaled to the
//!    bucket's CDF range — LearnedSort never retrains ("samples data
//!    only once").
//! 3. **Homogeneity check** per bucket: all-equal buckets (and equality
//!    buckets) are already sorted and skipped (the duplicate fix of
//!    LearnedSort 2.0).
//! 4. **Model-based Counting Sort** in the sub-buckets, then an
//!    **Insertion Sort** correction pass.
//!
//! Bucket counts scale with input size (`B = clamp(n/2000, 2, 1000)`,
//! duplicate-aware — see [`LearnedSortConfig::bucket_target`]) so small
//! benchmark inputs keep the paper's ~1000-key base-case granularity
//! (the paper's fixed B=1000 assumes N ≈ 10⁸ — Section 3.3 discusses
//! exactly this trade-off).

pub mod counting_sort;
pub mod partition2;
pub mod partition2_par;

use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::rmi::model::{Rmi, RmiConfig};
use crate::sample_sort::base_case::small_sort;
use crate::sample_sort::partition::partition;
use crate::scheduler::run_task_pool;
use crate::util::rng::Xoshiro256pp;
use crate::util::timer::{phase_scope, Phase};

use counting_sort::model_counting_sort;

/// Which of the two partition implementations LearnedSort runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionScheme {
    /// v1: the shared IPS⁴o block-partition framework (fixed-capacity
    /// block buffers, count-then-permute; kept as the differential
    /// baseline for the 2.0 path).
    Blocks,
    /// v2: the 2.0 re-design's in-place fragmented-bucket partition
    /// ([`partition2`]) — variable-size buckets, batched branchless RMI
    /// prediction, equality buckets for heavy duplicates.
    Fragments,
}

/// Tuning knobs of LearnedSort 2.0.
#[derive(Debug, Clone, Copy)]
pub struct LearnedSortConfig {
    /// Sampling rate for model training (paper: 1%).
    pub sample_frac: f64,
    /// Sample size floor.
    pub min_sample: usize,
    /// Sample size cap.
    pub max_sample: usize,
    /// Second-level model count (paper: B = 1000).
    pub leaves: usize,
    /// Max fan-out per partitioning round (paper: 1000).
    pub max_fanout: usize,
    /// Target keys per round-1 bucket. The effective fan-out is
    /// duplicate-aware: `n / bucket_target` is additionally capped by the
    /// training sample's distinct count when the sampled distinct ratio
    /// is low (dup-heavy streams get fewer, proportionally larger
    /// buckets instead of thousands of mostly-empty ones).
    pub bucket_target: usize,
    /// Below this, sort directly with the base case.
    pub base_case: usize,
    /// Sub-buckets at or below this size go to model counting sort.
    pub counting_threshold: usize,
    /// Keys per buffer block in the v1 block-partition rounds.
    pub block: usize,
    /// Which partition scheme runs the two rounds.
    pub scheme: PartitionScheme,
    /// Keys per fragment in the v2 fragmented partition.
    pub fragment: usize,
    /// Max equality buckets (heavy duplicate values) per round (v2).
    pub max_equality: usize,
}

impl Default for LearnedSortConfig {
    fn default() -> Self {
        LearnedSortConfig {
            sample_frac: 0.01,
            min_sample: 256,
            max_sample: 1 << 16,
            leaves: 1000,
            max_fanout: 1000,
            // ~2000-key round-1 buckets: inputs up to ~2M keys reach the
            // counting-sort base in ONE partitioning round (2 model evals
            // per key instead of 3) — at the paper's N=1e8 this still
            // resolves to the paper's two rounds (perf log, §Perf)
            bucket_target: 2000,
            base_case: 2048,
            counting_threshold: 2048,
            block: 128,
            scheme: PartitionScheme::Fragments,
            fragment: 128,
            max_equality: 16,
        }
    }
}

impl LearnedSortConfig {
    /// The 1.x-shaped configuration: block partition, no equality
    /// buckets. Kept callable so the differential harness can pin the
    /// two schemes against each other.
    pub fn v1() -> LearnedSortConfig {
        LearnedSortConfig {
            scheme: PartitionScheme::Blocks,
            ..LearnedSortConfig::default()
        }
    }
}

/// Rescaled view of the global model over one bucket's CDF range —
/// round 2 classifies with `floor((F(x) - lo) / width * nb)`.
struct SubRangeRmi<'a> {
    rmi: &'a Rmi,
    lo: f64,
    inv_width: f64,
    nb: usize,
}

impl<'a, K: SortKey> Classifier<K> for SubRangeRmi<'a> {
    fn num_buckets(&self) -> usize {
        self.nb
    }

    #[inline(always)]
    fn classify(&self, key: K) -> usize {
        let f = self.rmi.predict(key.to_f64());
        let rel = (f - self.lo) * self.inv_width * self.nb as f64;
        let b = rel as usize; // saturating cast clamps negatives to 0
        if b >= self.nb {
            self.nb - 1
        } else {
            b
        }
    }

    fn is_equality_bucket(&self, _b: usize) -> bool {
        false
    }

    fn classify_batch(&self, keys: &[K], out: &mut [u32]) {
        debug_assert_eq!(keys.len(), out.len());
        // the shared 8-wide branchless prediction kernel
        let mut kc = keys.chunks_exact(8);
        let mut oc = out.chunks_exact_mut(8);
        for (k8, o8) in (&mut kc).zip(&mut oc) {
            let mut xs = [0.0f64; 8];
            for (x, k) in xs.iter_mut().zip(k8.iter()) {
                *x = k.to_f64();
            }
            let ps = self.rmi.predict_batch(&xs);
            for (o, &p) in o8.iter_mut().zip(ps.iter()) {
                let rel = (p - self.lo) * self.inv_width * self.nb as f64;
                let b = rel as usize;
                let b = if b >= self.nb { self.nb - 1 } else { b };
                *o = b as u32;
            }
        }
        for (k, o) in kc.remainder().iter().zip(oc.into_remainder()) {
            *o = Classifier::<K>::classify(self, *k) as u32;
        }
    }
}

/// Sort with LearnedSort 2.0 (sequential — the paper benchmarks it
/// sequentially only).
pub fn sort<K: SortKey>(data: &mut [K]) {
    sort_cfg(data, &LearnedSortConfig::default());
}

/// Sort with explicit configuration (tests and ablations).
pub fn sort_cfg<K: SortKey>(data: &mut [K], cfg: &LearnedSortConfig) {
    let n = data.len();
    if n <= cfg.base_case {
        let _g = phase_scope(Phase::BaseCase);
        small_sort(data);
        return;
    }
    let (rmi, skeys) = train_model(data, cfg);

    // ---- Routine 2 fan-out: duplicate-aware round-1 bucket count -----
    let distinct = count_distinct_sorted(&skeys);
    let nb1 = round1_fanout(n, distinct, skeys.len(), cfg);
    match cfg.scheme {
        PartitionScheme::Blocks => sort_rounds_blocks(data, rmi, nb1, cfg),
        PartitionScheme::Fragments => sort_rounds_fragments(data, rmi, &skeys, nb1, cfg),
    }
    // the rounds order by ordered bits (homogeneity checks, equality
    // buckets, counting sort all work in bit space); for keys whose bits
    // coarsen the full order — string prefixes — finish equal-bits runs
    // under the full comparator. Compiles away for bit-exact key types.
    crate::key::repair_bit_ties(data);
}

/// Sort with LearnedSort 2.0 across `threads` workers: the parallel
/// fragmented partition ([`partition2_par`]) for the round-1 split, then
/// the round-1 buckets recurse independently on the scheduler's task
/// pool (each runs the unmodified sequential second round + counting
/// base). `threads <= 1` and base-case-sized inputs take the sequential
/// [`sort`] path outright.
///
/// The model is trained exactly as in the sequential path (the sample
/// rng is keyed on `n` alone), the partition boundaries depend only on
/// the per-key bucket map, and every bucket is fully sorted — so the
/// output is byte-identical to the sequential sort for any thread count
/// (pinned by the differential matrix in `tests/differential.rs`).
pub fn sort_par<K: SortKey>(data: &mut [K], threads: usize) {
    sort_par_cfg(data, &LearnedSortConfig::default(), threads);
}

/// Parallel sort with explicit configuration (tests and ablations).
/// Both [`PartitionScheme`]s are honored: `Fragments` runs the parallel
/// fragmented partition, `Blocks` the shared IPS⁴o block partition.
pub fn sort_par_cfg<K: SortKey>(data: &mut [K], cfg: &LearnedSortConfig, threads: usize) {
    let threads = threads.max(1);
    let n = data.len();
    if threads == 1 || n <= cfg.base_case {
        sort_cfg(data, cfg);
        return;
    }
    let (rmi, skeys) = train_model(data, cfg);
    let distinct = count_distinct_sorted(&skeys);
    let nb1 = round1_fanout(n, distinct, skeys.len(), cfg);
    match cfg.scheme {
        PartitionScheme::Blocks => sort_rounds_blocks_par(data, rmi, nb1, cfg, threads),
        PartitionScheme::Fragments => {
            sort_rounds_fragments_par(data, rmi, &skeys, nb1, cfg, threads)
        }
    }
    // same string-tie seam as `sort_cfg` — and because the repair sorts
    // each equal-bits run deterministically, parallel output stays
    // byte-identical to sequential for coarse-bits keys too
    crate::key::repair_bit_ties(data);
}

/// Routine 1: train the CDF model (once). Returns the trained RMI and
/// the bit-sorted key sample that drives the duplicate defenses. The
/// sample rng is keyed on `n` alone, so the sequential and parallel
/// entry points train identical models over the same input.
fn train_model<K: SortKey>(data: &[K], cfg: &LearnedSortConfig) -> (Rmi, Vec<K>) {
    let n = data.len();
    let mut rng = Xoshiro256pp::new(0x1EA2_4ED ^ n as u64);
    let _g = phase_scope(Phase::ModelTrain);
    let ssz = ((n as f64 * cfg.sample_frac) as usize)
        .clamp(cfg.min_sample, cfg.max_sample)
        .min(n);
    // drawn as keys (not embeddings): the duplicate defenses need exact
    // bit patterns, not the lossy f64 embedding
    let mut skeys: Vec<K> = Vec::with_capacity(ssz);
    for _ in 0..ssz {
        skeys.push(data[rng.next_below(n as u64) as usize]);
    }
    skeys.sort_unstable_by(|a, b| a.to_bits_ordered().cmp(&b.to_bits_ordered()));
    // bit order embeds monotonically into f64, so this stays sorted
    let sample: Vec<f64> = skeys.iter().map(|k| k.to_f64()).collect();
    (Rmi::train(&sample, RmiConfig { n_leaves: cfg.leaves }), skeys)
}

/// Distinct values in a bit-sorted sample.
fn count_distinct_sorted<K: SortKey>(sample: &[K]) -> usize {
    if sample.is_empty() {
        return 0;
    }
    1 + sample
        .windows(2)
        .filter(|w| w[0].to_bits_ordered() != w[1].to_bits_ordered())
        .count()
}

/// Round-1 fan-out: the density target `n / bucket_target`, capped by the
/// sample's distinct count when the sampled distinct ratio says the
/// stream is duplicate-heavy. A fan-out beyond the number of distinct
/// values only manufactures empty buckets while the heavy values still
/// pile into few of them — the 1.x failure mode this config fixes.
fn round1_fanout(
    n: usize,
    sample_distinct: usize,
    sample_len: usize,
    cfg: &LearnedSortConfig,
) -> usize {
    let base = (n / cfg.bucket_target.max(1)).clamp(2, cfg.max_fanout);
    if sample_len == 0 {
        return base;
    }
    let ratio = sample_distinct as f64 / sample_len as f64;
    if ratio >= 0.5 {
        return base;
    }
    base.min(sample_distinct.max(2))
}

/// v1 rounds: the shared IPS⁴o block-partition framework.
fn sort_rounds_blocks<K: SortKey>(
    data: &mut [K],
    rmi: Rmi,
    nb1: usize,
    cfg: &LearnedSortConfig,
) {
    let c1 = crate::classifier::rmi_classifier::RmiClassifier::new(rmi, nb1);
    let r1 = partition(data, &c1, cfg.block, 1);
    let rmi = c1.rmi();

    let mut scratch: Vec<K> = Vec::new();
    let mut counts: Vec<u32> = Vec::new();
    for b1 in 0..nb1 {
        let (lo, hi) = (r1.boundaries[b1], r1.boundaries[b1 + 1]);
        if hi - lo < 2 {
            continue;
        }
        let bucket = &mut data[lo..hi];
        sort_block_bucket(bucket, rmi, b1, nb1, cfg, &mut scratch, &mut counts);
    }
}

/// Finish one round-1 bucket of the v1 block scheme: homogeneity check,
/// optional second block-partition round, model counting sort. Shared by
/// the sequential loop and the parallel task pool.
fn sort_block_bucket<K: SortKey>(
    bucket: &mut [K],
    rmi: &Rmi,
    b1: usize,
    nb1: usize,
    cfg: &LearnedSortConfig,
    scratch: &mut Vec<K>,
    counts: &mut Vec<u32>,
) {
    // ---- Routine 3: homogeneity check (duplicate fix) ----------------
    if is_homogeneous(bucket) {
        return;
    }
    let f_lo = b1 as f64 / nb1 as f64;
    let f_width = 1.0 / nb1 as f64;
    if bucket.len() > cfg.counting_threshold {
        // ---- Routine 2b: second partitioning round -------------------
        let nb2 = (bucket.len() / (cfg.counting_threshold / 2).max(1)).clamp(2, cfg.max_fanout);
        let c2 = SubRangeRmi {
            rmi,
            lo: f_lo,
            inv_width: nb1 as f64,
            nb: nb2,
        };
        let r2 = partition(bucket, &c2, cfg.block, 1);
        for b2 in 0..nb2 {
            let (slo, shi) = (r2.boundaries[b2], r2.boundaries[b2 + 1]);
            if shi - slo < 2 {
                continue;
            }
            let sub = &mut bucket[slo..shi];
            if is_homogeneous(sub) {
                continue;
            }
            // ---- Routine 4: model counting sort + correction ---------
            counting_base(
                sub,
                rmi,
                f_lo + (b2 as f64 / nb2 as f64) * f_width,
                nb1 as f64 * nb2 as f64,
                scratch,
                counts,
            );
        }
    } else {
        counting_base(bucket, rmi, f_lo, nb1 as f64, scratch, counts);
    }
}

/// Parallel v1 rounds: the block partition runs striped across the
/// workers, then each round-1 bucket becomes a task on the scheduler
/// pool. Kept so `Blocks` stays honored under [`sort_par_cfg`] (it is
/// the differential baseline, not the default).
fn sort_rounds_blocks_par<K: SortKey>(
    data: &mut [K],
    rmi: Rmi,
    nb1: usize,
    cfg: &LearnedSortConfig,
    threads: usize,
) {
    let c1 = crate::classifier::rmi_classifier::RmiClassifier::new(rmi, nb1);
    // striping pays for itself only when every worker gets a few blocks
    let pthreads = if data.len() >= 4 * cfg.block * threads {
        threads
    } else {
        1
    };
    let r1 = partition(data, &c1, cfg.block, pthreads);
    let rmi = c1.rmi();

    let base = data.as_mut_ptr() as usize;
    let mut tasks: Vec<(usize, usize, usize)> = Vec::new();
    for b1 in 0..nb1 {
        let (lo, hi) = (r1.boundaries[b1], r1.boundaries[b1 + 1]);
        if hi - lo < 2 {
            continue;
        }
        tasks.push((b1, lo, hi - lo));
    }
    run_task_pool(threads, tasks, |(b1, lo, len), _spawner| {
        // SAFETY: bucket extents are disjoint sub-ranges of `data`, one
        // task each, and the pool joins before `data` is touched again.
        let bucket = unsafe { std::slice::from_raw_parts_mut((base as *mut K).add(lo), len) };
        let mut scratch: Vec<K> = Vec::new();
        let mut counts: Vec<u32> = Vec::new();
        sort_block_bucket(bucket, rmi, b1, nb1, cfg, &mut scratch, &mut counts);
    });
}

/// v2 rounds: the 2.0 in-place fragmented partition with equality
/// buckets ([`partition2`]).
fn sort_rounds_fragments<K: SortKey>(
    data: &mut [K],
    rmi: Rmi,
    sample_sorted: &[K],
    nb1: usize,
    cfg: &LearnedSortConfig,
) {
    let heavy = partition2::detect_heavy(sample_sorted, nb1, cfg.max_equality);
    let c1 = partition2::EqRmiClassifier::new(rmi, nb1, &heavy);
    let r1 = partition2::fragmented_partition(data, &c1, cfg.fragment);
    let nb = c1.total_buckets();
    let rmi = c1.rmi();

    let mut scratch: Vec<K> = Vec::new();
    let mut counts: Vec<u32> = Vec::new();
    for b1 in 0..nb {
        let (lo, hi) = (r1.boundaries[b1], r1.boundaries[b1 + 1]);
        if hi - lo < 2 {
            continue;
        }
        // ---- Routine 3: equality buckets hold one value — sorted -----
        if c1.is_eq_bucket(b1) {
            continue;
        }
        let bucket = &mut data[lo..hi];
        let (f_lo, f_hi) = c1.model_range(b1);
        sort_fragment_bucket(bucket, rmi, f_lo, f_hi, cfg, &mut scratch, &mut counts);
    }
}

/// Finish one round-1 bucket of the v2 fragmented scheme: homogeneity
/// check, optional second fragmented round rescaled over the bucket's
/// model CDF window `[f_lo, f_hi)`, model counting sort. Shared by the
/// sequential loop and the parallel task pool (equality buckets are
/// skipped by both callers before reaching here).
fn sort_fragment_bucket<K: SortKey>(
    bucket: &mut [K],
    rmi: &Rmi,
    f_lo: f64,
    f_hi: f64,
    cfg: &LearnedSortConfig,
    scratch: &mut Vec<K>,
    counts: &mut Vec<u32>,
) {
    if is_homogeneous(bucket) {
        return;
    }
    // rescale over the CDF window of the model bucket this final bucket
    // was split from (the window of the whole split group — correctness
    // only needs the counting base's insertion repair)
    let scale1 = 1.0 / (f_hi - f_lo);
    if bucket.len() > cfg.counting_threshold {
        // ---- Routine 2b: second fragmented round ---------------------
        let nb2 = (bucket.len() / (cfg.counting_threshold / 2).max(1)).clamp(2, cfg.max_fanout);
        let c2 = SubRangeRmi {
            rmi,
            lo: f_lo,
            inv_width: scale1,
            nb: nb2,
        };
        let r2 = partition2::fragmented_partition(bucket, &c2, cfg.fragment);
        for b2 in 0..nb2 {
            let (slo, shi) = (r2.boundaries[b2], r2.boundaries[b2 + 1]);
            if shi - slo < 2 {
                continue;
            }
            let sub = &mut bucket[slo..shi];
            if is_homogeneous(sub) {
                continue;
            }
            // ---- Routine 4: model counting sort + correction ---------
            counting_base(
                sub,
                rmi,
                f_lo + (b2 as f64 / nb2 as f64) / scale1,
                scale1 * nb2 as f64,
                scratch,
                counts,
            );
        }
    } else {
        counting_base(bucket, rmi, f_lo, scale1, scratch, counts);
    }
}

/// Parallel v2 rounds: the thread-parallel fragmented partition
/// ([`partition2_par`]) for the round-1 split, then every non-equality
/// round-1 bucket recurses as an independent task on the scheduler pool
/// (the per-bucket second round and counting base are the unmodified
/// sequential routines). Heavy-value equality buckets and the
/// duplicate-aware fan-out work exactly as in the sequential path: the
/// classifier is built from the same sample before any thread forks.
fn sort_rounds_fragments_par<K: SortKey>(
    data: &mut [K],
    rmi: Rmi,
    sample_sorted: &[K],
    nb1: usize,
    cfg: &LearnedSortConfig,
    threads: usize,
) {
    let heavy = partition2::detect_heavy(sample_sorted, nb1, cfg.max_equality);
    let c1 = partition2::EqRmiClassifier::new(rmi, nb1, &heavy);
    let r1 = partition2_par::fragmented_partition_par(data, &c1, cfg.fragment, threads);
    let nb = c1.total_buckets();
    let rmi = c1.rmi();

    let base = data.as_mut_ptr() as usize;
    let mut tasks: Vec<(usize, usize, usize)> = Vec::new();
    for b1 in 0..nb {
        let (lo, hi) = (r1.boundaries[b1], r1.boundaries[b1 + 1]);
        if hi - lo < 2 {
            continue;
        }
        // ---- Routine 3: equality buckets hold one value — sorted -----
        if c1.is_eq_bucket(b1) {
            continue;
        }
        tasks.push((b1, lo, hi - lo));
    }
    run_task_pool(threads, tasks, |(b1, lo, len), _spawner| {
        // SAFETY: bucket extents are disjoint sub-ranges of `data`, one
        // task each, and the pool joins before `data` is touched again.
        let bucket = unsafe { std::slice::from_raw_parts_mut((base as *mut K).add(lo), len) };
        let (f_lo, f_hi) = c1.model_range(b1);
        let mut scratch: Vec<K> = Vec::new();
        let mut counts: Vec<u32> = Vec::new();
        sort_fragment_bucket(bucket, rmi, f_lo, f_hi, cfg, &mut scratch, &mut counts);
    });
}

/// Model counting sort over a sub-bucket covering CDF range
/// `[f_lo, f_lo + 1/scale)`.
fn counting_base<K: SortKey>(
    sub: &mut [K],
    rmi: &Rmi,
    f_lo: f64,
    scale: f64,
    scratch: &mut Vec<K>,
    counts: &mut Vec<u32>,
) {
    let _g = phase_scope(Phase::BaseCase);
    let m = sub.len() as f64;
    model_counting_sort(
        sub,
        |k| {
            let rel = (rmi.predict(k.to_f64()) - f_lo) * scale;
            // saturating float->usize cast clamps negatives to 0
            (rel * m) as usize
        },
        scratch,
        counts,
    );
}

#[inline]
fn is_homogeneous<K: SortKey>(data: &[K]) -> bool {
    let first = data[0].to_bits_ordered();
    data.iter().all(|k| k.to_bits_ordered() == first)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn sorts_various_sizes() {
        for n in [0usize, 1, 100, 2048, 2049, 10_000, 200_000] {
            let mut rng = Xoshiro256pp::new(n as u64 + 3);
            let mut v: Vec<f64> = (0..n).map(|_| rng.uniform(0.0, 1e6)).collect();
            sort(&mut v);
            assert!(is_sorted(&v), "n={n}");
        }
    }

    #[test]
    fn sorts_skewed_distributions() {
        let mut rng = Xoshiro256pp::new(4);
        let mut v: Vec<f64> = (0..150_000).map(|_| rng.lognormal(0.0, 2.0)).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
        let mut v: Vec<f64> = (0..150_000).map(|_| rng.exponential(2.0)).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn duplicate_heavy_homogeneity_path() {
        let mut rng = Xoshiro256pp::new(5);
        let n = 120_000;
        let mut v: Vec<f64> = (0..n).map(|_| rng.next_below(30) as f64).collect();
        let mut want = v.clone();
        want.sort_unstable_by(f64::total_cmp);
        sort(&mut v);
        assert_eq!(v, want);
        // root-dups pattern
        let m = (n as f64).sqrt() as u64;
        let mut v: Vec<f64> = (0..n as u64).map(|i| (i % m) as f64).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn u64_keys() {
        let mut rng = Xoshiro256pp::new(6);
        let mut v: Vec<u64> = (0..100_000).map(|_| rng.next_below(1 << 50)).collect();
        let mut want = v.clone();
        want.sort_unstable();
        sort(&mut v);
        assert_eq!(v, want);
    }

    #[test]
    fn constant_input() {
        let mut v = vec![5.5f64; 50_000];
        sort(&mut v);
        assert!(v.iter().all(|&x| x == 5.5));
    }

    #[test]
    fn already_sorted_and_reverse() {
        let mut v: Vec<f64> = (0..100_000).map(|i| i as f64).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
        let mut v: Vec<f64> = (0..100_000).rev().map(|i| i as f64).collect();
        sort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn v1_blocks_scheme_still_sorts() {
        let cfg = LearnedSortConfig::v1();
        assert_eq!(cfg.scheme, PartitionScheme::Blocks);
        let mut rng = Xoshiro256pp::new(7);
        let mut v: Vec<f64> = (0..120_000).map(|_| rng.lognormal(0.0, 1.5)).collect();
        let mut want = v.clone();
        want.sort_unstable_by(f64::total_cmp);
        sort_cfg(&mut v, &cfg);
        assert_eq!(v, want);
        let mut v: Vec<u64> = (0..120_000).map(|_| rng.next_below(64)).collect();
        let mut want = v.clone();
        want.sort_unstable();
        sort_cfg(&mut v, &cfg);
        assert_eq!(v, want);
    }

    #[test]
    fn v2_matches_std_sort_bitwise_on_dup_heavy_input() {
        // ≥90% duplicates: the 1.x spill-bucket failure mode
        let mut rng = Xoshiro256pp::new(8);
        let n = 150_000;
        let mut v: Vec<f64> = Vec::with_capacity(n);
        for _ in 0..n {
            if rng.next_below(10) < 9 {
                v.push(77.25);
            } else {
                v.push(rng.uniform(0.0, 1e4));
            }
        }
        let mut want = v.clone();
        want.sort_unstable_by(f64::total_cmp);
        sort(&mut v);
        let got: Vec<u64> = v.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u64> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp);
    }

    #[test]
    fn dup_heavy_fanout_is_capped_by_distinct_estimate() {
        let cfg = LearnedSortConfig::default();
        // dup-heavy: 30 distinct values in a 4096-key sample caps the
        // fan-out at 30 (the 1.x config would have opened 500 buckets)
        assert!(round1_fanout(1_000_000, 30, 4096, &cfg) <= 30);
        // smooth streams keep the density target untouched
        assert_eq!(round1_fanout(1_000_000, 4000, 4096, &cfg), 500);
        // degenerate distinct counts still yield a valid 2-way fan-out
        assert_eq!(round1_fanout(1_000_000, 1, 4096, &cfg), 2);
        // the cap never raises the fan-out above the density target
        assert_eq!(round1_fanout(10_000, 4, 4096, &cfg), 4);
        assert_eq!(round1_fanout(10_000, 900, 4096, &cfg), 5);
    }

    #[test]
    fn sort_par_matches_sequential_bytes() {
        // same model (rng keyed on n), same boundaries, fully sorted
        // buckets ⇒ byte-identical output for any thread count
        let mut rng = Xoshiro256pp::new(10);
        let n = 150_000;
        let data: Vec<f64> = (0..n)
            .map(|_| {
                if rng.next_below(10) < 9 {
                    3.25
                } else {
                    rng.lognormal(0.0, 2.0)
                }
            })
            .collect();
        for threads in [1usize, 2, 3, 4] {
            for cfg in [LearnedSortConfig::default(), LearnedSortConfig::v1()] {
                let mut seq = data.clone();
                sort_cfg(&mut seq, &cfg);
                let mut par = data.clone();
                sort_par_cfg(&mut par, &cfg, threads);
                let a: Vec<u64> = seq.iter().map(|x| x.to_bits()).collect();
                let b: Vec<u64> = par.iter().map(|x| x.to_bits()).collect();
                assert_eq!(a, b, "threads={threads} scheme={:?}", cfg.scheme);
            }
        }
    }

    #[test]
    fn parallel_fragments_scheme_executes_fragment_path() {
        // regression: sort_par must honor PartitionScheme::Fragments
        // (it used to fall back to the v1 block scheme silently) — the
        // frag-par spans prove the parallel fragment partition ran
        let _l = crate::obs::test_lock();
        let mut rng = Xoshiro256pp::new(11);
        let data: Vec<f64> = (0..120_000).map(|_| rng.uniform(0.0, 1e6)).collect();

        crate::obs::reset();
        crate::obs::set_enabled(true);
        let mut v = data.clone();
        sort_par(&mut v, 4);
        crate::obs::set_enabled(false);
        assert!(is_sorted(&v));
        let names = crate::obs::trace::span_names(&crate::obs::trace::snapshot());
        assert!(
            names.contains(&crate::obs::S_FRAG_PAR_SWEEP),
            "parallel sweep span missing: {names:?}"
        );
        assert!(
            names.contains(&crate::obs::S_FRAG_PAR_MERGE),
            "merge/compaction span missing: {names:?}"
        );
        let m = crate::obs::metrics::snapshot();
        assert!(m.counters.get(crate::obs::C_FRAG_PAR).copied().unwrap_or(0) >= 1);

        // the v1 Blocks config must stay off the fragment path
        crate::obs::reset();
        crate::obs::set_enabled(true);
        let mut v = data;
        sort_par_cfg(&mut v, &LearnedSortConfig::v1(), 4);
        crate::obs::set_enabled(false);
        assert!(is_sorted(&v));
        let names = crate::obs::trace::span_names(&crate::obs::trace::snapshot());
        assert!(!names.contains(&crate::obs::S_FRAG_PAR_SWEEP));
        crate::obs::reset();
    }

    #[test]
    fn narrow_width_keys_sort() {
        let mut rng = Xoshiro256pp::new(9);
        let mut v: Vec<u32> = (0..80_000).map(|_| rng.next_below(1 << 20) as u32).collect();
        let mut want = v.clone();
        want.sort_unstable();
        sort(&mut v);
        assert_eq!(v, want);
        let mut v: Vec<f32> = (0..80_000).map(|_| rng.uniform(-1e3, 1e3) as f32).collect();
        let mut want = v.clone();
        want.sort_unstable_by(f32::total_cmp);
        sort(&mut v);
        let got: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp);
    }
}
