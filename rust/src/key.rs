//! Key abstraction shared by every sorting engine.
//!
//! The paper sorts 64-bit doubles (synthetic datasets) and 64-bit unsigned
//! integers (real-world datasets). All engines here are generic over
//! [`SortKey`], which provides:
//!
//! * a **total order** via an order-preserving mapping to `u64`
//!   ([`SortKey::to_bits_ordered`]) — also the digit source for the radix
//!   engines (this is the "key extractor that maps floats to integers" the
//!   paper passes to IPS²Ra);
//! * a **model embedding** ([`SortKey::to_f64`]) used by the learned
//!   engines to feed the RMI;
//! * a **fixed-width little-endian codec** ([`SortKey::to_le_bytes`] /
//!   [`SortKey::from_le_bytes`], [`SortKey::WIDTH`]) — the on-disk
//!   encoding the external sorter spills and merges through, plus the
//!   [`KeyKind`] tag stored in the self-describing spill-file header.
//!
//! # Records and strings
//!
//! The trait is deliberately wider than "element == fixed-width numeric":
//!
//! * [`SortItem`] is a **record** — a key plus a fixed-width byte payload
//!   (row id, pointer, small value) that travels with the key through
//!   every engine. Because the record *is* the element, the in-memory
//!   engines move payloads alongside keys with zero extra plumbing; the
//!   external pipeline stores payloads in a **lane**
//!   ([`SortKey::LANE_WIDTH`] trailing bytes of the encoding) that the
//!   spill codecs carry next to the core key bits.
//! * [`PrefixString`] is a **length-bounded string key**: the first
//!   [`PrefixString::PREFIX`] bytes map big-endian into the ordered-bits
//!   space the RMI already models, and the remaining tail rides in the
//!   lane. Its bit image is a *monotone coarsening* of the full
//!   lexicographic order ([`SortKey::ORDER_IN_BITS`] is `false`): bit
//!   comparisons are never wrong, merely unable to distinguish keys that
//!   share an 8-byte prefix, so bits-driven machinery (bucketing, shard
//!   cuts, delta encoding) stays valid and only *tie regions* — maximal
//!   runs of equal bits — need the [`SortKey::key_cmp`] fallback
//!   comparator (see [`repair_bit_ties`]).
//!
//! Bare numeric keys are the zero-lane specialization
//! (`LANE_WIDTH == 0`, `ORDER_IN_BITS == true`): every default method
//! keeps their behavior bit-for-bit, so existing call sites compile and
//! run unchanged.

use std::cmp::Ordering;
use std::fmt::Debug;

/// The key domains the pipeline understands, as recorded in the
/// spill-file header's key-type tag (see [`crate::external::spill`]).
///
/// The paper's two domains are `f64` (synthetic datasets) and `u64`
/// (real-world datasets); the 32-bit variants open the narrower workloads
/// of PCF Learned Sort and the duplicate-heavy integer streams of
/// "Defeating duplicates" at half the spill bytes per key. [`KeyKind::Str`]
/// tags prefix-encoded string keys ([`PrefixString`]): their *core* on-disk
/// width is the 8 prefix bytes that carry the ordered bits — the tail
/// travels in the record lane, like any payload.
///
/// A record ([`SortItem`]) shares its key's tag: the header distinguishes
/// records from bare keys by the lane-width byte, not the tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KeyKind {
    /// 64-bit unsigned integers.
    U64,
    /// 64-bit IEEE-754 doubles.
    F64,
    /// 32-bit unsigned integers.
    U32,
    /// 32-bit IEEE-754 floats.
    F32,
    /// Prefix-encoded string keys ([`PrefixString`]).
    Str,
}

impl KeyKind {
    /// Tag byte stored in the spill header (stable across versions).
    pub const fn tag(self) -> u8 {
        match self {
            KeyKind::U64 => 0,
            KeyKind::F64 => 1,
            KeyKind::U32 => 2,
            KeyKind::F32 => 3,
            KeyKind::Str => 4,
        }
    }

    /// Encoded bytes per key of this kind's **core** (the part that maps
    /// into ordered-bits space). For strings this is the 8-byte prefix;
    /// the tail bytes are lane bytes and accounted separately.
    pub const fn width(self) -> usize {
        match self {
            KeyKind::U64 | KeyKind::F64 | KeyKind::Str => 8,
            KeyKind::U32 | KeyKind::F32 => 4,
        }
    }

    /// Lane bytes the *bare* key of this kind carries (0 for numerics;
    /// the string tail for [`KeyKind::Str`]). A record's total lane is
    /// this plus its payload width.
    pub const fn base_lane(self) -> usize {
        match self {
            KeyKind::Str => PrefixString::LEN - PrefixString::PREFIX,
            _ => 0,
        }
    }

    /// CLI / header-error spelling of the kind.
    pub const fn name(self) -> &'static str {
        match self {
            KeyKind::U64 => "u64",
            KeyKind::F64 => "f64",
            KeyKind::U32 => "u32",
            KeyKind::F32 => "f32",
            KeyKind::Str => "str",
        }
    }

    /// Inverse of [`KeyKind::tag`]; `None` for tags no version defines.
    pub const fn from_tag(tag: u8) -> Option<KeyKind> {
        match tag {
            0 => Some(KeyKind::U64),
            1 => Some(KeyKind::F64),
            2 => Some(KeyKind::U32),
            3 => Some(KeyKind::F32),
            4 => Some(KeyKind::Str),
            _ => None,
        }
    }

    /// Parse a CLI spelling (`u64`, `f64`, `u32`, `f32`, `str`).
    pub fn parse(s: &str) -> Option<KeyKind> {
        match s {
            "u64" => Some(KeyKind::U64),
            "f64" => Some(KeyKind::F64),
            "u32" => Some(KeyKind::U32),
            "f32" => Some(KeyKind::F32),
            "str" => Some(KeyKind::Str),
            _ => None,
        }
    }
}

/// A sortable element: a bare key (`u64`, `u32`, `f64`, `f32`,
/// [`PrefixString`]) or a record ([`SortItem`]) carrying one.
pub trait SortKey: Copy + Send + Sync + Debug + 'static {
    /// Order-preserving map into `u64`: `a < b  ⇒  a.to_bits_ordered() <=
    /// b.to_bits_ordered()` — an exact order embedding when
    /// [`SortKey::ORDER_IN_BITS`] holds (`a < b ⇔ bits(a) < bits(b)`), and
    /// a monotone coarsening otherwise (distinct keys may share bits, but
    /// bits never invert the order).
    fn to_bits_ordered(self) -> u64;

    /// Embedding used as RMI model input.
    fn to_f64(self) -> f64;

    /// Inverse of [`SortKey::to_bits_ordered`] up to the bit image (used
    /// by generators/tests and bit-space probes). Lane bytes that the bit
    /// image does not capture come back zeroed — use
    /// [`SortKey::with_lane`] to reconstruct a full key.
    fn from_bits_ordered(bits: u64) -> Self;

    /// Number of significant bytes in [`SortKey::to_bits_ordered`]
    /// (8 for 64-bit keys, 4 for 32-bit keys) — the radix digit count.
    const RADIX_BYTES: usize;

    /// Which key domain this is — the tag the external sorter's
    /// self-describing spill header records, so a file sorted as one type
    /// can never be silently decoded as another. Records share their
    /// key's tag (the header's lane byte tells them apart).
    const KIND: KeyKind;

    /// Bytes per element in the fixed-width little-endian spill encoding
    /// (`size_of::<Self>()` for every supported type): the core key bytes
    /// followed by [`SortKey::LANE_WIDTH`] lane bytes.
    const WIDTH: usize;

    /// Trailing bytes of the encoding that do **not** participate in
    /// [`SortKey::to_bits_ordered`]: record payloads and string tails.
    /// `0` for bare numeric keys. Invariant:
    /// `WIDTH - LANE_WIDTH == KIND.width()`.
    const LANE_WIDTH: usize = 0;

    /// `true` when [`SortKey::to_bits_ordered`] is an exact order
    /// embedding — bit comparisons alone decide the total order. `false`
    /// for keys whose bits are a coarsening (string prefixes): equal-bits
    /// ties must be broken by [`SortKey::key_cmp`], and bit-sorted output
    /// needs [`repair_bit_ties`].
    const ORDER_IN_BITS: bool = true;

    /// The encoded form: the `[u8; WIDTH]` array [`SortKey::to_le_bytes`]
    /// produces. An associated type because array lengths cannot depend on
    /// an associated const on stable Rust.
    type Bytes: AsRef<[u8]> + AsMut<[u8]> + Copy + Default + Send + Sync + Debug;

    /// Encode the element as `WIDTH` little-endian bytes in its *native*
    /// representation (`u64::to_le_bytes`-style, not the ordered bits) —
    /// the spill/`gen --out` on-disk format, chosen so dataset files and
    /// sorted outputs round-trip byte-exactly.
    fn to_le_bytes(self) -> Self::Bytes;

    /// Decode an element from its fixed-width little-endian encoding.
    fn from_le_bytes(bytes: Self::Bytes) -> Self;

    /// Write this element's [`SortKey::LANE_WIDTH`] lane bytes into
    /// `out` (which must be exactly that long). No-op for lane-free keys.
    /// The delta spill codec stores lanes alongside the bit-space tokens;
    /// [`SortKey::with_lane`] is the inverse.
    #[inline(always)]
    fn write_lane(self, out: &mut [u8]) {
        debug_assert_eq!(out.len(), Self::LANE_WIDTH);
        let _ = out;
    }

    /// Reconstruct an element from its ordered bits plus its
    /// [`SortKey::LANE_WIDTH`] lane bytes — exact for every supported
    /// type (`K::with_lane(k.to_bits_ordered(), lane_of(k)) == k`).
    /// Lane-free keys ignore `lane`.
    #[inline(always)]
    fn with_lane(bits: u64, lane: &[u8]) -> Self {
        debug_assert_eq!(lane.len(), Self::LANE_WIDTH);
        let _ = lane;
        Self::from_bits_ordered(bits)
    }

    /// Largest value [`SortKey::to_bits_ordered`] can produce for this
    /// domain (`u64::MAX` for 64-bit keys, `u32::MAX` for 32-bit keys).
    /// Binary searches over ordered-bits space must clamp to this: beyond
    /// it, [`SortKey::from_bits_ordered`] truncates and the order mapping
    /// is no longer monotone.
    #[inline(always)]
    fn max_ordered_bits() -> u64 {
        if Self::RADIX_BYTES >= 8 {
            u64::MAX
        } else {
            (1u64 << (8 * Self::RADIX_BYTES)) - 1
        }
    }

    /// Total-order comparison. Defaults to the bit order (exact when
    /// [`SortKey::ORDER_IN_BITS`]); coarse-bits keys override this with
    /// the full comparison — it is the tie-region fallback comparator.
    #[inline(always)]
    fn key_cmp(self, other: Self) -> Ordering {
        self.to_bits_ordered().cmp(&other.to_bits_ordered())
    }

    /// `self < other` under the key's total order.
    #[inline(always)]
    fn key_lt(self, other: Self) -> bool {
        self.to_bits_ordered() < other.to_bits_ordered()
    }

    /// `self <= other` under the key's total order.
    #[inline(always)]
    fn key_le(self, other: Self) -> bool {
        self.to_bits_ordered() <= other.to_bits_ordered()
    }

    /// `self == other` under the key's total order.
    #[inline(always)]
    fn key_eq(self, other: Self) -> bool {
        self.to_bits_ordered() == other.to_bits_ordered()
    }

    /// The larger key under the total order.
    #[inline(always)]
    fn key_max(self, other: Self) -> Self {
        if self.key_lt(other) {
            other
        } else {
            self
        }
    }

    /// The smaller key under the total order.
    #[inline(always)]
    fn key_min(self, other: Self) -> Self {
        if other.key_lt(self) {
            other
        } else {
            self
        }
    }

    /// Radix digit: byte `d` (0 = most significant) of the ordered bits,
    /// counting within the key's significant width.
    #[inline(always)]
    fn radix_digit(self, d: usize) -> usize {
        debug_assert!(d < Self::RADIX_BYTES);
        let shift = 8 * (Self::RADIX_BYTES - 1 - d);
        ((self.to_bits_ordered() >> shift) & 0xFF) as usize
    }
}

impl SortKey for u64 {
    const RADIX_BYTES: usize = 8;
    const KIND: KeyKind = KeyKind::U64;
    const WIDTH: usize = 8;
    type Bytes = [u8; 8];

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        self
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        bits
    }

    #[inline(always)]
    fn to_le_bytes(self) -> [u8; 8] {
        u64::to_le_bytes(self)
    }

    #[inline(always)]
    fn from_le_bytes(bytes: [u8; 8]) -> Self {
        u64::from_le_bytes(bytes)
    }
}

impl SortKey for u32 {
    const RADIX_BYTES: usize = 4;
    const KIND: KeyKind = KeyKind::U32;
    const WIDTH: usize = 4;
    type Bytes = [u8; 4];

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        self as u64
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        bits as u32
    }

    #[inline(always)]
    fn to_le_bytes(self) -> [u8; 4] {
        u32::to_le_bytes(self)
    }

    #[inline(always)]
    fn from_le_bytes(bytes: [u8; 4]) -> Self {
        u32::from_le_bytes(bytes)
    }
}

impl SortKey for f64 {
    const RADIX_BYTES: usize = 8;
    const KIND: KeyKind = KeyKind::F64;
    const WIDTH: usize = 8;
    type Bytes = [u8; 8];

    /// Standard IEEE-754 total-order flip: negative floats reverse, the
    /// sign bit becomes the top of the unsigned range.
    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        let b = self.to_bits();
        if b >> 63 == 1 {
            !b
        } else {
            b | 0x8000_0000_0000_0000
        }
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        let b = if bits >> 63 == 1 {
            bits & 0x7FFF_FFFF_FFFF_FFFF
        } else {
            !bits
        };
        f64::from_bits(b)
    }

    #[inline(always)]
    fn to_le_bytes(self) -> [u8; 8] {
        f64::to_le_bytes(self)
    }

    #[inline(always)]
    fn from_le_bytes(bytes: [u8; 8]) -> Self {
        f64::from_le_bytes(bytes)
    }
}

impl SortKey for f32 {
    const RADIX_BYTES: usize = 4;
    const KIND: KeyKind = KeyKind::F32;
    const WIDTH: usize = 4;
    type Bytes = [u8; 4];

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        let b = self.to_bits();
        let m = if b >> 31 == 1 { !b } else { b | 0x8000_0000 };
        m as u64
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        let bits = bits as u32;
        let b = if bits >> 31 == 1 {
            bits & 0x7FFF_FFFF
        } else {
            !bits
        };
        f32::from_bits(b)
    }

    #[inline(always)]
    fn to_le_bytes(self) -> [u8; 4] {
        f32::to_le_bytes(self)
    }

    #[inline(always)]
    fn from_le_bytes(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

// ---------------------------------------------------------------------------
// PrefixString: length-bounded string keys in ordered-bits space.
// ---------------------------------------------------------------------------

/// A length-bounded string key: up to [`PrefixString::LEN`] bytes,
/// zero-padded, ordered lexicographically (unsigned byte order).
///
/// The first [`PrefixString::PREFIX`] bytes, read big-endian, are the
/// ordered bits the RMI models and the spill codecs delta-encode:
/// big-endian `u64` order over the prefix *is* lexicographic order over
/// the prefix, so bit comparisons are a monotone coarsening of the full
/// order — never wrong, only blind past byte 8. The tail bytes ride in
/// the record lane and break prefix ties via [`SortKey::key_cmp`].
///
/// Zero-padding makes `"abc"` and `"abc\0"` the same key: the domain is
/// NUL-free byte strings of at most 16 bytes, which is what the
/// length-bounded prefix contract promises. Longer inputs truncate to
/// their first 16 bytes.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PrefixString(pub [u8; PrefixString::LEN]);

impl PrefixString {
    /// Total bounded key length in bytes.
    pub const LEN: usize = 16;

    /// Leading bytes that map into the ordered-bits space.
    pub const PREFIX: usize = 8;

    /// Build a key from a byte string: zero-padded below
    /// [`PrefixString::LEN`] bytes, truncated above it.
    #[inline]
    pub fn from_bytes(s: &[u8]) -> PrefixString {
        let mut b = [0u8; Self::LEN];
        let n = s.len().min(Self::LEN);
        b[..n].copy_from_slice(&s[..n]);
        PrefixString(b)
    }

    /// Build a key from UTF-8 text (same padding/truncation rules; the
    /// truncation is byte-wise, so a multi-byte code point may split —
    /// ordering is over raw bytes either way).
    #[inline]
    pub fn from_str_key(s: &str) -> PrefixString {
        Self::from_bytes(s.as_bytes())
    }

    /// The padded 16-byte image.
    #[inline]
    pub fn as_bytes(&self) -> &[u8; Self::LEN] {
        &self.0
    }

    /// The key without its zero padding.
    #[inline]
    pub fn trimmed(&self) -> &[u8] {
        let end = self.0.iter().rposition(|&b| b != 0).map_or(0, |i| i + 1);
        &self.0[..end]
    }
}

impl Debug for PrefixString {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PrefixString({:?})", String::from_utf8_lossy(self.trimmed()))
    }
}

impl SortKey for PrefixString {
    const RADIX_BYTES: usize = 8;
    const KIND: KeyKind = KeyKind::Str;
    const WIDTH: usize = PrefixString::LEN;
    const LANE_WIDTH: usize = PrefixString::LEN - PrefixString::PREFIX;
    const ORDER_IN_BITS: bool = false;
    type Bytes = [u8; PrefixString::LEN];

    /// Big-endian read of the 8-byte prefix: lexicographic order of the
    /// prefix equals numeric order of the bits.
    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        u64::from_be_bytes(self.0[..Self::PREFIX].try_into().unwrap())
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self.to_bits_ordered() as f64
    }

    /// Prefix from the bits, zeroed tail — the bit image only.
    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        let mut b = [0u8; Self::LEN];
        b[..Self::PREFIX].copy_from_slice(&bits.to_be_bytes());
        PrefixString(b)
    }

    /// The on-disk encoding is the padded bytes as-is (the natural
    /// interchange form for strings; "LE" is vacuous for a byte string).
    #[inline(always)]
    fn to_le_bytes(self) -> [u8; PrefixString::LEN] {
        self.0
    }

    #[inline(always)]
    fn from_le_bytes(bytes: [u8; PrefixString::LEN]) -> Self {
        PrefixString(bytes)
    }

    #[inline(always)]
    fn write_lane(self, out: &mut [u8]) {
        out.copy_from_slice(&self.0[Self::PREFIX..]);
    }

    #[inline(always)]
    fn with_lane(bits: u64, lane: &[u8]) -> Self {
        let mut b = [0u8; Self::LEN];
        b[..Self::PREFIX].copy_from_slice(&bits.to_be_bytes());
        b[Self::PREFIX..].copy_from_slice(lane);
        PrefixString(b)
    }

    /// Full 16-byte lexicographic comparison (the tie-region fallback).
    #[inline(always)]
    fn key_cmp(self, other: Self) -> Ordering {
        self.0.cmp(&other.0)
    }

    #[inline(always)]
    fn key_lt(self, other: Self) -> bool {
        self.0 < other.0
    }

    #[inline(always)]
    fn key_le(self, other: Self) -> bool {
        self.0 <= other.0
    }

    #[inline(always)]
    fn key_eq(self, other: Self) -> bool {
        self.0 == other.0
    }
}

// ---------------------------------------------------------------------------
// SortItem: key + fixed-width payload records.
// ---------------------------------------------------------------------------

/// A record: a [`SortKey`] plus `P` opaque payload bytes (row id,
/// pointer, packed columns) that travel with the key through every
/// engine and on-disk format.
///
/// `SortItem` itself implements [`SortKey`], ordering and modelling
/// purely by its key — the payload is never compared. Bare keys are the
/// `P = 0` specialization in spirit; in code they stay plain `u64`/`f64`
/// /... so nothing existing changes representation.
#[derive(Clone, Copy, Debug)]
pub struct SortItem<K: SortKey, const P: usize> {
    /// The sorting key.
    pub key: K,
    /// The payload carried alongside it.
    pub val: [u8; P],
}

impl<K: SortKey, const P: usize> SortItem<K, P> {
    /// Build a record.
    #[inline(always)]
    pub fn new(key: K, val: [u8; P]) -> Self {
        SortItem { key, val }
    }
}

/// Encoded form of a [`SortItem`]: the key's encoding immediately
/// followed by the payload bytes. `repr(C)` with byte-only fields —
/// alignment 1, no padding — so the struct *is* its byte image and can
/// hand out `&[u8]` views over the whole encoding.
#[derive(Clone, Copy, Debug)]
#[repr(C)]
pub struct ItemBytes<KB, const P: usize> {
    k: KB,
    v: [u8; P],
}

impl<KB: Default, const P: usize> Default for ItemBytes<KB, P> {
    #[inline(always)]
    fn default() -> Self {
        ItemBytes {
            k: KB::default(),
            v: [0u8; P],
        }
    }
}

impl<KB: AsRef<[u8]> + Copy, const P: usize> AsRef<[u8]> for ItemBytes<KB, P> {
    #[inline(always)]
    fn as_ref(&self) -> &[u8] {
        // Every KB used is a byte array (possibly a nested ItemBytes of
        // byte arrays): alignment 1, fully initialized, and repr(C) with
        // a trailing [u8; P] leaves no padding — the struct's bytes are
        // exactly `k` then `v`.
        debug_assert_eq!(std::mem::align_of::<Self>(), 1);
        debug_assert_eq!(std::mem::size_of::<Self>(), std::mem::size_of::<KB>() + P);
        unsafe {
            std::slice::from_raw_parts(self as *const Self as *const u8, std::mem::size_of::<Self>())
        }
    }
}

impl<KB: AsMut<[u8]> + Copy, const P: usize> AsMut<[u8]> for ItemBytes<KB, P> {
    #[inline(always)]
    fn as_mut(&mut self) -> &mut [u8] {
        debug_assert_eq!(std::mem::align_of::<Self>(), 1);
        debug_assert_eq!(std::mem::size_of::<Self>(), std::mem::size_of::<KB>() + P);
        unsafe {
            std::slice::from_raw_parts_mut(self as *mut Self as *mut u8, std::mem::size_of::<Self>())
        }
    }
}

impl<K: SortKey, const P: usize> SortKey for SortItem<K, P> {
    const RADIX_BYTES: usize = K::RADIX_BYTES;
    const KIND: KeyKind = K::KIND;
    const WIDTH: usize = K::WIDTH + P;
    const LANE_WIDTH: usize = K::LANE_WIDTH + P;
    const ORDER_IN_BITS: bool = K::ORDER_IN_BITS;
    type Bytes = ItemBytes<K::Bytes, P>;

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        self.key.to_bits_ordered()
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self.key.to_f64()
    }

    /// Bit image only: the payload comes back zeroed (bit-space probes
    /// never need it); [`SortKey::with_lane`] reconstructs full records.
    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        SortItem {
            key: K::from_bits_ordered(bits),
            val: [0u8; P],
        }
    }

    #[inline(always)]
    fn to_le_bytes(self) -> ItemBytes<K::Bytes, P> {
        ItemBytes {
            k: self.key.to_le_bytes(),
            v: self.val,
        }
    }

    #[inline(always)]
    fn from_le_bytes(bytes: ItemBytes<K::Bytes, P>) -> Self {
        SortItem {
            key: K::from_le_bytes(bytes.k),
            val: bytes.v,
        }
    }

    #[inline(always)]
    fn write_lane(self, out: &mut [u8]) {
        debug_assert_eq!(out.len(), Self::LANE_WIDTH);
        self.key.write_lane(&mut out[..K::LANE_WIDTH]);
        out[K::LANE_WIDTH..].copy_from_slice(&self.val);
    }

    #[inline(always)]
    fn with_lane(bits: u64, lane: &[u8]) -> Self {
        debug_assert_eq!(lane.len(), Self::LANE_WIDTH);
        let mut val = [0u8; P];
        val.copy_from_slice(&lane[K::LANE_WIDTH..]);
        SortItem {
            key: K::with_lane(bits, &lane[..K::LANE_WIDTH]),
            val,
        }
    }

    #[inline(always)]
    fn key_cmp(self, other: Self) -> Ordering {
        self.key.key_cmp(other.key)
    }

    #[inline(always)]
    fn key_lt(self, other: Self) -> bool {
        self.key.key_lt(other.key)
    }

    #[inline(always)]
    fn key_le(self, other: Self) -> bool {
        self.key.key_le(other.key)
    }

    #[inline(always)]
    fn key_eq(self, other: Self) -> bool {
        self.key.key_eq(other.key)
    }
}

// ---------------------------------------------------------------------------
// Tie repair: promote a bit-sorted slice to fully sorted.
// ---------------------------------------------------------------------------

/// Re-sort every maximal run of equal ordered bits with the full
/// comparator. A no-op (compiled out) for keys whose bits decide the
/// total order.
///
/// This is the seam that lets all the bits-driven machinery — fragmented
/// partitions, equality buckets, radix passes, delta blocks — stay
/// bit-based for coarse-bits keys ([`PrefixString`] and records over it):
/// bit order is a monotone coarsening of the full order, so a bit-sorted
/// slice is correct *between* tie regions, and only the regions
/// themselves (keys sharing an 8-byte prefix) need the fallback
/// comparator. Cost is `O(n)` scan plus a comparison sort per tie
/// region; inputs without prefix ties pay one scan.
pub fn repair_bit_ties<K: SortKey>(data: &mut [K]) {
    if K::ORDER_IN_BITS {
        return;
    }
    let n = data.len();
    let mut i = 0;
    while i < n {
        let bits = data[i].to_bits_ordered();
        let mut j = i + 1;
        while j < n && data[j].to_bits_ordered() == bits {
            j += 1;
        }
        if j - i > 1 {
            data[i..j].sort_unstable_by(|a, b| a.key_cmp(*b));
        }
        i = j;
    }
}

/// Streaming form of [`repair_bit_ties`] for sorted-order *checks*:
/// `true` when `prev` may correctly precede `next` in a fully sorted
/// sequence. Bit-exact keys compare bits; coarse-bits keys compare fully.
#[inline(always)]
pub fn in_full_order<K: SortKey>(prev: K, next: K) -> bool {
    !next.key_lt(prev)
}

// ---------------------------------------------------------------------------
// Kind/payload dispatch.
// ---------------------------------------------------------------------------

/// Payload widths the non-generic surfaces (CLI, coordinator jobs,
/// external `sort_and_verify`) can dispatch to. The engines themselves
/// are generic over any `P`; these are the monomorphizations the binary
/// ships — `8` covers the row-id case, `64` a small packed row.
pub const DISPATCH_PAYLOADS: [usize; 3] = [0, 8, 64];

/// Dispatch a runtime `(KeyKind, payload-width)` pair onto a concrete
/// [`SortKey`] type and run `$body` with `$K` bound to it — the one place
/// the kind/width matrix is spelled out, shared by the CLI, the
/// coordinator, the bench harness and the external sorter's entry point.
///
/// `$payload` is the record payload width in bytes (`0` = bare key; see
/// [`DISPATCH_PAYLOADS`]); the `_` arm runs for unsupported widths.
///
/// ```
/// use aipso::key::KeyKind;
/// let width = aipso::dispatch_key_type!(KeyKind::U32, 8usize, K => {
///     <K as aipso::key::SortKey>::WIDTH
/// }, _ => 0);
/// assert_eq!(width, 12); // 4-byte key + 8-byte payload
/// ```
#[macro_export]
macro_rules! dispatch_key_type {
    ($kind:expr, $payload:expr, $K:ident => $body:expr, _ => $fallback:expr) => {{
        use $crate::key::{KeyKind, PrefixString, SortItem};
        match ($kind, $payload) {
            (KeyKind::U64, 0usize) => {
                type $K = u64;
                $body
            }
            (KeyKind::F64, 0usize) => {
                type $K = f64;
                $body
            }
            (KeyKind::U32, 0usize) => {
                type $K = u32;
                $body
            }
            (KeyKind::F32, 0usize) => {
                type $K = f32;
                $body
            }
            (KeyKind::Str, 0usize) => {
                type $K = PrefixString;
                $body
            }
            (KeyKind::U64, 8usize) => {
                type $K = SortItem<u64, 8>;
                $body
            }
            (KeyKind::F64, 8usize) => {
                type $K = SortItem<f64, 8>;
                $body
            }
            (KeyKind::U32, 8usize) => {
                type $K = SortItem<u32, 8>;
                $body
            }
            (KeyKind::F32, 8usize) => {
                type $K = SortItem<f32, 8>;
                $body
            }
            (KeyKind::Str, 8usize) => {
                type $K = SortItem<PrefixString, 8>;
                $body
            }
            (KeyKind::U64, 64usize) => {
                type $K = SortItem<u64, 64>;
                $body
            }
            (KeyKind::F64, 64usize) => {
                type $K = SortItem<f64, 64>;
                $body
            }
            (KeyKind::U32, 64usize) => {
                type $K = SortItem<u32, 64>;
                $body
            }
            (KeyKind::F32, 64usize) => {
                type $K = SortItem<f32, 64>;
                $body
            }
            (KeyKind::Str, 64usize) => {
                type $K = SortItem<PrefixString, 64>;
                $body
            }
            _ => $fallback,
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_order_preserved() {
        let xs = [
            f64::NEG_INFINITY,
            -1e300,
            -2.5,
            -0.0,
            0.0,
            1e-300,
            2.5,
            1e300,
            f64::INFINITY,
        ];
        for w in xs.windows(2) {
            assert!(
                w[0].to_bits_ordered() <= w[1].to_bits_ordered(),
                "{:?} !<= {:?}",
                w[0],
                w[1]
            );
        }
        // -0.0 and 0.0 are distinct bit patterns but adjacent in order
        assert!((-0.0f64).to_bits_ordered() < 0.0f64.to_bits_ordered());
    }

    #[test]
    fn f64_roundtrip() {
        for x in [-123.456f64, 0.0, 7.25, 1e-12, -1e100] {
            assert_eq!(f64::from_bits_ordered(x.to_bits_ordered()), x);
        }
    }

    #[test]
    fn u64_digits() {
        let k = 0x0102_0304_0506_0708u64;
        assert_eq!(k.radix_digit(0), 0x01);
        assert_eq!(k.radix_digit(7), 0x08);
    }

    #[test]
    fn f32_order_and_roundtrip() {
        let xs = [-1e30f32, -1.0, 0.0, 1.0, 1e30];
        for w in xs.windows(2) {
            assert!(w[0].to_bits_ordered() < w[1].to_bits_ordered());
        }
        for x in xs {
            assert_eq!(f32::from_bits_ordered(x.to_bits_ordered()), x);
        }
    }

    #[test]
    fn cmp_helpers() {
        assert!(1u64.key_lt(2));
        assert!(1u64.key_le(1));
        assert!(2.5f64.key_eq(2.5));
        assert_eq!(3u64.key_max(5), 5);
        assert_eq!(3u64.key_min(5), 3);
        assert_eq!(1u64.key_cmp(2), Ordering::Less);
        assert_eq!(2u64.key_cmp(2), Ordering::Equal);
    }

    #[test]
    fn le_codec_is_native_and_width_consistent() {
        assert_eq!(SortKey::to_le_bytes(0x0102_0304u32), [4, 3, 2, 1]);
        assert_eq!(SortKey::to_le_bytes(1.5f64), 1.5f64.to_le_bytes());
        assert_eq!(<u32 as SortKey>::WIDTH, 4);
        assert_eq!(<f32 as SortKey>::WIDTH, 4);
        assert_eq!(<u64 as SortKey>::WIDTH, 8);
        assert_eq!(<f64 as SortKey>::WIDTH, 8);
        assert_eq!(<u32 as SortKey>::WIDTH, std::mem::size_of::<u32>());
        assert_eq!(u64::from_le_bytes(SortKey::to_le_bytes(77u64)), 77);
        let x = -3.25f32;
        assert_eq!(<f32 as SortKey>::from_le_bytes(SortKey::to_le_bytes(x)), x);
    }

    #[test]
    fn kind_tags_roundtrip() {
        for kind in [
            KeyKind::U64,
            KeyKind::F64,
            KeyKind::U32,
            KeyKind::F32,
            KeyKind::Str,
        ] {
            assert_eq!(KeyKind::from_tag(kind.tag()), Some(kind));
            assert_eq!(KeyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(KeyKind::from_tag(250), None);
        assert_eq!(KeyKind::parse("i64"), None);
        assert_eq!(KeyKind::U32.width(), 4);
        assert_eq!(KeyKind::F64.width(), 8);
        assert_eq!(KeyKind::Str.width(), 8, "the core width is the prefix");
        assert_eq!(KeyKind::Str.base_lane(), 8);
        assert_eq!(KeyKind::U64.base_lane(), 0);
        assert_eq!(<u32 as SortKey>::KIND, KeyKind::U32);
        assert_eq!(<f64 as SortKey>::KIND, KeyKind::F64);
        assert_eq!(<PrefixString as SortKey>::KIND, KeyKind::Str);
    }

    #[test]
    fn max_ordered_bits_caps_narrow_domains() {
        assert_eq!(u64::max_ordered_bits(), u64::MAX);
        assert_eq!(f64::max_ordered_bits(), u64::MAX);
        assert_eq!(u32::max_ordered_bits(), u32::MAX as u64);
        assert_eq!(f32::max_ordered_bits(), u32::MAX as u64);
        // the cap really is the top of the ordered range (for floats the
        // IEEE total order puts positive NaN above +inf)
        assert_eq!(u32::from_bits_ordered(u32::max_ordered_bits()), u32::MAX);
        assert!(f32::from_bits_ordered(f32::max_ordered_bits()).is_nan());
        assert!(
            f32::INFINITY.to_bits_ordered() <= f32::max_ordered_bits(),
            "every representable key must stay inside the cap"
        );
    }

    // -- PrefixString -------------------------------------------------------

    #[test]
    fn prefix_string_bits_are_a_monotone_coarsening() {
        let strs = [
            "", "a", "aa", "ab", "abcdefgh", "abcdefgha", "abcdefghb", "b", "zzzzzzzzzzzzzzzz",
        ];
        let keys: Vec<PrefixString> = strs.iter().map(|s| PrefixString::from_str_key(s)).collect();
        for w in keys.windows(2) {
            assert!(w[0].key_lt(w[1]), "{:?} !< {:?}", w[0], w[1]);
            assert!(
                w[0].to_bits_ordered() <= w[1].to_bits_ordered(),
                "bits must never invert the order: {:?} vs {:?}",
                w[0],
                w[1]
            );
            assert_eq!(w[0].key_cmp(w[1]), Ordering::Less);
        }
        // keys sharing the 8-byte prefix collide in bits but not in order
        let a = PrefixString::from_str_key("abcdefgha");
        let b = PrefixString::from_str_key("abcdefghb");
        assert_eq!(a.to_bits_ordered(), b.to_bits_ordered());
        assert!(a.key_lt(b) && !a.key_eq(b));
        assert!(!PrefixString::ORDER_IN_BITS);
    }

    #[test]
    fn prefix_string_codec_and_lane_roundtrip() {
        for s in ["", "x", "hello", "exactly8", "long key with tail", "\u{00e9}clair"] {
            let k = PrefixString::from_str_key(s);
            // native codec: the padded bytes as-is
            assert_eq!(PrefixString::from_le_bytes(k.to_le_bytes()), k);
            assert_eq!(k.to_le_bytes().len(), PrefixString::LEN);
            // bits + lane reconstruct the full key exactly
            let mut lane = [0u8; PrefixString::LEN - PrefixString::PREFIX];
            k.write_lane(&mut lane);
            assert_eq!(PrefixString::with_lane(k.to_bits_ordered(), &lane), k);
        }
        // truncation is the documented bound, padding is canonical
        let long = PrefixString::from_str_key("0123456789abcdefOVERFLOW");
        assert_eq!(long.as_bytes(), b"0123456789abcdef");
        assert_eq!(
            PrefixString::from_str_key("abc"),
            PrefixString::from_bytes(b"abc\0\0")
        );
        assert_eq!(PrefixString::from_str_key("abc").trimmed(), b"abc");
    }

    #[test]
    fn prefix_string_width_invariant() {
        assert_eq!(
            PrefixString::WIDTH - PrefixString::LANE_WIDTH,
            KeyKind::Str.width()
        );
        assert_eq!(PrefixString::WIDTH, std::mem::size_of::<PrefixString>());
        assert_eq!(PrefixString::max_ordered_bits(), u64::MAX);
    }

    // -- SortItem -----------------------------------------------------------

    #[test]
    fn sort_item_orders_by_key_only() {
        let a = SortItem::<u64, 8>::new(5, *b"payloadA");
        let b = SortItem::<u64, 8>::new(5, *b"payloadB");
        let c = SortItem::<u64, 8>::new(9, *b"payloadC");
        assert!(a.key_eq(b), "payload must not affect the order");
        assert_eq!(a.key_cmp(b), Ordering::Equal);
        assert!(a.key_lt(c) && b.key_le(c));
        assert_eq!(a.to_bits_ordered(), 5);
        assert_eq!(a.key_max(c).key, 9);
    }

    #[test]
    fn sort_item_codec_is_key_then_payload() {
        let r = SortItem::<u32, 8>::new(0x0102_0304, [9, 8, 7, 6, 5, 4, 3, 2]);
        assert_eq!(<SortItem<u32, 8>>::WIDTH, 12);
        assert_eq!(<SortItem<u32, 8>>::LANE_WIDTH, 8);
        let enc = r.to_le_bytes();
        assert_eq!(enc.as_ref(), &[4, 3, 2, 1, 9, 8, 7, 6, 5, 4, 3, 2]);
        assert_eq!(enc.as_ref().len(), <SortItem<u32, 8>>::WIDTH);
        let back = <SortItem<u32, 8>>::from_le_bytes(enc);
        assert_eq!(back.key, r.key);
        assert_eq!(back.val, r.val);
        // AsMut writes through to the decoded record
        let mut enc2 = <SortItem<u32, 8> as SortKey>::Bytes::default();
        enc2.as_mut().copy_from_slice(enc.as_ref());
        let back2 = <SortItem<u32, 8>>::from_le_bytes(enc2);
        assert_eq!(back2.key, r.key);
        assert_eq!(back2.val, r.val);
    }

    #[test]
    fn sort_item_lane_roundtrip_and_width_invariants() {
        let r = SortItem::<u64, 8>::new(0xDEAD_BEEF, *b"rowid007");
        let mut lane = [0u8; 8];
        r.write_lane(&mut lane);
        assert_eq!(&lane, b"rowid007");
        let back = <SortItem<u64, 8>>::with_lane(r.to_bits_ordered(), &lane);
        assert_eq!(back.key, r.key);
        assert_eq!(back.val, r.val);
        assert_eq!(
            <SortItem<u64, 8>>::WIDTH - <SortItem<u64, 8>>::LANE_WIDTH,
            <SortItem<u64, 8>>::KIND.width()
        );
        assert_eq!(
            <SortItem<f32, 64>>::WIDTH - <SortItem<f32, 64>>::LANE_WIDTH,
            KeyKind::F32.width()
        );
        // records over string keys compose: lane = string tail + payload
        type SR = SortItem<PrefixString, 8>;
        let sr = SR::new(PrefixString::from_str_key("abcdefgh-tail"), *b"ROWID042");
        assert_eq!(SR::WIDTH, 24);
        assert_eq!(SR::LANE_WIDTH, 16);
        assert!(!SR::ORDER_IN_BITS);
        let mut lane = [0u8; 16];
        sr.write_lane(&mut lane);
        let back = SR::with_lane(sr.to_bits_ordered(), &lane);
        assert_eq!(back.key, sr.key);
        assert_eq!(back.val, sr.val);
        assert_eq!(sr.to_le_bytes().as_ref().len(), 24);
    }

    #[test]
    fn sort_item_from_bits_zeroes_the_payload() {
        let r = <SortItem<u64, 8>>::from_bits_ordered(77);
        assert_eq!(r.key, 77);
        assert_eq!(r.val, [0u8; 8]);
    }

    // -- tie repair ---------------------------------------------------------

    #[test]
    fn repair_bit_ties_fixes_prefix_collisions_only() {
        let mk = PrefixString::from_str_key;
        // bit-sorted (by 8-byte prefix) but tie regions internally reversed
        let mut keys = vec![
            mk("apple"),
            mk("prefix00zzz"),
            mk("prefix00aaa"),
            mk("prefix00mmm"),
            mk("zebra"),
        ];
        let mut want = keys.clone();
        want.sort_unstable_by(|a, b| a.key_cmp(*b));
        repair_bit_ties(&mut keys);
        assert_eq!(keys, want);
        assert!(keys.windows(2).all(|w| w[0].key_le(w[1])));
    }

    #[test]
    fn repair_bit_ties_is_a_noop_for_exact_bit_orders() {
        let mut keys = vec![3u64, 1, 2]; // unsorted, but u64 bits are exact
        let before = keys.clone();
        repair_bit_ties(&mut keys);
        assert_eq!(keys, before, "exact-bits keys are never touched");
        assert!(in_full_order(1u64, 2u64));
        assert!(!in_full_order(2u64, 1u64));
        assert!(in_full_order(2u64, 2u64));
    }

    // -- dispatch -----------------------------------------------------------

    #[test]
    fn dispatch_covers_the_kind_by_payload_matrix() {
        for kind in [
            KeyKind::U64,
            KeyKind::F64,
            KeyKind::U32,
            KeyKind::F32,
            KeyKind::Str,
        ] {
            for payload in DISPATCH_PAYLOADS {
                let (w, lane) = crate::dispatch_key_type!(kind, payload, K => {
                    (<K as SortKey>::WIDTH, <K as SortKey>::LANE_WIDTH)
                }, _ => panic!("unsupported dispatch ({kind:?}, {payload})"));
                assert_eq!(w - lane, kind.width(), "{kind:?}/{payload}");
                assert_eq!(lane, kind.base_lane() + payload, "{kind:?}/{payload}");
            }
            // unsupported widths fall through
            let fell = crate::dispatch_key_type!(kind, 7usize, _K => false, _ => true);
            assert!(fell);
        }
    }
}
