//! Key abstraction shared by every sorting engine.
//!
//! The paper sorts 64-bit doubles (synthetic datasets) and 64-bit unsigned
//! integers (real-world datasets). All engines here are generic over
//! [`SortKey`], which provides:
//!
//! * a **total order** via an order-preserving mapping to `u64`
//!   ([`SortKey::to_bits_ordered`]) — also the digit source for the radix
//!   engines (this is the "key extractor that maps floats to integers" the
//!   paper passes to IPS²Ra);
//! * a **model embedding** ([`SortKey::to_f64`]) used by the learned
//!   engines to feed the RMI.

use std::fmt::Debug;

/// A sortable key: `u64`, `u32`, `f64` or `f32`.
pub trait SortKey: Copy + Send + Sync + Debug + 'static {
    /// Order-preserving map into `u64`: `a < b  ⇔  a.to_bits_ordered() <
    /// b.to_bits_ordered()` (for floats, under IEEE total order).
    fn to_bits_ordered(self) -> u64;

    /// Embedding used as RMI model input.
    fn to_f64(self) -> f64;

    /// Inverse of [`SortKey::to_bits_ordered`] (used by generators/tests).
    fn from_bits_ordered(bits: u64) -> Self;

    /// Number of significant bytes in [`SortKey::to_bits_ordered`]
    /// (8 for 64-bit keys, 4 for 32-bit keys) — the radix digit count.
    const RADIX_BYTES: usize;

    /// `self < other` under the key's total order.
    #[inline(always)]
    fn key_lt(self, other: Self) -> bool {
        self.to_bits_ordered() < other.to_bits_ordered()
    }

    /// `self <= other` under the key's total order.
    #[inline(always)]
    fn key_le(self, other: Self) -> bool {
        self.to_bits_ordered() <= other.to_bits_ordered()
    }

    /// `self == other` under the key's total order.
    #[inline(always)]
    fn key_eq(self, other: Self) -> bool {
        self.to_bits_ordered() == other.to_bits_ordered()
    }

    /// The larger key under the total order.
    #[inline(always)]
    fn key_max(self, other: Self) -> Self {
        if self.key_lt(other) {
            other
        } else {
            self
        }
    }

    /// The smaller key under the total order.
    #[inline(always)]
    fn key_min(self, other: Self) -> Self {
        if other.key_lt(self) {
            other
        } else {
            self
        }
    }

    /// Radix digit: byte `d` (0 = most significant) of the ordered bits,
    /// counting within the key's significant width.
    #[inline(always)]
    fn radix_digit(self, d: usize) -> usize {
        debug_assert!(d < Self::RADIX_BYTES);
        let shift = 8 * (Self::RADIX_BYTES - 1 - d);
        ((self.to_bits_ordered() >> shift) & 0xFF) as usize
    }
}

impl SortKey for u64 {
    const RADIX_BYTES: usize = 8;

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        self
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        bits
    }
}

impl SortKey for u32 {
    const RADIX_BYTES: usize = 4;

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        self as u64
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        bits as u32
    }
}

impl SortKey for f64 {
    const RADIX_BYTES: usize = 8;

    /// Standard IEEE-754 total-order flip: negative floats reverse, the
    /// sign bit becomes the top of the unsigned range.
    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        let b = self.to_bits();
        if b >> 63 == 1 {
            !b
        } else {
            b | 0x8000_0000_0000_0000
        }
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        let b = if bits >> 63 == 1 {
            bits & 0x7FFF_FFFF_FFFF_FFFF
        } else {
            !bits
        };
        f64::from_bits(b)
    }
}

impl SortKey for f32 {
    const RADIX_BYTES: usize = 4;

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        let b = self.to_bits();
        let m = if b >> 31 == 1 { !b } else { b | 0x8000_0000 };
        m as u64
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        let bits = bits as u32;
        let b = if bits >> 31 == 1 {
            bits & 0x7FFF_FFFF
        } else {
            !bits
        };
        f32::from_bits(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_order_preserved() {
        let xs = [
            f64::NEG_INFINITY,
            -1e300,
            -2.5,
            -0.0,
            0.0,
            1e-300,
            2.5,
            1e300,
            f64::INFINITY,
        ];
        for w in xs.windows(2) {
            assert!(
                w[0].to_bits_ordered() <= w[1].to_bits_ordered(),
                "{:?} !<= {:?}",
                w[0],
                w[1]
            );
        }
        // -0.0 and 0.0 are distinct bit patterns but adjacent in order
        assert!((-0.0f64).to_bits_ordered() < 0.0f64.to_bits_ordered());
    }

    #[test]
    fn f64_roundtrip() {
        for x in [-123.456f64, 0.0, 7.25, 1e-12, -1e100] {
            assert_eq!(f64::from_bits_ordered(x.to_bits_ordered()), x);
        }
    }

    #[test]
    fn u64_digits() {
        let k = 0x0102_0304_0506_0708u64;
        assert_eq!(k.radix_digit(0), 0x01);
        assert_eq!(k.radix_digit(7), 0x08);
    }

    #[test]
    fn f32_order_and_roundtrip() {
        let xs = [-1e30f32, -1.0, 0.0, 1.0, 1e30];
        for w in xs.windows(2) {
            assert!(w[0].to_bits_ordered() < w[1].to_bits_ordered());
        }
        for x in xs {
            assert_eq!(f32::from_bits_ordered(x.to_bits_ordered()), x);
        }
    }

    #[test]
    fn cmp_helpers() {
        assert!(1u64.key_lt(2));
        assert!(1u64.key_le(1));
        assert!(2.5f64.key_eq(2.5));
        assert_eq!(3u64.key_max(5), 5);
        assert_eq!(3u64.key_min(5), 3);
    }
}
