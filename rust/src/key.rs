//! Key abstraction shared by every sorting engine.
//!
//! The paper sorts 64-bit doubles (synthetic datasets) and 64-bit unsigned
//! integers (real-world datasets). All engines here are generic over
//! [`SortKey`], which provides:
//!
//! * a **total order** via an order-preserving mapping to `u64`
//!   ([`SortKey::to_bits_ordered`]) — also the digit source for the radix
//!   engines (this is the "key extractor that maps floats to integers" the
//!   paper passes to IPS²Ra);
//! * a **model embedding** ([`SortKey::to_f64`]) used by the learned
//!   engines to feed the RMI;
//! * a **fixed-width little-endian codec** ([`SortKey::to_le_bytes`] /
//!   [`SortKey::from_le_bytes`], [`SortKey::WIDTH`]) — the on-disk
//!   encoding the external sorter spills and merges through, plus the
//!   [`KeyKind`] tag stored in the self-describing spill-file header.

use std::fmt::Debug;

/// The four key domains the pipeline understands, as recorded in the
/// spill-file header's key-type tag (see [`crate::external::spill`]).
///
/// The paper's two domains are `f64` (synthetic datasets) and `u64`
/// (real-world datasets); the 32-bit variants open the narrower workloads
/// of PCF Learned Sort and the duplicate-heavy integer streams of
/// "Defeating duplicates" at half the spill bytes per key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KeyKind {
    /// 64-bit unsigned integers.
    U64,
    /// 64-bit IEEE-754 doubles.
    F64,
    /// 32-bit unsigned integers.
    U32,
    /// 32-bit IEEE-754 floats.
    F32,
}

impl KeyKind {
    /// Tag byte stored in the spill header (stable across versions).
    pub const fn tag(self) -> u8 {
        match self {
            KeyKind::U64 => 0,
            KeyKind::F64 => 1,
            KeyKind::U32 => 2,
            KeyKind::F32 => 3,
        }
    }

    /// Encoded bytes per key of this kind.
    pub const fn width(self) -> usize {
        match self {
            KeyKind::U64 | KeyKind::F64 => 8,
            KeyKind::U32 | KeyKind::F32 => 4,
        }
    }

    /// CLI / header-error spelling of the kind.
    pub const fn name(self) -> &'static str {
        match self {
            KeyKind::U64 => "u64",
            KeyKind::F64 => "f64",
            KeyKind::U32 => "u32",
            KeyKind::F32 => "f32",
        }
    }

    /// Inverse of [`KeyKind::tag`]; `None` for tags no version defines.
    pub const fn from_tag(tag: u8) -> Option<KeyKind> {
        match tag {
            0 => Some(KeyKind::U64),
            1 => Some(KeyKind::F64),
            2 => Some(KeyKind::U32),
            3 => Some(KeyKind::F32),
            _ => None,
        }
    }

    /// Parse a CLI spelling (`u64`, `f64`, `u32`, `f32`).
    pub fn parse(s: &str) -> Option<KeyKind> {
        match s {
            "u64" => Some(KeyKind::U64),
            "f64" => Some(KeyKind::F64),
            "u32" => Some(KeyKind::U32),
            "f32" => Some(KeyKind::F32),
            _ => None,
        }
    }
}

/// A sortable key: `u64`, `u32`, `f64` or `f32`.
pub trait SortKey: Copy + Send + Sync + Debug + 'static {
    /// Order-preserving map into `u64`: `a < b  ⇔  a.to_bits_ordered() <
    /// b.to_bits_ordered()` (for floats, under IEEE total order).
    fn to_bits_ordered(self) -> u64;

    /// Embedding used as RMI model input.
    fn to_f64(self) -> f64;

    /// Inverse of [`SortKey::to_bits_ordered`] (used by generators/tests).
    fn from_bits_ordered(bits: u64) -> Self;

    /// Number of significant bytes in [`SortKey::to_bits_ordered`]
    /// (8 for 64-bit keys, 4 for 32-bit keys) — the radix digit count.
    const RADIX_BYTES: usize;

    /// Which of the four key domains this is — the tag the external
    /// sorter's self-describing spill header records, so a file sorted as
    /// one type can never be silently decoded as another.
    const KIND: KeyKind;

    /// Bytes per key in the fixed-width little-endian spill encoding
    /// (always `size_of::<Self>()` for the four supported domains).
    const WIDTH: usize;

    /// The encoded form: the `[u8; WIDTH]` array [`SortKey::to_le_bytes`]
    /// produces. An associated type because array lengths cannot depend on
    /// an associated const on stable Rust.
    type Bytes: AsRef<[u8]> + AsMut<[u8]> + Copy + Default + Send + Sync + Debug;

    /// Encode the key as `WIDTH` little-endian bytes in its *native*
    /// representation (`u64::to_le_bytes`-style, not the ordered bits) —
    /// the spill/`gen --out` on-disk format, chosen so dataset files and
    /// sorted outputs round-trip byte-exactly.
    fn to_le_bytes(self) -> Self::Bytes;

    /// Decode a key from its fixed-width little-endian encoding.
    fn from_le_bytes(bytes: Self::Bytes) -> Self;

    /// Largest value [`SortKey::to_bits_ordered`] can produce for this
    /// domain (`u64::MAX` for 64-bit keys, `u32::MAX` for 32-bit keys).
    /// Binary searches over ordered-bits space must clamp to this: beyond
    /// it, [`SortKey::from_bits_ordered`] truncates and the order mapping
    /// is no longer monotone.
    #[inline(always)]
    fn max_ordered_bits() -> u64 {
        if Self::RADIX_BYTES >= 8 {
            u64::MAX
        } else {
            (1u64 << (8 * Self::RADIX_BYTES)) - 1
        }
    }

    /// `self < other` under the key's total order.
    #[inline(always)]
    fn key_lt(self, other: Self) -> bool {
        self.to_bits_ordered() < other.to_bits_ordered()
    }

    /// `self <= other` under the key's total order.
    #[inline(always)]
    fn key_le(self, other: Self) -> bool {
        self.to_bits_ordered() <= other.to_bits_ordered()
    }

    /// `self == other` under the key's total order.
    #[inline(always)]
    fn key_eq(self, other: Self) -> bool {
        self.to_bits_ordered() == other.to_bits_ordered()
    }

    /// The larger key under the total order.
    #[inline(always)]
    fn key_max(self, other: Self) -> Self {
        if self.key_lt(other) {
            other
        } else {
            self
        }
    }

    /// The smaller key under the total order.
    #[inline(always)]
    fn key_min(self, other: Self) -> Self {
        if other.key_lt(self) {
            other
        } else {
            self
        }
    }

    /// Radix digit: byte `d` (0 = most significant) of the ordered bits,
    /// counting within the key's significant width.
    #[inline(always)]
    fn radix_digit(self, d: usize) -> usize {
        debug_assert!(d < Self::RADIX_BYTES);
        let shift = 8 * (Self::RADIX_BYTES - 1 - d);
        ((self.to_bits_ordered() >> shift) & 0xFF) as usize
    }
}

impl SortKey for u64 {
    const RADIX_BYTES: usize = 8;
    const KIND: KeyKind = KeyKind::U64;
    const WIDTH: usize = 8;
    type Bytes = [u8; 8];

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        self
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        bits
    }

    #[inline(always)]
    fn to_le_bytes(self) -> [u8; 8] {
        u64::to_le_bytes(self)
    }

    #[inline(always)]
    fn from_le_bytes(bytes: [u8; 8]) -> Self {
        u64::from_le_bytes(bytes)
    }
}

impl SortKey for u32 {
    const RADIX_BYTES: usize = 4;
    const KIND: KeyKind = KeyKind::U32;
    const WIDTH: usize = 4;
    type Bytes = [u8; 4];

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        self as u64
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        bits as u32
    }

    #[inline(always)]
    fn to_le_bytes(self) -> [u8; 4] {
        u32::to_le_bytes(self)
    }

    #[inline(always)]
    fn from_le_bytes(bytes: [u8; 4]) -> Self {
        u32::from_le_bytes(bytes)
    }
}

impl SortKey for f64 {
    const RADIX_BYTES: usize = 8;
    const KIND: KeyKind = KeyKind::F64;
    const WIDTH: usize = 8;
    type Bytes = [u8; 8];

    /// Standard IEEE-754 total-order flip: negative floats reverse, the
    /// sign bit becomes the top of the unsigned range.
    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        let b = self.to_bits();
        if b >> 63 == 1 {
            !b
        } else {
            b | 0x8000_0000_0000_0000
        }
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        let b = if bits >> 63 == 1 {
            bits & 0x7FFF_FFFF_FFFF_FFFF
        } else {
            !bits
        };
        f64::from_bits(b)
    }

    #[inline(always)]
    fn to_le_bytes(self) -> [u8; 8] {
        f64::to_le_bytes(self)
    }

    #[inline(always)]
    fn from_le_bytes(bytes: [u8; 8]) -> Self {
        f64::from_le_bytes(bytes)
    }
}

impl SortKey for f32 {
    const RADIX_BYTES: usize = 4;
    const KIND: KeyKind = KeyKind::F32;
    const WIDTH: usize = 4;
    type Bytes = [u8; 4];

    #[inline(always)]
    fn to_bits_ordered(self) -> u64 {
        let b = self.to_bits();
        let m = if b >> 31 == 1 { !b } else { b | 0x8000_0000 };
        m as u64
    }

    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline(always)]
    fn from_bits_ordered(bits: u64) -> Self {
        let bits = bits as u32;
        let b = if bits >> 31 == 1 {
            bits & 0x7FFF_FFFF
        } else {
            !bits
        };
        f32::from_bits(b)
    }

    #[inline(always)]
    fn to_le_bytes(self) -> [u8; 4] {
        f32::to_le_bytes(self)
    }

    #[inline(always)]
    fn from_le_bytes(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_order_preserved() {
        let xs = [
            f64::NEG_INFINITY,
            -1e300,
            -2.5,
            -0.0,
            0.0,
            1e-300,
            2.5,
            1e300,
            f64::INFINITY,
        ];
        for w in xs.windows(2) {
            assert!(
                w[0].to_bits_ordered() <= w[1].to_bits_ordered(),
                "{:?} !<= {:?}",
                w[0],
                w[1]
            );
        }
        // -0.0 and 0.0 are distinct bit patterns but adjacent in order
        assert!((-0.0f64).to_bits_ordered() < 0.0f64.to_bits_ordered());
    }

    #[test]
    fn f64_roundtrip() {
        for x in [-123.456f64, 0.0, 7.25, 1e-12, -1e100] {
            assert_eq!(f64::from_bits_ordered(x.to_bits_ordered()), x);
        }
    }

    #[test]
    fn u64_digits() {
        let k = 0x0102_0304_0506_0708u64;
        assert_eq!(k.radix_digit(0), 0x01);
        assert_eq!(k.radix_digit(7), 0x08);
    }

    #[test]
    fn f32_order_and_roundtrip() {
        let xs = [-1e30f32, -1.0, 0.0, 1.0, 1e30];
        for w in xs.windows(2) {
            assert!(w[0].to_bits_ordered() < w[1].to_bits_ordered());
        }
        for x in xs {
            assert_eq!(f32::from_bits_ordered(x.to_bits_ordered()), x);
        }
    }

    #[test]
    fn cmp_helpers() {
        assert!(1u64.key_lt(2));
        assert!(1u64.key_le(1));
        assert!(2.5f64.key_eq(2.5));
        assert_eq!(3u64.key_max(5), 5);
        assert_eq!(3u64.key_min(5), 3);
    }

    #[test]
    fn le_codec_is_native_and_width_consistent() {
        assert_eq!(SortKey::to_le_bytes(0x0102_0304u32), [4, 3, 2, 1]);
        assert_eq!(SortKey::to_le_bytes(1.5f64), 1.5f64.to_le_bytes());
        assert_eq!(<u32 as SortKey>::WIDTH, 4);
        assert_eq!(<f32 as SortKey>::WIDTH, 4);
        assert_eq!(<u64 as SortKey>::WIDTH, 8);
        assert_eq!(<f64 as SortKey>::WIDTH, 8);
        assert_eq!(<u32 as SortKey>::WIDTH, std::mem::size_of::<u32>());
        assert_eq!(u64::from_le_bytes(SortKey::to_le_bytes(77u64)), 77);
        let x = -3.25f32;
        assert_eq!(<f32 as SortKey>::from_le_bytes(SortKey::to_le_bytes(x)), x);
    }

    #[test]
    fn kind_tags_roundtrip() {
        for kind in [KeyKind::U64, KeyKind::F64, KeyKind::U32, KeyKind::F32] {
            assert_eq!(KeyKind::from_tag(kind.tag()), Some(kind));
            assert_eq!(KeyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(KeyKind::from_tag(250), None);
        assert_eq!(KeyKind::parse("i64"), None);
        assert_eq!(KeyKind::U32.width(), 4);
        assert_eq!(KeyKind::F64.width(), 8);
        assert_eq!(<u32 as SortKey>::KIND, KeyKind::U32);
        assert_eq!(<f64 as SortKey>::KIND, KeyKind::F64);
    }

    #[test]
    fn max_ordered_bits_caps_narrow_domains() {
        assert_eq!(u64::max_ordered_bits(), u64::MAX);
        assert_eq!(f64::max_ordered_bits(), u64::MAX);
        assert_eq!(u32::max_ordered_bits(), u32::MAX as u64);
        assert_eq!(f32::max_ordered_bits(), u32::MAX as u64);
        // the cap really is the top of the ordered range (for floats the
        // IEEE total order puts positive NaN above +inf)
        assert_eq!(u32::from_bits_ordered(u32::max_ordered_bits()), u32::MAX);
        assert!(f32::from_bits_ordered(f32::max_ordered_bits()).is_nan());
        assert!(
            f32::INFINITY.to_bits_ordered() <= f32::max_ordered_bits(),
            "every representable key must stay inside the cap"
        );
    }
}
