//! Sort-job coordinator (substrate S12) — the L3 service layer.
//!
//! The paper's contribution is the parallel sorting engine itself; this
//! module is the thin deployment shell a database/ETL system would embed
//! it behind: a job queue with an engine router, thread budgeting, and
//! per-job metrics. `aipso serve` and `examples/e2e_pipeline.rs` drive it.
//!
//! Design: jobs are submitted to a FIFO; a dispatcher thread admits one
//! job at a time onto the core pool (sorting is memory-bandwidth bound —
//! co-running two large sorts thrashes, so admission is serialized; small
//! jobs are batched through the sequential path in parallel instead).
//! Out-of-core jobs ([`JobPayload::External`]) used to take the same
//! exclusive path; now that the external pipeline overlaps its IO and
//! bounds its memory explicitly (`ExternalConfig::memory_budget`), a
//! *parallel* external job runs on an **overlap lane**: its own thread,
//! concurrent with the in-memory queue, at most one in flight at a time
//! (further external jobs queue behind it without blocking the
//! dispatcher). Disk-bound phases of the external sort then no longer
//! stall the in-memory service path. Non-parallel external jobs keep the
//! old exclusive single-thread admission — and still wait for the lane to
//! drain first, upholding the one-external-sort-per-disk rule.
//!
//! ```
//! use aipso::coordinator::{Coordinator, JobSpec, KeyBuf};
//!
//! let coordinator = Coordinator::new(2);
//! coordinator.submit(JobSpec::auto(0, KeyBuf::U64((0..10_000u64).rev().collect())));
//! let (reports, metrics) = coordinator.drain();
//! assert!(reports[0].verified_sorted);
//! assert_eq!(metrics.total_jobs(), 1);
//! ```

pub mod job;
pub mod metrics;
pub mod router;

pub use job::{ExternalJob, JobPayload, JobReport, JobSpec, KeyBuf};
pub use metrics::MetricsRegistry;
pub use router::{route, EngineChoice};

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::external;
use crate::scheduler::effective_threads;
use crate::{is_sorted, sort_parallel, sort_sequential};

/// Jobs below this size run sequentially, several at a time.
pub const SMALL_JOB: usize = 1 << 15;

/// The coordinator service: owns a dispatcher thread; `submit` is
/// non-blocking, `drain` collects reports.
pub struct Coordinator {
    tx: Option<mpsc::Sender<JobSpec>>,
    handle: Option<std::thread::JoinHandle<()>>,
    reports: Arc<Mutex<Vec<JobReport>>>,
    metrics: Arc<Mutex<MetricsRegistry>>,
}

impl Coordinator {
    /// Start a coordinator whose dispatcher schedules onto `threads`
    /// workers (0 = all available cores).
    pub fn new(threads: usize) -> Coordinator {
        let threads = effective_threads(threads);
        let (tx, rx) = mpsc::channel::<JobSpec>();
        let reports: Arc<Mutex<Vec<JobReport>>> = Arc::default();
        let metrics: Arc<Mutex<MetricsRegistry>> = Arc::default();
        let reports_w = reports.clone();
        let metrics_w = metrics.clone();
        let handle = std::thread::spawn(move || {
            // Dispatcher: admit small jobs in sequential batches, large
            // jobs exclusively onto the full pool.
            let mut small: Vec<JobSpec> = Vec::new();
            let flush_small = |batch: &mut Vec<JobSpec>| {
                if batch.is_empty() {
                    return;
                }
                let done: Vec<JobReport> = {
                    let out: Mutex<Vec<JobReport>> = Mutex::new(Vec::new());
                    // run each small job sequentially, spread over threads
                    let jobs = Mutex::new(std::mem::take(batch));
                    std::thread::scope(|s| {
                        for _ in 0..threads.min(4) {
                            s.spawn(|| loop {
                                let Some(job) = jobs.lock().unwrap().pop() else {
                                    return;
                                };
                                let rep = run_job(job, 1);
                                out.lock().unwrap().push(rep);
                            });
                        }
                    });
                    out.into_inner().unwrap()
                };
                for rep in done {
                    metrics_w.lock().unwrap().record(&rep);
                    reports_w.lock().unwrap().push(rep);
                }
            };
            // Overlap lane: at most one parallel external job runs on its
            // own thread, concurrent with the in-memory queue (external
            // sorts are disk-bound for much of their lifetime and bound
            // their own memory, so they no longer serialize admission).
            // Further parallel external jobs wait in a pending queue —
            // never blocking the dispatcher — because two external sorts
            // would compete for the same disk and double the budget.
            let mut external_lane: Option<std::thread::JoinHandle<()>> = None;
            let mut pending_external: std::collections::VecDeque<JobSpec> =
                std::collections::VecDeque::new();
            let spawn_external = |job: JobSpec| {
                let metrics_l = metrics_w.clone();
                let reports_l = reports_w.clone();
                std::thread::spawn(move || {
                    let rep = run_job(job, threads);
                    metrics_l.lock().unwrap().record(&rep);
                    reports_l.lock().unwrap().push(rep);
                })
            };
            while let Ok(job) = rx.recv() {
                // reap a finished lane; promote the next pending external
                if external_lane.as_ref().is_some_and(|h| h.is_finished()) {
                    let _ = external_lane.take().unwrap().join();
                }
                if external_lane.is_none() {
                    if let Some(next) = pending_external.pop_front() {
                        external_lane = Some(spawn_external(next));
                        metrics_w
                            .lock()
                            .unwrap()
                            .observe_lane_depth(pending_external.len());
                    }
                }
                if !job.payload.is_external() && job.payload.len_hint() < SMALL_JOB {
                    small.push(job);
                    if small.len() >= 8 {
                        flush_small(&mut small);
                    }
                    continue;
                }
                if job.payload.is_external() && job.parallel {
                    if external_lane.is_none() {
                        external_lane = Some(spawn_external(job));
                    } else {
                        pending_external.push_back(job);
                    }
                    metrics_w
                        .lock()
                        .unwrap()
                        .observe_lane_depth(pending_external.len());
                    continue;
                }
                if job.payload.is_external() {
                    // non-parallel external: exclusive path — must not
                    // co-run with the lane either (same one-disk rule)
                    if let Some(h) = external_lane.take() {
                        let _ = h.join();
                    }
                }
                flush_small(&mut small);
                let rep = run_job(job, threads);
                metrics_w.lock().unwrap().record(&rep);
                reports_w.lock().unwrap().push(rep);
            }
            flush_small(&mut small);
            if let Some(h) = external_lane.take() {
                let _ = h.join();
            }
            // queue closed: run any still-pending externals one at a time
            for job in pending_external {
                let rep = run_job(job, threads);
                metrics_w.lock().unwrap().record(&rep);
                reports_w.lock().unwrap().push(rep);
            }
        });
        Coordinator {
            tx: Some(tx),
            handle: Some(handle),
            reports,
            metrics,
        }
    }

    /// Queue a job (non-blocking).
    pub fn submit(&self, job: JobSpec) {
        self.tx
            .as_ref()
            .expect("coordinator already drained")
            .send(job)
            .expect("dispatcher gone");
    }

    /// Close the queue, wait for all jobs, return reports in completion
    /// order.
    pub fn drain(mut self) -> (Vec<JobReport>, MetricsRegistry) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            h.join().expect("dispatcher panicked");
        }
        let reports = std::mem::take(&mut *self.reports.lock().unwrap());
        let metrics = std::mem::take(&mut *self.metrics.lock().unwrap());
        (reports, metrics)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Execute one job: route, sort, verify, report.
fn run_job(mut job: JobSpec, threads: usize) -> JobReport {
    let engine = route(&job);
    let t0 = std::time::Instant::now();
    let (n, sorted, external) = match &mut job.payload {
        // one arm per key domain used to live here; `with_keybuf!` is now
        // the single spelled-out dispatch over KeyBuf variants
        JobPayload::InMemory(buf) => crate::with_keybuf!(buf, v => {
            if threads > 1 && job.parallel {
                sort_parallel(engine, v, threads);
            } else {
                sort_sequential(engine, v);
            }
            (v.len(), is_sorted(v), None)
        }),
        JobPayload::External(ext) => {
            let ext_threads = if job.parallel { threads } else { 1 };
            let (n, ok, report) = run_external_job(job.id, ext, ext_threads);
            (n, ok, Some(report))
        }
    };
    let secs = t0.elapsed().as_secs_f64();
    JobReport {
        id: job.id,
        engine,
        n,
        secs,
        keys_per_sec: n as f64 / secs.max(1e-12),
        verified_sorted: sorted,
        threads,
        external,
    }
}

/// Run one out-of-core job and stream-verify its output file. The
/// pipeline's report rides along (zeroed default on failure) so the
/// coordinator can surface run counts, retrains and per-epoch splits.
fn run_external_job(
    id: u64,
    ext: &ExternalJob,
    threads: usize,
) -> (usize, bool, external::ExternalSortReport) {
    let mut cfg = ext.config.clone();
    if cfg.threads == 0 {
        cfg.threads = threads;
    }
    let outcome =
        external::sort_and_verify(ext.key_kind, ext.payload, &ext.input, &ext.output, &cfg);
    match outcome {
        Ok((rep, _sort_secs, ok)) => (rep.keys as usize, ok, rep),
        Err(e) => {
            eprintln!("external job {id} failed: {e}");
            (0, false, external::ExternalSortReport::default())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;
    use crate::SortEngine;

    fn job(id: u64, n: usize, parallel: bool) -> JobSpec {
        let mut rng = Xoshiro256pp::new(id);
        JobSpec {
            id,
            payload: JobPayload::InMemory(KeyBuf::U64(
                (0..n).map(|_| rng.next_u64()).collect(),
            )),
            engine: EngineChoice::Auto,
            parallel,
        }
    }

    #[test]
    fn runs_all_jobs_and_verifies() {
        let c = Coordinator::new(4);
        for i in 0..12 {
            c.submit(job(i, if i % 3 == 0 { 100_000 } else { 5_000 }, true));
        }
        let (reports, metrics) = c.drain();
        assert_eq!(reports.len(), 12);
        assert!(reports.iter().all(|r| r.verified_sorted));
        assert_eq!(metrics.total_jobs(), 12);
        assert!(metrics.total_keys() > 0);
    }

    #[test]
    fn string_and_record_jobs_run_in_memory() {
        use crate::key::{PrefixString, SortItem};
        let mut rng = Xoshiro256pp::new(123);
        // every key shares an 8-byte prefix, so all ordered-bits images
        // collide: routing sees a dup-heavy job and the engines lean
        // entirely on the tie-repair pass for the tail order
        let strs: Vec<PrefixString> = (0..20_000)
            .map(|_| {
                let mut b = [0u8; 12];
                b[..8].copy_from_slice(b"prefix--");
                b[8..].copy_from_slice(&rng.next_u32().to_be_bytes());
                PrefixString::from_bytes(&b)
            })
            .collect();
        let recs: Vec<SortItem<u64, 8>> = (0..20_000)
            .map(|i| SortItem::new(rng.next_below(1000), (i as u64).to_le_bytes()))
            .collect();
        let c = Coordinator::new(2);
        c.submit(JobSpec::auto(0, KeyBuf::Str(strs.clone())));
        c.submit(JobSpec::auto(1, KeyBuf::Rec64(recs)));
        let (reports, metrics) = c.drain();
        assert_eq!(reports.len(), 2);
        assert!(reports.iter().all(|r| r.verified_sorted), "full-order verified");
        assert_eq!(metrics.total_failures(), 0);
        assert_eq!(reports.iter().find(|r| r.id == 0).unwrap().n, strs.len());
    }

    #[test]
    fn explicit_engine_respected() {
        let c = Coordinator::new(2);
        let mut j = job(1, 50_000, false);
        j.engine = EngineChoice::Fixed(SortEngine::Ips2ra);
        c.submit(j);
        let (reports, _) = c.drain();
        assert_eq!(reports[0].engine, SortEngine::Ips2ra);
    }

    #[test]
    fn empty_coordinator_drains() {
        let c = Coordinator::new(2);
        let (reports, _) = c.drain();
        assert!(reports.is_empty());
    }

    #[test]
    fn external_jobs_admitted_alongside_in_memory() {
        use crate::external::{read_keys_file, write_keys_file, ExternalConfig};
        use crate::key::KeyKind;

        let dir = std::env::temp_dir();
        let input = dir.join(format!("aipso-coord-ext-{}.bin", std::process::id()));
        let output = dir.join(format!("aipso-coord-ext-{}.out.bin", std::process::id()));
        let mut rng = Xoshiro256pp::new(77);
        let keys: Vec<u64> = (0..40_000).map(|_| rng.next_u64()).collect();
        write_keys_file(&input, &keys).unwrap();

        let c = Coordinator::new(2);
        c.submit(job(0, 40_000, true)); // in-memory, exclusive path (≥ SMALL_JOB)
        c.submit(JobSpec::external(
            1,
            ExternalJob {
                input: input.clone(),
                output: output.clone(),
                key_kind: KeyKind::U64,
                payload: 0,
                // 8Ki-key chunks force several runs + a real merge
                config: ExternalConfig::with_budget(8192 * 8),
            },
        ));
        c.submit(job(2, 1_000, false)); // small-batch path
        let (reports, metrics) = c.drain();
        assert_eq!(reports.len(), 3);
        assert!(reports.iter().all(|r| r.verified_sorted));
        let ext = reports.iter().find(|r| r.id == 1).unwrap();
        let ext_report = ext.external.as_ref().expect("external report surfaced");
        assert_eq!(ext.n, keys.len());
        assert!(ext_report.runs >= 4, "runs={}", ext_report.runs);
        assert_eq!(ext_report.keys as usize, keys.len());
        assert!(!ext_report.epochs.is_empty(), "epoch counters surfaced");
        assert_eq!(metrics.total_failures(), 0);

        let mut want = keys;
        want.sort_unstable();
        assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }

    #[test]
    fn external_job_spill_codec_flows_through_the_report() {
        // An external job configured with the delta spill codec must sort
        // exactly and surface its compressed-vs-raw spill accounting in
        // JobReport.external.
        use crate::external::{read_keys_file, write_keys_file, ExternalConfig, SpillCodec};
        use crate::key::KeyKind;

        let dir = std::env::temp_dir();
        let input = dir.join(format!("aipso-coord-codec-{}.bin", std::process::id()));
        let output = dir.join(format!("aipso-coord-codec-{}.out.bin", std::process::id()));
        let mut rng = Xoshiro256pp::new(91);
        // duplicate-heavy ids so the delta codec has something to collapse
        let keys: Vec<u64> = (0..40_000).map(|_| rng.next_below(500)).collect();
        write_keys_file(&input, &keys).unwrap();

        let c = Coordinator::new(2);
        c.submit(JobSpec::external(
            7,
            ExternalJob {
                input: input.clone(),
                output: output.clone(),
                key_kind: KeyKind::U64,
                payload: 0,
                config: ExternalConfig {
                    spill_codec: SpillCodec::Delta,
                    ..ExternalConfig::with_budget(8192 * 8)
                },
            },
        ));
        let (reports, _) = c.drain();
        assert_eq!(reports.len(), 1);
        assert!(reports[0].verified_sorted);
        let ext = reports[0].external.as_ref().expect("external report");
        assert!(ext.runs >= 4, "runs={}", ext.runs);
        assert!(
            ext.spill_bytes * 2 < ext.spill_bytes_raw,
            "dup-heavy delta spill must compress ({} vs raw {})",
            ext.spill_bytes,
            ext.spill_bytes_raw
        );
        let mut want = keys;
        want.sort_unstable();
        assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&output);
    }

    #[test]
    fn two_external_jobs_serialize_on_the_overlap_lane() {
        use crate::external::{read_keys_file, write_keys_file, ExternalConfig};
        use crate::key::KeyKind;

        let dir = std::env::temp_dir();
        let mut rng = Xoshiro256pp::new(88);
        let mut files = Vec::new();
        for i in 0..2u64 {
            let input = dir.join(format!("aipso-coord-lane-{}-{i}.bin", std::process::id()));
            let output =
                dir.join(format!("aipso-coord-lane-{}-{i}.out.bin", std::process::id()));
            let keys: Vec<u64> = (0..30_000).map(|_| rng.next_u64()).collect();
            write_keys_file(&input, &keys).unwrap();
            files.push((input, output, keys));
        }

        let c = Coordinator::new(2);
        for (i, (input, output, _)) in files.iter().enumerate() {
            let mut spec = JobSpec::external(
                i as u64,
                ExternalJob {
                    input: input.clone(),
                    output: output.clone(),
                    key_kind: KeyKind::U64,
                    payload: 0,
                    config: ExternalConfig::with_budget(8192 * 8),
                },
            );
            // second external is non-parallel: the exclusive path must
            // wait for the overlap lane (one external sort per disk)
            spec.parallel = i == 0;
            c.submit(spec);
            // in-memory work interleaves with the external lane
            c.submit(job(10 + i as u64, 50_000, true));
        }
        let (reports, metrics) = c.drain();
        assert_eq!(reports.len(), 4);
        assert!(reports.iter().all(|r| r.verified_sorted));
        assert_eq!(metrics.total_failures(), 0);
        assert_eq!(reports.iter().filter(|r| r.external.is_some()).count(), 2);
        for (input, output, keys) in files {
            let mut want = keys;
            want.sort_unstable();
            assert_eq!(read_keys_file::<u64>(&output).unwrap(), want);
            let _ = std::fs::remove_file(&input);
            let _ = std::fs::remove_file(&output);
        }
    }
}
