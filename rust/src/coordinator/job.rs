//! Job types for the coordinator.

use std::path::PathBuf;

use crate::coordinator::router::EngineChoice;
use crate::external::{ExternalConfig, ExternalSortReport};
use crate::key::{KeyKind, PrefixString, SortItem, SortKey};
use crate::SortEngine;

/// Owned key buffer, covering the key domains of the pipeline: the
/// paper's two 64-bit domains, the narrow widths the external path's
/// self-describing spill format already handles, prefix-encoded string
/// keys, and records (key + fixed-width payload).
///
/// Code that needs the buffer's element type generically should go
/// through [`crate::with_keybuf!`] rather than matching the variants —
/// the macro is the single place the variant list is spelled out, so a
/// new domain is a one-site change instead of five drifting `match`es.
#[derive(Debug, Clone)]
pub enum KeyBuf {
    /// 64-bit doubles (the synthetic datasets).
    F64(Vec<f64>),
    /// 64-bit unsigned integers (the real-world datasets).
    U64(Vec<u64>),
    /// 32-bit floats (narrow synthetic streams).
    F32(Vec<f32>),
    /// 32-bit unsigned integers (narrow real-world streams).
    U32(Vec<u32>),
    /// Prefix-encoded string keys (16-byte bounded, 8-byte ordered-bits
    /// prefix + comparison tail — see [`PrefixString`]).
    Str(Vec<PrefixString>),
    /// Records: 64-bit unsigned key + 8-byte payload (row ids).
    Rec64(Vec<SortItem<u64, 8>>),
}

/// Run `$body` with `$v` bound to the vector inside any [`KeyBuf`]
/// variant — the one place the coordinator/CLI/bench key-domain dispatch
/// is spelled out. `$buf` may be any expression evaluating to a
/// `KeyBuf`, `&KeyBuf` or `&mut KeyBuf`; `$v` binds accordingly.
///
/// ```
/// use aipso::coordinator::KeyBuf;
/// let buf = KeyBuf::U32(vec![3, 1, 2]);
/// let n = aipso::with_keybuf!(&buf, v => v.len());
/// assert_eq!(n, 3);
/// ```
#[macro_export]
macro_rules! with_keybuf {
    ($buf:expr, $v:ident => $body:expr) => {
        match $buf {
            $crate::coordinator::KeyBuf::F64($v) => $body,
            $crate::coordinator::KeyBuf::U64($v) => $body,
            $crate::coordinator::KeyBuf::F32($v) => $body,
            $crate::coordinator::KeyBuf::U32($v) => $body,
            $crate::coordinator::KeyBuf::Str($v) => $body,
            $crate::coordinator::KeyBuf::Rec64($v) => $body,
        }
    };
}

impl KeyBuf {
    /// Number of keys in the buffer.
    pub fn len(&self) -> usize {
        crate::with_keybuf!(self, v => v.len())
    }

    /// True when the buffer holds no keys.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Duplicate fraction of a probe prefix (router heuristic input).
    /// Every domain probes through its ordered-bits image — only
    /// equality matters here, not order. For string keys equal bits
    /// means equal *prefix*, which is exactly the collision load the
    /// router's duplicate heuristics care about; records probe on the
    /// key alone (payloads never affect routing).
    pub fn probe_duplicate_fraction(&self, probe: usize) -> f64 {
        crate::with_keybuf!(self, v => {
            probe_dup(v.iter().map(|k| SortKey::to_bits_ordered(*k)), probe)
        })
    }
}

fn probe_dup(keys: impl Iterator<Item = u64>, probe: usize) -> f64 {
    let mut sample: Vec<u64> = keys.take(probe).collect();
    if sample.len() < 2 {
        return 0.0;
    }
    sample.sort_unstable();
    let distinct = 1 + sample.windows(2).filter(|w| w[0] != w[1]).count();
    1.0 - distinct as f64 / sample.len() as f64
}

/// An out-of-core sort request: sort the binary key file `input` into
/// `output` under `config.memory_budget` bytes of working set.
#[derive(Debug, Clone)]
pub struct ExternalJob {
    /// Input key file (`aipso gen --out` format: self-describing header +
    /// fixed-width LE keys, or a legacy headerless 8-byte file).
    pub input: PathBuf,
    /// Where the sorted output file is written.
    pub output: PathBuf,
    /// Which key domain to sort the file as (validated against the
    /// input's header when one is present).
    pub key_kind: KeyKind,
    /// Per-record payload width in bytes (0 = bare keys). Must be one of
    /// [`crate::key::DISPATCH_PAYLOADS`]; the spill format carries the
    /// payload in a per-entry lane (v4/v5 headers).
    pub payload: usize,
    /// Budget, threading and merge knobs for the external sorter.
    pub config: ExternalConfig,
}

/// What a job operates on: resident keys, or an on-disk dataset too large
/// to hold in memory.
#[derive(Debug, Clone)]
pub enum JobPayload {
    /// Keys held in memory, sorted on the core pool.
    InMemory(KeyBuf),
    /// An on-disk dataset, sorted by the out-of-core pipeline.
    External(ExternalJob),
}

impl JobPayload {
    /// Key count for admission decisions. External jobs read the input's
    /// spill header (falling back to `bytes / 8` for headerless v0
    /// files); an unreadable or malformed file admits as "huge" — the
    /// exclusive path then fails the job (`verified_sorted: false`,
    /// `n: 0`) and logs the IO error to stderr.
    pub fn len_hint(&self) -> usize {
        match self {
            JobPayload::InMemory(keys) => keys.len(),
            JobPayload::External(ext) => crate::external::file_key_count(&ext.input)
                .map(|n| n as usize)
                .unwrap_or(usize::MAX),
        }
    }

    /// True for out-of-core jobs.
    pub fn is_external(&self) -> bool {
        matches!(self, JobPayload::External(_))
    }
}

/// A sort request.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Caller-chosen identifier, echoed in the [`JobReport`].
    pub id: u64,
    /// The keys (or on-disk dataset) to sort.
    pub payload: JobPayload,
    /// Fixed engine, or automatic routing.
    pub engine: EngineChoice,
    /// Allow the coordinator to use the parallel engines (and, for
    /// external jobs, the overlapped admission lane).
    pub parallel: bool,
}

impl JobSpec {
    /// In-memory job with automatic engine routing.
    pub fn auto(id: u64, keys: KeyBuf) -> JobSpec {
        JobSpec {
            id,
            payload: JobPayload::InMemory(keys),
            engine: EngineChoice::Auto,
            parallel: true,
        }
    }

    /// Out-of-core job. Admitted on the coordinator's overlap lane: it
    /// runs concurrently with in-memory jobs (its memory is bounded by its
    /// own budget and much of its time is disk-bound), but never alongside
    /// another external job — even with `ExternalConfig::spill_dirs`
    /// striping runs across several disks, two jobs would interleave their
    /// spill traffic on every stripe rather than partition it, so the
    /// serializing lane keeps each job's IO (sync or pooled, see
    /// `external::io`) sequential per device.
    pub fn external(id: u64, job: ExternalJob) -> JobSpec {
        JobSpec {
            id,
            payload: JobPayload::External(job),
            engine: EngineChoice::Auto,
            parallel: true,
        }
    }
}

/// Completion record for one job.
#[derive(Debug, Clone)]
pub struct JobReport {
    /// The submitting caller's job id.
    pub id: u64,
    /// Engine the router selected (or the caller fixed).
    pub engine: SortEngine,
    /// Keys sorted.
    pub n: usize,
    /// Wall-clock time spent sorting.
    pub secs: f64,
    /// Sorting rate (the paper's metric).
    pub keys_per_sec: f64,
    /// Whether the output passed the post-sort verification.
    pub verified_sorted: bool,
    /// Worker threads the job was admitted with.
    pub threads: usize,
    /// The out-of-core pipeline's report when the job ran through the
    /// external path (`None` = in-memory job). Surfaces the run counts,
    /// mid-stream `retrains`, per-epoch learned/fallback chunk splits and
    /// the spill-codec accounting (`spill_bytes` vs `spill_bytes_raw` —
    /// what the configured `ExternalConfig::spill_codec` actually wrote
    /// vs the fixed-width baseline); a failed external job carries a
    /// zeroed default report so callers can still tell the paths apart.
    pub external: Option<ExternalSortReport>,
}

impl JobReport {
    /// Serialize for machine consumption — the per-job entries of `aipso
    /// serve --metrics-json` and the `report` section of an external
    /// job's telemetry document.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("id".to_string(), Json::Num(self.id as f64));
        m.insert(
            "engine".to_string(),
            Json::Str(self.engine.paper_name(self.threads > 1).to_string()),
        );
        m.insert("n".to_string(), Json::Num(self.n as f64));
        m.insert("secs".to_string(), Json::Num(self.secs));
        m.insert("keys_per_sec".to_string(), Json::Num(self.keys_per_sec));
        m.insert("verified_sorted".to_string(), Json::Bool(self.verified_sorted));
        m.insert("threads".to_string(), Json::Num(self.threads as f64));
        m.insert(
            "external".to_string(),
            self.external
                .as_ref()
                .map(ExternalSortReport::to_json)
                .unwrap_or(Json::Null),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keybuf_len_and_dup() {
        let b = KeyBuf::U64(vec![1, 1, 1, 2]);
        assert_eq!(b.len(), 4);
        assert!(!b.is_empty());
        assert!((b.probe_duplicate_fraction(4) - 0.5).abs() < 1e-12);
        let f = KeyBuf::F64(vec![1.0, 2.0, 3.0]);
        assert_eq!(f.probe_duplicate_fraction(3), 0.0);
        assert_eq!(KeyBuf::U64(vec![]).probe_duplicate_fraction(10), 0.0);
    }

    #[test]
    fn keybuf_narrow_widths() {
        let b = KeyBuf::U32(vec![9, 9, 9, 3]);
        assert_eq!(b.len(), 4);
        assert!((b.probe_duplicate_fraction(4) - 0.5).abs() < 1e-12);
        let f = KeyBuf::F32(vec![1.5, 1.5, 2.5, 3.5]);
        assert_eq!(f.len(), 4);
        assert!((f.probe_duplicate_fraction(4) - 0.25).abs() < 1e-12);
        assert_eq!(KeyBuf::F32(vec![]).probe_duplicate_fraction(10), 0.0);
        assert_eq!(KeyBuf::U32(vec![7]).probe_duplicate_fraction(10), 0.0);
    }

    #[test]
    fn keybuf_strings_and_records_dispatch() {
        // prefix-collided strings count as duplicates in the probe: the
        // first 8 bytes ("prefix-a") collide for three of the four keys,
        // so the router sees 2 distinct bit patterns out of 4
        let s = KeyBuf::Str(vec![
            PrefixString::from_bytes(b"prefix-aa"),
            PrefixString::from_bytes(b"prefix-aa"),
            PrefixString::from_bytes(b"prefix-ab"),
            PrefixString::from_bytes(b"zzz"),
        ]);
        assert_eq!(s.len(), 4);
        assert!((s.probe_duplicate_fraction(4) - 0.5).abs() < 1e-12);
        // records probe on the key alone — distinct payloads don't make
        // equal keys distinct
        let r = KeyBuf::Rec64(vec![
            SortItem::new(5u64, [1u8; 8]),
            SortItem::new(5u64, [2u8; 8]),
            SortItem::new(9u64, [3u8; 8]),
        ]);
        assert_eq!(r.len(), 3);
        assert!((r.probe_duplicate_fraction(3) - (1.0 - 2.0 / 3.0)).abs() < 1e-12);
    }

    #[test]
    fn payload_len_hints() {
        let p = JobPayload::InMemory(KeyBuf::U64(vec![1, 2, 3]));
        assert_eq!(p.len_hint(), 3);
        assert!(!p.is_external());
        let missing = JobPayload::External(ExternalJob {
            input: PathBuf::from("/definitely/not/a/file.bin"),
            output: PathBuf::from("/tmp/out.bin"),
            key_kind: KeyKind::U64,
            payload: 0,
            config: ExternalConfig::default(),
        });
        assert!(missing.is_external());
        assert_eq!(missing.len_hint(), usize::MAX);
    }

    #[test]
    fn external_len_hint_reads_the_header_count() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("aipso-job-hint-{}.bin", std::process::id()));
        crate::external::write_keys_file::<u32>(&p, &[1, 2, 3, 4, 5]).unwrap();
        let payload = JobPayload::External(ExternalJob {
            input: p.clone(),
            output: dir.join("out.bin"),
            key_kind: KeyKind::U32,
            payload: 0,
            config: ExternalConfig::default(),
        });
        // bytes/8 would undercount a 4-byte file; the header knows better
        assert_eq!(payload.len_hint(), 5);
        let _ = std::fs::remove_file(&p);
    }
}
