//! Job types for the coordinator.

use crate::coordinator::router::EngineChoice;
use crate::SortEngine;

/// Owned key buffer, matching the paper's two key domains.
#[derive(Debug, Clone)]
pub enum KeyBuf {
    F64(Vec<f64>),
    U64(Vec<u64>),
}

impl KeyBuf {
    pub fn len(&self) -> usize {
        match self {
            KeyBuf::F64(v) => v.len(),
            KeyBuf::U64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Duplicate fraction of a probe prefix (router heuristic input).
    pub fn probe_duplicate_fraction(&self, probe: usize) -> f64 {
        match self {
            KeyBuf::F64(v) => probe_dup(v.iter().map(|x| x.to_bits()), probe),
            KeyBuf::U64(v) => probe_dup(v.iter().copied(), probe),
        }
    }
}

fn probe_dup(keys: impl Iterator<Item = u64>, probe: usize) -> f64 {
    let mut sample: Vec<u64> = keys.take(probe).collect();
    if sample.len() < 2 {
        return 0.0;
    }
    sample.sort_unstable();
    let distinct = 1 + sample.windows(2).filter(|w| w[0] != w[1]).count();
    1.0 - distinct as f64 / sample.len() as f64
}

/// A sort request.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: u64,
    pub keys: KeyBuf,
    pub engine: EngineChoice,
    /// Allow the coordinator to use the parallel engines.
    pub parallel: bool,
}

impl JobSpec {
    pub fn auto(id: u64, keys: KeyBuf) -> JobSpec {
        JobSpec {
            id,
            keys,
            engine: EngineChoice::Auto,
            parallel: true,
        }
    }
}

/// Completion record for one job.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub id: u64,
    pub engine: SortEngine,
    pub n: usize,
    pub secs: f64,
    pub keys_per_sec: f64,
    pub verified_sorted: bool,
    pub threads: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keybuf_len_and_dup() {
        let b = KeyBuf::U64(vec![1, 1, 1, 2]);
        assert_eq!(b.len(), 4);
        assert!(!b.is_empty());
        assert!((b.probe_duplicate_fraction(4) - 0.5).abs() < 1e-12);
        let f = KeyBuf::F64(vec![1.0, 2.0, 3.0]);
        assert_eq!(f.probe_duplicate_fraction(3), 0.0);
        assert_eq!(KeyBuf::U64(vec![]).probe_duplicate_fraction(10), 0.0);
    }
}
