//! Job types for the coordinator.

use std::path::PathBuf;

use crate::coordinator::router::EngineChoice;
use crate::external::{ExternalConfig, ExternalSortReport};
use crate::key::KeyKind;
use crate::SortEngine;

/// Owned key buffer, covering the four key widths of the pipeline (the
/// paper's two 64-bit domains plus the narrow widths the external path's
/// self-describing spill format already handles).
#[derive(Debug, Clone)]
pub enum KeyBuf {
    /// 64-bit doubles (the synthetic datasets).
    F64(Vec<f64>),
    /// 64-bit unsigned integers (the real-world datasets).
    U64(Vec<u64>),
    /// 32-bit floats (narrow synthetic streams).
    F32(Vec<f32>),
    /// 32-bit unsigned integers (narrow real-world streams).
    U32(Vec<u32>),
}

impl KeyBuf {
    /// Number of keys in the buffer.
    pub fn len(&self) -> usize {
        match self {
            KeyBuf::F64(v) => v.len(),
            KeyBuf::U64(v) => v.len(),
            KeyBuf::F32(v) => v.len(),
            KeyBuf::U32(v) => v.len(),
        }
    }

    /// True when the buffer holds no keys.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Duplicate fraction of a probe prefix (router heuristic input).
    /// Narrow widths widen their bit patterns into the shared u64 probe —
    /// only equality matters here, not order.
    pub fn probe_duplicate_fraction(&self, probe: usize) -> f64 {
        match self {
            KeyBuf::F64(v) => probe_dup(v.iter().map(|x| x.to_bits()), probe),
            KeyBuf::U64(v) => probe_dup(v.iter().copied(), probe),
            KeyBuf::F32(v) => probe_dup(v.iter().map(|x| u64::from(x.to_bits())), probe),
            KeyBuf::U32(v) => probe_dup(v.iter().map(|&x| u64::from(x)), probe),
        }
    }
}

fn probe_dup(keys: impl Iterator<Item = u64>, probe: usize) -> f64 {
    let mut sample: Vec<u64> = keys.take(probe).collect();
    if sample.len() < 2 {
        return 0.0;
    }
    sample.sort_unstable();
    let distinct = 1 + sample.windows(2).filter(|w| w[0] != w[1]).count();
    1.0 - distinct as f64 / sample.len() as f64
}

/// An out-of-core sort request: sort the binary key file `input` into
/// `output` under `config.memory_budget` bytes of working set.
#[derive(Debug, Clone)]
pub struct ExternalJob {
    /// Input key file (`aipso gen --out` format: self-describing header +
    /// fixed-width LE keys, or a legacy headerless 8-byte file).
    pub input: PathBuf,
    /// Where the sorted output file is written.
    pub output: PathBuf,
    /// Which of the four key domains to sort the file as (validated
    /// against the input's header when one is present).
    pub key_kind: KeyKind,
    /// Budget, threading and merge knobs for the external sorter.
    pub config: ExternalConfig,
}

/// What a job operates on: resident keys, or an on-disk dataset too large
/// to hold in memory.
#[derive(Debug, Clone)]
pub enum JobPayload {
    /// Keys held in memory, sorted on the core pool.
    InMemory(KeyBuf),
    /// An on-disk dataset, sorted by the out-of-core pipeline.
    External(ExternalJob),
}

impl JobPayload {
    /// Key count for admission decisions. External jobs read the input's
    /// spill header (falling back to `bytes / 8` for headerless v0
    /// files); an unreadable or malformed file admits as "huge" — the
    /// exclusive path then fails the job (`verified_sorted: false`,
    /// `n: 0`) and logs the IO error to stderr.
    pub fn len_hint(&self) -> usize {
        match self {
            JobPayload::InMemory(keys) => keys.len(),
            JobPayload::External(ext) => crate::external::file_key_count(&ext.input)
                .map(|n| n as usize)
                .unwrap_or(usize::MAX),
        }
    }

    /// True for out-of-core jobs.
    pub fn is_external(&self) -> bool {
        matches!(self, JobPayload::External(_))
    }
}

/// A sort request.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Caller-chosen identifier, echoed in the [`JobReport`].
    pub id: u64,
    /// The keys (or on-disk dataset) to sort.
    pub payload: JobPayload,
    /// Fixed engine, or automatic routing.
    pub engine: EngineChoice,
    /// Allow the coordinator to use the parallel engines (and, for
    /// external jobs, the overlapped admission lane).
    pub parallel: bool,
}

impl JobSpec {
    /// In-memory job with automatic engine routing.
    pub fn auto(id: u64, keys: KeyBuf) -> JobSpec {
        JobSpec {
            id,
            payload: JobPayload::InMemory(keys),
            engine: EngineChoice::Auto,
            parallel: true,
        }
    }

    /// Out-of-core job. Admitted on the coordinator's overlap lane: it
    /// runs concurrently with in-memory jobs (its memory is bounded by its
    /// own budget and much of its time is disk-bound), but never alongside
    /// another external job — even with `ExternalConfig::spill_dirs`
    /// striping runs across several disks, two jobs would interleave their
    /// spill traffic on every stripe rather than partition it, so the
    /// serializing lane keeps each job's IO (sync or pooled, see
    /// `external::io`) sequential per device.
    pub fn external(id: u64, job: ExternalJob) -> JobSpec {
        JobSpec {
            id,
            payload: JobPayload::External(job),
            engine: EngineChoice::Auto,
            parallel: true,
        }
    }
}

/// Completion record for one job.
#[derive(Debug, Clone)]
pub struct JobReport {
    /// The submitting caller's job id.
    pub id: u64,
    /// Engine the router selected (or the caller fixed).
    pub engine: SortEngine,
    /// Keys sorted.
    pub n: usize,
    /// Wall-clock time spent sorting.
    pub secs: f64,
    /// Sorting rate (the paper's metric).
    pub keys_per_sec: f64,
    /// Whether the output passed the post-sort verification.
    pub verified_sorted: bool,
    /// Worker threads the job was admitted with.
    pub threads: usize,
    /// The out-of-core pipeline's report when the job ran through the
    /// external path (`None` = in-memory job). Surfaces the run counts,
    /// mid-stream `retrains`, per-epoch learned/fallback chunk splits and
    /// the spill-codec accounting (`spill_bytes` vs `spill_bytes_raw` —
    /// what the configured `ExternalConfig::spill_codec` actually wrote
    /// vs the fixed-width baseline); a failed external job carries a
    /// zeroed default report so callers can still tell the paths apart.
    pub external: Option<ExternalSortReport>,
}

impl JobReport {
    /// Serialize for machine consumption — the per-job entries of `aipso
    /// serve --metrics-json` and the `report` section of an external
    /// job's telemetry document.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("id".to_string(), Json::Num(self.id as f64));
        m.insert(
            "engine".to_string(),
            Json::Str(self.engine.paper_name(self.threads > 1).to_string()),
        );
        m.insert("n".to_string(), Json::Num(self.n as f64));
        m.insert("secs".to_string(), Json::Num(self.secs));
        m.insert("keys_per_sec".to_string(), Json::Num(self.keys_per_sec));
        m.insert("verified_sorted".to_string(), Json::Bool(self.verified_sorted));
        m.insert("threads".to_string(), Json::Num(self.threads as f64));
        m.insert(
            "external".to_string(),
            self.external
                .as_ref()
                .map(ExternalSortReport::to_json)
                .unwrap_or(Json::Null),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keybuf_len_and_dup() {
        let b = KeyBuf::U64(vec![1, 1, 1, 2]);
        assert_eq!(b.len(), 4);
        assert!(!b.is_empty());
        assert!((b.probe_duplicate_fraction(4) - 0.5).abs() < 1e-12);
        let f = KeyBuf::F64(vec![1.0, 2.0, 3.0]);
        assert_eq!(f.probe_duplicate_fraction(3), 0.0);
        assert_eq!(KeyBuf::U64(vec![]).probe_duplicate_fraction(10), 0.0);
    }

    #[test]
    fn keybuf_narrow_widths() {
        let b = KeyBuf::U32(vec![9, 9, 9, 3]);
        assert_eq!(b.len(), 4);
        assert!((b.probe_duplicate_fraction(4) - 0.5).abs() < 1e-12);
        let f = KeyBuf::F32(vec![1.5, 1.5, 2.5, 3.5]);
        assert_eq!(f.len(), 4);
        assert!((f.probe_duplicate_fraction(4) - 0.25).abs() < 1e-12);
        assert_eq!(KeyBuf::F32(vec![]).probe_duplicate_fraction(10), 0.0);
        assert_eq!(KeyBuf::U32(vec![7]).probe_duplicate_fraction(10), 0.0);
    }

    #[test]
    fn payload_len_hints() {
        let p = JobPayload::InMemory(KeyBuf::U64(vec![1, 2, 3]));
        assert_eq!(p.len_hint(), 3);
        assert!(!p.is_external());
        let missing = JobPayload::External(ExternalJob {
            input: PathBuf::from("/definitely/not/a/file.bin"),
            output: PathBuf::from("/tmp/out.bin"),
            key_kind: KeyKind::U64,
            config: ExternalConfig::default(),
        });
        assert!(missing.is_external());
        assert_eq!(missing.len_hint(), usize::MAX);
    }

    #[test]
    fn external_len_hint_reads_the_header_count() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("aipso-job-hint-{}.bin", std::process::id()));
        crate::external::write_keys_file::<u32>(&p, &[1, 2, 3, 4, 5]).unwrap();
        let payload = JobPayload::External(ExternalJob {
            input: p.clone(),
            output: dir.join("out.bin"),
            key_kind: KeyKind::U32,
            config: ExternalConfig::default(),
        });
        // bytes/8 would undercount a 4-byte file; the header knows better
        assert_eq!(payload.len_hint(), 5);
        let _ = std::fs::remove_file(&p);
    }
}
