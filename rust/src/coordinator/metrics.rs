//! Per-engine aggregate metrics for the coordinator, backed by the
//! observability layer's [`MetricSet`].
//!
//! The registry keeps its original surface (`record`, `total_*`,
//! `engines`, `report`) but the scalar aggregation now lives in a
//! per-service [`MetricSet`] instance — the same counter/histogram
//! machinery the pipeline's process-global telemetry uses — so the
//! coordinator's accounting exports through the identical JSON shape
//! ([`MetricsRegistry::to_json`], served by `aipso serve
//! --metrics-json`). Unlike the global helpers this instance is *not*
//! gated on [`crate::obs::enabled`]: the coordinator always accounted
//! for its jobs, and still does.

use std::collections::BTreeMap;

use crate::coordinator::job::JobReport;
use crate::obs::metrics::{MetricSet, DEPTH_BUCKETS};
use crate::util::fmt;
use crate::util::json::Json;

/// Counter: jobs completed across all engines.
pub const C_JOBS: &str = "coord.jobs.completed";
/// Counter: keys sorted across all engines.
pub const C_KEYS: &str = "coord.keys.sorted";
/// Counter: jobs whose output failed verification.
pub const C_FAILURES: &str = "coord.jobs.failed";

/// Aggregate counters for one engine.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Jobs completed.
    pub jobs: usize,
    /// Keys sorted across those jobs.
    pub keys: usize,
    /// Total sorting seconds.
    pub secs: f64,
    /// Jobs whose output failed verification.
    pub failures: usize,
}

/// Per-engine metrics aggregated over a coordinator's lifetime.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    per_engine: BTreeMap<&'static str, EngineStats>,
    set: MetricSet,
}

impl MetricsRegistry {
    /// Fold one completed job into the aggregates.
    pub fn record(&mut self, rep: &JobReport) {
        let e = self
            .per_engine
            .entry(rep.engine.paper_name(rep.threads > 1))
            .or_default();
        e.jobs += 1;
        e.keys += rep.n;
        e.secs += rep.secs;
        if !rep.verified_sorted {
            e.failures += 1;
        }
        self.set.add(C_JOBS, 1);
        self.set.add(C_KEYS, rep.n as u64);
        if !rep.verified_sorted {
            self.set.add(C_FAILURES, 1);
        }
    }

    /// Sample the overlap lane's pending-external queue depth into the
    /// [`crate::obs::M_LANE_DEPTH`] histogram (the dispatcher calls this
    /// at every lane event: park, promote, spawn).
    pub fn observe_lane_depth(&self, depth: usize) {
        self.set
            .observe(crate::obs::M_LANE_DEPTH, DEPTH_BUCKETS, depth as f64);
    }

    /// Jobs recorded across all engines.
    pub fn total_jobs(&self) -> usize {
        self.set.counter(C_JOBS) as usize
    }

    /// Keys sorted across all engines.
    pub fn total_keys(&self) -> usize {
        self.set.counter(C_KEYS) as usize
    }

    /// Verification failures across all engines.
    pub fn total_failures(&self) -> usize {
        self.set.counter(C_FAILURES) as usize
    }

    /// Iterate (engine paper name, stats) pairs in name order.
    pub fn engines(&self) -> impl Iterator<Item = (&&'static str, &EngineStats)> {
        self.per_engine.iter()
    }

    /// Machine-readable dump: per-engine aggregates plus the backing
    /// registry's counters and histograms (same shape as the telemetry
    /// document's `metrics` section). `aipso serve --metrics-json` writes
    /// this.
    pub fn to_json(&self) -> Json {
        let mut engines = BTreeMap::new();
        for (name, e) in &self.per_engine {
            let mut o = BTreeMap::new();
            o.insert("jobs".to_string(), Json::Num(e.jobs as f64));
            o.insert("keys".to_string(), Json::Num(e.keys as f64));
            o.insert("secs".to_string(), Json::Num(e.secs));
            o.insert("failures".to_string(), Json::Num(e.failures as f64));
            engines.insert(name.to_string(), Json::Obj(o));
        }
        let mut m = BTreeMap::new();
        m.insert("engines".to_string(), Json::Obj(engines));
        m.insert("metrics".to_string(), self.set.snapshot().to_json());
        Json::Obj(m)
    }

    /// Markdown summary table.
    pub fn report(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .per_engine
            .iter()
            .map(|(name, e)| {
                vec![
                    name.to_string(),
                    e.jobs.to_string(),
                    fmt::keys(e.keys),
                    fmt::secs(e.secs),
                    fmt::rate(e.keys as f64 / e.secs.max(1e-12)),
                    e.failures.to_string(),
                ]
            })
            .collect();
        fmt::markdown_table(
            &["engine", "jobs", "keys", "time", "rate", "failures"],
            &rows,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::SortEngine;

    fn rep(engine: SortEngine, n: usize, ok: bool) -> JobReport {
        JobReport {
            id: 0,
            engine,
            n,
            secs: 0.5,
            keys_per_sec: n as f64 / 0.5,
            verified_sorted: ok,
            threads: 4,
            external: None,
        }
    }

    #[test]
    fn aggregates_per_engine() {
        let mut m = MetricsRegistry::default();
        m.record(&rep(SortEngine::Aips2o, 1000, true));
        m.record(&rep(SortEngine::Aips2o, 2000, true));
        m.record(&rep(SortEngine::Ips4o, 500, false));
        assert_eq!(m.total_jobs(), 3);
        assert_eq!(m.total_keys(), 3500);
        assert_eq!(m.total_failures(), 1);
        let report = m.report();
        assert!(report.contains("AIPS2o"));
        assert!(report.contains("IPS4o"));
    }

    #[test]
    fn totals_come_from_the_metric_set() {
        // The registry's totals are the MetricSet counters — not a
        // parallel tally that could drift from the export.
        let mut m = MetricsRegistry::default();
        m.record(&rep(SortEngine::Aips2o, 1234, false));
        let j = m.to_json();
        let counters = j.get("metrics").and_then(|s| s.get("counters")).unwrap();
        assert_eq!(
            counters.get(C_JOBS).and_then(Json::as_usize),
            Some(m.total_jobs())
        );
        assert_eq!(
            counters.get(C_KEYS).and_then(Json::as_usize),
            Some(1234)
        );
        assert_eq!(counters.get(C_FAILURES).and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn lane_depth_lands_in_the_histogram_export() {
        let m = MetricsRegistry::default();
        m.observe_lane_depth(0);
        m.observe_lane_depth(3);
        let j = m.to_json();
        let h = j
            .get("metrics")
            .and_then(|s| s.get("histograms"))
            .and_then(|hs| hs.get(crate::obs::M_LANE_DEPTH))
            .expect("lane-depth histogram exported");
        assert_eq!(h.get("count").and_then(Json::as_usize), Some(2));
        assert_eq!(h.get("max").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn engine_breakdown_serializes() {
        let mut m = MetricsRegistry::default();
        m.record(&rep(SortEngine::Aips2o, 1000, true));
        let j = m.to_json();
        let engines = j.get("engines").unwrap();
        let (name, _) = m.engines().next().unwrap();
        let e = engines.get(name).expect("engine entry present");
        assert_eq!(e.get("jobs").and_then(Json::as_usize), Some(1));
        assert_eq!(e.get("keys").and_then(Json::as_usize), Some(1000));
    }
}
