//! Per-engine aggregate metrics for the coordinator.

use std::collections::BTreeMap;

use crate::coordinator::job::JobReport;
use crate::util::fmt;

/// Aggregate counters for one engine.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    /// Jobs completed.
    pub jobs: usize,
    /// Keys sorted across those jobs.
    pub keys: usize,
    /// Total sorting seconds.
    pub secs: f64,
    /// Jobs whose output failed verification.
    pub failures: usize,
}

/// Per-engine metrics aggregated over a coordinator's lifetime.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    per_engine: BTreeMap<&'static str, EngineStats>,
}

impl MetricsRegistry {
    /// Fold one completed job into the aggregates.
    pub fn record(&mut self, rep: &JobReport) {
        let e = self
            .per_engine
            .entry(rep.engine.paper_name(rep.threads > 1))
            .or_default();
        e.jobs += 1;
        e.keys += rep.n;
        e.secs += rep.secs;
        if !rep.verified_sorted {
            e.failures += 1;
        }
    }

    /// Jobs recorded across all engines.
    pub fn total_jobs(&self) -> usize {
        self.per_engine.values().map(|e| e.jobs).sum()
    }

    /// Keys sorted across all engines.
    pub fn total_keys(&self) -> usize {
        self.per_engine.values().map(|e| e.keys).sum()
    }

    /// Verification failures across all engines.
    pub fn total_failures(&self) -> usize {
        self.per_engine.values().map(|e| e.failures).sum()
    }

    /// Iterate (engine paper name, stats) pairs in name order.
    pub fn engines(&self) -> impl Iterator<Item = (&&'static str, &EngineStats)> {
        self.per_engine.iter()
    }

    /// Markdown summary table.
    pub fn report(&self) -> String {
        let rows: Vec<Vec<String>> = self
            .per_engine
            .iter()
            .map(|(name, e)| {
                vec![
                    name.to_string(),
                    e.jobs.to_string(),
                    fmt::keys(e.keys),
                    fmt::secs(e.secs),
                    fmt::rate(e.keys as f64 / e.secs.max(1e-12)),
                    e.failures.to_string(),
                ]
            })
            .collect();
        fmt::markdown_table(
            &["engine", "jobs", "keys", "time", "rate", "failures"],
            &rows,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::SortEngine;

    fn rep(engine: SortEngine, n: usize, ok: bool) -> JobReport {
        JobReport {
            id: 0,
            engine,
            n,
            secs: 0.5,
            keys_per_sec: n as f64 / 0.5,
            verified_sorted: ok,
            threads: 4,
            external: None,
        }
    }

    #[test]
    fn aggregates_per_engine() {
        let mut m = MetricsRegistry::default();
        m.record(&rep(SortEngine::Aips2o, 1000, true));
        m.record(&rep(SortEngine::Aips2o, 2000, true));
        m.record(&rep(SortEngine::Ips4o, 500, false));
        assert_eq!(m.total_jobs(), 3);
        assert_eq!(m.total_keys(), 3500);
        assert_eq!(m.total_failures(), 1);
        let report = m.report();
        assert!(report.contains("AIPS2o"));
        assert!(report.contains("IPS4o"));
    }
}
