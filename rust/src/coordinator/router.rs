//! Engine routing policy.
//!
//! Mirrors the paper's own guidance: AIPS²o for large inputs ("a practical
//! algorithm" — Section 5.2), IPS⁴o when a probe shows heavy duplication
//! (its equality buckets win RootDups-like inputs), pdqsort for small jobs
//! where model/sampling overhead cannot amortize.

use crate::coordinator::job::{JobPayload, JobSpec};
use crate::SortEngine;

/// How a job selects its sorting engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineChoice {
    /// Let the router pick from the job's shape.
    Auto,
    /// Force a specific engine.
    Fixed(SortEngine),
}

/// Inputs below this always go to pdqsort.
pub const SMALL_INPUT: usize = 1 << 14;
/// Probe size for the duplicate heuristic.
pub const PROBE: usize = 1024;
/// Probe duplicate fraction above which IPS⁴o is preferred.
pub const DUP_THRESHOLD: f64 = 0.30;

/// Counter: jobs routed to the external pipeline.
pub const C_ROUTE_EXTERNAL: &str = "coord.route.external";
/// Counter: jobs with a caller-fixed engine.
pub const C_ROUTE_FIXED: &str = "coord.route.fixed";
/// Counter: auto-routed small jobs (pdqsort).
pub const C_ROUTE_SMALL: &str = "coord.route.auto.small";
/// Counter: auto-routed duplicate-heavy jobs (IPS⁴o).
pub const C_ROUTE_DUP: &str = "coord.route.auto.dup-heavy";
/// Counter: auto-routed large smooth jobs (AIPS²o).
pub const C_ROUTE_LARGE: &str = "coord.route.auto.large";

/// Pick the engine for a job (paper Section 5.2's guidance; see the
/// module docs for the policy). While observability is enabled, every
/// decision bumps its `coord.route.*` counter so a service dump shows
/// which policy arms actually fire.
pub fn route(job: &JobSpec) -> SortEngine {
    // Out-of-core jobs always run the external pipeline; their engine
    // label follows the configured run-generation strategy (learned runs
    // report as AIPS²o, the baseline as IPS⁴o). A `Fixed` choice cannot be
    // honored there, so it is ignored rather than misattributed in the
    // metrics.
    let keys = match &job.payload {
        JobPayload::External(ext) => {
            crate::obs::metrics::counter_add(C_ROUTE_EXTERNAL, 1);
            return match ext.config.run_gen {
                crate::external::RunGen::LearnedReuse => SortEngine::Aips2o,
                crate::external::RunGen::Ips4o => SortEngine::Ips4o,
            };
        }
        JobPayload::InMemory(keys) => keys,
    };
    match job.engine {
        EngineChoice::Fixed(e) => {
            crate::obs::metrics::counter_add(C_ROUTE_FIXED, 1);
            e
        }
        EngineChoice::Auto => {
            let n = keys.len();
            if n < SMALL_INPUT {
                crate::obs::metrics::counter_add(C_ROUTE_SMALL, 1);
                SortEngine::StdSort
            } else if keys.probe_duplicate_fraction(PROBE) > DUP_THRESHOLD {
                crate::obs::metrics::counter_add(C_ROUTE_DUP, 1);
                SortEngine::Ips4o
            } else {
                crate::obs::metrics::counter_add(C_ROUTE_LARGE, 1);
                SortEngine::Aips2o
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::KeyBuf;

    fn spec(keys: KeyBuf) -> JobSpec {
        JobSpec::auto(0, keys)
    }

    #[test]
    fn small_jobs_to_pdqsort() {
        let j = spec(KeyBuf::U64((0..100).collect()));
        assert_eq!(route(&j), SortEngine::StdSort);
    }

    #[test]
    fn large_smooth_jobs_to_aips2o() {
        let j = spec(KeyBuf::U64((0..100_000).collect()));
        assert_eq!(route(&j), SortEngine::Aips2o);
    }

    #[test]
    fn duplicate_heavy_jobs_to_ips4o() {
        let j = spec(KeyBuf::U64((0..100_000).map(|i| i % 5).collect()));
        assert_eq!(route(&j), SortEngine::Ips4o);
    }

    #[test]
    fn narrow_widths_route_like_wide_ones() {
        let j = spec(KeyBuf::U32((0..100_000).collect()));
        assert_eq!(route(&j), SortEngine::Aips2o);
        let j = spec(KeyBuf::U32((0..100_000).map(|i| i % 5).collect()));
        assert_eq!(route(&j), SortEngine::Ips4o);
        let mut dups = vec![0.5f32; 80_000];
        dups.extend((0..20_000).map(|i| i as f32));
        let j = spec(KeyBuf::F32(dups));
        assert_eq!(route(&j), SortEngine::Ips4o);
        let j = spec(KeyBuf::F32(vec![1.0; 64]));
        assert_eq!(route(&j), SortEngine::StdSort);
    }

    #[test]
    fn fixed_overrides() {
        let mut j = spec(KeyBuf::U64((0..100).collect()));
        j.engine = EngineChoice::Fixed(SortEngine::LearnedSort);
        assert_eq!(route(&j), SortEngine::LearnedSort);
    }

    #[test]
    fn route_decisions_are_counted_when_tracing() {
        let _l = crate::obs::test_lock();
        crate::obs::reset();
        crate::obs::set_enabled(true);
        route(&spec(KeyBuf::U64((0..100).collect())));
        route(&spec(KeyBuf::U64((0..100_000).collect())));
        route(&spec(KeyBuf::U64((0..100_000).map(|i| i % 5).collect())));
        crate::obs::set_enabled(false);
        let m = crate::obs::metrics::snapshot();
        assert_eq!(m.counters.get(C_ROUTE_SMALL), Some(&1));
        assert_eq!(m.counters.get(C_ROUTE_LARGE), Some(&1));
        assert_eq!(m.counters.get(C_ROUTE_DUP), Some(&1));
    }
}
