//! # aipso — LearnedSort as a learning-augmented SampleSort
//!
//! Reproduction of Carvalho & Lawrence, *"LearnedSort as a learning-augmented
//! SampleSort: Analysis and Parallelization"*, SSDBM 2023
//! (DOI 10.1145/3603719.3603731).
//!
//! The crate implements, from scratch:
//!
//! * **AIPS²o** (the paper's contribution): the IPS⁴o in-place parallel
//!   super-scalar SampleSort framework augmented with a *monotonic* RMI
//!   (learned CDF model) partitioning strategy — [`aips2o`].
//! * Every competitor the paper benchmarks against: [`sample_sort`]
//!   (IPS⁴o), [`radix_sort`] (IPS²Ra + SkaSort), [`learned_sort`]
//!   (LearnedSort 2.0), and [`baseline`] (pdqsort / parallel mergesort
//!   stand-ins for `std::sort` / `par_unseq`).
//! * The analysis algorithms of Section 3: Quicksort with Learned Pivots
//!   and Learned Quicksort — [`learned_qs`].
//! * All substrates: PRNG + samplers ([`util::rng`]), dataset generators
//!   ([`datasets`]), the native RMI ([`rmi`]), classifiers
//!   ([`classifier`]), a work-pool scheduler ([`scheduler`]), the PJRT
//!   artifact runtime ([`runtime`]), a sort-job coordinator
//!   ([`coordinator`]), and the benchmark harness ([`bench_harness`]).
//! * A **parallel out-of-core sorter** ([`external`]): datasets larger
//!   than memory are sorted under an explicit byte budget — run generation
//!   overlaps chunk IO with pool-parallel sorting and reuses one monotonic
//!   RMI across all chunks (with a drift-probe fallback to IPS⁴o); the
//!   merge inverts that RMI into quantile shards and runs range-disjoint
//!   loser trees concurrently. `ARCHITECTURE.md` (repository root) walks
//!   the module map and the full external data flow.
//!
//! The learned model also exists as an AOT-compiled JAX/Pallas artifact
//! (see `python/compile/`); [`runtime`] loads and executes it via PJRT so
//! the Rust binary is self-contained once `make artifacts` has run.
//!
//! ## Quickstart
//!
//! ```no_run
//! use aipso::{SortEngine, sort_parallel};
//!
//! let mut keys = aipso::datasets::generate_f64("uniform", 1 << 20, 42).unwrap();
//! sort_parallel(SortEngine::Aips2o, &mut keys, 0 /* 0 = all cores */);
//! assert!(aipso::is_sorted(&keys));
//! ```
//!
//! Out-of-core (dataset ≫ RAM; see `examples/extsort.rs`):
//!
//! ```no_run
//! use aipso::external::{self, ExternalConfig};
//!
//! let mut cfg = ExternalConfig::with_budget(64 << 20); // 64 MiB working set
//! cfg.threads = 8; // overlapped chunk IO + RMI-sharded parallel merge
//! let report = external::sort_file::<f64>(
//!     "uniform.bin".as_ref(),
//!     "uniform.sorted.bin".as_ref(),
//!     &cfg,
//! ).unwrap();
//! assert!(report.rmi_trained);
//! ```

#![warn(missing_docs)]

pub mod aips2o;
pub mod baseline;
pub mod bench_harness;
pub mod classifier;
pub mod coordinator;
pub mod datasets;
pub mod external;
pub mod key;
pub mod learned_qs;
pub mod learned_sort;
pub mod obs;
pub mod radix_sort;
pub mod rmi;
pub mod runtime;
pub mod sample_sort;
pub mod scheduler;
pub mod util;

pub use key::{KeyKind, SortKey};

/// Every sorting engine in the paper's evaluation, by paper name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SortEngine {
    /// AIPS²o / AI1S²o — the paper's contribution (learned SampleSort).
    Aips2o,
    /// IPS⁴o / I1S⁴o — in-place parallel super-scalar SampleSort.
    Ips4o,
    /// IPS²Ra / I1S²Ra — in-place parallel super-scalar radix sort.
    Ips2ra,
    /// LearnedSort 2.0 (sequential only, as in the paper).
    LearnedSort,
    /// `std::sort` stand-in: Rust pdqsort (`sort_unstable`); the parallel
    /// variant is our mergesort (for `std::execution::par_unseq`).
    StdSort,
    /// Quicksort with Learned Pivots (paper Algorithm 1+2, analysis only).
    LearnedPivotQs,
    /// Learned Quicksort, B=2 (paper Algorithm 3, analysis only).
    LearnedQs,
}

impl SortEngine {
    /// Engines in the paper's sequential benchmark (Figures 1–3).
    pub const SEQUENTIAL_FIGURES: [SortEngine; 5] = [
        SortEngine::LearnedSort,
        SortEngine::Ips4o,
        SortEngine::Ips2ra,
        SortEngine::Aips2o,
        SortEngine::StdSort,
    ];

    /// Engines in the paper's parallel benchmark (Figures 4–6).
    /// LearnedSort is excluded to match the paper ("there is only a
    /// sequential implementation" there); this repo's parallel
    /// LearnedSort exists anyway ([`learned_sort::sort_par`]) and is
    /// measured by the `fig_parallel` thread sweep instead.
    pub const PARALLEL_FIGURES: [SortEngine; 4] = [
        SortEngine::Aips2o,
        SortEngine::Ips4o,
        SortEngine::Ips2ra,
        SortEngine::StdSort,
    ];

    /// Display name following the paper's convention (I1S⁴o = sequential).
    pub fn paper_name(&self, parallel: bool) -> &'static str {
        match (self, parallel) {
            (SortEngine::Aips2o, true) => "AIPS2o",
            (SortEngine::Aips2o, false) => "AI1S2o",
            (SortEngine::Ips4o, true) => "IPS4o",
            (SortEngine::Ips4o, false) => "I1S4o",
            (SortEngine::Ips2ra, true) => "IPS2Ra",
            (SortEngine::Ips2ra, false) => "I1S2Ra",
            (SortEngine::LearnedSort, _) => "LearnedSort",
            (SortEngine::StdSort, true) => "std::sort(par)",
            (SortEngine::StdSort, false) => "std::sort",
            (SortEngine::LearnedPivotQs, _) => "LearnedPivotQS",
            (SortEngine::LearnedQs, _) => "LearnedQS",
        }
    }

    /// Parse an engine from any paper spelling or CLI shorthand.
    pub fn parse(s: &str) -> Option<SortEngine> {
        Some(match s.to_ascii_lowercase().as_str() {
            "aips2o" | "ai1s2o" => SortEngine::Aips2o,
            "ips4o" | "i1s4o" => SortEngine::Ips4o,
            "ips2ra" | "i1s2ra" => SortEngine::Ips2ra,
            "learnedsort" | "ls" => SortEngine::LearnedSort,
            "std" | "stdsort" | "std::sort" | "std::sort(par)" => SortEngine::StdSort,
            "learnedpivotqs" | "lpqs" => SortEngine::LearnedPivotQs,
            "learnedqs" | "lqs" => SortEngine::LearnedQs,
            _ => return None,
        })
    }

    /// Every engine, in the paper's presentation order.
    pub fn all() -> [SortEngine; 7] {
        [
            SortEngine::Aips2o,
            SortEngine::Ips4o,
            SortEngine::Ips2ra,
            SortEngine::LearnedSort,
            SortEngine::StdSort,
            SortEngine::LearnedPivotQs,
            SortEngine::LearnedQs,
        ]
    }
}

/// Sort `keys` sequentially with the given engine.
///
/// Works for every [`SortKey`] — bare numerics, prefix-encoded strings
/// ([`key::PrefixString`]) and records ([`key::SortItem`]). The engines
/// order by `to_bits_ordered()`; for keys whose bits are a *coarsening*
/// of the full order (string prefixes) a final [`key::repair_bit_ties`]
/// pass finishes equal-bits runs under the full comparator. That pass
/// compiles to nothing for bit-exact key types.
pub fn sort_sequential<K: SortKey>(engine: SortEngine, keys: &mut [K]) {
    match engine {
        SortEngine::Aips2o => aips2o::sort_seq(keys),
        SortEngine::Ips4o => sample_sort::sort_seq(keys),
        SortEngine::Ips2ra => radix_sort::sort_seq(keys),
        SortEngine::LearnedSort => learned_sort::sort(keys),
        SortEngine::StdSort => baseline::std_sort(keys),
        SortEngine::LearnedPivotQs => learned_qs::learned_pivot::sort(keys),
        SortEngine::LearnedQs => learned_qs::learned_quicksort::sort(keys),
    }
    key::repair_bit_ties(keys);
}

/// Sort `keys` with `threads` workers (0 = all available cores).
/// LearnedSort runs the thread-parallel fragmented partition
/// ([`learned_sort::sort_par`]) — going beyond the paper, which
/// benchmarks LearnedSort sequentially only (see
/// [`SortEngine::PARALLEL_FIGURES`], which keeps the paper's engine
/// set). The remaining engines without a parallel implementation run
/// sequentially.
pub fn sort_parallel<K: SortKey>(engine: SortEngine, keys: &mut [K], threads: usize) {
    let threads = scheduler::effective_threads(threads);
    match engine {
        SortEngine::Aips2o => aips2o::sort_par(keys, threads),
        SortEngine::Ips4o => sample_sort::sort_par(keys, threads),
        SortEngine::Ips2ra => radix_sort::sort_par(keys, threads),
        SortEngine::LearnedSort => learned_sort::sort_par(keys, threads),
        SortEngine::StdSort => baseline::par_sort(keys, threads),
        _ => sort_sequential(engine, keys),
    }
    // no-op for bit-exact keys; finishes string-prefix ties (see
    // `sort_sequential`) — idempotent when the engine deferred here
    key::repair_bit_ties(keys);
}

/// Check that a slice is sorted under the key's total order.
pub fn is_sorted<K: SortKey>(keys: &[K]) -> bool {
    keys.windows(2).all(|w| !w[1].key_lt(w[0]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parse_roundtrip() {
        // every paper spelling — sequential and parallel — must parse back
        // to its engine; all seven engines round-trip
        for e in SortEngine::all() {
            for parallel in [false, true] {
                let name = e.paper_name(parallel);
                assert_eq!(SortEngine::parse(name), Some(e), "paper name {name:?}");
            }
        }
        assert_eq!(SortEngine::parse("ips4o"), Some(SortEngine::Ips4o));
        assert_eq!(SortEngine::parse("nope"), None);
    }

    #[test]
    fn is_sorted_works() {
        assert!(is_sorted::<u64>(&[]));
        assert!(is_sorted(&[1u64]));
        assert!(is_sorted(&[1u64, 1, 2, 3]));
        assert!(!is_sorted(&[2u64, 1]));
        assert!(is_sorted(&[-1.0f64, 0.0, 0.5]));
        assert!(!is_sorted(&[0.5f64, -1.0]));
    }
}
