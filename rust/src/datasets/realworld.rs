//! Simulated real-world datasets (substitution per DESIGN.md §6).
//!
//! The paper uses five SOSD datasets (Marcus et al., VLDB '20) that are not
//! redistributable here. Each simulator below reproduces the *property* the
//! paper's evaluation exercises with that dataset:
//!
//! | Dataset | Property exercised | Simulator |
//! |---------|--------------------|-----------|
//! | OSM/Cell_IDs | clustered non-uniform CDF, radix-unbalanced prefixes | Morton codes of a city-cluster mixture |
//! | Wiki/Edit | near-monotone timestamps with bursts + many same-second duplicates (RMI-hard per Maltry & Dittrich) | piecewise-Poisson edit process |
//! | FB/IDs | extreme heavy tail — the known RMI-hard case | lognormal body + Pareto tail id space |
//! | Books/Sales | popularity counts: Zipf-like plateaus of duplicates | Zipf ranks with plateau quantization |
//! | NYC/Pickup | seasonal timestamps (daily/weekly cycles) | sinusoid-modulated arrival process |

use crate::util::rng::{Xoshiro256pp, Zipf};

/// OSM/Cell_IDs: uniformly sampled location ids from OpenStreetMap.
/// Simulated as Morton (z-order) codes of points drawn from a mixture of
/// ~256 geographic clusters — produces the clustered, prefix-skewed id
/// space real cell ids have.
pub fn osm_cellids(n: usize, rng: &mut Xoshiro256pp) -> Vec<u64> {
    let (centers, zipf) = osm_components(rng);
    (0..n).map(|_| osm_sample(&centers, &zipf, rng)).collect()
}

/// Cluster centers + popularity law, drawn once per dataset instance
/// (split out so chunked generation reuses one draw).
pub fn osm_components(rng: &mut Xoshiro256pp) -> (Vec<(f64, f64, f64)>, Zipf) {
    const CLUSTERS: usize = 256;
    let centers: Vec<(f64, f64, f64)> = (0..CLUSTERS)
        .map(|_| {
            (
                rng.uniform(0.0, 1.0),              // lat in unit square
                rng.uniform(0.0, 1.0),              // lon
                rng.uniform(0.0005, 0.02),          // cluster spread
            )
        })
        .collect();
    // Cluster popularity is itself heavy-tailed (big cities dominate).
    (centers, Zipf::new(CLUSTERS as u64, 1.3))
}

/// One Morton-coded cell id from the fixed cluster mixture.
pub fn osm_sample(centers: &[(f64, f64, f64)], zipf: &Zipf, rng: &mut Xoshiro256pp) -> u64 {
    let c = (zipf.sample(rng) - 1) as usize;
    let (clat, clon, sd) = centers[c];
    let lat = (clat + sd * rng.normal()).clamp(0.0, 1.0);
    let lon = (clon + sd * rng.normal()).clamp(0.0, 1.0);
    morton_interleave(
        (lat * (u32::MAX as f64)) as u32,
        (lon * (u32::MAX as f64)) as u32,
    )
}

/// Interleave the bits of x and y into a 64-bit Morton code (z-order).
#[inline]
pub fn morton_interleave(x: u32, y: u32) -> u64 {
    spread_bits(x) | (spread_bits(y) << 1)
}

/// One Morton-coded cell id *native to the u32 domain*: 16-bit
/// coordinates interleave into a 32-bit z-order code, so the clustered,
/// prefix-skewed structure survives at width 4. (Truncating the 64-bit
/// code to its low 32 bits instead keeps only the noisy within-cluster
/// bits — the cluster identity lives in the code's *top* bits — which is
/// exactly the `gen --width 4` artifact this sampler replaces.)
pub fn osm_sample_u32(centers: &[(f64, f64, f64)], zipf: &Zipf, rng: &mut Xoshiro256pp) -> u32 {
    let c = (zipf.sample(rng) - 1) as usize;
    let (clat, clon, sd) = centers[c];
    let lat = (clat + sd * rng.normal()).clamp(0.0, 1.0);
    let lon = (clon + sd * rng.normal()).clamp(0.0, 1.0);
    morton_interleave16(
        (lat * (u16::MAX as f64)) as u16,
        (lon * (u16::MAX as f64)) as u16,
    )
}

/// Interleave the bits of x and y into a 32-bit Morton code (z-order).
#[inline]
pub fn morton_interleave16(x: u16, y: u16) -> u32 {
    spread_bits16(x) | (spread_bits16(y) << 1)
}

#[inline]
fn spread_bits16(v: u16) -> u32 {
    let mut x = v as u32;
    x = (x | (x << 8)) & 0x00FF_00FF;
    x = (x | (x << 4)) & 0x0F0F_0F0F;
    x = (x | (x << 2)) & 0x3333_3333;
    x = (x | (x << 1)) & 0x5555_5555;
    x
}

#[inline]
fn spread_bits(v: u32) -> u64 {
    let mut x = v as u64;
    x = (x | (x << 16)) & 0x0000_FFFF_0000_FFFF;
    x = (x | (x << 8)) & 0x00FF_00FF_00FF_00FF;
    x = (x | (x << 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    x = (x | (x << 2)) & 0x3333_3333_3333_3333;
    x = (x | (x << 1)) & 0x5555_5555_5555_5555;
    x
}

/// Wiki/Edit: edit timestamps from Wikipedia articles. Simulated as ~20
/// years of POSIX seconds with a piecewise-varying edit rate (growth +
/// random bursts); multiple edits share the same second, producing the
/// duplicate density the paper calls out as hard for the RMI.
pub fn wiki_edit(n: usize, rng: &mut Xoshiro256pp) -> Vec<u64> {
    let mut t = WIKI_T0;
    let mut out = wiki_edit_fill(&mut t, n, rng, false);
    // The SOSD file is sorted; the sort benchmark shuffles it. Emit
    // shuffled (sortedness is a property benchmarks control separately).
    rng.shuffle(&mut out);
    out
}

/// Epoch of the simulated edit process (~2001).
pub const WIKI_T0: u64 = 1_000_000_000;

/// Produce `n` edit timestamps continuing the process from clock `*t`.
/// With `shuffle` the chunk is shuffled locally (the monolithic generator
/// shuffles globally instead — chunked output is the same multiset).
pub fn wiki_edit_fill(t: &mut u64, n: usize, rng: &mut Xoshiro256pp, shuffle: bool) -> Vec<u64> {
    const SPAN: u64 = 20 * 365 * 24 * 3600;
    let mut out = Vec::with_capacity(n);
    // Burst state: occasionally an article gets a flurry of same-second
    // edits (vandalism reverts, bot runs).
    while out.len() < n {
        // growth: later timestamps arrive faster (rate grows over the span)
        let frac = (t.saturating_sub(WIKI_T0)) as f64 / SPAN as f64;
        let rate = 1.0 + 8.0 * frac;
        let burst = if rng.next_f64() < 0.02 {
            2 + rng.next_below(24) as usize
        } else {
            1 + rng.poisson(rate * 0.35) as usize
        };
        for _ in 0..burst {
            if out.len() >= n {
                break;
            }
            out.push(*t);
        }
        // next edit-second gap (skewed toward small gaps)
        *t += 1 + (rng.exponential(0.8) * 3.0) as u64;
        if *t > WIKI_T0 + SPAN {
            *t = WIKI_T0 + rng.next_below(SPAN);
        }
    }
    if shuffle {
        rng.shuffle(&mut out);
    }
    out
}

/// FB/IDs: Facebook user ids sampled by a random walk of the graph.
/// Simulated as a sparse id space with a lognormal body and an extreme
/// Pareto tail — reproducing the "RMI-hard" CDF the paper attributes its
/// lowest AIPS2o throughput to.
pub fn fb_ids(n: usize, rng: &mut Xoshiro256pp) -> Vec<u64> {
    (0..n).map(|_| fb_id_sample(rng)).collect()
}

/// One heavy-tailed user id.
pub fn fb_id_sample(rng: &mut Xoshiro256pp) -> u64 {
    let body = rng.lognormal(24.0, 2.2); // spans many octaves
    let x = if rng.next_f64() < 0.005 {
        // heavy tail: a few astronomically large ids
        body * rng.pareto(0.6)
    } else {
        body
    };
    // clamp into u64, keep sparse high range
    if x >= u64::MAX as f64 {
        u64::MAX - rng.next_below(1 << 20)
    } else {
        x as u64
    }
}

/// One heavy-tailed user id *native to the u32 domain*: the same
/// lognormal-body + Pareto-tail law re-scoped so the body's octaves span
/// the 32-bit range the way the u64 law spans 64 bits. (Truncating the
/// 64-bit ids — most of which exceed 2³² — to their low 32 bits wraps
/// them into structureless noise, destroying the heavy tail the paper
/// calls RMI-hard; this sampler keeps it in-domain.)
pub fn fb_id_sample_u32(rng: &mut Xoshiro256pp) -> u32 {
    // e^12 ≈ 1.6e5 median; σ=1.8 puts the body's p999 near 4e7, so the
    // p999/p50 ratio (~e^(3.09σ) ≈ 260 before the Pareto tail) keeps the
    // RMI-hard heavy-tail property well inside the u32 range
    let body = rng.lognormal(12.0, 1.8);
    let x = if rng.next_f64() < 0.005 {
        body * rng.pareto(0.6)
    } else {
        body
    };
    if x >= u32::MAX as f64 {
        u32::MAX - rng.next_below(1 << 10) as u32
    } else {
        x as u32
    }
}

/// Books/Sales: Amazon book popularity. Simulated as Zipf-ranked sales
/// counts quantized onto plateaus (many books share identical low counts —
/// extensive duplicates at the bottom of the range).
pub fn books_sales(n: usize, rng: &mut Xoshiro256pp) -> Vec<u64> {
    if n == 0 {
        return Vec::new();
    }
    let z = books_rank_law(n);
    (0..n).map(|_| books_sample(&z, rng)).collect()
}

/// The popularity law for an N-book catalogue.
pub fn books_rank_law(n: usize) -> Zipf {
    Zipf::new((n as u64).max(1000), 0.9)
}

/// One quantized sales count under the fixed popularity law.
pub fn books_sample(z: &Zipf, rng: &mut Xoshiro256pp) -> u64 {
    let rank = z.sample(rng);
    // sales ~ C / rank^0.9, quantized to integers; the long tail
    // of low-sales books collapses onto plateau values (3, 4, 5 ...
    // sales) — extensive duplicate classes, as in the real data
    let sales = (5e4 / (rank as f64).powf(0.9)) as u64;
    if sales < 1000 {
        sales
    } else {
        // jitter big counts slightly (distinct bestsellers)
        sales * 1000 + rng.next_below(sales)
    }
}

/// NYC/Pickup: yellow-taxi pickup timestamps. Simulated as one year of
/// POSIX seconds from an arrival process whose intensity follows daily and
/// weekly sinusoidal cycles (rush hours, quiet Sundays).
pub fn nyc_pickup(n: usize, rng: &mut Xoshiro256pp) -> Vec<u64> {
    (0..n).map(|_| nyc_sample(rng)).collect()
}

/// One seasonal pickup timestamp.
pub fn nyc_sample(rng: &mut Xoshiro256pp) -> u64 {
    const T0: u64 = 1_640_995_200; // 2022-01-01
    const YEAR: f64 = 365.0 * 24.0 * 3600.0;
    let day = 24.0 * 3600.0;
    let week = 7.0 * day;
    // rejection-sample a time of year by seasonal intensity
    loop {
        let t = rng.next_f64() * YEAR;
        let daily = 0.6 + 0.4 * (std::f64::consts::TAU * (t % day) / day - 1.0).cos();
        let weekly = 0.8 + 0.2 * (std::f64::consts::TAU * (t % week) / week).cos();
        if rng.next_f64() < daily * weekly {
            return T0 + t as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::new(0x0513)
    }

    fn dup_fraction(v: &[u64]) -> f64 {
        let mut s = v.to_vec();
        s.sort_unstable();
        let dups = s.windows(2).filter(|w| w[0] == w[1]).count();
        dups as f64 / v.len().max(1) as f64
    }

    #[test]
    fn morton_roundtrip_order() {
        // Morton of (0,0) is 0; growing coordinates grow the code's prefix.
        assert_eq!(morton_interleave(0, 0), 0);
        assert!(morton_interleave(u32::MAX, u32::MAX) > morton_interleave(1, 1));
        assert_eq!(morton_interleave(1, 0), 1);
        assert_eq!(morton_interleave(0, 1), 2);
    }

    #[test]
    fn osm_is_clustered() {
        let v = osm_cellids(20_000, &mut rng());
        assert_eq!(v.len(), 20_000);
        // clustered: top-16 8-bit prefixes should hold most of the mass
        let mut pref = [0usize; 256];
        for &x in &v {
            pref[(x >> 56) as usize] += 1;
        }
        let mut p = pref.to_vec();
        p.sort_unstable_by(|a, b| b.cmp(a));
        let top: usize = p[..16].iter().sum();
        assert!(top as f64 > 0.5 * v.len() as f64, "not clustered: top16={top}");
    }

    #[test]
    fn morton16_roundtrip_order() {
        assert_eq!(morton_interleave16(0, 0), 0);
        assert_eq!(morton_interleave16(1, 0), 1);
        assert_eq!(morton_interleave16(0, 1), 2);
        assert!(morton_interleave16(u16::MAX, u16::MAX) > morton_interleave16(1, 1));
        assert_eq!(morton_interleave16(u16::MAX, u16::MAX), u32::MAX);
    }

    #[test]
    fn osm_u32_native_sampler_is_clustered() {
        // The 32-bit Morton codes must keep the cluster structure in
        // their *top* bits — the property low-32 truncation destroyed.
        let mut r = rng();
        let (centers, zipf) = osm_components(&mut r);
        let v: Vec<u32> = (0..20_000).map(|_| osm_sample_u32(&centers, &zipf, &mut r)).collect();
        let mut pref = [0usize; 256];
        for &x in &v {
            pref[(x >> 24) as usize] += 1;
        }
        let mut p = pref.to_vec();
        p.sort_unstable_by(|a, b| b.cmp(a));
        let top: usize = p[..16].iter().sum();
        assert!(top as f64 > 0.5 * v.len() as f64, "not clustered: top16={top}");
    }

    #[test]
    fn fb_u32_native_sampler_keeps_the_heavy_tail() {
        // p999/p50 must stay orders of magnitude apart in-domain; the old
        // low-32 truncation wrapped the (mostly > 2^32) ids into
        // near-uniform noise with a tail ratio of ~2.
        let mut r = rng();
        let mut s: Vec<u32> = (0..50_000).map(|_| fb_id_sample_u32(&mut r)).collect();
        s.sort_unstable();
        let p50 = s[s.len() / 2] as f64;
        let p999 = s[s.len() * 999 / 1000] as f64;
        assert!(p999 / p50 > 1e2, "tail not heavy: p999/p50 = {}", p999 / p50);
        // and the distinct-key ratio survives (ids are near-unique; some
        // integer collisions around the body's median are expected)
        let distinct = 1 + s.windows(2).filter(|w| w[0] != w[1]).count();
        assert!(
            distinct as f64 > 0.9 * s.len() as f64,
            "native u32 ids must stay near-distinct ({distinct}/{})",
            s.len()
        );
    }

    #[test]
    fn wiki_has_many_duplicates() {
        let v = wiki_edit(30_000, &mut rng());
        assert_eq!(v.len(), 30_000);
        assert!(dup_fraction(&v) > 0.1, "dup fraction {}", dup_fraction(&v));
    }

    #[test]
    fn fb_is_heavy_tailed() {
        let v = fb_ids(50_000, &mut rng());
        let mut s = v.clone();
        s.sort_unstable();
        let p50 = s[s.len() / 2] as f64;
        let p999 = s[s.len() * 999 / 1000] as f64;
        assert!(p999 / p50 > 1e3, "tail not heavy: p999/p50 = {}", p999 / p50);
    }

    #[test]
    fn books_have_duplicate_plateaus() {
        let v = books_sales(50_000, &mut rng());
        assert!(dup_fraction(&v) > 0.05, "dup fraction {}", dup_fraction(&v));
    }

    #[test]
    fn nyc_within_year_and_seasonal() {
        let v = nyc_pickup(20_000, &mut rng());
        let t0 = 1_640_995_200u64;
        let year = 365 * 24 * 3600;
        assert!(v.iter().all(|&t| t >= t0 && t < t0 + year + 1));
        // daily seasonality: histogram over hour-of-day must be non-uniform
        let mut hours = [0usize; 24];
        for &t in &v {
            hours[(((t - t0) % 86_400) / 3_600) as usize] += 1;
        }
        let max = *hours.iter().max().unwrap() as f64;
        let min = *hours.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) > 1.5, "no seasonality: {hours:?}");
    }
}
