//! Synthetic datasets — generated exactly as the paper specifies
//! (Section 5, "Synthetic Datasets"; RootDups/TwoDups from BlockQuicksort,
//! Edelkamp & Weiß 2016).

use crate::util::rng::{Xoshiro256pp, Zipf};

/// Uniform distribution with a = 0 and b = N.
pub fn uniform(n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    uniform_of(n, n, rng)
}

/// `len` draws of the Uniform(0, n_total) dataset (chunked generation
/// needs the range decoupled from the draw count).
pub fn uniform_of(n_total: usize, len: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..len).map(|_| rng.uniform(0.0, n_total as f64)).collect()
}

/// Normal distribution with mu = 0 and sigma = 1.
pub fn normal(n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Log-normal distribution with mu = 0 and sigma = 0.5.
pub fn lognormal(n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..n).map(|_| rng.lognormal(0.0, 0.5)).collect()
}

/// Random additive distribution of five Gaussian distributions: component
/// means/sds drawn once per dataset instance, then equal-weight mixture.
pub fn mix_gauss(n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    let comps = mix_gauss_components(n, rng);
    (0..n).map(|_| mix_gauss_sample(&comps, rng)).collect()
}

/// The mixture's component (mean, sd) pairs — drawn once per dataset
/// instance (split out so chunked generation reuses one draw).
pub fn mix_gauss_components(n: usize, rng: &mut Xoshiro256pp) -> Vec<(f64, f64)> {
    let scale = (n as f64).max(1e4);
    (0..5)
        .map(|_| (rng.uniform(0.0, scale), rng.uniform(scale / 100.0, scale / 10.0)))
        .collect()
}

/// One draw from the fixed mixture.
pub fn mix_gauss_sample(comps: &[(f64, f64)], rng: &mut Xoshiro256pp) -> f64 {
    let (mu, sd) = comps[rng.next_below(comps.len() as u64) as usize];
    rng.normal_with(mu, sd)
}

/// Exponential distribution with lambda = 2.
pub fn exponential(n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..n).map(|_| rng.exponential(2.0)).collect()
}

/// Chi-squared distribution with k = 4.
pub fn chi_squared(n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    (0..n).map(|_| rng.chi_squared(4)).collect()
}

/// RootDups: A[i] = i mod sqrt(N) — sqrt(N) distinct values, each repeated
/// ~sqrt(N) times in a periodic ramp (the equality-bucket stress test).
pub fn root_dups(n: usize) -> Vec<f64> {
    root_dups_range(n, 0, n)
}

/// The RootDups slice `[start, start + len)` of an N = `n_total` dataset
/// (index-based, so chunked generation is exact).
pub fn root_dups_range(n_total: usize, start: usize, len: usize) -> Vec<f64> {
    let m = (n_total as f64).sqrt().floor().max(1.0) as usize;
    (start..start + len).map(|i| (i % m) as f64).collect()
}

/// TwoDups: A[i] = i^2 + N/2 mod N.
pub fn two_dups(n: usize) -> Vec<f64> {
    two_dups_range(n, 0, n)
}

/// The TwoDups slice `[start, start + len)` of an N = `n_total` dataset.
pub fn two_dups_range(n_total: usize, start: usize, len: usize) -> Vec<f64> {
    let nn = n_total.max(1) as u128;
    (start as u128..(start + len) as u128)
        .map(|i| ((i * i + nn / 2) % nn) as f64)
        .collect()
}

/// Zipfian distribution with s = 0.75 over {1..N}.
pub fn zipf(n: usize, rng: &mut Xoshiro256pp) -> Vec<f64> {
    if n == 0 {
        return Vec::new();
    }
    let z = zipf_law(n);
    (0..n).map(|_| z.sample(rng) as f64).collect()
}

/// The paper's Zipf law (s = 0.75 over {1..N}) as a reusable sampler.
pub fn zipf_law(n: usize) -> Zipf {
    Zipf::new(n.max(1) as u64, 0.75)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn rng() -> Xoshiro256pp {
        Xoshiro256pp::new(0xDA7A)
    }

    #[test]
    fn uniform_bounds_and_spread() {
        let v = uniform(50_000, &mut rng());
        assert!(v.iter().all(|&x| (0.0..50_000.0).contains(&x)));
        let m = stats::mean(&v);
        assert!((m - 25_000.0).abs() < 500.0, "mean={m}");
    }

    #[test]
    fn normal_standardized() {
        let v = normal(100_000, &mut rng());
        assert!(stats::mean(&v).abs() < 0.02);
        assert!((stats::stddev(&v) - 1.0).abs() < 0.02);
    }

    #[test]
    fn lognormal_positive_with_median_one() {
        let v = lognormal(100_000, &mut rng());
        assert!(v.iter().all(|&x| x > 0.0));
        // median of LogN(0, s) is e^0 = 1
        assert!((stats::median(&v) - 1.0).abs() < 0.03);
    }

    #[test]
    fn mix_gauss_is_multimodal_spread() {
        let v = mix_gauss(50_000, &mut rng());
        // spread far wider than any single component's sd
        assert!(stats::stddev(&v) > 1_000.0);
    }

    #[test]
    fn root_dups_value_universe() {
        let n = 10_000;
        let v = root_dups(n);
        let m = (n as f64).sqrt() as usize;
        assert!(v.iter().all(|&x| (x as usize) < m));
        // every value appears ~ sqrt(N) times
        let count0 = v.iter().filter(|&&x| x == 0.0).count();
        assert!(count0 >= n / m);
    }

    #[test]
    fn two_dups_formula() {
        let v = two_dups(1000);
        assert_eq!(v[0], 500.0); // 0 + 500 mod 1000
        assert_eq!(v[1], 501.0);
        assert_eq!(v[30], (30u128 * 30 + 500).rem_euclid(1000) as f64);
        assert!(v.iter().all(|&x| x < 1000.0));
    }

    #[test]
    fn zipf_skew() {
        let v = zipf(50_000, &mut rng());
        let ones = v.iter().filter(|&&x| x == 1.0).count();
        // rank-1 should be the clear mode under s=0.75
        assert!(ones > 50, "ones={ones}");
        assert!(v.iter().all(|&x| x >= 1.0 && x <= 50_000.0));
    }

    #[test]
    fn empty_inputs_ok() {
        assert!(root_dups(0).is_empty());
        assert!(two_dups(0).is_empty());
        assert!(zipf(0, &mut rng()).is_empty());
    }
}
