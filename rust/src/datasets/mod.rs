//! Dataset suite (substrate S2) — the paper's 14 benchmark inputs.
//!
//! Synthetic datasets (64-bit doubles, Section 5 "Synthetic Datasets") are
//! generated exactly as specified. Real-world datasets (64-bit unsigned
//! integers, from SOSD / Marcus et al.) are not redistributable, so
//! [`realworld`] builds *statistical simulators* that reproduce the property
//! each dataset exercises in the paper's evaluation — CDF smoothness
//! (RMI fit quality), duplicate density (equality buckets) and radix-prefix
//! skew (IPS²Ra balance). See DESIGN.md §6 for the substitution table.

pub mod realworld;
pub mod synthetic;

use std::path::Path;

use crate::key::{KeyKind, PrefixString, SortItem, SortKey};
use crate::util::rng::{Xoshiro256pp, Zipf};

/// Key type of a dataset, mirroring the paper (synthetic = f64 doubles,
/// real-world = u64 ids/timestamps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeyType {
    /// 64-bit doubles.
    F64,
    /// 64-bit unsigned integers.
    U64,
}

impl KeyType {
    /// The spill-codec domain of a natively-written (8-byte) file of this
    /// dataset. (The 4-byte narrowed domains are chosen by
    /// [`write_dataset_file_width`], which owns the narrowing rule.)
    pub fn kind(self) -> KeyKind {
        match self {
            KeyType::F64 => KeyKind::F64,
            KeyType::U64 => KeyKind::U64,
        }
    }
}

/// Which paper figure a dataset appears in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FigureGroup {
    /// Figures 1 & 4: Uniform, Normal, Log-Normal.
    Synthetic1,
    /// Figures 2 & 5: MixGauss, Exponential, Chi-Squared, RootDups,
    /// TwoDups, Zipf.
    Synthetic2,
    /// Figures 3 & 6: OSM, Wiki, FB, Books, NYC.
    RealWorld,
}

/// Registry entry for one of the paper's 14 benchmark datasets.
#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    /// CLI name (`aipso gen --dataset NAME`).
    pub name: &'static str,
    /// Name as printed in the paper's figures.
    pub paper_name: &'static str,
    /// Key domain (synthetic = f64, real-world = u64).
    pub key_type: KeyType,
    /// Which figure the dataset appears in.
    pub group: FigureGroup,
    /// Relative input size vs the synthetic N (paper: real-world sets are
    /// 2x except NYC).
    pub size_factor: f64,
    /// One-line description of the generating law.
    pub description: &'static str,
}

/// All 14 datasets, in the paper's presentation order.
pub const ALL: [DatasetSpec; 14] = [
    DatasetSpec { name: "uniform", paper_name: "Uniform", key_type: KeyType::F64, group: FigureGroup::Synthetic1, size_factor: 1.0, description: "U(0, N)" },
    DatasetSpec { name: "normal", paper_name: "Normal", key_type: KeyType::F64, group: FigureGroup::Synthetic1, size_factor: 1.0, description: "N(0, 1)" },
    DatasetSpec { name: "lognormal", paper_name: "Log-Normal", key_type: KeyType::F64, group: FigureGroup::Synthetic1, size_factor: 1.0, description: "LogN(0, 0.5)" },
    DatasetSpec { name: "mix_gauss", paper_name: "Mix Gauss", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "random additive mixture of five Gaussians" },
    DatasetSpec { name: "exponential", paper_name: "Exponential", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "Exp(lambda=2)" },
    DatasetSpec { name: "chi_squared", paper_name: "Chi-Squared", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "chi2(k=4)" },
    DatasetSpec { name: "root_dups", paper_name: "Root Dups", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "A[i] = i mod sqrt(N) (BlockQuicksort)" },
    DatasetSpec { name: "two_dups", paper_name: "Two Dups", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "A[i] = i^2 + N/2 mod N (BlockQuicksort)" },
    DatasetSpec { name: "zipf", paper_name: "Zipf", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "Zipf(s=0.75)" },
    DatasetSpec { name: "osm_cellids", paper_name: "OSM/Cell_IDs", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 2.0, description: "simulated OpenStreetMap cell ids (clustered Morton codes)" },
    DatasetSpec { name: "wiki_edit", paper_name: "Wiki/Edit", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 2.0, description: "simulated Wikipedia edit timestamps (bursty, duplicate-heavy)" },
    DatasetSpec { name: "fb_ids", paper_name: "FB/IDs", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 2.0, description: "simulated Facebook user ids (heavy-tailed, RMI-hard)" },
    DatasetSpec { name: "books_sales", paper_name: "Books/Sales", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 2.0, description: "simulated Amazon book popularity (Zipf plateaus)" },
    DatasetSpec { name: "nyc_pickup", paper_name: "NYC/Pickup", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 1.0, description: "simulated taxi pickup timestamps (seasonal)" },
];

/// Look up a dataset by CLI name or paper name.
pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    ALL.iter().find(|d| d.name == name || d.paper_name == name)
}

/// CLI names of the nine synthetic (f64) datasets.
pub fn f64_names() -> Vec<&'static str> {
    ALL.iter()
        .filter(|d| d.key_type == KeyType::F64)
        .map(|d| d.name)
        .collect()
}

/// CLI names of the five simulated real-world (u64) datasets.
pub fn u64_names() -> Vec<&'static str> {
    ALL.iter()
        .filter(|d| d.key_type == KeyType::U64)
        .map(|d| d.name)
        .collect()
}

/// Generate a double-keyed (synthetic) dataset by name. One all-at-once
/// chunk of the same stream [`chunked_f64`] produces, so the two paths
/// cannot drift (a single `wiki_edit`-style full chunk also shuffles
/// globally, keeping u64 parity below).
pub fn generate_f64(name: &str, n: usize, seed: u64) -> Result<Vec<f64>, String> {
    let mut gen = chunked_f64(name, n, seed)?;
    Ok(gen.next_chunk(n).unwrap_or_default())
}

/// Generate an integer-keyed (simulated real-world) dataset by name.
pub fn generate_u64(name: &str, n: usize, seed: u64) -> Result<Vec<u64>, String> {
    let mut gen = chunked_u64(name, n, seed)?;
    Ok(gen.next_chunk(n).unwrap_or_default())
}

/// Generate a narrow-width (f32) synthetic dataset by name: one
/// all-at-once chunk of the [`chunked_f32`] stream.
pub fn generate_f32(name: &str, n: usize, seed: u64) -> Result<Vec<f32>, String> {
    let mut gen = chunked_f32(name, n, seed)?;
    Ok(gen.next_chunk(n).unwrap_or_default())
}

/// Generate a narrow-width (u32) simulated real-world dataset by name:
/// one all-at-once chunk of the [`chunked_u32`] stream.
pub fn generate_u32(name: &str, n: usize, seed: u64) -> Result<Vec<u32>, String> {
    let mut gen = chunked_u32(name, n, seed)?;
    Ok(gen.next_chunk(n).unwrap_or_default())
}

// ---------------------------------------------------------------------------
// Chunked generation — every paper distribution as an on-disk file.
//
// The external sorter needs inputs far larger than memory, so each dataset
// is also available as a *stateful chunk stream*: construction draws the
// per-instance components (mixture parameters, cluster centers, popularity
// laws), then `next_chunk` produces consecutive slices in bounded memory.
// For the per-element samplers the chunk stream is draw-for-draw identical
// to `generate_f64`/`generate_u64` with the same (name, n, seed);
// `wiki_edit` (a stateful arrival process) is statistically equivalent but
// not byte-identical: bursts truncate at chunk boundaries and shuffling is
// per-chunk instead of global.
// ---------------------------------------------------------------------------

enum F64Kind {
    Uniform,
    Normal,
    LogNormal,
    MixGauss(Vec<(f64, f64)>),
    Exponential,
    ChiSquared,
    RootDups,
    TwoDups,
    Zipf(Zipf),
}

/// Stateful chunk stream over one of the nine f64 (synthetic) datasets.
pub struct ChunkedF64 {
    kind: F64Kind,
    rng: Xoshiro256pp,
    n: usize,
    produced: usize,
}

/// Open a chunk stream over a synthetic dataset of `n` total keys.
pub fn chunked_f64(name: &str, n: usize, seed: u64) -> Result<ChunkedF64, String> {
    let mut rng = Xoshiro256pp::new(seed);
    let kind = match name {
        "uniform" => F64Kind::Uniform,
        "normal" => F64Kind::Normal,
        "lognormal" => F64Kind::LogNormal,
        "mix_gauss" => F64Kind::MixGauss(synthetic::mix_gauss_components(n, &mut rng)),
        "exponential" => F64Kind::Exponential,
        "chi_squared" => F64Kind::ChiSquared,
        "root_dups" => F64Kind::RootDups,
        "two_dups" => F64Kind::TwoDups,
        "zipf" => F64Kind::Zipf(synthetic::zipf_law(n)),
        _ => {
            return Err(format!(
                "unknown f64 dataset '{name}' (u64 dataset? use chunked_u64)"
            ))
        }
    };
    Ok(ChunkedF64 {
        kind,
        rng,
        n,
        produced: 0,
    })
}

impl ChunkedF64 {
    /// Keys not yet produced.
    pub fn remaining(&self) -> usize {
        self.n - self.produced
    }

    /// Next up-to-`max_len` keys; `None` once `n` keys were produced.
    pub fn next_chunk(&mut self, max_len: usize) -> Option<Vec<f64>> {
        let ChunkedF64 {
            kind,
            rng,
            n,
            produced,
        } = self;
        let len = max_len.min(*n - *produced);
        if len == 0 {
            return None;
        }
        let start = *produced;
        let out: Vec<f64> = match kind {
            F64Kind::Uniform => synthetic::uniform_of(*n, len, rng),
            F64Kind::Normal => synthetic::normal(len, rng),
            F64Kind::LogNormal => synthetic::lognormal(len, rng),
            F64Kind::MixGauss(comps) => (0..len)
                .map(|_| synthetic::mix_gauss_sample(comps, rng))
                .collect(),
            F64Kind::Exponential => synthetic::exponential(len, rng),
            F64Kind::ChiSquared => synthetic::chi_squared(len, rng),
            F64Kind::RootDups => synthetic::root_dups_range(*n, start, len),
            F64Kind::TwoDups => synthetic::two_dups_range(*n, start, len),
            F64Kind::Zipf(z) => (0..len).map(|_| z.sample(rng) as f64).collect(),
        };
        *produced += len;
        Some(out)
    }
}

enum U64Kind {
    Osm {
        centers: Vec<(f64, f64, f64)>,
        zipf: Zipf,
    },
    Wiki {
        t: u64,
    },
    Fb,
    Books(Zipf),
    Nyc,
}

/// Stateful chunk stream over one of the five u64 (real-world) datasets.
pub struct ChunkedU64 {
    kind: U64Kind,
    rng: Xoshiro256pp,
    n: usize,
    produced: usize,
}

/// Open a chunk stream over a simulated real-world dataset of `n` keys.
pub fn chunked_u64(name: &str, n: usize, seed: u64) -> Result<ChunkedU64, String> {
    let mut rng = Xoshiro256pp::new(seed);
    let kind = match name {
        "osm_cellids" => {
            let (centers, zipf) = realworld::osm_components(&mut rng);
            U64Kind::Osm { centers, zipf }
        }
        "wiki_edit" => U64Kind::Wiki {
            t: realworld::WIKI_T0,
        },
        "fb_ids" => U64Kind::Fb,
        "books_sales" => U64Kind::Books(realworld::books_rank_law(n)),
        "nyc_pickup" => U64Kind::Nyc,
        _ => {
            return Err(format!(
                "unknown u64 dataset '{name}' (f64 dataset? use chunked_f64)"
            ))
        }
    };
    Ok(ChunkedU64 {
        kind,
        rng,
        n,
        produced: 0,
    })
}

impl ChunkedU64 {
    /// Keys not yet produced.
    pub fn remaining(&self) -> usize {
        self.n - self.produced
    }

    /// Next up-to-`max_len` keys; `None` once `n` keys were produced.
    pub fn next_chunk(&mut self, max_len: usize) -> Option<Vec<u64>> {
        let ChunkedU64 {
            kind,
            rng,
            n,
            produced,
        } = self;
        let len = max_len.min(*n - *produced);
        if len == 0 {
            return None;
        }
        let out: Vec<u64> = match kind {
            U64Kind::Osm { centers, zipf } => (0..len)
                .map(|_| realworld::osm_sample(centers, zipf, rng))
                .collect(),
            U64Kind::Wiki { t } => realworld::wiki_edit_fill(t, len, rng, true),
            U64Kind::Fb => (0..len).map(|_| realworld::fb_id_sample(rng)).collect(),
            U64Kind::Books(z) => (0..len).map(|_| realworld::books_sample(z, rng)).collect(),
            U64Kind::Nyc => (0..len).map(|_| realworld::nyc_sample(rng)).collect(),
        };
        *produced += len;
        Some(out)
    }
}

/// Write a synthetic dataset as a binary key file (8-byte LE doubles, the
/// `sort_file` input format) in bounded memory.
pub fn write_f64_file(
    name: &str,
    n: usize,
    seed: u64,
    path: &Path,
    chunk_len: usize,
) -> Result<(), String> {
    let mut gen = chunked_f64(name, n, seed)?;
    write_chunks(path, chunk_len, |len| gen.next_chunk(len))
}

/// Write a simulated real-world dataset as a binary key file (8-byte LE
/// unsigned integers) in bounded memory.
pub fn write_u64_file(
    name: &str,
    n: usize,
    seed: u64,
    path: &Path,
    chunk_len: usize,
) -> Result<(), String> {
    let mut gen = chunked_u64(name, n, seed)?;
    write_chunks(path, chunk_len, |len| gen.next_chunk(len))
}

/// Stateful chunk stream over a synthetic dataset in the f32 domain:
/// each law is sampled at full generator resolution and rounded to the
/// nearest representable `f32`. For the continuous synthetic laws the
/// nearest-f32 rounding *is* the natural f32 parameterization (same
/// values the old width-4 cast produced — the float side never had a
/// truncation artifact; [`chunked_u32`] is where narrowing semantics
/// actually changed), packaged as a first-class sampler so the width-4
/// pipeline has one code path per domain.
pub struct ChunkedF32 {
    inner: ChunkedF64,
}

/// Open a native f32 chunk stream over a synthetic dataset of `n` keys.
pub fn chunked_f32(name: &str, n: usize, seed: u64) -> Result<ChunkedF32, String> {
    Ok(ChunkedF32 {
        inner: chunked_f64(name, n, seed)?,
    })
}

impl ChunkedF32 {
    /// Keys not yet produced.
    pub fn remaining(&self) -> usize {
        self.inner.remaining()
    }

    /// Next up-to-`max_len` keys; `None` once `n` keys were produced.
    pub fn next_chunk(&mut self, max_len: usize) -> Option<Vec<f32>> {
        self.inner
            .next_chunk(max_len)
            .map(|c| c.into_iter().map(|x| x as f32).collect())
    }
}

enum U32Kind {
    Osm {
        centers: Vec<(f64, f64, f64)>,
        zipf: Zipf,
    },
    Wiki {
        t: u64,
    },
    Fb,
    Books(Zipf),
    Nyc,
}

/// Stateful chunk stream over a real-world dataset *native to the u32
/// domain*. The previous width-4 path truncated each `u64` draw to its
/// low 32 bits, which wraps every distribution whose entropy lives in the
/// top bits into structureless near-noise (OSM loses its cluster
/// prefixes, FB its heavy tail). The native arms re-scope each law
/// instead: 32-bit Morton codes for OSM, a u32-spanning heavy-tail id law
/// for FB, and direct (lossless — all values `< 2³²`) sampling for the
/// timestamp/sales laws.
pub struct ChunkedU32 {
    kind: U32Kind,
    rng: Xoshiro256pp,
    n: usize,
    produced: usize,
}

/// Open a native u32 chunk stream over a real-world dataset of `n` keys.
pub fn chunked_u32(name: &str, n: usize, seed: u64) -> Result<ChunkedU32, String> {
    let mut rng = Xoshiro256pp::new(seed);
    let kind = match name {
        "osm_cellids" => {
            let (centers, zipf) = realworld::osm_components(&mut rng);
            U32Kind::Osm { centers, zipf }
        }
        "wiki_edit" => U32Kind::Wiki {
            t: realworld::WIKI_T0,
        },
        "fb_ids" => U32Kind::Fb,
        "books_sales" => U32Kind::Books(realworld::books_rank_law(n)),
        "nyc_pickup" => U32Kind::Nyc,
        _ => {
            return Err(format!(
                "unknown u32 dataset '{name}' (f64 dataset? use chunked_f32)"
            ))
        }
    };
    Ok(ChunkedU32 {
        kind,
        rng,
        n,
        produced: 0,
    })
}

impl ChunkedU32 {
    /// Keys not yet produced.
    pub fn remaining(&self) -> usize {
        self.n - self.produced
    }

    /// Next up-to-`max_len` keys; `None` once `n` keys were produced.
    pub fn next_chunk(&mut self, max_len: usize) -> Option<Vec<u32>> {
        let ChunkedU32 {
            kind,
            rng,
            n,
            produced,
        } = self;
        let len = max_len.min(*n - *produced);
        if len == 0 {
            return None;
        }
        let out: Vec<u32> = match kind {
            U32Kind::Osm { centers, zipf } => (0..len)
                .map(|_| realworld::osm_sample_u32(centers, zipf, rng))
                .collect(),
            // timestamps fit u32 until 2106 — the cast is lossless
            U32Kind::Wiki { t } => realworld::wiki_edit_fill(t, len, rng, true)
                .into_iter()
                .map(|x| x as u32)
                .collect(),
            U32Kind::Fb => (0..len).map(|_| realworld::fb_id_sample_u32(rng)).collect(),
            // sales counts top out near 5e7 — lossless
            U32Kind::Books(z) => (0..len)
                .map(|_| realworld::books_sample(z, rng) as u32)
                .collect(),
            U32Kind::Nyc => (0..len).map(|_| realworld::nyc_sample(rng) as u32).collect(),
        };
        *produced += len;
        Some(out)
    }
}

/// Map one ordered-bits image to a prefix-encoded string key: 16 hex
/// digits, most significant nibble first. Hex digits are ASCII-ordered,
/// so string order equals the source's numeric order — and the 8-char
/// prefix only covers the top 32 bits, so any dataset whose draws share
/// high bits (timestamps, the dup laws) produces prefix-*tied* keys whose
/// order lives entirely in the comparison tail.
fn str_key_of(bits: u64) -> PrefixString {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut buf = [0u8; 16];
    for (i, b) in buf.iter_mut().enumerate() {
        *b = HEX[((bits >> (60 - 4 * i)) & 0xF) as usize];
    }
    PrefixString::from_bytes(&buf)
}

/// Stateful chunk stream rendering any registered dataset as
/// prefix-encoded string keys (the `--key str` workload): each draw of
/// the native f64/u64 stream becomes its 16-hex-digit render via
/// [`str_key_of`], preserving the law's order and tie structure.
pub struct ChunkedStr {
    f: Option<ChunkedF64>,
    u: Option<ChunkedU64>,
}

/// Open a string-key chunk stream over any registered dataset.
pub fn chunked_str(name: &str, n: usize, seed: u64) -> Result<ChunkedStr, String> {
    let spec = spec(name).ok_or_else(|| format!("unknown dataset {name}"))?;
    Ok(match spec.key_type {
        KeyType::F64 => ChunkedStr {
            f: Some(chunked_f64(spec.name, n, seed)?),
            u: None,
        },
        KeyType::U64 => ChunkedStr {
            f: None,
            u: Some(chunked_u64(spec.name, n, seed)?),
        },
    })
}

impl ChunkedStr {
    /// Keys not yet produced.
    pub fn remaining(&self) -> usize {
        match (&self.f, &self.u) {
            (Some(g), _) => g.remaining(),
            (_, Some(g)) => g.remaining(),
            _ => unreachable!("chunked_str holds exactly one stream"),
        }
    }

    /// Next up-to-`max_len` keys; `None` once `n` keys were produced.
    pub fn next_chunk(&mut self, max_len: usize) -> Option<Vec<PrefixString>> {
        if let Some(g) = &mut self.f {
            g.next_chunk(max_len)
                .map(|c| c.iter().map(|x| str_key_of(x.to_bits_ordered())).collect())
        } else {
            self.u
                .as_mut()
                .unwrap()
                .next_chunk(max_len)
                .map(|c| c.iter().map(|x| str_key_of(*x)).collect())
        }
    }
}

/// Generate a string-keyed dataset by name: one all-at-once chunk of the
/// [`chunked_str`] stream.
pub fn generate_str(name: &str, n: usize, seed: u64) -> Result<Vec<PrefixString>, String> {
    let mut gen = chunked_str(name, n, seed)?;
    Ok(gen.next_chunk(n).unwrap_or_default())
}

/// Attach `P`-byte payloads to a key chunk, making records: the payload
/// carries the key's global stream position (row id, LE u64) so a sorted
/// output can be checked for key-aligned payload integrity; payloads
/// wider than 8 bytes fill the tail with an index-derived pattern so
/// every byte is data-dependent.
pub fn attach_payloads<K: SortKey, const P: usize>(
    keys: Vec<K>,
    start: u64,
) -> Vec<SortItem<K, P>> {
    keys.into_iter()
        .enumerate()
        .map(|(i, k)| {
            let id = (start + i as u64).to_le_bytes();
            let mut val = [0u8; P];
            let m = P.min(8);
            val[..m].copy_from_slice(&id[..m]);
            for (j, b) in val.iter_mut().enumerate().skip(m) {
                *b = id[j % 8] ^ (j as u8);
            }
            SortItem::new(k, val)
        })
        .collect()
}

/// Write a synthetic dataset at 4-byte width through the dataset-native
/// f32 sampler ([`chunked_f32`]) — the PCF-style narrow-key workload — in
/// bounded memory.
pub fn write_f32_file(
    name: &str,
    n: usize,
    seed: u64,
    path: &Path,
    chunk_len: usize,
) -> Result<(), String> {
    let mut gen = chunked_f32(name, n, seed)?;
    write_chunks(path, chunk_len, |len| gen.next_chunk(len))
}

/// Write a simulated real-world dataset at 4-byte width through the
/// dataset-native u32 sampler ([`chunked_u32`] — no low-32 truncation of
/// the 8-byte stream) in bounded memory.
pub fn write_u32_file(
    name: &str,
    n: usize,
    seed: u64,
    path: &Path,
    chunk_len: usize,
) -> Result<(), String> {
    let mut gen = chunked_u32(name, n, seed)?;
    write_chunks(path, chunk_len, |len| gen.next_chunk(len))
}

/// Stream chunks to disk through the external sorter's spill codec (one
/// self-describing encoding for generated files, spilled runs and sorted
/// outputs, at the key type's native width).
fn write_chunks<K: crate::key::SortKey>(
    path: &Path,
    chunk_len: usize,
    mut next: impl FnMut(usize) -> Option<Vec<K>>,
) -> Result<(), String> {
    let io_err = |e: std::io::Error| format!("{}: {e}", path.display());
    let mut w =
        crate::external::RunWriter::<K>::create(path.to_path_buf(), 1 << 16).map_err(io_err)?;
    while let Some(chunk) = next(chunk_len.max(1)) {
        w.write_slice(&chunk).map_err(io_err)?;
    }
    w.finish().map_err(io_err)?;
    Ok(())
}

/// Write any registered dataset by name at its native 8-byte width
/// (dispatching on its key type).
pub fn write_dataset_file(
    name: &str,
    n: usize,
    seed: u64,
    path: &Path,
    chunk_len: usize,
) -> Result<KeyType, String> {
    let spec = spec(name).ok_or_else(|| format!("unknown dataset {name}"))?;
    match spec.key_type {
        KeyType::F64 => write_f64_file(spec.name, n, seed, path, chunk_len)?,
        KeyType::U64 => write_u64_file(spec.name, n, seed, path, chunk_len)?,
    }
    Ok(spec.key_type)
}

/// Write any registered dataset by name at an explicit key width: `8`
/// writes the native `f64`/`u64` stream, `4` the dataset-native
/// `f32`/`u32` stream (`gen --width` — [`chunked_f32`]/[`chunked_u32`],
/// not a truncation of the 8-byte draws). Returns the key domain of the
/// written file.
pub fn write_dataset_file_width(
    name: &str,
    n: usize,
    seed: u64,
    path: &Path,
    chunk_len: usize,
    width: usize,
) -> Result<KeyKind, String> {
    let spec = spec(name).ok_or_else(|| format!("unknown dataset {name}"))?;
    match (width, spec.key_type) {
        (8, _) => {
            write_dataset_file(name, n, seed, path, chunk_len)?;
            Ok(spec.key_type.kind())
        }
        (4, KeyType::F64) => {
            write_f32_file(spec.name, n, seed, path, chunk_len)?;
            Ok(KeyKind::F32)
        }
        (4, KeyType::U64) => {
            write_u32_file(spec.name, n, seed, path, chunk_len)?;
            Ok(KeyKind::U32)
        }
        _ => Err(format!("unsupported key width {width} (use 4 or 8)")),
    }
}

/// Monomorphic record writer: attach `P`-byte row-id payloads to each
/// chunk and stream the `SortItem`s through the spill codec (v4 header).
fn write_rec<K: SortKey, const P: usize>(
    path: &Path,
    chunk_len: usize,
    mut next: impl FnMut(usize) -> Option<Vec<K>>,
) -> Result<(), String> {
    let mut idx = 0u64;
    write_chunks::<SortItem<K, P>>(path, chunk_len, |len| {
        next(len).map(|c| {
            let out = attach_payloads::<K, P>(c, idx);
            idx += out.len() as u64;
            out
        })
    })
}

/// Dispatch a bare-key chunk stream over the supported payload widths
/// ([`crate::key::DISPATCH_PAYLOADS`]).
fn write_rec_payload<K: SortKey>(
    path: &Path,
    chunk_len: usize,
    payload: usize,
    next: impl FnMut(usize) -> Option<Vec<K>>,
) -> Result<(), String> {
    match payload {
        0 => write_chunks(path, chunk_len, next),
        8 => write_rec::<K, 8>(path, chunk_len, next),
        64 => write_rec::<K, 64>(path, chunk_len, next),
        p => Err(format!(
            "unsupported payload width {p} (supported: {:?})",
            crate::key::DISPATCH_PAYLOADS
        )),
    }
}

/// Write any registered dataset with the full key/record surface of the
/// CLI: `str_keys` renders the stream as prefix-encoded strings
/// ([`chunked_str`]); `payload > 0` attaches row-id payloads, making a
/// record (v4) file. `width` keeps the numeric narrowing rule of
/// [`write_dataset_file_width`] and is ignored for string keys (one
/// 16-byte encoding). Returns the key domain written.
pub fn write_dataset_file_ext(
    name: &str,
    n: usize,
    seed: u64,
    path: &Path,
    chunk_len: usize,
    width: usize,
    str_keys: bool,
    payload: usize,
) -> Result<KeyKind, String> {
    if str_keys {
        let mut g = chunked_str(name, n, seed)?;
        write_rec_payload::<PrefixString>(path, chunk_len, payload, |len| g.next_chunk(len))?;
        return Ok(KeyKind::Str);
    }
    if payload == 0 {
        return write_dataset_file_width(name, n, seed, path, chunk_len, width);
    }
    let spec = spec(name).ok_or_else(|| format!("unknown dataset {name}"))?;
    match (width, spec.key_type) {
        (8, KeyType::F64) => {
            let mut g = chunked_f64(spec.name, n, seed)?;
            write_rec_payload::<f64>(path, chunk_len, payload, |len| g.next_chunk(len))?;
            Ok(KeyKind::F64)
        }
        (8, KeyType::U64) => {
            let mut g = chunked_u64(spec.name, n, seed)?;
            write_rec_payload::<u64>(path, chunk_len, payload, |len| g.next_chunk(len))?;
            Ok(KeyKind::U64)
        }
        (4, KeyType::F64) => {
            let mut g = chunked_f32(spec.name, n, seed)?;
            write_rec_payload::<f32>(path, chunk_len, payload, |len| g.next_chunk(len))?;
            Ok(KeyKind::F32)
        }
        (4, KeyType::U64) => {
            let mut g = chunked_u32(spec.name, n, seed)?;
            write_rec_payload::<u32>(path, chunk_len, payload, |len| g.next_chunk(len))?;
            Ok(KeyKind::U32)
        }
        _ => Err(format!("unsupported key width {width} (use 4 or 8)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_14_datasets() {
        assert_eq!(ALL.len(), 14);
        assert_eq!(f64_names().len(), 9);
        assert_eq!(u64_names().len(), 5);
    }

    #[test]
    fn lookup_by_both_names() {
        assert!(spec("uniform").is_some());
        assert!(spec("OSM/Cell_IDs").is_some());
        assert!(spec("bogus").is_none());
    }

    #[test]
    fn all_f64_generate() {
        for name in f64_names() {
            let v = generate_f64(name, 1000, 1).unwrap();
            assert_eq!(v.len(), 1000, "{name}");
            assert!(v.iter().all(|x| x.is_finite()), "{name} produced non-finite");
        }
    }

    #[test]
    fn all_u64_generate() {
        for name in u64_names() {
            let v = generate_u64(name, 1000, 1).unwrap();
            assert_eq!(v.len(), 1000, "{name}");
        }
    }

    #[test]
    fn wrong_key_type_errors() {
        assert!(generate_f64("wiki_edit", 10, 1).is_err());
        assert!(generate_u64("uniform", 10, 1).is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_f64("normal", 500, 7).unwrap();
        let b = generate_f64("normal", 500, 7).unwrap();
        let c = generate_f64("normal", 500, 8).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    fn drain_f64(name: &str, n: usize, seed: u64, chunk: usize) -> Vec<f64> {
        let mut g = chunked_f64(name, n, seed).unwrap();
        let mut out = Vec::new();
        while let Some(c) = g.next_chunk(chunk) {
            out.extend(c);
        }
        out
    }

    fn drain_u64(name: &str, n: usize, seed: u64, chunk: usize) -> Vec<u64> {
        let mut g = chunked_u64(name, n, seed).unwrap();
        let mut out = Vec::new();
        while let Some(c) = g.next_chunk(chunk) {
            out.extend(c);
        }
        out
    }

    #[test]
    fn chunked_f64_matches_monolithic() {
        // chunk stream is draw-for-draw identical to the one-shot generator
        for name in f64_names() {
            let mono = generate_f64(name, 3000, 5).unwrap();
            let chunked = drain_f64(name, 3000, 5, 700);
            assert_eq!(
                mono.len(),
                chunked.len(),
                "{name}: length mismatch"
            );
            let mb: Vec<u64> = mono.iter().map(|x| x.to_bits()).collect();
            let cb: Vec<u64> = chunked.iter().map(|x| x.to_bits()).collect();
            assert_eq!(mb, cb, "{name}: chunked stream diverges");
        }
    }

    #[test]
    fn chunked_u64_matches_monolithic_distribution() {
        for name in u64_names() {
            let mut mono = generate_u64(name, 3000, 5).unwrap();
            let mut chunked = drain_u64(name, 3000, 5, 700);
            assert_eq!(chunked.len(), 3000, "{name}");
            if name == "wiki_edit" {
                // the edit process chunks with truncated bursts + local
                // shuffles — check the distribution's shape, not the bytes
                mono.sort_unstable();
                chunked.sort_unstable();
                let dups = chunked.windows(2).filter(|w| w[0] == w[1]).count();
                assert!(dups > 100, "{name}: duplicate bursts lost ({dups})");
                assert!(
                    *chunked.first().unwrap() >= realworld::WIKI_T0,
                    "{name}: timestamps before the epoch"
                );
            } else {
                assert_eq!(mono, chunked, "{name}: chunked stream diverges");
            }
        }
    }

    #[test]
    fn chunked_handles_degenerate_sizes() {
        assert!(chunked_f64("uniform", 0, 1).unwrap().next_chunk(10).is_none());
        let one = drain_u64("fb_ids", 1, 1, 1000);
        assert_eq!(one.len(), 1);
        assert!(chunked_f64("wiki_edit", 10, 1).is_err());
        assert!(chunked_u64("uniform", 10, 1).is_err());
    }

    #[test]
    fn width_4_files_use_the_native_32_bit_streams() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("aipso-ds-w4-{}.bin", std::process::id()));
        let kind = write_dataset_file_width("uniform", 800, 5, &p, 128, 4).unwrap();
        assert_eq!(kind, KeyKind::F32);
        let back = crate::external::read_keys_file::<f32>(&p).unwrap();
        let mut gen = chunked_f32("uniform", 800, 5).unwrap();
        let want = gen.next_chunk(800).unwrap();
        assert!(gen.next_chunk(1).is_none());
        let gb: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        let wb: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(gb, wb, "the file must be the native f32 stream");

        let kind = write_dataset_file_width("fb_ids", 800, 5, &p, 128, 4).unwrap();
        assert_eq!(kind, KeyKind::U32);
        let back = crate::external::read_keys_file::<u32>(&p).unwrap();
        let mut gen = chunked_u32("fb_ids", 800, 5).unwrap();
        let want = gen.next_chunk(800).unwrap();
        assert_eq!(back, want, "the file must be the native u32 stream");

        // width 8 defers to the native writer; anything else errors
        let kind = write_dataset_file_width("uniform", 100, 5, &p, 64, 8).unwrap();
        assert_eq!(kind, KeyKind::F64);
        assert!(write_dataset_file_width("uniform", 10, 5, &p, 64, 2).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn chunked_32_bit_streams_cover_all_datasets_and_reject_mismatches() {
        for name in f64_names() {
            let mut g = chunked_f32(name, 2000, 9).unwrap();
            let mut total = 0;
            while let Some(c) = g.next_chunk(700) {
                assert!(c.iter().all(|x| x.is_finite()), "{name}");
                total += c.len();
            }
            assert_eq!(total, 2000, "{name}");
        }
        for name in u64_names() {
            let mut g = chunked_u32(name, 2000, 9).unwrap();
            let mut total = 0;
            while let Some(c) = g.next_chunk(700) {
                total += c.len();
            }
            assert_eq!(total, 2000, "{name}");
        }
        assert!(chunked_f32("wiki_edit", 10, 1).is_err());
        assert!(chunked_u32("uniform", 10, 1).is_err());
        assert!(chunked_f32("uniform", 0, 1).unwrap().next_chunk(10).is_none());
        assert!(chunked_u32("fb_ids", 0, 1).unwrap().next_chunk(10).is_none());
    }

    fn distinct_ratio(bits: &mut [u64]) -> f64 {
        bits.sort_unstable();
        let distinct = 1 + bits.windows(2).filter(|w| w[0] != w[1]).count();
        distinct as f64 / bits.len().max(1) as f64
    }

    #[test]
    fn width_4_zipf_and_uniform_keep_their_distinct_key_ratio() {
        // The narrow-width bugfix's acceptance: a width-4 file of zipf or
        // uniform must carry (about) the same distinct-key structure as
        // the width-8 stream — narrowing is a re-parameterization of the
        // law, not a collapse into near-duplicates.
        let n = 40_000;
        for name in ["zipf", "uniform"] {
            let mut wide: Vec<u64> = generate_f64(name, n, 11)
                .unwrap()
                .iter()
                .map(|x| x.to_bits())
                .collect();
            let mut g = chunked_f32(name, n, 11).unwrap();
            let mut narrow: Vec<u64> = Vec::with_capacity(n);
            while let Some(c) = g.next_chunk(8192) {
                narrow.extend(c.iter().map(|x| x.to_bits() as u64));
            }
            let rw = distinct_ratio(&mut wide);
            let rn = distinct_ratio(&mut narrow);
            assert!(
                rn > 0.9 * rw,
                "{name}: width-4 distinct ratio {rn} collapsed vs width-8 {rw}"
            );
        }
    }

    #[test]
    fn string_streams_preserve_order_and_tie_structure() {
        // every dataset renders; order of the string keys equals the
        // numeric order of the source stream
        for name in ["uniform", "wiki_edit"] {
            let s = generate_str(name, 2000, 4).unwrap();
            assert_eq!(s.len(), 2000, "{name}");
            let mut nums: Vec<u64> = match spec(name).unwrap().key_type {
                KeyType::F64 => generate_f64(name, 2000, 4)
                    .unwrap()
                    .iter()
                    .map(|x| x.to_bits_ordered())
                    .collect(),
                KeyType::U64 => generate_u64(name, 2000, 4).unwrap(),
            };
            let mut strs = s.clone();
            nums.sort_unstable();
            strs.sort_unstable();
            let roundtrip: Vec<PrefixString> = nums.iter().map(|&b| str_key_of(b)).collect();
            assert_eq!(
                strs.iter().map(|k| k.as_bytes().to_vec()).collect::<Vec<_>>(),
                roundtrip.iter().map(|k| k.as_bytes().to_vec()).collect::<Vec<_>>(),
                "{name}: string order must equal numeric order"
            );
        }
        // wiki timestamps share their top 32 bits heavily: the 8-char
        // prefix must actually tie (that's the workload's whole point)
        let s = generate_str("wiki_edit", 2000, 4).unwrap();
        let mut bits: Vec<u64> = s.iter().map(|k| k.to_bits_ordered()).collect();
        bits.sort_unstable();
        let prefix_ties = bits.windows(2).filter(|w| w[0] == w[1]).count();
        assert!(prefix_ties > 100, "prefix ties lost: {prefix_ties}");
        assert!(chunked_str("bogus", 10, 1).is_err());
    }

    #[test]
    fn record_files_roundtrip_with_row_id_payloads() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("aipso-ds-rec-{}.bin", std::process::id()));
        let kind =
            write_dataset_file_ext("fb_ids", 600, 7, &p, 128, 8, false, 8).unwrap();
        assert_eq!(kind, KeyKind::U64);
        let back = crate::external::read_keys_file::<SortItem<u64, 8>>(&p).unwrap();
        let want = generate_u64("fb_ids", 600, 7).unwrap();
        assert_eq!(back.len(), want.len());
        for (i, (rec, k)) in back.iter().zip(&want).enumerate() {
            assert_eq!(rec.key, *k, "key stream intact at {i}");
            assert_eq!(rec.val, (i as u64).to_le_bytes(), "row id payload at {i}");
        }
        // string-key records: header carries the Str domain
        let kind =
            write_dataset_file_ext("uniform", 300, 7, &p, 128, 8, true, 64).unwrap();
        assert_eq!(kind, KeyKind::Str);
        let back =
            crate::external::read_keys_file::<SortItem<PrefixString, 64>>(&p).unwrap();
        assert_eq!(back.len(), 300);
        let want = generate_str("uniform", 300, 7).unwrap();
        assert_eq!(back[5].key.as_bytes(), want[5].as_bytes());
        assert!(write_dataset_file_ext("uniform", 10, 7, &p, 128, 8, false, 3).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn write_files_roundtrip_via_external_codec() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("aipso-ds-file-{}.bin", std::process::id()));
        write_f64_file("two_dups", 1234, 3, &p, 100).unwrap();
        let back = crate::external::read_keys_file::<f64>(&p).unwrap();
        assert_eq!(back, generate_f64("two_dups", 1234, 3).unwrap());
        let kt = write_dataset_file("nyc_pickup", 500, 3, &p, 128).unwrap();
        assert_eq!(kt, KeyType::U64);
        let back = crate::external::read_keys_file::<u64>(&p).unwrap();
        assert_eq!(back, generate_u64("nyc_pickup", 500, 3).unwrap());
        let _ = std::fs::remove_file(&p);
    }
}
