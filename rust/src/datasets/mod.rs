//! Dataset suite (substrate S2) — the paper's 14 benchmark inputs.
//!
//! Synthetic datasets (64-bit doubles, Section 5 "Synthetic Datasets") are
//! generated exactly as specified. Real-world datasets (64-bit unsigned
//! integers, from SOSD / Marcus et al.) are not redistributable, so
//! [`realworld`] builds *statistical simulators* that reproduce the property
//! each dataset exercises in the paper's evaluation — CDF smoothness
//! (RMI fit quality), duplicate density (equality buckets) and radix-prefix
//! skew (IPS²Ra balance). See DESIGN.md §6 for the substitution table.

pub mod realworld;
pub mod synthetic;

use crate::util::rng::Xoshiro256pp;

/// Key type of a dataset, mirroring the paper (synthetic = f64 doubles,
/// real-world = u64 ids/timestamps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KeyType {
    F64,
    U64,
}

/// Which paper figure a dataset appears in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FigureGroup {
    /// Figures 1 & 4: Uniform, Normal, Log-Normal.
    Synthetic1,
    /// Figures 2 & 5: MixGauss, Exponential, Chi-Squared, RootDups,
    /// TwoDups, Zipf.
    Synthetic2,
    /// Figures 3 & 6: OSM, Wiki, FB, Books, NYC.
    RealWorld,
}

#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub paper_name: &'static str,
    pub key_type: KeyType,
    pub group: FigureGroup,
    /// Relative input size vs the synthetic N (paper: real-world sets are
    /// 2x except NYC).
    pub size_factor: f64,
    pub description: &'static str,
}

/// All 14 datasets, in the paper's presentation order.
pub const ALL: [DatasetSpec; 14] = [
    DatasetSpec { name: "uniform", paper_name: "Uniform", key_type: KeyType::F64, group: FigureGroup::Synthetic1, size_factor: 1.0, description: "U(0, N)" },
    DatasetSpec { name: "normal", paper_name: "Normal", key_type: KeyType::F64, group: FigureGroup::Synthetic1, size_factor: 1.0, description: "N(0, 1)" },
    DatasetSpec { name: "lognormal", paper_name: "Log-Normal", key_type: KeyType::F64, group: FigureGroup::Synthetic1, size_factor: 1.0, description: "LogN(0, 0.5)" },
    DatasetSpec { name: "mix_gauss", paper_name: "Mix Gauss", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "random additive mixture of five Gaussians" },
    DatasetSpec { name: "exponential", paper_name: "Exponential", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "Exp(lambda=2)" },
    DatasetSpec { name: "chi_squared", paper_name: "Chi-Squared", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "chi2(k=4)" },
    DatasetSpec { name: "root_dups", paper_name: "Root Dups", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "A[i] = i mod sqrt(N) (BlockQuicksort)" },
    DatasetSpec { name: "two_dups", paper_name: "Two Dups", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "A[i] = i^2 + N/2 mod N (BlockQuicksort)" },
    DatasetSpec { name: "zipf", paper_name: "Zipf", key_type: KeyType::F64, group: FigureGroup::Synthetic2, size_factor: 1.0, description: "Zipf(s=0.75)" },
    DatasetSpec { name: "osm_cellids", paper_name: "OSM/Cell_IDs", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 2.0, description: "simulated OpenStreetMap cell ids (clustered Morton codes)" },
    DatasetSpec { name: "wiki_edit", paper_name: "Wiki/Edit", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 2.0, description: "simulated Wikipedia edit timestamps (bursty, duplicate-heavy)" },
    DatasetSpec { name: "fb_ids", paper_name: "FB/IDs", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 2.0, description: "simulated Facebook user ids (heavy-tailed, RMI-hard)" },
    DatasetSpec { name: "books_sales", paper_name: "Books/Sales", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 2.0, description: "simulated Amazon book popularity (Zipf plateaus)" },
    DatasetSpec { name: "nyc_pickup", paper_name: "NYC/Pickup", key_type: KeyType::U64, group: FigureGroup::RealWorld, size_factor: 1.0, description: "simulated taxi pickup timestamps (seasonal)" },
];

pub fn spec(name: &str) -> Option<&'static DatasetSpec> {
    ALL.iter().find(|d| d.name == name || d.paper_name == name)
}

pub fn f64_names() -> Vec<&'static str> {
    ALL.iter()
        .filter(|d| d.key_type == KeyType::F64)
        .map(|d| d.name)
        .collect()
}

pub fn u64_names() -> Vec<&'static str> {
    ALL.iter()
        .filter(|d| d.key_type == KeyType::U64)
        .map(|d| d.name)
        .collect()
}

/// Generate a double-keyed (synthetic) dataset by name.
pub fn generate_f64(name: &str, n: usize, seed: u64) -> Result<Vec<f64>, String> {
    let mut rng = Xoshiro256pp::new(seed);
    Ok(match name {
        "uniform" => synthetic::uniform(n, &mut rng),
        "normal" => synthetic::normal(n, &mut rng),
        "lognormal" => synthetic::lognormal(n, &mut rng),
        "mix_gauss" => synthetic::mix_gauss(n, &mut rng),
        "exponential" => synthetic::exponential(n, &mut rng),
        "chi_squared" => synthetic::chi_squared(n, &mut rng),
        "root_dups" => synthetic::root_dups(n),
        "two_dups" => synthetic::two_dups(n),
        "zipf" => synthetic::zipf(n, &mut rng),
        _ => return Err(format!("unknown f64 dataset '{name}' (u64 dataset? use generate_u64)")),
    })
}

/// Generate an integer-keyed (simulated real-world) dataset by name.
pub fn generate_u64(name: &str, n: usize, seed: u64) -> Result<Vec<u64>, String> {
    let mut rng = Xoshiro256pp::new(seed);
    Ok(match name {
        "osm_cellids" => realworld::osm_cellids(n, &mut rng),
        "wiki_edit" => realworld::wiki_edit(n, &mut rng),
        "fb_ids" => realworld::fb_ids(n, &mut rng),
        "books_sales" => realworld::books_sales(n, &mut rng),
        "nyc_pickup" => realworld::nyc_pickup(n, &mut rng),
        _ => return Err(format!("unknown u64 dataset '{name}' (f64 dataset? use generate_f64)")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_14_datasets() {
        assert_eq!(ALL.len(), 14);
        assert_eq!(f64_names().len(), 9);
        assert_eq!(u64_names().len(), 5);
    }

    #[test]
    fn lookup_by_both_names() {
        assert!(spec("uniform").is_some());
        assert!(spec("OSM/Cell_IDs").is_some());
        assert!(spec("bogus").is_none());
    }

    #[test]
    fn all_f64_generate() {
        for name in f64_names() {
            let v = generate_f64(name, 1000, 1).unwrap();
            assert_eq!(v.len(), 1000, "{name}");
            assert!(v.iter().all(|x| x.is_finite()), "{name} produced non-finite");
        }
    }

    #[test]
    fn all_u64_generate() {
        for name in u64_names() {
            let v = generate_u64(name, 1000, 1).unwrap();
            assert_eq!(v.len(), 1000, "{name}");
        }
    }

    #[test]
    fn wrong_key_type_errors() {
        assert!(generate_f64("wiki_edit", 10, 1).is_err());
        assert!(generate_u64("uniform", 10, 1).is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate_f64("normal", 500, 7).unwrap();
        let b = generate_f64("normal", 500, 7).unwrap();
        let c = generate_f64("normal", 500, 8).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
