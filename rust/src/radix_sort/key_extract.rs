//! Digit extraction (substrate S8): the radix engines see every key
//! through its order-preserving `u64` image ([`SortKey::to_bits_ordered`]),
//! which is exactly the float→integer key extractor the paper passes to
//! IPS²Ra for the double-keyed datasets.

use crate::classifier::Classifier;
use crate::key::SortKey;

/// 256-way classifier on byte `level` (0 = most significant of the key's
/// significant width) — the IPS²Ra "splitter" at one recursion level.
#[derive(Debug, Clone, Copy)]
pub struct DigitClassifier {
    shift: u32,
}

impl DigitClassifier {
    /// Classifier for recursion level `level` of key type `K`.
    pub fn new<K: SortKey>(level: usize) -> DigitClassifier {
        debug_assert!(level < K::RADIX_BYTES);
        DigitClassifier {
            shift: (8 * (K::RADIX_BYTES - 1 - level)) as u32,
        }
    }

    /// Classifier for an explicit bit shift (used after common-prefix
    /// skipping).
    pub fn with_shift(shift: u32) -> DigitClassifier {
        DigitClassifier { shift }
    }

    /// The bit shift this classifier extracts its digit at.
    pub fn shift(&self) -> u32 {
        self.shift
    }
}

impl<K: SortKey> Classifier<K> for DigitClassifier {
    fn num_buckets(&self) -> usize {
        256
    }

    #[inline(always)]
    fn classify(&self, key: K) -> usize {
        ((key.to_bits_ordered() >> self.shift) & 0xFF) as usize
    }

    fn is_equality_bucket(&self, _b: usize) -> bool {
        false
    }

    fn classify_batch(&self, keys: &[K], out: &mut [u32]) {
        let sh = self.shift;
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = ((k.to_bits_ordered() >> sh) & 0xFF) as u32;
        }
    }
}

/// Highest differing byte position of the ordered images (common-prefix
/// skip). Returns `None` when all keys are equal.
pub fn first_diverging_shift<K: SortKey>(keys: &[K]) -> Option<u32> {
    if keys.is_empty() {
        return None;
    }
    let mut lo = u64::MAX;
    let mut hi = 0u64;
    for k in keys {
        let b = k.to_bits_ordered();
        lo = lo.min(b);
        hi = hi.max(b);
    }
    if lo == hi {
        return None;
    }
    let diff = lo ^ hi;
    // byte index (from msb of the significant width) of the first set bit
    let leading_byte = (63 - diff.leading_zeros()) / 8;
    Some(8 * leading_byte)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digit_extraction_u64() {
        let c = DigitClassifier::new::<u64>(0);
        assert_eq!(Classifier::<u64>::classify(&c, 0xAB00_0000_0000_0000), 0xAB);
        let c = DigitClassifier::new::<u64>(7);
        assert_eq!(Classifier::<u64>::classify(&c, 0x00000000_000000CD), 0xCD);
    }

    #[test]
    fn digit_extraction_f64_ordered() {
        // negative floats must classify below positive ones at byte 0
        let c = DigitClassifier::new::<f64>(0);
        let neg = Classifier::<f64>::classify(&c, -1.0f64);
        let pos = Classifier::<f64>::classify(&c, 1.0f64);
        assert!(neg < pos);
    }

    #[test]
    fn diverging_shift() {
        assert_eq!(first_diverging_shift::<u64>(&[5, 5, 5]), None);
        // differ in lowest byte
        assert_eq!(first_diverging_shift::<u64>(&[5, 6]), Some(0));
        // differ at second-highest byte
        let keys = [0x00AA_0000_0000_0000u64, 0x00BB_0000_0000_0000u64];
        assert_eq!(first_diverging_shift::<u64>(&keys), Some(48));
        assert_eq!(first_diverging_shift::<u64>(&[]), None);
    }

    #[test]
    fn u32_digits() {
        let c = DigitClassifier::new::<u32>(0);
        assert_eq!(Classifier::<u32>::classify(&c, 0xAB00_0000u32), 0xAB);
        let c = DigitClassifier::new::<u32>(3);
        assert_eq!(Classifier::<u32>::classify(&c, 0xCDu32), 0xCD);
    }
}
