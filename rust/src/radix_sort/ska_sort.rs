//! SkaSort — in-place MSD byte radix sort with American-flag swap cycles
//! (Skarupke 2016). The base case of IPS²Ra and of AIPS²o (the paper,
//! Section 4: "SkaSort is used for the base case when there are less than
//! 4096 elements").

use crate::key::SortKey;
use crate::radix_sort::key_extract::first_diverging_shift;
use crate::sample_sort::base_case::small_sort;

/// Below this, comparison sorting beats the byte histogram: each ska
/// level zeroes ~8 KiB of bucket bookkeeping, which dominates on small
/// segments (perf log, EXPERIMENTS.md §Perf). SkaSort proper uses
/// std::sort below 128 for the same reason.
pub const SKA_INSERTION_THRESHOLD: usize = 1024;

/// In-place MSD radix sort over the order-preserving bit image.
pub fn ska_sort<K: SortKey>(data: &mut [K]) {
    if data.len() < 2 {
        return;
    }
    // skip common prefix bytes up front
    match first_diverging_shift(data) {
        None => (), // all equal
        Some(shift) => ska_rec(data, shift),
    }
}

fn ska_rec<K: SortKey>(data: &mut [K], shift: u32) {
    if data.len() <= SKA_INSERTION_THRESHOLD {
        small_sort(data);
        return;
    }
    // histogram of the current byte
    let mut counts = [0usize; 256];
    for k in data.iter() {
        counts[((k.to_bits_ordered() >> shift) & 0xFF) as usize] += 1;
    }
    // bucket start/end offsets
    let mut starts = [0usize; 256];
    let mut ends = [0usize; 256];
    let mut acc = 0usize;
    for d in 0..256 {
        starts[d] = acc;
        acc += counts[d];
        ends[d] = acc;
    }
    // American flag permutation: advance per-bucket cursors, swapping
    // each key directly to its bucket.
    let mut cursors = starts;
    for d in 0..256 {
        let mut i = cursors[d];
        while i < ends[d] {
            let b = ((data[i].to_bits_ordered() >> shift) & 0xFF) as usize;
            if b == d {
                i += 1;
                cursors[d] = i;
            } else {
                data.swap(i, cursors[b]);
                cursors[b] += 1;
            }
        }
    }
    // recurse per bucket on the next byte
    if shift == 0 {
        return;
    }
    for d in 0..256 {
        let seg = &mut data[starts[d]..ends[d]];
        if seg.len() > 1 {
            // re-check divergence: lets us skip constant bytes cheaply
            if let Some(s) = first_diverging_shift(seg) {
                ska_rec(seg, s.min(shift - 8));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn sorts_random_u64() {
        let mut rng = Xoshiro256pp::new(0x5CA);
        for n in [0usize, 1, 2, 63, 64, 65, 1000, 50_000] {
            let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let mut want = v.clone();
            want.sort_unstable();
            ska_sort(&mut v);
            assert_eq!(v, want, "n={n}");
        }
    }

    #[test]
    fn sorts_narrow_universe() {
        let mut rng = Xoshiro256pp::new(0x5CB);
        let mut v: Vec<u64> = (0..30_000).map(|_| rng.next_below(7)).collect();
        let mut want = v.clone();
        want.sort_unstable();
        ska_sort(&mut v);
        assert_eq!(v, want);
    }

    #[test]
    fn sorts_common_prefix_keys() {
        // all keys share the top 6 bytes — prefix skip must engage
        let mut rng = Xoshiro256pp::new(0x5CC);
        let base = 0xDEAD_BEEF_0000_0000u64;
        let mut v: Vec<u64> = (0..20_000).map(|_| base | rng.next_below(1 << 16)).collect();
        let mut want = v.clone();
        want.sort_unstable();
        ska_sort(&mut v);
        assert_eq!(v, want);
    }

    #[test]
    fn sorts_floats() {
        let mut rng = Xoshiro256pp::new(0x5CD);
        let mut v: Vec<f64> = (0..25_000).map(|_| rng.normal() * 1e6).collect();
        ska_sort(&mut v);
        assert!(is_sorted(&v));
    }

    #[test]
    fn all_equal_fast_path() {
        let mut v = vec![42u64; 10_000];
        ska_sort(&mut v);
        assert!(v.iter().all(|&x| x == 42));
    }
}
