//! IPS²Ra drivers: the shared block-partition framework with a byte-digit
//! classifier, descending one digit per recursion level; SkaSort below the
//! base-case threshold.
//!
//! Note the property the paper measures: radix buckets have **no balance
//! guarantee** (any number of keys may share a prefix byte), which is why
//! IPS²Ra loses the parallel benchmark — threads idle while one heavy
//! bucket is processed (Section 5.2). We reproduce that behaviour, not fix
//! it.

use crate::key::SortKey;
use crate::radix_sort::key_extract::{first_diverging_shift, DigitClassifier};
use crate::radix_sort::ska_sort::ska_sort;
use crate::sample_sort::partition::partition;
use crate::scheduler::run_task_pool;
use crate::util::timer::{phase_scope, Phase};

/// Below this, SkaSort (matches IPS²Ra's base case & the paper's 4096).
pub const BASE_CASE: usize = 4096;
/// Keys per block in the partition framework.
const BLOCK: usize = 128;

/// Sequential IPS²Ra (paper name: I1S²Ra).
pub fn sort_seq<K: SortKey>(data: &mut [K]) {
    sort_rec(data, 1);
}

/// Parallel IPS²Ra.
pub fn sort_par<K: SortKey>(data: &mut [K], threads: usize) {
    let threads = threads.max(1);
    let n = data.len();
    if threads == 1 || n <= BASE_CASE.max(4 * BLOCK * threads) {
        return sort_seq(data);
    }
    let Some(shift) = first_diverging_shift(data) else {
        return; // constant input
    };
    // Top level: cooperative partition on the first diverging byte.
    let classifier = DigitClassifier::with_shift(shift);
    let result = partition(data, &classifier, BLOCK, threads);
    let base = data.as_mut_ptr() as usize;
    let mut tasks: Vec<(usize, usize)> = Vec::new();
    for b in 0..256 {
        let (lo, hi) = (result.boundaries[b], result.boundaries[b + 1]);
        if hi - lo > 1 {
            tasks.push((lo, hi - lo));
        }
    }
    run_task_pool(threads, tasks, move |(off, len), spawner| {
        // SAFETY: disjoint partition ranges.
        let sub = unsafe { std::slice::from_raw_parts_mut((base as *mut K).add(off), len) };
        if len <= BASE_CASE {
            let _g = phase_scope(Phase::BaseCase);
            ska_sort(sub);
            return;
        }
        let Some(shift) = first_diverging_shift(sub) else {
            return;
        };
        let classifier = DigitClassifier::with_shift(shift);
        let res = partition(sub, &classifier, BLOCK, 1);
        for b in 0..256 {
            let (lo, hi) = (res.boundaries[b], res.boundaries[b + 1]);
            if hi - lo > 1 {
                spawner.spawn((off + lo, hi - lo));
            }
        }
    });
}

fn sort_rec<K: SortKey>(data: &mut [K], threads: usize) {
    if data.len() <= BASE_CASE {
        let _g = phase_scope(Phase::BaseCase);
        ska_sort(data);
        return;
    }
    let Some(shift) = first_diverging_shift(data) else {
        return;
    };
    let classifier = DigitClassifier::with_shift(shift);
    let result = partition(data, &classifier, BLOCK, threads);
    for b in 0..256 {
        let (lo, hi) = (result.boundaries[b], result.boundaries[b + 1]);
        if hi - lo > 1 {
            sort_rec(&mut data[lo..hi], 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::is_sorted;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn seq_sorts() {
        let mut rng = Xoshiro256pp::new(0x2A);
        for n in [0usize, 1, 100, 4096, 4097, 100_000] {
            let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let mut want = v.clone();
            want.sort_unstable();
            sort_seq(&mut v);
            assert_eq!(v, want, "n={n}");
        }
    }

    #[test]
    fn par_sorts() {
        for (n, t) in [(50_000usize, 2usize), (200_000, 4), (123_457, 8)] {
            let mut rng = Xoshiro256pp::new(n as u64);
            let mut v: Vec<u64> = (0..n).map(|_| rng.next_below(1 << 44)).collect();
            let mut want = v.clone();
            want.sort_unstable();
            sort_par(&mut v, t);
            assert_eq!(v, want, "n={n} t={t}");
        }
    }

    #[test]
    fn skewed_prefixes() {
        // everything in one byte-bucket at level 0 — exercises prefix skip
        let mut rng = Xoshiro256pp::new(0x2B);
        let mut v: Vec<u64> = (0..100_000)
            .map(|_| 0xAA00_0000_0000_0000u64 | rng.next_below(1 << 20))
            .collect();
        let mut want = v.clone();
        want.sort_unstable();
        sort_par(&mut v, 4);
        assert_eq!(v, want);
    }

    #[test]
    fn floats_and_duplicates() {
        let mut rng = Xoshiro256pp::new(0x2C);
        let mut v: Vec<f64> = (0..80_000).map(|_| (rng.next_below(50) as f64) - 25.0).collect();
        sort_par(&mut v, 4);
        assert!(is_sorted(&v));
        let mut c = vec![1.5f64; 10_000];
        sort_seq(&mut c);
        assert!(is_sorted(&c));
    }
}
