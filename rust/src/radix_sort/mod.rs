//! IPS²Ra — In-place Parallel Super Scalar Radix Sort (engine E2), plus
//! SkaSort (substrate S6), after Axtmann et al. (TOPC '22) and Skarupke
//! ("I Wrote a Faster Sorting Algorithm", 2016).
//!
//! IPS²Ra is "the IPS⁴o framework with a most-significant-digit radix
//! strategy": the splitter tree is replaced by a byte-digit classifier and
//! the recursion descends one digit per level. SkaSort (in-place American
//! flag byte sort) is the base case — the same role it plays in the
//! original IPS²Ra. Floats route through the order-preserving bit image
//! (the paper's "key extractor that maps floats to integers").

pub mod ips2ra;
pub mod key_extract;
pub mod ska_sort;

pub use ips2ra::{sort_par, sort_seq};
