//! Parallel-balance model — the testbed substitution for the paper's
//! 48-core machine (DESIGN.md §6).
//!
//! This box exposes a single core, so parallel *wallclock* cannot
//! reproduce Figures 4–6. What does transfer is the paper's explanation
//! of those figures (Section 5.2): "AIPS²o creates the best partition of
//! the data ... which creates many subproblems of a balanced size. This
//! favours the performance of AIPS²o because it manages to keep every
//! thread of the CPU busy", while "IPS²Ra does not manage to use all the
//! hardware because its partitions are not balanced".
//!
//! We therefore measure the *real* top-level bucket-size distribution each
//! engine produces on the *real* dataset, then compute the makespan of an
//! LPT (longest-processing-time) schedule of the recursion onto T
//! simulated workers, plus the cooperative partition pass. The resulting
//! *simulated speedup* reproduces the figures' ranking mechanism exactly;
//! absolute keys/s still comes from the measured sequential rates.

use crate::aips2o::{build_partition_model, StrategyConfig};
use crate::classifier::decision_tree::DecisionTree;
use crate::classifier::Classifier;
use crate::key::SortKey;
use crate::radix_sort::key_extract::{first_diverging_shift, DigitClassifier};
use crate::util::rng::Xoshiro256pp;
use crate::SortEngine;

/// Top-level bucket sizes engine `engine` would produce on `data`.
pub fn top_level_bucket_sizes<K: SortKey>(
    data: &[K],
    engine: SortEngine,
    seed: u64,
) -> Vec<usize> {
    let mut rng = Xoshiro256pp::new(seed);
    let n = data.len();
    match engine {
        SortEngine::Aips2o => {
            match build_partition_model(data, &StrategyConfig::default(), &mut rng) {
                None => vec![n],
                Some(model) => count_buckets(data, &model),
            }
        }
        SortEngine::Ips4o | SortEngine::LearnedSort => {
            // IPS4o's tree (LearnedSort's round-1 RMI behaves like Aips2o's)
            let mut sample: Vec<K> = (0..(8 * 256).min(n.max(1)))
                .map(|_| data[rng.next_below(n.max(1) as u64) as usize])
                .collect();
            sample.sort_unstable_by(|a, b| a.to_bits_ordered().cmp(&b.to_bits_ordered()));
            let tree = DecisionTree::from_sorted_sample(&sample, 256);
            count_buckets(data, &tree)
        }
        SortEngine::Ips2ra => match first_diverging_shift(data) {
            None => vec![n],
            Some(shift) => {
                let c = DigitClassifier::with_shift(shift);
                count_buckets(data, &c)
            }
        },
        // parallel mergesort: perfectly equal chunks by construction
        _ => {
            let t = 48;
            let chunk = n.div_ceil(t);
            (0..t).map(|i| chunk.min(n.saturating_sub(i * chunk))).collect()
        }
    }
}

fn count_buckets<K: SortKey, C: Classifier<K> + ?Sized>(data: &[K], c: &C) -> Vec<usize> {
    let mut counts = vec![0usize; c.num_buckets()];
    for &k in data {
        counts[c.classify(k)] += 1;
    }
    counts
}

/// Balance statistics of a bucket-size vector.
#[derive(Debug, Clone, Copy)]
pub struct BalanceStats {
    /// Largest bucket as a fraction of n (1.0 = everything in one bucket).
    pub max_fraction: f64,
    /// Coefficient of variation of the non-empty bucket sizes.
    pub cv: f64,
    /// Number of non-empty buckets.
    pub nonempty: usize,
}

/// Compute [`BalanceStats`] over a bucket-size vector.
pub fn balance_stats(sizes: &[usize]) -> BalanceStats {
    let n: usize = sizes.iter().sum();
    let nonempty: Vec<f64> = sizes.iter().filter(|&&s| s > 0).map(|&s| s as f64).collect();
    if n == 0 || nonempty.is_empty() {
        return BalanceStats {
            max_fraction: 0.0,
            cv: 0.0,
            nonempty: 0,
        };
    }
    let max = nonempty.iter().cloned().fold(0.0, f64::max);
    let mean = crate::util::stats::mean(&nonempty);
    let sd = crate::util::stats::stddev(&nonempty);
    BalanceStats {
        max_fraction: max / n as f64,
        cv: if mean > 0.0 { sd / mean } else { 0.0 },
        nonempty: nonempty.len(),
    }
}

/// Sort-cost model for a bucket of `len` keys: c · len·log2(len) work.
fn bucket_cost(len: usize) -> f64 {
    if len < 2 {
        return len as f64;
    }
    len as f64 * (len as f64).log2()
}

/// LPT makespan of scheduling `sizes` onto `threads` workers.
pub fn lpt_makespan(sizes: &[usize], threads: usize) -> f64 {
    let mut costs: Vec<f64> = sizes.iter().filter(|&&s| s > 0).map(|&s| bucket_cost(s)).collect();
    costs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut loads = vec![0.0f64; threads.max(1)];
    for c in costs {
        // assign to least-loaded worker (binary-heap-free: linear scan is
        // fine at k <= 4096 buckets)
        let (imin, _) = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        loads[imin] += c;
    }
    loads.iter().cloned().fold(0.0, f64::max)
}

/// Simulated speedup of the partition-then-recurse engine on `threads`
/// cores: sequential cost / (cooperative partition + LPT makespan).
pub fn simulated_speedup(sizes: &[usize], threads: usize) -> f64 {
    let n: usize = sizes.iter().sum();
    if n == 0 {
        return 1.0;
    }
    let threads = threads.max(1);
    // cooperative phases scale with threads; partition pass costs ~2 ops
    // per key (classify + permute)
    let partition_seq = 2.0 * n as f64;
    let recursion_seq: f64 = sizes.iter().map(|&s| bucket_cost(s)).sum();
    let seq = partition_seq + recursion_seq;
    let par = partition_seq / threads as f64 + lpt_makespan(sizes, threads);
    seq / par
}

/// Simulated speedup of the chunk-sort + pairwise-merge baseline
/// (`std::sort(par_unseq)` stand-in). Unlike the partition engines, merge
/// parallelism *decays*: level l has T/2^l merge pairs, and the final
/// merge is a single linear pass — the model the paper's baseline actually
/// exhibits. makespan = (n/T)·log2(n/T) + Σ_l n·2^l/T ≈ ... + 2n.
pub fn simulated_merge_speedup(n: usize, threads: usize) -> f64 {
    if n < 2 {
        return 1.0;
    }
    let t = threads.max(1) as f64;
    let nf = n as f64;
    let seq = bucket_cost(n);
    let chunk = (nf / t).max(2.0);
    let mut makespan = chunk * chunk.log2();
    let levels = (t.log2().ceil()) as usize;
    for l in 1..=levels {
        // T/2^l pairs run concurrently; each merges n·2^l/T keys linearly
        makespan += nf * (1u64 << l) as f64 / t;
    }
    seq / makespan
}

/// Engine-appropriate simulated speedup.
pub fn simulated_engine_speedup(
    engine: SortEngine,
    sizes: &[usize],
    n: usize,
    threads: usize,
) -> f64 {
    match engine {
        SortEngine::StdSort => simulated_merge_speedup(n, threads),
        _ => simulated_speedup(sizes, threads),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;

    #[test]
    fn balanced_buckets_near_linear_speedup() {
        let sizes = vec![1000usize; 256];
        let s = simulated_speedup(&sizes, 48);
        assert!(s > 30.0, "balanced speedup {s}");
    }

    #[test]
    fn one_giant_bucket_kills_speedup() {
        let mut sizes = vec![100usize; 255];
        sizes.push(1_000_000);
        let s = simulated_speedup(&sizes, 48);
        assert!(s < 4.0, "skewed speedup {s}");
    }

    #[test]
    fn stats_detect_skew() {
        let b = balance_stats(&[10, 10, 10, 10]);
        assert!(b.max_fraction < 0.3);
        assert!(b.cv < 1e-9);
        let b = balance_stats(&[1, 1, 998]);
        assert!(b.max_fraction > 0.9);
        assert!(b.cv > 1.0);
    }

    #[test]
    fn paper_mechanism_uniform_dataset() {
        // On uniform data, AIPS2o's learned partition must be at least as
        // balanced as IPS2Ra's radix partition — the paper's Figure 4
        // mechanism.
        let data = datasets::generate_f64("uniform", 300_000, 3).unwrap();
        let a = balance_stats(&top_level_bucket_sizes(&data, SortEngine::Aips2o, 1));
        let r = balance_stats(&top_level_bucket_sizes(&data, SortEngine::Ips2ra, 1));
        assert!(
            a.max_fraction <= r.max_fraction * 1.5 + 0.01,
            "aips2o {a:?} vs ips2ra {r:?}"
        );
    }

    #[test]
    fn radix_skew_on_clustered_data() {
        // OSM cell ids are prefix-clustered: the radix partition must be
        // visibly less balanced than the learned/tree partitions.
        let data = datasets::generate_u64("osm_cellids", 300_000, 3).unwrap();
        let a = balance_stats(&top_level_bucket_sizes(&data, SortEngine::Aips2o, 1));
        let r = balance_stats(&top_level_bucket_sizes(&data, SortEngine::Ips2ra, 1));
        assert!(
            r.max_fraction > a.max_fraction,
            "expected radix skew: aips2o {a:?} vs ips2ra {r:?}"
        );
    }

    #[test]
    fn merge_baseline_speedup_capped_by_final_merge() {
        // the last merge is one linear pass: speedup well under T
        let s48 = simulated_merge_speedup(2_000_000, 48);
        assert!(s48 > 4.0 && s48 < 16.0, "merge speedup {s48}");
        // and a balanced partition engine beats it handily
        let sizes = vec![2_000_000 / 1024; 1024];
        assert!(simulated_speedup(&sizes, 48) > 2.0 * s48);
    }

    #[test]
    fn lpt_makespan_bounds() {
        assert_eq!(lpt_makespan(&[], 4), 0.0);
        let sizes = vec![100usize; 8];
        let one = lpt_makespan(&sizes, 1);
        let four = lpt_makespan(&sizes, 4);
        assert!((one / four - 4.0).abs() < 1e-9);
    }
}
